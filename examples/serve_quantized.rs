//! END-TO-END driver: the full three-layer system on a real workload.
//!
//! 1. Load the *trained* Llama-mini (JAX-trained at build time).
//! 2. Quantize every projection with ICQuant^SK at 2 bits + 5 % outliers
//!    (≈2.3 bits/weight storage) into a single `ICQZ` container,
//!    register it in the artifact registry, and report ppl before/after
//!    through the PJRT-compiled eval graph.
//! 3. Start the serving coordinator (dynamic batcher + prefill/decode
//!    KV-cache scheduler over AOT-compiled HLO) **loading its weights
//!    from the registered container through the LRU runtime-plane
//!    cache**, and serve a batched workload of corpus prompts.
//! 4. Serve the *same container* again through the **native fused-kernel
//!    backend** (`icquant::kernels`): every projection is a gather+FMA
//!    GEMM straight off the (n+1)-bit runtime planes — no PJRT, no f32
//!    weight plane, and the decode cache is shared with step 3, so the
//!    planes are not decoded twice.
//!
//!     cargo run --release --example serve_quantized
//!
//! This is the system the paper's intro motivates: weights live at
//! ≈2.3 bits in a checksummed, content-addressed artifact; Python never
//! runs at request time.

use icquant::coordinator::backend::{NativeBackend, PjrtBackend};
use icquant::coordinator::{SchedulerKind, ServeConfig, Server};
use icquant::eval::{load_corpus_tokens, perplexity, weight_literals};
use icquant::icquant::IcqConfig;
use icquant::kernels::NativeModel;
use icquant::model::{artifacts_dir, TrainedModel};
use icquant::quant::QuantizerKind;
use icquant::runtime::Engine;
use icquant::store::{container, quantize_trained, DecodeCache, Registry, StoredModel};
use icquant::util::human_bytes;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let model = TrainedModel::load(&dir)?;
    model.validate()?;
    println!(
        "loaded Llama-mini: {} layers, d={}, {} projection params",
        model.config.n_layers,
        model.config.d_model,
        model.projection_params()
    );

    // --- quantize → pack → register ----------------------------------------
    let cfg = IcqConfig {
        bits: 2,
        outlier_ratio: 0.05,
        gap_bits: 0, // Lemma-1-optimal b for γ
        quantizer: QuantizerKind::SensitiveKmeans,
    };
    let t0 = Instant::now();
    let packed = quantize_trained(&model, &cfg)?;
    let registry = Registry::open(Registry::default_root())?;
    let record = registry.put_model("llama-mini-icq2", &packed)?;
    let (_, container_path) = registry.resolve(&record.spec())?;
    let info = container::inspect(&container_path)?;
    println!(
        "\nquantized with ICQuant^SK in {:.2}s → {}",
        t0.elapsed().as_secs_f64(),
        record.spec()
    );
    println!(
        "  bits/weight: {:.3} storage ({:.3} code) | container {}",
        info.storage_bits_per_weight,
        info.code_bits_per_weight,
        human_bytes(record.bytes)
    );
    let fp_bytes = model.projection_params() * 4;
    println!(
        "  projection storage {} → ≈{} ({:.1}x smaller than fp32)",
        human_bytes(fp_bytes as u64),
        human_bytes((model.projection_params() as f64 * info.storage_bits_per_weight / 8.0) as u64),
        fp_bytes as f64 * 8.0 / (model.projection_params() as f64 * info.storage_bits_per_weight),
    );
    assert!(registry.verify(&record.spec())?.ok(), "fresh artifact failed verify");

    // --- perplexity before/after (container decode path) -------------------
    let cache = Arc::new(DecodeCache::new(512 << 20));
    let stored = StoredModel::open(&container_path, cache.clone())?;
    let qmodel = stored.to_trained_model()?;
    let mut engine = Engine::new(&dir)?;
    let test = load_corpus_tokens(&dir, "test")?;
    let fp_ppl = perplexity(&mut engine, weight_literals(&model)?, &test, 8)?;
    let q_ppl = perplexity(&mut engine, weight_literals(&qmodel)?, &test, 8)?;
    println!(
        "\ntest perplexity: fp32 {:.3} → ICQuant^SK {:.3} ({:+.2}%)",
        fp_ppl,
        q_ppl,
        (q_ppl / fp_ppl - 1.0) * 100.0
    );
    drop(engine);

    // --- serve from the container ------------------------------------------
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(15),
        max_new_tokens: 24,
        buckets: vec![1, 2, 4, 8],
        prefill_len: 64,
        pad_id: b' ' as i32,
        // PJRT's compiled buckets are served in run-to-completion waves
        // (the backend cannot splice a sequence into live batch KV).
        scheduler: SchedulerKind::RunToCompletion,
    };
    println!("\nstarting coordinator from {} (buckets {:?})…", record.spec(), cfg.buckets);
    let dir2 = dir.clone();
    let cpath = container_path.clone();
    let serve_cache = cache.clone();
    let server = Server::start(cfg, move || {
        let mut b = PjrtBackend::from_container(&dir2, &cpath, serve_cache)?;
        b.warmup()?;
        Ok(b)
    });

    let corpus = load_corpus_tokens(&dir, "test")?;
    let n_requests = 24;
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        let start = (i * 5077) % (corpus.len() - 128);
        let prompt = corpus[start..start + 48].to_vec();
        rxs.push(server.submit(prompt, 24)?.1);
    }
    let mut sample = None;
    let mut total_tokens = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(600))?;
        anyhow::ensure!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        total_tokens += resp.tokens.len();
        if i == 0 {
            sample = Some(resp.tokens.clone());
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics.snapshot();
    let cstats = cache.stats();

    println!("\n=== end-to-end serving report (quantized model) ===");
    println!("requests / tokens      : {} / {}", snap.requests, total_tokens);
    println!("throughput             : {:.1} tokens/s", total_tokens as f64 / wall);
    println!("batches (avg size)     : {} ({:.2})", snap.batches, snap.avg_batch_size);
    println!("avg prefill            : {:.1} ms", snap.avg_prefill_ms);
    println!("avg decode per token   : {:.1} ms", snap.avg_decode_ms_per_token);
    println!("p50 / p99 latency      : {:.0} / {:.0} ms", snap.p50_latency_ms, snap.p99_latency_ms);
    println!(
        "decode cache           : {} hits / {} misses ({})",
        cstats.hits,
        cstats.misses,
        human_bytes(cstats.decoded_bytes)
    );
    if let Some(tokens) = sample {
        let text: String = tokens
            .iter()
            .map(|&t| t as u8 as char)
            .map(|c| if c.is_ascii_graphic() || c == ' ' { c } else { '?' })
            .collect();
        println!("sample continuation    : {:?}", text);
    }
    server.shutdown();

    // --- serve the same container through the native fused kernels ---------
    let stored = StoredModel::open(&container_path, cache.clone())?;
    let native = NativeModel::from_stored(&stored, 0)?;
    println!(
        "\nstarting native fused-kernel coordinator ({} resident vs {} f32, {}-wide kernel pool)…",
        human_bytes(native.quantized_bytes() as u64),
        human_bytes(native.dequantized_bytes() as u64),
        native.threads()
    );
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(15),
        max_new_tokens: 24,
        buckets: vec![1, 2, 4, 8],
        prefill_len: 64,
        pad_id: b' ' as i32,
        // The native backend admits mid-decode: freed KV slots refill
        // from the queue between decode steps (DESIGN.md §9).
        scheduler: SchedulerKind::Continuous,
    };
    let server = Server::start(cfg, move || Ok(NativeBackend::new(native)));
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        let start = (i * 5077) % (corpus.len() - 128);
        let prompt = corpus[start..start + 48].to_vec();
        rxs.push(server.submit(prompt, 24)?.1);
    }
    let mut total_tokens = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(600))?;
        anyhow::ensure!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        total_tokens += resp.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics.snapshot();
    println!("\n=== native fused-kernel serving report ===");
    println!("requests / tokens      : {} / {}", snap.requests, total_tokens);
    println!("throughput             : {:.1} tokens/s", total_tokens as f64 / wall);
    println!("avg decode per token   : {:.1} ms", snap.avg_decode_ms_per_token);
    println!(
        "paged KV cache         : {} prefix block hits, {}/{} blocks peak/total, {} evicted",
        snap.prefix_hits, snap.blocks_in_use_peak, snap.kv_total_blocks, snap.blocks_evicted
    );
    let cstats = cache.stats();
    println!(
        "shared plane cache     : {} hits / {} misses — the PJRT phase's decodes were reused",
        cstats.hits, cstats.misses
    );
    server.shutdown();
    println!("\nserve_quantized OK");
    Ok(())
}

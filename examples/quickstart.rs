//! Quickstart: quantize a weight matrix with ICQuant, inspect the
//! storage breakdown, round-trip through the on-disk artifact, and run a
//! mat-vec off the quantized runtime plane.
//!
//!     cargo run --release --example quickstart

use icquant::icq::{lemma1_bound, optimal_b};
use icquant::icquant::{packed, IcqConfig, IcqMatrix};
use icquant::quant::{self, QuantizerKind};
use icquant::synthzoo;
use icquant::util::human_bytes;

fn main() -> anyhow::Result<()> {
    // 1. A heavy-tailed weight matrix (one synthetic output layer; swap in
    //    your own `Matrix` here).
    let (rows, cols) = (512, 2048);
    let w = synthzoo::demo_matrix(rows, cols, 42);
    println!("weights: {}x{} f32 ({})", rows, cols, human_bytes((rows * cols * 4) as u64));

    // 2. Pick the operating point: 2-bit codes, 5 % outliers, Lemma-1
    //    optimal gap width.
    let gamma = 0.05;
    let cfg = IcqConfig {
        bits: 2,
        outlier_ratio: gamma,
        gap_bits: 0, // 0 = auto (argmin of the Lemma 1 bound)
        quantizer: QuantizerKind::Rtn,
    };
    println!(
        "\nLemma 1: optimal b at γ={:.0}% is {} (bound {:.3} bits/weight)",
        gamma * 100.0,
        optimal_b(gamma),
        lemma1_bound(gamma, optimal_b(gamma))
    );

    // 3. Quantize.
    let q = IcqMatrix::quantize(&w, None, &cfg)?;
    println!("\nstorage breakdown (bits/weight):");
    println!("  codes          : {:.3}", q.bits as f64);
    println!("  outlier indices: {:.3}  ← the paper's ≈0.31-bit index code", q.index_bits_per_weight());
    println!("  codebooks      : {:.3}", q.codebook_bits_per_weight());
    println!("  total          : {:.3}", q.avg_bits_per_weight_full());

    // 4. Compare against the alternatives at the same base bits.
    let rec = q.dequantize();
    let vanilla2 = quant::quantize_per_row(&w, None, QuantizerKind::Rtn, 2).dequantize();
    let vanilla3 = quant::quantize_per_row(&w, None, QuantizerKind::Rtn, 3).dequantize();
    println!("\nreconstruction MSE:");
    println!("  vanilla RTN 2-bit      : {:.3e}", w.mse(&vanilla2));
    println!("  ICQuant 2-bit ({:.2}b)  : {:.3e}", q.avg_bits_per_weight(), w.mse(&rec));
    println!("  vanilla RTN 3-bit      : {:.3e}  ← ICQuant matches this", w.mse(&vanilla3));

    // 5. Serialize → load → decode to the runtime plane → matvec.
    let path = std::env::temp_dir().join("quickstart.icqm");
    packed::save(&q, &path)?;
    println!(
        "\nartifact: {} ({} = {:.2} bits/weight on disk)",
        path.display(),
        human_bytes(std::fs::metadata(&path)?.len()),
        std::fs::metadata(&path)?.len() as f64 * 8.0 / (rows * cols) as f64
    );
    let loaded = packed::load(&path)?;
    let rt = loaded.to_runtime();
    let x: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.01).sin()).collect();
    let mut y = vec![0.0f32; rows];
    rt.matvec(&x, &mut y);
    println!("matvec off the quantized plane: y[0..4] = {:?}", &y[..4]);
    println!("\nquickstart OK");
    Ok(())
}

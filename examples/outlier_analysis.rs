//! Outlier analysis walk-through: the §2 statistics pipeline on both the
//! synthetic zoo and the *trained* Llama-mini weights — range share,
//! positional uniformity (chi-square), and what they imply for the index
//! coding cost.
//!
//!     cargo run --release --example outlier_analysis

use icquant::icq::bound::empirical_overhead;
use icquant::icq::{lemma1_bound, optimal_b};
use icquant::model::{artifacts_dir, TrainedModel};
use icquant::quant::mixed_precision::top_k_by_magnitude;
use icquant::stats::{avg_range_taken, rejection_rate};
use icquant::synthzoo::{family, LayerType};

fn analyze(label: &str, w: &icquant::util::tensor::Matrix, gamma: f64) {
    let range = avg_range_taken(w, gamma);
    // Choose a group size that gives the chi-square test resolution.
    let group = (w.cols / 8).max(16);
    let rej = rejection_rate(w, 0.0625, group, 0.05);
    let k = ((gamma * w.cols as f64) as usize).max(1);
    let rows: Vec<Vec<usize>> = (0..w.rows)
        .map(|r| top_k_by_magnitude(w.row(r), k))
        .collect();
    let b = optimal_b(gamma);
    let emp = empirical_overhead(&rows, w.cols, b);
    println!(
        "{:<22} {:>6}x{:<5} {:>9.3} {:>11.1}% {:>8} {:>9.4} {:>9.4}",
        label,
        w.rows,
        w.cols,
        range,
        rej * 100.0,
        b,
        emp,
        lemma1_bound(gamma, b),
    );
}

fn main() -> anyhow::Result<()> {
    let gamma = 0.05;
    println!(
        "{:<22} {:>12} {:>9} {:>12} {:>8} {:>9} {:>9}",
        "layer", "shape", "range@5%", "chi2 reject", "b*", "B emp", "B bound"
    );

    println!("-- synthetic zoo (llama2-7b-sim, statistics width) --");
    let f = family("llama2-7b").unwrap();
    for lt in [LayerType::QProj, LayerType::OProj, LayerType::DownProj] {
        let w = f.gen_stat_layer(lt, 0);
        analyze(lt.name(), &w, gamma);
    }

    match TrainedModel::load(&artifacts_dir()) {
        Ok(m) => {
            println!("-- trained Llama-mini projections --");
            for name in ["l0.wq", "l1.wo", "l2.w_up", "l3.w_down"] {
                if let Some(t) = m.get(name) {
                    analyze(name, &t.as_matrix(), gamma);
                }
            }
            println!(
                "\nTakeaway: trained weights show the same ≈uniform outlier\n\
                 placement as the zoo ⇒ the measured index-code cost B sits\n\
                 on the Lemma 1 bound, so ICQuant's 0.3-bit overhead claim\n\
                 transfers to real trained transformers."
            );
        }
        Err(_) => println!("(run `make artifacts` to include the trained model)"),
    }
    Ok(())
}

#!/usr/bin/env bash
# CI for the rust crate: build, test, format, lint, and record the store
# bench. Mirrors the tier-1 verify (`cargo build --release && cargo test
# -q`) plus hygiene gates.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "=== cargo build --release ==="
cargo build --release

echo "=== cargo test -q ==="
cargo test -q

echo "=== cargo fmt --check ==="
cargo fmt --check

echo "=== cargo clippy -- -D warnings ==="
cargo clippy --all-targets -- -D warnings

echo "=== cargo doc --no-deps (broken intra-doc links fail) ==="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "=== kernels bench → BENCH_kernels.json ==="
# Fused GEMV vs dequantize-then-matmul; asserts equal results and the
# peak-resident-bytes win, records thread scaling.
if cargo bench --bench kernels; then
    if [ -f BENCH_kernels.json ]; then
        mv BENCH_kernels.json ../BENCH_kernels.json
        echo "recorded ../BENCH_kernels.json"
    fi
else
    echo "WARNING: kernels bench failed; BENCH_kernels.json not refreshed" >&2
fi

echo "=== serving bench → BENCH_serving.json ==="
# Continuous-batching vs run-to-completion on the mixed-length staggered
# workload; asserts identical per-request outputs across schedulers and
# records the throughput / short-request-p50 trajectory per PR.
if cargo bench --bench serving; then
    if [ -f BENCH_serving.json ]; then
        mv BENCH_serving.json ../BENCH_serving.json
        echo "recorded ../BENCH_serving.json"
    fi
else
    echo "WARNING: serving bench failed; BENCH_serving.json not refreshed" >&2
fi

echo "=== store bench → BENCH_store.json ==="
# The bench binary writes BENCH_store.json into the working directory;
# keep the recorded copy at the repo root next to this script.
if cargo bench --bench store; then
    if [ -f BENCH_store.json ]; then
        mv BENCH_store.json ../BENCH_store.json
        echo "recorded ../BENCH_store.json"
    fi
else
    echo "WARNING: store bench failed; BENCH_store.json not refreshed" >&2
fi

echo "CI OK"

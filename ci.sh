#!/usr/bin/env bash
# CI for the rust crate: build, test, format, lint, and record the store
# bench. Mirrors the tier-1 verify (`cargo build --release && cargo test
# -q`) plus hygiene gates.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "=== cargo build --release ==="
cargo build --release

echo "=== cargo test -q ==="
cargo test -q

echo "=== cargo fmt --check ==="
cargo fmt --check

echo "=== cargo clippy -q -- -D warnings ==="
cargo clippy -q --all-targets -- -D warnings

echo "=== cargo doc --no-deps (broken intra-doc links fail) ==="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "=== kernels bench → BENCH_kernels.json ==="
# Packed-vs-byte plane and pool-vs-spawn A/Bs; asserts bit-identical
# results, the peak-resident-bytes win, and the 2-bit plane shrink.
# This bench is a CI gate: it must run and must record the required
# keys, or the packed-serving claims are unbacked.
cargo bench --bench kernels
test -f BENCH_kernels.json || { echo "FAIL: kernels bench wrote no BENCH_kernels.json" >&2; exit 1; }
mv BENCH_kernels.json ../BENCH_kernels.json
echo "recorded ../BENCH_kernels.json"
for key in bytes_per_weight fused_vs_dequant_speedup plane_shrink_ratio_2bit pool_vs_spawn_speedup; do
    grep -q "\"$key\"" ../BENCH_kernels.json \
        || { echo "FAIL: BENCH_kernels.json missing required key '$key'" >&2; exit 1; }
done

echo "=== serving bench → BENCH_serving.json ==="
# Continuous-batching vs run-to-completion on the mixed-length staggered
# workload; asserts identical per-request outputs across schedulers and
# records the throughput / short-request-p50 trajectory per PR.
if cargo bench --bench serving; then
    if [ -f BENCH_serving.json ]; then
        mv BENCH_serving.json ../BENCH_serving.json
        echo "recorded ../BENCH_serving.json"
    fi
else
    echo "WARNING: serving bench failed; BENCH_serving.json not refreshed" >&2
fi

echo "=== store bench → BENCH_store.json ==="
# The bench binary writes BENCH_store.json into the working directory;
# keep the recorded copy at the repo root next to this script.
if cargo bench --bench store; then
    if [ -f BENCH_store.json ]; then
        mv BENCH_store.json ../BENCH_store.json
        echo "recorded ../BENCH_store.json"
    fi
else
    echo "WARNING: store bench failed; BENCH_store.json not refreshed" >&2
fi

echo "CI OK"

#!/usr/bin/env bash
# CI for the rust crate: build, test, format, lint, and record the store
# bench. Mirrors the tier-1 verify (`cargo build --release && cargo test
# -q`) plus hygiene gates.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "=== cargo build --release ==="
cargo build --release

echo "=== cargo test -q (ICQ_SIMD=scalar: bit-identity reference tier) ==="
# The scalar tier must reproduce the pre-SIMD kernels bit-exactly
# (DESIGN.md §14), so the whole suite runs once pinned to it...
ICQ_SIMD=scalar cargo test -q

echo "=== cargo test -q (ICQ_SIMD=auto: detected vector tier) ==="
# ...and once on the host's auto-detected tier, where the divergence
# suite enforces the bounded-error contract on every vectorized loop.
ICQ_SIMD=auto cargo test -q

echo "=== icquant lint (in-tree static analysis, DESIGN.md section 13) ==="
# Hard gate: SAFETY/ORDERING/PANIC justification coverage, hot-path
# allocation bans, DESIGN.md section references, BENCH key emission,
# and the trace-name registry must all hold on the real tree.
./target/release/icquant lint --root ..

echo "=== randomized suites: seed × pool-worker matrix ==="
# Re-run the scheduler fuzz harness and the end-to-end pipeline property
# under several seeds and kernel-pool widths (DESIGN.md §10). The
# harness prints its completed-case counts; the run is gated on the
# fuzz harness finishing at least 64 randomized cases per matrix cell.
FUZZ_LOG_DIR=$(mktemp -d)
for seed in 1 2; do
    for workers in 1 4; do
        log="$FUZZ_LOG_DIR/fuzz_s${seed}_w${workers}.log"
        echo "--- ICQ_TEST_SEED=$seed ICQ_POOL_WORKERS=$workers ---"
        ICQ_TEST_SEED=$seed ICQ_POOL_WORKERS=$workers \
            cargo test -q --test scheduler_fuzz --test e2e_pipeline -- --nocapture \
            | tee "$log"
        # `|| true`: grep exits 1 on zero matches, which under pipefail
        # would abort the script before the FAIL diagnostic below —
        # awk's `s + 0` already yields 0 for an empty stream.
        cases=$( (grep -o 'scheduler_fuzz: completed [0-9]*' "$log" || true) \
            | awk '{s += $3} END {print s + 0}')
        if [ "$cases" -lt 64 ]; then
            echo "FAIL: fuzz harness completed only $cases randomized cases (< 64)" >&2
            exit 1
        fi
        echo "fuzz harness: $cases randomized cases (seed=$seed workers=$workers)"
    done
done
rm -rf "$FUZZ_LOG_DIR"

echo "=== streaming delivery tier (ICQ_FUZZ_STREAMING=1, ICQ_SIMD=scalar) ==="
# Run the scheduler suite once with every fuzz submission routed through
# submit_streaming on the scalar kernel tier (DESIGN.md §15): the
# per-token channel must reproduce the whole-mode outputs bit-exactly,
# and the dedicated streaming property tests run in the same pass.
ICQ_FUZZ_STREAMING=1 ICQ_SIMD=scalar \
    cargo test -q --test scheduler_fuzz --test streaming

echo "=== cargo fmt --check ==="
cargo fmt --check

echo "=== cargo clippy -q -- -D warnings (+ unsafe-doc/todo/dbg lints) ==="
cargo clippy -q --all-targets -- -D warnings \
    -D clippy::undocumented_unsafe_blocks -D clippy::todo -D clippy::dbg_macro

echo "=== optional sanitizer tier (nightly miri / tsan) ==="
# Deeper checking when the toolchain supports it; skipped with a visible
# notice otherwise (this container ships no rustup nightly). Miri runs
# the pool and trace unit tests (raw-pointer trampolines, ring
# registration); TSan rebuilds std and runs the scheduler fuzz harness.
if command -v rustup >/dev/null 2>&1 && rustup toolchain list 2>/dev/null | grep -q '^nightly'; then
    if rustup component list --toolchain nightly 2>/dev/null | grep -q '^miri.*(installed)'; then
        echo "--- cargo +nightly miri test: kernels::pool + trace unit tests ---"
        MIRIFLAGS="-Zmiri-disable-isolation" \
            cargo +nightly miri test -q --lib kernels::pool trace
    else
        echo "NOTICE: nightly toolchain lacks the miri component — skipping Miri tier" >&2
    fi
    if rustup component list --toolchain nightly 2>/dev/null | grep -q '^rust-src.*(installed)'; then
        host=$(rustc -vV | awk '/^host:/ {print $2}')
        echo "--- ThreadSanitizer: tests/scheduler_fuzz.rs ($host) ---"
        RUSTFLAGS="-Zsanitizer=thread" \
            cargo +nightly test -q -Zbuild-std --target "$host" --test scheduler_fuzz
    else
        echo "NOTICE: nightly toolchain lacks rust-src — skipping TSan tier" >&2
    fi
else
    echo "NOTICE: no rustup nightly toolchain — skipping sanitizer tier (Miri + TSan)" >&2
fi

echo "=== cargo doc --no-deps (broken intra-doc links fail) ==="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "=== kernels bench → BENCH_kernels.json ==="
# Packed-vs-byte plane and pool-vs-spawn A/Bs; asserts bit-identical
# results, the peak-resident-bytes win, and the 2-bit plane shrink.
# This bench is a CI gate: it must run and must record the required
# keys, or the packed-serving claims are unbacked.
cargo bench --bench kernels
test -f BENCH_kernels.json || { echo "FAIL: kernels bench wrote no BENCH_kernels.json" >&2; exit 1; }
mv BENCH_kernels.json ../BENCH_kernels.json
echo "recorded ../BENCH_kernels.json"
for key in bytes_per_weight fused_vs_dequant_speedup plane_shrink_ratio_2bit pool_vs_spawn_speedup \
        simd_vs_scalar_speedup simd_tier int8_act_speedup; do
    grep -q "\"$key\"" ../BENCH_kernels.json \
        || { echo "FAIL: BENCH_kernels.json missing required key '$key'" >&2; exit 1; }
done

echo "=== paging bench → BENCH_paging.json ==="
# Paged-vs-contiguous layout A/B and the shared-system-prompt TTFT
# workload (DESIGN.md §10). Hard gate: the bench asserts bit-identical
# streams across layouts and a measured prefill win from prefix reuse,
# and the recorded JSON must carry the required keys.
cargo bench --bench paging
test -f BENCH_paging.json || { echo "FAIL: paging bench wrote no BENCH_paging.json" >&2; exit 1; }
mv BENCH_paging.json ../BENCH_paging.json
echo "recorded ../BENCH_paging.json"
for key in paged_vs_contiguous_ratio shared_prefix_ttft_speedup shared_prefix_prefill_speedup \
        prefix_hits block_utilization \
        kv_bytes_per_token_f32 kv_bytes_per_token_kv8 kv_bytes_per_token_kv4 \
        resident_tokens_per_mib_f32 resident_tokens_per_mib_kv8 resident_tokens_per_mib_kv4 \
        kv8_resident_ratio kv4_resident_ratio; do
    grep -q "\"$key\"" ../BENCH_paging.json \
        || { echo "FAIL: BENCH_paging.json missing required key '$key'" >&2; exit 1; }
done

echo "=== serving bench → BENCH_serving.json ==="
# Continuous-batching vs run-to-completion on the mixed-length staggered
# workload; asserts identical per-request outputs across schedulers,
# records the throughput / short-request-p50 trajectory per PR, and
# asserts the disabled tracer stays within 2% of a decode step
# (recorded as trace_overhead_pct). Hard gate: the bench must run and
# the recorded JSON must carry the required keys.
cargo bench --bench serving
test -f BENCH_serving.json || { echo "FAIL: serving bench wrote no BENCH_serving.json" >&2; exit 1; }
mv BENCH_serving.json ../BENCH_serving.json
echo "recorded ../BENCH_serving.json"
for key in throughput_speedup short_p50_speedup trace_overhead_pct trace_disabled_ns_per_call; do
    grep -q "\"$key\"" ../BENCH_serving.json \
        || { echo "FAIL: BENCH_serving.json missing required key '$key'" >&2; exit 1; }
done

echo "=== workloads bench → BENCH_workloads.json ==="
# Trace-replay workload zoo (DESIGN.md §15): chat with shared system
# prompts, long-document summarization, bursty multi-tenant arrivals,
# adversarial over-long prompts, mid-stream disconnects, and a
# mixed-priority overload. Hard gates inside the bench: the overload
# scenario must show high-priority p99 TTFT strictly below low
# priority, disconnect clients must be cancelled, and sheds must be
# accounted; the recorded JSON must carry the required keys.
cargo bench --bench workloads
test -f BENCH_workloads.json \
    || { echo "FAIL: workloads bench wrote no BENCH_workloads.json" >&2; exit 1; }
mv BENCH_workloads.json ../BENCH_workloads.json
echo "recorded ../BENCH_workloads.json"
for key in p50_ttft_ms_high p99_ttft_ms_high p50_ttft_ms_low p99_ttft_ms_low \
        shed_requests cancelled_requests; do
    grep -q "\"$key\"" ../BENCH_workloads.json \
        || { echo "FAIL: BENCH_workloads.json missing required key '$key'" >&2; exit 1; }
done

echo "=== serve_demo trace → trace-check ==="
# End-to-end observability gate: run the native serving demo with the
# flight recorder armed, then validate the emitted Chrome-trace JSON
# (non-empty, balanced spans, monotone per-thread timestamps, and all
# four event categories: request / scheduler / pool / kv).
TRACE_OUT=$(mktemp -t icq_trace_XXXX.json)
./target/release/icquant serve --backend native --family llama3.2-1b \
    --requests 8 --batch 4 --tokens 8 --trace-out "$TRACE_OUT"
./target/release/icquant trace-check "$TRACE_OUT"
rm -f "$TRACE_OUT"
# Same gate with 4-bit quantized KV blocks (ISSUE 7): the trace must
# stay well-formed when the kv category carries quantize_block /
# dequant_write events and the report shows quantized accounting.
TRACE_OUT_KV=$(mktemp -t icq_trace_kv4_XXXX.json)
./target/release/icquant serve --backend native --family llama3.2-1b \
    --requests 8 --batch 4 --tokens 8 --kv-bits 4 --trace-out "$TRACE_OUT_KV"
./target/release/icquant trace-check "$TRACE_OUT_KV"
rm -f "$TRACE_OUT_KV"
# SIMD-tier knobs (DESIGN.md §14): a pinned-scalar int8-activation serve
# must complete and emit a valid trace carrying kernel_dispatch instants.
TRACE_OUT_SIMD=$(mktemp -t icq_trace_simd_XXXX.json)
./target/release/icquant serve --backend native --family llama3.2-1b \
    --requests 8 --batch 4 --tokens 8 --simd scalar --act-quant int8 \
    --trace-out "$TRACE_OUT_SIMD"
./target/release/icquant trace-check "$TRACE_OUT_SIMD"
grep -q '"kernel_dispatch"' "$TRACE_OUT_SIMD" \
    || { echo "FAIL: serve trace carries no kernel_dispatch instants" >&2; exit 1; }
rm -f "$TRACE_OUT_SIMD"

echo "=== store bench → BENCH_store.json ==="
# The bench binary writes BENCH_store.json into the working directory;
# keep the recorded copy at the repo root next to this script.
if cargo bench --bench store; then
    if [ -f BENCH_store.json ]; then
        mv BENCH_store.json ../BENCH_store.json
        echo "recorded ../BENCH_store.json"
    fi
else
    echo "WARNING: store bench failed; BENCH_store.json not refreshed" >&2
fi

echo "CI OK"

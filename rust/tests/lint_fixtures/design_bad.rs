//! Positive cases for the `design-ref` checker. The fixture test runs
//! this against a synthetic section set containing only §1 and §2.
//!
//! A dangling pointer: DESIGN.md §9 does not exist here. //~ expect: design-ref

/// Also bad: a bare DESIGN.md § reference with no number. //~ expect: design-ref
pub fn nothing() {}

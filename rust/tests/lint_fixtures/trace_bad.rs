// Positive cases for the `trace-names` checker. The fixture test runs
// this against a synthetic registry containing only "registered_demo",
// under a rust/src/ relative path.

pub fn record_things(id: u64) {
    crate::trace::instant(Cat::Sched, "registered_demo", id, 0, 0);
    crate::trace::instant(Cat::Sched, "unregistered_demo", id, 0, 0); //~ expect: trace-names
    let name = "dynamic";
    let _s = crate::trace::span(Cat::Sched, name, id); //~ expect: trace-names
}

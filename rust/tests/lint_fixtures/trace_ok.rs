// Negative cases for the `trace-names` checker: a registered literal
// name, a call in test code, and the pattern spelled inside a string.

pub fn record_things(id: u64) {
    crate::trace::instant(Cat::Sched, "registered_demo", id, 0, 0);
}

pub fn pattern_in_string() -> &'static str {
    "trace::instant(Cat::Sched, \"unregistered_demo\", 0, 0, 0);"
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_sites_are_exempt() {
        crate::trace::instant(Cat::Sched, "test_only_name", 1, 0, 0);
    }
}

// Synthetic trace-name registry for the fixture tests: one entry that
// the trace_bad/trace_ok fixtures record, one duplicate, one unused.

pub const DEMO: &str = "registered_demo";
pub const UNUSED: &str = "never_recorded"; //~ expect: trace-names
pub const DUP: &str = "registered_demo"; //~ expect: trace-names

// Negative cases for the `hot-path` checker: a tagged fn that stays on
// the stack, and an untagged fn that may allocate freely.

/// Dot product over two slices; stack-only.
// lint: hot-path
#[inline]
pub fn hot_dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Untagged helpers are outside the checker's scope.
pub fn cold_collect(n: usize) -> Vec<f32> {
    let mut v = Vec::with_capacity(n);
    v.resize(n, 0.0);
    v
}

pub fn strings_do_not_count() -> &'static str {
    // The banned spellings below live in a string literal.
    "Vec::new format! .push("
}

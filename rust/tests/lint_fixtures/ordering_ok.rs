// Negative cases for the `ordering` checker: every use below is justified
// (site comment, cluster comment, or enclosing fn doc) or exempt.

use std::sync::atomic::{AtomicUsize, Ordering};

static N: AtomicUsize = AtomicUsize::new(0);
static M: AtomicUsize = AtomicUsize::new(0);

pub fn site_comment() -> usize {
    // ORDERING: relaxed — standalone fixture counter, no payload published.
    N.fetch_add(1, Ordering::Relaxed)
}

pub fn trailing_comment() -> usize {
    N.load(Ordering::Relaxed) // ORDERING: relaxed — monotonic read, staleness fine.
}

pub fn cluster() {
    // ORDERING: relaxed — independent statistics counters; one comment
    // covers the whole adjacent cluster of sites.
    N.store(0, Ordering::Relaxed);
    M.store(0, Ordering::Relaxed);
}

/// Reset both counters.
///
/// ORDERING: relaxed throughout — fn-level justification covers the body.
pub fn fn_doc_level() {
    N.store(0, Ordering::Relaxed);
    M.store(0, Ordering::Relaxed);
}

pub fn named_orderings() -> usize {
    // Acquire/Release/AcqRel encode intent in the name and are exempt.
    N.load(Ordering::Acquire) + M.swap(0, Ordering::AcqRel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_code_is_exempt() {
        N.store(0, Ordering::Relaxed);
        assert_eq!(N.load(Ordering::SeqCst), 0);
    }
}

//! Negative cases for the `design-ref` checker, run against a synthetic
//! section set containing §1 and §2: both references below resolve.
//!
//! Layout notes live in DESIGN.md §1; the pipeline is DESIGN.md §2.

pub fn nothing() -> &'static str {
    // String literals are not scanned for references:
    "see DESIGN.md §40 for nothing"
}

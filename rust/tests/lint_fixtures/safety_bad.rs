// Positive cases for the `safety` checker: every site below is missing
// its justification and must produce exactly one diagnostic.
//
// NOTE: this directory is excluded from the real `icquant lint` walk and
// is never compiled; files are parsed by the analyzer only.

static mut COUNTER: usize = 0;

pub fn bump() -> usize {
    unsafe { //~ expect: safety
        COUNTER += 1;
        COUNTER
    }
}

struct Wrap(*const u8);

unsafe impl Send for Wrap {} //~ expect: safety

pub unsafe fn peek(p: *const u8) -> u8 { //~ expect: safety
    *p
}

// Positive cases for the `hot-path` checker: a tagged fn that allocates
// and locks, plus a tag that is attached to nothing.

/// Sums the input, but allocates scratch on the way.
// lint: hot-path
pub fn hot_sum(xs: &[f32]) -> f32 {
    let mut scratch = Vec::new(); //~ expect: hot-path
    scratch.push(0.0f32); //~ expect: hot-path
    let label = format!("n={}", xs.len()); //~ expect: hot-path
    let _ = label;
    xs.iter().sum::<f32>() + scratch[0]
}

// lint: hot-path //~ expect: hot-path

pub struct NotAFn;

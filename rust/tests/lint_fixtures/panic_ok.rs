// Negative cases for the `panic` checker, analyzed as if under
// rust/src/coordinator/: the lock-poisoning idiom, a justified unwrap,
// and test code are all quiet.

use std::sync::{Condvar, Mutex};

pub struct Gate {
    mx: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    /// Lock-poisoning propagation is idiomatic and exempt.
    pub fn wait_open(&self) {
        let mut open = self.mx.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

pub fn justified(xs: &[i32]) -> i32 {
    // PANIC: callers guarantee non-empty input by construction.
    *xs.first().unwrap()
}

pub fn trailing(xs: &[i32]) -> i32 {
    *xs.last().unwrap() // PANIC: length checked by the caller.
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v = [1i32];
        assert_eq!(*v.first().unwrap(), 1);
    }
}

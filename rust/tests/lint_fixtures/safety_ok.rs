// Negative cases for the `safety` checker: every site below carries the
// justification the checker wants, or is not an unsafe site at all.

static mut COUNTER: usize = 0;

pub fn bump() -> usize {
    // SAFETY: single-threaded fixture; no aliased access to COUNTER.
    unsafe {
        COUNTER += 1;
        COUNTER
    }
}

struct Wrap(*const u8);

// SAFETY: the pointer is only dereferenced on the owning thread.
unsafe impl Send for Wrap {}

/// Read one byte through a raw pointer.
///
/// # Safety
/// `p` must be valid for reads of one byte.
pub unsafe fn peek(p: *const u8) -> u8 {
    *p
}

// SAFETY: justification above attributes also counts.
#[allow(dead_code)]
unsafe fn attributed() {}

/// A fn-pointer *type* is not an unsafe declaration.
pub struct Table {
    pub call: unsafe fn(*const u8) -> u8,
}

pub fn not_code() -> &'static str {
    // The word below lives in a string literal, not in code.
    "unsafe { ignored }"
}

// Positive cases for the `ordering` checker: Relaxed/SeqCst uses with no
// justification anywhere the checker looks.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::atomic::Ordering::SeqCst; //~ expect: ordering

static N: AtomicUsize = AtomicUsize::new(0);

pub fn bump() -> usize {
    N.fetch_add(1, Ordering::Relaxed) //~ expect: ordering
}

pub fn strict() -> usize {
    N.load(Ordering::SeqCst) //~ expect: ordering
}

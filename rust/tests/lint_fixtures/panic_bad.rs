// Positive cases for the `panic` checker. Fixture tests analyze this
// file as if it lived under rust/src/coordinator/, where the policy
// applies.

pub fn first(xs: &[i32]) -> i32 {
    *xs.first().unwrap() //~ expect: panic
}

pub fn parsed(s: &str) -> i32 {
    s.parse().expect("fixture: not a number") //~ expect: panic
}

pub fn not_poison_propagation(cell: std::sync::Mutex<i32>) -> i32 {
    // `into_inner()` consumes the mutex; this is not the lock idiom.
    cell.into_inner().unwrap() //~ expect: panic
}

//! Integration tests across the full stack: artifacts → model IO → PJRT
//! runtime → eval → coordinator. These require `make artifacts` to have
//! run (they are skipped with a message otherwise, so `cargo test` stays
//! green on a fresh checkout).

use icquant::coordinator::backend::PjrtBackend;
use icquant::coordinator::{ServeConfig, Server};
use icquant::eval::{load_corpus_tokens, perplexity, weight_literals};
use icquant::icquant::{IcqConfig, IcqMatrix};
use icquant::model::{artifacts_dir, TrainedModel};
use icquant::quant::QuantizerKind;
use icquant::runtime::{Engine, HostTensor};
use icquant::store::{container, quantize_trained, DecodeCache, StoredModel};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn have_artifacts() -> bool {
    artifacts_dir().join("aot_manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn model_loads_and_validates() {
    require_artifacts!();
    let m = TrainedModel::load(&artifacts_dir()).unwrap();
    m.validate().unwrap();
    assert_eq!(m.config.vocab, 256);
    assert_eq!(m.projections().len(), 7 * m.config.n_layers);
    assert!(!m.sensitivity.is_empty(), "sensitivity artifact missing");
    // Trained model should beat the uniform baseline comfortably.
    assert!(m.val_loss < 3.0, "val loss {}", m.val_loss);
}

#[test]
fn engine_executes_forward_loss() {
    require_artifacts!();
    let dir = artifacts_dir();
    let model = TrainedModel::load(&dir).unwrap();
    let mut engine = Engine::new(&dir).unwrap();
    let weights = weight_literals(&model).unwrap();
    let tokens = load_corpus_tokens(&dir, "test").unwrap();
    let ppl = perplexity(&mut engine, weights, &tokens, 4).unwrap();
    // Perplexity through PJRT must be consistent with the training-side
    // validation loss (same architecture, same weights, different split).
    let val_ppl = model.val_loss.exp();
    assert!(ppl > 1.0 && ppl < val_ppl * 2.0, "ppl {} vs val {}", ppl, val_ppl);
}

#[test]
fn quantized_weights_degrade_gracefully() {
    require_artifacts!();
    let dir = artifacts_dir();
    let model = TrainedModel::load(&dir).unwrap();
    let mut engine = Engine::new(&dir).unwrap();
    let tokens = load_corpus_tokens(&dir, "test").unwrap();

    let fp_ppl = {
        let w = weight_literals(&model).unwrap();
        perplexity(&mut engine, w, &tokens, 4).unwrap()
    };

    // ICQuant 3-bit SK on every projection.
    let mut replacements = HashMap::new();
    for t in model.projections() {
        let w = t.as_matrix();
        let sens = model.sensitivity_of(&t.name).map(|s| s.as_matrix());
        let cfg = IcqConfig {
            bits: 3,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::SensitiveKmeans,
        };
        let q = IcqMatrix::quantize(&w, sens.as_ref(), &cfg).unwrap();
        replacements.insert(t.name.clone(), q.dequantize());
    }
    let qm = model.with_replaced(&replacements);
    let q_ppl = {
        let w = weight_literals(&qm).unwrap();
        perplexity(&mut engine, w, &tokens, 4).unwrap()
    };
    assert!(q_ppl >= fp_ppl * 0.99, "q {} vs fp {}", q_ppl, fp_ppl);
    assert!(
        q_ppl < fp_ppl * 1.5,
        "3.31-bit ICQuant should be near-lossless: q {} vs fp {}",
        q_ppl,
        fp_ppl
    );
}

#[test]
fn forward_q_entry_matches_dequantized_fp_path() {
    require_artifacts!();
    let dir = artifacts_dir();
    let model = TrainedModel::load(&dir).unwrap();
    let mut engine = Engine::new(&dir).unwrap();
    let tokens = load_corpus_tokens(&dir, "test").unwrap();
    let bits = 2u32;

    // Quantize projections; build both the forward_q args (codes + fused
    // codebooks) and the dequantized FP replacement weights.
    let mut q_args: Vec<xla::Literal> = Vec::new();
    let mut replacements = HashMap::new();
    let b = engine.manifest().eval_batch;
    let s = model.config.max_seq;
    let mut toks = Vec::with_capacity(b * s);
    let mut targets = Vec::with_capacity(b * s);
    for seq in 0..b {
        let start = seq * (s + 1);
        toks.extend_from_slice(&tokens[start..start + s]);
        targets.extend_from_slice(&tokens[start + 1..start + s + 1]);
    }
    q_args.push(HostTensor::I32(toks.clone(), vec![b, s]).to_literal().unwrap());
    q_args.push(HostTensor::I32(targets.clone(), vec![b, s]).to_literal().unwrap());

    let cfg = IcqConfig { bits, outlier_ratio: 0.05, gap_bits: 6, quantizer: QuantizerKind::Rtn };
    for t in &model.tensors {
        if t.is_projection() {
            let q = IcqMatrix::quantize(&t.as_matrix(), None, &cfg).unwrap();
            let rt = q.to_runtime();
            replacements.insert(t.name.clone(), rt.dequantize());
            // The PJRT entry takes byte-lane codes (TPU has no sub-byte
            // lanes); unpack the packed runtime plane for the ABI.
            let codes_i32: Vec<i32> =
                rt.byte_codes().iter().map(|&c| c as i32).collect();
            q_args.push(
                HostTensor::I32(codes_i32, vec![rt.rows, rt.cols]).to_literal().unwrap(),
            );
            let cb_flat: Vec<f32> = rt.codebooks_flat().to_vec();
            let cb_cols = 1usize << (bits + 1);
            q_args.push(
                HostTensor::F32(cb_flat, vec![rt.rows, cb_cols]).to_literal().unwrap(),
            );
        } else {
            q_args.push(
                HostTensor::F32(t.data.clone(), t.shape.clone()).to_literal().unwrap(),
            );
        }
    }

    // Quantized-graph NLL (Pallas dequant inside the HLO)…
    let refs: Vec<&xla::Literal> = q_args.iter().collect();
    let out = engine
        .execute_literals(&format!("forward_q{}_b{}", bits, b), &refs)
        .unwrap();
    let q_nll = Engine::scalar_f32(&out[0]).unwrap();

    // …must equal the FP graph on dequantized weights.
    let fp_model = model.with_replaced(&replacements);
    let weights = weight_literals(&fp_model).unwrap();
    let data = [
        HostTensor::I32(toks, vec![b, s]).to_literal().unwrap(),
        HostTensor::I32(targets, vec![b, s]).to_literal().unwrap(),
    ];
    let args: Vec<&xla::Literal> = data.iter().chain(weights.iter()).collect();
    let out = engine
        .execute_literals(&format!("forward_loss_b{}", b), &args)
        .unwrap();
    let fp_nll = Engine::scalar_f32(&out[0]).unwrap();

    assert!(
        (q_nll - fp_nll).abs() < 2e-3,
        "forward_q {} vs fp-on-dequant {}",
        q_nll,
        fp_nll
    );
}

#[test]
fn pjrt_serves_from_icqz_container() {
    require_artifacts!();
    let dir = artifacts_dir();
    let model = TrainedModel::load(&dir).unwrap();
    let cfg = IcqConfig {
        bits: 3,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let packed = quantize_trained(&model, &cfg).unwrap();
    let cdir = std::env::temp_dir().join("icq_it_container");
    std::fs::create_dir_all(&cdir).unwrap();
    let cpath = cdir.join("llama-mini.icqz");
    container::save(&packed, &cpath).unwrap();
    assert!(container::verify(&cpath).unwrap().ok());

    let cache = Arc::new(DecodeCache::new(256 << 20));
    // The container round-trips to a servable model with the same ABI.
    let stored = StoredModel::open(&cpath, cache.clone()).unwrap();
    let qmodel = stored.to_trained_model().unwrap();
    qmodel.validate().unwrap();

    let cfg = ServeConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(10),
        max_new_tokens: 4,
        buckets: vec![1, 2, 4, 8],
        prefill_len: 64,
        ..ServeConfig::default()
    };
    let dir2 = dir.clone();
    let cache2 = cache.clone();
    let server = Server::start(cfg, move || {
        PjrtBackend::from_container(&dir2, &cpath, cache2)
    });
    let prompt: Vec<i32> = b"The rapid deployment of large language "
        .iter()
        .map(|&b| b as i32)
        .collect();
    let (_, rx) = server.submit(prompt, 4).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
    assert!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
    assert_eq!(resp.tokens.len(), 4);
    server.shutdown();
    // Backend construction decoded each projection once, through the
    // shared cache that already served `to_trained_model` above.
    let stats = cache.stats();
    assert_eq!(stats.misses as usize, model.projections().len());
    assert!(stats.hits >= stats.misses);
}

#[test]
fn serving_end_to_end_with_pjrt() {
    require_artifacts!();
    let dir = artifacts_dir();
    let model = TrainedModel::load(&dir).unwrap();
    let cfg = ServeConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(10),
        max_new_tokens: 8,
        buckets: vec![1, 2, 4, 8],
        prefill_len: 64,
        ..ServeConfig::default()
    };
    let dir2 = dir.clone();
    let server = Server::start(cfg, move || {
        let mut b = PjrtBackend::new(&dir2, &model)?;
        b.warmup()?;
        Ok(b)
    });
    let prompt: Vec<i32> = b"Yhe rapid deployment of large language "
        .iter()
        .map(|&b| b as i32)
        .collect();
    let mut rxs = Vec::new();
    for _ in 0..6 {
        let (_, rx) = server.submit(prompt.clone(), 8).unwrap();
        rxs.push(rx);
    }
    let mut outputs = Vec::new();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        assert_eq!(resp.tokens.len(), 8);
        // Tokens must be valid bytes.
        assert!(resp.tokens.iter().all(|&t| (0..256).contains(&t)));
        outputs.push(resp.tokens);
    }
    // Same prompt ⇒ same greedy generation, batched or not.
    for o in &outputs[1..] {
        assert_eq!(o, &outputs[0]);
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, 6);
    assert!(snap.tokens == 48);
    server.shutdown();
}

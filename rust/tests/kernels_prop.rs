//! Property tests for the fused quantized-plane kernels: the fused GEMV
//! (and GEMM, their pooled multi-threaded variants, and explicit-pool
//! dispatch) must be **bit-identical** to `RuntimePlane::dequantize()`
//! followed by a dense matmul, across bit-widths 2..=5 (packed widths
//! 3..=6 — 3-bit codes cross byte boundaries inside every row), outlier
//! ratios (including γ = 0, where the outlier codebook is all padding),
//! odd shapes (1×1, 1×N, row counts that leave remainder chunks under
//! every split, col counts at the gather BLOCK ± 1), and any worker
//! count.

use icquant::icquant::{IcqConfig, IcqMatrix};
use icquant::kernels::{gemm, gemm_mt, gemm_on, gemv, gemv_mt, gemv_on, WorkerPool};
use icquant::quant::QuantizerKind;
use icquant::synthzoo;
use icquant::util::miniprop::{check, Config};
use icquant::util::tensor::Matrix;

fn bits_of(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn prop_fused_gemv_bit_identical_to_dequant_matmul() {
    check(
        "fused-gemv-bit-identity",
        Config::with_cases(48),
        |rng, size| {
            let rows = 1 + (size * 40.0 * rng.f64()) as usize;
            let cols = 1 + (size * 900.0 * rng.f64()) as usize;
            let bits = rng.range_inclusive(2, 5) as u32;
            let gamma = if rng.bool(0.5) { 0.05 } else { 0.0 };
            let threads = rng.range_inclusive(1, 7) as usize;
            let seed = rng.next_u64();
            (rows, cols, bits, gamma, threads, seed)
        },
        |&(rows, cols, bits, gamma, threads, seed)| {
            let w = synthzoo::demo_matrix(rows, cols, seed);
            let cfg = IcqConfig {
                bits,
                outlier_ratio: gamma,
                gap_bits: 6,
                quantizer: QuantizerKind::Rtn,
            };
            let q = IcqMatrix::quantize(&w, None, &cfg)
                .map_err(|e| format!("quantize: {}", e))?;
            let rt = q.to_runtime();
            let x: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.31).sin()).collect();

            // Reference: dequantize, then dense matmul (x as cols×1).
            let dense = rt.dequantize();
            let want = dense.matmul(&Matrix::from_vec(cols, 1, x.clone())).data;

            let mut y = vec![0.0f32; rows];
            gemv(&rt, &x, &mut y);
            if bits_of(&y) != bits_of(&want) {
                return Err(format!(
                    "single-thread fused GEMV not bit-identical ({}x{} {}bit γ={})",
                    rows, cols, bits, gamma
                ));
            }
            // Thread splits, including thread counts that do not divide
            // the row count (remainder chunks) and exceed it.
            for t in [threads, rows, rows + 3] {
                let mut ymt = vec![0.0f32; rows];
                gemv_mt(&rt, &x, &mut ymt, t);
                if bits_of(&ymt) != bits_of(&want) {
                    return Err(format!(
                        "{}-thread fused GEMV not bit-identical ({}x{} {}bit)",
                        t, rows, cols, bits
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fused_gemm_bit_identical_to_dequant_matmul() {
    check(
        "fused-gemm-bit-identity",
        Config::with_cases(32),
        |rng, size| {
            let rows = 1 + (size * 24.0 * rng.f64()) as usize;
            let cols = 1 + (size * 500.0 * rng.f64()) as usize;
            let batch = 1 + rng.below(7) as usize;
            let bits = rng.range_inclusive(2, 5) as u32;
            let gamma = if rng.bool(0.5) { 0.05 } else { 0.0 };
            let threads = rng.range_inclusive(1, 5) as usize;
            let seed = rng.next_u64();
            (rows, cols, batch, bits, gamma, threads, seed)
        },
        |&(rows, cols, batch, bits, gamma, threads, seed)| {
            let w = synthzoo::demo_matrix(rows, cols, seed);
            let cfg = IcqConfig {
                bits,
                outlier_ratio: gamma,
                gap_bits: 6,
                quantizer: QuantizerKind::Rtn,
            };
            let q = IcqMatrix::quantize(&w, None, &cfg)
                .map_err(|e| format!("quantize: {}", e))?;
            let rt = q.to_runtime();
            let x = Matrix::from_vec(
                batch,
                cols,
                (0..batch * cols).map(|i| (i as f32 * 0.17).cos()).collect(),
            );

            // Reference: y = x · dequantize(W)ᵀ via the dense matmul.
            let want = x.matmul(&rt.dequantize().transpose());

            let mut y = Matrix::zeros(batch, rows);
            gemm(&rt, &x, &mut y);
            if bits_of(&y.data) != bits_of(&want.data) {
                return Err(format!(
                    "fused GEMM not bit-identical ({}x{} batch {} {}bit γ={})",
                    rows, cols, batch, bits, gamma
                ));
            }
            for t in [threads, batch + 2] {
                let mut ymt = Matrix::zeros(batch, rows);
                gemm_mt(&rt, &x, &mut ymt, t);
                if bits_of(&ymt.data) != bits_of(&want.data) {
                    return Err(format!(
                        "{}-thread fused GEMM not bit-identical ({}x{} batch {})",
                        t, rows, cols, batch
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Gather-block boundary shapes, pinned: the fused kernels unpack 512
/// codes per block, so cols at 511/512/513 exercise the full-block,
/// exact-fit, and one-code-tail paths — at widths whose codes cross
/// byte boundaries (3-bit for n=2, 5-bit for n=4).
#[test]
fn fused_gemv_block_boundary_cols_pinned() {
    const BLOCK: usize = 512; // kernels' gather block size
    for &cols in &[BLOCK - 1, BLOCK, BLOCK + 1] {
        for bits in [2u32, 4] {
            let w = synthzoo::demo_matrix(6, cols, 0xB10C + bits as u64);
            let cfg = IcqConfig {
                bits,
                outlier_ratio: 0.05,
                gap_bits: 6,
                quantizer: QuantizerKind::Rtn,
            };
            let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
            let rt = q.to_runtime();
            let x: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.43).sin()).collect();
            let want = rt
                .dequantize()
                .matmul(&Matrix::from_vec(cols, 1, x.clone()))
                .data;
            let mut y = vec![0.0f32; 6];
            gemv(&rt, &x, &mut y);
            assert_eq!(bits_of(&y), bits_of(&want), "bits={} cols={}", bits, cols);
            let mut ymt = vec![0.0f32; 6];
            gemv_mt(&rt, &x, &mut ymt, 4);
            assert_eq!(bits_of(&ymt), bits_of(&want), "mt bits={} cols={}", bits, cols);
        }
    }
}

/// Pool determinism: the same GEMV/GEMM dispatched onto pools of 1, 2,
/// and 4 workers must produce bit-identical outputs — chunk→output
/// mapping is fixed by the caller, so worker count (and which worker
/// claims which chunk) cannot show up in the results.
#[test]
fn pool_worker_count_is_output_invariant() {
    let w = synthzoo::demo_matrix(29, 700, 0x9001);
    let cfg = IcqConfig {
        bits: 2,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
    let rt = q.to_runtime();
    let x: Vec<f32> = (0..700).map(|i| (i as f32 * 0.29).cos()).collect();
    let mut want_v = vec![0.0f32; 29];
    gemv(&rt, &x, &mut want_v);
    let xm = Matrix::from_vec(3, 700, (0..3 * 700).map(|i| (i as f32 * 0.07).sin()).collect());
    let mut want_m = Matrix::zeros(3, 29);
    gemm(&rt, &xm, &mut want_m);
    for workers in [1usize, 2, 4] {
        let pool = WorkerPool::new(workers);
        let mut y = vec![0.0f32; 29];
        gemv_on(&pool, &rt, &x, &mut y);
        assert_eq!(bits_of(&y), bits_of(&want_v), "gemv workers={}", workers);
        let mut ym = Matrix::zeros(3, 29);
        gemm_on(&pool, &rt, &xm, &mut ym);
        assert_eq!(bits_of(&ym.data), bits_of(&want_m.data), "gemm workers={}", workers);
    }
}

/// The explicit corner shapes called out in the issue, pinned (the
/// property above covers them probabilistically).
#[test]
fn fused_gemv_corner_shapes_pinned() {
    for &(rows, cols) in &[(1usize, 1usize), (1, 513), (5, 2), (7, 64)] {
        for bits in [2u32, 3, 4, 5] {
            for gamma in [0.0, 0.05] {
                let w = synthzoo::demo_matrix(rows, cols, 0xC0 + bits as u64);
                let cfg = IcqConfig {
                    bits,
                    outlier_ratio: gamma,
                    gap_bits: 6,
                    quantizer: QuantizerKind::Rtn,
                };
                let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
                let rt = q.to_runtime();
                let x: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.7).sin()).collect();
                let want = rt
                    .dequantize()
                    .matmul(&Matrix::from_vec(cols, 1, x.clone()))
                    .data;
                // Thread counts around the row count hit every split
                // (empty-tail, remainder, one-row-per-thread).
                for threads in 1..=rows + 2 {
                    let mut y = vec![0.0f32; rows];
                    gemv_mt(&rt, &x, &mut y, threads);
                    assert_eq!(
                        bits_of(&y),
                        bits_of(&want),
                        "{}x{} {}bit γ={} threads={}",
                        rows,
                        cols,
                        bits,
                        gamma,
                        threads
                    );
                }
            }
        }
    }
}

//! Property tests for the fused quantized-plane kernels: the fused GEMV
//! (and GEMM, and their multi-threaded variants) must be **bit-identical**
//! to `RuntimePlane::dequantize()` followed by a dense matmul, across
//! bit-widths, outlier ratios (including γ = 0, where the outlier
//! codebook is all padding), and odd shapes (1×1, 1×N, row counts that
//! leave remainder chunks under every thread split).

use icquant::icquant::{IcqConfig, IcqMatrix};
use icquant::kernels::{gemm, gemm_mt, gemv, gemv_mt};
use icquant::quant::QuantizerKind;
use icquant::synthzoo;
use icquant::util::miniprop::{check, Config};
use icquant::util::tensor::Matrix;

fn bits_of(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn prop_fused_gemv_bit_identical_to_dequant_matmul() {
    check(
        "fused-gemv-bit-identity",
        Config::with_cases(48),
        |rng, size| {
            let rows = 1 + (size * 40.0 * rng.f64()) as usize;
            let cols = 1 + (size * 900.0 * rng.f64()) as usize;
            let bits = rng.range_inclusive(2, 4) as u32;
            let gamma = if rng.bool(0.5) { 0.05 } else { 0.0 };
            let threads = rng.range_inclusive(1, 7) as usize;
            let seed = rng.next_u64();
            (rows, cols, bits, gamma, threads, seed)
        },
        |&(rows, cols, bits, gamma, threads, seed)| {
            let w = synthzoo::demo_matrix(rows, cols, seed);
            let cfg = IcqConfig {
                bits,
                outlier_ratio: gamma,
                gap_bits: 6,
                quantizer: QuantizerKind::Rtn,
            };
            let q = IcqMatrix::quantize(&w, None, &cfg)
                .map_err(|e| format!("quantize: {}", e))?;
            let rt = q.to_runtime();
            let x: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.31).sin()).collect();

            // Reference: dequantize, then dense matmul (x as cols×1).
            let dense = rt.dequantize();
            let want = dense.matmul(&Matrix::from_vec(cols, 1, x.clone())).data;

            let mut y = vec![0.0f32; rows];
            gemv(&rt, &x, &mut y);
            if bits_of(&y) != bits_of(&want) {
                return Err(format!(
                    "single-thread fused GEMV not bit-identical ({}x{} {}bit γ={})",
                    rows, cols, bits, gamma
                ));
            }
            // Thread splits, including thread counts that do not divide
            // the row count (remainder chunks) and exceed it.
            for t in [threads, rows, rows + 3] {
                let mut ymt = vec![0.0f32; rows];
                gemv_mt(&rt, &x, &mut ymt, t);
                if bits_of(&ymt) != bits_of(&want) {
                    return Err(format!(
                        "{}-thread fused GEMV not bit-identical ({}x{} {}bit)",
                        t, rows, cols, bits
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fused_gemm_bit_identical_to_dequant_matmul() {
    check(
        "fused-gemm-bit-identity",
        Config::with_cases(32),
        |rng, size| {
            let rows = 1 + (size * 24.0 * rng.f64()) as usize;
            let cols = 1 + (size * 500.0 * rng.f64()) as usize;
            let batch = 1 + rng.below(7) as usize;
            let bits = rng.range_inclusive(2, 4) as u32;
            let gamma = if rng.bool(0.5) { 0.05 } else { 0.0 };
            let threads = rng.range_inclusive(1, 5) as usize;
            let seed = rng.next_u64();
            (rows, cols, batch, bits, gamma, threads, seed)
        },
        |&(rows, cols, batch, bits, gamma, threads, seed)| {
            let w = synthzoo::demo_matrix(rows, cols, seed);
            let cfg = IcqConfig {
                bits,
                outlier_ratio: gamma,
                gap_bits: 6,
                quantizer: QuantizerKind::Rtn,
            };
            let q = IcqMatrix::quantize(&w, None, &cfg)
                .map_err(|e| format!("quantize: {}", e))?;
            let rt = q.to_runtime();
            let x = Matrix::from_vec(
                batch,
                cols,
                (0..batch * cols).map(|i| (i as f32 * 0.17).cos()).collect(),
            );

            // Reference: y = x · dequantize(W)ᵀ via the dense matmul.
            let want = x.matmul(&rt.dequantize().transpose());

            let mut y = Matrix::zeros(batch, rows);
            gemm(&rt, &x, &mut y);
            if bits_of(&y.data) != bits_of(&want.data) {
                return Err(format!(
                    "fused GEMM not bit-identical ({}x{} batch {} {}bit γ={})",
                    rows, cols, batch, bits, gamma
                ));
            }
            for t in [threads, batch + 2] {
                let mut ymt = Matrix::zeros(batch, rows);
                gemm_mt(&rt, &x, &mut ymt, t);
                if bits_of(&ymt.data) != bits_of(&want.data) {
                    return Err(format!(
                        "{}-thread fused GEMM not bit-identical ({}x{} batch {})",
                        t, rows, cols, batch
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The explicit corner shapes called out in the issue, pinned (the
/// property above covers them probabilistically).
#[test]
fn fused_gemv_corner_shapes_pinned() {
    for &(rows, cols) in &[(1usize, 1usize), (1, 513), (5, 2), (7, 64)] {
        for bits in [2u32, 3, 4] {
            for gamma in [0.0, 0.05] {
                let w = synthzoo::demo_matrix(rows, cols, 0xC0 + bits as u64);
                let cfg = IcqConfig {
                    bits,
                    outlier_ratio: gamma,
                    gap_bits: 6,
                    quantizer: QuantizerKind::Rtn,
                };
                let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
                let rt = q.to_runtime();
                let x: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.7).sin()).collect();
                let want = rt
                    .dequantize()
                    .matmul(&Matrix::from_vec(cols, 1, x.clone()))
                    .data;
                // Thread counts around the row count hit every split
                // (empty-tail, remainder, one-row-per-thread).
                for threads in 1..=rows + 2 {
                    let mut y = vec![0.0f32; rows];
                    gemv_mt(&rt, &x, &mut y, threads);
                    assert_eq!(
                        bits_of(&y),
                        bits_of(&want),
                        "{}x{} {}bit γ={} threads={}",
                        rows,
                        cols,
                        bits,
                        gamma,
                        threads
                    );
                }
            }
        }
    }
}

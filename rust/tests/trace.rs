//! Integration tests for the flight-recorder tracer (DESIGN.md §11).
//!
//! The tracer is process-global state (one enable flag, per-thread
//! rings, shared histograms), so every test here serializes on one
//! lock, resets the recorder, and disables it again before releasing —
//! the lib tests only ever exercise the disabled path.

use icquant::coordinator::metrics::{Metrics, RequestTiming};
use icquant::trace::{self, Cat, Stage, Tracer};
use icquant::util::json::Json;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// Serialize tests that touch the global tracer; reset on acquire.
fn tracer_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let g = LOCK
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner());
    Tracer::disable();
    Tracer::reset();
    g
}

fn export_events(doc: &Json) -> Vec<Json> {
    doc.req("traceEvents").unwrap().as_arr().unwrap().to_vec()
}

/// Validate the Chrome-trace invariants the exporter promises: every
/// event carries the required fields, per-thread timestamps are
/// monotone, and B/E pairs balance with depth never going negative.
fn assert_schema_valid(doc: &Json) {
    let events = export_events(doc);
    let mut depth: HashMap<i64, i64> = HashMap::new();
    let mut last_ts: HashMap<i64, f64> = HashMap::new();
    for e in &events {
        let ph = e.req("ph").unwrap().as_str().unwrap();
        let tid = e.req("tid").unwrap().as_i64().unwrap();
        let ts = e.req("ts").unwrap().as_f64().unwrap();
        e.req("pid").unwrap().as_i64().unwrap();
        e.req("cat").unwrap().as_str().unwrap();
        e.req("name").unwrap().as_str().unwrap();
        if let Some(&prev) = last_ts.get(&tid) {
            assert!(ts >= prev, "ts regressed on tid {}: {} < {}", tid, ts, prev);
        }
        last_ts.insert(tid, ts);
        let d = depth.entry(tid).or_insert(0);
        match ph {
            "B" => *d += 1,
            "E" => {
                *d -= 1;
                assert!(*d >= 0, "unmatched E on tid {}", tid);
            }
            "i" => {}
            other => panic!("unknown phase {:?}", other),
        }
    }
    for (tid, d) in depth {
        assert_eq!(d, 0, "tid {} left {} span(s) open", tid, d);
    }
}

#[test]
fn wraparound_keeps_newest_events_within_byte_budget() {
    let _g = tracer_lock();
    // A 1-byte budget clamps to the 16-event minimum ring.
    Tracer::enable(1);
    for i in 0..100u64 {
        trace::instant(Cat::Sched, "wrap", i, 0, 0);
    }
    Tracer::disable();
    assert_eq!(Tracer::event_count(), 16, "ring must hold exactly its capacity");
    let doc = Tracer::export();
    let ids: Vec<u64> = export_events(&doc)
        .iter()
        .filter(|e| e.req("name").unwrap().as_str() == Some("wrap"))
        .map(|e| e.req("args").unwrap().req("id").unwrap().as_f64().unwrap() as u64)
        .collect();
    // Overwrite-oldest: exactly the newest 16 instants survive, in order.
    assert_eq!(ids, (84..100).collect::<Vec<u64>>());
    let dropped = doc
        .req("otherData")
        .unwrap()
        .req("dropped_events")
        .unwrap()
        .as_f64()
        .unwrap();
    assert_eq!(dropped, 84.0);
    Tracer::reset();
}

#[test]
fn multithreaded_recording_loses_no_spans_below_capacity() {
    let _g = tracer_lock();
    Tracer::enable(trace::DEFAULT_BYTE_BUDGET);
    const THREADS: usize = 4;
    const SPANS: usize = 50;
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            std::thread::spawn(move || {
                for i in 0..SPANS {
                    let s = trace::span_args(
                        Cat::Pool,
                        "mt_span",
                        (t * SPANS + i) as u64,
                        t as i64,
                        i as i64,
                    );
                    trace::instant(Cat::Kv, "mt_instant", i as u64, 0, 0);
                    drop(s);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    Tracer::disable();
    let doc = Tracer::export();
    assert_schema_valid(&doc);
    let events = export_events(&doc);
    let count = |ph: &str, name: &str| {
        events
            .iter()
            .filter(|e| {
                e.req("ph").unwrap().as_str() == Some(ph)
                    && e.req("name").unwrap().as_str() == Some(name)
            })
            .count()
    };
    // Below ring capacity (~4.6k events/thread) nothing is lost.
    assert_eq!(count("B", "mt_span"), THREADS * SPANS);
    assert_eq!(count("E", "mt_span"), THREADS * SPANS);
    assert_eq!(count("i", "mt_instant"), THREADS * SPANS);
    let dropped = doc
        .req("otherData")
        .unwrap()
        .req("dropped_events")
        .unwrap()
        .as_f64()
        .unwrap();
    assert_eq!(dropped, 0.0);
    Tracer::reset();
}

#[test]
fn export_is_schema_valid_and_closes_dangling_spans() {
    let _g = tracer_lock();
    Tracer::enable(trace::DEFAULT_BYTE_BUDGET);
    {
        let _outer = trace::span_args(Cat::Sched, "outer", 1, 10, 20);
        let inner = trace::span(Cat::Request, "inner", 2);
        trace::instant(Cat::Kv, "poke", 3, 1, 2);
        drop(inner);
    }
    trace::stage_us(Stage::DecodeStep, 150);
    trace::stage_ms(Stage::Total, 1.5);
    // A span deliberately left open: the exporter must close it at the
    // thread's last timestamp rather than emit an unbalanced stream.
    std::mem::forget(trace::span(Cat::Pool, "dangling", 4));
    trace::instant(Cat::Pool, "after", 5, 0, 0);
    Tracer::disable();

    let dir = std::env::temp_dir().join("icq_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("out.json");
    Tracer::export_to(&path).unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_schema_valid(&doc);

    let events = export_events(&doc);
    let danglings: Vec<&str> = events
        .iter()
        .filter(|e| e.req("name").unwrap().as_str() == Some("dangling"))
        .map(|e| e.req("ph").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(danglings, vec!["B", "E"], "dangling span must be closed on export");
    // Stage histograms ride along in otherData.
    let hists = doc.req("otherData").unwrap().req("histograms").unwrap();
    assert_eq!(
        hists.req("decode_step").unwrap().req("count").unwrap().as_f64(),
        Some(1.0)
    );
    assert_eq!(hists.req("total").unwrap().req("count").unwrap().as_f64(), Some(1.0));
    let _ = std::fs::remove_dir_all(&dir);
    Tracer::reset();
}

#[test]
fn flight_dump_returns_recent_events() {
    let _g = tracer_lock();
    Tracer::enable(trace::DEFAULT_BYTE_BUDGET);
    for i in 0..10u64 {
        trace::instant(Cat::Request, "fail_ctx", i, 0, 0);
    }
    let dump = trace::flight_dump("test trigger").expect("armed recorder must dump");
    assert!(dump.contains("test trigger"));
    assert!(dump.contains("request/fail_ctx"));
    // Disarming the flight recorder silences dumps without stopping
    // event recording.
    Tracer::set_flight_recorder(false);
    assert!(trace::flight_dump("quiet").is_none());
    Tracer::set_flight_recorder(true);
    Tracer::disable();
    Tracer::reset();
}

#[test]
fn concurrent_metrics_recording_and_snapshots() {
    // No tracer involvement needed, but Metrics and the tracer share
    // the serving hot path; keep the test serialized all the same.
    let _g = tracer_lock();
    let metrics = Arc::new(Metrics::default());
    const THREADS: usize = 4;
    const PER_THREAD: usize = 200;
    let mut handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let m = metrics.clone();
            std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    if i % 10 == 0 {
                        m.record_request(&RequestTiming::failed("boom".into()));
                    } else {
                        m.record_request(&RequestTiming {
                            queue_ms: 1.0,
                            prefill_ms: 2.0,
                            ttft_ms: 3.0,
                            decode_ms: 4.0,
                            tokens: 2,
                            error: None,
                        });
                    }
                    m.record_step(t + 1);
                }
            })
        })
        .collect();
    // One more thread snapshots while the recorders hammer the lock.
    {
        let m = metrics.clone();
        handles.push(std::thread::spawn(move || {
            for _ in 0..100 {
                let s = m.snapshot();
                assert!(s.requests + s.errors <= (THREADS * PER_THREAD) as u64);
                assert!(s.p50_latency_ms >= 0.0);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let s = metrics.snapshot();
    let failed = (THREADS * PER_THREAD / 10) as u64;
    assert_eq!(s.errors, failed);
    assert_eq!(s.requests, (THREADS * PER_THREAD) as u64 - failed);
    assert_eq!(s.tokens, s.requests * 2);
    // Successful timings only: every total is 1+2+4 = 7 ms.
    assert_eq!(s.p50_latency_ms, 7.0);
    assert_eq!(s.p99_latency_ms, 7.0);
    assert_eq!(s.decode_steps, (THREADS * PER_THREAD) as u64);
}

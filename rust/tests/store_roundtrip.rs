//! Store-subsystem integration: ICQZ containers, the artifact registry,
//! and the LRU decode cache feeding the serving coordinator. These run
//! without PJRT artifacts (pure library + a deterministic backend).

use icquant::coordinator::backend::{Backend, DecodeState};
use icquant::coordinator::{ServeConfig, Server};
use icquant::icquant::{packed, IcqConfig, IcqMatrix};
use icquant::quant::QuantizerKind;
use icquant::store::{container, DecodeCache, Registry, StoredModel};
use icquant::store::container::{IcqzModel, TensorPayload};
use icquant::synthzoo;
use icquant::util::miniprop::{check, Config};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("icq_store_it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// pack → load → decode must be bit-identical to the in-memory
/// `IcqMatrix` path (codebooks compared at their serialized f16
/// precision), and `serialized_size` must exactly match bytes written —
/// for both the single-matrix `ICQM` and the container `ICQZ`.
#[test]
fn prop_container_roundtrip_bitexact_and_sized() {
    let dir = tmp_dir("prop_roundtrip");
    check(
        "icqz-roundtrip",
        Config::with_cases(10),
        |rng, size| {
            let n_tensors = 1 + (size * 4.0) as usize;
            let bits = rng.range_inclusive(2, 4) as u32;
            let kind = if rng.bool(0.5) {
                QuantizerKind::Rtn
            } else {
                QuantizerKind::SensitiveKmeans
            };
            let seed = rng.next_u64();
            (n_tensors, bits, kind, seed)
        },
        |&(n_tensors, bits, kind, seed)| {
            let cfg = IcqConfig {
                bits,
                outlier_ratio: 0.05,
                gap_bits: 6,
                quantizer: kind,
            };
            // A mix of quantized and dense entries.
            let mut entries = Vec::new();
            let mut originals = Vec::new();
            for i in 0..n_tensors {
                let rows = 4 + 3 * i;
                let cols = 96 + 32 * i;
                let w = synthzoo::demo_matrix(rows, cols, seed ^ i as u64);
                let q = IcqMatrix::quantize(&w, None, &cfg)
                    .map_err(|e| format!("quantize: {}", e))?;

                // ICQM: exact size + bit-exact byte roundtrip.
                let bytes = packed::to_bytes(&q);
                if bytes.len() != packed::serialized_size(&q) {
                    return Err(format!(
                        "ICQM serialized_size {} != {} written",
                        packed::serialized_size(&q),
                        bytes.len()
                    ));
                }
                let q2 = packed::from_bytes(&bytes).map_err(|e| format!("ICQM load: {}", e))?;
                if packed::to_bytes(&q2) != bytes {
                    return Err("ICQM re-serialization not bit-identical".into());
                }

                originals.push(q.clone());
                entries.push((format!("t{}.wq", i), TensorPayload::Quantized(q)));
                entries.push((
                    format!("t{}.norm", i),
                    TensorPayload::Dense {
                        shape: vec![rows],
                        data: (0..rows).map(|r| r as f32 * 0.5 - 1.0).collect(),
                    },
                ));
            }
            let model = IcqzModel { config: None, val_loss: f64::NAN, entries };

            // ICQZ: exact size.
            let path = dir.join("case.icqz");
            container::save(&model, &path).map_err(|e| format!("save: {}", e))?;
            let actual = std::fs::metadata(&path).unwrap().len() as usize;
            let predicted = container::serialized_size(&model).unwrap();
            if actual != predicted {
                return Err(format!("ICQZ size {} != predicted {}", actual, predicted));
            }

            // ICQZ: decode path bit-identical to the in-memory path.
            let back = container::load(&path).map_err(|e| format!("load: {}", e))?;
            let cache = Arc::new(DecodeCache::new(1 << 26));
            let stored = StoredModel::from_model(back, cache, "prop");
            for (i, q) in originals.iter().enumerate() {
                let loaded = stored
                    .decode(&format!("t{}.wq", i))
                    .map_err(|e| format!("decode: {}", e))?;
                // Reference: the in-memory matrix with codebooks taken to
                // the f16 precision serialization stores.
                let mut reference = q.clone();
                reference.inlier_cbs =
                    q.inlier_cbs.iter().map(|c| c.to_f16_precision()).collect();
                reference.outlier_cbs =
                    q.outlier_cbs.iter().map(|c| c.to_f16_precision()).collect();
                let want = reference.to_runtime().dequantize();
                if loaded.data != want.data {
                    return Err(format!("tensor t{}.wq decode not bit-identical", i));
                }
            }
            Ok(())
        },
    );
}

/// The full acceptance path: `pack` a synthzoo model, register it,
/// resolve by name@hash, and serve end-to-end through the coordinator
/// with every weight plane pulled through the LRU decode cache.
#[test]
fn coordinator_serves_from_container_via_decode_cache() {
    let dir = tmp_dir("serve");
    let family = synthzoo::family("llama3.2-1b").unwrap();
    let cfg = IcqConfig {
        bits: 2,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let model = icquant::store::synth_model(&family, &cfg, Some(2)).unwrap();
    let reg = Registry::open(dir.join("registry")).unwrap();
    let record = reg.put_model("serve-test", &model).unwrap();
    let (_, path) = reg.resolve(&record.spec()).unwrap();
    assert!(reg.verify("serve-test").unwrap().ok());

    let cache = Arc::new(DecodeCache::new(64 << 20));
    let stored = StoredModel::open(&path, cache.clone()).unwrap();
    let n_quantized = stored.quantized_names().len() as u64;
    assert_eq!(n_quantized, 14); // 7 projections × 2 blocks

    /// Deterministic backend that, on every prefill and decode step,
    /// reads all projection planes through the store's decode cache —
    /// the access pattern of a per-batch weight consumer.
    struct CachedStoreBackend {
        stored: StoredModel,
        names: Vec<String>,
        hashes: Vec<u64>,
    }

    impl CachedStoreBackend {
        fn weight_salt(&self) -> u64 {
            let mut salt = 0u64;
            for name in &self.names {
                let plane = self.stored.decode(name).expect("cached decode");
                salt ^= plane.data.len() as u64;
                salt = salt.wrapping_mul(0x100000001b3);
                salt ^= plane.data[0].to_bits() as u64;
            }
            salt
        }
    }

    impl Backend for CachedStoreBackend {
        fn new_state(&mut self, cap: usize) -> anyhow::Result<DecodeState> {
            self.hashes = vec![0; cap];
            Ok(DecodeState::empty(cap))
        }

        fn prefill_into(
            &mut self,
            state: &mut DecodeState,
            slot: usize,
            prompt: &[i32],
        ) -> anyhow::Result<()> {
            // Reads every plane through the shared cache, like a real
            // per-request weight consumer.
            let salt = self.weight_salt();
            let mut h = salt ^ 0xcbf29ce484222325;
            for &t in prompt {
                h = (h ^ t as u64).wrapping_mul(0x100000001b3);
            }
            self.hashes[slot] = h;
            state.last_tokens[slot] = (h % 256) as i32;
            state.pos[slot] = 0;
            state.active[slot] = true;
            Ok(())
        }

        fn decode(&mut self, state: &mut DecodeState) -> anyhow::Result<Vec<i32>> {
            let salt = self.weight_salt();
            let mut out = vec![0i32; state.cap];
            for slot in 0..state.cap {
                if !state.active[slot] {
                    continue;
                }
                let h = self.hashes[slot];
                let step = state.pos[slot] as u64;
                let t =
                    (((h ^ salt).rotate_left((step % 63) as u32 + 1) ^ step) % 256) as i32;
                out[slot] = t;
                state.last_tokens[slot] = t;
                state.pos[slot] += 1;
            }
            Ok(out)
        }
    }

    let names: Vec<String> =
        stored.quantized_names().iter().map(|s| s.to_string()).collect();
    let server = Server::start(
        ServeConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            max_new_tokens: 8,
            buckets: vec![1, 2, 4],
            prefill_len: 16,
            ..ServeConfig::default()
        },
        move || Ok(CachedStoreBackend { stored, names, hashes: Vec::new() }),
    );

    let mut rxs = Vec::new();
    for i in 0..12 {
        let (_, rx) = server.submit(vec![i as i32; 8], 6).unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        assert_eq!(resp.tokens.len(), 6);
    }
    server.shutdown();

    // Each of the 14 planes decoded exactly once; every subsequent
    // per-step weight read was a cache hit.
    let stats = cache.stats();
    assert_eq!(stats.misses, n_quantized, "planes decoded more than once");
    assert!(
        stats.hits >= n_quantized * 6,
        "expected many cache hits across decode steps, got {}",
        stats.hits
    );
    assert_eq!(server.metrics.snapshot().requests, 12);
}

/// Under a starved byte budget the cache still serves correct planes —
/// it just re-decodes (evictions > 0, served data unchanged).
#[test]
fn starved_cache_still_serves_correct_planes() {
    let family = synthzoo::family("llama3.2-1b").unwrap();
    let cfg = IcqConfig {
        bits: 2,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let model = icquant::store::synth_model(&family, &cfg, Some(1)).unwrap();
    let big = Arc::new(DecodeCache::new(64 << 20));
    let small = Arc::new(DecodeCache::new(100 * 1024)); // ~1.5 planes
    let dir = tmp_dir("starved");
    let path = dir.join("m.icqz");
    container::save(&model, &path).unwrap();
    let a = StoredModel::open(&path, big.clone()).unwrap();
    let b = StoredModel::open(&path, small.clone()).unwrap();
    let names: Vec<String> = a.quantized_names().iter().map(|s| s.to_string()).collect();
    for round in 0..3 {
        for name in &names {
            let pa = a.decode(name).unwrap();
            let pb = b.decode(name).unwrap();
            assert_eq!(pa.data, pb.data, "round {} tensor {}", round, name);
        }
    }
    assert!(small.stats().evictions > 0, "starved cache never evicted");
    assert!(small.bytes_used() <= 100 * 1024 || small.len() == 1);
    assert_eq!(big.stats().misses, names.len() as u64);
    assert!(big.stats().evictions == 0);
}

/// Registry garbage collection drops unreferenced objects but never a
/// model the manifest still points at (and that model still loads).
#[test]
fn registry_gc_keeps_live_artifacts_loadable() {
    let dir = tmp_dir("gc");
    let family = synthzoo::family("llama3.2-1b").unwrap();
    let cfg = IcqConfig {
        bits: 3,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let model = icquant::store::synth_model(&family, &cfg, Some(1)).unwrap();
    let reg = Registry::open(dir.join("registry")).unwrap();
    let rec = reg.put_model("live", &model).unwrap();
    // Simulate debris.
    std::fs::write(
        dir.join("registry/objects").join(format!("{}.icqz", "d".repeat(32))),
        b"junk",
    )
    .unwrap();
    let removed = reg.gc().unwrap();
    assert_eq!(removed.len(), 1);
    let (_, path) = reg.resolve("live").unwrap();
    let loaded = container::load(&path).unwrap();
    assert_eq!(loaded.entries.len(), model.entries.len());
    assert!(reg.verify(&rec.spec()).unwrap().ok());
}

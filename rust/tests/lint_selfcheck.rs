//! Self-hosting gate for `icquant lint` (DESIGN.md §13).
//!
//! Two layers:
//!
//! 1. `real_tree_is_lint_clean` runs the full pass over this repository
//!    and asserts zero diagnostics — the same bar `ci.sh` enforces, so a
//!    regression fails in `cargo test` before it fails in CI.
//! 2. Fixture tests: each checker has a deliberately-bad and a
//!    deliberately-clean snippet under `tests/lint_fixtures/` (a
//!    directory the real walk skips). Expected diagnostics are marked
//!    in-fixture with `//~ expect: <check>` trailing comments; the test
//!    asserts the checker fires on exactly those lines and nowhere else.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

use icquant::analysis::{self, checks, model::FileModel, Diagnostic};

fn repo_root() -> PathBuf {
    // CARGO_MANIFEST_DIR is rust/; the repo root is one level up.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("rust/ has a parent").to_path_buf()
}

#[test]
fn real_tree_is_lint_clean() {
    let report = analysis::lint(&repo_root()).expect("lint pass over the real tree");
    assert!(report.files >= 30, "walker found only {} .rs files", report.files);
    let rendered: Vec<String> = report.diagnostics.iter().map(|d| d.to_string()).collect();
    assert!(
        report.diagnostics.is_empty(),
        "`icquant lint` must self-host at zero diagnostics; got {}:\n{}",
        rendered.len(),
        rendered.join("\n")
    );
}

// ---------------------------------------------------------------------------
// Fixture harness
// ---------------------------------------------------------------------------

const MARKER: &str = "//~ expect: ";

fn fixture(name: &str) -> String {
    let path = repo_root().join("rust/tests/lint_fixtures").join(name);
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

/// Parse `//~ expect: <check>` markers into sorted (line, check) pairs.
fn markers(src: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (i, line) in src.lines().enumerate() {
        if let Some(p) = line.find(MARKER) {
            let check = line[p + MARKER.len()..]
                .split_whitespace()
                .next()
                .expect("marker names a check")
                .to_string();
            out.push((i + 1, check));
        }
    }
    out.sort();
    out
}

fn got(diags: &[Diagnostic]) -> Vec<(usize, String)> {
    let mut out: Vec<(usize, String)> =
        diags.iter().map(|d| (d.line, d.check.to_string())).collect();
    out.sort();
    out
}

fn assert_matches_markers(name: &str, src: &str, diags: &[Diagnostic]) {
    let rendered: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    assert_eq!(
        got(diags),
        markers(src),
        "fixture {name}: diagnostics disagree with //~ markers; got:\n{}",
        rendered.join("\n")
    );
}

/// Drive the per-file checkers (safety / ordering / hot-path / panic)
/// on a fixture, analyzed as if it lived at `rel`.
fn check_per_file(name: &str, rel: &str) {
    let src = fixture(name);
    let diags = analysis::analyze_source(rel, &src);
    assert_matches_markers(name, &src, &diags);
}

#[test]
fn safety_checker_fires_and_stays_quiet() {
    check_per_file("safety_bad.rs", "rust/src/lintfix/safety_bad.rs");
    check_per_file("safety_ok.rs", "rust/src/lintfix/safety_ok.rs");
}

#[test]
fn ordering_checker_fires_and_stays_quiet() {
    check_per_file("ordering_bad.rs", "rust/src/lintfix/ordering_bad.rs");
    check_per_file("ordering_ok.rs", "rust/src/lintfix/ordering_ok.rs");
}

#[test]
fn hot_path_checker_fires_and_stays_quiet() {
    check_per_file("hotpath_bad.rs", "rust/src/lintfix/hotpath_bad.rs");
    check_per_file("hotpath_ok.rs", "rust/src/lintfix/hotpath_ok.rs");
}

#[test]
fn panic_checker_fires_and_stays_quiet() {
    // The panic policy only applies under coordinator/, kernels/, trace/.
    check_per_file("panic_bad.rs", "rust/src/coordinator/panic_bad.rs");
    check_per_file("panic_ok.rs", "rust/src/coordinator/panic_ok.rs");
}

#[test]
fn panic_checker_is_scoped_to_policy_dirs() {
    // The same bad source outside the scoped dirs produces nothing.
    let src = fixture("panic_bad.rs");
    let m = FileModel::build("rust/src/quant/panic_bad.rs", &src);
    let mut diags = Vec::new();
    checks::panic_policy(&m, &mut diags);
    assert!(diags.is_empty(), "panic policy must not apply outside scoped dirs");
}

#[test]
fn design_ref_checker_fires_and_stays_quiet() {
    let sections: BTreeSet<u32> = [1u32, 2].into_iter().collect();
    for name in ["design_bad.rs", "design_ok.rs"] {
        let src = fixture(name);
        let m = FileModel::build(&format!("rust/src/lintfix/{name}"), &src);
        let mut diags = Vec::new();
        checks::design_refs(&m, &sections, &mut diags);
        assert_matches_markers(name, &src, &diags);
    }
}

#[test]
fn design_section_parser_reads_headers() {
    let sections = checks::design_sections("## §1 A\ntext\n## §12 B\n");
    assert_eq!(sections, [1u32, 12].into_iter().collect::<BTreeSet<u32>>());
    // And the real DESIGN.md declares the section this pass documents.
    let real = checks::design_sections(
        &fs::read_to_string(repo_root().join("DESIGN.md")).expect("read DESIGN.md"),
    );
    assert!(real.contains(&13), "DESIGN.md must document the lint pass in §13");
}

#[test]
fn trace_name_checker_fires_and_stays_quiet() {
    let names_src = fixture("names_demo.rs");
    let names = FileModel::build("rust/src/trace/names.rs", &names_src);
    let mut registry_diags = Vec::new();
    let registry: BTreeMap<String, usize> =
        checks::trace_registry(&names, &mut registry_diags);
    assert!(registry.contains_key("registered_demo"));

    let mut used = BTreeSet::new();
    for name in ["trace_bad.rs", "trace_ok.rs"] {
        let src = fixture(name);
        let m = FileModel::build(&format!("rust/src/lintfix/{name}"), &src);
        let mut diags = Vec::new();
        checks::trace_names(&m, &registry, &mut used, &mut diags);
        assert_matches_markers(name, &src, &diags);
    }

    // Registry-level diagnostics (duplicate + never-recorded) line up with
    // the markers in the registry fixture itself.
    let mut unused_diags = Vec::new();
    checks::trace_unused(&names, &registry, &used, &mut unused_diags);
    let mut all = registry_diags;
    all.extend(unused_diags);
    assert_matches_markers("names_demo.rs", &names_src, &all);
}

#[test]
fn trace_registry_consts_and_all_agree() {
    // The lint checker parses the consts; `icquant trace-check` walks
    // `ALL`. A const left out of `ALL` would split those two views.
    let src = fs::read_to_string(repo_root().join("rust/src/trace/names.rs"))
        .expect("read trace/names.rs");
    let names = FileModel::build("rust/src/trace/names.rs", &src);
    let mut diags = Vec::new();
    let registry = checks::trace_registry(&names, &mut diags);
    assert!(diags.is_empty(), "real registry has duplicates: {:?}", got(&diags));
    assert_eq!(registry.len(), icquant::trace::names::ALL.len());
    for name in registry.keys() {
        assert!(icquant::trace::names::is_registered(name), "{name} missing from ALL");
    }
}

#[test]
fn bench_key_checker_joins_continuations() {
    let bench_src = "fn main() { println!(\"{}\", \"present_key\"); }\n";
    let bench = FileModel::build("rust/benches/demo.rs", bench_src);

    // A key list wrapped with a backslash continuation: the missing key
    // sits on the continued line and must still be attributed to the
    // logical line's first physical line.
    let ci = "for key in present_key \\\n    missing_key; do\n";
    let mut diags = Vec::new();
    checks::bench_keys("ci.sh", ci, &[&bench], &mut diags);
    assert_eq!(diags.len(), 1, "exactly the missing key fires");
    assert!(diags[0].message.contains("missing_key"), "{}", diags[0]);
    assert_eq!(diags[0].line, 1, "diagnostic anchors at the logical line start");

    let mut quiet = Vec::new();
    checks::bench_keys("ci.sh", "for key in present_key; do\n", &[&bench], &mut quiet);
    assert!(quiet.is_empty(), "present keys are quiet");
}

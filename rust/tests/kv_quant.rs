//! Block-state property tests for the ICQ-quantized paged KV cache
//! (ISSUE 7, DESIGN.md §12).
//!
//! The fuzz harness (`tests/scheduler_fuzz.rs`) checks schedule
//! invariance of quantized streams with sharing off; this file pins
//! down the block state machine itself:
//!
//! * fill → quantize roundtrip stays inside the per-channel ICQ error
//!   bound (`range / (2·(2^bits − 1))`, outliers exact);
//! * a CoW fork of a quantized block is **deep** — corrupting the
//!   child's codes never perturbs the registry-shared parent;
//! * eviction / deregistration of registered chains whose blocks are
//!   quantized keeps every allocator + byte-accounting invariant;
//! * `stats()`'s O(1) resident-byte mirror matches the O(n) recompute
//!   through fills, decodes, hot tails and frees;
//! * prefix sharing composes with quantization deterministically (the
//!   cell the fuzz matrix deliberately skips).
//!
//! Seeded via `ICQ_TEST_SEED`-compatible fixed seeds; everything here
//! is deterministic by construction.

use icquant::icquant::IcqConfig;
use icquant::kernels::{KvCache, KvLayout, NativeModel};
use icquant::quant::QuantizerKind;
use icquant::store::{synth_model, DecodeCache, StoredModel};
use icquant::synthzoo::FamilySpec;
use icquant::util::prng::Rng;
use std::sync::Arc;

fn tiny_stored(seed: u64) -> StoredModel {
    let family = FamilySpec {
        name: "kvq-tiny",
        d_model: 32,
        d_ff: 64,
        n_blocks: 2,
        tail_frac: 0.02,
        tail_scale: 2.5,
        oproj_hot: 0.5,
        seed,
    };
    let cfg = IcqConfig {
        bits: 2,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let model = synth_model(&family, &cfg, None).unwrap();
    let cache = Arc::new(DecodeCache::new(64 << 20));
    StoredModel::from_model(model, cache, "kvq-tiny")
}

fn tiny_native() -> NativeModel {
    NativeModel::from_stored(&tiny_stored(0x4B5A), 1).unwrap()
}

fn random_prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.below(256) as i32).collect()
}

/// Bytes one fully f32 block holds across both K and V planes of every
/// layer — the denominator of every compression claim below.
fn f32_block_bytes(m: &NativeModel, block_tokens: usize) -> usize {
    2 * m.config.n_layers * block_tokens * m.config.d_model * 4
}

// ---------------------------------------------------------------------------
// 1. Roundtrip error bound.
// ---------------------------------------------------------------------------

/// Quantize-on-fill must reconstruct every cached K/V value to within
/// the ICQ per-channel bound: the inlier grid spans at most the full
/// channel range (outlier removal only shrinks it), so the worst
/// rounding error is `range / (2·(levels − 1))`; the per-channel
/// outlier itself is kept exact. The f32 truth comes from an identical
/// cache with `kv_bits=off` — prefill is one forward pass, so both
/// caches store bit-identical rows before the quantize epilogue fires.
#[test]
fn quantized_blocks_roundtrip_within_per_channel_error_bound() {
    let m = tiny_native();
    let bt = 4usize;
    let n_prompt = 8usize; // two full blocks, no hot tail
    let d = m.config.d_model;
    for bits in [4u32, 8] {
        let mut rng = Rng::new(0xB0B5 + bits as u64);
        let prompt = random_prompt(&mut rng, n_prompt);
        let base = KvLayout {
            block_tokens: bt,
            total_blocks: None,
            prefix_sharing: false,
            kv_bits: None,
        };
        let mut truth = KvCache::with_layout(&m.config, 1, base);
        let quantized_layout = KvLayout { kv_bits: Some(bits), ..base };
        let mut quant = KvCache::with_layout(&m.config, 1, quantized_layout);
        m.prefill_slot(&mut truth, 0, &prompt).unwrap();
        m.prefill_slot(&mut quant, 0, &prompt).unwrap();
        quant.debug_validate();
        for b in 0..n_prompt / bt {
            assert!(quant.debug_block_is_quantized(0, b), "full block {} must quantize", b);
        }
        let levels = (1u32 << bits) as f32 - 1.0;
        for layer in 0..m.config.n_layers {
            for block in 0..n_prompt / bt {
                let span = block * bt..(block + 1) * bt;
                let exact: Vec<(Vec<f32>, Vec<f32>)> =
                    span.clone().map(|p| truth.debug_read(layer, 0, p)).collect();
                let deq: Vec<(Vec<f32>, Vec<f32>)> =
                    span.map(|p| quant.debug_read(layer, 0, p)).collect();
                for ch in 0..d {
                    for plane in 0..2 {
                        let col = |rows: &[(Vec<f32>, Vec<f32>)]| -> Vec<f32> {
                            rows.iter()
                                .map(|(k, v)| if plane == 0 { k[ch] } else { v[ch] })
                                .collect()
                        };
                        let want = col(&exact);
                        let got = col(&deq);
                        let lo = want.iter().cloned().fold(f32::INFINITY, f32::min);
                        let hi = want.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                        let bound = (hi - lo) / (2.0 * levels) * 1.001 + 1e-5;
                        for (t, (w, g)) in want.iter().zip(&got).enumerate() {
                            assert!(
                                (w - g).abs() <= bound,
                                "bits={} layer={} block={} ch={} plane={} t={}: \
                                 |{} - {}| > bound {} (range [{}, {}])",
                                bits, layer, block, ch, plane, t, w, g, bound, lo, hi
                            );
                        }
                    }
                }
            }
        }
        let stats = quant.stats();
        assert_eq!(stats.blocks_quantized, (n_prompt / bt) as u64);
        assert_eq!(stats.quantized_blocks, n_prompt / bt);
        assert_eq!(stats.kv_bits, Some(bits));
    }
}

// ---------------------------------------------------------------------------
// 2. Deep CoW fork of a quantized block.
// ---------------------------------------------------------------------------

/// Forking a quantized block clones its code stream, not a dequantized
/// image: after the fork, flipping every code byte in the child must
/// leave the registry-shared parent's dequantized contents untouched,
/// while the child's own reads visibly change.
#[test]
fn cow_fork_of_quantized_block_is_deep() {
    let m = tiny_native();
    let bt = 4usize;
    let layout = KvLayout {
        block_tokens: bt,
        total_blocks: None,
        prefix_sharing: true,
        kv_bits: Some(4),
    };
    let mut rng = Rng::new(0xF04C);
    let prompt = random_prompt(&mut rng, 2 * bt);
    let mut kv = KvCache::with_layout(&m.config, 2, layout);
    m.prefill_slot(&mut kv, 0, &prompt).unwrap();
    // Same prompt in slot 1: the aligned-reuse rule reuses block 0 from
    // the registry (the tail block is recomputed so writes never land
    // in an immutable quantized block), so both slots share physical
    // block 0 — refcount 3 with the registry pin.
    m.prefill_slot(&mut kv, 1, &prompt).unwrap();
    kv.debug_validate();
    let stats = kv.stats();
    assert!(stats.prefix_hit_blocks >= 1, "slot 1 must reuse the registered prefix block");
    assert!(kv.debug_block_is_quantized(0, 0) && kv.debug_block_is_quantized(1, 0));

    let snapshot = |kv: &mut KvCache, slot: usize| -> Vec<(Vec<f32>, Vec<f32>)> {
        (0..bt)
            .flat_map(|p| (0..m.config.n_layers).map(move |l| (l, p)))
            .map(|(l, p)| kv.debug_read(l, slot, p))
            .collect()
    };
    let parent_before = snapshot(&mut kv, 1);
    let child_before = snapshot(&mut kv, 0);
    assert_eq!(parent_before, child_before, "shared block must read identically from both slots");

    kv.debug_fork_block(0, 0).unwrap();
    kv.debug_validate();
    assert!(kv.debug_block_is_quantized(0, 0), "fork of a quantized block stays quantized");
    assert_eq!(kv.stats().cow_forks, stats.cow_forks + 1);

    kv.debug_corrupt_quant(0, 0);
    kv.debug_validate();
    assert_eq!(snapshot(&mut kv, 1), parent_before, "corrupting the fork perturbed the parent");
    assert_ne!(snapshot(&mut kv, 0), child_before, "corrupted codes must change the child's reads");
}

// ---------------------------------------------------------------------------
// 3. Eviction / deregistration of quantized chains.
// ---------------------------------------------------------------------------

/// An overcommitted pool with prefix sharing on: registered chains
/// accumulate quantized blocks until allocation pressure evicts them
/// (deregistering descendants), and every invariant — refcounts,
/// region recycling, quantized byte accounting — must hold after every
/// operation and after the pool drains.
#[test]
fn evicting_quantized_registered_chains_keeps_invariants() {
    let m = tiny_native();
    let bt = 4usize;
    let layout = KvLayout {
        block_tokens: bt,
        total_blocks: Some(10),
        prefix_sharing: true,
        kv_bits: Some(4),
    };
    let mut rng = Rng::new(0xE71C);
    let prefix = random_prompt(&mut rng, 2 * bt);
    let mut kv = KvCache::with_layout(&m.config, 1, layout);
    for _ in 0..10 {
        let mut prompt = prefix.clone();
        prompt.extend(random_prompt(&mut rng, bt));
        let mut last = m.prefill_slot(&mut kv, 0, &prompt).unwrap();
        kv.debug_validate();
        for _ in 0..2 {
            last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
            kv.debug_validate();
        }
        kv.free_slot(0);
        kv.debug_validate();
    }
    let stats = kv.stats();
    assert!(stats.blocks_evicted > 0, "overcommitted pool must evict registered chains");
    assert!(stats.blocks_quantized > 0, "evicted chains were quantized blocks");
    assert!(stats.registered_blocks <= stats.total_blocks);
    // Only the registry holds blocks now; its chains are all-quantized
    // (registered blocks are full by construction).
    assert_eq!(stats.resident_tokens, 0);
    assert_eq!(stats.quantized_blocks, stats.blocks_in_use);
    assert_eq!(kv.resident_kv_bytes(), stats.kv_resident_bytes);
}

// ---------------------------------------------------------------------------
// 4. Byte accounting through mixed block states.
// ---------------------------------------------------------------------------

/// `stats()`'s O(1) resident-byte counter must equal the O(n) walk at
/// every state transition — hot f32 tails, quantized interiors, frees —
/// and quantized residency must actually be smaller than the all-f32
/// footprint it replaces.
#[test]
fn resident_byte_accounting_tracks_block_states() {
    let m = tiny_native();
    let bt = 4usize;
    let layout = KvLayout {
        block_tokens: bt,
        total_blocks: None,
        prefix_sharing: false,
        kv_bits: Some(4),
    };
    let f32_block = f32_block_bytes(&m, bt);
    let mut rng = Rng::new(0xACC7);
    let mut kv = KvCache::with_layout(&m.config, 2, layout);

    // 10 tokens: two quantized blocks + a 2-token hot f32 tail.
    let p0 = random_prompt(&mut rng, 10);
    let mut last = m.prefill_slot(&mut kv, 0, &p0).unwrap();
    kv.debug_validate();
    assert!(kv.debug_block_is_quantized(0, 0) && kv.debug_block_is_quantized(0, 1));
    assert!(!kv.debug_block_is_quantized(0, 2), "hot tail must stay f32");
    let s = kv.stats();
    assert_eq!(s.resident_tokens, 10);
    assert_eq!((s.quantized_blocks, s.blocks_in_use), (2, 3));
    assert_eq!(s.kv_resident_bytes, kv.resident_kv_bytes());
    assert!(
        s.kv_resident_bytes < s.blocks_in_use * f32_block,
        "quantized residency {} must beat the f32 footprint {}",
        s.kv_resident_bytes,
        s.blocks_in_use * f32_block
    );

    // Two decodes complete the third block at the forward epilogue.
    for _ in 0..2 {
        last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
        kv.debug_validate();
    }
    let _ = last;
    let s = kv.stats();
    assert_eq!((s.resident_tokens, s.quantized_blocks), (12, 3));
    assert_eq!(s.blocks_quantized, 3);
    assert_eq!(s.kv_resident_bytes, kv.resident_kv_bytes());

    // A second, shorter lane adds one f32 tail block.
    let p1 = random_prompt(&mut rng, 3);
    m.prefill_slot(&mut kv, 1, &p1).unwrap();
    kv.debug_validate();
    let s = kv.stats();
    assert_eq!((s.resident_tokens, s.quantized_blocks, s.blocks_in_use), (15, 3, 4));
    assert_eq!(s.kv_resident_bytes, kv.resident_kv_bytes());

    // Freeing the quantized lane drops its payload; the arena region of
    // the f32 tail recycles (debug_validate checks region accounting).
    kv.free_slot(0);
    kv.debug_validate();
    let s = kv.stats();
    assert_eq!((s.resident_tokens, s.quantized_blocks, s.blocks_in_use), (3, 0, 1));
    assert_eq!(s.kv_resident_bytes, f32_block);
    assert_eq!(s.kv_resident_bytes, kv.resident_kv_bytes());

    kv.free_slot(1);
    kv.debug_validate();
    assert_eq!(kv.stats().kv_resident_bytes, 0);
    assert_eq!(kv.resident_kv_bytes(), 0);
}

// ---------------------------------------------------------------------------
// 5. Sharing × quantization composes deterministically.
// ---------------------------------------------------------------------------

/// The fuzz matrix forces sharing off in its quantized cells because
/// hit-vs-miss against the registry depends on admission order; here
/// the order is fixed, so the full composition — quantized registry
/// chains, aligned reuse, CoW forks — must be reproducible
/// bit-for-bit across independent runs.
#[test]
fn prefix_sharing_composes_with_quantization_deterministically() {
    let m = tiny_native();
    let layout = KvLayout {
        block_tokens: 4,
        total_blocks: None,
        prefix_sharing: true,
        kv_bits: Some(4),
    };
    let run = || -> (Vec<Vec<i32>>, u64, u64) {
        let mut rng = Rng::new(0x5EED);
        let prefix = random_prompt(&mut rng, 8);
        let mut kv = KvCache::with_layout(&m.config, 2, layout);
        let mut streams = Vec::new();
        for i in 0..4 {
            let slot = i % 2;
            let mut prompt = prefix.clone();
            prompt.extend(random_prompt(&mut rng, 2 + i));
            let mut last = m.prefill_slot(&mut kv, slot, &prompt).unwrap();
            kv.debug_validate();
            let mut out = vec![last];
            for _ in 0..4 {
                last = m.decode_slots(&mut kv, &[last], &[slot]).unwrap()[0];
                kv.debug_validate();
                out.push(last);
            }
            streams.push(out);
        }
        let s = kv.stats();
        (streams, s.blocks_quantized, s.prefix_hit_blocks)
    };
    let (streams_a, quantized_a, hits_a) = run();
    let (streams_b, quantized_b, hits_b) = run();
    assert_eq!(streams_a, streams_b, "sharing × quantization must be run-to-run deterministic");
    assert_eq!((quantized_a, hits_a), (quantized_b, hits_b));
    assert!(hits_a > 0, "later lanes must reuse the quantized shared prefix");
    assert!(quantized_a > 0);
}

//! Divergence gate for the SIMD kernel tier (DESIGN.md §14), mirroring
//! how the kv-quant tier is gated by `tests/kv_quant.rs`:
//!
//! * `Tier::Scalar` is **bit-identical** to the reference kernels — the
//!   tier-dispatched entry points with the scalar tier must reproduce
//!   [`gemv`]/[`gemm`] exactly, at every bit width and block-boundary
//!   shape.
//! * Vector tiers (AVX2/NEON) satisfy the bounded-error contract: per
//!   output element, `|simd − scalar| ≤ 2⁻²⁰ · Σ_c |l_c · x_c|` — the
//!   bound scales with the sum of *absolute* products, so cancellation
//!   in the true dot cannot make it vacuous or flaky.
//! * Pooled dispatch never adds divergence: `gemv_on_tier` is
//!   bit-identical to `gemv_tier` at any worker count, per tier.
//! * Selecting an unsupported tier degrades gracefully to scalar, and
//!   `ICQ_SIMD` parsing is conservative (unknown values pin scalar).
//! * The int8 activation path is bounded by its quantization step:
//!   `Σ_c (|l_c|·εx + |x_c|·εl) + n·εl·εx` with `εl = cb_scale/2`,
//!   `εx = x_scale/2` — and its integer accumulation makes the result
//!   tier-invariant bit-exactly.

use icquant::icquant::{IcqConfig, IcqMatrix};
use icquant::kernels::simd;
use icquant::kernels::{
    gemm, gemm_tier, gemv, gemv_i8, gemv_on_tier, gemv_tier, Tier, TierPref, WorkerPool,
};
use icquant::quant::QuantizerKind;
use icquant::synthzoo;
use icquant::util::tensor::Matrix;

const BLOCK: usize = 512; // kernels' gather block size

fn bits_of(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

fn plane(rows: usize, cols: usize, bits: u32, seed: u64) -> IcqMatrix {
    let w = synthzoo::demo_matrix(rows, cols, seed);
    let cfg = IcqConfig {
        bits,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    IcqMatrix::quantize(&w, None, &cfg).unwrap()
}

fn activations(cols: usize) -> Vec<f32> {
    (0..cols).map(|i| (i as f32 * 0.37).sin()).collect()
}

/// The scalar tier must be bit-identical to the untiered reference
/// kernels — all bit widths, cols at BLOCK−1/BLOCK/BLOCK+1 plus an odd
/// non-boundary shape.
#[test]
fn scalar_tier_is_bit_identical_to_reference() {
    for &cols in &[BLOCK - 1, BLOCK, BLOCK + 1, 777] {
        for bits in [2u32, 3, 4, 5] {
            let q = plane(9, cols, bits, 0x51D0 + bits as u64);
            let rt = q.to_runtime();
            let x = activations(cols);
            let mut want = vec![0.0f32; 9];
            gemv(&rt, &x, &mut want);
            let mut got = vec![0.0f32; 9];
            gemv_tier(&rt, &x, &mut got, Tier::Scalar);
            assert_eq!(bits_of(&got), bits_of(&want), "gemv bits={} cols={}", bits, cols);

            let xm = Matrix::from_vec(
                3,
                cols,
                (0..3 * cols).map(|i| (i as f32 * 0.17).cos()).collect(),
            );
            let mut wantm = Matrix::zeros(3, 9);
            gemm(&rt, &xm, &mut wantm);
            let mut gotm = Matrix::zeros(3, 9);
            gemm_tier(&rt, &xm, &mut gotm, Tier::Scalar);
            assert_eq!(
                bits_of(&gotm.data),
                bits_of(&wantm.data),
                "gemm bits={} cols={}",
                bits,
                cols
            );
        }
    }
}

/// Bounded-error contract for the host's vector tier: per output row,
/// the tier may diverge from scalar by at most 2⁻²⁰ of the sum of
/// absolute per-term products. On hosts without a vector tier the
/// detected tier is scalar and the test degenerates to bit-identity.
#[test]
fn vector_tier_respects_bounded_error_contract() {
    let tier = simd::detect(TierPref::Auto);
    for &cols in &[BLOCK - 1, BLOCK, BLOCK + 1, 777] {
        for bits in [2u32, 3, 4, 5] {
            let q = plane(9, cols, bits, 0xD1F0 + bits as u64);
            let rt = q.to_runtime();
            let dense = rt.dequantize();
            let x = activations(cols);
            let mut y_scalar = vec![0.0f32; 9];
            gemv_tier(&rt, &x, &mut y_scalar, Tier::Scalar);
            let mut y_simd = vec![0.0f32; 9];
            gemv_tier(&rt, &x, &mut y_simd, tier);
            for r in 0..9 {
                let abs_sum: f32 =
                    dense.row(r).iter().zip(&x).map(|(l, xv)| (l * xv).abs()).sum();
                let bound = abs_sum / (1u32 << 20) as f32 + 1e-12;
                let diff = (y_simd[r] - y_scalar[r]).abs();
                assert!(
                    diff <= bound,
                    "{} tier row {} diverged by {} (bound {}; bits={} cols={})",
                    tier.name(),
                    r,
                    diff,
                    bound,
                    bits,
                    cols
                );
            }
        }
    }
}

/// Pooled dispatch must not change results **within** a tier: each
/// output row is one chunk with the tier's fixed reduction tree, so any
/// worker count reproduces the single-threaded tiered output exactly.
#[test]
fn pooled_dispatch_is_bit_identical_within_tier() {
    let tier = simd::detect(TierPref::Auto);
    for t in [Tier::Scalar, tier] {
        let q = plane(29, 700, 2, 0x9002);
        let rt = q.to_runtime();
        let x = activations(700);
        let mut want = vec![0.0f32; 29];
        gemv_tier(&rt, &x, &mut want, t);
        for workers in [1usize, 2, 4] {
            let pool = WorkerPool::new(workers);
            let mut y = vec![0.0f32; 29];
            gemv_on_tier(&pool, &rt, &x, &mut y, t);
            assert_eq!(
                bits_of(&y),
                bits_of(&want),
                "{} tier, {} workers",
                t.name(),
                workers
            );
        }
    }
}

/// Forcing a tier the host cannot run must degrade to scalar, never
/// trap: `detect` re-checks CPU features for explicit preferences.
#[test]
fn unsupported_tier_selection_degrades_gracefully() {
    #[cfg(target_arch = "x86_64")]
    assert_eq!(simd::detect(TierPref::Neon), Tier::Scalar);
    #[cfg(not(target_arch = "x86_64"))]
    assert_eq!(simd::detect(TierPref::Avx2), Tier::Scalar);
    // Whatever auto-detection picked must be runnable: a GEMV on the
    // detected tier completes and stays within the divergence bound
    // (checked above); here it just must not crash on a tiny shape.
    let q = plane(1, 1, 2, 0x0601);
    let rt = q.to_runtime();
    let mut y = vec![0.0f32; 1];
    gemv_tier(&rt, &[0.5f32], &mut y, simd::detect(TierPref::Auto));
}

/// `ICQ_SIMD` parsing: exact names map to preferences, unknown values
/// conservatively pin scalar, unset means auto. The sole env-mutating
/// test in this binary (no other test here reads the variable), and it
/// restores the prior value for the surrounding CI run.
#[test]
fn icq_simd_env_parsing_is_conservative() {
    assert_eq!(TierPref::parse("auto"), Some(TierPref::Auto));
    assert_eq!(TierPref::parse("scalar"), Some(TierPref::Scalar));
    assert_eq!(TierPref::parse("avx2"), Some(TierPref::Avx2));
    assert_eq!(TierPref::parse("neon"), Some(TierPref::Neon));
    assert_eq!(TierPref::parse("AVX2"), None);
    assert_eq!(TierPref::parse(""), None);

    let prior = std::env::var("ICQ_SIMD").ok();
    std::env::set_var("ICQ_SIMD", "scalar");
    assert_eq!(simd::env_pref(), TierPref::Scalar);
    std::env::set_var("ICQ_SIMD", "definitely-not-a-tier");
    assert_eq!(simd::env_pref(), TierPref::Scalar);
    std::env::remove_var("ICQ_SIMD");
    assert_eq!(simd::env_pref(), TierPref::Auto);
    match prior {
        Some(v) => std::env::set_var("ICQ_SIMD", v),
        None => std::env::remove_var("ICQ_SIMD"),
    }
}

/// int8 activation path: bounded by the quantization steps of both
/// sides, and — because the inner product accumulates in exact integer
/// arithmetic — bit-identical across tiers.
#[test]
fn int8_activation_path_is_bounded_and_tier_invariant() {
    let tier = simd::detect(TierPref::Auto);
    for &cols in &[BLOCK - 1, BLOCK + 1, 777] {
        for bits in [2u32, 3, 4, 5] {
            let q = plane(9, cols, bits, 0x18A0 + bits as u64);
            let rt = q.to_runtime();
            let dense = rt.dequantize();
            let x = activations(cols);
            let mut y_ref = vec![0.0f32; 9];
            gemv(&rt, &x, &mut y_ref);
            let mut y_i8 = vec![0.0f32; 9];
            gemv_i8(&rt, &x, &mut y_i8, tier);

            // Recompute the kernel's own scales to build the bound.
            let mut xq = Vec::new();
            let x_scale = simd::quantize_activations(&x, &mut xq);
            let ex = x_scale * 0.5;
            for r in 0..9 {
                let mut staging = [0i8; 256];
                let cb_scale = simd::quantize_codebook(rt.codebook(r), &mut staging);
                let el = cb_scale * 0.5;
                let bound: f32 = dense
                    .row(r)
                    .iter()
                    .zip(&x)
                    .map(|(l, xv)| l.abs() * ex + xv.abs() * el + el * ex)
                    .sum();
                let bound = bound * 1.01 + 1e-6;
                let diff = (y_i8[r] - y_ref[r]).abs();
                assert!(
                    diff <= bound,
                    "int8 row {} off by {} (bound {}; bits={} cols={})",
                    r,
                    diff,
                    bound,
                    bits,
                    cols
                );
            }

            let mut y_scalar_i8 = vec![0.0f32; 9];
            gemv_i8(&rt, &x, &mut y_scalar_i8, Tier::Scalar);
            assert_eq!(
                bits_of(&y_i8),
                bits_of(&y_scalar_i8),
                "int8 must be tier-invariant (bits={} cols={})",
                bits,
                cols
            );
        }
    }
}

/// The scalar dispatch helpers the model routes attention through are
/// exactly the open-coded loops they replaced.
#[test]
fn scalar_helpers_match_open_coded_loops() {
    let a: Vec<f32> = (0..37).map(|i| (i as f32 * 0.3).sin()).collect();
    let b: Vec<f32> = (0..37).map(|i| (i as f32 * 0.7).cos()).collect();
    let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
    assert_eq!(simd::dot(Tier::Scalar, &a, &b).to_bits(), want.to_bits());

    let mut out = vec![0.25f32; 37];
    let mut want_out = out.clone();
    simd::axpy(Tier::Scalar, &mut out, 0.6, &b);
    for (o, v) in want_out.iter_mut().zip(&b) {
        *o += 0.6 * *v;
    }
    assert_eq!(bits_of(&out), bits_of(&want_out));

    let codes: Vec<u8> = (0..37).map(|i| (i * 7 % 256) as u8).collect();
    let mut levels = vec![0.0f32; 37];
    simd::affine_u8(Tier::Scalar, &codes, -1.25, 0.01, &mut levels);
    for (l, &c) in levels.iter().zip(&codes) {
        assert_eq!(l.to_bits(), (-1.25f32 + 0.01 * c as f32).to_bits());
    }
}

//! Randomized scheduler / paged-KV fuzz harness (ISSUE 5).
//!
//! Three layers of differential testing, all seeded through
//! `miniprop::Config::from_env` (override with `ICQ_TEST_SEED`; failing
//! cases panic with their seed) and sized through `ICQ_POOL_WORKERS`
//! (comma-separated kernel-pool widths, default `1,2,4`):
//!
//! 1. **Scheduler equivalence** — randomized workloads (arrival jitter,
//!    prompt/target lengths incl. empty and over-long, slot caps 1–8,
//!    early retirements via tiny targets, bounded-KV clamps) through the
//!    real `Server` worker over deterministic mock backends, asserting
//!    the continuous-batching scheduler delivers exactly the
//!    run-to-completion outputs with no lost or duplicated responses
//!    and sane occupancy metrics.
//! 2. **Paged-cache interleavings** — random block sizes, pool sizes,
//!    prefix-sharing patterns and admit/decode/retire interleavings
//!    driven straight against `NativeModel` + paged `KvCache`, asserting
//!    bit-identical streams vs the contiguous-equivalent layout and
//!    validating every allocator/refcount invariant after every op.
//!    Runs the full `kv_bits ∈ {off, 8, 4}` matrix (ISSUE 7): `off`
//!    must match the contiguous reference exactly (pre-quantization
//!    behavior), quantized cells must match a same-layout solo
//!    reference exactly (schedule invariance, DESIGN.md §12).
//! 3. **Native server differential** — full `Server` runs over the
//!    paged `NativeBackend` under both schedulers, asserting identical
//!    outputs — with shared prompt prefixes at `kv_bits=off`, and at
//!    8/4-bit quantized KV with sharing off.
//!
//! `ci.sh` runs this binary under a seed × pool-worker matrix and gates
//! on the total completed-case count printed by each test.

use icquant::coordinator::backend::{Backend, DecodeState, MockBackend, NativeBackend};
use icquant::coordinator::{SchedulerKind, ServeConfig, Server, SubmitOpts, TokenEvent};
use icquant::icquant::IcqConfig;
use icquant::kernels::{KvCache, KvLayout, NativeModel};
use icquant::quant::QuantizerKind;
use icquant::store::{synth_model, DecodeCache, StoredModel};
use icquant::synthzoo::FamilySpec;
use icquant::util::miniprop::{check, pool_worker_matrix, Config};
use icquant::util::prng::Rng;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

/// A mock whose KV capacity is bounded, so the fuzz exercises the
/// over-long-request clamp on both schedulers.
struct BoundedMock {
    inner: MockBackend,
    max_pos: usize,
}

impl Backend for BoundedMock {
    fn new_state(&mut self, cap: usize) -> anyhow::Result<DecodeState> {
        self.inner.new_state(cap)
    }
    fn prefill_into(
        &mut self,
        state: &mut DecodeState,
        slot: usize,
        prompt: &[i32],
    ) -> anyhow::Result<()> {
        self.inner.prefill_into(state, slot, prompt)
    }
    fn decode(&mut self, state: &mut DecodeState) -> anyhow::Result<Vec<i32>> {
        self.inner.decode(state)
    }
    fn vocab(&self) -> Option<usize> {
        self.inner.vocab()
    }
    fn max_positions(&self) -> Option<usize> {
        Some(self.max_pos)
    }
}

#[derive(Debug, Clone)]
struct FuzzRequest {
    prompt: Vec<i32>,
    want: usize,
    jitter_us: u64,
}

#[derive(Debug, Clone)]
struct FuzzWorkload {
    cap: usize,
    max_new_tokens: usize,
    prefill_len: usize,
    /// `Some(n)` bounds the mock's KV to `n` positions.
    max_pos: Option<usize>,
    requests: Vec<FuzzRequest>,
}

/// Whole-mode or streaming receiver — `ICQ_FUZZ_STREAMING=1` runs the
/// whole fuzz over the per-token stream API, so the scheduler
/// equivalence property also pins the §15 streaming order.
enum FuzzRx {
    Whole(std::sync::mpsc::Receiver<icquant::coordinator::GenerateResponse>),
    Stream(std::sync::mpsc::Receiver<TokenEvent>),
}

fn run_workload(w: &FuzzWorkload, scheduler: SchedulerKind) -> Vec<(u64, Vec<i32>)> {
    let cfg = ServeConfig {
        max_batch: w.cap,
        max_wait: Duration::from_millis(1),
        max_new_tokens: w.max_new_tokens,
        buckets: vec![1, 2, 4, 8],
        prefill_len: w.prefill_len,
        pad_id: b' ' as i32,
        scheduler,
        ..ServeConfig::default()
    };
    // `usize::MAX` makes the bound a no-op — one backend type for both
    // the bounded and unbounded arms of the fuzz.
    let max_pos = w.max_pos.unwrap_or(usize::MAX);
    let streaming = std::env::var("ICQ_FUZZ_STREAMING").is_ok_and(|v| v == "1");
    let server = Server::start(cfg, move || {
        Ok(BoundedMock { inner: MockBackend::new(), max_pos })
    });
    let mut rxs = Vec::new();
    for r in &w.requests {
        if r.jitter_us > 0 {
            std::thread::sleep(Duration::from_micros(r.jitter_us));
        }
        if streaming {
            let opts = SubmitOpts { max_new_tokens: r.want, ..SubmitOpts::default() };
            let (id, rx) = server.submit_streaming(r.prompt.clone(), opts).unwrap();
            rxs.push((id, FuzzRx::Stream(rx)));
        } else {
            let (id, rx) = server.submit(r.prompt.clone(), r.want).unwrap();
            rxs.push((id, FuzzRx::Whole(rx)));
        }
    }
    let out: Vec<(u64, Vec<i32>)> = rxs
        .into_iter()
        .map(|(id, rx)| match rx {
            FuzzRx::Whole(rx) => {
                let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
                assert!(resp.timing.error.is_none(), "request failed: {:?}", resp.timing.error);
                assert_eq!(resp.id, id);
                (id, resp.tokens)
            }
            FuzzRx::Stream(rx) => {
                let mut tokens = Vec::new();
                loop {
                    match rx.recv_timeout(Duration::from_secs(30)).expect("stream event") {
                        TokenEvent::Token(t) => tokens.push(t),
                        TokenEvent::Done(_) => break,
                        TokenEvent::Failed(e) => panic!("request failed: {}", e),
                    }
                }
                (id, tokens)
            }
        })
        .collect();
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests as usize, w.requests.len(), "metrics lost requests");
    if scheduler == SchedulerKind::Continuous {
        // Wave mode records compiled-bucket occupancy, which may round
        // above the slot cap; the slot scheduler never can.
        assert!(
            snap.avg_active_slots <= w.cap as f64 + 1e-9,
            "occupancy exceeded the slot cap: {:.2} > {}",
            snap.avg_active_slots,
            w.cap
        );
    }
    server.shutdown();
    out
}

/// Layer 1: the continuous scheduler must deliver exactly the
/// run-to-completion outputs for arbitrary workloads.
#[test]
fn fuzz_scheduler_equivalence_over_random_workloads() {
    const CASES: usize = 80;
    check(
        "scheduler-equivalence",
        Config::from_env(CASES),
        |rng, size| {
            let n = 1 + (size * 19.0) as usize;
            let cap = 1 + rng.below(8) as usize;
            let max_new_tokens = 1 + rng.below(10) as usize;
            let prefill_len = 4 + rng.below(28) as usize;
            let max_pos = if rng.bool(0.25) { Some(2 + rng.below(8) as usize) } else { None };
            let requests = (0..n)
                .map(|_| FuzzRequest {
                    // Empty, short, window-sized and over-long prompts.
                    prompt: (0..rng.below(40) as usize)
                        .map(|_| rng.below(256) as i32)
                        .collect(),
                    // 0 = satisfied by prefill alone; values beyond
                    // max_new_tokens exercise the cap.
                    want: rng.below(13) as usize,
                    jitter_us: if rng.bool(0.3) {
                        (size * rng.below(1500) as f64) as u64
                    } else {
                        0
                    },
                })
                .collect();
            FuzzWorkload { cap, max_new_tokens, prefill_len, max_pos, requests }
        },
        |w| {
            let cont = run_workload(w, SchedulerKind::Continuous);
            let wave = run_workload(w, SchedulerKind::RunToCompletion);
            icquant::prop_assert!(
                cont.len() == w.requests.len(),
                "continuous lost responses: {} of {}",
                cont.len(),
                w.requests.len()
            );
            let ids: HashSet<u64> = cont.iter().map(|(id, _)| *id).collect();
            icquant::prop_assert!(ids.len() == cont.len(), "duplicated response ids");
            for (i, ((_, ct), (_, wt))) in cont.iter().zip(&wave).enumerate() {
                icquant::prop_assert!(
                    ct == wt,
                    "request {} diverged between schedulers: {:?} vs {:?}",
                    i,
                    ct,
                    wt
                );
                let mut want = w.requests[i].want.min(w.max_new_tokens);
                if let Some(mp) = w.max_pos {
                    want = want.min(mp);
                }
                icquant::prop_assert!(
                    ct.len() == want,
                    "request {} length {} != clamped target {}",
                    i,
                    ct.len(),
                    want
                );
            }
            Ok(())
        },
    );
    println!("scheduler_fuzz: completed {} randomized cases (scheduler-equivalence)", CASES);
}

fn tiny_stored(seed: u64) -> StoredModel {
    let family = FamilySpec {
        name: "fuzz-tiny",
        d_model: 32,
        d_ff: 64,
        n_blocks: 2,
        tail_frac: 0.02,
        tail_scale: 2.5,
        oproj_hot: 0.5,
        seed,
    };
    let cfg = IcqConfig {
        bits: 2,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let model = synth_model(&family, &cfg, None).unwrap();
    let cache = Arc::new(DecodeCache::new(64 << 20));
    StoredModel::from_model(model, cache, "fuzz-tiny")
}

/// One sequence's reference stream: alone, contiguous-equivalent layout.
fn reference_stream(m: &NativeModel, prompt: &[i32], steps: usize) -> Vec<i32> {
    let mut kv = KvCache::with_layout(&m.config, 1, KvLayout::contiguous(&m.config));
    let mut last = m.prefill_slot(&mut kv, 0, prompt).unwrap();
    let mut out = vec![last];
    for _ in 0..steps {
        last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
        out.push(last);
    }
    out
}

/// One sequence's reference stream: alone, under the **same** paged
/// layout as the interleaved run. With `kv_bits` on this is the
/// schedule-invariance contract (DESIGN.md §12): quantization is
/// content-deterministic and triggers at fixed per-lane positions, so a
/// lane's stream must be bit-identical however it was interleaved.
fn solo_stream(m: &NativeModel, layout: KvLayout, prompt: &[i32], steps: usize) -> Vec<i32> {
    let mut kv = KvCache::with_layout(&m.config, 1, layout);
    let mut last = m.prefill_slot(&mut kv, 0, prompt).unwrap();
    let mut out = vec![last];
    for _ in 0..steps {
        last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
        out.push(last);
    }
    out
}

/// The `kv_bits` cells of the fuzz matrix (ISSUE 7): off must stay
/// bit-identical to the contiguous reference; quantized cells assert
/// exact schedule invariance against a same-layout solo reference.
const KV_MODES: [Option<u32>; 3] = [None, Some(8), Some(4)];

#[derive(Debug, Clone)]
struct PagedCase {
    block_tokens: usize,
    sharing: bool,
    cap: usize,
    /// `Some` = overcommitted pool sized for the active lanes' worst
    /// case but not for registry accumulation, so allocations under
    /// pressure must evict registered blocks (never truly exhaust:
    /// every lane needs at most `⌈32/bt⌉` blocks and the pool holds
    /// `cap × (⌈32/bt⌉ + 1)`).
    total_blocks: Option<usize>,
    /// Shared system-prompt prefix length (0 = unrelated prompts).
    prefix_len: usize,
    /// Per-request distinct tail length and decode steps.
    requests: Vec<(usize, usize)>,
    seed: u64,
}

/// Layer 2: random paged layouts and admit/decode/retire interleavings
/// against the model, checked token-for-token against a reference and
/// invariant-validated after every operation, across the `kv_bits`
/// matrix. `kv_bits=off` cells compare against the **contiguous**
/// reference (bit-identical — the pre-quantization contract, verbatim).
/// Quantized cells compare against a same-layout **solo** reference:
/// exact equality, because quantization is content-deterministic and
/// per-lane (sharing is forced off — with it on, whether a lane's
/// prefill reads a quantized registry block or its own fresh f32 blocks
/// depends on admission history; that composition is pinned down
/// deterministically in `tests/kv_quant.rs` instead).
#[test]
fn fuzz_paged_interleavings_bit_identical_across_pool_widths() {
    let workers = pool_worker_matrix();
    let mut total = 0usize;
    for &w in &workers {
        let stored = tiny_stored(0x7157);
        let m = NativeModel::from_stored(&stored, w).unwrap();
        for &kv_bits in &KV_MODES {
            const CASES: usize = 10;
            total += CASES;
            check(
                &format!("paged-interleavings-w{}-kv{:?}", w, kv_bits),
                Config::from_env(CASES),
                |rng, size| {
                    let block_tokens = *[1usize, 2, 3, 4, 5, 8, 16]
                        .get(rng.below(7) as usize)
                        .unwrap();
                    let cap = 2 + rng.below(3) as usize;
                    // Half the cases run an overcommitted pool so eviction,
                    // descendant deregistration and CoW-under-pressure are
                    // fuzzed, not just unit-tested (prompts + decodes stay
                    // under 32 tokens, so the sizing above always leaves a
                    // block allocatable by evicting registry-only blocks).
                    let total_blocks = if rng.bool(0.5) {
                        Some(cap * (32usize.div_ceil(block_tokens) + 1))
                    } else {
                        None
                    };
                    PagedCase {
                        block_tokens,
                        sharing: kv_bits.is_none() && rng.bool(0.7),
                        cap,
                        total_blocks,
                        prefix_len: rng.below(13) as usize,
                        requests: (0..(2 + (size * 4.0) as usize))
                            .map(|_| (1 + rng.below(6) as usize, 1 + rng.below(6) as usize))
                            .collect(),
                        seed: rng.next_u64(),
                    }
                },
                |case| {
                    let layout = KvLayout {
                        block_tokens: case.block_tokens,
                        total_blocks: case.total_blocks,
                        prefix_sharing: case.sharing,
                        kv_bits,
                    };
                    let mut rng = Rng::new(case.seed);
                    let prefix: Vec<i32> =
                        (0..case.prefix_len).map(|_| rng.below(256) as i32).collect();
                    let prompts: Vec<Vec<i32>> = case
                        .requests
                        .iter()
                        .map(|&(tail, _)| {
                            let mut p = prefix.clone();
                            p.extend((0..tail).map(|_| rng.below(256) as i32));
                            p
                        })
                        .collect();
                    let refs: Vec<Vec<i32>> = prompts
                        .iter()
                        .zip(&case.requests)
                        .map(|(p, &(_, steps))| match kv_bits {
                            None => reference_stream(&m, p, steps),
                            Some(_) => solo_stream(&m, layout, p, steps),
                        })
                        .collect();

                    // Random interleaving: admit into free slots, decode the
                    // active subset, retire finished sequences.
                    let mut kv = KvCache::with_layout(&m.config, case.cap, layout);
                    let mut slot_of: Vec<Option<usize>> = vec![None; prompts.len()];
                    let mut emitted: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
                    let mut last: Vec<i32> = vec![0; prompts.len()];
                    let mut next_req = 0usize;
                    let mut guard = 0usize;
                    while emitted.iter().zip(&refs).any(|(e, r)| e.len() < r.len()) {
                        guard += 1;
                        if guard > 10_000 {
                            return Err("interleaving failed to make progress".into());
                        }
                        // Maybe admit (always admit if nothing is active).
                        let active: Vec<usize> =
                            (0..prompts.len()).filter(|&i| slot_of[i].is_some()).collect();
                        let free_slot = (0..case.cap)
                            .find(|s| !slot_of.iter().any(|&x| x == Some(*s)));
                        if next_req < prompts.len()
                            && free_slot.is_some()
                            && (active.is_empty() || rng.bool(0.5))
                        {
                            let slot = free_slot.unwrap();
                            let first = m
                                .prefill_slot(&mut kv, slot, &prompts[next_req])
                                .map_err(|e| format!("prefill: {:#}", e))?;
                            kv.debug_validate();
                            if first != refs[next_req][0] {
                                return Err(format!(
                                    "request {} first token {} != reference {}",
                                    next_req, first, refs[next_req][0]
                                ));
                            }
                            emitted[next_req].push(first);
                            last[next_req] = first;
                            slot_of[next_req] = Some(slot);
                            next_req += 1;
                            continue;
                        }
                        // Decode a random non-empty subset of active lanes.
                        let mut lanes: Vec<usize> = active
                            .iter()
                            .copied()
                            .filter(|_| rng.bool(0.8))
                            .collect();
                        if lanes.is_empty() {
                            lanes = active.clone();
                        }
                        if lanes.is_empty() {
                            continue;
                        }
                        lanes.sort_by_key(|&i| slot_of[i].unwrap());
                        let slots: Vec<usize> =
                            lanes.iter().map(|&i| slot_of[i].unwrap()).collect();
                        let feed: Vec<i32> = lanes.iter().map(|&i| last[i]).collect();
                        let next = m
                            .decode_slots(&mut kv, &feed, &slots)
                            .map_err(|e| format!("decode: {:#}", e))?;
                        kv.debug_validate();
                        for (j, &i) in lanes.iter().enumerate() {
                            last[i] = next[j];
                            emitted[i].push(next[j]);
                            let want = &refs[i];
                            let at = emitted[i].len() - 1;
                            if emitted[i][at] != want[at] {
                                return Err(format!(
                                    "request {} diverged at token {}: {} != {}",
                                    i, at, emitted[i][at], want[at]
                                ));
                            }
                            if emitted[i].len() == want.len() {
                                kv.free_slot(slot_of[i].take().unwrap());
                                kv.debug_validate();
                            }
                        }
                    }
                    for (i, (e, r)) in emitted.iter().zip(&refs).enumerate() {
                        icquant::prop_assert!(
                            e == r,
                            "request {} stream mismatch under paging",
                            i
                        );
                    }
                    Ok(())
                },
            );
        }
    }
    println!(
        "scheduler_fuzz: completed {} randomized cases (paged-interleavings, workers {:?})",
        total, workers
    );
}

/// Layer 3: the whole server (continuous vs run-to-completion) over the
/// paged native backend, across the `kv_bits` matrix. `off` cells keep
/// shared prompt prefixes (the pre-quantization differential,
/// verbatim); quantized cells run with sharing off, where per-lane
/// quantization is schedule-deterministic, so the two schedulers must
/// still produce **identical** outputs (with sharing on, whether a
/// lane's prefill hits a quantized registry block depends on admission
/// batching, which legitimately differs between the schedulers).
#[test]
fn fuzz_native_server_scheduler_differential() {
    let workers = pool_worker_matrix();
    let mut total = 0usize;
    for &w in &workers {
        for &kv_bits in &KV_MODES {
            const CASES: usize = 3;
            total += CASES;
            check(
                &format!("native-server-differential-w{}-kv{:?}", w, kv_bits),
                Config::from_env(CASES),
                |rng, _| {
                    let block_tokens = *[2usize, 4, 16].get(rng.below(3) as usize).unwrap();
                    let n = 3 + rng.below(4) as usize;
                    let prefix = rng.below(10) as usize;
                    let seed = rng.next_u64();
                    (block_tokens, n, prefix, seed)
                },
                |&(block_tokens, n, prefix_len, seed)| {
                    let mut run = |scheduler: SchedulerKind| -> Vec<Vec<i32>> {
                        let stored = tiny_stored(0x7157);
                        let layout = KvLayout {
                            block_tokens,
                            total_blocks: None,
                            prefix_sharing: kv_bits.is_none(),
                            kv_bits,
                        };
                        let backend = NativeBackend::from_stored(&stored, w)
                            .unwrap()
                            .with_kv_layout(layout);
                        let cfg = ServeConfig {
                            max_batch: 3,
                            max_wait: Duration::from_millis(1),
                            max_new_tokens: 6,
                            buckets: vec![1, 2, 3],
                            prefill_len: 16,
                            pad_id: b' ' as i32,
                            scheduler,
                            ..ServeConfig::default()
                        };
                        let server = Server::start(cfg, move || Ok(backend));
                        let mut rng = Rng::new(seed);
                        let prefix: Vec<i32> =
                            (0..prefix_len).map(|_| rng.below(256) as i32).collect();
                        let mut rxs = Vec::new();
                        for _ in 0..n {
                            let mut p = prefix.clone();
                            p.extend((0..1 + rng.below(5) as usize).map(|_| rng.below(256) as i32));
                            let want = 1 + rng.below(5) as usize;
                            rxs.push(server.submit(p, want).unwrap().1);
                        }
                        let out = rxs
                            .into_iter()
                            .map(|rx| {
                                let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                                assert!(r.timing.error.is_none(), "{:?}", r.timing.error);
                                r.tokens
                            })
                            .collect();
                        server.shutdown();
                        out
                    };
                    let cont = run(SchedulerKind::Continuous);
                    let wave = run(SchedulerKind::RunToCompletion);
                    icquant::prop_assert!(
                        cont == wave,
                        "paged native outputs diverged between schedulers"
                    );
                    Ok(())
                },
            );
        }
    }
    println!(
        "scheduler_fuzz: completed {} randomized cases (native-server-differential, workers {:?})",
        total, workers
    );
}

//! Streaming front-end properties (ISSUE 10, DESIGN.md §15).
//!
//! 1. **Streaming order** — tokens received over `submit_streaming`
//!    concatenate bit-identically to the whole-mode response for the
//!    same prompt, under both schedulers and `kv_bits ∈ {off, 4}` on
//!    the paged native backend.
//! 2. **Incremental delivery** — the first token arrives while the
//!    sequence is still decoding (asserted via `SimBackend` timing),
//!    i.e. streaming actually streams instead of buffering a whole
//!    response behind a token-shaped API.

use icquant::coordinator::backend::{NativeBackend, SimBackend};
use icquant::coordinator::{SchedulerKind, ServeConfig, Server, SubmitOpts, TokenEvent};
use icquant::icquant::IcqConfig;
use icquant::kernels::KvLayout;
use icquant::quant::QuantizerKind;
use icquant::store::{synth_model, DecodeCache, StoredModel};
use icquant::synthzoo::FamilySpec;
use icquant::util::prng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tiny_stored() -> StoredModel {
    let family = FamilySpec {
        name: "stream-tiny",
        d_model: 32,
        d_ff: 64,
        n_blocks: 2,
        tail_frac: 0.02,
        tail_scale: 2.5,
        oproj_hot: 0.5,
        seed: 0x51AE,
    };
    let cfg = IcqConfig {
        bits: 2,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let model = synth_model(&family, &cfg, None).unwrap();
    let cache = Arc::new(DecodeCache::new(64 << 20));
    StoredModel::from_model(model, cache, "stream-tiny")
}

fn collect_stream(rx: &std::sync::mpsc::Receiver<TokenEvent>) -> Vec<i32> {
    let mut tokens = Vec::new();
    loop {
        match rx.recv_timeout(Duration::from_secs(60)).expect("stream event") {
            TokenEvent::Token(t) => tokens.push(t),
            TokenEvent::Done(_) => break,
            TokenEvent::Failed(e) => panic!("stream failed: {}", e),
        }
    }
    tokens
}

/// Streamed tokens must concatenate to exactly the non-streaming
/// response for the same prompt — both schedulers, with the paged KV
/// quantizer off and at 4 bits.
#[test]
fn streamed_tokens_concatenate_to_whole_response_native_kv_matrix() {
    for scheduler in [SchedulerKind::Continuous, SchedulerKind::RunToCompletion] {
        for kv_bits in [None, Some(4u32)] {
            let stored = tiny_stored();
            let layout = KvLayout {
                block_tokens: 4,
                total_blocks: None,
                // Quantized cells run with sharing off: per-lane
                // quantization is content-deterministic, so repeat
                // submissions must match exactly (the same contract the
                // scheduler-differential fuzz pins down).
                prefix_sharing: kv_bits.is_none(),
                kv_bits,
            };
            let backend = NativeBackend::from_stored(&stored, 1).unwrap().with_kv_layout(layout);
            let cfg = ServeConfig {
                max_batch: 3,
                max_wait: Duration::from_millis(1),
                max_new_tokens: 6,
                buckets: vec![1, 2, 3],
                prefill_len: 16,
                pad_id: b' ' as i32,
                scheduler,
                ..ServeConfig::default()
            };
            let server = Server::start(cfg, move || Ok(backend));
            let mut rng = Rng::new(0xBEEF);
            let prompts: Vec<Vec<i32>> = (0..4)
                .map(|_| {
                    (0..3 + rng.below(8) as usize).map(|_| rng.below(256) as i32).collect()
                })
                .collect();
            // Whole-mode pass first...
            let whole: Vec<Vec<i32>> = prompts
                .iter()
                .map(|p| {
                    let (_, rx) = server.submit(p.clone(), 5).unwrap();
                    let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                    assert!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
                    resp.tokens
                })
                .collect();
            // ...then the same prompts over the stream.
            let opts = SubmitOpts { max_new_tokens: 5, ..SubmitOpts::default() };
            for (p, want) in prompts.iter().zip(&whole) {
                let (_, rx) = server.submit_streaming(p.clone(), opts).unwrap();
                let got = collect_stream(&rx);
                assert_eq!(
                    &got, want,
                    "stream != whole response ({:?}, kv_bits {:?})",
                    scheduler, kv_bits
                );
            }
            server.shutdown();
        }
    }
    println!("streaming: native kv matrix OK");
}

/// Acceptance gate: the streaming path delivers its first token while
/// the sequence is still decoding. With a 20 ms simulated decode step
/// and a 16-token target, a buffered implementation would deliver all
/// events in one burst at completion; incremental delivery leaves
/// ≥ 15 steps of wall time between the first token and `Done`.
#[test]
fn first_token_arrives_before_sequence_completes() {
    for scheduler in [SchedulerKind::Continuous, SchedulerKind::RunToCompletion] {
        let cfg = ServeConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            max_new_tokens: 16,
            buckets: vec![1],
            prefill_len: 8,
            pad_id: 0,
            scheduler,
            ..ServeConfig::default()
        };
        let server = Server::start(cfg, || {
            Ok(SimBackend::new(Duration::from_millis(1), Duration::from_millis(20)))
        });
        let opts = SubmitOpts { max_new_tokens: 16, ..SubmitOpts::default() };
        let (_, rx) = server.submit_streaming(vec![1, 2, 3], opts).unwrap();
        let first = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(matches!(first, TokenEvent::Token(_)), "got {:?}", first);
        let first_at = Instant::now();
        let mut tokens = 1usize;
        let done_at = loop {
            match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
                TokenEvent::Token(_) => tokens += 1,
                TokenEvent::Done(timing) => {
                    assert_eq!(timing.tokens, 16);
                    break Instant::now();
                }
                TokenEvent::Failed(e) => panic!("stream failed: {}", e),
            }
        };
        assert_eq!(tokens, 16);
        assert!(
            done_at - first_at >= Duration::from_millis(100),
            "stream was buffered: Done arrived {:?} after the first token ({:?})",
            done_at - first_at,
            scheduler
        );
        server.shutdown();
    }
    println!("streaming: first token precedes completion under both schedulers");
}

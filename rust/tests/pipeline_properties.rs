//! Cross-module property tests: invariants that must hold across the
//! whole quantize → serialize → load → runtime-decode → compute pipeline
//! for random configurations. These run without artifacts (pure library).

use icquant::icquant::{packed, IcqConfig, IcqMatrix};
use icquant::quant::QuantizerKind;
use icquant::synthzoo;
use icquant::util::miniprop::{check, Config};

/// The full artifact pipeline is lossless with respect to the quantized
/// representation: dequantize(load(save(q))) == dequantize(q) at f16
/// codebook precision, and the runtime plane agrees with both.
#[test]
fn prop_full_pipeline_consistency() {
    let dir = std::env::temp_dir().join("icq_pipeline_prop");
    std::fs::create_dir_all(&dir).unwrap();
    check(
        "pipeline-consistency",
        Config::with_cases(12),
        |rng, size| {
            let rows = 4 + (size * 28.0) as usize;
            let cols = 64 + (size * 400.0) as usize;
            let bits = rng.range_inclusive(2, 4) as u32;
            let ratio = 0.02 + rng.f64() * 0.08;
            let gap_bits = rng.range_inclusive(4, 8) as u32;
            let kind = if rng.bool(0.5) {
                QuantizerKind::Rtn
            } else {
                QuantizerKind::SensitiveKmeans
            };
            let seed = rng.next_u64();
            (rows, cols, bits, ratio, gap_bits, kind, seed)
        },
        |&(rows, cols, bits, ratio, gap_bits, kind, seed)| {
            let w = synthzoo::demo_matrix(rows, cols, seed);
            let cfg = IcqConfig { bits, outlier_ratio: ratio, gap_bits, quantizer: kind };
            let q = IcqMatrix::quantize(&w, None, &cfg)
                .map_err(|e| format!("quantize: {}", e))?;

            // 1. Storage accounting: measured B within the Lemma 1 bound
            //    plus clustering slack (demo matrices are near-uniform).
            let bound = icquant::icq::lemma1_bound(ratio.max(1.0 / cols as f64), gap_bits);
            let b = q.index_bits_per_weight();
            if b > bound * 1.30 + 0.05 {
                return Err(format!("B {} far above bound {}", b, bound));
            }

            // 2. Serialize → load roundtrip (f16 codebook precision).
            let path = std::env::temp_dir().join("icq_pipeline_prop/case.icqm");
            packed::save(&q, &path).map_err(|e| format!("save: {}", e))?;
            let q2 = packed::load(&path).map_err(|e| format!("load: {}", e))?;
            let d1 = q.dequantize();
            let d2 = q2.dequantize();
            if d1.mse(&d2) > 1e-5 {
                return Err(format!("save/load mse {}", d1.mse(&d2)));
            }

            // 3. Runtime plane agrees with the reference dequantization.
            let rt = q2.to_runtime();
            let d3 = rt.dequantize();
            if d2.mse(&d3) > 1e-12 {
                return Err(format!("runtime decode mse {}", d2.mse(&d3)));
            }

            // 4. matvec off the quantized plane equals dense matvec.
            let x: Vec<f32> = (0..cols).map(|i| ((i * 37 + 11) as f32 * 0.01).sin()).collect();
            let mut y = vec![0.0f32; rows];
            rt.matvec(&x, &mut y);
            for r in 0..rows {
                let want: f32 = d3.row(r).iter().zip(&x).map(|(a, b)| a * b).sum();
                if (y[r] - want).abs() > 1e-2 * (1.0 + want.abs()) {
                    return Err(format!("matvec row {}: {} vs {}", r, y[r], want));
                }
            }

            // 5. Quantization error bounded by the inlier range resolution:
            //    worse than FP but sane (no blowup on any config).
            let mse = w.mse(&d1);
            let var = w.data.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
                / w.numel() as f64;
            if mse > var {
                return Err(format!("mse {} exceeds signal var {}", mse, var));
            }
            Ok(())
        },
    );
}

/// Monotonicity: more bits ⇒ lower error; larger γ (up to ~10 %) at the
/// same bits ⇒ lower error on heavy-tailed data (the paper's Table 4
/// 8.25 % > 5 % observation at the error level).
#[test]
fn prop_error_monotonicity() {
    check(
        "error-monotonicity",
        Config::with_cases(10),
        |rng, _| rng.next_u64(),
        |&seed| {
            let w = synthzoo::demo_matrix(24, 768, seed);
            let mse_at = |bits: u32, ratio: f64| {
                let cfg = IcqConfig {
                    bits,
                    outlier_ratio: ratio,
                    gap_bits: 0,
                    quantizer: QuantizerKind::Rtn,
                };
                let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
                w.mse(&q.dequantize())
            };
            let m2 = mse_at(2, 0.05);
            let m3 = mse_at(3, 0.05);
            let m4 = mse_at(4, 0.05);
            if !(m4 < m3 && m3 < m2) {
                return Err(format!("bits not monotone: {} {} {}", m2, m3, m4));
            }
            let g0 = mse_at(2, 0.0);
            let g5 = mse_at(2, 0.05);
            if g5 >= g0 {
                return Err(format!("γ=5% ({}) not better than γ=0 ({})", g5, g0));
            }
            Ok(())
        },
    );
}

/// The permutation fallback composes with quantization: quantizing a
/// permuted o_proj-style matrix and inverting reproduces quantizing in
/// the original basis up to codebook differences, and never increases
/// the index-coding overhead.
#[test]
fn prop_permutation_composes_with_icquant() {
    use icquant::icq::ColumnPermutation;
    use icquant::synthzoo::{family, LayerType};
    let f = family("llama3-8b").unwrap();
    let w = f.gen_layer(LayerType::OProj, 0);
    let cfg = IcqConfig { bits: 2, outlier_ratio: 0.05, gap_bits: 6, quantizer: QuantizerKind::Rtn };

    let direct = IcqMatrix::quantize(&w, None, &cfg).unwrap();
    let p = ColumnPermutation::new(w.cols, 99);
    let wp = p.apply(&w);
    let permuted = IcqMatrix::quantize(&wp, None, &cfg).unwrap();

    // Overhead never increases under permutation (uniformity enforced).
    assert!(
        permuted.index_bits_per_weight() <= direct.index_bits_per_weight() + 1e-9,
        "permuted B {} > direct B {}",
        permuted.index_bits_per_weight(),
        direct.index_bits_per_weight()
    );
    // Reconstruction in the original basis has comparable error.
    let rec = p.invert(&permuted.dequantize());
    let mse_direct = w.mse(&direct.dequantize());
    let mse_perm = w.mse(&rec);
    assert!(
        mse_perm < mse_direct * 1.2 + 1e-9,
        "permuted mse {} vs direct {}",
        mse_perm,
        mse_direct
    );
}

//! End-to-end pipeline property (ISSUE 5): the whole PR 1→5 stack in
//! one test. A SynthZoo checkpoint is quantized at bits ∈ {2, 3, 4},
//! packed into an `ICQZ` container on disk, pushed through the
//! content-hash registry, reopened via the shared decode cache into
//! bit-packed `RuntimePlane`s, and served greedily by the native
//! fused-kernel model over the **paged KV cache** — asserting every
//! emitted token is **bit-identical** to an independent
//! dequantize-then-forward reference model (dense f32 matmuls, its own
//! contiguous KV), both at the model API and through the full `Server`
//! scheduler.
//!
//! Seeded via `ICQ_TEST_SEED` (miniprop reports failing seeds); kernel
//! pool widths via `ICQ_POOL_WORKERS` — the ci.sh matrix.
//!
//! ISSUE 7 adds the quantized-KV divergence gate: with `kv_bits` on,
//! streams are lossy by design, so the acceptance bar becomes
//! teacher-forced greedy agreement against the same f32 reference
//! (≥ 95% @ 8-bit, ≥ 80% @ 4-bit) with first-divergence logging.

use icquant::coordinator::backend::{argmax_rows, NativeBackend};
use icquant::coordinator::batcher::{clamp_pad_id, fit_prompt};
use icquant::coordinator::{SchedulerKind, ServeConfig, Server};
use icquant::icquant::IcqConfig;
use icquant::kernels::{KvCache, KvLayout, NativeModel, Tier};
use icquant::model::ModelConfig;
use icquant::quant::QuantizerKind;
use icquant::store::{container, synth_model, DecodeCache, Registry, StoredModel};
use icquant::synthzoo::FamilySpec;
use icquant::util::miniprop::{check, pool_worker_matrix, Config};
use icquant::util::tensor::Matrix;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("icq_e2e_pipeline").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------------------------------------------------------------------------
// Reference model: dequantized f32 weights, dense matmuls, contiguous
// per-position KV — an independent implementation of the same
// architecture. The fused kernels' accumulation contract (DESIGN.md §8)
// says gemm ≡ x · dequantize(W)ᵀ bit-for-bit, and the forward helpers
// mirror `kernels/model.rs` op for op, so the whole greedy stream must
// match exactly.
// ---------------------------------------------------------------------------

const ROPE_THETA: f32 = 10000.0;
const NORM_EPS: f32 = 1e-5;

struct RefBlock {
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    /// Dequantized projections, pre-transposed for `x · Wᵀ`.
    wq_t: Matrix,
    wk_t: Matrix,
    wv_t: Matrix,
    wo_t: Matrix,
    w_gate_t: Matrix,
    w_up_t: Matrix,
    w_down_t: Matrix,
}

struct RefModel {
    cfg: ModelConfig,
    tok_emb: Matrix,
    lm_head: Matrix,
    final_norm: Vec<f32>,
    blocks: Vec<RefBlock>,
    inv_freq: Vec<f32>,
}

/// Per-layer K/V rows, one `d_model` row per position — the simplest
/// possible contiguous cache.
struct RefKv {
    k: Vec<Vec<Vec<f32>>>,
    v: Vec<Vec<Vec<f32>>>,
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn rmsnorm(x: &[f32], w: &[f32]) -> Vec<f32> {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + NORM_EPS).sqrt();
    x.iter().zip(w).map(|(xv, wv)| xv * r * wv).collect()
}

fn apply_rope(row: &mut [f32], heads: usize, hd: usize, pos: usize, inv_freq: &[f32]) {
    let half = hd / 2;
    for head in 0..heads {
        let h = &mut row[head * hd..(head + 1) * hd];
        for (j, &freq) in inv_freq.iter().enumerate() {
            let ang = pos as f32 * freq;
            let (sin, cos) = ang.sin_cos();
            let (a, b) = (h[j], h[j + half]);
            h[j] = a * cos - b * sin;
            h[j + half] = a * sin + b * cos;
        }
    }
}

fn softmax(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

impl RefModel {
    fn build(stored: &StoredModel) -> RefModel {
        let cfg = stored.config.clone().expect("container has a config");
        let plane_t = |name: &str| -> Matrix {
            stored.runtime_plane(name).unwrap().dequantize().transpose()
        };
        let dense_mat = |name: &str| -> Matrix {
            let (shape, data) = stored.dense(name).unwrap();
            Matrix::from_vec(shape[0], shape[1], data.to_vec())
        };
        let dense_vec = |name: &str| -> Vec<f32> { stored.dense(name).unwrap().1.to_vec() };
        let blocks = (0..cfg.n_layers)
            .map(|i| RefBlock {
                attn_norm: dense_vec(&format!("l{}.attn_norm", i)),
                mlp_norm: dense_vec(&format!("l{}.mlp_norm", i)),
                wq_t: plane_t(&format!("l{}.wq", i)),
                wk_t: plane_t(&format!("l{}.wk", i)),
                wv_t: plane_t(&format!("l{}.wv", i)),
                wo_t: plane_t(&format!("l{}.wo", i)),
                w_gate_t: plane_t(&format!("l{}.w_gate", i)),
                w_up_t: plane_t(&format!("l{}.w_up", i)),
                w_down_t: plane_t(&format!("l{}.w_down", i)),
            })
            .collect();
        let half = cfg.head_dim() / 2;
        let inv_freq =
            (0..half).map(|j| ROPE_THETA.powf(-(j as f32) / half as f32)).collect();
        RefModel {
            tok_emb: dense_mat("tok_emb"),
            lm_head: dense_mat("lm_head"),
            final_norm: dense_vec("final_norm"),
            blocks,
            inv_freq,
            cfg,
        }
    }

    fn empty_kv(&self) -> RefKv {
        RefKv {
            k: vec![Vec::new(); self.cfg.n_layers],
            v: vec![Vec::new(); self.cfg.n_layers],
        }
    }

    /// Process one token at the next position; returns greedy argmax of
    /// the resulting logits.
    fn step(&self, kv: &mut RefKv, token: i32) -> i32 {
        let cfg = &self.cfg;
        let (d, hd, heads) = (cfg.d_model, cfg.head_dim(), cfg.n_heads);
        let pos = kv.k[0].len();
        let scale = 1.0 / (hd as f32).sqrt();
        let id = (token.max(0) as usize).min(cfg.vocab - 1);
        let mut x = self.tok_emb.row(id).to_vec();
        for (layer, bw) in self.blocks.iter().enumerate() {
            let h = Matrix::from_vec(1, d, rmsnorm(&x, &bw.attn_norm));
            let mut q = h.matmul(&bw.wq_t);
            let mut k = h.matmul(&bw.wk_t);
            let v = h.matmul(&bw.wv_t);
            apply_rope(q.row_mut(0), heads, hd, pos, &self.inv_freq);
            apply_rope(k.row_mut(0), heads, hd, pos, &self.inv_freq);
            kv.k[layer].push(k.data.clone());
            kv.v[layer].push(v.data.clone());

            let mut attn = vec![0.0f32; d];
            let span = pos + 1;
            let mut scores = vec![0.0f32; span];
            for head in 0..heads {
                let qh = &q.row(0)[head * hd..(head + 1) * hd];
                for (p, s) in scores.iter_mut().enumerate() {
                    *s = dot(qh, &kv.k[layer][p][head * hd..(head + 1) * hd]) * scale;
                }
                softmax(&mut scores);
                let out = &mut attn[head * hd..(head + 1) * hd];
                for (p, &w) in scores.iter().enumerate() {
                    for (o, kvv) in
                        out.iter_mut().zip(&kv.v[layer][p][head * hd..(head + 1) * hd])
                    {
                        *o += w * *kvv;
                    }
                }
            }
            let o = Matrix::from_vec(1, d, attn).matmul(&bw.wo_t);
            for (a, b) in x.iter_mut().zip(&o.data) {
                *a += *b;
            }

            let h = Matrix::from_vec(1, d, rmsnorm(&x, &bw.mlp_norm));
            let mut gate = h.matmul(&bw.w_gate_t);
            let up = h.matmul(&bw.w_up_t);
            for (g, u) in gate.data.iter_mut().zip(&up.data) {
                *g = silu(*g) * *u;
            }
            let down = gate.matmul(&bw.w_down_t);
            for (a, b) in x.iter_mut().zip(&down.data) {
                *a += *b;
            }
        }
        let h = rmsnorm(&x, &self.final_norm);
        let logits: Vec<f32> =
            (0..cfg.vocab).map(|vi| dot(self.lm_head.row(vi), &h)).collect();
        argmax_rows(&logits, 1)[0]
    }

    /// Greedy continuation: feed the prompt token by token, then `steps`
    /// generated tokens. Returns `steps + 1` tokens (the prefill
    /// prediction first) — the same shape as the native
    /// prefill-then-decode stream.
    fn continuation(&self, prompt: &[i32], steps: usize) -> Vec<i32> {
        let mut kv = self.empty_kv();
        let mut last = 0;
        for &t in prompt {
            last = self.step(&mut kv, t);
        }
        let mut out = vec![last];
        for _ in 0..steps {
            last = self.step(&mut kv, last);
            out.push(last);
        }
        out
    }
}

/// Native greedy stream through the paged cache, same shape as
/// [`RefModel::continuation`].
fn native_stream(
    m: &NativeModel,
    layout: KvLayout,
    prompt: &[i32],
    steps: usize,
) -> Vec<i32> {
    let mut kv = KvCache::with_layout(&m.config, 1, layout);
    let mut last = m.prefill_slot(&mut kv, 0, prompt).unwrap();
    let mut out = vec![last];
    for _ in 0..steps {
        last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
        out.push(last);
        kv.debug_validate();
    }
    out
}

/// Build the full artifact chain for one bit-width and return the
/// StoredModel opened from the registry-resolved container path.
fn stored_via_registry(dir: &PathBuf, bits: u32) -> StoredModel {
    let family = FamilySpec {
        name: "e2e-tiny",
        d_model: 32,
        d_ff: 64,
        n_blocks: 2,
        tail_frac: 0.02,
        tail_scale: 2.5,
        oproj_hot: 0.5,
        seed: 0xE2E0 + bits as u64,
    };
    let qcfg = IcqConfig {
        bits,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let model = synth_model(&family, &qcfg, None).unwrap();

    // Container on disk → registry put → name@hash resolve → reopen.
    let raw_path = dir.join(format!("e2e-b{}.icqz", bits));
    container::save(&model, &raw_path).unwrap();
    let loaded = container::load(&raw_path).unwrap();
    let reg = Registry::open(dir.join("registry")).unwrap();
    let record = reg.put_model(&format!("e2e-b{}", bits), &loaded).unwrap();
    let (_, resolved) = reg.resolve(&record.spec()).unwrap();
    let cache = Arc::new(DecodeCache::new(64 << 20));
    StoredModel::open(&resolved, cache).unwrap()
}

/// The acceptance property: quantize → container → registry → cached
/// packed planes → native paged serve ≡ dequantize-then-forward, at
/// every bit width, block size and pool width exercised.
#[test]
fn e2e_native_paged_serve_matches_dequantized_reference() {
    let dir = tmp_dir("bitwidths");
    let workers = pool_worker_matrix();
    for bits in [2u32, 3, 4] {
        let stored = stored_via_registry(&dir, bits);
        let reference = RefModel::build(&stored);
        for &w in &workers {
            // Pin the scalar tier: this property is exact bit-identity
            // against the dequantized reference, which only the scalar
            // tier guarantees (DESIGN.md §14).
            let native = NativeModel::from_stored(&stored, w).unwrap().with_simd(Tier::Scalar);
            check(
                &format!("e2e-pipeline-b{}-w{}", bits, w),
                Config::from_env(4),
                |rng, size| {
                    let plen = 1 + (size * 19.0) as usize;
                    let prompt: Vec<i32> =
                        (0..plen).map(|_| rng.below(256) as i32).collect();
                    let steps = 1 + rng.below(6) as usize;
                    let block_tokens =
                        *[2usize, 4, 16].get(rng.below(3) as usize).unwrap();
                    (prompt, steps, block_tokens)
                },
                |(prompt, steps, block_tokens)| {
                    let want = reference.continuation(prompt, *steps);
                    let layout = KvLayout {
                        block_tokens: *block_tokens,
                        total_blocks: None,
                        prefix_sharing: true,
                        kv_bits: None,
                    };
                    let got = native_stream(&native, layout, prompt, *steps);
                    icquant::prop_assert!(
                        got == want,
                        "bits={} workers={} bt={}: native {:?} != reference {:?}",
                        bits,
                        w,
                        block_tokens,
                        got,
                        want
                    );
                    Ok(())
                },
            );
        }
    }
    println!("e2e_pipeline: completed {} randomized cases", 3 * pool_worker_matrix().len() * 4);
}

/// The same property through the full serving stack: `Server` +
/// continuous scheduler + paged `NativeBackend`, shared-prefix prompts
/// included. The server's visible stream is the decode outputs (the
/// prefill prediction seeds generation), i.e. `continuation[1..]`.
#[test]
fn e2e_server_streams_match_dequantized_reference() {
    let dir = tmp_dir("server");
    let stored = stored_via_registry(&dir, 2);
    let reference = RefModel::build(&stored);
    let workers = pool_worker_matrix();
    let w = *workers.last().unwrap();
    // Scalar tier: the served streams are compared token-exactly.
    let native = NativeModel::from_stored(&stored, w).unwrap().with_simd(Tier::Scalar);
    let vocab = native.config.vocab;

    let cfg = ServeConfig {
        max_batch: 3,
        max_wait: Duration::from_millis(1),
        max_new_tokens: 6,
        buckets: vec![1, 2, 3],
        prefill_len: 12,
        pad_id: b' ' as i32,
        scheduler: SchedulerKind::Continuous,
        ..ServeConfig::default()
    };
    let prefill_len = cfg.prefill_len;
    let pad = clamp_pad_id(cfg.pad_id, Some(vocab));
    let layout = KvLayout {
        block_tokens: 4,
        total_blocks: None,
        prefix_sharing: true,
        kv_bits: None,
    };
    let server = Server::start(cfg, move || {
        Ok(NativeBackend::new(native).with_kv_layout(layout))
    });

    // Six requests, three sharing one system-prompt prefix.
    let system: Vec<i32> = vec![83, 89, 83, 84, 69, 77, 58, 32];
    let mut prompts = Vec::new();
    for i in 0..6 {
        let mut p = if i % 2 == 0 { system.clone() } else { vec![78 + i] };
        p.extend_from_slice(&[65 + i, 66 + i]);
        prompts.push(p);
    }
    let mut rxs = Vec::new();
    for p in &prompts {
        rxs.push(server.submit(p.clone(), 5).unwrap().1);
    }
    for (p, rx) in prompts.iter().zip(rxs) {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        let padded = fit_prompt(p, prefill_len, pad);
        let want = reference.continuation(&padded, 5);
        assert_eq!(
            resp.tokens,
            want[1..6].to_vec(),
            "served stream != dequantized reference for prompt {:?}",
            p
        );
    }
    let snap = server.metrics.snapshot();
    assert!(snap.prefix_hits > 0, "shared system prompts must hit the prefix cache");
    server.shutdown();
    println!("e2e_pipeline: server differential OK ({} prefix block hits)", snap.prefix_hits);
}

/// ISSUE 7 divergence gate: quantized-KV decoding is lossy by design,
/// so instead of bit-identity the acceptance bar is teacher-forced
/// greedy agreement with the dequantize-then-forward f32 reference —
/// every decode step feeds the **reference's** token, so each position
/// is compared under an identical context and disagreements measure
/// only the KV quantization error, never compounding token drift.
/// Gates: ≥ 95% of tokens agree at `kv_bits=8`, ≥ 80% at `kv_bits=4`;
/// the first diverging position is logged for triage.
#[test]
fn e2e_quantized_kv_decode_passes_greedy_divergence_gate() {
    let dir = tmp_dir("kv_quant_gate");
    let stored = stored_via_registry(&dir, 4);
    let reference = RefModel::build(&stored);
    let w = *pool_worker_matrix().last().unwrap();
    // Scalar tier: the agreement thresholds below were pinned against
    // the scalar kernels; KV caches created in the loop are pinned too.
    let native = NativeModel::from_stored(&stored, w).unwrap().with_simd(Tier::Scalar);
    let mut rng = icquant::util::prng::Rng::new(0xD1F7);
    let prompts: Vec<Vec<i32>> = (0..8)
        .map(|i| (0..(10 + 2 * i)).map(|_| rng.below(256) as i32).collect())
        .collect();
    const STEPS: usize = 16;
    for (kv_bits, min_agree) in [(8u32, 0.95f64), (4, 0.80)] {
        let layout = KvLayout {
            block_tokens: 4,
            total_blocks: None,
            prefix_sharing: false,
            kv_bits: Some(kv_bits),
        };
        let mut agree = 0usize;
        let mut total = 0usize;
        let mut first_divergence: Option<(usize, usize, i32, i32)> = None;
        for (pi, prompt) in prompts.iter().enumerate() {
            let want = reference.continuation(prompt, STEPS);
            let mut kv = KvCache::with_layout(&native.config, 1, layout);
            kv.set_simd(Tier::Scalar);
            let mut got = vec![native.prefill_slot(&mut kv, 0, prompt).unwrap()];
            for step in 0..STEPS {
                let forced = want[step];
                got.push(native.decode_slots(&mut kv, &[forced], &[0]).unwrap()[0]);
            }
            kv.debug_validate();
            assert!(kv.stats().blocks_quantized > 0, "gate must exercise quantized blocks");
            for (pos, (w, g)) in want.iter().zip(&got).enumerate() {
                total += 1;
                if w == g {
                    agree += 1;
                } else if first_divergence.is_none() {
                    first_divergence = Some((pi, pos, *g, *w));
                }
            }
        }
        let frac = agree as f64 / total as f64;
        if let Some((pi, pos, g, wtok)) = first_divergence {
            println!(
                "e2e_pipeline: kv{} first divergence at prompt {} pos {}: got {} want {}",
                kv_bits, pi, pos, g, wtok
            );
        }
        println!(
            "e2e_pipeline: kv{} teacher-forced greedy agreement {}/{} ({:.1}%)",
            kv_bits, agree, total, frac * 100.0
        );
        assert!(
            frac >= min_agree,
            "kv{} greedy agreement {:.3} below the {:.2} divergence gate",
            kv_bits, frac, min_agree
        );
    }
}

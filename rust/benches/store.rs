//! Store benches: ICQZ pack/load throughput, full-file verify, and the
//! cached-vs-uncached decode path the coordinator rides on. Results are
//! printed and also recorded as `BENCH_store.json` (consumed by ci.sh).

use icquant::bench::{bench_fn, bench_throughput, black_box, BenchResult};
use icquant::icquant::IcqConfig;
use icquant::quant::QuantizerKind;
use icquant::store::{container, synth_model, DecodeCache, StoredModel};
use icquant::util::json::Json;
use std::sync::Arc;

fn main() {
    let dir = std::env::temp_dir().join("icq_store_bench");
    std::fs::create_dir_all(&dir).unwrap();
    let family = icquant::synthzoo::family("llama3.2-1b").unwrap();
    let cfg = IcqConfig {
        bits: 2,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let model = synth_model(&family, &cfg, None).unwrap();
    let path = dir.join("bench.icqz");
    container::save(&model, &path).unwrap();
    let file_bytes = std::fs::metadata(&path).unwrap().len();
    let info = container::inspect(&path).unwrap();
    println!(
        "container: {} sections, {} quantized params, {:.3} bits/weight, {} bytes\n",
        info.sections.len(),
        info.quantized_params,
        info.storage_bits_per_weight,
        file_bytes
    );

    let mut results: Vec<BenchResult> = Vec::new();

    results.push(bench_throughput("store/pack (save container)", 300, file_bytes, || {
        container::save(black_box(&model), black_box(&path)).unwrap();
    }));
    println!("{}", results.last().unwrap().report());

    results.push(bench_throughput("store/load (decode container)", 300, file_bytes, || {
        black_box(container::load(black_box(&path)).unwrap());
    }));
    println!("{}", results.last().unwrap().report());

    results.push(bench_throughput("store/verify (CRC full file)", 300, file_bytes, || {
        let report = container::verify(black_box(&path)).unwrap();
        assert!(report.ok());
    }));
    println!("{}", results.last().unwrap().report());

    // Decode path: cold (fresh cache every iteration) vs hot (shared).
    let loaded = container::load(&path).unwrap();
    let cold_stored = StoredModel::from_model(loaded, Arc::new(DecodeCache::new(0)), "cold");
    let names: Vec<String> =
        cold_stored.quantized_names().iter().map(|s| s.to_string()).collect();
    let plane_bytes: u64 = names
        .iter()
        .map(|n| cold_stored.decode(n).unwrap().numel() as u64 * 4)
        .sum();
    results.push(bench_throughput(
        "store/decode all planes (uncached)",
        400,
        plane_bytes,
        || {
            for n in &names {
                black_box(cold_stored.decode(n).unwrap());
            }
        },
    ));
    println!("{}", results.last().unwrap().report());

    let hot_cache = Arc::new(DecodeCache::new(256 << 20));
    let hot_stored =
        StoredModel::from_model(container::load(&path).unwrap(), hot_cache.clone(), "hot");
    for n in &names {
        hot_stored.runtime_plane(n).unwrap(); // warm
    }
    // The cache-hit path proper: an Arc clone of the resident runtime
    // plane (what the native kernels consume per batch).
    let runtime_bytes: u64 = names
        .iter()
        .map(|n| hot_stored.runtime_plane(n).unwrap().memory_bytes() as u64)
        .sum();
    results.push(bench_throughput(
        "store/runtime planes (LRU cached)",
        400,
        runtime_bytes,
        || {
            for n in &names {
                black_box(hot_stored.runtime_plane(n).unwrap());
            }
        },
    ));
    println!("{}", results.last().unwrap().report());
    // decode() on a warm cache = cached plane + transient f32
    // dequantize (the PJRT weight-upload path).
    results.push(bench_throughput(
        "store/decode all planes (cached, transient f32)",
        400,
        plane_bytes,
        || {
            for n in &names {
                black_box(hot_stored.decode(n).unwrap());
            }
        },
    ));
    println!("{}", results.last().unwrap().report());
    let s = hot_cache.stats();
    println!(
        "  cache: {} hits / {} misses ({:.1}% hit rate)",
        s.hits,
        s.misses,
        s.hit_rate() * 100.0
    );

    results.push(bench_fn("store/to_trained_model (cached)", 300, || {
        black_box(hot_stored.to_trained_model().unwrap());
    }));
    println!("{}", results.last().unwrap().report());

    // Record machine-readable results for ci.sh / regression tracking.
    let json = Json::obj(vec![
        ("bench", Json::str("store")),
        ("container_bytes", Json::num(file_bytes as f64)),
        (
            "storage_bits_per_weight",
            Json::num(info.storage_bits_per_weight),
        ),
        (
            "results",
            Json::arr(
                results
                    .iter()
                    .map(|r| {
                        let mut fields = vec![
                            ("name", Json::str(r.name.clone())),
                            ("mean_ns", Json::num(r.mean_ns)),
                            ("p50_ns", Json::num(r.p50_ns)),
                            ("p99_ns", Json::num(r.p99_ns)),
                            ("iters", Json::num(r.iters as f64)),
                        ];
                        if let Some(b) = r.bytes_per_iter {
                            fields.push(("bytes_per_iter", Json::num(b as f64)));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("BENCH_store.json", json.to_string()).unwrap();
    println!("\nwrote BENCH_store.json");
}

//! Coordinator benches, recorded as `BENCH_serving.json` (ci.sh).
//!
//! Three tiers:
//!
//! 1. **Coordinator overhead** — full submit→respond loop over the mock
//!    backend (queueing, batching, channels; zero model cost).
//! 2. **Scheduler A/B** — the PR-acceptance workload: mixed request
//!    lengths (`max_new_tokens ∈ {2, 32}`) with staggered arrivals,
//!    served by [`SimBackend`] (deterministic mock streams + a simulated
//!    per-active-slot step cost) under both the continuous-batching
//!    scheduler and the legacy run-to-completion wave scheduler. The
//!    bench asserts per-request outputs are identical across schedulers
//!    and that continuous batching wins on throughput and short-request
//!    p50 latency.
//! 3. **PJRT decode/prefill latency** per bucket (needs `make
//!    artifacts`) — the paper-table analogue of tokens/s serving
//!    throughput.

use icquant::bench::{bench_fn, black_box, BenchResult};
use icquant::coordinator::backend::{Backend, MockBackend, PjrtBackend, SimBackend};
use icquant::coordinator::{SchedulerKind, ServeConfig, Server};
use icquant::model::{artifacts_dir, TrainedModel};
use icquant::trace::{self, Cat, Tracer};
use icquant::util::json::Json;
use std::time::{Duration, Instant};

const N_REQUESTS: usize = 32;
const SHORT_TOKENS: usize = 2;
const LONG_TOKENS: usize = 32;
const SLOTS: usize = 4;
const STAGGER: Duration = Duration::from_micros(500);
const SIM_PREFILL: Duration = Duration::from_micros(300);
const SIM_STEP_PER_SLOT: Duration = Duration::from_micros(150);

struct WorkloadReport {
    tokens: usize,
    wall_s: f64,
    tokens_per_s: f64,
    short_p50_ms: f64,
    long_p50_ms: f64,
    avg_ttft_ms: f64,
    avg_active_slots: f64,
    /// Per-request token streams, in submission order.
    outputs: Vec<Vec<i32>>,
}

fn p50(mut xs: Vec<f64>) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[(xs.len() - 1) / 2]
}

/// Mixed-length, staggered-arrival workload through one scheduler.
fn run_mixed_workload(scheduler: SchedulerKind) -> WorkloadReport {
    let cfg = ServeConfig {
        max_batch: SLOTS,
        max_wait: Duration::from_millis(3),
        max_new_tokens: LONG_TOKENS,
        buckets: vec![1, 2, SLOTS],
        prefill_len: 16,
        pad_id: b' ' as i32,
        scheduler,
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, || {
        Ok(SimBackend::new(SIM_PREFILL, SIM_STEP_PER_SLOT))
    });
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..N_REQUESTS {
        let want = if i % 2 == 0 { SHORT_TOKENS } else { LONG_TOKENS };
        let prompt: Vec<i32> = (0..8).map(|j| ((i * 13 + j) % 256) as i32).collect();
        let (_, rx) = server.submit(prompt, want).unwrap();
        rxs.push((rx, want));
        std::thread::sleep(STAGGER); // arrivals land mid-decode
    }
    let mut outputs = Vec::new();
    let mut short_lat = Vec::new();
    let mut long_lat = Vec::new();
    let mut tokens = 0usize;
    for (rx, want) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("response");
        assert!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        assert_eq!(resp.tokens.len(), want);
        tokens += resp.tokens.len();
        if want == SHORT_TOKENS {
            short_lat.push(resp.timing.total_ms());
        } else {
            long_lat.push(resp.timing.total_ms());
        }
        outputs.push(resp.tokens);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let snap = server.metrics.snapshot();
    server.shutdown();
    WorkloadReport {
        tokens,
        wall_s,
        tokens_per_s: tokens as f64 / wall_s,
        short_p50_ms: p50(short_lat),
        long_p50_ms: p50(long_lat),
        avg_ttft_ms: snap.avg_ttft_ms,
        avg_active_slots: snap.avg_active_slots,
        outputs,
    }
}

fn workload_json(r: &WorkloadReport) -> Json {
    Json::obj(vec![
        ("tokens", Json::num(r.tokens as f64)),
        ("wall_s", Json::num(r.wall_s)),
        ("tokens_per_s", Json::num(r.tokens_per_s)),
        ("short_p50_ms", Json::num(r.short_p50_ms)),
        ("long_p50_ms", Json::num(r.long_p50_ms)),
        ("avg_ttft_ms", Json::num(r.avg_ttft_ms)),
        ("avg_active_slots", Json::num(r.avg_active_slots)),
    ])
}

fn result_json(r: &BenchResult) -> Json {
    Json::obj(vec![
        ("name", Json::str(r.name.clone())),
        ("mean_ns", Json::num(r.mean_ns)),
        ("p50_ns", Json::num(r.p50_ns)),
        ("p99_ns", Json::num(r.p99_ns)),
        ("iters", Json::num(r.iters as f64)),
    ])
}

fn main() {
    // L3-only: full submit→respond loop over the mock backend measures
    // pure coordinator overhead per request (queueing, scheduling,
    // channels) — target: negligible vs a multi-ms model step.
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        max_new_tokens: 4,
        buckets: vec![1, 2, 4, 8],
        prefill_len: 16,
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, || Ok(MockBackend::new()));
    let prompt: Vec<i32> = (0..16).collect();
    let overhead = bench_fn("serving/coordinator_overhead (1 req roundtrip)", 400, || {
        let (_, rx) = server.submit(black_box(prompt.clone()), 4).unwrap();
        black_box(rx.recv().unwrap());
    });
    println!("{}", overhead.report());
    server.shutdown();

    // Scheduler A/B on the acceptance workload.
    println!(
        "\nmixed workload: {} requests, max_new_tokens ∈ {{{}, {}}}, \
         {}µs stagger, {} KV slots, sim step {}µs/slot",
        N_REQUESTS,
        SHORT_TOKENS,
        LONG_TOKENS,
        STAGGER.as_micros(),
        SLOTS,
        SIM_STEP_PER_SLOT.as_micros()
    );
    let wave = run_mixed_workload(SchedulerKind::RunToCompletion);
    let cont = run_mixed_workload(SchedulerKind::Continuous);
    // Continuous batching must change scheduling, never results.
    assert_eq!(
        cont.outputs, wave.outputs,
        "per-request outputs differ between schedulers"
    );
    let report = |name: &str, r: &WorkloadReport| {
        println!(
            "{:<24} {:>8.1} tok/s  short p50 {:>7.2} ms  long p50 {:>7.2} ms  \
             ttft {:>6.2} ms  occupancy {:>4.2}",
            name, r.tokens_per_s, r.short_p50_ms, r.long_p50_ms, r.avg_ttft_ms, r.avg_active_slots
        );
    };
    report("run-to-completion", &wave);
    report("continuous", &cont);
    println!(
        "speedup: {:.2}x throughput, {:.2}x short-request p50",
        cont.tokens_per_s / wave.tokens_per_s,
        wave.short_p50_ms / cont.short_p50_ms
    );
    assert!(
        cont.tokens_per_s > wave.tokens_per_s,
        "continuous batching lost on throughput: {:.1} vs {:.1} tok/s",
        cont.tokens_per_s,
        wave.tokens_per_s
    );
    assert!(
        cont.short_p50_ms < wave.short_p50_ms,
        "continuous batching lost on short-request p50: {:.2} vs {:.2} ms",
        cont.short_p50_ms,
        wave.short_p50_ms
    );

    // Tracing overhead: the serving hot path now carries trace
    // instants/spans that must stay ≈ free while the tracer is
    // disabled (one relaxed atomic load each, no allocation, no
    // lock). Measure the disabled probe directly and scale it to a
    // per-decode-step call count well above what the scheduler
    // actually emits.
    assert!(!Tracer::is_enabled(), "tracer must be disabled for the overhead probe");
    const PROBE_CALLS: u64 = 1024;
    let probe = bench_fn("serving/trace_disabled_instant (x1024)", 300, || {
        for i in 0..PROBE_CALLS {
            trace::instant(Cat::Sched, "probe", black_box(i), 0, 0);
        }
    });
    println!("\n{}", probe.report());
    let trace_disabled_ns_per_call = probe.mean_ns / PROBE_CALLS as f64;
    // Conservative bound: ~32 trace calls per decode step (the slot
    // loop emits a handful), against the sim backend's 150µs step.
    const TRACE_POINTS_PER_STEP: f64 = 32.0;
    let trace_overhead_pct = 100.0 * trace_disabled_ns_per_call * TRACE_POINTS_PER_STEP
        / SIM_STEP_PER_SLOT.as_nanos() as f64;
    println!(
        "trace disabled: {:.2} ns/call → {:.4}% of a {}µs decode step at {} calls/step",
        trace_disabled_ns_per_call,
        trace_overhead_pct,
        SIM_STEP_PER_SLOT.as_micros(),
        TRACE_POINTS_PER_STEP as u64
    );
    assert!(
        trace_overhead_pct < 2.0,
        "disabled tracer costs {:.3}% of a decode step (budget: 2%)",
        trace_overhead_pct
    );

    // Informational: the same workload with the tracer recording.
    Tracer::enable(trace::DEFAULT_BYTE_BUDGET);
    let traced = run_mixed_workload(SchedulerKind::Continuous);
    let traced_events = Tracer::event_count();
    Tracer::disable();
    Tracer::reset();
    assert_eq!(
        traced.outputs, cont.outputs,
        "tracing changed per-request outputs"
    );
    println!(
        "traced continuous        {:>8.1} tok/s  ({} events recorded)",
        traced.tokens_per_s, traced_events
    );

    let json = Json::obj(vec![
        ("bench", Json::str("serving")),
        (
            "workload",
            Json::obj(vec![
                ("requests", Json::num(N_REQUESTS as f64)),
                ("short_tokens", Json::num(SHORT_TOKENS as f64)),
                ("long_tokens", Json::num(LONG_TOKENS as f64)),
                ("stagger_us", Json::num(STAGGER.as_micros() as f64)),
                ("kv_slots", Json::num(SLOTS as f64)),
                ("sim_prefill_us", Json::num(SIM_PREFILL.as_micros() as f64)),
                (
                    "sim_step_per_slot_us",
                    Json::num(SIM_STEP_PER_SLOT.as_micros() as f64),
                ),
            ]),
        ),
        ("continuous", workload_json(&cont)),
        ("run_to_completion", workload_json(&wave)),
        (
            "throughput_speedup",
            Json::num(cont.tokens_per_s / wave.tokens_per_s),
        ),
        (
            "short_p50_speedup",
            Json::num(wave.short_p50_ms / cont.short_p50_ms),
        ),
        ("coordinator_overhead", result_json(&overhead)),
        ("trace_disabled_ns_per_call", Json::num(trace_disabled_ns_per_call)),
        ("trace_overhead_pct", Json::num(trace_overhead_pct)),
        ("traced_tokens_per_s", Json::num(traced.tokens_per_s)),
        ("traced_events", Json::num(traced_events as f64)),
    ]);
    std::fs::write("BENCH_serving.json", json.to_string()).unwrap();
    println!("\nwrote BENCH_serving.json");

    // End-to-end PJRT decode-step latency per bucket (needs artifacts).
    if !artifacts_dir().join("aot_manifest.json").exists() {
        println!("(skipping PJRT benches: run `make artifacts`)");
        return;
    }
    let model = TrainedModel::load(&artifacts_dir()).unwrap();
    let mut backend = PjrtBackend::new(&artifacts_dir(), &model).unwrap();
    backend.warmup().unwrap();
    for bucket in [1usize, 4, 8] {
        let prompts: Vec<Vec<i32>> = (0..bucket).map(|i| vec![(i as i32) + 65; 64]).collect();
        let mut state = backend.prefill(&prompts).unwrap();
        let r = bench_fn(&format!("serving/pjrt_decode_step_b{}", bucket), 2500, || {
            // Reset positions to keep the KV cache in range across
            // iterations (wave-uniform across lanes).
            if state.pos[0] >= 120 {
                for p in state.pos.iter_mut() {
                    *p = 64;
                }
            }
            black_box(backend.decode(&mut state).unwrap());
        });
        // tokens/s at this bucket = bucket / step-latency.
        println!(
            "{}   ({:.1} tokens/s)",
            r.report(),
            bucket as f64 / (r.mean_ns * 1e-9)
        );
    }

    // Prefill latency per bucket.
    for bucket in [1usize, 8] {
        let prompts: Vec<Vec<i32>> = (0..bucket).map(|i| vec![(i as i32) + 65; 64]).collect();
        let r = bench_fn(&format!("serving/pjrt_prefill_b{}", bucket), 2500, || {
            black_box(backend.prefill(black_box(&prompts)).unwrap());
        });
        println!("{}", r.report());
    }
}

//! Coordinator benches: batching overhead with the mock backend (pure
//! L3 cost) and, when artifacts exist, the end-to-end PJRT decode step —
//! the paper-table analogue of tokens/s serving throughput.

use icquant::bench::{bench_fn, black_box};
use icquant::coordinator::backend::{Backend, MockBackend, PjrtBackend};
use icquant::coordinator::{ServeConfig, Server};
use icquant::model::{artifacts_dir, TrainedModel};
use std::time::Duration;

fn main() {
    // L3-only: full submit→respond loop over the mock backend measures
    // pure coordinator overhead per request (queueing, batching,
    // channels) — target: negligible vs a multi-ms model step.
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        max_new_tokens: 4,
        buckets: vec![1, 2, 4, 8],
        prefill_len: 16,
    };
    let server = Server::start(cfg, MockBackend::new);
    let prompt: Vec<i32> = (0..16).collect();
    let r = bench_fn("serving/coordinator_overhead (1 req roundtrip)", 400, || {
        let (_, rx) = server.submit(black_box(prompt.clone()), 4);
        black_box(rx.recv().unwrap());
    });
    println!("{}", r.report());
    server.shutdown();

    // End-to-end PJRT decode-step latency per bucket (needs artifacts).
    if !artifacts_dir().join("aot_manifest.json").exists() {
        println!("(skipping PJRT benches: run `make artifacts`)");
        return;
    }
    let model = TrainedModel::load(&artifacts_dir()).unwrap();
    let mut backend = PjrtBackend::new(&artifacts_dir(), &model).unwrap();
    backend.warmup().unwrap();
    for bucket in [1usize, 4, 8] {
        let prompts: Vec<Vec<i32>> = (0..bucket).map(|i| vec![(i as i32) + 65; 64]).collect();
        let mut state = backend.prefill(&prompts).unwrap();
        let r = bench_fn(&format!("serving/pjrt_decode_step_b{}", bucket), 2500, || {
            // Reset pos to keep the KV cache in range across iterations.
            if state.pos >= 120 {
                state.pos = 64;
            }
            black_box(backend.decode(&mut state).unwrap());
        });
        // tokens/s at this bucket = bucket / step-latency.
        println!(
            "{}   ({:.1} tokens/s)",
            r.report(),
            bucket as f64 / (r.mean_ns * 1e-9)
        );
    }

    // Prefill latency per bucket.
    for bucket in [1usize, 8] {
        let prompts: Vec<Vec<i32>> = (0..bucket).map(|i| vec![(i as i32) + 65; 64]).collect();
        let r = bench_fn(&format!("serving/pjrt_prefill_b{}", bucket), 2500, || {
            black_box(backend.prefill(black_box(&prompts)).unwrap());
        });
        println!("{}", r.report());
    }
}

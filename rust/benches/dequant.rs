//! Dequantization / serving-plane benches — the memory-bound hot path
//! the paper's deployment argument rests on. The matvec off the
//! quantized plane is the CPU analogue of the TPU kernel in
//! python/compile/kernels/dequant_matmul.py (DESIGN.md §8).

use icquant::bench::{bench_throughput, black_box};
use icquant::bitstream::PackedPlane;
use icquant::icquant::{IcqConfig, IcqMatrix};
use icquant::quant::QuantizerKind;
use icquant::synthzoo;
use icquant::util::prng::Rng;

fn main() {
    let (rows, cols) = (512, 2048);
    let w = synthzoo::demo_matrix(rows, cols, 5);
    let cfg = IcqConfig { bits: 2, outlier_ratio: 0.05, gap_bits: 6, quantizer: QuantizerKind::Rtn };
    let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();

    // Storage plane → byte codes (bulk bit-unpack).
    let mut rng = Rng::new(1);
    let codes: Vec<u16> = (0..rows * cols).map(|_| (rng.next_u64() & 3) as u16).collect();
    let plane = PackedPlane::pack(rows, cols, 2, &codes);
    let mut out = vec![0u8; rows * cols];
    let r = bench_throughput(
        "dequant/unpack_2bit_plane (bytes out)",
        500,
        (rows * cols) as u64,
        || plane.unpack_into_u8(black_box(&mut out)),
    );
    println!("{}", r.report());

    // Full storage → runtime decode (unpack + gap streams + fuse).
    let r = bench_throughput(
        "dequant/to_runtime (storage→serving plane)",
        800,
        (rows * cols) as u64,
        || {
            black_box(q.to_runtime());
        },
    );
    println!("{}", r.report());

    // Runtime plane → f32 (the per-layer dequant a naive server would do).
    let rt = q.to_runtime();
    let r = bench_throughput(
        "dequant/runtime_to_f32 (f32 bytes out)",
        500,
        (rows * cols * 4) as u64,
        || {
            black_box(rt.dequantize());
        },
    );
    println!("{}", r.report());

    // Fused gather+FMA matvec straight off the bit-packed codes —
    // weight bytes touched per op is the packed plane (≈(n+1)/8 bytes
    // per weight + codebooks): the memory-bound figure of merit.
    let x: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut y = vec![0.0f32; rows];
    let r = bench_throughput(
        "dequant/matvec_quantized (packed bytes)",
        500,
        rt.memory_bytes() as u64,
        || rt.matvec(black_box(&x), black_box(&mut y)),
    );
    println!("{}", r.report());

    // FP32 matvec reference: touches 4x the bytes for the same math.
    let dense = rt.dequantize();
    let r = bench_throughput(
        "dequant/matvec_f32_reference (f32 bytes)",
        500,
        (rows * cols * 4) as u64,
        || {
            for i in 0..rows {
                let row = dense.row(i);
                let mut acc = 0.0f32;
                for (a, b) in row.iter().zip(&x) {
                    acc += a * b;
                }
                y[i] = black_box(acc);
            }
        },
    );
    println!("{}", r.report());
}

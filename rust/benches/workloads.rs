//! Workload-zoo trace-replay bench (ISSUE 10), recorded as
//! `BENCH_workloads.json` (ci.sh gates on its keys).
//!
//! Replays mixed serving scenarios through the real `Server` over
//! [`SimBackend`] (deterministic streams + a simulated per-slot step
//! cost) and records per-class latency distributions:
//!
//! * **chat** — many short requests sharing one system prompt.
//! * **summarize** — few long-document requests (over-long prompts the
//!   prefill window left-truncates) with long generations.
//! * **burst** — everything arrives at once; admission order and queue
//!   depth dominate.
//! * **adversarial** — over-long prompts asking for more tokens than
//!   the server allows; the clamps must serve them, not error.
//! * **disconnect** — streaming clients that vanish mid-stream; their
//!   sequences must cancel and count, not decode to target for nobody.
//! * **overload** — mixed-priority pressure on two slots with a
//!   per-class queue bound: high priority must jump the queue (the
//!   acceptance gate asserts high-priority p99 TTFT strictly below
//!   low-priority) and overflow must shed.

use icquant::coordinator::backend::SimBackend;
use icquant::coordinator::{
    Class, SchedulerKind, ServeConfig, Server, SubmitOpts, TokenEvent,
};
use icquant::util::json::Json;
use std::time::{Duration, Instant};

const PREFILL: Duration = Duration::from_micros(300);
const STEP: Duration = Duration::from_micros(400);

fn pct(mut xs: Vec<f64>, q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[((xs.len() as f64 - 1.0) * q).round() as usize]
}

fn base_cfg(slots: usize) -> ServeConfig {
    ServeConfig {
        max_batch: slots,
        max_wait: Duration::from_millis(1),
        max_new_tokens: 64,
        buckets: vec![1, 2, 4, 8],
        prefill_len: 16,
        pad_id: 0,
        scheduler: SchedulerKind::Continuous,
        ..ServeConfig::default()
    }
}

struct Req {
    prompt: Vec<i32>,
    want: usize,
    class: Class,
    tenant: u64,
    stagger: Duration,
}

impl Req {
    fn plain(prompt: Vec<i32>, want: usize) -> Req {
        Req { prompt, want, class: Class::default(), tenant: 0, stagger: Duration::ZERO }
    }
}

#[derive(Default)]
struct Outcome {
    ttft_ms: Vec<f64>,
    itl_ms: Vec<f64>,
    tokens: usize,
    wall_s: f64,
    failed: usize,
}

impl Outcome {
    fn absorb(&mut self, resp: icquant::coordinator::GenerateResponse) {
        match resp.timing.error {
            Some(_) => self.failed += 1,
            None => {
                self.ttft_ms.push(resp.timing.ttft_ms);
                // Mean inter-token gap per request; the per-class
                // distribution below is across requests.
                self.itl_ms.push(resp.timing.decode_ms / resp.tokens.len().max(1) as f64);
                self.tokens += resp.tokens.len();
            }
        }
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("requests_ok", Json::num(self.ttft_ms.len() as f64)),
            ("requests_failed", Json::num(self.failed as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("p50_ttft_ms", Json::num(pct(self.ttft_ms.clone(), 0.50))),
            ("p99_ttft_ms", Json::num(pct(self.ttft_ms.clone(), 0.99))),
            ("p50_itl_ms", Json::num(pct(self.itl_ms.clone(), 0.50))),
            ("p99_itl_ms", Json::num(pct(self.itl_ms.clone(), 0.99))),
        ])
    }
}

/// Replay one scenario: submit in order (with optional stagger), then
/// collect every response.
fn replay(name: &str, cfg: ServeConfig, reqs: Vec<Req>) -> Outcome {
    let server = Server::start(cfg, || Ok(SimBackend::new(PREFILL, STEP)));
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for r in reqs {
        if r.stagger > Duration::ZERO {
            std::thread::sleep(r.stagger);
        }
        let opts = SubmitOpts { max_new_tokens: r.want, class: r.class, tenant: r.tenant };
        rxs.push(server.submit_with(r.prompt, opts).unwrap().1);
    }
    let mut out = Outcome::default();
    for rx in rxs {
        out.absorb(rx.recv_timeout(Duration::from_secs(60)).expect("response"));
    }
    out.wall_s = t0.elapsed().as_secs_f64();
    server.shutdown();
    println!(
        "{:<12} {:>3} ok {:>2} failed  {:>6} tok  p50 ttft {:>7.2} ms  p99 ttft {:>7.2} ms",
        name,
        out.ttft_ms.len(),
        out.failed,
        out.tokens,
        pct(out.ttft_ms.clone(), 0.50),
        pct(out.ttft_ms.clone(), 0.99),
    );
    out
}

fn chat() -> Outcome {
    // A shared 12-token system prompt with short unique tails, arriving
    // on a light stagger — the steady-state interactive mix.
    let system: Vec<i32> = (0..12).map(|i| 64 + i).collect();
    let reqs = (0..12)
        .map(|i| {
            let mut p = system.clone();
            p.extend([100 + i, 101 + i, 102 + i]);
            Req { stagger: Duration::from_micros(500), ..Req::plain(p, 8) }
        })
        .collect();
    replay("chat", base_cfg(4), reqs)
}

fn summarize() -> Outcome {
    // Long documents (left-truncated to the prefill window) with long
    // generations: few requests, deep decode.
    let reqs = (0..4)
        .map(|i| Req::plain((0..64).map(|j| (i * 64 + j) % 256).collect(), 24))
        .collect();
    replay("summarize", base_cfg(2), reqs)
}

fn burst() -> Outcome {
    // Everything at once, across four tenants.
    let reqs = (0..16)
        .map(|i| Req { tenant: (i % 4) as u64, ..Req::plain(vec![i; 6], 4) })
        .collect();
    replay("burst", base_cfg(4), reqs)
}

fn adversarial() -> Outcome {
    // Prompts far beyond the prefill window asking for far more tokens
    // than allowed: the window truncates, max_new_tokens clamps, and
    // every request must still be served.
    let reqs = (0..3)
        .map(|i| Req::plain((0..512).map(|j| (i + j) % 256).collect(), 400))
        .collect();
    let out = replay("adversarial", base_cfg(2), reqs);
    assert_eq!(out.failed, 0, "adversarial prompts must clamp, not fail");
    out
}

/// Streaming clients that drop their receiver mid-stream: the server
/// must cancel their sequences (counted in `Metrics.cancelled`) while
/// patient clients on the same slots are served to completion.
fn disconnects() -> u64 {
    let server = Server::start(base_cfg(2), || Ok(SimBackend::new(PREFILL, STEP)));
    let opts = SubmitOpts { max_new_tokens: 48, ..SubmitOpts::default() };
    let mut dropped = Vec::new();
    let mut patient = Vec::new();
    for i in 0..6 {
        let (_, rx) = server.submit_streaming(vec![i; 4], opts).unwrap();
        if i < 4 {
            dropped.push(rx);
        } else {
            patient.push(rx);
        }
    }
    // Each impatient client reads two tokens, then vanishes.
    for rx in dropped {
        for _ in 0..2 {
            match rx.recv_timeout(Duration::from_secs(30)).expect("stream event") {
                TokenEvent::Token(_) => {}
                other => panic!("expected a token, got {:?}", other),
            }
        }
        drop(rx);
    }
    for rx in patient {
        let mut tokens = 0usize;
        loop {
            match rx.recv_timeout(Duration::from_secs(30)).expect("stream event") {
                TokenEvent::Token(_) => tokens += 1,
                TokenEvent::Done(_) => break,
                TokenEvent::Failed(e) => panic!("patient stream failed: {}", e),
            }
        }
        assert_eq!(tokens, 48, "patient client must be served to target");
    }
    let metrics = server.metrics.clone();
    server.shutdown();
    let cancelled = metrics.snapshot().cancelled;
    println!("{:<12} {} mid-stream disconnects cancelled", "disconnect", cancelled);
    assert!(cancelled >= 4, "disconnected streams were not cancelled: {}", cancelled);
    cancelled
}

struct Overload {
    low: Outcome,
    high: Outcome,
    shed: u64,
}

/// Mixed-priority pressure: two slots, a low-priority flood behind a
/// per-class queue bound, then a high-priority burst that must jump
/// the queue.
fn overload() -> Overload {
    let mut cfg = base_cfg(2);
    cfg.qos.max_queue_per_class = 6;
    let server = Server::start(cfg, || Ok(SimBackend::new(PREFILL, STEP)));
    let low_opts = SubmitOpts { max_new_tokens: 16, ..SubmitOpts::default() };
    let high_opts = SubmitOpts {
        max_new_tokens: 8,
        class: Class { priority: 5, deadline: None },
        ..SubmitOpts::default()
    };
    let mut low_rxs = Vec::new();
    for i in 0..12 {
        low_rxs.push(server.submit_with(vec![i; 4], low_opts).unwrap().1);
    }
    // The flood is queued (and partially shed) before the burst lands.
    std::thread::sleep(Duration::from_millis(5));
    let mut high_rxs = Vec::new();
    for i in 0..6 {
        high_rxs.push(server.submit_with(vec![100 + i; 4], high_opts).unwrap().1);
    }
    let mut low = Outcome::default();
    for rx in low_rxs {
        low.absorb(rx.recv_timeout(Duration::from_secs(60)).expect("low response"));
    }
    let mut high = Outcome::default();
    for rx in high_rxs {
        high.absorb(rx.recv_timeout(Duration::from_secs(60)).expect("high response"));
    }
    let metrics = server.metrics.clone();
    server.shutdown();
    let shed = metrics.snapshot().shed;
    assert_eq!(low.failed as u64, shed, "low-class failures must all be sheds");
    assert_eq!(high.failed, 0, "high class must never shed in this scenario");
    assert!(shed > 0, "the low-priority flood must overflow its queue bound");
    println!(
        "{:<12} high p99 ttft {:>7.2} ms  low p99 ttft {:>7.2} ms  {} shed",
        "overload",
        pct(high.ttft_ms.clone(), 0.99),
        pct(low.ttft_ms.clone(), 0.99),
        shed
    );
    Overload { low, high, shed }
}

fn main() {
    println!(
        "workload zoo: sim prefill {}µs, step {}µs/slot\n",
        PREFILL.as_micros(),
        STEP.as_micros()
    );
    let chat = chat();
    let summarize = summarize();
    let burst = burst();
    let adversarial = adversarial();
    let cancelled = disconnects();
    let ov = overload();

    let p50_high = pct(ov.high.ttft_ms.clone(), 0.50);
    let p99_high = pct(ov.high.ttft_ms.clone(), 0.99);
    let p50_low = pct(ov.low.ttft_ms.clone(), 0.50);
    let p99_low = pct(ov.low.ttft_ms.clone(), 0.99);
    // The acceptance gate: priority admission must be visible in the
    // tail — a high-priority request under overload never waits behind
    // the whole low-priority queue.
    assert!(
        p99_high < p99_low,
        "high-priority p99 TTFT must beat low-priority under overload: {:.2} vs {:.2} ms",
        p99_high,
        p99_low
    );

    let json = Json::obj(vec![
        ("bench", Json::str("workloads")),
        (
            "sim",
            Json::obj(vec![
                ("prefill_us", Json::num(PREFILL.as_micros() as f64)),
                ("step_per_slot_us", Json::num(STEP.as_micros() as f64)),
            ]),
        ),
        ("chat", chat.json()),
        ("summarize", summarize.json()),
        ("burst", burst.json()),
        ("adversarial", adversarial.json()),
        ("overload_low", ov.low.json()),
        ("overload_high", ov.high.json()),
        ("p50_ttft_ms_high", Json::num(p50_high)),
        ("p99_ttft_ms_high", Json::num(p99_high)),
        ("p50_ttft_ms_low", Json::num(p50_low)),
        ("p99_ttft_ms_low", Json::num(p99_low)),
        ("shed_requests", Json::num(ov.shed as f64)),
        ("cancelled_requests", Json::num(cancelled as f64)),
    ]);
    std::fs::write("BENCH_workloads.json", json.to_string()).unwrap();
    println!("\nwrote BENCH_workloads.json");
}

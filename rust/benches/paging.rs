//! Paged-KV serving benches, recorded as `BENCH_paging.json` (ci.sh
//! hard gate). Two A/Bs over the native fused-kernel backend:
//!
//! 1. **Layout** — the same mixed workload through the paged cache
//!    (16-token blocks) and through the contiguous-equivalent layout
//!    (one `max_seq` block per slot, sharing off): tokens/s for each,
//!    plus the block-table overhead ratio. Outputs are asserted
//!    bit-identical — paging must never change a stream.
//! 2. **Shared prefix** — the dominant multi-user scenario: every
//!    request carries the same long system prompt. With prefix sharing
//!    the registry serves the prefix blocks and prefill recomputes only
//!    the per-request tail, so TTFT and prefill latency drop; the bench
//!    records the measured improvement and the prefix-hit counters, and
//!    asserts outputs identical to the no-sharing arm.
//! 3. **Quantized KV residency** (ISSUE 7) — long aligned prefills at
//!    `kv_bits ∈ {off, 8, 4}`, recording `kv_bytes_per_token_*` and
//!    `resident_tokens_per_mib_*`; `kv4_resident_ratio` (tokens/MiB at
//!    4-bit vs f32) is asserted ≥ 3× — the headline capacity win of
//!    DESIGN.md §12 (hot f32 tails amortize with context length; the
//!    measurement uses block-aligned prompts so every block is cold).

use icquant::coordinator::backend::NativeBackend;
use icquant::coordinator::{SchedulerKind, ServeConfig, Server};
use icquant::icquant::IcqConfig;
use icquant::kernels::{KvCache, KvLayout, NativeModel};
use icquant::quant::QuantizerKind;
use icquant::store::{synth_model, DecodeCache, StoredModel};
use icquant::synthzoo::FamilySpec;
use icquant::util::json::Json;
use icquant::util::prng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

const SLOTS: usize = 4;
const THREADS: usize = 2;
const N_REQUESTS: usize = 24;
const PREFILL_LEN: usize = 48;
const SYSTEM_PROMPT: usize = 40;
const MAX_TOKENS: usize = 8;
/// Residency section: larger blocks amortize the per-channel (lo, hi)
/// range overhead of quantized planes; prompts are 3 full blocks so
/// the measurement sees only cold (quantizable) blocks.
const KV_BENCH_BLOCK_TOKENS: usize = 32;
const KV_BENCH_PREFILL: usize = 3 * KV_BENCH_BLOCK_TOKENS;

fn bench_family() -> FamilySpec {
    FamilySpec {
        name: "paging-bench",
        d_model: 64,
        d_ff: 128,
        n_blocks: 2,
        tail_frac: 0.02,
        tail_scale: 2.5,
        oproj_hot: 0.5,
        seed: 0x9A6E,
    }
}

fn stored() -> StoredModel {
    let cfg = IcqConfig {
        bits: 2,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let model = synth_model(&bench_family(), &cfg, None).unwrap();
    let cache = Arc::new(DecodeCache::new(256 << 20));
    StoredModel::from_model(model, cache, "paging-bench")
}

struct RunReport {
    tokens: usize,
    wall_s: f64,
    tokens_per_s: f64,
    avg_ttft_ms: f64,
    avg_prefill_ms: f64,
    prefix_hits: u64,
    prefix_hit_tokens: u64,
    blocks_in_use_peak: usize,
    kv_total_blocks: usize,
    block_utilization: f64,
    cow_forks: u64,
    outputs: Vec<Vec<i32>>,
}

/// Serve `prompts` through the continuous scheduler with one KV layout.
fn run_workload(stored: &StoredModel, layout: KvLayout, prompts: &[Vec<i32>]) -> RunReport {
    let native = NativeModel::from_stored(stored, THREADS).unwrap();
    let cfg = ServeConfig {
        max_batch: SLOTS,
        max_wait: Duration::from_millis(2),
        max_new_tokens: MAX_TOKENS,
        buckets: vec![1, 2, SLOTS],
        prefill_len: PREFILL_LEN,
        pad_id: b' ' as i32,
        scheduler: SchedulerKind::Continuous,
        ..ServeConfig::default()
    };
    let server =
        Server::start(cfg, move || Ok(NativeBackend::new(native).with_kv_layout(layout)));
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for p in prompts {
        rxs.push(server.submit(p.clone(), MAX_TOKENS).unwrap().1);
    }
    let mut outputs = Vec::new();
    let mut tokens = 0usize;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(600)).expect("response");
        assert!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        tokens += resp.tokens.len();
        outputs.push(resp.tokens);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let snap = server.metrics.snapshot();
    server.shutdown();
    RunReport {
        tokens,
        wall_s,
        tokens_per_s: tokens as f64 / wall_s,
        avg_ttft_ms: snap.avg_ttft_ms,
        avg_prefill_ms: snap.avg_prefill_ms,
        prefix_hits: snap.prefix_hits,
        prefix_hit_tokens: snap.prefix_hit_tokens,
        blocks_in_use_peak: snap.blocks_in_use_peak,
        kv_total_blocks: snap.kv_total_blocks,
        block_utilization: snap.block_utilization,
        cow_forks: snap.cow_forks,
        outputs,
    }
}

/// Fill every slot with a block-aligned prompt at one `kv_bits`
/// setting and read the cache's resident-byte counters — the capacity
/// side of KV quantization, measured on real cache state rather than
/// arithmetic. Returns `(bytes/token, tokens/MiB)`.
fn measure_residency(stored: &StoredModel, kv_bits: Option<u32>) -> (f64, f64) {
    let native = NativeModel::from_stored(stored, THREADS).unwrap();
    let layout = KvLayout {
        block_tokens: KV_BENCH_BLOCK_TOKENS,
        total_blocks: None,
        prefix_sharing: false,
        kv_bits,
    };
    let mut kv = KvCache::with_layout(&native.config, SLOTS, layout);
    let mut rng = Rng::new(0x4B17);
    for slot in 0..SLOTS {
        let prompt: Vec<i32> =
            (0..KV_BENCH_PREFILL).map(|_| rng.below(256) as i32).collect();
        native.prefill_slot(&mut kv, slot, &prompt).unwrap();
    }
    kv.debug_validate();
    let s = kv.stats();
    assert_eq!(s.resident_tokens, SLOTS * KV_BENCH_PREFILL);
    if kv_bits.is_some() {
        assert_eq!(
            s.quantized_blocks, s.blocks_in_use,
            "block-aligned prompts must quantize every block"
        );
    }
    let bytes_per_token = s.kv_resident_bytes as f64 / s.resident_tokens as f64;
    (bytes_per_token, (1u64 << 20) as f64 / bytes_per_token)
}

fn report_json(r: &RunReport) -> Json {
    Json::obj(vec![
        ("tokens", Json::num(r.tokens as f64)),
        ("wall_s", Json::num(r.wall_s)),
        ("tokens_per_s", Json::num(r.tokens_per_s)),
        ("avg_ttft_ms", Json::num(r.avg_ttft_ms)),
        ("avg_prefill_ms", Json::num(r.avg_prefill_ms)),
        ("prefix_hits", Json::num(r.prefix_hits as f64)),
        ("prefix_hit_tokens", Json::num(r.prefix_hit_tokens as f64)),
        ("blocks_in_use_peak", Json::num(r.blocks_in_use_peak as f64)),
        ("kv_total_blocks", Json::num(r.kv_total_blocks as f64)),
        ("block_utilization", Json::num(r.block_utilization)),
        ("cow_forks", Json::num(r.cow_forks as f64)),
    ])
}

fn main() {
    let stored = stored();
    println!(
        "paging bench: {} requests, {} KV slots, prefill {} tokens, {} decode tokens, {} threads",
        N_REQUESTS, SLOTS, PREFILL_LEN, MAX_TOKENS, THREADS
    );

    // --- 1. layout A/B: paged vs contiguous-equivalent, mixed prompts --
    let mut rng = Rng::new(0x9A6E_BEEF);
    let mixed: Vec<Vec<i32>> = (0..N_REQUESTS)
        .map(|_| {
            (0..8 + rng.below(32) as usize).map(|_| rng.below(256) as i32).collect()
        })
        .collect();
    let model_cfg = stored.config.clone().unwrap();
    let paged_layout = KvLayout {
        block_tokens: 16,
        total_blocks: None,
        prefix_sharing: true,
        kv_bits: None,
    };
    let paged = run_workload(&stored, paged_layout, &mixed);
    let contiguous = run_workload(&stored, KvLayout::contiguous(&model_cfg), &mixed);
    assert_eq!(
        paged.outputs, contiguous.outputs,
        "paged and contiguous streams must be bit-identical"
    );
    let layout_ratio = paged.tokens_per_s / contiguous.tokens_per_s;
    println!(
        "layout A/B:  paged {:.1} tok/s vs contiguous {:.1} tok/s (ratio {:.3}); \
         peak blocks {}/{} ({:.0}% utilized)",
        paged.tokens_per_s,
        contiguous.tokens_per_s,
        layout_ratio,
        paged.blocks_in_use_peak,
        paged.kv_total_blocks,
        paged.block_utilization * 100.0
    );

    // --- 2. shared system prompt: sharing on vs off -------------------
    let system: Vec<i32> = (0..SYSTEM_PROMPT).map(|_| 32 + rng.below(95) as i32).collect();
    let shared_prompts: Vec<Vec<i32>> = (0..N_REQUESTS)
        .map(|_| {
            let mut p = system.clone();
            p.extend((0..6).map(|_| rng.below(256) as i32));
            p
        })
        .collect();
    let sharing_on = run_workload(&stored, paged_layout, &shared_prompts);
    let sharing_off = run_workload(
        &stored,
        KvLayout { prefix_sharing: false, ..paged_layout },
        &shared_prompts,
    );
    assert_eq!(
        sharing_on.outputs, sharing_off.outputs,
        "prefix sharing must never change a stream"
    );
    assert!(
        sharing_on.prefix_hits > 0,
        "shared system prompts produced no prefix hits"
    );
    assert!(
        sharing_on.avg_prefill_ms < sharing_off.avg_prefill_ms,
        "prefix reuse did not reduce prefill latency: {:.2} ms vs {:.2} ms",
        sharing_on.avg_prefill_ms,
        sharing_off.avg_prefill_ms
    );
    let ttft_speedup = sharing_off.avg_ttft_ms / sharing_on.avg_ttft_ms;
    let prefill_speedup = sharing_off.avg_prefill_ms / sharing_on.avg_prefill_ms;
    println!(
        "shared-prefix: ttft {:.2} ms → {:.2} ms ({:.2}x), prefill {:.2} ms → {:.2} ms ({:.2}x)",
        sharing_off.avg_ttft_ms,
        sharing_on.avg_ttft_ms,
        ttft_speedup,
        sharing_off.avg_prefill_ms,
        sharing_on.avg_prefill_ms,
        prefill_speedup
    );
    println!(
        "               {} prefix block hits ({} prompt tokens not recomputed), {} CoW forks",
        sharing_on.prefix_hits, sharing_on.prefix_hit_tokens, sharing_on.cow_forks
    );

    // --- 3. quantized KV residency: f32 vs 8- vs 4-bit blocks ---------
    let (bpt_f32, tpm_f32) = measure_residency(&stored, None);
    let (bpt_kv8, tpm_kv8) = measure_residency(&stored, Some(8));
    let (bpt_kv4, tpm_kv4) = measure_residency(&stored, Some(4));
    let kv8_ratio = tpm_kv8 / tpm_f32;
    let kv4_ratio = tpm_kv4 / tpm_f32;
    println!(
        "kv residency:  f32 {:.0} B/token ({:.0} tokens/MiB) | kv8 {:.0} B/token \
         ({:.0} tokens/MiB, {:.2}x) | kv4 {:.0} B/token ({:.0} tokens/MiB, {:.2}x)",
        bpt_f32, tpm_f32, bpt_kv8, tpm_kv8, kv8_ratio, bpt_kv4, tpm_kv4, kv4_ratio
    );
    assert!(
        kv4_ratio >= 3.0,
        "4-bit KV must hold >= 3x more resident tokens per MiB than f32, got {:.2}x",
        kv4_ratio
    );

    let json = Json::obj(vec![
        ("bench", Json::str("paging")),
        (
            "workload",
            Json::obj(vec![
                ("requests", Json::num(N_REQUESTS as f64)),
                ("kv_slots", Json::num(SLOTS as f64)),
                ("prefill_len", Json::num(PREFILL_LEN as f64)),
                ("system_prompt_tokens", Json::num(SYSTEM_PROMPT as f64)),
                ("max_tokens", Json::num(MAX_TOKENS as f64)),
                ("block_tokens", Json::num(16.0)),
                ("threads", Json::num(THREADS as f64)),
            ]),
        ),
        ("paged", report_json(&paged)),
        ("contiguous", report_json(&contiguous)),
        ("paged_vs_contiguous_ratio", Json::num(layout_ratio)),
        ("shared_prefix", report_json(&sharing_on)),
        ("unshared_prefix", report_json(&sharing_off)),
        ("shared_prefix_ttft_speedup", Json::num(ttft_speedup)),
        ("shared_prefix_prefill_speedup", Json::num(prefill_speedup)),
        ("prefix_hits", Json::num(sharing_on.prefix_hits as f64)),
        ("kv_bench_block_tokens", Json::num(KV_BENCH_BLOCK_TOKENS as f64)),
        ("kv_bench_prefill", Json::num(KV_BENCH_PREFILL as f64)),
        ("kv_bytes_per_token_f32", Json::num(bpt_f32)),
        ("kv_bytes_per_token_kv8", Json::num(bpt_kv8)),
        ("kv_bytes_per_token_kv4", Json::num(bpt_kv4)),
        ("resident_tokens_per_mib_f32", Json::num(tpm_f32)),
        ("resident_tokens_per_mib_kv8", Json::num(tpm_kv8)),
        ("resident_tokens_per_mib_kv4", Json::num(tpm_kv4)),
        ("kv8_resident_ratio", Json::num(kv8_ratio)),
        ("kv4_resident_ratio", Json::num(kv4_ratio)),
    ]);
    std::fs::write("BENCH_paging.json", json.to_string()).unwrap();
    println!("\nwrote BENCH_paging.json");
}

//! Index-coding throughput: the load-path cost of ICQuant's storage
//! format (paper §3.2 — the overhead must be storage, not compute).

use icquant::bench::{bench_fn, bench_throughput, black_box};
use icquant::icq::{encode_gaps, RowIndexCode};
use icquant::util::prng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let d = 4096;
    let gamma = 0.05;
    let k = (gamma * d as f64) as usize;
    let b = 6u32;

    // Per-row encode.
    let positions = rng.sample_indices(d, k);
    let r = bench_fn("icq/encode_row (d=4096, γ=5%, b=6)", 300, || {
        black_box(encode_gaps(black_box(&positions), b));
    });
    println!("{}", r.report());

    // Per-row packed encode (bit stream).
    let r = bench_fn("icq/encode_packed_row", 300, || {
        black_box(RowIndexCode::encode(black_box(&positions), b));
    });
    println!("{}", r.report());

    // Decode to positions.
    let code = RowIndexCode::encode(&positions, b);
    let r = bench_fn("icq/decode_row", 300, || {
        black_box(code.decode());
    });
    println!("{}", r.report());

    // Decode into mask — the model-load hot path. Throughput counted
    // against the row's weight count (how fast we can "unlock" weights).
    let mut mask = vec![false; d];
    let r = bench_throughput("icq/decode_into_mask (per weight-byte)", 300, d as u64, || {
        mask.iter_mut().for_each(|m| *m = false);
        code.decode_into_mask(black_box(&mut mask));
    });
    println!("{}", r.report());

    // Full-matrix scale: 4096 rows (a 4096x4096 layer's index plane).
    let rows: Vec<RowIndexCode> = (0..512)
        .map(|_| RowIndexCode::encode(&rng.sample_indices(d, k), b))
        .collect();
    let total_weights = (512 * d) as u64;
    let mut mask = vec![false; d];
    let r = bench_throughput(
        "icq/decode_layer_512rows (per weight-byte)",
        500,
        total_weights,
        || {
            for code in &rows {
                mask.iter_mut().for_each(|m| *m = false);
                code.decode_into_mask(&mut mask);
            }
        },
    );
    println!("{}", r.report());
}

//! Fused quantized-plane kernel benches (DESIGN.md §8) — the numbers the
//! tentpole claims rest on, recorded as `BENCH_kernels.json` (ci.sh
//! fails if the required keys are missing).
//!
//! Four comparisons:
//!
//! * **packed vs byte plane**: fused GEMV off the bit-packed (n+1)-bit
//!   runtime plane vs the same blocked kernel off a v1-style
//!   byte-per-code plane, at 2/3/4 bits — the bandwidth story of this
//!   PR. Resident plane bytes for both layouts are recorded
//!   (`plane_shrink_ratio_2bit`; the ceiling is 8/(n+1) ≈ 2.67× at
//!   2-bit, since codes go from 8 to n+1 bits).
//! * **fused vs dequantize-then-matmul**: hot GEMV and end-to-end cache
//!   miss (storage → one served matvec), with measured peak heap via a
//!   counting allocator.
//! * **pool vs spawn**: the same multi-threaded GEMV dispatched onto the
//!   persistent worker pool vs per-call `thread::scope` spawning — the
//!   per-token overhead the pool removes.
//! * **tokens/s**: a small native-model decode loop (every projection on
//!   the pooled fused kernels), the serving-shaped figure of merit.
//! * **SIMD tier vs scalar** (DESIGN.md §14): the same fused GEMV with
//!   its inner loops dispatched on the auto-detected vector tier, plus
//!   the int8-activation integer GEMV — recorded as
//!   `simd_vs_scalar_speedup` / `simd_tier` / `int8_act_speedup`, with
//!   the speedup hard-asserted ≥ 1.3× whenever a vector tier is active.
//!
//! Every compared pair is asserted bit-identical before timing (the
//! SIMD/int8 pairs instead satisfy the bounded-error divergence
//! contract, property-tested in `tests/simd_divergence.rs`).

use icquant::bench::{bench_throughput, black_box, BenchResult};
use icquant::icquant::runtime::RuntimePlane;
use icquant::icquant::{IcqConfig, IcqMatrix};
use icquant::kernels::simd;
use icquant::kernels::{available_threads, gemv, gemv_i8, gemv_mt, gemv_tier, Tier, TierPref};
use icquant::quant::QuantizerKind;
use icquant::store::{synth_model, DecodeCache, StoredModel};
use icquant::synthzoo::FamilySpec;
use icquant::util::json::Json;
use icquant::util::tensor::Matrix;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Counting allocator: makes "peak resident bytes" a *measurement* of
// what each path actually allocates, not an arithmetic identity.
// ---------------------------------------------------------------------------

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

// SAFETY: pure pass-through to `System` plus two counters — allocation
// correctness (layout handling, null on failure) is `System`'s.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: same contract as `System.alloc`; the counter updates never
    // touch the returned memory.
    // ORDERING: relaxed — the counters are a statistic; `measure_peak`
    // runs the measured closure on the calling thread, so its own
    // allocations are sequenced, and cross-thread noise is measurement
    // jitter, not a correctness input.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    // SAFETY: same contract as `System.dealloc`.
    // ORDERING: relaxed — see `alloc`.
    unsafe fn dealloc(&self, p: *mut u8, layout: Layout) {
        System.dealloc(p, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Live heap bytes the closure adds at its peak, above its baseline.
/// ORDERING: relaxed — single-threaded measurement protocol: the
/// closure's allocations happen on this thread between the two loads.
fn measure_peak<F: FnOnce()>(f: F) -> usize {
    let base = LIVE.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    f();
    PEAK.load(Ordering::Relaxed).saturating_sub(base)
}

const ROWS: usize = 768;
const COLS: usize = 2048;
const BLOCK: usize = 512;

fn quantized(bits: u32) -> IcqMatrix {
    let w = icquant::synthzoo::demo_matrix(ROWS, COLS, 7 + bits as u64);
    let cfg = IcqConfig {
        bits,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    IcqMatrix::quantize(&w, None, &cfg).unwrap()
}

// ---------------------------------------------------------------------------
// v1 byte-per-code plane, reconstructed for the A/B: same blocked
// gather+accumulate kernel, the only difference is the code bytes moved.
// ---------------------------------------------------------------------------

struct BytePlane {
    rows: usize,
    cols: usize,
    cb_stride: usize,
    codes: Vec<u8>,
    codebooks: Vec<f32>,
}

impl BytePlane {
    fn from_runtime(rt: &RuntimePlane) -> BytePlane {
        BytePlane {
            rows: rt.rows,
            cols: rt.cols,
            cb_stride: rt.cb_stride(),
            codes: rt.byte_codes(),
            codebooks: rt.codebooks_flat().to_vec(),
        }
    }

    fn memory_bytes(&self) -> usize {
        self.codes.len() + self.codebooks.len() * 4
    }

    /// The pre-PR fused GEMV: block-staged gather off byte codes.
    fn gemv(&self, x: &[f32], y: &mut [f32]) {
        let mut levels = [0.0f32; BLOCK];
        for (r, out) in y.iter_mut().enumerate() {
            let cb = &self.codebooks[r * self.cb_stride..(r + 1) * self.cb_stride];
            let codes = &self.codes[r * self.cols..(r + 1) * self.cols];
            let mut acc = 0.0f32;
            let mut c0 = 0usize;
            while c0 < self.cols {
                let len = BLOCK.min(self.cols - c0);
                for (l, &code) in levels[..len].iter_mut().zip(&codes[c0..c0 + len]) {
                    *l = cb[code as usize];
                }
                for (l, xv) in levels[..len].iter().zip(&x[c0..c0 + len]) {
                    acc += *l * *xv;
                }
                c0 += len;
            }
            *out = acc;
        }
    }
}

/// The pre-PR multi-threaded dispatch: spawn scoped threads per call —
/// what the persistent pool replaced on the decode path. Both sides of
/// the A/B run the same kernel body (`kernels::gemv_rows`); only the
/// dispatch differs.
fn gemv_mt_spawn(plane: &RuntimePlane, x: &[f32], y: &mut [f32], threads: usize) {
    let chunk = plane.rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (ti, ychunk) in y.chunks_mut(chunk).enumerate() {
            s.spawn(move || icquant::kernels::gemv_rows(plane, x, ti * chunk, ychunk));
        }
    });
}

/// Reference y: dequantize then dense matvec (the path being replaced).
fn dequant_matvec(dense: &Matrix, x: &[f32], y: &mut [f32]) {
    for r in 0..dense.rows {
        let row = dense.row(r);
        let mut acc = 0.0f32;
        for (w, xv) in row.iter().zip(x) {
            acc += *w * *xv;
        }
        y[r] = acc;
    }
}

fn result_json(r: &BenchResult) -> Json {
    let mut fields = vec![
        ("name", Json::str(r.name.clone())),
        ("mean_ns", Json::num(r.mean_ns)),
        ("p50_ns", Json::num(r.p50_ns)),
        ("p99_ns", Json::num(r.p99_ns)),
        ("iters", Json::num(r.iters as f64)),
    ];
    if let Some(b) = r.bytes_per_iter {
        fields.push(("bytes_per_iter", Json::num(b as f64)));
    }
    Json::obj(fields)
}

fn bits_of(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

/// Native-model decode loop → tokens/s (the serving-shaped number).
fn native_tokens_per_s() -> f64 {
    let family = FamilySpec {
        name: "bench-native",
        d_model: 64,
        d_ff: 128,
        n_blocks: 2,
        tail_frac: 0.02,
        tail_scale: 2.5,
        oproj_hot: 0.5,
        seed: 0xBE7C,
    };
    let cfg = IcqConfig {
        bits: 2,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    let model = synth_model(&family, &cfg, None).unwrap();
    let cache = Arc::new(DecodeCache::new(64 << 20));
    let stored = StoredModel::from_model(model, cache, "bench-native");
    let native = icquant::kernels::NativeModel::from_stored(&stored, 2).unwrap();
    let batch = 4usize;
    let prompts: Vec<Vec<i32>> =
        (0..batch).map(|i| (0..8).map(|j| (i * 13 + j * 7) as i32 % 256).collect()).collect();
    let (mut last, mut kv) = native.prefill(&prompts).unwrap();
    // Warmup decode.
    for _ in 0..4 {
        last = native.decode_step(&mut kv, &last).unwrap();
    }
    let steps = 48usize;
    let t0 = Instant::now();
    for _ in 0..steps {
        last = native.decode_step(&mut kv, &last).unwrap();
    }
    (batch * steps) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let x: Vec<f32> = (0..COLS).map(|i| (i as f32 * 0.37).sin()).collect();
    let cores = available_threads();
    println!(
        "fused kernels bench: {}x{} plane, {} cores available\n",
        ROWS, COLS, cores
    );

    let mut results: Vec<BenchResult> = Vec::new();
    let mut footprints: Vec<Json> = Vec::new();
    let mut scaling: Vec<Json> = Vec::new();
    let mut fused_vs_dequant_speedup_2bit = 0.0f64;
    let mut packed_vs_byte_speedup_2bit = 0.0f64;
    let mut plane_shrink_ratio_2bit = 0.0f64;
    let mut bytes_per_weight_2bit = 0.0f64;

    for bits in [2u32, 3, 4] {
        let q = quantized(bits);
        let rt = q.to_runtime();
        let byte_plane = BytePlane::from_runtime(&rt);
        let dense = rt.dequantize();

        // Equal results first: fused-off-packed is bit-identical to
        // dequantize-then-matmul AND to the byte-code kernel, single-
        // and multi-threaded.
        let mut y_fused = vec![0.0f32; ROWS];
        let mut y_ref = vec![0.0f32; ROWS];
        let mut y_byte = vec![0.0f32; ROWS];
        gemv(&rt, &x, &mut y_fused);
        dequant_matvec(&dense, &x, &mut y_ref);
        byte_plane.gemv(&x, &mut y_byte);
        assert_eq!(
            bits_of(&y_fused),
            bits_of(&y_ref),
            "fused GEMV diverged from dequantize-then-matmul at {} bits",
            bits
        );
        assert_eq!(
            bits_of(&y_fused),
            bits_of(&y_byte),
            "packed plane diverged from byte-code plane at {} bits",
            bits
        );
        for threads in [2usize, 4] {
            let mut y_mt = vec![0.0f32; ROWS];
            gemv_mt(&rt, &x, &mut y_mt, threads);
            assert_eq!(y_mt, y_fused, "mt path diverged at {} threads", threads);
        }

        // Hot path: weight bytes streamed per matvec, per layout.
        let packed_bytes = rt.memory_bytes() as u64;
        let byte_bytes = byte_plane.memory_bytes() as u64;
        let f32_bytes = (ROWS * COLS * 4) as u64;
        let mut y = vec![0.0f32; ROWS];
        results.push(bench_throughput(
            &format!("kernels/gemv_packed_{}bit (1 thread)", bits),
            300,
            packed_bytes,
            || gemv(black_box(&rt), black_box(&x), black_box(&mut y)),
        ));
        println!("{}", results.last().unwrap().report());
        let packed_ns = results.last().unwrap().mean_ns;
        results.push(bench_throughput(
            &format!("kernels/gemv_byte_codes_{}bit", bits),
            300,
            byte_bytes,
            || byte_plane.gemv(black_box(&x), black_box(&mut y)),
        ));
        println!("{}", results.last().unwrap().report());
        let byte_ns = results.last().unwrap().mean_ns;
        results.push(bench_throughput(
            &format!("kernels/matvec_dequantized_f32_{}bit", bits),
            300,
            f32_bytes,
            || dequant_matvec(black_box(&dense), black_box(&x), black_box(&mut y)),
        ));
        println!("{}", results.last().unwrap().report());
        let dequant_ns = results.last().unwrap().mean_ns;

        // End-to-end cache miss: storage → one served matvec.
        results.push(bench_throughput(
            &format!("kernels/e2e_fused_decode+gemv_{}bit", bits),
            400,
            packed_bytes,
            || {
                let plane = black_box(&q).to_runtime();
                gemv(&plane, black_box(&x), black_box(&mut y));
            },
        ));
        println!("{}", results.last().unwrap().report());
        results.push(bench_throughput(
            &format!("kernels/e2e_dequant+matvec_{}bit", bits),
            400,
            f32_bytes,
            || {
                let plane = black_box(&q).to_runtime();
                let dense = plane.dequantize();
                dequant_matvec(&dense, black_box(&x), black_box(&mut y));
            },
        ));
        println!("{}", results.last().unwrap().report());

        // Measured peak heap growth of one cold serve (decode included),
        // via the counting allocator: if the fused path ever secretly
        // materialized an f32 (or byte) plane, this assert would catch it.
        let mut yp = vec![0.0f32; ROWS];
        let peak_fused = measure_peak(|| {
            let plane = black_box(&q).to_runtime();
            gemv(&plane, &x, &mut yp);
            black_box(&plane);
        });
        let peak_dequant = measure_peak(|| {
            let plane = black_box(&q).to_runtime();
            let dense = plane.dequantize();
            dequant_matvec(&dense, &x, &mut yp);
            black_box(&dense);
        });
        assert!(
            peak_fused + ROWS * COLS * 2 < peak_dequant,
            "fused path must win on measured peak resident bytes ({} vs {})",
            peak_fused,
            peak_dequant
        );
        let shrink = byte_plane.memory_bytes() as f64 / rt.memory_bytes() as f64;
        println!(
            "  resident plane: packed {} B vs byte-codes {} B ({:.2}x smaller; {:.3} bits/weight) | peak heap fused {} vs dequant {}\n",
            rt.memory_bytes(),
            byte_plane.memory_bytes(),
            shrink,
            rt.bits_per_weight(),
            peak_fused,
            peak_dequant
        );
        if bits == 2 {
            fused_vs_dequant_speedup_2bit = dequant_ns / packed_ns;
            packed_vs_byte_speedup_2bit = byte_ns / packed_ns;
            plane_shrink_ratio_2bit = shrink;
            bytes_per_weight_2bit = rt.memory_bytes() as f64 / (ROWS * COLS) as f64;
            // Codes shrink 8→(n+1) bits, so the layout ceiling at 2-bit
            // is 8/3 ≈ 2.67× (codebooks and row padding shave a little).
            assert!(
                shrink >= 2.5,
                "packed plane must shrink ≥2.5x vs byte codes at 2-bit, got {:.2}",
                shrink
            );
        }
        footprints.push(Json::obj(vec![
            ("bits", Json::num(bits as f64)),
            ("plane_bytes_packed", Json::num(rt.memory_bytes() as f64)),
            ("plane_bytes_byte_codes", Json::num(byte_plane.memory_bytes() as f64)),
            ("plane_shrink_ratio", Json::num(shrink)),
            ("resident_bits_per_weight", Json::num(rt.bits_per_weight())),
            ("peak_resident_bytes_fused", Json::num(peak_fused as f64)),
            ("peak_resident_bytes_dequant", Json::num(peak_dequant as f64)),
            ("f32_plane_bytes", Json::num((ROWS * COLS * 4) as f64)),
            ("storage_bytes", Json::num(q.storage_bytes() as f64)),
            ("equal_results", Json::Bool(true)),
        ]));
    }

    // Pool vs spawn + thread scaling on the 2-bit plane (the paper's
    // headline regime): identical partitioning, only dispatch differs.
    let q = quantized(2);
    let rt = q.to_runtime();
    let threads = 4usize.min(cores.max(1));
    let mut y_pool = vec![0.0f32; ROWS];
    let mut y_spawn = vec![0.0f32; ROWS];
    gemv_mt(&rt, &x, &mut y_pool, threads);
    gemv_mt_spawn(&rt, &x, &mut y_spawn, threads);
    assert_eq!(bits_of(&y_pool), bits_of(&y_spawn), "pool vs spawn outputs diverged");
    let mut y = vec![0.0f32; ROWS];
    let r_pool = bench_throughput(
        &format!("kernels/gemv_mt_pool ({} threads)", threads),
        300,
        rt.memory_bytes() as u64,
        || gemv_mt(black_box(&rt), black_box(&x), black_box(&mut y), threads),
    );
    println!("{}", r_pool.report());
    let r_spawn = bench_throughput(
        &format!("kernels/gemv_mt_scoped_spawn ({} threads)", threads),
        300,
        rt.memory_bytes() as u64,
        || gemv_mt_spawn(black_box(&rt), black_box(&x), black_box(&mut y), threads),
    );
    println!("{}", r_spawn.report());
    let pool_vs_spawn_speedup = r_spawn.mean_ns / r_pool.mean_ns;
    println!("\npool vs per-call spawn: {:.2}x", pool_vs_spawn_speedup);
    results.push(r_pool);
    results.push(r_spawn);

    let mut per_thread_ns = Vec::new();
    for threads in [1usize, 2, 4] {
        let mut y = vec![0.0f32; ROWS];
        let r = bench_throughput(
            &format!("kernels/gemv_packed_2bit ({} threads)", threads),
            300,
            rt.memory_bytes() as u64,
            || gemv_mt(black_box(&rt), black_box(&x), black_box(&mut y), threads),
        );
        println!("{}", r.report());
        per_thread_ns.push((threads, r.mean_ns));
        results.push(r);
    }
    let speedup_2t = per_thread_ns[0].1 / per_thread_ns[1].1;
    let speedup_4t = per_thread_ns[0].1 / per_thread_ns[2].1;
    println!(
        "thread scaling: 2t {:.2}x, 4t {:.2}x (1t baseline; {} cores)",
        speedup_2t, speedup_4t, cores
    );
    scaling.push(Json::obj(vec![
        ("cores_available", Json::num(cores as f64)),
        ("speedup_2_threads", Json::num(speedup_2t)),
        ("speedup_4_threads", Json::num(speedup_4t)),
        ("pool_vs_spawn_speedup", Json::num(pool_vs_spawn_speedup)),
    ]));

    // SIMD tier vs scalar on the 2-bit plane (DESIGN.md §14): identical
    // fused kernel, only the inner unpack/gather/accumulate dispatch
    // differs. The divergence suite is the correctness gate; here the
    // outputs are sanity-checked against the tier's bounded-error
    // contract before timing.
    let tier = simd::detect(TierPref::Auto);
    let mut y_scalar = vec![0.0f32; ROWS];
    let mut y_simd = vec![0.0f32; ROWS];
    gemv(&rt, &x, &mut y_scalar);
    gemv_tier(&rt, &x, &mut y_simd, tier);
    for (r, (a, b)) in y_scalar.iter().zip(&y_simd).enumerate() {
        let tol = 1e-4f32 * a.abs().max(1.0);
        assert!(
            (a - b).abs() <= tol,
            "simd tier diverged at row {}: {} vs {} ({} tier)",
            r,
            a,
            b,
            tier.name()
        );
    }
    let mut y = vec![0.0f32; ROWS];
    let r_scalar = bench_throughput(
        "kernels/gemv_2bit (scalar tier)",
        300,
        rt.memory_bytes() as u64,
        || gemv_tier(black_box(&rt), black_box(&x), black_box(&mut y), Tier::Scalar),
    );
    println!("{}", r_scalar.report());
    let r_simd = bench_throughput(
        &format!("kernels/gemv_2bit ({} tier)", tier.name()),
        300,
        rt.memory_bytes() as u64,
        || gemv_tier(black_box(&rt), black_box(&x), black_box(&mut y), tier),
    );
    println!("{}", r_simd.report());
    let simd_vs_scalar_speedup = r_scalar.mean_ns / r_simd.mean_ns;
    let r_i8 = bench_throughput(
        &format!("kernels/gemv_i8_2bit ({} tier)", tier.name()),
        300,
        rt.memory_bytes() as u64,
        || gemv_i8(black_box(&rt), black_box(&x), black_box(&mut y), tier),
    );
    println!("{}", r_i8.report());
    let int8_act_speedup = r_scalar.mean_ns / r_i8.mean_ns;
    println!(
        "\nSIMD tier: {} | vs scalar {:.2}x | int8 activations {:.2}x",
        tier.name(),
        simd_vs_scalar_speedup,
        int8_act_speedup
    );
    if tier != Tier::Scalar {
        // Acceptance gate: an active vector tier must actually pay.
        assert!(
            simd_vs_scalar_speedup >= 1.3,
            "active SIMD tier ({}) must be ≥1.3x over scalar, got {:.2}x",
            tier.name(),
            simd_vs_scalar_speedup
        );
    }
    results.push(r_scalar);
    results.push(r_simd);
    results.push(r_i8);

    let tokens_per_s = native_tokens_per_s();
    println!("native decode loop: {:.1} tokens/s (tiny model, pooled kernels)", tokens_per_s);

    let json = Json::obj(vec![
        ("bench", Json::str("kernels")),
        ("rows", Json::num(ROWS as f64)),
        ("cols", Json::num(COLS as f64)),
        // Required keys (checked by ci.sh): the serving figure of merit
        // and the headline speedup, both at 2-bit.
        ("bytes_per_weight", Json::num(bytes_per_weight_2bit)),
        ("fused_vs_dequant_speedup", Json::num(fused_vs_dequant_speedup_2bit)),
        ("packed_vs_byte_speedup", Json::num(packed_vs_byte_speedup_2bit)),
        ("plane_shrink_ratio_2bit", Json::num(plane_shrink_ratio_2bit)),
        ("pool_vs_spawn_speedup", Json::num(pool_vs_spawn_speedup)),
        ("simd_vs_scalar_speedup", Json::num(simd_vs_scalar_speedup)),
        ("simd_tier", Json::str(tier.name())),
        ("int8_act_speedup", Json::num(int8_act_speedup)),
        ("tokens_per_s_native", Json::num(tokens_per_s)),
        ("footprints", Json::arr(footprints)),
        ("thread_scaling", Json::arr(scaling)),
        ("results", Json::arr(results.iter().map(result_json).collect())),
    ]);
    std::fs::write("BENCH_kernels.json", json.to_string()).unwrap();
    println!("\nwrote BENCH_kernels.json");
}

//! Fused quantized-plane kernel benches (DESIGN.md §8) — the numbers the
//! tentpole claims rest on, recorded as `BENCH_kernels.json` (ci.sh).
//!
//! Three comparisons, at 2/3/4 bits and 1/2/4 threads:
//!
//! * **hot GEMV**: fused gather+FMA off the runtime plane vs matvec over
//!   a pre-dequantized f32 plane (pure bandwidth story).
//! * **end-to-end cache miss**: storage artifact → serve one matvec —
//!   fused path decodes to the runtime plane and runs the fused GEMV;
//!   the baseline additionally dequantizes to f32 before its matvec.
//!   Peak resident bytes are recorded for both; fused must win.
//! * **thread scaling**: fused GEMV at 1/2/4 threads.
//!
//! Every compared pair is asserted bit-identical before timing.

use icquant::bench::{bench_throughput, black_box, BenchResult};
use icquant::icquant::{IcqConfig, IcqMatrix};
use icquant::kernels::{available_threads, gemv, gemv_mt};
use icquant::quant::QuantizerKind;
use icquant::synthzoo;
use icquant::util::json::Json;
use icquant::util::tensor::Matrix;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

// ---------------------------------------------------------------------------
// Counting allocator: makes "peak resident bytes" a *measurement* of
// what each path actually allocates, not an arithmetic identity.
// ---------------------------------------------------------------------------

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, p: *mut u8, layout: Layout) {
        System.dealloc(p, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Live heap bytes the closure adds at its peak, above its baseline.
fn measure_peak<F: FnOnce()>(f: F) -> usize {
    let base = LIVE.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    f();
    PEAK.load(Ordering::Relaxed).saturating_sub(base)
}

const ROWS: usize = 768;
const COLS: usize = 2048;

fn quantized(bits: u32) -> IcqMatrix {
    let w = synthzoo::demo_matrix(ROWS, COLS, 7 + bits as u64);
    let cfg = IcqConfig {
        bits,
        outlier_ratio: 0.05,
        gap_bits: 6,
        quantizer: QuantizerKind::Rtn,
    };
    IcqMatrix::quantize(&w, None, &cfg).unwrap()
}

/// Reference y: dequantize then dense matvec (the path being replaced).
fn dequant_matvec(dense: &Matrix, x: &[f32], y: &mut [f32]) {
    for r in 0..dense.rows {
        let row = dense.row(r);
        let mut acc = 0.0f32;
        for (w, xv) in row.iter().zip(x) {
            acc += *w * *xv;
        }
        y[r] = acc;
    }
}

fn result_json(r: &BenchResult) -> Json {
    let mut fields = vec![
        ("name", Json::str(r.name.clone())),
        ("mean_ns", Json::num(r.mean_ns)),
        ("p50_ns", Json::num(r.p50_ns)),
        ("p99_ns", Json::num(r.p99_ns)),
        ("iters", Json::num(r.iters as f64)),
    ];
    if let Some(b) = r.bytes_per_iter {
        fields.push(("bytes_per_iter", Json::num(b as f64)));
    }
    Json::obj(fields)
}

fn main() {
    let x: Vec<f32> = (0..COLS).map(|i| (i as f32 * 0.37).sin()).collect();
    let cores = available_threads();
    println!(
        "fused kernels bench: {}x{} plane, {} cores available\n",
        ROWS, COLS, cores
    );

    let mut results: Vec<BenchResult> = Vec::new();
    let mut footprints: Vec<Json> = Vec::new();
    let mut scaling: Vec<Json> = Vec::new();

    for bits in [2u32, 3, 4] {
        let q = quantized(bits);
        let rt = q.to_runtime();
        let dense = rt.dequantize();

        // Equal results first: fused output is bit-identical to
        // dequantize-then-matmul, single- and multi-threaded.
        let mut y_fused = vec![0.0f32; ROWS];
        let mut y_ref = vec![0.0f32; ROWS];
        gemv(&rt, &x, &mut y_fused);
        dequant_matvec(&dense, &x, &mut y_ref);
        assert_eq!(
            y_fused.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "fused GEMV diverged from dequantize-then-matmul at {} bits",
            bits
        );
        for threads in [2usize, 4] {
            let mut y_mt = vec![0.0f32; ROWS];
            gemv_mt(&rt, &x, &mut y_mt, threads);
            assert_eq!(y_mt, y_fused, "mt path diverged at {} threads", threads);
        }

        // Hot path: weight bytes streamed per matvec.
        let fused_bytes = rt.memory_bytes() as u64;
        let f32_bytes = (ROWS * COLS * 4) as u64;
        let mut y = vec![0.0f32; ROWS];
        results.push(bench_throughput(
            &format!("kernels/gemv_fused_{}bit (1 thread)", bits),
            400,
            fused_bytes,
            || gemv(black_box(&rt), black_box(&x), black_box(&mut y)),
        ));
        println!("{}", results.last().unwrap().report());
        results.push(bench_throughput(
            &format!("kernels/matvec_dequantized_f32_{}bit", bits),
            400,
            f32_bytes,
            || dequant_matvec(black_box(&dense), black_box(&x), black_box(&mut y)),
        ));
        println!("{}", results.last().unwrap().report());

        // End-to-end cache miss: storage → one served matvec. The fused
        // path's peak resident set is the runtime plane; the baseline
        // holds runtime plane + f32 plane at its peak.
        results.push(bench_throughput(
            &format!("kernels/e2e_fused_decode+gemv_{}bit", bits),
            600,
            fused_bytes,
            || {
                let plane = black_box(&q).to_runtime();
                gemv(&plane, black_box(&x), black_box(&mut y));
            },
        ));
        println!("{}", results.last().unwrap().report());
        results.push(bench_throughput(
            &format!("kernels/e2e_dequant+matvec_{}bit", bits),
            600,
            f32_bytes,
            || {
                let plane = black_box(&q).to_runtime();
                let dense = plane.dequantize();
                dequant_matvec(&dense, black_box(&x), black_box(&mut y));
            },
        ));
        println!("{}", results.last().unwrap().report());

        // Measured peak heap growth of one cold serve (decode included),
        // via the counting allocator: if the fused path ever secretly
        // materialized an f32 plane, this assert would catch it.
        let mut yp = vec![0.0f32; ROWS];
        let peak_fused = measure_peak(|| {
            let plane = black_box(&q).to_runtime();
            gemv(&plane, &x, &mut yp);
            black_box(&plane);
        });
        let peak_dequant = measure_peak(|| {
            let plane = black_box(&q).to_runtime();
            let dense = plane.dequantize();
            dequant_matvec(&dense, &x, &mut yp);
            black_box(&dense);
        });
        assert!(
            peak_fused + ROWS * COLS * 2 < peak_dequant,
            "fused path must win on measured peak resident bytes ({} vs {})",
            peak_fused,
            peak_dequant
        );
        println!(
            "  measured peak heap: fused {} vs dequant {} ({:.2}x)\n",
            peak_fused,
            peak_dequant,
            peak_dequant as f64 / peak_fused as f64
        );
        footprints.push(Json::obj(vec![
            ("bits", Json::num(bits as f64)),
            ("peak_resident_bytes_fused", Json::num(peak_fused as f64)),
            ("peak_resident_bytes_dequant", Json::num(peak_dequant as f64)),
            ("runtime_plane_bytes", Json::num(rt.memory_bytes() as f64)),
            ("f32_plane_bytes", Json::num((ROWS * COLS * 4) as f64)),
            ("storage_bytes", Json::num(q.storage_bytes() as f64)),
            ("equal_results", Json::Bool(true)),
        ]));
    }

    // Thread scaling on the 2-bit plane (the paper's headline regime).
    let q = quantized(2);
    let rt = q.to_runtime();
    let mut per_thread_ns = Vec::new();
    for threads in [1usize, 2, 4] {
        let mut y = vec![0.0f32; ROWS];
        let r = bench_throughput(
            &format!("kernels/gemv_fused_2bit ({} threads)", threads),
            400,
            rt.memory_bytes() as u64,
            || gemv_mt(black_box(&rt), black_box(&x), black_box(&mut y), threads),
        );
        println!("{}", r.report());
        per_thread_ns.push((threads, r.mean_ns));
        results.push(r);
    }
    let speedup_2t = per_thread_ns[0].1 / per_thread_ns[1].1;
    let speedup_4t = per_thread_ns[0].1 / per_thread_ns[2].1;
    println!(
        "\nthread scaling: 2t {:.2}x, 4t {:.2}x (1t baseline; {} cores)",
        speedup_2t, speedup_4t, cores
    );
    scaling.push(Json::obj(vec![
        ("cores_available", Json::num(cores as f64)),
        ("speedup_2_threads", Json::num(speedup_2t)),
        ("speedup_4_threads", Json::num(speedup_4t)),
    ]));

    let json = Json::obj(vec![
        ("bench", Json::str("kernels")),
        ("rows", Json::num(ROWS as f64)),
        ("cols", Json::num(COLS as f64)),
        ("footprints", Json::arr(footprints)),
        ("thread_scaling", Json::arr(scaling)),
        ("results", Json::arr(results.iter().map(result_json).collect())),
    ]);
    std::fs::write("BENCH_kernels.json", json.to_string()).unwrap();
    println!("\nwrote BENCH_kernels.json");
}

//! Quantization-time benches: fitting cost of each method per layer —
//! the PTQ pipeline's build-time budget (paper: ICQuant needs no
//! fine-tuning and little calibration, so quantization itself is cheap).

use icquant::bench::{bench_fn, black_box};
use icquant::experiments::methods::Method;
use icquant::icquant::{IcqConfig, IcqMatrix};
use icquant::quant::{kmeans, rtn, QuantizerKind};
use icquant::synthzoo;

fn main() {
    let w = synthzoo::demo_matrix(256, 1024, 3);
    let row = w.row(17).to_vec();

    let r = bench_fn("quant/fit_rtn (row d=1024)", 200, || {
        black_box(rtn::fit_rtn(black_box(&row), 3));
    });
    println!("{}", r.report());

    let r = bench_fn("quant/fit_kmeans 8 levels (row d=1024)", 400, || {
        black_box(kmeans::fit_kmeans(black_box(&row), None, 3, 25));
    });
    println!("{}", r.report());

    for (name, cfg) in [
        (
            "quant/icq_rtn 2b matrix 256x1024",
            IcqConfig { bits: 2, outlier_ratio: 0.05, gap_bits: 6, quantizer: QuantizerKind::Rtn },
        ),
        (
            "quant/icq_sk 2b matrix 256x1024",
            IcqConfig {
                bits: 2,
                outlier_ratio: 0.05,
                gap_bits: 6,
                quantizer: QuantizerKind::SensitiveKmeans,
            },
        ),
    ] {
        let r = bench_fn(name, 1500, || {
            black_box(IcqMatrix::quantize(black_box(&w), None, &cfg).unwrap());
        });
        println!("{}", r.report());
    }

    // Method-level comparison at 2 bits (one layer each).
    for m in [
        Method::Rtn { bits: 2 },
        Method::RtnGroup { bits: 2, group: 64 },
        Method::SqueezeLite { bits: 2, ratio: 0.05 },
        Method::AqlmLite { bits: 2, dim: 2 },
        Method::IcqSk { bits: 2, ratio: 0.05 },
    ] {
        let r = bench_fn(&format!("method/{} 256x1024", m.name()), 2000, || {
            black_box(m.quantize_matrix(black_box(&w), None, 1));
        });
        println!("{}", r.report());
    }
}

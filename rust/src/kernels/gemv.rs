//! Fused quantized-plane GEMV/GEMM (DESIGN.md §8).
//!
//! `y = W x` computed **directly from the bit-packed fused (n+1)-bit
//! [`RuntimePlane`]** — per-block unpack + per-row codebook gather +
//! accumulate, no f32 weight materialization and no byte-code plane.
//! The weight bytes touched per output element are `(n+1)/8` code bytes
//! plus the (L1-resident) `2^(n+1)`-entry codebook, so the kernel moves
//! ≈3/32 of the bytes the dequantize-then-matmul path moves at 2-bit
//! (and ≈⅜ of what the byte-aligned v1 plane moved); on the memory-bound
//! shapes the paper targets that is the whole latency story.
//!
//! Unpacking is fused into the gather loop: each BLOCK of codes is
//! unpacked into a stack `u8` buffer
//! ([`crate::bitstream::unpack_aligned_u8`] — fixed-width octet paths
//! for the serving widths, generic tail fallback), then LUT-gathered.
//! Rows are byte-aligned and `BLOCK·width ≡ 0 (mod 8)`, so every block
//! starts on a byte boundary — no bit-offset bookkeeping in the loop.
//!
//! Accumulation contract: every output element is produced by **one f32
//! accumulator walking columns in order**, exactly like
//! [`RuntimePlane::dequantize`] followed by [`Matrix::matmul`]. The
//! blocked inner loop only stages codes and decoded levels into stack
//! buffers — it never reassociates the sum — so fused output is
//! bit-identical to the dequantize-then-matmul reference
//! (property-tested in `tests/kernels_prop.rs`). Scope: the contract
//! holds for **finite** activations — [`Matrix::matmul`] skips exact-0.0
//! weights, so a ±∞/NaN activation at a column whose dequantized level
//! is exactly 0.0 would propagate here (0·∞ = NaN) but be skipped by the
//! dense reference.
//!
//! Threading: row-partitioned (GEMV) or batch/band-partitioned (GEMM)
//! chunks dispatched onto a persistent [`WorkerPool`] — `gemv_mt`/
//! `gemm_mt` use the process-global pool, the `*_on` forms take an
//! explicit handle (what [`NativeModel`](crate::kernels::NativeModel)
//! threads through). No `thread::scope` spawn remains on the per-token
//! decode path. Each output element is still written by exactly one
//! chunk, so the bit-identity contract survives pooling unchanged; a
//! panicking chunk is re-raised with its failing row range in the
//! message instead of poisoning the region with a bare join.
//!
//! SIMD tier (DESIGN.md §14): every kernel exists in the plain form
//! above (the scalar reference — the historical entry points are
//! unchanged and stay bit-identical) and a `*_tier` form taking a
//! resolved [`Tier`]. The BLOCK staging loop is shared
//! (`for_each_block`), so the tier dispatches in exactly one place;
//! [`Tier::Scalar`] routes through the same scalar bodies as the plain
//! entry points, and vector tiers carry the bounded-error contract
//! enforced by `tests/simd_divergence.rs`. [`gemv_i8`]/[`gemv_i8_on`]
//! are the opt-in int8-activation form of the GEMV
//! (`--act-quant=int8`).

use crate::bitstream::unpack_aligned_u8;
use crate::icquant::runtime::RuntimePlane;
use crate::kernels::pool::{self, PoolPanic, WorkerPool};
use crate::kernels::simd::{self, Tier};
use crate::util::tensor::Matrix;

/// Codes decoded per gather block. Sized so the staged codes + levels
/// (`BLOCK × 5 B`) stay well inside L1 alongside the codebook; any
/// width's block (`BLOCK·width` bits) is a whole number of bytes.
const BLOCK: usize = 512;

/// Single-threaded fused GEMV: `y[r] = Σ_c cb_r[code(r,c)] · x[c]`.
///
/// Bit-identical to `plane.dequantize()` then dense matvec (same
/// accumulation order, see module docs).
pub fn gemv(plane: &RuntimePlane, x: &[f32], y: &mut [f32]) {
    gemv_tier(plane, x, y, Tier::Scalar)
}

/// Tier-dispatched fused GEMV: [`gemv`] with the inner loops routed
/// through the resolved SIMD [`Tier`]. `Tier::Scalar` is bit-identical
/// to [`gemv`]; vector tiers are bounded by the divergence contract
/// (DESIGN.md §14).
pub fn gemv_tier(plane: &RuntimePlane, x: &[f32], y: &mut [f32], tier: Tier) {
    assert_eq!(x.len(), plane.cols, "x length must equal plane cols");
    assert_eq!(y.len(), plane.rows, "y length must equal plane rows");
    gemv_rows_tier(plane, x, 0, y, tier);
}

/// Drive `consume(c0, levels)` over every decoded BLOCK of weight row
/// `r` — the single staging loop all fused kernels share, and the SIMD
/// tier's one integration point. BLOCK-aligned offsets start on byte
/// boundaries, so each block is a pure byte-window unpack; the decoded
/// levels are bit-identical in every tier (only downstream
/// accumulation differs). `codes`/`levels` are caller-owned stack
/// scratch so row loops reuse them without reallocation.
// lint: hot-path
#[inline(always)]
fn for_each_block(
    plane: &RuntimePlane,
    r: usize,
    tier: Tier,
    codes: &mut [u8; BLOCK],
    levels: &mut [f32; BLOCK],
    mut consume: impl FnMut(usize, &[f32]),
) {
    let cols = plane.cols;
    let width = plane.width();
    let wbits = width as usize;
    let cb = plane.codebook(r);
    let bytes = plane.row_bytes(r);
    let mut c0 = 0usize;
    while c0 < cols {
        let len = BLOCK.min(cols - c0);
        let src = &bytes[c0 * wbits / 8..];
        simd::unpack_gather(tier, src, width, cb, &mut codes[..len], &mut levels[..len]);
        consume(c0, &levels[..len]);
        c0 += len;
    }
}

/// Fused GEMV over the row range `[row0, row0 + y.len())` — the unit the
/// pooled path hands to each chunk. Hidden-public so the pool-vs-spawn
/// bench baseline dispatches the *same* kernel body it times against
/// (`benches/kernels.rs`); not part of the supported API.
// lint: hot-path
#[doc(hidden)]
pub fn gemv_rows(plane: &RuntimePlane, x: &[f32], row0: usize, y: &mut [f32]) {
    gemv_rows_tier(plane, x, row0, y, Tier::Scalar)
}

/// [`gemv_rows`] with the inner loops dispatched on `tier`. The f32
/// accumulator is carried **across** blocks ([`simd::dot_acc`]), which
/// is what keeps the scalar tier bit-identical to the dense reference:
/// a per-block dot-from-zero would reassociate the sum.
// lint: hot-path
fn gemv_rows_tier(plane: &RuntimePlane, x: &[f32], row0: usize, y: &mut [f32], tier: Tier) {
    let mut codes = [0u8; BLOCK];
    let mut levels = [0.0f32; BLOCK];
    for (i, out) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let mut acc = 0.0f32;
        for_each_block(plane, r, tier, &mut codes, &mut levels, |c0, lv| {
            acc = simd::dot_acc(tier, acc, lv, &x[c0..c0 + lv.len()]);
        });
        *out = acc;
    }
}

/// Multi-threaded fused GEMV on the process-global pool: contiguous row
/// chunks, partitioned `threads` ways. `threads ≤ 1` (or a single-chunk
/// split) runs inline.
pub fn gemv_mt(plane: &RuntimePlane, x: &[f32], y: &mut [f32], threads: usize) {
    gemv_chunked(pool::global(), plane, x, y, threads, Tier::Scalar)
}

/// [`gemv_mt`] on an explicit pool, partitioned to the pool's width.
pub fn gemv_on(pool: &WorkerPool, plane: &RuntimePlane, x: &[f32], y: &mut [f32]) {
    gemv_chunked(pool, plane, x, y, pool.threads(), Tier::Scalar)
}

/// [`gemv_on`] dispatched on `tier`. Chunking never changes the result
/// within a tier: each output element is produced by one chunk with the
/// tier's fixed reduction tree, so pooled output is bit-identical to
/// [`gemv_tier`] at any worker count.
pub fn gemv_on_tier(pool: &WorkerPool, plane: &RuntimePlane, x: &[f32], y: &mut [f32], tier: Tier) {
    gemv_chunked(pool, plane, x, y, pool.threads(), tier)
}

fn gemv_chunked(
    pool: &WorkerPool,
    plane: &RuntimePlane,
    x: &[f32],
    y: &mut [f32],
    threads: usize,
    tier: Tier,
) {
    assert_eq!(x.len(), plane.cols, "x length must equal plane cols");
    assert_eq!(y.len(), plane.rows, "y length must equal plane rows");
    let threads = threads.max(1).min(plane.rows.max(1));
    if threads == 1 {
        return gemv_rows_tier(plane, x, 0, y, tier);
    }
    let chunk = plane.rows.div_ceil(threads);
    let rows = plane.rows;
    if let Err(p) = pool.try_for_chunks_mut(y, chunk, |ti, ychunk| {
        gemv_rows_tier(plane, x, ti * chunk, ychunk, tier)
    }) {
        panic_with_rows("fused GEMV", "output rows", p, chunk, rows);
    }
}

/// Fused GEMV with int8-quantized activations (`--act-quant=int8`,
/// DESIGN.md §14): activations get one per-call absmax i8 scale, each
/// row's codebook an absmax i8 scale, and the inner product runs in
/// integers. Integer accumulation is exact, so the result is identical
/// across tiers; error vs the f32 path is bounded by the two
/// quantization steps (see `tests/simd_divergence.rs`).
pub fn gemv_i8(plane: &RuntimePlane, x: &[f32], y: &mut [f32], tier: Tier) {
    assert_eq!(x.len(), plane.cols, "x length must equal plane cols");
    assert_eq!(y.len(), plane.rows, "y length must equal plane rows");
    let mut xq = Vec::new();
    let x_scale = simd::quantize_activations(x, &mut xq);
    gemv_rows_i8(plane, &xq, x_scale, 0, y, tier);
}

/// [`gemv_i8`] on an explicit pool, row-partitioned like [`gemv_on`].
/// Activations are quantized once, before the fan-out.
pub fn gemv_i8_on(pool: &WorkerPool, plane: &RuntimePlane, x: &[f32], y: &mut [f32], tier: Tier) {
    assert_eq!(x.len(), plane.cols, "x length must equal plane cols");
    assert_eq!(y.len(), plane.rows, "y length must equal plane rows");
    let mut xq = Vec::new();
    let x_scale = simd::quantize_activations(x, &mut xq);
    let threads = pool.threads().max(1).min(plane.rows.max(1));
    if threads == 1 {
        return gemv_rows_i8(plane, &xq, x_scale, 0, y, tier);
    }
    let chunk = plane.rows.div_ceil(threads);
    let rows = plane.rows;
    let xq = &xq;
    if let Err(p) = pool.try_for_chunks_mut(y, chunk, |ti, ychunk| {
        gemv_rows_i8(plane, xq, x_scale, ti * chunk, ychunk, tier)
    }) {
        panic_with_rows("int8 fused GEMV", "output rows", p, chunk, rows);
    }
}

/// Int8 GEMV over the row range `[row0, row0 + y.len())`: unpack codes,
/// gather i8 levels from the row's quantized codebook, integer inner
/// product per block (≤ 512·127² per block keeps the i32 lanes exact),
/// i64 accumulate across blocks, one f64 rescale at the end (an i64
/// magnitude can exceed f32's 2²⁴ integer range).
// lint: hot-path
fn gemv_rows_i8(
    plane: &RuntimePlane,
    xq: &[i8],
    x_scale: f32,
    row0: usize,
    y: &mut [f32],
    tier: Tier,
) {
    let cols = plane.cols;
    let width = plane.width();
    let wbits = width as usize;
    let entries = 1usize << width;
    let mut codes = [0u8; BLOCK];
    let mut li8 = [0i8; BLOCK];
    let mut cb_i8 = [0i8; 256];
    for (i, out) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let cb_scale = simd::quantize_codebook(plane.codebook(r), &mut cb_i8);
        let bytes = plane.row_bytes(r);
        let mut acc = 0i64;
        let mut c0 = 0usize;
        while c0 < cols {
            let len = BLOCK.min(cols - c0);
            unpack_aligned_u8(&bytes[c0 * wbits / 8..], width, &mut codes[..len]);
            simd::gather_i8(tier, &codes[..len], &cb_i8, entries, &mut li8[..len]);
            acc += simd::dot_i8(tier, &li8[..len], &xq[c0..c0 + len]) as i64;
            c0 += len;
        }
        *out = (acc as f64 * cb_scale as f64 * x_scale as f64) as f32;
    }
}

/// Re-raise a pooled chunk's panic with the failing row range attached.
fn panic_with_rows(kernel: &str, what: &str, p: PoolPanic, chunk: usize, total: usize) -> ! {
    let r0 = p.task * chunk;
    let r1 = ((p.task + 1) * chunk).min(total);
    std::panic::panic_any(format!(
        "{} worker for {} {}..{} panicked: {}",
        kernel,
        what,
        r0,
        r1,
        p.message()
    ))
}

/// Single-threaded fused GEMM: `y = x Wᵀ` with `x: (m × cols)` row-major
/// activations and `y: (m × rows)` — the serving shape (each `x` row is
/// one token's activation vector). `y` is overwritten, not accumulated
/// into.
///
/// Each weight row's block is unpacked and decoded once and reused
/// across all `m` activation rows; every `y[i][r]` still accumulates in
/// column order with a single accumulator (bit-identical to the dense
/// path).
pub fn gemm(plane: &RuntimePlane, x: &Matrix, y: &mut Matrix) {
    gemm_tier(plane, x, y, Tier::Scalar)
}

/// Tier-dispatched fused GEMM: [`gemm`] with the inner loops routed
/// through the resolved SIMD [`Tier`] (same contract as [`gemv_tier`]).
pub fn gemm_tier(plane: &RuntimePlane, x: &Matrix, y: &mut Matrix, tier: Tier) {
    assert_eq!(x.cols, plane.cols, "x cols must equal plane cols");
    assert_eq!((y.rows, y.cols), (x.rows, plane.rows), "y must be (m × rows)");
    gemm_slice(plane, x, 0, x.rows, &mut y.data, tier);
}

/// Multi-threaded fused GEMM on the process-global pool. `y` is
/// overwritten.
///
/// Partitioning adapts to the shape: with enough activation rows each
/// chunk takes a contiguous `x`-row range (reads shared, writes disjoint
/// `y` rows); when the batch is smaller than the executor count — the
/// bucket-1 decode step, exactly where latency matters — chunks take
/// contiguous *weight-row* bands instead, each computing a column band
/// of `y` into a private buffer that is stitched afterwards.
pub fn gemm_mt(plane: &RuntimePlane, x: &Matrix, y: &mut Matrix, threads: usize) {
    gemm_chunked(pool::global(), plane, x, y, threads, Tier::Scalar)
}

/// [`gemm_mt`] on an explicit pool, partitioned to the pool's width —
/// the per-token serving entry ([`crate::kernels::NativeModel`]).
pub fn gemm_on(pool: &WorkerPool, plane: &RuntimePlane, x: &Matrix, y: &mut Matrix) {
    gemm_chunked(pool, plane, x, y, pool.threads(), Tier::Scalar)
}

/// [`gemm_on`] dispatched on `tier` — what
/// [`crate::kernels::NativeModel`] routes every projection through.
pub fn gemm_on_tier(pool: &WorkerPool, plane: &RuntimePlane, x: &Matrix, y: &mut Matrix, t: Tier) {
    gemm_chunked(pool, plane, x, y, pool.threads(), t)
}

fn gemm_chunked(
    pool: &WorkerPool,
    plane: &RuntimePlane,
    x: &Matrix,
    y: &mut Matrix,
    threads: usize,
    tier: Tier,
) {
    assert_eq!(x.cols, plane.cols, "x cols must equal plane cols");
    assert_eq!((y.rows, y.cols), (x.rows, plane.rows), "y must be (m × rows)");
    let threads = threads.max(1);
    let m = x.rows;
    if threads == 1 || m == 0 {
        return gemm_slice(plane, x, 0, m, &mut y.data, tier);
    }
    let rows_w = plane.rows;
    if m >= threads {
        let chunk = m.div_ceil(threads);
        if let Err(p) = pool.try_for_chunks_mut(&mut y.data, chunk * rows_w, |ti, yslice| {
            let mc = yslice.len() / rows_w;
            gemm_slice(plane, x, ti * chunk, mc, yslice, tier);
        }) {
            panic_with_rows("fused GEMM", "activation rows", p, chunk, m);
        }
        return;
    }
    // Batch smaller than the executor count: band over weight rows.
    let t = threads.min(rows_w);
    if t <= 1 {
        return gemm_slice(plane, x, 0, m, &mut y.data, tier);
    }
    let chunk = rows_w.div_ceil(t);
    let n_bands = rows_w.div_ceil(chunk);
    // One flat scratch with a uniform per-band stride (the tail band
    // short-writes) instead of a Vec of per-band Vecs: this path is the
    // bucket-1 decode step, and the hot-path audit (DESIGN.md §13)
    // flagged its n_bands+1 allocations per call — now a single buffer.
    let stride = m * chunk;
    let mut flat = vec![0.0f32; n_bands * stride];
    if let Err(p) = pool.try_for_chunks_mut(&mut flat, stride, |ti, band| {
        let r0 = ti * chunk;
        let r1 = ((ti + 1) * chunk).min(rows_w);
        gemm_band_into(plane, x, r0, r1, &mut band[..m * (r1 - r0)], tier);
    }) {
        // One panicking band must not poison the forward anonymously:
        // name the weight-row range it owned.
        panic_with_rows("fused GEMM band", "weight rows", p, chunk, rows_w);
    }
    for ti in 0..n_bands {
        let r0 = ti * chunk;
        let bw = (rows_w - r0).min(chunk);
        let band = &flat[ti * stride..][..m * bw];
        for i in 0..m {
            y.data[i * rows_w + r0..i * rows_w + r0 + bw]
                .copy_from_slice(&band[i * bw..(i + 1) * bw]);
        }
    }
}

/// Fused GEMM over activation rows `i0..i0+m` of `x`, writing `y` (the
/// matching `m × plane.rows` row-major output slice; overwritten).
// lint: hot-path
fn gemm_slice(plane: &RuntimePlane, x: &Matrix, i0: usize, m: usize, y: &mut [f32], tier: Tier) {
    debug_assert_eq!(y.len(), m * plane.rows);
    let rows_w = plane.rows;
    for v in y.iter_mut() {
        *v = 0.0;
    }
    let mut codes = [0u8; BLOCK];
    let mut levels = [0.0f32; BLOCK];
    for r in 0..rows_w {
        for_each_block(plane, r, tier, &mut codes, &mut levels, |c0, lv| {
            for i in 0..m {
                let xrow = &x.row(i0 + i)[c0..c0 + lv.len()];
                let cell = &mut y[i * rows_w + r];
                *cell = simd::dot_acc(tier, *cell, lv, xrow);
            }
        });
    }
}

/// Fused GEMM restricted to weight rows `r0..r1`, overwriting `band`
/// (exactly `m × (r1-r0)`, row-major) with the column band of `y`, each
/// element accumulated in column order by one chunk (the bit-identity
/// contract holds per tier).
// lint: hot-path
fn gemm_band_into(
    plane: &RuntimePlane,
    x: &Matrix,
    r0: usize,
    r1: usize,
    band: &mut [f32],
    tier: Tier,
) {
    let m = x.rows;
    let bw = r1 - r0;
    debug_assert_eq!(band.len(), m * bw);
    for v in band.iter_mut() {
        *v = 0.0;
    }
    let mut codes = [0u8; BLOCK];
    let mut levels = [0.0f32; BLOCK];
    for r in r0..r1 {
        for_each_block(plane, r, tier, &mut codes, &mut levels, |c0, lv| {
            for i in 0..m {
                let xrow = &x.row(i)[c0..c0 + lv.len()];
                let cell = &mut band[i * bw + (r - r0)];
                *cell = simd::dot_acc(tier, *cell, lv, xrow);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::{IcqConfig, IcqMatrix};
    use crate::synthzoo;

    fn runtime(rows: usize, cols: usize, bits: u32, seed: u64) -> RuntimePlane {
        let w = synthzoo::demo_matrix(rows, cols, seed);
        let cfg = IcqConfig { bits, outlier_ratio: 0.05, gap_bits: 6, ..Default::default() };
        IcqMatrix::quantize(&w, None, &cfg).unwrap().to_runtime()
    }

    fn xvec(cols: usize) -> Vec<f32> {
        (0..cols).map(|i| (i as f32 * 0.37).sin()).collect()
    }

    /// Reference: dequantize to f32, then dense matmul.
    fn dequant_matvec(plane: &RuntimePlane, x: &[f32]) -> Vec<f32> {
        let dense = plane.dequantize();
        let xm = Matrix::from_vec(x.len(), 1, x.to_vec());
        dense.matmul(&xm).data
    }

    #[test]
    fn gemv_bit_identical_to_dequant_matmul() {
        for bits in [2u32, 3, 4, 5] {
            let plane = runtime(64, 777, bits, 41 + bits as u64);
            let x = xvec(777);
            let mut y = vec![0.0f32; 64];
            gemv(&plane, &x, &mut y);
            let want = dequant_matvec(&plane, &x);
            for (a, b) in y.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "bits={}", bits);
            }
        }
    }

    #[test]
    fn gemv_mt_matches_single_thread_exactly() {
        // Odd row count vs thread count exercises the remainder chunk.
        let plane = runtime(13, 256, 2, 7);
        let x = xvec(256);
        let mut y1 = vec![0.0f32; 13];
        gemv(&plane, &x, &mut y1);
        for threads in [1usize, 2, 3, 4, 13, 64] {
            let mut yt = vec![0.0f32; 13];
            gemv_mt(&plane, &x, &mut yt, threads);
            assert_eq!(
                y1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                yt.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threads={}",
                threads
            );
        }
    }

    #[test]
    fn explicit_pool_matches_global_pool() {
        let plane = runtime(17, 300, 3, 19);
        let x = xvec(300);
        let mut want = vec![0.0f32; 17];
        gemv(&plane, &x, &mut want);
        for workers in [1usize, 2, 4] {
            let pool = WorkerPool::new(workers);
            let mut y = vec![0.0f32; 17];
            gemv_on(&pool, &plane, &x, &mut y);
            assert_eq!(
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "workers={}",
                workers
            );
        }
    }

    #[test]
    fn gemm_bit_identical_to_dequant_matmul() {
        let plane = runtime(24, 300, 3, 11);
        let m = 5;
        let x = Matrix::from_vec(
            m,
            300,
            (0..m * 300).map(|i| (i as f32 * 0.11).cos()).collect(),
        );
        let mut y = Matrix::zeros(m, 24);
        gemm(&plane, &x, &mut y);
        let want = x.matmul(&plane.dequantize().transpose());
        assert_eq!(
            y.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // Multi-threaded path, including more threads than rows.
        for threads in [2usize, 3, 8] {
            let mut yt = Matrix::zeros(m, 24);
            gemm_mt(&plane, &x, &mut yt, threads);
            assert_eq!(yt.data, y.data, "threads={}", threads);
        }
        // Explicit pools (band path: batch < executors).
        for workers in [2usize, 4] {
            let pool = WorkerPool::new(workers);
            let mut yt = Matrix::zeros(m, 24);
            gemm_on(&pool, &plane, &x, &mut yt);
            assert_eq!(yt.data, y.data, "workers={}", workers);
        }
    }

    #[test]
    fn degenerate_shapes() {
        // 1×1 and 1×N planes (the smallest serving shapes).
        for (rows, cols) in [(1usize, 1usize), (1, 97), (3, 1)] {
            let plane = runtime(rows, cols, 2, 99);
            let x = xvec(cols);
            let mut y = vec![0.0f32; rows];
            gemv_mt(&plane, &x, &mut y, 4);
            let want = dequant_matvec(&plane, &x);
            for (a, b) in y.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}x{}", rows, cols);
            }
        }
    }

    #[test]
    fn block_boundary_shapes() {
        // cols exactly at, one under, and one over the gather block, at
        // widths whose codes cross byte boundaries (3- and 5-bit).
        for bits in [2u32, 4] {
            for cols in [BLOCK - 1, BLOCK, BLOCK + 1, 2 * BLOCK + 1] {
                let plane = runtime(4, cols, bits, 3);
                let x = xvec(cols);
                let mut y = vec![0.0f32; 4];
                gemv(&plane, &x, &mut y);
                let want = dequant_matvec(&plane, &x);
                for (a, b) in y.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "bits={} cols={}", bits, cols);
                }
            }
        }
    }

    #[test]
    fn band_panic_names_the_failing_row_range() {
        // Satellite regression: a panicking band worker used to surface
        // as a bare `join().expect("gemm band worker")`, poisoning the
        // whole forward anonymously. The pooled path re-raises with the
        // failing row range and the original payload text.
        let pool = WorkerPool::new(2);
        let mut slots = vec![0u8; 10];
        let err = pool
            .try_for_chunks_mut(&mut slots, 3, |i, _| {
                if i == 2 {
                    panic!("band exploded");
                }
            })
            .expect_err("injected panic must surface");
        let raised = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            panic_with_rows("fused GEMM band", "weight rows", err, 3, 10)
        }))
        .expect_err("panic_with_rows must panic");
        let msg = raised
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string>".into());
        assert!(msg.contains("weight rows 6..9"), "msg={}", msg);
        assert!(msg.contains("band exploded"), "msg={}", msg);
    }
}

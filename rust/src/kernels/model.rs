//! Native CPU transformer forward over fused quantized planes
//! (DESIGN.md §8), with a **paged KV cache** (DESIGN.md §10).
//!
//! [`NativeModel`] mirrors the Llama-mini architecture the python side
//! AOT-compiles (`python/compile/model.py`: RMSNorm → RoPE multi-head
//! attention → RMSNorm → SwiGLU, byte vocab), but every projection is a
//! fused [`gemm_on_tier`](crate::kernels::gemm_on_tier) **straight off
//! the bit-packed quantized [`RuntimePlane`]**, dispatched onto the
//! model's persistent [`WorkerPool`] on the model's resolved SIMD
//! [`Tier`] (DESIGN.md §14) — no f32 weight plane ever exists and no
//! thread is spawned at request time. The attention dot-products,
//! weighted-value accumulation, and KV dequant fill route through the
//! same tier; with [`ActQuant::Int8`] the single-token decode
//! projections take the int8-activation GEMV instead. Dense side
//! tensors (embeddings, norms, `lm_head`) stay f32; they are <2 % of
//! the weight bytes.
//!
//! The KV cache is **paged** (DESIGN.md §10): storage is a pool of
//! fixed-size token blocks, each slot walks a per-slot **block table**,
//! blocks are handed out by a free-list allocator and **refcounted** so
//! requests with identical prompt prefixes map their prefix blocks onto
//! one shared physical copy (a block-chain registry keyed by exact
//! token content — the dominant multi-user scenario: shared system
//! prompts) and skip recomputing them at prefill. Writes into a shared
//! block **copy-on-write fork** it first, so sharing can never leak one
//! sequence's state into another. Lanes never attend across each other
//! and each lane carries its own position, so a sequence's tokens are
//! bit-identical whether it runs alone, in a uniform batch, interleaved
//! with strangers, or on top of a reused prefix — at any block size.
//!
//! This is the deployment story the paper's intro argues for: the
//! serving working set is packed codes + codebooks (≈(n+1)/32 of f32 —
//! ~3 bits/weight at n=2), which makes the **KV cache** the memory
//! bottleneck at scale; paging + prefix sharing is what turns the tiny
//! weight footprint into more concurrent users. The PJRT backend
//! remains the reference executor; this one trades its compiled graphs
//! for zero Python/XLA dependence at request time.

use crate::coordinator::backend::argmax_rows;
use crate::icq::RowIndexCode;
use crate::icquant::runtime::RuntimePlane;
use crate::kernels::simd::{self, ActQuant, Tier};
use crate::kernels::{gemm_on_tier, gemv_i8_on, WorkerPool};
use crate::model::ModelConfig;
use crate::quant::rtn::fit_rtn_range;
use crate::store::StoredModel;
use crate::trace::{self, Cat};
use crate::util::tensor::Matrix;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// RoPE base frequency (python `ModelConfig.rope_theta`).
const ROPE_THETA: f32 = 10000.0;
/// RMSNorm epsilon (python `ModelConfig.norm_eps`).
const NORM_EPS: f32 = 1e-5;

/// Tokens per KV block when the caller does not pick one. Small enough
/// that short requests waste little tail capacity, large enough that
/// block-table walks stay cheap.
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Sentinel "no previous block" parent id for the first block of a
/// prefix chain.
const NO_PARENT: usize = usize::MAX;

/// Sentinel "no f32 region" id: the block is either free or holds a
/// quantized payload instead of float storage.
const NO_REGION: usize = usize::MAX;

/// Gap width of the outlier index stream in quantized KV planes. The
/// positions span one whole plane (`H·hd·block_tokens` symbols), so an
/// 8-bit gap keeps escape symbols rare even for sparse outliers.
const KV_GAP_BITS: u32 = 8;

/// Layout knobs for the paged KV cache (DESIGN.md §10).
#[derive(Clone, Copy, Debug)]
pub struct KvLayout {
    /// Tokens per physical block.
    pub block_tokens: usize,
    /// Physical blocks in the pool. `None` ⇒ fully provisioned
    /// (`slots × ⌈max_seq / block_tokens⌉`), where allocation can never
    /// fail; smaller values overcommit — prefix sharing stretches the
    /// pool, admission is gated on free blocks, and exhaustion is a
    /// clean per-request error.
    pub total_blocks: Option<usize>,
    /// Shared-prefix reuse: block-chain registry + copy-on-write.
    pub prefix_sharing: bool,
    /// ICQ-quantize full KV blocks to this many bits per value
    /// (DESIGN.md §12). `None` keeps every block f32 — the bit-exact
    /// pre-quantization behaviour. `Some(b)` (2..=8; the CLI exposes 4
    /// and 8) quantizes each block per-head-channel the moment it fills,
    /// keeping only the hot tail block at f32; decoding is lossy but
    /// deterministic, so streams stay schedule-invariant at a fixed
    /// layout.
    pub kv_bits: Option<u32>,
}

impl Default for KvLayout {
    fn default() -> Self {
        KvLayout {
            block_tokens: DEFAULT_BLOCK_TOKENS,
            total_blocks: None,
            prefix_sharing: true,
            kv_bits: None,
        }
    }
}

impl KvLayout {
    /// The contiguous-equivalent layout: one `max_seq`-token block per
    /// slot, no sharing — the pre-paging behaviour, kept as the A/B
    /// baseline (`benches/paging.rs`) and differential-test reference.
    pub fn contiguous(cfg: &ModelConfig) -> KvLayout {
        KvLayout {
            block_tokens: cfg.max_seq,
            total_blocks: None,
            prefix_sharing: false,
            kv_bits: None,
        }
    }
}

/// Point-in-time paged-cache counters (cumulative counters never reset
/// for the life of the cache; gauges reflect the current pool state).
/// Surfaced through `Backend::kv_cache_stats` into serving
/// [`Metrics`](crate::coordinator::metrics::Metrics).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvCacheStats {
    /// Tokens per block.
    pub block_tokens: usize,
    /// Physical blocks in the pool.
    pub total_blocks: usize,
    /// Blocks currently allocated (tables + registry).
    pub blocks_in_use: usize,
    /// Blocks currently registered for prefix sharing.
    pub registered_blocks: usize,
    /// Cumulative: prompt blocks served from the registry instead of
    /// being recomputed.
    pub prefix_hit_blocks: u64,
    /// Cumulative: prompt tokens whose prefill compute was skipped.
    pub prefix_hit_tokens: u64,
    /// Cumulative: registered blocks recycled under pool pressure.
    pub blocks_evicted: u64,
    /// Cumulative: copy-on-write forks (writes into shared blocks).
    pub cow_forks: u64,
    /// KV quantization width (`None` ⇒ every block f32).
    pub kv_bits: Option<u32>,
    /// Blocks currently in the `Icq` state (gauge).
    pub quantized_blocks: usize,
    /// Cumulative: block quantization events (a re-quantized
    /// dequantize-then-write block counts again).
    pub blocks_quantized: u64,
    /// Cumulative: attention reads of a quantized block served from an
    /// already-staged dequant scratch entry (shared-prefix lanes in the
    /// same forward hitting one staged copy).
    pub dequant_scratch_hits: u64,
    /// Logical bytes of all used blocks: quantized payload bytes plus
    /// full f32 cost for `F32` blocks (gauge). `bytes/token` is this
    /// over [`resident_tokens`](KvCacheStats::resident_tokens).
    pub kv_resident_bytes: usize,
    /// Tokens currently resident across slot lanes (Σ per-slot pos).
    pub resident_tokens: usize,
}

/// A registered (shareable) block: its chain key, for removal from the
/// index on eviction, and an LRU tick.
struct RegEntry {
    key: PrefixKey,
    last_use: u64,
}

/// Identity of one prefix-chain block: the physical id of its parent
/// block (or [`NO_PARENT`]) plus the exact `block_tokens` token ids it
/// covers. Exact-content keys — no hashing of the chain, so a lookup
/// hit *proves* the cached KV was computed from this prefix.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct PrefixKey {
    parent: usize,
    tokens: Vec<i32>,
}

/// One ICQ-quantized K or V plane of one physical block in one layer
/// (DESIGN.md §12). Channel = one `(head, dim)` coordinate; its
/// `block_tokens` values along the token axis are quantized together:
/// an optional single outlier (taken only when removing it at least
/// halves the channel range — the paper's range-halving trick) is kept
/// exact and gap-coded into one plane-wide [`RowIndexCode`] stream, and
/// the inliers round to a per-channel uniform grid
/// ([`fit_rtn_range`]).
#[derive(Clone)]
struct QuantPlane {
    /// Packed `bits`-wide codes, channel-major: channel `ch` owns codes
    /// `ch·block_tokens .. (ch+1)·block_tokens`.
    codes: Vec<u8>,
    /// Per-channel inlier grid `[lo, hi]` (2 f32 per channel).
    ranges: Vec<f32>,
    /// Outlier positions over the flattened channel-major stream.
    outliers: RowIndexCode,
    /// Outlier values (exact f32), in position order.
    outlier_vals: Vec<f32>,
}

impl QuantPlane {
    /// Payload bytes of this plane: packed codes + grid endpoints +
    /// exact outliers + the gap stream.
    fn payload_bytes(&self) -> usize {
        self.codes.len()
            + self.ranges.len() * 4
            + self.outlier_vals.len() * 4
            + self.outliers.bytes().len()
    }
}

/// The quantized payload of one physical block: per layer, one K and
/// one V [`QuantPlane`]. A block is either f32 (owns an arena region)
/// or `Icq` (owns one of these) — never both.
#[derive(Clone)]
struct QuantBlock {
    bits: u32,
    k: Vec<QuantPlane>,
    v: Vec<QuantPlane>,
}

impl QuantBlock {
    fn payload_bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(QuantPlane::payload_bytes).sum()
    }
}

/// Write code `val` (`bits` wide, LSB-first) at slot `i` of a packed
/// code buffer.
#[inline]
fn pack_code(buf: &mut [u8], i: usize, bits: u32, val: u32) {
    let mut bit = i * bits as usize;
    let mut left = bits;
    let mut v = val;
    while left > 0 {
        let byte = bit / 8;
        let off = (bit % 8) as u32;
        let take = left.min(8 - off);
        let mask = ((1u32 << take) - 1) as u8;
        buf[byte] |= ((v as u8) & mask) << off;
        v >>= take;
        bit += take as usize;
        left -= take;
    }
}

/// Read the `bits`-wide code at slot `i` of a packed code buffer.
#[inline]
fn unpack_code(buf: &[u8], i: usize, bits: u32) -> u32 {
    let mut bit = i * bits as usize;
    let mut left = bits;
    let mut out = 0u32;
    let mut shift = 0u32;
    while left > 0 {
        let byte = bit / 8;
        let off = (bit % 8) as u32;
        let take = left.min(8 - off);
        let mask = (1u32 << take) - 1;
        out |= (((buf[byte] as u32) >> off) & mask) << shift;
        shift += take;
        bit += take as usize;
        left -= take;
    }
    out
}

/// Quantize one `[H, block_tokens, hd]` f32 plane per head-channel.
/// Deterministic in the input values alone, so a block's payload is
/// identical wherever (and whenever) it was quantized — the property
/// the prefix registry and the fuzz invariance contract lean on.
fn quantize_plane(src: &[f32], n_heads: usize, bt: usize, hd: usize, bits: u32) -> QuantPlane {
    let n_ch = n_heads * hd;
    let mut codes = vec![0u8; (n_ch * bt * bits as usize).div_ceil(8)];
    let mut ranges = Vec::with_capacity(n_ch * 2);
    let mut out_pos = Vec::new();
    let mut out_vals = Vec::new();
    let mut vals = vec![0.0f32; bt];
    for ch in 0..n_ch {
        let (h, d) = (ch / hd, ch % hd);
        for (t, v) in vals.iter_mut().enumerate() {
            *v = src[h * bt * hd + t * hd + d];
        }
        let (lo, hi) = crate::quant::min_max(&vals);
        // Top-magnitude candidate outlier (ties break to the first
        // token, matching `top_k_by_magnitude`'s determinism rule).
        let mut star = 0usize;
        for (t, &v) in vals.iter().enumerate() {
            if v.abs() > vals[star].abs() {
                star = t;
            }
        }
        let (mut lo2, mut hi2) = (f32::INFINITY, f32::NEG_INFINITY);
        for (t, &v) in vals.iter().enumerate() {
            if t != star {
                lo2 = lo2.min(v);
                hi2 = hi2.max(v);
            }
        }
        // ICQ's range-halving rule: pay the index entry only when the
        // remaining inliers span at most half the full range (≥1 bit of
        // grid resolution bought back).
        let take = bt >= 2 && hi > lo && hi2 >= lo2 && (hi2 - lo2) <= 0.5 * (hi - lo);
        let (glo, ghi) = if take { (lo2, hi2) } else { (lo, hi) };
        let cb = fit_rtn_range(glo, ghi, bits);
        ranges.push(glo);
        ranges.push(ghi);
        if take {
            out_pos.push(ch * bt + star);
            out_vals.push(vals[star]);
        }
        for (t, &v) in vals.iter().enumerate() {
            let code = if take && t == star { 0 } else { cb.encode(v) as u32 };
            pack_code(&mut codes, ch * bt + t, bits, code);
        }
    }
    QuantPlane {
        codes,
        ranges,
        outliers: RowIndexCode::encode(&out_pos, KV_GAP_BITS),
        outlier_vals: out_vals,
    }
}

/// Decode one quantized plane back into `[H, block_tokens, hd]` f32.
/// The grid mirrors [`fit_rtn_range`] (`level(c) = lo + c·(hi−lo)/(2ᵇ−1)`),
/// then exact outlier values overwrite their positions.
///
/// The affine fill is staged through [`simd::affine_u8`] in chunks:
/// codes decode into a stack buffer, the tier computes `lo + step·code`
/// (the scalar tier reproduces the historical rounding exactly), and a
/// scalar scatter places the strided `[t, d]` layout. KV code widths
/// are ≤ 8 bits, so every code fits the u8 staging buffer.
fn dequantize_plane(
    qp: &QuantPlane,
    n_heads: usize,
    bt: usize,
    hd: usize,
    bits: u32,
    tier: Tier,
    dst: &mut [f32],
) {
    let n_ch = n_heads * hd;
    let levels = (1usize << bits) as f32;
    let mut cbuf = [0u8; 128];
    let mut lbuf = [0.0f32; 128];
    for ch in 0..n_ch {
        let (h, d) = (ch / hd, ch % hd);
        let (lo, hi) = (qp.ranges[2 * ch], qp.ranges[2 * ch + 1]);
        let step = if hi > lo { (hi - lo) / (levels - 1.0) } else { 0.0 };
        let mut t0 = 0usize;
        while t0 < bt {
            let n = (bt - t0).min(128);
            for (j, c) in cbuf[..n].iter_mut().enumerate() {
                *c = unpack_code(&qp.codes, ch * bt + t0 + j, bits) as u8;
            }
            simd::affine_u8(tier, &cbuf[..n], lo, step, &mut lbuf[..n]);
            for (j, &lv) in lbuf[..n].iter().enumerate() {
                dst[h * bt * hd + (t0 + j) * hd + d] = lv;
            }
            t0 += n;
        }
    }
    for (i, p) in qp.outliers.positions().enumerate() {
        let (ch, t) = (p / bt, p % bt);
        dst[(ch / hd) * bt * hd + t * hd + (ch % hd)] = qp.outlier_vals[i];
    }
}

/// Paged, slot-addressed KV cache (DESIGN.md §10): per layer, a pool of
/// `[total_blocks, H, block_tokens, hd]` flat f32 blocks — plain host
/// memory, unlike the PJRT path's device literals.
///
/// Each slot holds one independent sequence: its per-slot
/// [`pos`](KvCache::pos) and a block table mapping logical token blocks
/// to physical pool blocks. Blocks are refcounted; prompt-prefix blocks
/// can be shared between slots (and outlive their slot in the prefix
/// registry), and any write into a shared block copy-on-write forks it
/// first. Retiring a sequence is [`free_slot`](KvCache::free_slot):
/// refcounts drop, exclusive blocks return to the free list, and the
/// lane's table empties — no zeroing, the position gate makes stale
/// data unreachable.
pub struct KvCache {
    slots: usize,
    max_seq: usize,
    n_heads: usize,
    head_dim: usize,
    block_tokens: usize,
    total_blocks: usize,
    sharing: bool,
    /// Per-slot next-write position (0 = free/fresh).
    pos: Vec<usize>,
    /// Per-slot block table: logical block index → physical block id.
    tables: Vec<Vec<usize>>,
    /// Per-block reference count (slot tables + prefix registry).
    refcount: Vec<u32>,
    /// Free-list allocator (stack of unreferenced block ids).
    free: Vec<usize>,
    /// Per-slot blocks reserved for future decode tokens
    /// ([`KvCache::reserve`]) — backed by free-list blocks, so a
    /// granted reservation can never fail to allocate.
    reserved: Vec<usize>,
    reserved_total: usize,
    /// Prefix-chain registry: block key → physical block.
    prefix_index: HashMap<PrefixKey, usize>,
    /// Registry bookkeeping per physical block.
    registered: Vec<Option<RegEntry>>,
    /// Incremental mirrors of registry state, so the per-step stats
    /// and admission headroom are O(1) instead of scanning the pool
    /// (`debug_validate` recomputes and checks both).
    registered_count: usize,
    /// Registered blocks with refcount 1 (held only by the index) —
    /// reclaimable on demand.
    evictable_count: usize,
    tick: u64,
    prefix_hit_blocks: u64,
    prefix_hit_tokens: u64,
    blocks_evicted: u64,
    cow_forks: u64,
    /// KV quantization width (`None` ⇒ pure f32, the bit-exact path).
    kv_bits: Option<u32>,
    /// Per-block quantized payload: `Some` ⇔ the block is in the `Icq`
    /// state (and then `region[b] == NO_REGION`).
    quant: Vec<Option<Box<QuantBlock>>>,
    /// Per-block f32 arena region id ([`NO_REGION`] ⇔ quantized or
    /// free). With quantization off this is the identity map and the
    /// arena is fully provisioned up front — the pre-§12 layout.
    region: Vec<usize>,
    /// Recycled arena regions (a block releases its region when it
    /// quantizes or frees).
    region_free: Vec<usize>,
    /// Arena regions allocated so far (high-water; never shrinks).
    regions: usize,
    /// Dequant scratch: staged f32 copies of quantized blocks for the
    /// current layer of the current forward. `scratch_tag[phys] ==
    /// scratch_gen` ⇔ the block is staged at arena slot
    /// `scratch_slot_of[phys]`. Sized to the forward's working set and
    /// reused across calls, so steady-state decode stays allocation-free.
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
    scratch_slot_of: Vec<usize>,
    scratch_tag: Vec<u64>,
    scratch_gen: u64,
    scratch_len: usize,
    /// Gauge mirrors of the quantized-block population (stats are O(1)
    /// on the decode loop; `debug_validate` recomputes both).
    quantized_count: usize,
    quant_payload_bytes: usize,
    blocks_quantized: u64,
    dequant_scratch_hits: u64,
    /// SIMD tier for the dequant affine fill (DESIGN.md §14), resolved
    /// once at construction from the environment.
    tier: Tier,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl KvCache {
    /// An empty cache with `slots` independent lanes and the default
    /// paged layout (fully provisioned, sharing on).
    pub fn new(cfg: &ModelConfig, slots: usize) -> KvCache {
        Self::with_layout(cfg, slots, KvLayout::default())
    }

    /// An empty cache with an explicit paged layout. `block_tokens` is
    /// clamped to `max_seq`: a block can never hold more positions than
    /// a sequence can reach, and an oversized value (e.g. a
    /// `--block-size` typo) would otherwise silently multiply the KV
    /// allocation by `block_tokens / max_seq`.
    pub fn with_layout(cfg: &ModelConfig, slots: usize, layout: KvLayout) -> KvCache {
        let bt = layout.block_tokens.min(cfg.max_seq.max(1));
        assert!(bt >= 1, "block_tokens must be >= 1");
        if let Some(b) = layout.kv_bits {
            assert!((2..=8).contains(&b), "kv_bits must be in 2..=8, got {}", b);
        }
        let per_slot = cfg.max_seq.div_ceil(bt);
        let total = layout.total_blocks.unwrap_or(slots.max(1) * per_slot).max(1);
        // Quantization off: the f32 arena is fully provisioned and
        // identity-mapped up front (the exact pre-§12 footprint). On:
        // regions are handed out lazily and recycled as blocks
        // quantize, so the arena only grows to the hot-tail watermark.
        let init_regions = if layout.kv_bits.is_none() { total } else { 0 };
        let per_layer = init_regions * cfg.n_heads * bt * cfg.head_dim();
        KvCache {
            slots,
            max_seq: cfg.max_seq,
            n_heads: cfg.n_heads,
            head_dim: cfg.head_dim(),
            block_tokens: bt,
            total_blocks: total,
            sharing: layout.prefix_sharing,
            pos: vec![0; slots],
            tables: vec![Vec::new(); slots],
            refcount: vec![0; total],
            // Reverse so allocation proceeds in ascending block order.
            free: (0..total).rev().collect(),
            reserved: vec![0; slots],
            reserved_total: 0,
            prefix_index: HashMap::new(),
            registered: (0..total).map(|_| None).collect(),
            registered_count: 0,
            evictable_count: 0,
            tick: 0,
            prefix_hit_blocks: 0,
            prefix_hit_tokens: 0,
            blocks_evicted: 0,
            cow_forks: 0,
            kv_bits: layout.kv_bits,
            quant: (0..total).map(|_| None).collect(),
            region: if layout.kv_bits.is_none() {
                (0..total).collect()
            } else {
                vec![NO_REGION; total]
            },
            region_free: Vec::new(),
            regions: init_regions,
            scratch_k: Vec::new(),
            scratch_v: Vec::new(),
            scratch_slot_of: vec![usize::MAX; total],
            scratch_tag: vec![0; total],
            scratch_gen: 0,
            scratch_len: 0,
            quantized_count: 0,
            quant_payload_bytes: 0,
            blocks_quantized: 0,
            dequant_scratch_hits: 0,
            tier: simd::from_env(),
            k: (0..cfg.n_layers).map(|_| vec![0.0; per_layer]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; per_layer]).collect(),
        }
    }

    /// Override the SIMD tier used by the dequant fill (the constructor
    /// resolves `ICQ_SIMD`; servers apply an explicit `--simd` choice
    /// here).
    pub fn set_simd(&mut self, tier: Tier) {
        self.tier = tier;
    }

    /// Number of KV lanes.
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Cached positions in `slot` (the next token writes at this index).
    pub fn pos(&self, slot: usize) -> usize {
        self.pos[slot]
    }

    /// Tokens per physical block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Physical blocks in the pool.
    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Blocks currently allocated (slot tables + prefix registry).
    pub fn blocks_in_use(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Whether shared-prefix reuse is enabled.
    pub fn prefix_sharing(&self) -> bool {
        self.sharing
    }

    /// Blocks an admission can draw on right now: unreserved free-list
    /// blocks plus registry blocks held by nothing else (evictable on
    /// demand). The scheduler gates admission rounds on this.
    pub fn admission_free_blocks(&self) -> usize {
        self.free.len().saturating_sub(self.reserved_total) + self.evictable_count
    }

    /// Admission headroom a prefill of `prompt` would consume, in the
    /// units of [`admission_free_blocks`](KvCache::admission_free_blocks):
    /// fresh blocks for the part of the prompt the prefix registry
    /// cannot serve, the copy-on-write fork block when the whole
    /// prompt is registered, the first decode block when the prompt
    /// fills its last block exactly (otherwise tail slack covers the
    /// first decode tokens) — **plus** any matched registry blocks
    /// that are currently evictable: mapping them pins them (refcount
    /// 2), removing them from the headroom other round members were
    /// counting on. The admission gate uses this so shared-prefix
    /// requests are charged what they actually consume — a round's
    /// lookups all run against the same pre-round registry this
    /// consults, so the estimate matches the prefill.
    pub fn admission_block_need(&self, prompt: &[i32]) -> usize {
        let bt = self.block_tokens;
        let total = prompt.len().div_ceil(bt).max(1);
        let mut matched = 0usize;
        let mut pins_evictable = 0usize;
        if self.sharing {
            // One reused key buffer: this estimate runs per queued
            // candidate per scheduler iteration while a round waits on
            // blocks, so a per-chunk Vec would be decode-loop garbage.
            let mut key = PrefixKey { parent: NO_PARENT, tokens: Vec::with_capacity(bt) };
            for chunk in prompt.chunks_exact(bt) {
                key.tokens.clear();
                key.tokens.extend_from_slice(chunk);
                match self.prefix_index.get(&key) {
                    Some(&b) => {
                        if self.refcount[b] == 1 {
                            pins_evictable += 1;
                        }
                        key.parent = b;
                        matched += 1;
                    }
                    None => break,
                }
            }
        }
        let fresh = total - matched;
        let alloc = if fresh == 0 {
            // Fully registered prompt: the final-token recompute forks
            // the shared tail, and the fork leaves no slack.
            2
        } else {
            fresh + usize::from(prompt.len() % bt == 0)
        };
        alloc + pins_evictable
    }

    /// Point-in-time counters (see [`KvCacheStats`]). O(1) — called on
    /// the serving loop every decode step.
    pub fn stats(&self) -> KvCacheStats {
        let f32_block = 2 * self.k.len() * self.stride() * 4;
        KvCacheStats {
            block_tokens: self.block_tokens,
            total_blocks: self.total_blocks,
            blocks_in_use: self.blocks_in_use(),
            registered_blocks: self.registered_count,
            prefix_hit_blocks: self.prefix_hit_blocks,
            prefix_hit_tokens: self.prefix_hit_tokens,
            blocks_evicted: self.blocks_evicted,
            cow_forks: self.cow_forks,
            kv_bits: self.kv_bits,
            quantized_blocks: self.quantized_count,
            blocks_quantized: self.blocks_quantized,
            dequant_scratch_hits: self.dequant_scratch_hits,
            kv_resident_bytes: self.quant_payload_bytes
                + f32_block * (self.blocks_in_use() - self.quantized_count),
            resident_tokens: self.pos.iter().sum(),
        }
    }

    /// KV quantization width (`None` ⇒ pure f32 blocks).
    pub fn kv_bits(&self) -> Option<u32> {
        self.kv_bits
    }

    /// Per-layer f32 values of one block (`H · block_tokens · hd`).
    #[inline]
    fn stride(&self) -> usize {
        self.n_heads * self.block_tokens * self.head_dim
    }

    /// Logical bytes of the used blocks: quantized payloads plus full
    /// f32 cost for `F32` blocks — what a fully packed layout holds
    /// resident ([`memory_bytes`](KvCache::memory_bytes) reports the
    /// physical arena, which stops growing at the hot watermark but
    /// never shrinks). O(total_blocks); `stats()` carries the O(1)
    /// mirror.
    pub fn resident_kv_bytes(&self) -> usize {
        let f32_block = 2 * self.k.len() * self.stride() * 4;
        self.refcount
            .iter()
            .enumerate()
            .filter(|&(_, &rc)| rc > 0)
            .map(|(b, _)| match &self.quant[b] {
                Some(q) => q.payload_bytes(),
                None => f32_block,
            })
            .sum()
    }

    /// Tokens currently resident across slot lanes.
    pub fn resident_tokens(&self) -> usize {
        self.pos.iter().sum()
    }

    /// Release `slot` for reuse by a new sequence: refcounts of its
    /// blocks drop (exclusive blocks return to the free list — blocks
    /// still held by the prefix registry or a sharing slot survive),
    /// its reservation returns to the pool, and its position resets.
    /// This is also the disconnect-cancel path (DESIGN.md §15): it must
    /// fully release a partially-decoded lane so an abandoned stream
    /// frees its blocks before the sequence would have finished.
    pub fn free_slot(&mut self, slot: usize) {
        for b in std::mem::take(&mut self.tables[slot]) {
            self.release(b);
        }
        self.pos[slot] = 0;
        self.reserved_total -= self.reserved[slot];
        self.reserved[slot] = 0;
    }

    fn release(&mut self, b: usize) {
        self.refcount[b] -= 1;
        if self.refcount[b] == 0 {
            debug_assert!(self.registered[b].is_none());
            self.recycle_storage(b);
            self.free.push(b);
        } else if self.refcount[b] == 1 && self.registered[b].is_some() {
            // Now held only by the index — reclaimable on demand.
            self.evictable_count += 1;
        }
    }

    /// Drop block `b`'s storage when it leaves use: a quantized payload
    /// is freed, an f32 region returns to the region free list. With
    /// quantization off regions stay identity-mapped forever (zero
    /// behavioural delta from the pre-§12 cache).
    fn recycle_storage(&mut self, b: usize) {
        if self.kv_bits.is_none() {
            debug_assert!(self.quant[b].is_none());
            return;
        }
        if let Some(q) = self.quant[b].take() {
            self.quantized_count -= 1;
            self.quant_payload_bytes -= q.payload_bytes();
        }
        if self.region[b] != NO_REGION {
            self.region_free.push(self.region[b]);
            self.region[b] = NO_REGION;
        }
    }

    /// Hand out an f32 arena region, growing the per-layer arenas by
    /// one block stride at the high-water mark.
    fn alloc_region(&mut self) -> usize {
        if let Some(r) = self.region_free.pop() {
            return r;
        }
        let r = self.regions;
        self.regions += 1;
        let stride = self.stride();
        for l in &mut self.k {
            l.resize((r + 1) * stride, 0.0);
        }
        for l in &mut self.v {
            l.resize((r + 1) * stride, 0.0);
        }
        r
    }

    /// Give block `b` writable f32 storage if it has none.
    fn ensure_region(&mut self, b: usize) {
        debug_assert!(self.quant[b].is_none());
        if self.region[b] == NO_REGION {
            let r = self.alloc_region();
            self.region[b] = r;
        }
    }

    /// Take one more reference to `b`, maintaining the evictable count
    /// (a registry-only block stops being reclaimable once a slot
    /// shares it).
    fn retain(&mut self, b: usize) {
        if self.refcount[b] == 1 && self.registered[b].is_some() {
            self.evictable_count -= 1;
        }
        self.refcount[b] += 1;
    }

    /// Ensure `slot` can write up to `want` more tokens from its
    /// current position, returning how many are now **guaranteed**
    /// (slack in its allocated blocks plus its reserved blocks). Total
    /// semantics: repeat calls extend an existing reservation instead
    /// of stacking on top of it, so the scheduler can reserve in
    /// phases (one block for every round member first, then the full
    /// targets). When unreserved free blocks run short, registry-only
    /// blocks are evicted into the free list to back the reservation —
    /// the same headroom [`admission_free_blocks`] advertises. The
    /// scheduler clamps each request's token target to the return
    /// value, so a decode step can never fail on pool exhaustion for a
    /// clamped sequence; [`free_slot`](KvCache::free_slot) releases
    /// the reservation.
    ///
    /// [`admission_free_blocks`]: KvCache::admission_free_blocks
    pub fn reserve(&mut self, slot: usize, want: usize) -> usize {
        if want == 0 {
            return 0;
        }
        let bt = self.block_tokens;
        let pos = self.pos[slot];
        let slack = self.tables[slot].len() * bt - pos;
        // A shared tail block must be forked before the slot can write
        // into it — that fork costs one extra block.
        let fork_need = if slack > 0 && self.refcount[self.tables[slot][pos / bt]] > 1 {
            1
        } else {
            0
        };
        let already = self.reserved[slot];
        let total_needed = fork_need + want.saturating_sub(slack).div_ceil(bt);
        let extra = total_needed.saturating_sub(already);
        let mut avail = self.free.len().saturating_sub(self.reserved_total);
        while avail < extra {
            if !self.evict_lru_to_free() {
                break;
            }
            avail = self.free.len().saturating_sub(self.reserved_total);
        }
        let grant = extra.min(avail);
        self.reserved[slot] += grant;
        self.reserved_total += grant;
        let total = already + grant;
        let guaranteed = if total >= fork_need {
            slack + (total - fork_need) * bt
        } else {
            0
        };
        let granted = guaranteed.min(want);
        trace::instant(Cat::Kv, "reserve", slot as u64, want as i64, granted as i64);
        granted
    }

    /// Evict the LRU registry-only block into the free list (backing a
    /// reservation rather than an immediate allocation).
    fn evict_lru_to_free(&mut self) -> bool {
        match self.evict_lru() {
            Some(b) => {
                self.free.push(b);
                true
            }
            None => false,
        }
    }

    /// Grab a block for `slot`: its own reservation first, then
    /// unreserved free blocks, then LRU eviction of registry-only
    /// blocks. Errors only when the pool is truly exhausted.
    fn alloc_block(&mut self, slot: usize) -> Result<usize> {
        let from_reservation = self.reserved[slot] > 0;
        let b = if from_reservation {
            // PANIC: invariant — reserved_total <= free.len(), so this
            // cannot miss (reservations are granted against free blocks
            // and unreserved allocation never dips into them).
            self.free.pop().expect("reserved block missing from free list")
        } else if self.free.len() > self.reserved_total {
            // PANIC: the guard one line up proved the free list holds
            // more than the reserved floor, so it is non-empty.
            self.free.pop().unwrap()
        } else if let Some(b) = self.evict_lru() {
            b
        } else {
            bail!(
                "KV block pool exhausted ({} blocks of {} tokens, {} reserved)",
                self.total_blocks,
                self.block_tokens,
                self.reserved_total
            );
        };
        if from_reservation {
            self.reserved[slot] -= 1;
            self.reserved_total -= 1;
        }
        debug_assert_eq!(self.refcount[b], 0);
        self.refcount[b] = 1;
        Ok(b)
    }

    /// Recycle the least-recently-used registry-only block (refcount 1
    /// — held by nothing but the index). Its registered descendants are
    /// de-registered too: their chain keys name this block as parent,
    /// and a recycled parent id must never let a stale chain match.
    fn evict_lru(&mut self) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        for (b, e) in self.registered.iter().enumerate() {
            if let Some(entry) = e {
                if self.refcount[b] == 1 && best.map_or(true, |(t, _)| entry.last_use < t) {
                    best = Some((entry.last_use, b));
                }
            }
        }
        let (_, b) = best?;
        // PANIC: `best` was selected from registered entries just above.
        let entry = self.registered[b].take().unwrap();
        self.prefix_index.remove(&entry.key);
        self.registered_count -= 1;
        self.evictable_count -= 1;
        self.refcount[b] = 0;
        self.recycle_storage(b);
        self.blocks_evicted += 1;
        trace::instant(Cat::Kv, "evict", b as u64, self.blocks_evicted as i64, 0);
        self.deregister_descendants(b);
        Some(b)
    }

    /// Remove every registered chain descendant of `parent` from the
    /// index (recursively). Blocks still referenced by slots stay
    /// allocated — they just stop being shareable; orphans whose only
    /// holder was the index return to the free list.
    fn deregister_descendants(&mut self, parent: usize) {
        let children: Vec<usize> = self
            .registered
            .iter()
            .enumerate()
            .filter(|(_, e)| e.as_ref().is_some_and(|e| e.key.parent == parent))
            .map(|(b, _)| b)
            .collect();
        for c in children {
            // PANIC: `children` was filtered to registered entries.
            let entry = self.registered[c].take().unwrap();
            self.prefix_index.remove(&entry.key);
            self.registered_count -= 1;
            if self.refcount[c] == 1 {
                self.evictable_count -= 1;
            }
            self.refcount[c] -= 1;
            if self.refcount[c] == 0 {
                // Only an orphan actually gets recycled; a block still
                // referenced by slot tables merely stops being
                // shareable and must not inflate the eviction counter.
                self.recycle_storage(c);
                self.free.push(c);
                self.blocks_evicted += 1;
            }
            self.deregister_descendants(c);
        }
    }

    /// Map the longest registered chain of `prompt`'s full blocks into
    /// `slot`'s (empty) table, sharing the physical blocks, and return
    /// the number of prompt tokens whose prefill compute is skipped.
    /// At least the final prompt token is always recomputed (its
    /// last-position logits seed generation); when the whole prompt is
    /// cached that recompute lands inside the shared tail block and the
    /// write copy-on-write forks it.
    fn map_shared_prefix(&mut self, slot: usize, prompt: &[i32]) -> usize {
        debug_assert!(self.tables[slot].is_empty() && self.pos[slot] == 0);
        if !self.sharing || prompt.len() < 2 {
            return 0;
        }
        let bt = self.block_tokens;
        self.tick += 1;
        let mut matched = 0usize;
        let mut key = PrefixKey { parent: NO_PARENT, tokens: Vec::with_capacity(bt) };
        for chunk in prompt.chunks_exact(bt) {
            key.tokens.clear();
            key.tokens.extend_from_slice(chunk);
            match self.prefix_index.get(&key) {
                Some(&b) => {
                    self.tables[slot].push(b);
                    self.retain(b);
                    if let Some(e) = self.registered[b].as_mut() {
                        e.last_use = self.tick;
                    }
                    key.parent = b;
                    matched += 1;
                }
                None => break,
            }
        }
        // Quantized blocks are immutable (DESIGN.md §12): a fully
        // registered prompt would rewrite its final token inside the
        // shared tail block, so under quantization the tail match is
        // dropped and that block recomputed in f32 — reuse stays
        // block-aligned and no write ever lands in an `Icq` block.
        if self.kv_bits.is_some() && matched > 0 && matched * bt >= prompt.len() {
            // PANIC: `matched > 0` blocks were just mapped into the table.
            let b = self.tables[slot].pop().unwrap();
            self.release(b);
            matched -= 1;
        }
        let reuse = (matched * bt).min(prompt.len() - 1);
        self.pos[slot] = reuse;
        self.prefix_hit_blocks += matched as u64;
        self.prefix_hit_tokens += reuse as u64;
        if matched > 0 {
            trace::instant(Cat::Kv, "prefix_hit", slot as u64, matched as i64, reuse as i64);
        }
        reuse
    }

    /// Register `slot`'s full prompt blocks in the prefix index so
    /// later identical prompts reuse them. Chains continue through the
    /// canonical (first-registered) physical block when a key already
    /// exists — contents are bit-identical by determinism either way.
    fn register_prefix(&mut self, slot: usize, prompt: &[i32]) {
        if !self.sharing {
            return;
        }
        let bt = self.block_tokens;
        self.tick += 1;
        let mut parent = NO_PARENT;
        for (i, chunk) in prompt.chunks_exact(bt).enumerate() {
            let key = PrefixKey { parent, tokens: chunk.to_vec() };
            if let Some(&b) = self.prefix_index.get(&key) {
                if let Some(e) = self.registered[b].as_mut() {
                    e.last_use = self.tick;
                }
                parent = b;
            } else {
                let phys = self.tables[slot][i];
                debug_assert!(self.registered[phys].is_none());
                self.prefix_index.insert(key.clone(), phys);
                // The slot already holds phys (refcount >= 1), so the
                // block is registered but not evictable.
                self.refcount[phys] += 1;
                self.registered[phys] = Some(RegEntry { key, last_use: self.tick });
                self.registered_count += 1;
                parent = phys;
            }
        }
    }

    /// Make positions `pos .. pos + seq` of `slot` writable: allocate
    /// blocks the table does not cover yet and **copy-on-write fork**
    /// any allocated block in the write range that other holders share.
    /// Forking copies the block across every layer before any layer
    /// writes, so the per-layer stores in the forward stay oblivious.
    fn prepare_append(&mut self, slot: usize, seq: usize) -> Result<()> {
        debug_assert!(seq > 0);
        let pos = self.pos[slot];
        ensure!(pos + seq <= self.max_seq, "KV slot {} overflow", slot);
        let bt = self.block_tokens;
        let first = pos / bt;
        let last = (pos + seq - 1) / bt;
        for b in first..=last {
            if b < self.tables[slot].len() {
                if self.refcount[self.tables[slot][b]] > 1 {
                    self.fork(slot, b).with_context(|| {
                        format!("copy-on-write fork of slot {} block {}", slot, b)
                    })?;
                }
                // A quantized block in the write range must come back
                // to f32 before the layer stores touch it. The aligned
                // shared-prefix rule keeps writes out of `Icq` blocks
                // on every production path, so this is a safety net for
                // exotic callers (and the debug fork hook).
                let phys = self.tables[slot][b];
                if self.quant[phys].is_some() {
                    self.dequantize_block(phys);
                }
            } else {
                let nb = self
                    .alloc_block(slot)
                    .with_context(|| format!("allocating KV block for slot {}", slot))?;
                self.ensure_region(nb);
                self.tables[slot].push(nb);
            }
        }
        Ok(())
    }

    /// Decode block `phys` back into freshly allocated f32 storage and
    /// drop its payload (state `Icq` → `F32`). The block re-quantizes
    /// at the next forward epilogue once it is complete again.
    fn dequantize_block(&mut self, phys: usize) {
        // PANIC: callers only pass blocks they observed in `Icq` state;
        // dequantizing an f32 block is a cache-state bug worth a crash.
        let q = self.quant[phys].take().expect("dequantize of an f32 block");
        self.quantized_count -= 1;
        self.quant_payload_bytes -= q.payload_bytes();
        let r = self.alloc_region();
        self.region[phys] = r;
        let stride = self.stride();
        let (heads, bt, hd) = (self.n_heads, self.block_tokens, self.head_dim);
        for layer in 0..self.k.len() {
            let dk = &mut self.k[layer][r * stride..][..stride];
            dequantize_plane(&q.k[layer], heads, bt, hd, q.bits, self.tier, dk);
            let dv = &mut self.v[layer][r * stride..][..stride];
            dequantize_plane(&q.v[layer], heads, bt, hd, q.bits, self.tier, dv);
        }
        trace::instant(Cat::Kv, "dequant_write", phys as u64, q.bits as i64, 0);
    }

    /// Copy-on-write: give `slot` a private copy of logical block
    /// `logical` (all layers, both tensors) and drop its reference to
    /// the shared original. A quantized original deep-clones its
    /// **codes** — no float plane is materialized, and mutating the
    /// child can never perturb the parent's payload.
    fn fork(&mut self, slot: usize, logical: usize) -> Result<()> {
        let old = self.tables[slot][logical];
        // `old` has refcount >= 2, so eviction inside alloc can never
        // pick it.
        let nb = self.alloc_block(slot)?;
        if let Some(q) = &self.quant[old] {
            let clone = q.clone();
            self.quant_payload_bytes += clone.payload_bytes();
            self.quantized_count += 1;
            self.quant[nb] = Some(clone);
        } else {
            self.ensure_region(nb);
            let stride = self.stride();
            let (src, dst) = (self.region[old] * stride, self.region[nb] * stride);
            for layer in 0..self.k.len() {
                self.k[layer].copy_within(src..src + stride, dst);
                self.v[layer].copy_within(src..src + stride, dst);
            }
        }
        // Via release: the original may be a registered block dropping
        // to registry-only (it becomes evictable; it cannot hit zero —
        // some other holder motivated the fork).
        self.release(old);
        self.tables[slot][logical] = nb;
        self.cow_forks += 1;
        trace::instant(Cat::Kv, "cow_fork", slot as u64, logical as i64, nb as i64);
        Ok(())
    }

    #[inline]
    fn idx(&self, slot: usize, pos: usize) -> usize {
        let phys = self.tables[slot][pos / self.block_tokens];
        let r = self.region[phys];
        debug_assert!(r != NO_REGION, "f32 access to a quantized block");
        (r * self.n_heads * self.block_tokens + pos % self.block_tokens) * self.head_dim
    }

    /// Append `seq` new positions from per-token projection outputs
    /// `k`/`v` of shape `(len(slot_ids)·seq × d_model)`; lane `i` of the
    /// activation rows lands in cache slot `slot_ids[i]` starting at
    /// `starts[i]`. The caller must have run
    /// [`prepare_append`](KvCache::prepare_append) for the range.
    fn store(
        &mut self,
        layer: usize,
        slot_ids: &[usize],
        starts: &[usize],
        seq: usize,
        k: &Matrix,
        v: &Matrix,
    ) {
        let hd = self.head_dim;
        let hstride = self.block_tokens * hd;
        for (i, &slot) in slot_ids.iter().enumerate() {
            for t in 0..seq {
                let krow = k.row(i * seq + t);
                let vrow = v.row(i * seq + t);
                let base = self.idx(slot, starts[i] + t);
                for head in 0..self.n_heads {
                    let at = base + head * hstride;
                    self.k[layer][at..at + hd]
                        .copy_from_slice(&krow[head * hd..(head + 1) * hd]);
                    self.v[layer][at..at + hd]
                        .copy_from_slice(&vrow[head * hd..(head + 1) * hd]);
                }
            }
        }
    }

    /// Arena offset of `(slot, head, pos)` within one block stride plus
    /// which base arena serves it: the block's own f32 region, or its
    /// staged dequant-scratch slot (which uses the same `[H, bt, hd]`
    /// layout). Quantized blocks must have been staged by
    /// [`stage_dequant`](KvCache::stage_dequant) this read epoch.
    #[inline]
    fn read_at(&self, slot: usize, head: usize, pos: usize) -> (bool, usize) {
        let phys = self.tables[slot][pos / self.block_tokens];
        let off = (head * self.block_tokens + pos % self.block_tokens) * self.head_dim;
        let r = self.region[phys];
        if r != NO_REGION {
            (false, r * self.stride() + off)
        } else {
            debug_assert!(
                self.scratch_tag[phys] == self.scratch_gen,
                "read of an unstaged quantized block"
            );
            (true, self.scratch_slot_of[phys] * self.stride() + off)
        }
    }

    #[inline]
    fn k_at(&self, layer: usize, slot: usize, head: usize, pos: usize) -> &[f32] {
        let (scratch, at) = self.read_at(slot, head, pos);
        if scratch {
            &self.scratch_k[at..at + self.head_dim]
        } else {
            &self.k[layer][at..at + self.head_dim]
        }
    }

    #[inline]
    fn v_at(&self, layer: usize, slot: usize, head: usize, pos: usize) -> &[f32] {
        let (scratch, at) = self.read_at(slot, head, pos);
        if scratch {
            &self.scratch_v[at..at + self.head_dim]
        } else {
            &self.v[layer][at..at + self.head_dim]
        }
    }

    /// Start a fresh dequant-scratch epoch: staged entries from the
    /// previous layer (whose planes differ) become stale in O(1).
    fn begin_read_epoch(&mut self) {
        self.scratch_gen += 1;
        self.scratch_len = 0;
    }

    /// Stage dequantized f32 copies of every quantized block `slot`
    /// reads in the current layer (positions `0..span`). Blocks already
    /// staged this epoch — prefix blocks shared with an earlier lane of
    /// the same forward — count as scratch hits. The arenas grow to the
    /// forward's working set once and are reused, so steady-state
    /// decode allocates nothing.
    fn stage_dequant(&mut self, layer: usize, slot: usize, span: usize) {
        if self.kv_bits.is_none() {
            return;
        }
        let bt = self.block_tokens;
        let blocks = span.div_ceil(bt).min(self.tables[slot].len());
        let stride = self.stride();
        let (heads, hd) = (self.n_heads, self.head_dim);
        for lb in 0..blocks {
            let phys = self.tables[slot][lb];
            if self.quant[phys].is_none() {
                continue;
            }
            if self.scratch_tag[phys] == self.scratch_gen {
                self.dequant_scratch_hits += 1;
                continue;
            }
            let si = self.scratch_len;
            self.scratch_len += 1;
            if self.scratch_k.len() < self.scratch_len * stride {
                self.scratch_k.resize(self.scratch_len * stride, 0.0);
                self.scratch_v.resize(self.scratch_len * stride, 0.0);
            }
            // PANIC: this branch is the `Icq`-state arm of the gather.
            let q = self.quant[phys].as_ref().unwrap();
            let dk = &mut self.scratch_k[si * stride..][..stride];
            dequantize_plane(&q.k[layer], heads, bt, hd, q.bits, self.tier, dk);
            let dv = &mut self.scratch_v[si * stride..][..stride];
            dequantize_plane(&q.v[layer], heads, bt, hd, q.bits, self.tier, dv);
            self.scratch_tag[phys] = self.scratch_gen;
            self.scratch_slot_of[phys] = si;
        }
    }

    /// Quantize every complete (fully written) block of `slot` that is
    /// still f32 — called at the end of each forward, so only the hot
    /// tail block stays f32 (DESIGN.md §12). Quantization reads the
    /// block's floats, builds the per-head-channel payload, and
    /// releases the f32 region back to the arena.
    fn quantize_complete(&mut self, slot: usize) {
        let Some(bits) = self.kv_bits else { return };
        let bt = self.block_tokens;
        let full = self.pos[slot] / bt;
        let stride = self.stride();
        let (heads, hd) = (self.n_heads, self.head_dim);
        for lb in 0..full {
            let phys = self.tables[slot][lb];
            if self.quant[phys].is_some() {
                continue;
            }
            let r = self.region[phys];
            debug_assert!(r != NO_REGION);
            let mut kq = Vec::with_capacity(self.k.len());
            let mut vq = Vec::with_capacity(self.v.len());
            for layer in 0..self.k.len() {
                let sk = &self.k[layer][r * stride..][..stride];
                kq.push(quantize_plane(sk, heads, bt, hd, bits));
                let sv = &self.v[layer][r * stride..][..stride];
                vq.push(quantize_plane(sv, heads, bt, hd, bits));
            }
            let q = Box::new(QuantBlock { bits, k: kq, v: vq });
            let payload = q.payload_bytes();
            self.quant[phys] = Some(q);
            self.region_free.push(r);
            self.region[phys] = NO_REGION;
            self.quantized_count += 1;
            self.quant_payload_bytes += payload;
            self.blocks_quantized += 1;
            trace::instant(
                Cat::Kv,
                "quantize_block",
                phys as u64,
                payload as i64,
                (2 * self.k.len() * stride * 4) as i64,
            );
        }
    }

    /// Host bytes held by this cache: the f32 arena (both tensors, all
    /// layers) plus every quantized payload. With quantization off this
    /// is exactly the pre-§12 fully provisioned footprint.
    pub fn memory_bytes(&self) -> usize {
        (self.k.iter().map(|l| l.len()).sum::<usize>()
            + self.v.iter().map(|l| l.len()).sum::<usize>())
            * 4
            + self.quant_payload_bytes
    }

    /// Whether `slot`'s logical block `logical` is in the `Icq` state.
    #[doc(hidden)]
    pub fn debug_block_is_quantized(&self, slot: usize, logical: usize) -> bool {
        self.quant[self.tables[slot][logical]].is_some()
    }

    /// Read one position's K and V rows (all heads concatenated),
    /// dequantizing through the scratch path when the block is
    /// quantized — the test harness's window into block contents.
    #[doc(hidden)]
    pub fn debug_read(&mut self, layer: usize, slot: usize, pos: usize) -> (Vec<f32>, Vec<f32>) {
        self.begin_read_epoch();
        self.stage_dequant(layer, slot, pos + 1);
        let hd = self.head_dim;
        let mut k = Vec::with_capacity(self.n_heads * hd);
        let mut v = Vec::with_capacity(self.n_heads * hd);
        for head in 0..self.n_heads {
            k.extend_from_slice(self.k_at(layer, slot, head, pos));
            v.extend_from_slice(self.v_at(layer, slot, head, pos));
        }
        (k, v)
    }

    /// Copy-on-write fork `slot`'s logical block `logical` regardless
    /// of sharing state — lets tests exercise the quantized-fork path
    /// directly.
    #[doc(hidden)]
    pub fn debug_fork_block(&mut self, slot: usize, logical: usize) -> Result<()> {
        self.fork(slot, logical)
    }

    /// Flip every code byte of `slot`'s logical block `logical`
    /// (quantized payload only) — used to prove forks are deep.
    #[doc(hidden)]
    pub fn debug_corrupt_quant(&mut self, slot: usize, logical: usize) {
        let phys = self.tables[slot][logical];
        // PANIC: test-only corruption hook; misuse on an f32 block
        // should fail loudly in the calling test.
        let q = self.quant[phys].as_mut().expect("corrupt target is not quantized");
        for plane in q.k.iter_mut().chain(q.v.iter_mut()) {
            for b in &mut plane.codes {
                *b ^= 0xFF;
            }
        }
    }

    /// Exhaustively check the allocator/refcount/registry invariants —
    /// the fuzz harnesses call this after every scheduling step. Not
    /// part of the supported API.
    #[doc(hidden)]
    pub fn debug_validate(&self) {
        let bt = self.block_tokens;
        let mut refs = vec![0u32; self.total_blocks];
        for (slot, table) in self.tables.iter().enumerate() {
            let pos = self.pos[slot];
            assert!(pos <= self.max_seq, "slot {} pos {} beyond max_seq", slot, pos);
            assert!(
                table.len() >= pos.div_ceil(bt) && table.len() <= (pos + 1).div_ceil(bt),
                "slot {} table len {} inconsistent with pos {}",
                slot,
                table.len(),
                pos
            );
            for &b in table {
                refs[b] += 1;
            }
        }
        for (b, e) in self.registered.iter().enumerate() {
            if let Some(entry) = e {
                refs[b] += 1;
                assert_eq!(
                    self.prefix_index.get(&entry.key),
                    Some(&b),
                    "registry entry for block {} missing from index",
                    b
                );
            }
        }
        let reg_count = self.registered.iter().filter(|e| e.is_some()).count();
        assert_eq!(self.prefix_index.len(), reg_count);
        assert_eq!(self.registered_count, reg_count, "registered_count out of sync");
        let evictable = self
            .registered
            .iter()
            .enumerate()
            .filter(|(b, e)| e.is_some() && self.refcount[*b] == 1)
            .count();
        assert_eq!(self.evictable_count, evictable, "evictable_count out of sync");
        for (b, &rc) in self.refcount.iter().enumerate() {
            assert_eq!(rc, refs[b], "block {} refcount {} != {} references", b, rc, refs[b]);
        }
        let mut seen = vec![false; self.total_blocks];
        for &b in &self.free {
            assert!(!seen[b], "block {} on the free list twice", b);
            seen[b] = true;
            assert_eq!(self.refcount[b], 0, "free block {} has references", b);
        }
        let in_use = self.refcount.iter().filter(|&&rc| rc > 0).count();
        assert_eq!(in_use + self.free.len(), self.total_blocks, "blocks leaked");
        assert_eq!(self.reserved_total, self.reserved.iter().sum::<usize>());
        assert!(self.reserved_total <= self.free.len(), "reservations exceed free blocks");

        // Quantized-block state machine + byte accounting (DESIGN.md §12).
        let mut qcount = 0usize;
        let mut payload = 0usize;
        let mut region_seen = vec![false; self.regions];
        for b in 0..self.total_blocks {
            let has_r = self.region[b] != NO_REGION;
            let has_q = self.quant[b].is_some();
            if self.refcount[b] > 0 {
                assert!(
                    has_r ^ has_q,
                    "block {} must be exactly one of F32/Icq (region={} quant={})",
                    b,
                    has_r,
                    has_q
                );
            } else {
                assert!(!has_q, "free block {} still holds a quantized payload", b);
                if self.kv_bits.is_some() {
                    assert!(!has_r, "free block {} still holds an f32 region", b);
                }
            }
            if has_r {
                let r = self.region[b];
                assert!(r < self.regions, "block {} region {} out of range", b, r);
                assert!(!region_seen[r], "region {} mapped twice", r);
                region_seen[r] = true;
            }
            if let Some(q) = &self.quant[b] {
                assert_eq!(
                    Some(q.bits),
                    self.kv_bits,
                    "block {} quantized at {} bits under kv_bits {:?}",
                    b,
                    q.bits,
                    self.kv_bits
                );
                qcount += 1;
                payload += q.payload_bytes();
            }
        }
        for &r in &self.region_free {
            assert!(!region_seen[r], "region {} both mapped and free", r);
            region_seen[r] = true;
        }
        assert!(region_seen.iter().all(|&s| s), "arena region leaked");
        assert_eq!(self.quantized_count, qcount, "quantized_count out of sync");
        assert_eq!(self.quant_payload_bytes, payload, "quantized byte accounting out of sync");
        let f32_block = 2 * self.k.len() * self.stride() * 4;
        assert_eq!(
            self.resident_kv_bytes(),
            payload + f32_block * (in_use - qcount),
            "resident byte accounting out of sync"
        );
        if self.kv_bits.is_some() {
            // Hot-tail rule: a partially filled tail block is always f32.
            for (slot, table) in self.tables.iter().enumerate() {
                let pos = self.pos[slot];
                if pos % bt != 0 && pos / bt < table.len() {
                    assert!(
                        self.quant[table[pos / bt]].is_none(),
                        "slot {} partial tail block is quantized",
                        slot
                    );
                }
            }
        }
    }
}

/// One transformer block's weights: quantized projections (shared with
/// the decode cache) + dense norms.
struct BlockWeights {
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    wq: Arc<RuntimePlane>,
    wk: Arc<RuntimePlane>,
    wv: Arc<RuntimePlane>,
    wo: Arc<RuntimePlane>,
    w_gate: Arc<RuntimePlane>,
    w_up: Arc<RuntimePlane>,
    w_down: Arc<RuntimePlane>,
}

/// The native-kernel model: quantized projections resident as fused
/// runtime planes, dense side tensors as f32.
pub struct NativeModel {
    pub config: ModelConfig,
    /// Persistent worker pool every fused GEMM dispatches through —
    /// spawned once at construction, parked between tokens. No
    /// per-projection thread spawn survives on the decode path.
    pool: Arc<WorkerPool>,
    tok_emb: Matrix,
    lm_head: Matrix,
    final_norm: Vec<f32>,
    blocks: Vec<BlockWeights>,
    /// RoPE frequencies `θ^(-j/half)` for `j in 0..head_dim/2`,
    /// precomputed once (they are position-independent).
    rope_inv_freq: Vec<f32>,
    /// SIMD kernel tier (DESIGN.md §14), resolved once at construction
    /// (`ICQ_SIMD`, [`Tier::Scalar`] default semantics preserved) and
    /// threaded into every projection, attention dot, and dequant fill.
    tier: Tier,
    /// Activation handling for single-token decode projections
    /// (`--act-quant`): [`ActQuant::Int8`] routes the bucket-1 GEMV
    /// through the integer inner product.
    act_quant: ActQuant,
}

impl NativeModel {
    /// Assemble from an opened container: projections come through the
    /// store's shared [`crate::store::DecodeCache`] (one fused decode per
    /// layer, shared with every other consumer of the artifact), dense
    /// tensors are copied out. `threads` sizes the model's persistent
    /// kernel pool (0 ⇒ all available cores).
    pub fn from_stored(stored: &StoredModel, threads: usize) -> Result<NativeModel> {
        Self::from_stored_with_pool(stored, Arc::new(WorkerPool::new(threads)))
    }

    /// [`Self::from_stored`] sharing an existing kernel pool — several
    /// models (or a model plus ad-hoc kernel callers) can dispatch onto
    /// one set of parked workers.
    pub fn from_stored_with_pool(
        stored: &StoredModel,
        pool: Arc<WorkerPool>,
    ) -> Result<NativeModel> {
        let config = stored
            .config
            .clone()
            .context("container carries no model config; cannot build a native model")?;
        ensure!(
            config.d_model % config.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            config.d_model,
            config.n_heads
        );
        ensure!(config.head_dim() % 2 == 0, "RoPE needs an even head_dim");
        let dense_mat = |name: &str| -> Result<Matrix> {
            let (shape, data) = stored.dense(name)?;
            ensure!(shape.len() == 2, "{} is not 2-D", name);
            Ok(Matrix::from_vec(shape[0], shape[1], data.to_vec()))
        };
        let dense_vec = |name: &str, want: usize| -> Result<Vec<f32>> {
            let (_, data) = stored.dense(name)?;
            ensure!(data.len() == want, "{}: expected {} values, found {}", name, want, data.len());
            Ok(data.to_vec())
        };
        let plane = |name: &str, rows: usize, cols: usize| -> Result<Arc<RuntimePlane>> {
            let p = stored.runtime_plane(name)?;
            ensure!(
                (p.rows, p.cols) == (rows, cols),
                "{}: expected {}x{}, container holds {}x{}",
                name,
                rows,
                cols,
                p.rows,
                p.cols
            );
            Ok(p)
        };

        let d = config.d_model;
        let ff = config.d_ff;
        let mut blocks = Vec::with_capacity(config.n_layers);
        for i in 0..config.n_layers {
            blocks.push(BlockWeights {
                attn_norm: dense_vec(&format!("l{}.attn_norm", i), d)?,
                mlp_norm: dense_vec(&format!("l{}.mlp_norm", i), d)?,
                wq: plane(&format!("l{}.wq", i), d, d)?,
                wk: plane(&format!("l{}.wk", i), d, d)?,
                wv: plane(&format!("l{}.wv", i), d, d)?,
                wo: plane(&format!("l{}.wo", i), d, d)?,
                w_gate: plane(&format!("l{}.w_gate", i), ff, d)?,
                w_up: plane(&format!("l{}.w_up", i), ff, d)?,
                w_down: plane(&format!("l{}.w_down", i), d, ff)?,
            });
        }
        let tok_emb = dense_mat("tok_emb")?;
        let lm_head = dense_mat("lm_head")?;
        ensure!(
            (tok_emb.rows, tok_emb.cols) == (config.vocab, d),
            "tok_emb shape mismatch"
        );
        ensure!(
            (lm_head.rows, lm_head.cols) == (config.vocab, d),
            "lm_head shape mismatch"
        );
        let half = config.head_dim() / 2;
        let rope_inv_freq = (0..half)
            .map(|j| ROPE_THETA.powf(-(j as f32) / half as f32))
            .collect();
        Ok(NativeModel {
            config,
            pool,
            tok_emb,
            lm_head,
            final_norm: dense_vec("final_norm", d)?,
            blocks,
            rope_inv_freq,
            tier: simd::from_env(),
            act_quant: ActQuant::F32,
        })
    }

    /// Builder override for the SIMD tier (e.g. `serve --simd`); the
    /// constructor default is [`simd::from_env`].
    pub fn with_simd(mut self, tier: Tier) -> NativeModel {
        self.tier = tier;
        self
    }

    /// Builder override for activation quantization (`--act-quant`).
    pub fn with_act_quant(mut self, act: ActQuant) -> NativeModel {
        self.act_quant = act;
        self
    }

    /// In-place form of [`Self::with_simd`].
    pub fn set_simd(&mut self, tier: Tier) {
        self.tier = tier;
    }

    /// In-place form of [`Self::with_act_quant`].
    pub fn set_act_quant(&mut self, act: ActQuant) {
        self.act_quant = act;
    }

    /// The resolved SIMD tier every kernel call dispatches on.
    pub fn simd_tier(&self) -> Tier {
        self.tier
    }

    /// The active activation-quantization mode.
    pub fn act_quant(&self) -> ActQuant {
        self.act_quant
    }

    /// Route one projection through the tier: the int8 path applies
    /// only to single-token (bucket-1 decode) calls — exactly the
    /// GEMV inner loop the act-quant knob targets — batched calls stay
    /// on the f32 tier path.
    fn project(&self, plane: &RuntimePlane, x: &Matrix, y: &mut Matrix) {
        if self.act_quant == ActQuant::Int8 && x.rows == 1 {
            gemv_i8_on(&self.pool, plane, x.row(0), &mut y.data, self.tier);
        } else {
            gemm_on_tier(&self.pool, plane, x, y, self.tier);
        }
    }

    /// Executor width of the kernel pool (workers + caller).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The model's persistent kernel pool.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Resident weight bytes of the quantized planes (codes + per-row
    /// codebooks) — the serving working set the fused kernels stream.
    pub fn quantized_bytes(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| {
                [&b.wq, &b.wk, &b.wv, &b.wo, &b.w_gate, &b.w_up, &b.w_down]
            })
            .map(|p| p.memory_bytes())
            .sum()
    }

    /// What the same projections would occupy dequantized to f32.
    pub fn dequantized_bytes(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| {
                [&b.wq, &b.wk, &b.wv, &b.wo, &b.w_gate, &b.w_up, &b.w_down]
            })
            .map(|p| p.rows * p.cols * 4)
            .sum()
    }

    /// Prompt pass for a batch of equal-length prompts: fills a fresh KV
    /// cache (slot `i` ← prompt `i`, default paged layout) and returns
    /// the last-position token ids (greedy).
    pub fn prefill(&self, prompts: &[Vec<i32>]) -> Result<(Vec<i32>, KvCache)> {
        let batch = prompts.len();
        ensure!(batch > 0, "empty batch");
        let seq = prompts[0].len();
        ensure!(seq > 0, "empty prompt");
        for p in prompts {
            ensure!(p.len() == seq, "prompts not normalized to one length");
        }
        ensure!(seq <= self.config.max_seq, "prompt exceeds max_seq");
        let mut tokens = Vec::with_capacity(batch * seq);
        for p in prompts {
            tokens.extend_from_slice(p);
        }
        let mut kv = KvCache::new(&self.config, batch);
        let slot_ids: Vec<usize> = (0..batch).collect();
        let logits = self.forward_slots(&tokens, &slot_ids, seq, &mut kv)?;
        Ok((argmax_rows(&logits, batch), kv))
    }

    /// Prompt pass for **one** sequence into lane `slot` of an existing
    /// cache, while other lanes stay live — the continuous scheduler's
    /// admission path. The slot's previous occupant is discarded.
    /// Shared-prefix reuse applies (DESIGN.md §10): registered prefix
    /// blocks are mapped instead of recomputed. Returns the first
    /// greedily sampled token.
    pub fn prefill_slot(&self, kv: &mut KvCache, slot: usize, prompt: &[i32]) -> Result<i32> {
        Ok(self.prefill_slots(kv, &[slot], prompt, prompt.len())?[0])
    }

    /// Prompt pass for **several** sequences at once, one per lane of
    /// `slot_ids` (ascending): `tokens` is `(len(slot_ids) × seq)`
    /// row-major, every prompt already normalized to `seq`. Each target
    /// lane's previous occupant is discarded. Returns the first greedily
    /// sampled token per lane.
    ///
    /// Each lane first maps the longest registered prefix chain of its
    /// prompt into its block table (skipping that much prefill
    /// compute); the remaining suffixes are then forwarded **batched by
    /// equal suffix length**, so a uniform admission round still
    /// decodes each weight block once for all lanes — k× less weight
    /// traffic than k single-slot prefills on this memory-bound path.
    pub fn prefill_slots(
        &self,
        kv: &mut KvCache,
        slot_ids: &[usize],
        tokens: &[i32],
        seq: usize,
    ) -> Result<Vec<i32>> {
        let result = self.prefill_slots_inner(kv, slot_ids, tokens, seq);
        if result.is_err() {
            // A failed round (e.g. block-pool exhaustion after some
            // lanes mapped shared prefixes) must not leak refcounts or
            // half-admitted positions: free everything we touched so
            // the cache stays consistent for the next round.
            for &s in slot_ids {
                if s < kv.slots {
                    kv.free_slot(s);
                }
            }
        }
        result
    }

    fn prefill_slots_inner(
        &self,
        kv: &mut KvCache,
        slot_ids: &[usize],
        tokens: &[i32],
        seq: usize,
    ) -> Result<Vec<i32>> {
        ensure!(!slot_ids.is_empty(), "empty admission");
        ensure!(seq > 0, "empty prompt");
        ensure!(seq <= self.config.max_seq, "prompt exceeds max_seq");
        ensure!(
            tokens.len() == slot_ids.len() * seq,
            "token buffer shape mismatch"
        );
        // Enforced here (not just per suffix group): duplicates that
        // land in different groups would each pass the group-local
        // forward validation while corrupting the shared slot's table.
        for w in slot_ids.windows(2) {
            ensure!(w[0] < w[1], "slot ids must be ascending and distinct");
        }
        for &s in slot_ids {
            ensure!(s < kv.slots, "slot {} out of range ({} slots)", s, kv.slots);
        }
        for &s in slot_ids {
            kv.free_slot(s);
        }
        // Map shared prefixes, then group lanes by remaining suffix
        // length so each group shares one forward pass.
        let mut reuse = vec![0usize; slot_ids.len()];
        for (i, &s) in slot_ids.iter().enumerate() {
            reuse[i] = kv.map_shared_prefix(s, &tokens[i * seq..(i + 1) * seq]);
        }
        let mut by_suffix: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, &r) in reuse.iter().enumerate() {
            by_suffix.entry(seq - r).or_default().push(i);
        }
        let mut firsts = vec![0i32; slot_ids.len()];
        for (&suffix, lanes) in &by_suffix {
            let group: Vec<usize> = lanes.iter().map(|&i| slot_ids[i]).collect();
            let mut buf = Vec::with_capacity(lanes.len() * suffix);
            for &i in lanes {
                buf.extend_from_slice(&tokens[i * seq + (seq - suffix)..(i + 1) * seq]);
            }
            let logits = self.forward_slots(&buf, &group, suffix, kv)?;
            for (j, &i) in lanes.iter().enumerate() {
                let row = &logits[j * self.config.vocab..(j + 1) * self.config.vocab];
                firsts[i] = argmax_rows(row, 1)[0];
            }
        }
        for (i, &s) in slot_ids.iter().enumerate() {
            kv.register_prefix(s, &tokens[i * seq..(i + 1) * seq]);
        }
        Ok(firsts)
    }

    /// One greedy decode step over every lane of the cache (uniform
    /// batch) — the wave-path analogue of [`Self::decode_slots`].
    pub fn decode_step(&self, kv: &mut KvCache, last_tokens: &[i32]) -> Result<Vec<i32>> {
        ensure!(last_tokens.len() == kv.slots, "token/slot mismatch");
        let slot_ids: Vec<usize> = (0..kv.slots).collect();
        self.decode_slots(kv, last_tokens, &slot_ids)
    }

    /// One greedy decode step over `slot_ids` only (ascending, no
    /// duplicates); lanes not listed are untouched and cost nothing —
    /// retired and still-free slots stop burning kernel time.
    /// `last_tokens[i]` feeds `slot_ids[i]`.
    pub fn decode_slots(
        &self,
        kv: &mut KvCache,
        last_tokens: &[i32],
        slot_ids: &[usize],
    ) -> Result<Vec<i32>> {
        ensure!(last_tokens.len() == slot_ids.len(), "token/slot mismatch");
        for &s in slot_ids {
            ensure!(s < kv.slots, "slot {} out of range ({} slots)", s, kv.slots);
            ensure!(kv.pos[s] > 0, "decode on unprefilled slot {}", s);
            ensure!(kv.pos[s] < self.config.max_seq, "KV slot {} exhausted", s);
        }
        let logits = self.forward_slots(last_tokens, slot_ids, 1, kv)?;
        Ok(argmax_rows(&logits, slot_ids.len()))
    }

    /// Core forward over an arbitrary lane subset: `tokens` is
    /// `(len(slot_ids) × seq)` row-major; row group `i` continues slot
    /// `slot_ids[i]` from its current position. Returns last-position
    /// logits `(len(slot_ids) × vocab)` and advances each slot's
    /// position by `seq`.
    fn forward_slots(
        &self,
        tokens: &[i32],
        slot_ids: &[usize],
        seq: usize,
        kv: &mut KvCache,
    ) -> Result<Vec<f32>> {
        let cfg = &self.config;
        let (d, hd, heads) = (cfg.d_model, cfg.head_dim(), cfg.n_heads);
        let n = slot_ids.len();
        ensure!(n > 0 && seq > 0, "empty forward");
        ensure!(tokens.len() == n * seq, "token buffer shape mismatch");
        for w in slot_ids.windows(2) {
            ensure!(w[0] < w[1], "slot ids must be ascending and distinct");
        }
        for &s in slot_ids {
            ensure!(s < kv.slots, "slot {} out of range", s);
        }
        let starts: Vec<usize> = slot_ids.iter().map(|&s| kv.pos[s]).collect();
        for (i, &s) in slot_ids.iter().enumerate() {
            ensure!(starts[i] + seq <= cfg.max_seq, "KV slot {} overflow", s);
        }
        // Block housekeeping before any layer writes: allocate table
        // entries for the new positions and copy-on-write fork shared
        // blocks in the write range (all layers at once).
        for &s in slot_ids {
            kv.prepare_append(s, seq)?;
        }
        let bs = n * seq;

        // Token embeddings (out-of-range ids are clamped into the byte
        // vocab rather than panicking on hostile input).
        let mut x = Matrix::zeros(bs, d);
        for (i, &t) in tokens.iter().enumerate() {
            let id = (t.max(0) as usize).min(cfg.vocab - 1);
            x.row_mut(i).copy_from_slice(self.tok_emb.row(id));
        }

        let scale = 1.0 / (hd as f32).sqrt();
        let max_span = starts.iter().max().copied().unwrap_or(0) + seq;
        for (layer, bw) in self.blocks.iter().enumerate() {
            // --- attention ---------------------------------------------
            let h = rmsnormed(&x, &bw.attn_norm);
            let mut q = Matrix::zeros(bs, d);
            let mut k = Matrix::zeros(bs, d);
            let mut v = Matrix::zeros(bs, d);
            self.project(&bw.wq, &h, &mut q);
            self.project(&bw.wk, &h, &mut k);
            self.project(&bw.wv, &h, &mut v);
            for i in 0..n {
                for t in 0..seq {
                    let row = i * seq + t;
                    let pos = starts[i] + t;
                    apply_rope(q.row_mut(row), heads, hd, pos, &self.rope_inv_freq);
                    apply_rope(k.row_mut(row), heads, hd, pos, &self.rope_inv_freq);
                }
            }
            kv.store(layer, slot_ids, &starts, seq, &k, &v);
            // Stage dequantized copies of every quantized block the
            // attention reads below will touch (no-op with kv_bits
            // off). Shared prefix blocks are staged once per layer and
            // hit from every lane.
            kv.begin_read_epoch();
            for (i, &slot) in slot_ids.iter().enumerate() {
                kv.stage_dequant(layer, slot, starts[i] + seq);
            }

            let mut attn = Matrix::zeros(bs, d);
            let mut scores = vec![0.0f32; max_span];
            for (i, &slot) in slot_ids.iter().enumerate() {
                for head in 0..heads {
                    for t in 0..seq {
                        let row = i * seq + t;
                        let span = starts[i] + t + 1; // causal: positions 0..=pos
                        let qh = &q.row(row)[head * hd..(head + 1) * hd];
                        for (p, s) in scores[..span].iter_mut().enumerate() {
                            *s = simd::dot(self.tier, qh, kv.k_at(layer, slot, head, p)) * scale;
                        }
                        softmax(&mut scores[..span]);
                        let out = &mut attn.row_mut(row)[head * hd..(head + 1) * hd];
                        for (p, &w) in scores[..span].iter().enumerate() {
                            simd::axpy(self.tier, out, w, kv.v_at(layer, slot, head, p));
                        }
                    }
                }
            }
            let mut o = Matrix::zeros(bs, d);
            self.project(&bw.wo, &attn, &mut o);
            add_assign(&mut x, &o);

            // --- SwiGLU MLP --------------------------------------------
            let h = rmsnormed(&x, &bw.mlp_norm);
            let mut gate = Matrix::zeros(bs, cfg.d_ff);
            let mut up = Matrix::zeros(bs, cfg.d_ff);
            self.project(&bw.w_gate, &h, &mut gate);
            self.project(&bw.w_up, &h, &mut up);
            for (g, u) in gate.data.iter_mut().zip(&up.data) {
                *g = silu(*g) * *u;
            }
            let mut down = Matrix::zeros(bs, d);
            self.project(&bw.w_down, &gate, &mut down);
            add_assign(&mut x, &down);
        }
        for (i, &s) in slot_ids.iter().enumerate() {
            kv.pos[s] = starts[i] + seq;
        }
        // Every block this forward completed leaves the hot tail:
        // quantize it now (no-op with kv_bits off), so registration and
        // the next forward's reads see the canonical `Icq` payload.
        for &s in slot_ids {
            kv.quantize_complete(s);
        }

        // Final norm + lm_head logits, last position per sequence only.
        let mut logits = vec![0.0f32; n * cfg.vocab];
        let mut hrow = vec![0.0f32; d];
        for i in 0..n {
            let xrow = x.row(i * seq + (seq - 1));
            rmsnorm_into(xrow, &self.final_norm, &mut hrow);
            let out = &mut logits[i * cfg.vocab..(i + 1) * cfg.vocab];
            for (vi, o) in out.iter_mut().enumerate() {
                *o = simd::dot(self.tier, self.lm_head.row(vi), &hrow);
            }
        }
        Ok(logits)
    }
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn add_assign(x: &mut Matrix, y: &Matrix) {
    debug_assert_eq!((x.rows, x.cols), (y.rows, y.cols));
    for (a, b) in x.data.iter_mut().zip(&y.data) {
        *a += *b;
    }
}

/// RMSNorm one row into a caller buffer.
fn rmsnorm_into(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + NORM_EPS).sqrt();
    for ((o, xv), wv) in out.iter_mut().zip(x).zip(w) {
        *o = xv * r * wv;
    }
}

/// Row-wise RMSNorm of a whole activation matrix.
fn rmsnormed(x: &Matrix, w: &[f32]) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        rmsnorm_into(x.row(r), w, out.row_mut(r));
    }
    out
}

/// In-place RoPE for one `(heads × hd)` activation row at `pos`
/// (half-split rotation, matching python `_apply_rope`).
/// `inv_freq` is the precomputed `θ^(-j/half)` table (`hd/2` entries).
fn apply_rope(row: &mut [f32], heads: usize, hd: usize, pos: usize, inv_freq: &[f32]) {
    let half = hd / 2;
    debug_assert_eq!(inv_freq.len(), half);
    for head in 0..heads {
        let h = &mut row[head * hd..(head + 1) * hd];
        for (j, &freq) in inv_freq.iter().enumerate() {
            let ang = pos as f32 * freq;
            let (sin, cos) = ang.sin_cos();
            let (a, b) = (h[j], h[j + half]);
            h[j] = a * cos - b * sin;
            h[j + half] = a * sin + b * cos;
        }
    }
}

/// Numerically-stable in-place softmax.
fn softmax(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::IcqConfig;
    use crate::quant::QuantizerKind;
    use crate::store::{synth_model, DecodeCache, StoredModel};
    use crate::synthzoo::FamilySpec;

    /// A deliberately tiny family so debug-mode tests stay fast.
    fn tiny_family() -> FamilySpec {
        FamilySpec {
            name: "tiny-test",
            d_model: 32,
            d_ff: 64,
            n_blocks: 2,
            tail_frac: 0.02,
            tail_scale: 2.5,
            oproj_hot: 0.5,
            seed: 0x7157,
        }
    }

    fn tiny_native(threads: usize) -> (NativeModel, Arc<DecodeCache>) {
        let cfg = IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::Rtn,
        };
        let model = synth_model(&tiny_family(), &cfg, None).unwrap();
        let cache = Arc::new(DecodeCache::new(64 << 20));
        let stored = StoredModel::from_model(model, cache.clone(), "native-test");
        (NativeModel::from_stored(&stored, threads).unwrap(), cache)
    }

    /// Greedy-generate `steps` tokens from `prompt` alone in a fresh
    /// cache with the given layout.
    fn stream_with_layout(
        m: &NativeModel,
        layout: KvLayout,
        prompt: &[i32],
        steps: usize,
    ) -> Vec<i32> {
        let mut kv = KvCache::with_layout(&m.config, 1, layout);
        let mut last = m.prefill_slot(&mut kv, 0, prompt).unwrap();
        let mut out = Vec::with_capacity(steps);
        kv.debug_validate();
        for _ in 0..steps {
            last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
            out.push(last);
            kv.debug_validate();
        }
        out
    }

    #[test]
    fn prefill_then_decode_produces_tokens_in_vocab() {
        let (m, _) = tiny_native(1);
        let prompts = vec![vec![72, 101, 108, 108, 111, 32, 119, 111], vec![84, 104, 101, 32, 113, 117, 105, 99]];
        let (first, mut kv) = m.prefill(&prompts).unwrap();
        assert_eq!(first.len(), 2);
        assert_eq!(kv.pos(0), 8);
        assert_eq!(kv.pos(1), 8);
        let mut last = first;
        for step in 0..4 {
            last = m.decode_step(&mut kv, &last).unwrap();
            assert_eq!(kv.pos(0), 9 + step);
            for &t in &last {
                assert!((0..m.config.vocab as i32).contains(&t));
            }
        }
    }

    #[test]
    fn decode_is_deterministic_and_thread_count_invariant() {
        // The fused kernels are bit-identical across thread counts, so
        // the whole generation must be too.
        let (m1, _) = tiny_native(1);
        let (m4, _) = tiny_native(4);
        let prompts = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let (t1, mut kv1) = m1.prefill(&prompts).unwrap();
        let (t4, mut kv4) = m4.prefill(&prompts).unwrap();
        assert_eq!(t1, t4);
        let (mut a, mut b) = (t1, t4);
        for _ in 0..5 {
            a = m1.decode_step(&mut kv1, &a).unwrap();
            b = m4.decode_step(&mut kv4, &b).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn incremental_decode_matches_full_prefill() {
        // Teacher-forcing consistency: prefill over [p0..p5] must leave
        // the model predicting the same next token as prefill over
        // [p0..p4] followed by one decode step feeding p5.
        let (m, _) = tiny_native(2);
        let full: Vec<i32> = vec![10, 20, 30, 40, 50, 60];
        let (next_full, _) = m.prefill(&[full.clone()]).unwrap();
        let (_, mut kv) = m.prefill(&[full[..5].to_vec()]).unwrap();
        let next_inc = m.decode_step(&mut kv, &[full[5]]).unwrap();
        assert_eq!(next_full, next_inc);
    }

    /// A sequence's greedy stream must not depend on how it was
    /// scheduled: alone via the batch path, or slot-prefilled into a
    /// shared cache and decoded beside a stranger at a different
    /// position. This is the correctness contract the continuous
    /// scheduler rests on.
    #[test]
    fn slot_path_matches_batch_path() {
        let (m, _) = tiny_native(2);
        let prompt_a: Vec<i32> = vec![72, 105, 32, 116, 104, 101];
        let prompt_b: Vec<i32> = vec![9, 8, 7];

        // Reference: each prompt alone through the batch path.
        let mut ref_stream_a = Vec::new();
        let (mut last, mut kv) = m.prefill(&[prompt_a.clone()]).unwrap();
        for _ in 0..4 {
            last = m.decode_step(&mut kv, &last).unwrap();
            ref_stream_a.push(last[0]);
        }
        let mut ref_stream_b = Vec::new();
        let (mut last, mut kv) = m.prefill(&[prompt_b.clone()]).unwrap();
        for _ in 0..4 {
            last = m.decode_step(&mut kv, &last).unwrap();
            ref_stream_b.push(last[0]);
        }

        // Slot path: A prefills into slot 0, decodes 2 steps alone, then
        // B is admitted into slot 1 mid-flight and both decode together.
        let mut kv = KvCache::new(&m.config, 2);
        let mut last_a = m.prefill_slot(&mut kv, 0, &prompt_a).unwrap();
        let mut got_a = Vec::new();
        for _ in 0..2 {
            let next = m.decode_slots(&mut kv, &[last_a], &[0]).unwrap();
            last_a = next[0];
            got_a.push(last_a);
        }
        let mut last_b = m.prefill_slot(&mut kv, 1, &prompt_b).unwrap();
        assert_eq!(kv.pos(0), prompt_a.len() + 2);
        assert_eq!(kv.pos(1), prompt_b.len());
        let mut got_b = Vec::new();
        for _ in 0..2 {
            let next = m.decode_slots(&mut kv, &[last_a, last_b], &[0, 1]).unwrap();
            last_a = next[0];
            last_b = next[1];
            got_a.push(last_a);
            got_b.push(last_b);
        }
        for _ in 0..2 {
            let next = m.decode_slots(&mut kv, &[last_b], &[1]).unwrap();
            last_b = next[0];
            got_b.push(last_b);
        }
        assert_eq!(got_a, ref_stream_a);
        assert_eq!(got_b, ref_stream_b);
    }

    /// Retiring a slot and admitting a new sequence into it must produce
    /// the same stream as a fresh cache — stale KV data from the previous
    /// occupant is unreachable behind the position gate.
    #[test]
    fn freed_slot_reuse_is_clean() {
        let (m, _) = tiny_native(1);
        let first: Vec<i32> = vec![100, 101, 102, 103, 104, 105, 106, 107];
        let second: Vec<i32> = vec![42, 43, 44];

        let mut ref_stream = Vec::new();
        let (mut last, mut kv) = m.prefill(&[second.clone()]).unwrap();
        for _ in 0..3 {
            last = m.decode_step(&mut kv, &last).unwrap();
            ref_stream.push(last[0]);
        }

        // Occupy the slot with a longer sequence, retire it, reuse it.
        let mut kv = KvCache::new(&m.config, 1);
        let mut last = m.prefill_slot(&mut kv, 0, &first).unwrap();
        for _ in 0..5 {
            last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
        }
        kv.free_slot(0);
        assert_eq!(kv.pos(0), 0);
        let mut last = m.prefill_slot(&mut kv, 0, &second).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
            got.push(last);
        }
        assert_eq!(got, ref_stream);
    }

    #[test]
    fn decode_slots_rejects_bad_slot_lists() {
        let (m, _) = tiny_native(1);
        let mut kv = KvCache::new(&m.config, 2);
        let last = m.prefill_slot(&mut kv, 0, &[1, 2, 3]).unwrap();
        // Unprefilled slot.
        assert!(m.decode_slots(&mut kv, &[last], &[1]).is_err());
        // Out-of-range slot.
        assert!(m.decode_slots(&mut kv, &[last], &[2]).is_err());
        // Duplicate slots.
        assert!(m.decode_slots(&mut kv, &[last, last], &[0, 0]).is_err());
        // Mismatched lengths.
        assert!(m.decode_slots(&mut kv, &[last, last], &[0]).is_err());
        // Duplicate slots in a batched admission are rejected up front
        // (suffix grouping could otherwise split them into separately
        // valid forwards while corrupting the shared slot's table).
        assert!(m.prefill_slots(&mut kv, &[0, 0], &[1, 2, 3, 4], 2).is_err());
    }

    #[test]
    fn working_set_is_quantized_not_f32() {
        let (m, cache) = tiny_native(1);
        // At tiny widths the per-row codebooks are a large share; at LLM
        // widths the ratio approaches 4× (codes are 1 B vs 4 B f32).
        assert!(m.quantized_bytes() < m.dequantized_bytes());
        // Every projection plane is resident in the shared cache (codes
        // + codebooks), and the cache charged quantized bytes, not f32.
        assert!(cache.bytes_used() >= m.quantized_bytes());
        assert!(cache.bytes_used() < m.dequantized_bytes());
    }

    #[test]
    fn kv_cache_accounting() {
        let (m, _) = tiny_native(1);
        let (_, kv) = m.prefill(&[vec![1, 2, 3]]).unwrap();
        let cfg = &m.config;
        // max_seq (256) is a multiple of the default block size, so the
        // fully-provisioned paged pool matches the contiguous footprint
        // exactly: blocks × H × block_tokens × hd == H × max_seq × hd.
        let want =
            2 * cfg.n_layers * cfg.n_heads * cfg.max_seq * cfg.head_dim() * 4;
        assert_eq!(kv.memory_bytes(), want);
        assert_eq!(kv.total_blocks(), cfg.max_seq.div_ceil(kv.block_tokens()));
    }

    /// The paged layout is invisible to the outputs: any block size,
    /// with or without prefix sharing, reproduces the
    /// contiguous-equivalent stream token for token.
    #[test]
    fn paged_streams_are_block_size_invariant() {
        let (m, _) = tiny_native(2);
        let prompt: Vec<i32> = (0..23).map(|i| (i * 11 + 3) % 256).collect();
        let reference =
            stream_with_layout(&m, KvLayout::contiguous(&m.config), &prompt, 6);
        for bt in [1usize, 3, 4, 7, 16, 64] {
            for sharing in [false, true] {
                let layout = KvLayout {
                    block_tokens: bt,
                    total_blocks: None,
                    prefix_sharing: sharing,
                    kv_bits: None,
                };
                let got = stream_with_layout(&m, layout, &prompt, 6);
                assert_eq!(
                    got, reference,
                    "stream diverged at block_tokens={} sharing={}",
                    bt, sharing
                );
            }
        }
    }

    /// Shared-prefix reuse: a second slot with the same prompt maps the
    /// registered prefix blocks (counted as hits), skips that prefill
    /// compute, and still produces a bit-identical stream.
    #[test]
    fn shared_prefix_reuse_is_bit_identical_and_counted() {
        let (m, _) = tiny_native(2);
        // 3 full blocks + a partial tail at block_tokens = 4.
        let prompt: Vec<i32> = (0..14).map(|i| (i * 7 + 1) % 256).collect();
        let layout = KvLayout {
            block_tokens: 4,
            total_blocks: None,
            prefix_sharing: true,
            kv_bits: None,
        };
        let reference = stream_with_layout(
            &m,
            KvLayout::contiguous(&m.config),
            &prompt,
            5,
        );

        let mut kv = KvCache::with_layout(&m.config, 2, layout);
        let mut last_a = m.prefill_slot(&mut kv, 0, &prompt).unwrap();
        assert_eq!(kv.stats().prefix_hit_blocks, 0, "first prefill cannot hit");
        let mut last_b = m.prefill_slot(&mut kv, 1, &prompt).unwrap();
        let stats = kv.stats();
        assert_eq!(stats.prefix_hit_blocks, 3, "3 full blocks should be reused");
        assert_eq!(stats.prefix_hit_tokens, 12);
        kv.debug_validate();
        let (mut got_a, mut got_b) = (vec![last_a], vec![last_b]);
        for _ in 0..4 {
            let next = m.decode_slots(&mut kv, &[last_a, last_b], &[0, 1]).unwrap();
            last_a = next[0];
            last_b = next[1];
            got_a.push(last_a);
            got_b.push(last_b);
            kv.debug_validate();
        }
        let mut want = vec![m.prefill(&[prompt.clone()]).unwrap().0[0]];
        want.extend_from_slice(&reference[..4]);
        assert_eq!(got_a, want);
        assert_eq!(got_b, want);
    }

    /// A prompt that is exactly full blocks and fully registered: the
    /// reuse keeps every shared block, recomputes only the final token,
    /// and that write copy-on-write forks the shared tail block.
    #[test]
    fn full_prompt_reuse_forks_on_write() {
        let (m, _) = tiny_native(1);
        let prompt: Vec<i32> = (0..12).map(|i| (i * 5 + 2) % 256).collect(); // 3 × bt=4
        let layout = KvLayout {
            block_tokens: 4,
            total_blocks: None,
            prefix_sharing: true,
            kv_bits: None,
        };
        let reference =
            stream_with_layout(&m, KvLayout::contiguous(&m.config), &prompt, 4);

        let mut kv = KvCache::with_layout(&m.config, 1, layout);
        let _ = m.prefill_slot(&mut kv, 0, &prompt).unwrap();
        kv.free_slot(0); // blocks survive in the registry
        kv.debug_validate();
        let mut last = m.prefill_slot(&mut kv, 0, &prompt).unwrap();
        let stats = kv.stats();
        assert_eq!(stats.prefix_hit_blocks, 3);
        assert_eq!(stats.prefix_hit_tokens, 11, "last token always recomputed");
        assert!(stats.cow_forks >= 1, "write into the shared tail must fork");
        kv.debug_validate();
        let mut got = Vec::new();
        for _ in 0..4 {
            last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
            got.push(last);
            kv.debug_validate();
        }
        assert_eq!(got, reference);
    }

    /// An overcommitted pool: eviction recycles registry-only blocks
    /// under pressure, and true exhaustion is a clean error, not a
    /// panic or corruption.
    #[test]
    fn overcommitted_pool_evicts_then_errors_cleanly() {
        let (m, _) = tiny_native(1);
        let layout = KvLayout {
            block_tokens: 4,
            total_blocks: Some(4),
            prefix_sharing: true,
            kv_bits: None,
        };
        let mut kv = KvCache::with_layout(&m.config, 2, layout);
        // Fill the registry via a retired 8-token prompt (2 blocks).
        let _ = m.prefill_slot(&mut kv, 0, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        kv.free_slot(0);
        assert_eq!(kv.stats().registered_blocks, 2);
        // A different 12-token prompt needs 3 blocks: 2 free + 1 evicted.
        let mut last = m
            .prefill_slot(&mut kv, 0, &[9, 9, 9, 9, 8, 8, 8, 8, 7, 7, 7, 7])
            .unwrap();
        assert!(kv.stats().blocks_evicted >= 1, "pressure must evict registry blocks");
        kv.debug_validate();
        // Decode to exhaustion: 4 blocks × 4 tokens = 16 positions total,
        // 12 used and nothing left to steal once the registry is empty.
        let mut err = None;
        for _ in 0..8 {
            match m.decode_slots(&mut kv, &[last], &[0]) {
                Ok(next) => last = next[0],
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        let err = err.expect("pool must exhaust");
        assert!(format!("{:#}", err).contains("exhausted"), "got: {:#}", err);
        kv.debug_validate();
    }

    /// Reservations clamp to the allocatable headroom and make the
    /// granted tokens immune to a competing slot's allocations.
    #[test]
    fn reservation_guarantees_decode_headroom() {
        let (m, _) = tiny_native(1);
        let layout = KvLayout {
            block_tokens: 4,
            total_blocks: Some(4),
            prefix_sharing: false,
            kv_bits: None,
        };
        let mut kv = KvCache::with_layout(&m.config, 2, layout);
        let mut last = m.prefill_slot(&mut kv, 0, &[1, 2, 3, 4, 5, 6]).unwrap();
        // 6 tokens in 2 blocks: slack 2, 2 free blocks → 10 allocatable.
        assert_eq!(kv.reserve(0, 64), 10);
        // Total semantics: a repeat call reports the same guarantee
        // instead of stacking a second reservation.
        assert_eq!(kv.reserve(0, 64), 10);
        kv.debug_validate();
        // A competitor cannot prefill into the reserved blocks…
        assert!(m.prefill_slot(&mut kv, 1, &[7, 7, 7, 7, 7]).is_err());
        kv.debug_validate();
        // …while the reserved slot decodes its full grant.
        for _ in 0..10 {
            last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
            kv.debug_validate();
        }
        // Retirement returns reservation and blocks to the pool.
        kv.free_slot(0);
        kv.debug_validate();
        assert_eq!(kv.admission_free_blocks(), 4);
        let _ = m.prefill_slot(&mut kv, 1, &[7, 7, 7, 7, 7]).unwrap();
        kv.debug_validate();
    }

    /// Reservations can tap registry-only blocks by evicting them —
    /// the same headroom `admission_free_blocks` advertises, so a
    /// request admitted on evictable headroom is never clamped to zero.
    #[test]
    fn reserve_evicts_registry_blocks_for_headroom() {
        let (m, _) = tiny_native(1);
        let layout = KvLayout {
            block_tokens: 4,
            total_blocks: Some(4),
            prefix_sharing: true,
            kv_bits: None,
        };
        let mut kv = KvCache::with_layout(&m.config, 2, layout);
        // Retired 8-token prompt: free list 2, registry 2 (evictable).
        let _ = m.prefill_slot(&mut kv, 0, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        kv.free_slot(0);
        // A different prompt drains the free list (its own registered
        // blocks are slot-held, refcount 2 — not evictable).
        let mut last = m
            .prefill_slot(&mut kv, 1, &[9, 9, 9, 9, 8, 8, 8, 8])
            .unwrap();
        assert_eq!(kv.admission_free_blocks(), 2, "only the old registry blocks remain");
        // The reservation must evict them rather than clamp to zero.
        assert_eq!(kv.reserve(1, 100), 8);
        assert_eq!(kv.stats().blocks_evicted, 2);
        kv.debug_validate();
        for _ in 0..8 {
            last = m.decode_slots(&mut kv, &[last], &[1]).unwrap()[0];
            kv.debug_validate();
        }
        // Pool truly full now: nothing further is grantable.
        assert_eq!(kv.reserve(1, 1), 0);
    }

    /// The prefix registry survives slot retirement: a recurring system
    /// prompt keeps hitting across otherwise unrelated requests.
    #[test]
    fn prefix_registry_survives_retirement() {
        let (m, _) = tiny_native(1);
        let layout = KvLayout {
            block_tokens: 4,
            total_blocks: None,
            prefix_sharing: true,
            kv_bits: None,
        };
        let mut kv = KvCache::with_layout(&m.config, 1, layout);
        let system: Vec<i32> = (0..8).map(|i| 64 + i).collect();
        for round in 0..3 {
            let mut prompt = system.clone();
            prompt.extend_from_slice(&[100 + round, 101 + round]);
            let _ = m.prefill_slot(&mut kv, 0, &prompt).unwrap();
            kv.free_slot(0);
            kv.debug_validate();
        }
        // Rounds 2 and 3 each reuse the 2 system-prompt blocks.
        assert_eq!(kv.stats().prefix_hit_blocks, 4);
        assert_eq!(kv.stats().prefix_hit_tokens, 16);
    }

    #[test]
    fn pack_unpack_roundtrips_every_width() {
        for bits in 1..=8u32 {
            let n = 37; // odd count so codes straddle byte boundaries
            let mask = (1u32 << bits) - 1;
            let vals: Vec<u32> = (0..n as u32).map(|i| (i * 2654435761) & mask).collect();
            let mut buf = vec![0u8; (n * bits as usize).div_ceil(8)];
            for (i, &v) in vals.iter().enumerate() {
                pack_code(&mut buf, i, bits, v);
            }
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(unpack_code(&buf, i, bits), v, "bits={} i={}", bits, i);
            }
        }
    }

    #[test]
    fn quantize_plane_roundtrip_honors_channel_error_bound() {
        // Per channel the inlier grid spans at most the channel's full
        // range, so round-to-nearest error is bounded by half a step of
        // the *full* range; outliers reconstruct exactly.
        let (heads, bt, hd) = (2, 16, 4);
        let mut rng = crate::util::prng::Rng::new(0xC0DE);
        for bits in [4u32, 8] {
            let src: Vec<f32> = (0..heads * bt * hd)
                .map(|_| (rng.below(2000) as f32 - 1000.0) / 100.0)
                .collect();
            let qp = quantize_plane(&src, heads, bt, hd, bits);
            let mut dst = vec![0.0f32; src.len()];
            dequantize_plane(&qp, heads, bt, hd, bits, Tier::Scalar, &mut dst);
            for h in 0..heads {
                for d in 0..hd {
                    let ch: Vec<f32> =
                        (0..bt).map(|t| src[h * bt * hd + t * hd + d]).collect();
                    let (lo, hi) = crate::quant::min_max(&ch);
                    let bound = (hi - lo) / (2.0 * ((1u32 << bits) - 1) as f32) + 1e-5;
                    for t in 0..bt {
                        let i = h * bt * hd + t * hd + d;
                        assert!(
                            (src[i] - dst[i]).abs() <= bound,
                            "bits={} ch=({},{}) t={}: |{} - {}| > {}",
                            bits, h, d, t, src[i], dst[i], bound
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn quantize_plane_keeps_single_outlier_exact() {
        // A constant channel with one spike is the range-halving rule's
        // best case: the spike goes to the index (exact), the inliers
        // collapse to a degenerate grid (also exact).
        let (heads, bt, hd) = (1, 8, 2);
        let mut src = vec![1.0f32; heads * bt * hd];
        src[3 * hd] = 50.0; // channel (0,0), token 3
        let qp = quantize_plane(&src, heads, bt, hd, 4);
        assert_eq!(qp.outlier_vals, vec![50.0]);
        let mut dst = vec![0.0f32; src.len()];
        dequantize_plane(&qp, heads, bt, hd, 4, Tier::Scalar, &mut dst);
        assert_eq!(src, dst, "spike + constant inliers reconstruct exactly");
    }

    #[test]
    fn quantize_plane_is_content_deterministic() {
        // The invariance contract (DESIGN.md §12): payloads depend only
        // on the block's float values, never on allocation history.
        let (heads, bt, hd) = (2, 16, 4);
        let mut rng = crate::util::prng::Rng::new(0x5EED);
        let src: Vec<f32> =
            (0..heads * bt * hd).map(|_| rng.below(1000) as f32 / 33.0).collect();
        let a = quantize_plane(&src, heads, bt, hd, 4);
        let b = quantize_plane(&src, heads, bt, hd, 4);
        assert_eq!(a.codes, b.codes);
        assert_eq!(a.ranges, b.ranges);
        assert_eq!(a.outlier_vals, b.outlier_vals);
        assert_eq!(a.outliers, b.outliers);
    }

    /// With kv_bits on, a stream must be bit-identical to itself across
    /// pool widths and block sizes (the schedule-invariance contract),
    /// and with kv_bits off, bit-identical to the pre-§12 f32 cache.
    #[test]
    fn quantized_stream_is_self_consistent_and_off_matches_f32() {
        let (m1, _) = tiny_native(1);
        let (m4, _) = tiny_native(4);
        let prompt: Vec<i32> = (0..10).map(|i| 40 + i).collect();
        let f32_layout =
            KvLayout { block_tokens: 4, total_blocks: None, prefix_sharing: true, kv_bits: None };
        let q_layout = KvLayout { kv_bits: Some(8), ..f32_layout };
        let base = stream_with_layout(&m1, f32_layout, &prompt, 6);
        let off = stream_with_layout(&m4, f32_layout, &prompt, 6);
        assert_eq!(base, off, "kv off is pool-width invariant");
        let q1 = stream_with_layout(&m1, q_layout, &prompt, 6);
        let q4 = stream_with_layout(&m4, q_layout, &prompt, 6);
        assert_eq!(q1, q4, "quantized stream is pool-width invariant");
    }

    /// Decode across a quantized block boundary: once a block fills it
    /// leaves the hot tail and later reads go through dequant scratch.
    #[test]
    fn blocks_quantize_behind_the_hot_tail() {
        let (m, _) = tiny_native(1);
        let layout = KvLayout {
            block_tokens: 4,
            total_blocks: None,
            prefix_sharing: false,
            kv_bits: Some(4),
        };
        let mut kv = KvCache::with_layout(&m.config, 1, layout);
        let mut last = m.prefill_slot(&mut kv, 0, &[7, 7, 7, 7, 8, 8]).unwrap();
        kv.debug_validate();
        // Prefill covered 6 positions: block 0 full (quantized), block 1
        // is the hot tail.
        assert!(kv.debug_block_is_quantized(0, 0));
        assert!(!kv.debug_block_is_quantized(0, 1));
        let s = kv.stats();
        assert_eq!(s.quantized_blocks, 1);
        assert_eq!(s.blocks_quantized, 1);
        // K+V, both layers, one block of bt×d_model f32 values each.
        let f32_block = 2 * m.config.n_layers * kv.block_tokens() * m.config.d_model * 4;
        assert!(
            s.kv_resident_bytes < 2 * f32_block,
            "1 quantized + 1 f32 block must undercut 2 f32 blocks ({} vs {})",
            s.kv_resident_bytes,
            2 * f32_block
        );
        assert_eq!(s.resident_tokens, 6);
        for _ in 0..4 {
            last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
            kv.debug_validate();
        }
        // pos = 10: blocks 0 and 1 quantized, block 2 is the tail.
        assert!(kv.debug_block_is_quantized(0, 1));
        assert!(!kv.debug_block_is_quantized(0, 2));
        assert_eq!(kv.stats().blocks_quantized, 2);
        let _ = last;
    }
}

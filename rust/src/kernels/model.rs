//! Native CPU transformer forward over fused quantized planes
//! (DESIGN.md §8).
//!
//! [`NativeModel`] mirrors the Llama-mini architecture the python side
//! AOT-compiles (`python/compile/model.py`: RMSNorm → RoPE multi-head
//! attention → RMSNorm → SwiGLU, byte vocab), but every projection is a
//! fused [`gemv::gemm_mt`](crate::kernels::gemm_mt) **straight off the
//! quantized [`RuntimePlane`]** — no f32 weight plane ever exists. Dense
//! side tensors (embeddings, norms, `lm_head`) stay f32; they are <2 %
//! of the weight bytes.
//!
//! This is the deployment story the paper's intro argues for: the
//! serving working set is codes + codebooks (≈¼ of f32), and the
//! per-token cost is a memory-bound sweep of those bytes. The PJRT
//! backend remains the reference executor; this one trades its compiled
//! graphs for zero Python/XLA dependence at request time.

use crate::coordinator::backend::argmax_rows;
use crate::icquant::runtime::RuntimePlane;
use crate::kernels::gemm_mt;
use crate::model::ModelConfig;
use crate::store::StoredModel;
use crate::util::tensor::Matrix;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// RoPE base frequency (python `ModelConfig.rope_theta`).
const ROPE_THETA: f32 = 10000.0;
/// RMSNorm epsilon (python `ModelConfig.norm_eps`).
const NORM_EPS: f32 = 1e-5;

/// One transformer block's weights: quantized projections (shared with
/// the decode cache) + dense norms.
struct BlockWeights {
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    wq: Arc<RuntimePlane>,
    wk: Arc<RuntimePlane>,
    wv: Arc<RuntimePlane>,
    wo: Arc<RuntimePlane>,
    w_gate: Arc<RuntimePlane>,
    w_up: Arc<RuntimePlane>,
    w_down: Arc<RuntimePlane>,
}

/// KV cache for one in-flight batch: per layer, `[B, H, max_seq, hd]`
/// flat f32 — plain host memory, unlike the PJRT path's device literals.
pub struct KvCache {
    batch: usize,
    /// Positions cached so far (the next token writes at this index).
    pub len: usize,
    max_seq: usize,
    n_heads: usize,
    head_dim: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl KvCache {
    fn new(cfg: &ModelConfig, batch: usize) -> KvCache {
        let per_layer = batch * cfg.n_heads * cfg.max_seq * cfg.head_dim();
        KvCache {
            batch,
            len: 0,
            max_seq: cfg.max_seq,
            n_heads: cfg.n_heads,
            head_dim: cfg.head_dim(),
            k: (0..cfg.n_layers).map(|_| vec![0.0; per_layer]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; per_layer]).collect(),
        }
    }

    #[inline]
    fn idx(&self, b: usize, head: usize, pos: usize) -> usize {
        ((b * self.n_heads + head) * self.max_seq + pos) * self.head_dim
    }

    /// Append `seq` new positions (starting at `pos0`) from per-token
    /// projection outputs `k`/`v` of shape `(batch·seq × d_model)`.
    fn store(&mut self, layer: usize, seq: usize, pos0: usize, k: &Matrix, v: &Matrix) {
        let hd = self.head_dim;
        for b in 0..self.batch {
            for t in 0..seq {
                let krow = k.row(b * seq + t);
                let vrow = v.row(b * seq + t);
                for head in 0..self.n_heads {
                    let at = self.idx(b, head, pos0 + t);
                    self.k[layer][at..at + hd]
                        .copy_from_slice(&krow[head * hd..(head + 1) * hd]);
                    self.v[layer][at..at + hd]
                        .copy_from_slice(&vrow[head * hd..(head + 1) * hd]);
                }
            }
        }
    }

    #[inline]
    fn k_at(&self, layer: usize, b: usize, head: usize, pos: usize) -> &[f32] {
        let at = self.idx(b, head, pos);
        &self.k[layer][at..at + self.head_dim]
    }

    #[inline]
    fn v_at(&self, layer: usize, b: usize, head: usize, pos: usize) -> &[f32] {
        let at = self.idx(b, head, pos);
        &self.v[layer][at..at + self.head_dim]
    }

    /// Host bytes held by this cache (both tensors, all layers).
    pub fn memory_bytes(&self) -> usize {
        (self.k.iter().map(|l| l.len()).sum::<usize>()
            + self.v.iter().map(|l| l.len()).sum::<usize>())
            * 4
    }
}

/// The native-kernel model: quantized projections resident as fused
/// runtime planes, dense side tensors as f32.
pub struct NativeModel {
    pub config: ModelConfig,
    /// Worker threads for the fused GEMMs (≥1).
    pub threads: usize,
    tok_emb: Matrix,
    lm_head: Matrix,
    final_norm: Vec<f32>,
    blocks: Vec<BlockWeights>,
    /// RoPE frequencies `θ^(-j/half)` for `j in 0..head_dim/2`,
    /// precomputed once (they are position-independent).
    rope_inv_freq: Vec<f32>,
}

impl NativeModel {
    /// Assemble from an opened container: projections come through the
    /// store's shared [`crate::store::DecodeCache`] (one fused decode per
    /// layer, shared with every other consumer of the artifact), dense
    /// tensors are copied out. `threads` sizes the kernel fan-out
    /// (0 ⇒ all available cores).
    pub fn from_stored(stored: &StoredModel, threads: usize) -> Result<NativeModel> {
        let threads = if threads == 0 { crate::kernels::available_threads() } else { threads };
        let config = stored
            .config
            .clone()
            .context("container carries no model config; cannot build a native model")?;
        ensure!(
            config.d_model % config.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            config.d_model,
            config.n_heads
        );
        ensure!(config.head_dim() % 2 == 0, "RoPE needs an even head_dim");
        let dense_mat = |name: &str| -> Result<Matrix> {
            let (shape, data) = stored.dense(name)?;
            ensure!(shape.len() == 2, "{} is not 2-D", name);
            Ok(Matrix::from_vec(shape[0], shape[1], data.to_vec()))
        };
        let dense_vec = |name: &str, want: usize| -> Result<Vec<f32>> {
            let (_, data) = stored.dense(name)?;
            ensure!(data.len() == want, "{}: expected {} values, found {}", name, want, data.len());
            Ok(data.to_vec())
        };
        let plane = |name: &str, rows: usize, cols: usize| -> Result<Arc<RuntimePlane>> {
            let p = stored.runtime_plane(name)?;
            ensure!(
                (p.rows, p.cols) == (rows, cols),
                "{}: expected {}x{}, container holds {}x{}",
                name,
                rows,
                cols,
                p.rows,
                p.cols
            );
            Ok(p)
        };

        let d = config.d_model;
        let ff = config.d_ff;
        let mut blocks = Vec::with_capacity(config.n_layers);
        for i in 0..config.n_layers {
            blocks.push(BlockWeights {
                attn_norm: dense_vec(&format!("l{}.attn_norm", i), d)?,
                mlp_norm: dense_vec(&format!("l{}.mlp_norm", i), d)?,
                wq: plane(&format!("l{}.wq", i), d, d)?,
                wk: plane(&format!("l{}.wk", i), d, d)?,
                wv: plane(&format!("l{}.wv", i), d, d)?,
                wo: plane(&format!("l{}.wo", i), d, d)?,
                w_gate: plane(&format!("l{}.w_gate", i), ff, d)?,
                w_up: plane(&format!("l{}.w_up", i), ff, d)?,
                w_down: plane(&format!("l{}.w_down", i), d, ff)?,
            });
        }
        let tok_emb = dense_mat("tok_emb")?;
        let lm_head = dense_mat("lm_head")?;
        ensure!(
            (tok_emb.rows, tok_emb.cols) == (config.vocab, d),
            "tok_emb shape mismatch"
        );
        ensure!(
            (lm_head.rows, lm_head.cols) == (config.vocab, d),
            "lm_head shape mismatch"
        );
        let half = config.head_dim() / 2;
        let rope_inv_freq = (0..half)
            .map(|j| ROPE_THETA.powf(-(j as f32) / half as f32))
            .collect();
        Ok(NativeModel {
            config,
            threads: threads.max(1),
            tok_emb,
            lm_head,
            final_norm: dense_vec("final_norm", d)?,
            blocks,
            rope_inv_freq,
        })
    }

    /// Resident weight bytes of the quantized planes (codes + per-row
    /// codebooks) — the serving working set the fused kernels stream.
    pub fn quantized_bytes(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| {
                [&b.wq, &b.wk, &b.wv, &b.wo, &b.w_gate, &b.w_up, &b.w_down]
            })
            .map(|p| p.memory_bytes())
            .sum()
    }

    /// What the same projections would occupy dequantized to f32.
    pub fn dequantized_bytes(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| {
                [&b.wq, &b.wk, &b.wv, &b.wo, &b.w_gate, &b.w_up, &b.w_down]
            })
            .map(|p| p.rows * p.cols * 4)
            .sum()
    }

    /// Prompt pass for a batch of equal-length prompts: fills a fresh KV
    /// cache and returns the last-position token ids (greedy).
    pub fn prefill(&self, prompts: &[Vec<i32>]) -> Result<(Vec<i32>, KvCache)> {
        let batch = prompts.len();
        ensure!(batch > 0, "empty batch");
        let seq = prompts[0].len();
        ensure!(seq > 0, "empty prompt");
        for p in prompts {
            ensure!(p.len() == seq, "prompts not normalized to one length");
        }
        ensure!(seq <= self.config.max_seq, "prompt exceeds max_seq");
        let mut tokens = Vec::with_capacity(batch * seq);
        for p in prompts {
            tokens.extend_from_slice(p);
        }
        let mut kv = KvCache::new(&self.config, batch);
        let logits = self.forward(&tokens, batch, seq, &mut kv)?;
        Ok((argmax_rows(&logits, batch), kv))
    }

    /// One greedy decode step: appends a position to the cache, returns
    /// the next token per sequence.
    pub fn decode_step(&self, kv: &mut KvCache, last_tokens: &[i32]) -> Result<Vec<i32>> {
        ensure!(last_tokens.len() == kv.batch, "token/batch mismatch");
        ensure!(kv.len < self.config.max_seq, "KV cache exhausted");
        let logits = self.forward(last_tokens, kv.batch, 1, kv)?;
        Ok(argmax_rows(&logits, kv.batch))
    }

    /// Core block-parallel forward: `tokens` is `(batch × seq)` row-major
    /// starting at position `kv.len`; returns last-position logits
    /// `(batch × vocab)` and advances the cache.
    fn forward(
        &self,
        tokens: &[i32],
        batch: usize,
        seq: usize,
        kv: &mut KvCache,
    ) -> Result<Vec<f32>> {
        let cfg = &self.config;
        let (d, hd, heads) = (cfg.d_model, cfg.head_dim(), cfg.n_heads);
        let pos0 = kv.len;
        ensure!(pos0 + seq <= cfg.max_seq, "KV cache overflow");
        ensure!(kv.batch == batch, "KV cache batch mismatch");
        let bs = batch * seq;

        // Token embeddings (out-of-range ids are clamped into the byte
        // vocab rather than panicking on hostile input).
        let mut x = Matrix::zeros(bs, d);
        for (i, &t) in tokens.iter().enumerate() {
            let id = (t.max(0) as usize).min(cfg.vocab - 1);
            x.row_mut(i).copy_from_slice(self.tok_emb.row(id));
        }

        let scale = 1.0 / (hd as f32).sqrt();
        for (layer, bw) in self.blocks.iter().enumerate() {
            // --- attention ---------------------------------------------
            let h = rmsnormed(&x, &bw.attn_norm);
            let mut q = Matrix::zeros(bs, d);
            let mut k = Matrix::zeros(bs, d);
            let mut v = Matrix::zeros(bs, d);
            gemm_mt(&bw.wq, &h, &mut q, self.threads);
            gemm_mt(&bw.wk, &h, &mut k, self.threads);
            gemm_mt(&bw.wv, &h, &mut v, self.threads);
            for b in 0..batch {
                for t in 0..seq {
                    let row = b * seq + t;
                    apply_rope(q.row_mut(row), heads, hd, pos0 + t, &self.rope_inv_freq);
                    apply_rope(k.row_mut(row), heads, hd, pos0 + t, &self.rope_inv_freq);
                }
            }
            kv.store(layer, seq, pos0, &k, &v);

            let mut attn = Matrix::zeros(bs, d);
            let mut scores = vec![0.0f32; pos0 + seq];
            for b in 0..batch {
                for head in 0..heads {
                    for t in 0..seq {
                        let row = b * seq + t;
                        let span = pos0 + t + 1; // causal: positions 0..=pos
                        let qh = &q.row(row)[head * hd..(head + 1) * hd];
                        for (p, s) in scores[..span].iter_mut().enumerate() {
                            *s = dot(qh, kv.k_at(layer, b, head, p)) * scale;
                        }
                        softmax(&mut scores[..span]);
                        let out = &mut attn.row_mut(row)[head * hd..(head + 1) * hd];
                        for (p, &w) in scores[..span].iter().enumerate() {
                            for (o, kvv) in out.iter_mut().zip(kv.v_at(layer, b, head, p)) {
                                *o += w * *kvv;
                            }
                        }
                    }
                }
            }
            let mut o = Matrix::zeros(bs, d);
            gemm_mt(&bw.wo, &attn, &mut o, self.threads);
            add_assign(&mut x, &o);

            // --- SwiGLU MLP --------------------------------------------
            let h = rmsnormed(&x, &bw.mlp_norm);
            let mut gate = Matrix::zeros(bs, cfg.d_ff);
            let mut up = Matrix::zeros(bs, cfg.d_ff);
            gemm_mt(&bw.w_gate, &h, &mut gate, self.threads);
            gemm_mt(&bw.w_up, &h, &mut up, self.threads);
            for (g, u) in gate.data.iter_mut().zip(&up.data) {
                *g = silu(*g) * *u;
            }
            let mut down = Matrix::zeros(bs, d);
            gemm_mt(&bw.w_down, &gate, &mut down, self.threads);
            add_assign(&mut x, &down);
        }
        kv.len = pos0 + seq;

        // Final norm + lm_head logits, last position per sequence only.
        let mut logits = vec![0.0f32; batch * cfg.vocab];
        let mut hrow = vec![0.0f32; d];
        for b in 0..batch {
            let xrow = x.row(b * seq + (seq - 1));
            rmsnorm_into(xrow, &self.final_norm, &mut hrow);
            let out = &mut logits[b * cfg.vocab..(b + 1) * cfg.vocab];
            for (vi, o) in out.iter_mut().enumerate() {
                *o = dot(self.lm_head.row(vi), &hrow);
            }
        }
        Ok(logits)
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn add_assign(x: &mut Matrix, y: &Matrix) {
    debug_assert_eq!((x.rows, x.cols), (y.rows, y.cols));
    for (a, b) in x.data.iter_mut().zip(&y.data) {
        *a += *b;
    }
}

/// RMSNorm one row into a caller buffer.
fn rmsnorm_into(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + NORM_EPS).sqrt();
    for ((o, xv), wv) in out.iter_mut().zip(x).zip(w) {
        *o = xv * r * wv;
    }
}

/// Row-wise RMSNorm of a whole activation matrix.
fn rmsnormed(x: &Matrix, w: &[f32]) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        rmsnorm_into(x.row(r), w, out.row_mut(r));
    }
    out
}

/// In-place RoPE for one `(heads × hd)` activation row at `pos`
/// (half-split rotation, matching python `_apply_rope`).
/// `inv_freq` is the precomputed `θ^(-j/half)` table (`hd/2` entries).
fn apply_rope(row: &mut [f32], heads: usize, hd: usize, pos: usize, inv_freq: &[f32]) {
    let half = hd / 2;
    debug_assert_eq!(inv_freq.len(), half);
    for head in 0..heads {
        let h = &mut row[head * hd..(head + 1) * hd];
        for (j, &freq) in inv_freq.iter().enumerate() {
            let ang = pos as f32 * freq;
            let (sin, cos) = ang.sin_cos();
            let (a, b) = (h[j], h[j + half]);
            h[j] = a * cos - b * sin;
            h[j + half] = a * sin + b * cos;
        }
    }
}

/// Numerically-stable in-place softmax.
fn softmax(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::IcqConfig;
    use crate::quant::QuantizerKind;
    use crate::store::{synth_model, DecodeCache, StoredModel};
    use crate::synthzoo::FamilySpec;

    /// A deliberately tiny family so debug-mode tests stay fast.
    fn tiny_family() -> FamilySpec {
        FamilySpec {
            name: "tiny-test",
            d_model: 32,
            d_ff: 64,
            n_blocks: 2,
            tail_frac: 0.02,
            tail_scale: 2.5,
            oproj_hot: 0.5,
            seed: 0x7157,
        }
    }

    fn tiny_native(threads: usize) -> (NativeModel, Arc<DecodeCache>) {
        let cfg = IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::Rtn,
        };
        let model = synth_model(&tiny_family(), &cfg, None).unwrap();
        let cache = Arc::new(DecodeCache::new(64 << 20));
        let stored = StoredModel::from_model(model, cache.clone(), "native-test");
        (NativeModel::from_stored(&stored, threads).unwrap(), cache)
    }

    #[test]
    fn prefill_then_decode_produces_tokens_in_vocab() {
        let (m, _) = tiny_native(1);
        let prompts = vec![vec![72, 101, 108, 108, 111, 32, 119, 111], vec![84, 104, 101, 32, 113, 117, 105, 99]];
        let (first, mut kv) = m.prefill(&prompts).unwrap();
        assert_eq!(first.len(), 2);
        assert_eq!(kv.len, 8);
        let mut last = first;
        for step in 0..4 {
            last = m.decode_step(&mut kv, &last).unwrap();
            assert_eq!(kv.len, 9 + step);
            for &t in &last {
                assert!((0..m.config.vocab as i32).contains(&t));
            }
        }
    }

    #[test]
    fn decode_is_deterministic_and_thread_count_invariant() {
        // The fused kernels are bit-identical across thread counts, so
        // the whole generation must be too.
        let (m1, _) = tiny_native(1);
        let (m4, _) = tiny_native(4);
        let prompts = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let (t1, mut kv1) = m1.prefill(&prompts).unwrap();
        let (t4, mut kv4) = m4.prefill(&prompts).unwrap();
        assert_eq!(t1, t4);
        let (mut a, mut b) = (t1, t4);
        for _ in 0..5 {
            a = m1.decode_step(&mut kv1, &a).unwrap();
            b = m4.decode_step(&mut kv4, &b).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn incremental_decode_matches_full_prefill() {
        // Teacher-forcing consistency: prefill over [p0..p5] must leave
        // the model predicting the same next token as prefill over
        // [p0..p4] followed by one decode step feeding p5.
        let (m, _) = tiny_native(2);
        let full: Vec<i32> = vec![10, 20, 30, 40, 50, 60];
        let (next_full, _) = m.prefill(&[full.clone()]).unwrap();
        let (_, mut kv) = m.prefill(&[full[..5].to_vec()]).unwrap();
        let next_inc = m.decode_step(&mut kv, &[full[5]]).unwrap();
        assert_eq!(next_full, next_inc);
    }

    #[test]
    fn working_set_is_quantized_not_f32() {
        let (m, cache) = tiny_native(1);
        // At tiny widths the per-row codebooks are a large share; at LLM
        // widths the ratio approaches 4× (codes are 1 B vs 4 B f32).
        assert!(m.quantized_bytes() < m.dequantized_bytes());
        // Every projection plane is resident in the shared cache (codes
        // + codebooks), and the cache charged quantized bytes, not f32.
        assert!(cache.bytes_used() >= m.quantized_bytes());
        assert!(cache.bytes_used() < m.dequantized_bytes());
    }

    #[test]
    fn kv_cache_accounting() {
        let (m, _) = tiny_native(1);
        let (_, kv) = m.prefill(&[vec![1, 2, 3]]).unwrap();
        let cfg = &m.config;
        let want =
            2 * cfg.n_layers * cfg.n_heads * cfg.max_seq * cfg.head_dim() * 4;
        assert_eq!(kv.memory_bytes(), want);
    }
}

//! Native CPU transformer forward over fused quantized planes
//! (DESIGN.md §8).
//!
//! [`NativeModel`] mirrors the Llama-mini architecture the python side
//! AOT-compiles (`python/compile/model.py`: RMSNorm → RoPE multi-head
//! attention → RMSNorm → SwiGLU, byte vocab), but every projection is a
//! fused [`gemm_on`](crate::kernels::gemm_on) **straight off the
//! bit-packed quantized [`RuntimePlane`]**, dispatched onto the model's
//! persistent [`WorkerPool`] — no f32 weight plane ever exists and no
//! thread is spawned at request time. Dense side tensors (embeddings,
//! norms, `lm_head`) stay f32; they are <2 % of the weight bytes.
//!
//! The KV cache is **slot-addressed** (DESIGN.md §9): each of its lanes
//! tracks its own position, so the continuous-batching scheduler can
//! prefill one request into a freed lane ([`NativeModel::prefill_slot`])
//! and decode an arbitrary subset of lanes ([`NativeModel::decode_slots`])
//! while the rest of the batch is mid-generation. Lanes never attend
//! across each other, so a sequence's tokens are bit-identical whether it
//! runs alone, in a uniform batch, or interleaved with strangers.
//!
//! This is the deployment story the paper's intro argues for: the
//! serving working set is packed codes + codebooks (≈(n+1)/32 of f32 —
//! ~3 bits/weight at n=2), and the per-token cost is a memory-bound
//! sweep of those bytes. The PJRT
//! backend remains the reference executor; this one trades its compiled
//! graphs for zero Python/XLA dependence at request time.

use crate::coordinator::backend::argmax_rows;
use crate::icquant::runtime::RuntimePlane;
use crate::kernels::{gemm_on, WorkerPool};
use crate::model::ModelConfig;
use crate::store::StoredModel;
use crate::util::tensor::Matrix;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// RoPE base frequency (python `ModelConfig.rope_theta`).
const ROPE_THETA: f32 = 10000.0;
/// RMSNorm epsilon (python `ModelConfig.norm_eps`).
const NORM_EPS: f32 = 1e-5;

/// One transformer block's weights: quantized projections (shared with
/// the decode cache) + dense norms.
struct BlockWeights {
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    wq: Arc<RuntimePlane>,
    wk: Arc<RuntimePlane>,
    wv: Arc<RuntimePlane>,
    wo: Arc<RuntimePlane>,
    w_gate: Arc<RuntimePlane>,
    w_up: Arc<RuntimePlane>,
    w_down: Arc<RuntimePlane>,
}

/// Slot-addressed KV cache: per layer, `[slots, H, max_seq, hd]` flat
/// f32 — plain host memory, unlike the PJRT path's device literals.
///
/// Each slot holds one independent sequence and advances its own
/// [`pos`](KvCache::pos). Retiring a sequence is `free_slot` (a position
/// reset — no zeroing needed, since attention never reads past a slot's
/// position); the next occupant overwrites from position 0.
pub struct KvCache {
    slots: usize,
    max_seq: usize,
    n_heads: usize,
    head_dim: usize,
    /// Per-slot next-write position (0 = free/fresh).
    pos: Vec<usize>,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl KvCache {
    /// An empty cache with `slots` independent lanes.
    pub fn new(cfg: &ModelConfig, slots: usize) -> KvCache {
        let per_layer = slots * cfg.n_heads * cfg.max_seq * cfg.head_dim();
        KvCache {
            slots,
            max_seq: cfg.max_seq,
            n_heads: cfg.n_heads,
            head_dim: cfg.head_dim(),
            pos: vec![0; slots],
            k: (0..cfg.n_layers).map(|_| vec![0.0; per_layer]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; per_layer]).collect(),
        }
    }

    /// Number of KV lanes.
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Cached positions in `slot` (the next token writes at this index).
    pub fn pos(&self, slot: usize) -> usize {
        self.pos[slot]
    }

    /// Release `slot` for reuse by a new sequence. The lane's data is
    /// left in place — the position gate makes it unreachable, and the
    /// next `prefill_slot` overwrites from 0.
    pub fn free_slot(&mut self, slot: usize) {
        self.pos[slot] = 0;
    }

    #[inline]
    fn idx(&self, slot: usize, head: usize, pos: usize) -> usize {
        ((slot * self.n_heads + head) * self.max_seq + pos) * self.head_dim
    }

    /// Append `seq` new positions from per-token projection outputs
    /// `k`/`v` of shape `(len(slot_ids)·seq × d_model)`; lane `i` of the
    /// activation rows lands in cache slot `slot_ids[i]` starting at
    /// `starts[i]`.
    fn store(
        &mut self,
        layer: usize,
        slot_ids: &[usize],
        starts: &[usize],
        seq: usize,
        k: &Matrix,
        v: &Matrix,
    ) {
        let hd = self.head_dim;
        for (i, &slot) in slot_ids.iter().enumerate() {
            for t in 0..seq {
                let krow = k.row(i * seq + t);
                let vrow = v.row(i * seq + t);
                for head in 0..self.n_heads {
                    let at = self.idx(slot, head, starts[i] + t);
                    self.k[layer][at..at + hd]
                        .copy_from_slice(&krow[head * hd..(head + 1) * hd]);
                    self.v[layer][at..at + hd]
                        .copy_from_slice(&vrow[head * hd..(head + 1) * hd]);
                }
            }
        }
    }

    #[inline]
    fn k_at(&self, layer: usize, slot: usize, head: usize, pos: usize) -> &[f32] {
        let at = self.idx(slot, head, pos);
        &self.k[layer][at..at + self.head_dim]
    }

    #[inline]
    fn v_at(&self, layer: usize, slot: usize, head: usize, pos: usize) -> &[f32] {
        let at = self.idx(slot, head, pos);
        &self.v[layer][at..at + self.head_dim]
    }

    /// Host bytes held by this cache (both tensors, all layers).
    pub fn memory_bytes(&self) -> usize {
        (self.k.iter().map(|l| l.len()).sum::<usize>()
            + self.v.iter().map(|l| l.len()).sum::<usize>())
            * 4
    }
}

/// The native-kernel model: quantized projections resident as fused
/// runtime planes, dense side tensors as f32.
pub struct NativeModel {
    pub config: ModelConfig,
    /// Persistent worker pool every fused GEMM dispatches through —
    /// spawned once at construction, parked between tokens. No
    /// per-projection thread spawn survives on the decode path.
    pool: Arc<WorkerPool>,
    tok_emb: Matrix,
    lm_head: Matrix,
    final_norm: Vec<f32>,
    blocks: Vec<BlockWeights>,
    /// RoPE frequencies `θ^(-j/half)` for `j in 0..head_dim/2`,
    /// precomputed once (they are position-independent).
    rope_inv_freq: Vec<f32>,
}

impl NativeModel {
    /// Assemble from an opened container: projections come through the
    /// store's shared [`crate::store::DecodeCache`] (one fused decode per
    /// layer, shared with every other consumer of the artifact), dense
    /// tensors are copied out. `threads` sizes the model's persistent
    /// kernel pool (0 ⇒ all available cores).
    pub fn from_stored(stored: &StoredModel, threads: usize) -> Result<NativeModel> {
        Self::from_stored_with_pool(stored, Arc::new(WorkerPool::new(threads)))
    }

    /// [`Self::from_stored`] sharing an existing kernel pool — several
    /// models (or a model plus ad-hoc kernel callers) can dispatch onto
    /// one set of parked workers.
    pub fn from_stored_with_pool(
        stored: &StoredModel,
        pool: Arc<WorkerPool>,
    ) -> Result<NativeModel> {
        let config = stored
            .config
            .clone()
            .context("container carries no model config; cannot build a native model")?;
        ensure!(
            config.d_model % config.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            config.d_model,
            config.n_heads
        );
        ensure!(config.head_dim() % 2 == 0, "RoPE needs an even head_dim");
        let dense_mat = |name: &str| -> Result<Matrix> {
            let (shape, data) = stored.dense(name)?;
            ensure!(shape.len() == 2, "{} is not 2-D", name);
            Ok(Matrix::from_vec(shape[0], shape[1], data.to_vec()))
        };
        let dense_vec = |name: &str, want: usize| -> Result<Vec<f32>> {
            let (_, data) = stored.dense(name)?;
            ensure!(data.len() == want, "{}: expected {} values, found {}", name, want, data.len());
            Ok(data.to_vec())
        };
        let plane = |name: &str, rows: usize, cols: usize| -> Result<Arc<RuntimePlane>> {
            let p = stored.runtime_plane(name)?;
            ensure!(
                (p.rows, p.cols) == (rows, cols),
                "{}: expected {}x{}, container holds {}x{}",
                name,
                rows,
                cols,
                p.rows,
                p.cols
            );
            Ok(p)
        };

        let d = config.d_model;
        let ff = config.d_ff;
        let mut blocks = Vec::with_capacity(config.n_layers);
        for i in 0..config.n_layers {
            blocks.push(BlockWeights {
                attn_norm: dense_vec(&format!("l{}.attn_norm", i), d)?,
                mlp_norm: dense_vec(&format!("l{}.mlp_norm", i), d)?,
                wq: plane(&format!("l{}.wq", i), d, d)?,
                wk: plane(&format!("l{}.wk", i), d, d)?,
                wv: plane(&format!("l{}.wv", i), d, d)?,
                wo: plane(&format!("l{}.wo", i), d, d)?,
                w_gate: plane(&format!("l{}.w_gate", i), ff, d)?,
                w_up: plane(&format!("l{}.w_up", i), ff, d)?,
                w_down: plane(&format!("l{}.w_down", i), d, ff)?,
            });
        }
        let tok_emb = dense_mat("tok_emb")?;
        let lm_head = dense_mat("lm_head")?;
        ensure!(
            (tok_emb.rows, tok_emb.cols) == (config.vocab, d),
            "tok_emb shape mismatch"
        );
        ensure!(
            (lm_head.rows, lm_head.cols) == (config.vocab, d),
            "lm_head shape mismatch"
        );
        let half = config.head_dim() / 2;
        let rope_inv_freq = (0..half)
            .map(|j| ROPE_THETA.powf(-(j as f32) / half as f32))
            .collect();
        Ok(NativeModel {
            config,
            pool,
            tok_emb,
            lm_head,
            final_norm: dense_vec("final_norm", d)?,
            blocks,
            rope_inv_freq,
        })
    }

    /// Executor width of the kernel pool (workers + caller).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The model's persistent kernel pool.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Resident weight bytes of the quantized planes (codes + per-row
    /// codebooks) — the serving working set the fused kernels stream.
    pub fn quantized_bytes(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| {
                [&b.wq, &b.wk, &b.wv, &b.wo, &b.w_gate, &b.w_up, &b.w_down]
            })
            .map(|p| p.memory_bytes())
            .sum()
    }

    /// What the same projections would occupy dequantized to f32.
    pub fn dequantized_bytes(&self) -> usize {
        self.blocks
            .iter()
            .flat_map(|b| {
                [&b.wq, &b.wk, &b.wv, &b.wo, &b.w_gate, &b.w_up, &b.w_down]
            })
            .map(|p| p.rows * p.cols * 4)
            .sum()
    }

    /// Prompt pass for a batch of equal-length prompts: fills a fresh KV
    /// cache (slot `i` ← prompt `i`) and returns the last-position token
    /// ids (greedy).
    pub fn prefill(&self, prompts: &[Vec<i32>]) -> Result<(Vec<i32>, KvCache)> {
        let batch = prompts.len();
        ensure!(batch > 0, "empty batch");
        let seq = prompts[0].len();
        ensure!(seq > 0, "empty prompt");
        for p in prompts {
            ensure!(p.len() == seq, "prompts not normalized to one length");
        }
        ensure!(seq <= self.config.max_seq, "prompt exceeds max_seq");
        let mut tokens = Vec::with_capacity(batch * seq);
        for p in prompts {
            tokens.extend_from_slice(p);
        }
        let mut kv = KvCache::new(&self.config, batch);
        let slot_ids: Vec<usize> = (0..batch).collect();
        let logits = self.forward_slots(&tokens, &slot_ids, seq, &mut kv)?;
        Ok((argmax_rows(&logits, batch), kv))
    }

    /// Prompt pass for **one** sequence into lane `slot` of an existing
    /// cache, while other lanes stay live — the continuous scheduler's
    /// admission path. The slot's previous occupant is discarded.
    /// Returns the first greedily sampled token.
    pub fn prefill_slot(&self, kv: &mut KvCache, slot: usize, prompt: &[i32]) -> Result<i32> {
        Ok(self.prefill_slots(kv, &[slot], prompt, prompt.len())?[0])
    }

    /// Prompt pass for **several** sequences at once, one per lane of
    /// `slot_ids` (ascending): `tokens` is `(len(slot_ids) × seq)`
    /// row-major, every prompt already normalized to `seq`. Each target
    /// lane's previous occupant is discarded. Returns the first greedily
    /// sampled token per lane. A batched admission decodes each weight
    /// block once for all lanes — k× less weight traffic than k
    /// single-slot prefills on this memory-bound path.
    pub fn prefill_slots(
        &self,
        kv: &mut KvCache,
        slot_ids: &[usize],
        tokens: &[i32],
        seq: usize,
    ) -> Result<Vec<i32>> {
        ensure!(!slot_ids.is_empty(), "empty admission");
        ensure!(seq > 0, "empty prompt");
        ensure!(seq <= self.config.max_seq, "prompt exceeds max_seq");
        ensure!(
            tokens.len() == slot_ids.len() * seq,
            "token buffer shape mismatch"
        );
        for &s in slot_ids {
            ensure!(s < kv.slots, "slot {} out of range ({} slots)", s, kv.slots);
        }
        for &s in slot_ids {
            kv.pos[s] = 0;
        }
        let logits = self.forward_slots(tokens, slot_ids, seq, kv)?;
        Ok(argmax_rows(&logits, slot_ids.len()))
    }

    /// One greedy decode step over every lane of the cache (uniform
    /// batch) — the wave-path analogue of [`Self::decode_slots`].
    pub fn decode_step(&self, kv: &mut KvCache, last_tokens: &[i32]) -> Result<Vec<i32>> {
        ensure!(last_tokens.len() == kv.slots, "token/slot mismatch");
        let slot_ids: Vec<usize> = (0..kv.slots).collect();
        self.decode_slots(kv, last_tokens, &slot_ids)
    }

    /// One greedy decode step over `slot_ids` only (ascending, no
    /// duplicates); lanes not listed are untouched and cost nothing —
    /// retired and still-free slots stop burning kernel time.
    /// `last_tokens[i]` feeds `slot_ids[i]`.
    pub fn decode_slots(
        &self,
        kv: &mut KvCache,
        last_tokens: &[i32],
        slot_ids: &[usize],
    ) -> Result<Vec<i32>> {
        ensure!(last_tokens.len() == slot_ids.len(), "token/slot mismatch");
        for &s in slot_ids {
            ensure!(s < kv.slots, "slot {} out of range ({} slots)", s, kv.slots);
            ensure!(kv.pos[s] > 0, "decode on unprefilled slot {}", s);
            ensure!(kv.pos[s] < self.config.max_seq, "KV slot {} exhausted", s);
        }
        let logits = self.forward_slots(last_tokens, slot_ids, 1, kv)?;
        Ok(argmax_rows(&logits, slot_ids.len()))
    }

    /// Core forward over an arbitrary lane subset: `tokens` is
    /// `(len(slot_ids) × seq)` row-major; row group `i` continues slot
    /// `slot_ids[i]` from its current position. Returns last-position
    /// logits `(len(slot_ids) × vocab)` and advances each slot's
    /// position by `seq`.
    fn forward_slots(
        &self,
        tokens: &[i32],
        slot_ids: &[usize],
        seq: usize,
        kv: &mut KvCache,
    ) -> Result<Vec<f32>> {
        let cfg = &self.config;
        let (d, hd, heads) = (cfg.d_model, cfg.head_dim(), cfg.n_heads);
        let n = slot_ids.len();
        ensure!(n > 0 && seq > 0, "empty forward");
        ensure!(tokens.len() == n * seq, "token buffer shape mismatch");
        for w in slot_ids.windows(2) {
            ensure!(w[0] < w[1], "slot ids must be ascending and distinct");
        }
        for &s in slot_ids {
            ensure!(s < kv.slots, "slot {} out of range", s);
        }
        let starts: Vec<usize> = slot_ids.iter().map(|&s| kv.pos[s]).collect();
        for (i, &s) in slot_ids.iter().enumerate() {
            ensure!(starts[i] + seq <= cfg.max_seq, "KV slot {} overflow", s);
        }
        let bs = n * seq;

        // Token embeddings (out-of-range ids are clamped into the byte
        // vocab rather than panicking on hostile input).
        let mut x = Matrix::zeros(bs, d);
        for (i, &t) in tokens.iter().enumerate() {
            let id = (t.max(0) as usize).min(cfg.vocab - 1);
            x.row_mut(i).copy_from_slice(self.tok_emb.row(id));
        }

        let scale = 1.0 / (hd as f32).sqrt();
        let max_span = starts.iter().max().copied().unwrap_or(0) + seq;
        for (layer, bw) in self.blocks.iter().enumerate() {
            // --- attention ---------------------------------------------
            let h = rmsnormed(&x, &bw.attn_norm);
            let mut q = Matrix::zeros(bs, d);
            let mut k = Matrix::zeros(bs, d);
            let mut v = Matrix::zeros(bs, d);
            gemm_on(&self.pool, &bw.wq, &h, &mut q);
            gemm_on(&self.pool, &bw.wk, &h, &mut k);
            gemm_on(&self.pool, &bw.wv, &h, &mut v);
            for i in 0..n {
                for t in 0..seq {
                    let row = i * seq + t;
                    let pos = starts[i] + t;
                    apply_rope(q.row_mut(row), heads, hd, pos, &self.rope_inv_freq);
                    apply_rope(k.row_mut(row), heads, hd, pos, &self.rope_inv_freq);
                }
            }
            kv.store(layer, slot_ids, &starts, seq, &k, &v);

            let mut attn = Matrix::zeros(bs, d);
            let mut scores = vec![0.0f32; max_span];
            for (i, &slot) in slot_ids.iter().enumerate() {
                for head in 0..heads {
                    for t in 0..seq {
                        let row = i * seq + t;
                        let span = starts[i] + t + 1; // causal: positions 0..=pos
                        let qh = &q.row(row)[head * hd..(head + 1) * hd];
                        for (p, s) in scores[..span].iter_mut().enumerate() {
                            *s = dot(qh, kv.k_at(layer, slot, head, p)) * scale;
                        }
                        softmax(&mut scores[..span]);
                        let out = &mut attn.row_mut(row)[head * hd..(head + 1) * hd];
                        for (p, &w) in scores[..span].iter().enumerate() {
                            for (o, kvv) in out.iter_mut().zip(kv.v_at(layer, slot, head, p)) {
                                *o += w * *kvv;
                            }
                        }
                    }
                }
            }
            let mut o = Matrix::zeros(bs, d);
            gemm_on(&self.pool, &bw.wo, &attn, &mut o);
            add_assign(&mut x, &o);

            // --- SwiGLU MLP --------------------------------------------
            let h = rmsnormed(&x, &bw.mlp_norm);
            let mut gate = Matrix::zeros(bs, cfg.d_ff);
            let mut up = Matrix::zeros(bs, cfg.d_ff);
            gemm_on(&self.pool, &bw.w_gate, &h, &mut gate);
            gemm_on(&self.pool, &bw.w_up, &h, &mut up);
            for (g, u) in gate.data.iter_mut().zip(&up.data) {
                *g = silu(*g) * *u;
            }
            let mut down = Matrix::zeros(bs, d);
            gemm_on(&self.pool, &bw.w_down, &gate, &mut down);
            add_assign(&mut x, &down);
        }
        for (i, &s) in slot_ids.iter().enumerate() {
            kv.pos[s] = starts[i] + seq;
        }

        // Final norm + lm_head logits, last position per sequence only.
        let mut logits = vec![0.0f32; n * cfg.vocab];
        let mut hrow = vec![0.0f32; d];
        for i in 0..n {
            let xrow = x.row(i * seq + (seq - 1));
            rmsnorm_into(xrow, &self.final_norm, &mut hrow);
            let out = &mut logits[i * cfg.vocab..(i + 1) * cfg.vocab];
            for (vi, o) in out.iter_mut().enumerate() {
                *o = dot(self.lm_head.row(vi), &hrow);
            }
        }
        Ok(logits)
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn add_assign(x: &mut Matrix, y: &Matrix) {
    debug_assert_eq!((x.rows, x.cols), (y.rows, y.cols));
    for (a, b) in x.data.iter_mut().zip(&y.data) {
        *a += *b;
    }
}

/// RMSNorm one row into a caller buffer.
fn rmsnorm_into(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + NORM_EPS).sqrt();
    for ((o, xv), wv) in out.iter_mut().zip(x).zip(w) {
        *o = xv * r * wv;
    }
}

/// Row-wise RMSNorm of a whole activation matrix.
fn rmsnormed(x: &Matrix, w: &[f32]) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        rmsnorm_into(x.row(r), w, out.row_mut(r));
    }
    out
}

/// In-place RoPE for one `(heads × hd)` activation row at `pos`
/// (half-split rotation, matching python `_apply_rope`).
/// `inv_freq` is the precomputed `θ^(-j/half)` table (`hd/2` entries).
fn apply_rope(row: &mut [f32], heads: usize, hd: usize, pos: usize, inv_freq: &[f32]) {
    let half = hd / 2;
    debug_assert_eq!(inv_freq.len(), half);
    for head in 0..heads {
        let h = &mut row[head * hd..(head + 1) * hd];
        for (j, &freq) in inv_freq.iter().enumerate() {
            let ang = pos as f32 * freq;
            let (sin, cos) = ang.sin_cos();
            let (a, b) = (h[j], h[j + half]);
            h[j] = a * cos - b * sin;
            h[j + half] = a * sin + b * cos;
        }
    }
}

/// Numerically-stable in-place softmax.
fn softmax(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::IcqConfig;
    use crate::quant::QuantizerKind;
    use crate::store::{synth_model, DecodeCache, StoredModel};
    use crate::synthzoo::FamilySpec;

    /// A deliberately tiny family so debug-mode tests stay fast.
    fn tiny_family() -> FamilySpec {
        FamilySpec {
            name: "tiny-test",
            d_model: 32,
            d_ff: 64,
            n_blocks: 2,
            tail_frac: 0.02,
            tail_scale: 2.5,
            oproj_hot: 0.5,
            seed: 0x7157,
        }
    }

    fn tiny_native(threads: usize) -> (NativeModel, Arc<DecodeCache>) {
        let cfg = IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::Rtn,
        };
        let model = synth_model(&tiny_family(), &cfg, None).unwrap();
        let cache = Arc::new(DecodeCache::new(64 << 20));
        let stored = StoredModel::from_model(model, cache.clone(), "native-test");
        (NativeModel::from_stored(&stored, threads).unwrap(), cache)
    }

    #[test]
    fn prefill_then_decode_produces_tokens_in_vocab() {
        let (m, _) = tiny_native(1);
        let prompts = vec![vec![72, 101, 108, 108, 111, 32, 119, 111], vec![84, 104, 101, 32, 113, 117, 105, 99]];
        let (first, mut kv) = m.prefill(&prompts).unwrap();
        assert_eq!(first.len(), 2);
        assert_eq!(kv.pos(0), 8);
        assert_eq!(kv.pos(1), 8);
        let mut last = first;
        for step in 0..4 {
            last = m.decode_step(&mut kv, &last).unwrap();
            assert_eq!(kv.pos(0), 9 + step);
            for &t in &last {
                assert!((0..m.config.vocab as i32).contains(&t));
            }
        }
    }

    #[test]
    fn decode_is_deterministic_and_thread_count_invariant() {
        // The fused kernels are bit-identical across thread counts, so
        // the whole generation must be too.
        let (m1, _) = tiny_native(1);
        let (m4, _) = tiny_native(4);
        let prompts = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let (t1, mut kv1) = m1.prefill(&prompts).unwrap();
        let (t4, mut kv4) = m4.prefill(&prompts).unwrap();
        assert_eq!(t1, t4);
        let (mut a, mut b) = (t1, t4);
        for _ in 0..5 {
            a = m1.decode_step(&mut kv1, &a).unwrap();
            b = m4.decode_step(&mut kv4, &b).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn incremental_decode_matches_full_prefill() {
        // Teacher-forcing consistency: prefill over [p0..p5] must leave
        // the model predicting the same next token as prefill over
        // [p0..p4] followed by one decode step feeding p5.
        let (m, _) = tiny_native(2);
        let full: Vec<i32> = vec![10, 20, 30, 40, 50, 60];
        let (next_full, _) = m.prefill(&[full.clone()]).unwrap();
        let (_, mut kv) = m.prefill(&[full[..5].to_vec()]).unwrap();
        let next_inc = m.decode_step(&mut kv, &[full[5]]).unwrap();
        assert_eq!(next_full, next_inc);
    }

    /// A sequence's greedy stream must not depend on how it was
    /// scheduled: alone via the batch path, or slot-prefilled into a
    /// shared cache and decoded beside a stranger at a different
    /// position. This is the correctness contract the continuous
    /// scheduler rests on.
    #[test]
    fn slot_path_matches_batch_path() {
        let (m, _) = tiny_native(2);
        let prompt_a: Vec<i32> = vec![72, 105, 32, 116, 104, 101];
        let prompt_b: Vec<i32> = vec![9, 8, 7];

        // Reference: each prompt alone through the batch path.
        let mut ref_stream_a = Vec::new();
        let (mut last, mut kv) = m.prefill(&[prompt_a.clone()]).unwrap();
        for _ in 0..4 {
            last = m.decode_step(&mut kv, &last).unwrap();
            ref_stream_a.push(last[0]);
        }
        let mut ref_stream_b = Vec::new();
        let (mut last, mut kv) = m.prefill(&[prompt_b.clone()]).unwrap();
        for _ in 0..4 {
            last = m.decode_step(&mut kv, &last).unwrap();
            ref_stream_b.push(last[0]);
        }

        // Slot path: A prefills into slot 0, decodes 2 steps alone, then
        // B is admitted into slot 1 mid-flight and both decode together.
        let mut kv = KvCache::new(&m.config, 2);
        let mut last_a = m.prefill_slot(&mut kv, 0, &prompt_a).unwrap();
        let mut got_a = Vec::new();
        for _ in 0..2 {
            let next = m.decode_slots(&mut kv, &[last_a], &[0]).unwrap();
            last_a = next[0];
            got_a.push(last_a);
        }
        let mut last_b = m.prefill_slot(&mut kv, 1, &prompt_b).unwrap();
        assert_eq!(kv.pos(0), prompt_a.len() + 2);
        assert_eq!(kv.pos(1), prompt_b.len());
        let mut got_b = Vec::new();
        for _ in 0..2 {
            let next = m.decode_slots(&mut kv, &[last_a, last_b], &[0, 1]).unwrap();
            last_a = next[0];
            last_b = next[1];
            got_a.push(last_a);
            got_b.push(last_b);
        }
        for _ in 0..2 {
            let next = m.decode_slots(&mut kv, &[last_b], &[1]).unwrap();
            last_b = next[0];
            got_b.push(last_b);
        }
        assert_eq!(got_a, ref_stream_a);
        assert_eq!(got_b, ref_stream_b);
    }

    /// Retiring a slot and admitting a new sequence into it must produce
    /// the same stream as a fresh cache — stale KV data from the previous
    /// occupant is unreachable behind the position gate.
    #[test]
    fn freed_slot_reuse_is_clean() {
        let (m, _) = tiny_native(1);
        let first: Vec<i32> = vec![100, 101, 102, 103, 104, 105, 106, 107];
        let second: Vec<i32> = vec![42, 43, 44];

        let mut ref_stream = Vec::new();
        let (mut last, mut kv) = m.prefill(&[second.clone()]).unwrap();
        for _ in 0..3 {
            last = m.decode_step(&mut kv, &last).unwrap();
            ref_stream.push(last[0]);
        }

        // Occupy the slot with a longer sequence, retire it, reuse it.
        let mut kv = KvCache::new(&m.config, 1);
        let mut last = m.prefill_slot(&mut kv, 0, &first).unwrap();
        for _ in 0..5 {
            last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
        }
        kv.free_slot(0);
        assert_eq!(kv.pos(0), 0);
        let mut last = m.prefill_slot(&mut kv, 0, &second).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            last = m.decode_slots(&mut kv, &[last], &[0]).unwrap()[0];
            got.push(last);
        }
        assert_eq!(got, ref_stream);
    }

    #[test]
    fn decode_slots_rejects_bad_slot_lists() {
        let (m, _) = tiny_native(1);
        let mut kv = KvCache::new(&m.config, 2);
        let last = m.prefill_slot(&mut kv, 0, &[1, 2, 3]).unwrap();
        // Unprefilled slot.
        assert!(m.decode_slots(&mut kv, &[last], &[1]).is_err());
        // Out-of-range slot.
        assert!(m.decode_slots(&mut kv, &[last], &[2]).is_err());
        // Duplicate slots.
        assert!(m.decode_slots(&mut kv, &[last, last], &[0, 0]).is_err());
        // Mismatched lengths.
        assert!(m.decode_slots(&mut kv, &[last, last], &[0]).is_err());
    }

    #[test]
    fn working_set_is_quantized_not_f32() {
        let (m, cache) = tiny_native(1);
        // At tiny widths the per-row codebooks are a large share; at LLM
        // widths the ratio approaches 4× (codes are 1 B vs 4 B f32).
        assert!(m.quantized_bytes() < m.dequantized_bytes());
        // Every projection plane is resident in the shared cache (codes
        // + codebooks), and the cache charged quantized bytes, not f32.
        assert!(cache.bytes_used() >= m.quantized_bytes());
        assert!(cache.bytes_used() < m.dequantized_bytes());
    }

    #[test]
    fn kv_cache_accounting() {
        let (m, _) = tiny_native(1);
        let (_, kv) = m.prefill(&[vec![1, 2, 3]]).unwrap();
        let cfg = &m.config;
        let want =
            2 * cfg.n_layers * cfg.n_heads * cfg.max_seq * cfg.head_dim() * 4;
        assert_eq!(kv.memory_bytes(), want);
    }
}

//! Runtime-dispatched SIMD kernel tier (DESIGN.md §14).
//!
//! The fused GEMV/GEMM and the KV dequant path dispatch their inner
//! loops through this module. A [`Tier`] is resolved **once** at model
//! construction (runtime CPU-feature detection, overridable via the
//! `ICQ_SIMD` env var or `serve --simd`) and then threaded by value into
//! every kernel call — the hot loops never re-detect. Three tiers:
//!
//! * **Scalar** — the bit-identity reference. Every scalar routine here
//!   reproduces the exact accumulation order of the pre-tier kernels,
//!   so `ICQ_SIMD=scalar` output is bit-identical to the historical
//!   fused path (and to dequantize-then-matmul; see the contract in
//!   the gemv module docs).
//! * **Avx2** — x86_64 AVX2+FMA: vectorized block unpack (8 codes per
//!   shuffle/shift/mask round instead of a per-code u64 shift
//!   register), in-register codebook gather (`vpermps` for 8/16-entry
//!   codebooks, hardware gather spill for wider), and 8-lane FMA
//!   dot-product accumulation with a **fixed reduction tree**.
//! * **Neon** — aarch64: `tbl`-based codebook gather and 4-lane FMA
//!   accumulation with the same fixed-tree shape.
//!
//! Error contract (enforced by `tests/simd_divergence.rs`): vector
//! tiers may reassociate the dot-product sum, so per output element
//! `|simd − scalar| ≤ 2⁻²⁰ · Σ|lᶜ·xᶜ|` (the bound is against the sum of
//! absolute terms — cancellation-safe). Unpack and gather are **exact**
//! in every tier; only the accumulation order differs. The opt-in int8
//! activation path ([`ActQuant::Int8`]) quantizes activations per call
//! (absmax scale) and the per-row codebook to i8, runs an integer inner
//! product (`maddubs` / `smull`+`sadalp`), and is bounded by its
//! quantization steps; its integer accumulation is exact, so int8
//! results are identical across tiers.
//!
//! Graceful degradation: [`Tier`] is a plain value, so a caller could
//! request a tier the host cannot run. Every dispatch shim re-verifies
//! the feature bits (cached by `std::arch` feature detection) before
//! entering the `unsafe` intrinsic body and silently falls back to the
//! scalar routine otherwise — an unsupported tier degrades, it never
//! faults.

use crate::bitstream::unpack_aligned_u8;

/// Resolved kernel tier, threaded by value into every dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Portable reference; bit-identical to the pre-tier kernels.
    Scalar,
    /// x86_64 AVX2+FMA vector paths.
    Avx2,
    /// aarch64 NEON vector paths.
    Neon,
}

impl Tier {
    /// Stable lowercase name (reports, metrics, bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Avx2 => "avx2",
            Tier::Neon => "neon",
        }
    }

    /// Small integer id for trace instants (`kernel_dispatch`).
    pub fn id(self) -> u8 {
        match self {
            Tier::Scalar => 0,
            Tier::Avx2 => 1,
            Tier::Neon => 2,
        }
    }
}

/// Requested tier, before feature detection ([`detect`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TierPref {
    /// Pick the best tier the host supports.
    #[default]
    Auto,
    /// Force the scalar reference tier.
    Scalar,
    /// Request AVX2 (falls back to scalar off-x86 or without AVX2+FMA).
    Avx2,
    /// Request NEON (falls back to scalar off-aarch64 or without NEON).
    Neon,
}

impl TierPref {
    /// Parse an `ICQ_SIMD` / `--simd` value; `None` for unknown input.
    pub fn parse(s: &str) -> Option<TierPref> {
        match s {
            "auto" => Some(TierPref::Auto),
            "scalar" => Some(TierPref::Scalar),
            "avx2" => Some(TierPref::Avx2),
            "neon" => Some(TierPref::Neon),
            _ => None,
        }
    }
}

/// Activation handling for the GEMV inner loop (`--act-quant`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ActQuant {
    /// Full-precision f32 activations (default).
    #[default]
    F32,
    /// Per-call absmax int8 activation quantization (DESIGN.md §14).
    Int8,
}

impl ActQuant {
    /// Stable lowercase name (reports, metrics).
    pub fn name(self) -> &'static str {
        match self {
            ActQuant::F32 => "f32",
            ActQuant::Int8 => "int8",
        }
    }
}

/// Resolve a preference against the host's CPU features. An explicitly
/// requested tier the host cannot run degrades to [`Tier::Scalar`]
/// rather than erroring: the scalar tier is always a correct answer.
pub fn detect(pref: TierPref) -> Tier {
    match pref {
        TierPref::Scalar => Tier::Scalar,
        TierPref::Avx2 => {
            if avx2_supported() {
                Tier::Avx2
            } else {
                Tier::Scalar
            }
        }
        TierPref::Neon => {
            if neon_supported() {
                Tier::Neon
            } else {
                Tier::Scalar
            }
        }
        TierPref::Auto => {
            if avx2_supported() {
                Tier::Avx2
            } else if neon_supported() {
                Tier::Neon
            } else {
                Tier::Scalar
            }
        }
    }
}

/// Read the `ICQ_SIMD` preference: unset means [`TierPref::Auto`]; an
/// unrecognized value conservatively means [`TierPref::Scalar`] (a typo
/// must not silently enable vector paths).
pub fn env_pref() -> TierPref {
    match std::env::var("ICQ_SIMD") {
        Ok(v) => TierPref::parse(&v).unwrap_or(TierPref::Scalar),
        Err(_) => TierPref::Auto,
    }
}

/// [`detect`] applied to [`env_pref`] — the construction-time default.
pub fn from_env() -> Tier {
    detect(env_pref())
}

#[cfg(target_arch = "x86_64")]
fn avx2_supported() -> bool {
    // The fused dot path needs FMA as well as the integer AVX2 ops;
    // treat the tier as one unit. std caches the cpuid probe.
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_supported() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_supported() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_supported() -> bool {
    false
}

/// Load up to 8 bytes at `off` as a little-endian u64, zero-padded past
/// the end of `src` (callers only consume bits that lie inside `src`).
// lint: hot-path
#[inline(always)]
fn load_window(src: &[u8], off: usize) -> u64 {
    let mut buf = [0u8; 8];
    let n = 8.min(src.len().saturating_sub(off));
    buf[..n].copy_from_slice(&src[off..off + n]);
    u64::from_le_bytes(buf)
}

// ---------------------------------------------------------------------------
// Dispatchers. Each takes the resolved `Tier` by value; the per-arch
// shims re-verify feature support before entering the intrinsic body
// and fall back to the scalar reference otherwise.
// ---------------------------------------------------------------------------

/// Unpack `levels.len()` `width`-bit codes from `src` and gather
/// `cb[code]` into `levels`. `codes` is scratch of the same length; its
/// contents are unspecified after non-scalar tiers (the AVX2 path fuses
/// unpack and gather in-register and never materializes bytes).
///
/// Exact in every tier: the decoded levels are bit-identical across
/// tiers, only downstream accumulation differs.
// lint: hot-path
#[inline]
pub fn unpack_gather(
    tier: Tier,
    src: &[u8],
    width: u32,
    cb: &[f32],
    codes: &mut [u8],
    levels: &mut [f32],
) {
    match tier {
        Tier::Scalar => unpack_gather_scalar(src, width, cb, codes, levels),
        Tier::Avx2 => unpack_gather_avx2(src, width, cb, codes, levels),
        Tier::Neon => unpack_gather_neon(src, width, cb, codes, levels),
    }
}

/// Continue a dot product: `acc + Σ levels[c]·x[c]`, term by term for
/// the scalar tier (the bit-identity order), fixed-tree FMA lanes for
/// vector tiers. The accumulator is carried **across** blocks by the
/// caller, which is what keeps the scalar tier bit-identical to the
/// pre-tier kernels.
// lint: hot-path
#[inline]
pub fn dot_acc(tier: Tier, acc: f32, levels: &[f32], x: &[f32]) -> f32 {
    match tier {
        Tier::Scalar => dot_acc_scalar(acc, levels, x),
        Tier::Avx2 => dot_acc_avx2(acc, levels, x),
        Tier::Neon => dot_acc_neon(acc, levels, x),
    }
}

/// Plain dot product (`dot_acc` from zero) — the attention-score shape.
// lint: hot-path
#[inline]
pub fn dot(tier: Tier, a: &[f32], b: &[f32]) -> f32 {
    dot_acc(tier, 0.0, a, b)
}

/// `out[i] += w · v[i]` — the attention weighted-value accumulation.
// lint: hot-path
#[inline]
pub fn axpy(tier: Tier, out: &mut [f32], w: f32, v: &[f32]) {
    match tier {
        Tier::Scalar => axpy_scalar(out, w, v),
        Tier::Avx2 => axpy_avx2(out, w, v),
        Tier::Neon => axpy_neon(out, w, v),
    }
}

/// `out[i] = lo + step · codes[i]` — the KV dequant affine fill. The
/// scalar tier reproduces the historical `lo + step * code` rounding;
/// vector tiers use FMA (within the 2⁻²⁰ contract).
// lint: hot-path
#[inline]
pub fn affine_u8(tier: Tier, codes: &[u8], lo: f32, step: f32, out: &mut [f32]) {
    match tier {
        Tier::Scalar => affine_u8_scalar(codes, lo, step, out),
        Tier::Avx2 => affine_u8_avx2(codes, lo, step, out),
        Tier::Neon => affine_u8_neon(codes, lo, step, out),
    }
}

/// Gather `table[code]` into `out` for the int8 path. `entries` is the
/// live codebook size; tables of ≤ 16 entries take the in-register
/// shuffle (`pshufb` / `tbl`), wider ones the scalar loop. Exact in
/// every tier.
// lint: hot-path
#[inline]
pub fn gather_i8(tier: Tier, codes: &[u8], table: &[i8; 256], entries: usize, out: &mut [i8]) {
    match tier {
        Tier::Avx2 if entries <= 16 => gather_i8_avx2(codes, table, out),
        Tier::Neon if entries <= 16 => gather_i8_neon(codes, table, out),
        _ => gather_i8_scalar(codes, table, out),
    }
}

/// Integer inner product `Σ levels[c]·x[c]` over i8 operands, exact in
/// every tier (integer accumulation never reassociates lossily). The
/// caller stages at most one gather block (≤ 512 terms) per call, so
/// the i32 accumulator cannot overflow: `512 · 127 · 127 < 2³¹`.
// lint: hot-path
#[inline]
pub fn dot_i8(tier: Tier, levels: &[i8], x: &[i8]) -> i32 {
    match tier {
        Tier::Scalar => dot_i8_scalar(levels, x),
        Tier::Avx2 => dot_i8_avx2(levels, x),
        Tier::Neon => dot_i8_neon(levels, x),
    }
}

/// Quantize activations to i8 with a per-call absmax scale. Returns the
/// dequantization scale (`x ≈ scale · q`); an all-zero or non-finite
/// input yields scale 0 and an all-zero `out` (the int8 path then
/// produces exact zeros instead of NaN). Quantized values stay in
/// `[-127, 127]`.
pub fn quantize_activations(x: &[f32], out: &mut Vec<i8>) -> f32 {
    out.clear();
    out.resize(x.len(), 0);
    let mut absmax = 0.0f32;
    for &v in x {
        let a = v.abs();
        if a > absmax {
            absmax = a;
        }
    }
    if absmax == 0.0 || !absmax.is_finite() {
        return 0.0;
    }
    let inv = 127.0 / absmax;
    for (q, &v) in out.iter_mut().zip(x) {
        *q = (v * inv).round() as i8;
    }
    absmax / 127.0
}

/// Quantize a per-row codebook to i8 into the 256-entry staging table
/// (the table is oversized so 16-byte vector loads stay in-bounds for
/// any codebook width). Returns the dequantization scale; degenerate
/// codebooks yield scale 0 and a zero table.
pub fn quantize_codebook(cb: &[f32], out: &mut [i8; 256]) -> f32 {
    out.fill(0);
    let mut absmax = 0.0f32;
    for &v in cb {
        let a = v.abs();
        if a > absmax {
            absmax = a;
        }
    }
    if absmax == 0.0 || !absmax.is_finite() {
        return 0.0;
    }
    let inv = 127.0 / absmax;
    for (o, &v) in out.iter_mut().zip(cb) {
        *o = (v * inv).round() as i8;
    }
    absmax / 127.0
}

// ---------------------------------------------------------------------------
// Scalar tier: the bit-identity reference bodies.
// ---------------------------------------------------------------------------

// lint: hot-path
#[inline]
fn unpack_gather_scalar(src: &[u8], width: u32, cb: &[f32], codes: &mut [u8], levels: &mut [f32]) {
    unpack_aligned_u8(src, width, codes);
    for (l, &code) in levels.iter_mut().zip(codes.iter()) {
        *l = cb[code as usize];
    }
}

// lint: hot-path
#[inline]
fn dot_acc_scalar(mut acc: f32, levels: &[f32], x: &[f32]) -> f32 {
    for (l, xv) in levels.iter().zip(x) {
        acc += *l * *xv;
    }
    acc
}

// lint: hot-path
#[inline]
fn axpy_scalar(out: &mut [f32], w: f32, v: &[f32]) {
    for (o, vv) in out.iter_mut().zip(v) {
        *o += w * *vv;
    }
}

// lint: hot-path
#[inline]
fn affine_u8_scalar(codes: &[u8], lo: f32, step: f32, out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(codes.iter()) {
        *o = lo + step * c as f32;
    }
}

// lint: hot-path
#[inline]
fn gather_i8_scalar(codes: &[u8], table: &[i8; 256], out: &mut [i8]) {
    for (o, &c) in out.iter_mut().zip(codes.iter()) {
        *o = table[c as usize];
    }
}

// lint: hot-path
#[inline]
fn dot_i8_scalar(levels: &[i8], x: &[i8]) -> i32 {
    let mut acc = 0i32;
    for (l, xv) in levels.iter().zip(x) {
        acc += *l as i32 * *xv as i32;
    }
    acc
}

// ---------------------------------------------------------------------------
// Per-arch dispatch shims: cfg-paired so every symbol exists on every
// target; the off-arch twin is the scalar body. The on-arch shim
// re-verifies feature support (cheap: std caches the probe) before the
// `unsafe` call, so a hand-constructed unsupported `Tier` degrades
// instead of executing illegal instructions.
// ---------------------------------------------------------------------------

// lint: hot-path
#[cfg(target_arch = "x86_64")]
fn unpack_gather_avx2(src: &[u8], width: u32, cb: &[f32], codes: &mut [u8], levels: &mut [f32]) {
    if !avx2_supported() || width == 0 || width > 8 {
        return unpack_gather_scalar(src, width, cb, codes, levels);
    }
    // SAFETY: AVX2+FMA verified above; width ∈ 1..=8 and the plane
    // invariant `cb.len() == 1 << width` bound every gathered index.
    unsafe { avx2::unpack_gather(src, width, cb, levels) }
}

// lint: hot-path
#[cfg(not(target_arch = "x86_64"))]
fn unpack_gather_avx2(src: &[u8], width: u32, cb: &[f32], codes: &mut [u8], levels: &mut [f32]) {
    unpack_gather_scalar(src, width, cb, codes, levels)
}

// lint: hot-path
#[cfg(target_arch = "aarch64")]
fn unpack_gather_neon(src: &[u8], width: u32, cb: &[f32], codes: &mut [u8], levels: &mut [f32]) {
    if !neon_supported() {
        return unpack_gather_scalar(src, width, cb, codes, levels);
    }
    unpack_aligned_u8(src, width, codes);
    // SAFETY: NEON verified above; unpacked codes are masked to `width`
    // bits, so every index is < `cb.len() == 1 << width`.
    unsafe { neon::gather_f32(cb, codes, levels) }
}

// lint: hot-path
#[cfg(not(target_arch = "aarch64"))]
fn unpack_gather_neon(src: &[u8], width: u32, cb: &[f32], codes: &mut [u8], levels: &mut [f32]) {
    unpack_gather_scalar(src, width, cb, codes, levels)
}

// lint: hot-path
#[cfg(target_arch = "x86_64")]
fn dot_acc_avx2(acc: f32, levels: &[f32], x: &[f32]) -> f32 {
    if !avx2_supported() {
        return dot_acc_scalar(acc, levels, x);
    }
    // SAFETY: AVX2+FMA verified above; the body only reads within the
    // shorter of the two slices.
    unsafe { avx2::dot_acc(acc, levels, x) }
}

// lint: hot-path
#[cfg(not(target_arch = "x86_64"))]
fn dot_acc_avx2(acc: f32, levels: &[f32], x: &[f32]) -> f32 {
    dot_acc_scalar(acc, levels, x)
}

// lint: hot-path
#[cfg(target_arch = "aarch64")]
fn dot_acc_neon(acc: f32, levels: &[f32], x: &[f32]) -> f32 {
    if !neon_supported() {
        return dot_acc_scalar(acc, levels, x);
    }
    // SAFETY: NEON verified above; the body only reads within the
    // shorter of the two slices.
    unsafe { neon::dot_acc(acc, levels, x) }
}

// lint: hot-path
#[cfg(not(target_arch = "aarch64"))]
fn dot_acc_neon(acc: f32, levels: &[f32], x: &[f32]) -> f32 {
    dot_acc_scalar(acc, levels, x)
}

// lint: hot-path
#[cfg(target_arch = "x86_64")]
fn axpy_avx2(out: &mut [f32], w: f32, v: &[f32]) {
    if !avx2_supported() {
        return axpy_scalar(out, w, v);
    }
    // SAFETY: AVX2+FMA verified above; the body only touches the
    // shorter of the two slices.
    unsafe { avx2::axpy(out, w, v) }
}

// lint: hot-path
#[cfg(not(target_arch = "x86_64"))]
fn axpy_avx2(out: &mut [f32], w: f32, v: &[f32]) {
    axpy_scalar(out, w, v)
}

// lint: hot-path
#[cfg(target_arch = "aarch64")]
fn axpy_neon(out: &mut [f32], w: f32, v: &[f32]) {
    if !neon_supported() {
        return axpy_scalar(out, w, v);
    }
    // SAFETY: NEON verified above; the body only touches the shorter of
    // the two slices.
    unsafe { neon::axpy(out, w, v) }
}

// lint: hot-path
#[cfg(not(target_arch = "aarch64"))]
fn axpy_neon(out: &mut [f32], w: f32, v: &[f32]) {
    axpy_scalar(out, w, v)
}

// lint: hot-path
#[cfg(target_arch = "x86_64")]
fn affine_u8_avx2(codes: &[u8], lo: f32, step: f32, out: &mut [f32]) {
    if !avx2_supported() {
        return affine_u8_scalar(codes, lo, step, out);
    }
    // SAFETY: AVX2+FMA verified above; the body only touches the
    // shorter of the two slices.
    unsafe { avx2::affine_u8(codes, lo, step, out) }
}

// lint: hot-path
#[cfg(not(target_arch = "x86_64"))]
fn affine_u8_avx2(codes: &[u8], lo: f32, step: f32, out: &mut [f32]) {
    affine_u8_scalar(codes, lo, step, out)
}

// lint: hot-path
#[cfg(target_arch = "aarch64")]
fn affine_u8_neon(codes: &[u8], lo: f32, step: f32, out: &mut [f32]) {
    if !neon_supported() {
        return affine_u8_scalar(codes, lo, step, out);
    }
    // SAFETY: NEON verified above; the body only touches the shorter of
    // the two slices.
    unsafe { neon::affine_u8(codes, lo, step, out) }
}

// lint: hot-path
#[cfg(not(target_arch = "aarch64"))]
fn affine_u8_neon(codes: &[u8], lo: f32, step: f32, out: &mut [f32]) {
    affine_u8_scalar(codes, lo, step, out)
}

// lint: hot-path
#[cfg(target_arch = "x86_64")]
fn gather_i8_avx2(codes: &[u8], table: &[i8; 256], out: &mut [i8]) {
    if !avx2_supported() {
        return gather_i8_scalar(codes, table, out);
    }
    // SAFETY: AVX2 verified above; the dispatcher only routes here for
    // codebooks of ≤ 16 entries, so every code fits the pshufb nibble.
    unsafe { avx2::gather_i8(codes, table, out) }
}

// lint: hot-path
#[cfg(not(target_arch = "x86_64"))]
fn gather_i8_avx2(codes: &[u8], table: &[i8; 256], out: &mut [i8]) {
    gather_i8_scalar(codes, table, out)
}

// lint: hot-path
#[cfg(target_arch = "aarch64")]
fn gather_i8_neon(codes: &[u8], table: &[i8; 256], out: &mut [i8]) {
    if !neon_supported() {
        return gather_i8_scalar(codes, table, out);
    }
    // SAFETY: NEON verified above; the dispatcher only routes here for
    // codebooks of ≤ 16 entries, so every code is a valid tbl index.
    unsafe { neon::gather_i8(codes, table, out) }
}

// lint: hot-path
#[cfg(not(target_arch = "aarch64"))]
fn gather_i8_neon(codes: &[u8], table: &[i8; 256], out: &mut [i8]) {
    gather_i8_scalar(codes, table, out)
}

// lint: hot-path
#[cfg(target_arch = "x86_64")]
fn dot_i8_avx2(levels: &[i8], x: &[i8]) -> i32 {
    if !avx2_supported() {
        return dot_i8_scalar(levels, x);
    }
    // SAFETY: AVX2 verified above; the body only reads within the
    // shorter of the two slices.
    unsafe { avx2::dot_i8(levels, x) }
}

// lint: hot-path
#[cfg(not(target_arch = "x86_64"))]
fn dot_i8_avx2(levels: &[i8], x: &[i8]) -> i32 {
    dot_i8_scalar(levels, x)
}

// lint: hot-path
#[cfg(target_arch = "aarch64")]
fn dot_i8_neon(levels: &[i8], x: &[i8]) -> i32 {
    if !neon_supported() {
        return dot_i8_scalar(levels, x);
    }
    // SAFETY: NEON verified above; the body only reads within the
    // shorter of the two slices.
    unsafe { neon::dot_i8(levels, x) }
}

// lint: hot-path
#[cfg(not(target_arch = "aarch64"))]
fn dot_i8_neon(levels: &[i8], x: &[i8]) -> i32 {
    dot_i8_scalar(levels, x)
}

// ---------------------------------------------------------------------------
// AVX2+FMA bodies (x86_64 only). Every fn is `unsafe` + target_feature;
// the dispatch shims above are the only callers and verify support
// first.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::load_window;
    use std::arch::x86_64::*;

    /// Fused unpack + codebook gather: decode `levels.len()` codes of
    /// `width` bits from `src` straight into f32 levels, 8 per round.
    ///
    /// Per round, the 8-code bit window (`width` bytes) is broadcast to
    /// every 64-bit element of a ymm; a per-width `pshufb` control then
    /// places, for lane k, the 4 bytes starting at byte `(k·width)>>3`
    /// of the window into that lane; `srlv` shifts by `(k·width)&7` and
    /// an and-mask isolates the code. Byte indexes past the 8-byte
    /// window read the broadcast copy (wrong bytes), but those bytes
    /// only reach dword bits ≥ 8 + width after the shift, which the
    /// ≤ 8-bit mask discards — only bytes `base` and `base+1` carry
    /// live bits, and those always index inside the window.
    ///
    /// # Safety
    ///
    /// Caller must verify AVX2+FMA. Requires `width ∈ 1..=8`,
    /// `cb.len() == 1 << width`, and `src` to hold every code bit
    /// (`ceil(levels.len()·width/8)` bytes).
    // lint: hot-path
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn unpack_gather(src: &[u8], width: u32, cb: &[f32], levels: &mut [f32]) {
        let n = levels.len();
        let w = width as usize;
        let mut shuf = [0u8; 32];
        let mut shifts = [0i32; 8];
        for k in 0..8 {
            let bit = k * w;
            let base = (bit >> 3) as u8;
            let half = k >> 2;
            let lane = k & 3;
            for b in 0..4u8 {
                shuf[half * 16 + lane * 4 + b as usize] = base + b;
            }
            shifts[k] = (bit & 7) as i32;
        }
        let shuf_v = _mm256_loadu_si256(shuf.as_ptr().cast());
        let shift_v = _mm256_loadu_si256(shifts.as_ptr().cast());
        let mask_v = _mm256_set1_epi32(((1u32 << width) - 1) as i32);
        let groups = n / 8;
        for g in 0..groups {
            let win = load_window(src, g * w);
            let wv = _mm256_set1_epi64x(win as i64);
            let dwords = _mm256_shuffle_epi8(wv, shuf_v);
            let codes_v = _mm256_and_si256(_mm256_srlv_epi32(dwords, shift_v), mask_v);
            let lv = gather8(cb, codes_v, width);
            _mm256_storeu_ps(levels.as_mut_ptr().add(g * 8), lv);
        }
        for i in groups * 8..n {
            let bit = i * w;
            let win = load_window(src, bit >> 3);
            let code = (win >> (bit & 7)) & ((1u64 << width) - 1);
            levels[i] = cb[code as usize];
        }
    }

    /// Gather the 8 codebook entries selected by the i32 lanes of
    /// `codes`. 8-entry codebooks (width 3) use one `vpermps`
    /// in-register shuffle; 16-entry (width 4) two `vpermps` (it only
    /// reads index bits [2:0]) blended on bit 3; anything else spills
    /// to the hardware gather, which reads only the indexed entries.
    ///
    /// # Safety
    ///
    /// Caller must verify AVX2; every lane of `codes` must be
    /// `< cb.len()`, and `cb.len() == 1 << width`.
    // lint: hot-path
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn gather8(cb: &[f32], codes: __m256i, width: u32) -> __m256 {
        if width == 3 {
            let table = _mm256_loadu_ps(cb.as_ptr());
            _mm256_permutevar8x32_ps(table, codes)
        } else if width == 4 {
            let t0 = _mm256_loadu_ps(cb.as_ptr());
            let t1 = _mm256_loadu_ps(cb.as_ptr().add(8));
            let lo = _mm256_permutevar8x32_ps(t0, codes);
            let hi = _mm256_permutevar8x32_ps(t1, codes);
            let sel = _mm256_castsi256_ps(_mm256_cmpgt_epi32(codes, _mm256_set1_epi32(7)));
            _mm256_blendv_ps(lo, hi, sel)
        } else {
            _mm256_i32gather_ps::<4>(cb.as_ptr(), codes)
        }
    }

    /// Dot-product continuation over two 8-lane FMA accumulators with a
    /// fixed reduction tree (DESIGN.md §14): `s0+s1` → fold the two
    /// 128-bit halves → pairwise horizontal fold — the tree shape never
    /// depends on pool width, so pooled and single-threaded runs of the
    /// same tier are bit-identical.
    ///
    /// # Safety
    ///
    /// Caller must verify AVX2+FMA. Reads only within the shorter of
    /// the two slices.
    // lint: hot-path
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_acc(acc: f32, levels: &[f32], x: &[f32]) -> f32 {
        let n = levels.len().min(x.len());
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let l0 = _mm256_loadu_ps(levels.as_ptr().add(i));
            let x0 = _mm256_loadu_ps(x.as_ptr().add(i));
            s0 = _mm256_fmadd_ps(l0, x0, s0);
            let l1 = _mm256_loadu_ps(levels.as_ptr().add(i + 8));
            let x1 = _mm256_loadu_ps(x.as_ptr().add(i + 8));
            s1 = _mm256_fmadd_ps(l1, x1, s1);
            i += 16;
        }
        while i + 8 <= n {
            let l0 = _mm256_loadu_ps(levels.as_ptr().add(i));
            let x0 = _mm256_loadu_ps(x.as_ptr().add(i));
            s0 = _mm256_fmadd_ps(l0, x0, s0);
            i += 8;
        }
        let s = _mm256_add_ps(s0, s1);
        let q = _mm_add_ps(_mm256_castps256_ps128(s), _mm256_extractf128_ps::<1>(s));
        let d = _mm_add_ps(q, _mm_movehl_ps(q, q));
        let sum = _mm_cvtss_f32(_mm_add_ss(d, _mm_shuffle_ps::<1>(d, d)));
        let mut total = acc + sum;
        while i < n {
            total += levels[i] * x[i];
            i += 1;
        }
        total
    }

    /// `out[i] += w · v[i]` over 8 FMA lanes; the scalar tail uses
    /// `mul_add` so every element sees exactly one fused rounding.
    ///
    /// # Safety
    ///
    /// Caller must verify AVX2+FMA. Touches only the shorter of the two
    /// slices.
    // lint: hot-path
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(out: &mut [f32], w: f32, v: &[f32]) {
        let n = out.len().min(v.len());
        let wv = _mm256_set1_ps(w);
        let mut i = 0usize;
        while i + 8 <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            let vv = _mm256_loadu_ps(v.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_fmadd_ps(wv, vv, o));
            i += 8;
        }
        while i < n {
            out[i] = w.mul_add(v[i], out[i]);
            i += 1;
        }
    }

    /// `out[i] = lo + step · codes[i]`: widen 8 u8 codes to f32 lanes,
    /// one FMA per lane; `mul_add` tail for the same single rounding.
    ///
    /// # Safety
    ///
    /// Caller must verify AVX2+FMA. Touches only the shorter of the two
    /// slices.
    // lint: hot-path
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn affine_u8(codes: &[u8], lo: f32, step: f32, out: &mut [f32]) {
        let n = codes.len().min(out.len());
        let lov = _mm256_set1_ps(lo);
        let stepv = _mm256_set1_ps(step);
        let mut i = 0usize;
        while i + 8 <= n {
            let c8 = _mm_loadl_epi64(codes.as_ptr().add(i).cast());
            let f = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(c8));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_fmadd_ps(stepv, f, lov));
            i += 8;
        }
        while i < n {
            out[i] = step.mul_add(codes[i] as f32, lo);
            i += 1;
        }
    }

    /// i8 codebook lookup via `pshufb`: the first 16 table entries are
    /// broadcast to both ymm halves and 32 codes resolve per round.
    ///
    /// # Safety
    ///
    /// Caller must verify AVX2 and that every code is < 16 (the shuffle
    /// control's high bit must stay clear).
    // lint: hot-path
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_i8(codes: &[u8], table: &[i8; 256], out: &mut [i8]) {
        let n = codes.len().min(out.len());
        let t = _mm256_broadcastsi128_si256(_mm_loadu_si128(table.as_ptr().cast()));
        let mut i = 0usize;
        while i + 32 <= n {
            let c = _mm256_loadu_si256(codes.as_ptr().add(i).cast());
            _mm256_storeu_si256(out.as_mut_ptr().add(i).cast(), _mm256_shuffle_epi8(t, c));
            i += 32;
        }
        while i < n {
            out[i] = table[codes[i] as usize];
            i += 1;
        }
    }

    /// Integer inner product, 32 i8 pairs per round. `maddubs` needs an
    /// unsigned operand, so the sign of `levels` is moved onto `x`
    /// (`|l| · sign(x, l)` preserves each product, and `sign` zeroing
    /// where `l == 0` matches the true zero product). Pair sums stay
    /// ≤ 2·127·127 = 32258 < i16::MAX, so `maddubs` never saturates;
    /// `madd` widens to i32 exactly.
    ///
    /// # Safety
    ///
    /// Caller must verify AVX2 and keep both operands in `[-127, 127]`
    /// (the quantizers in this module guarantee that). Reads only
    /// within the shorter of the two slices.
    // lint: hot-path
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8(levels: &[i8], x: &[i8]) -> i32 {
        let n = levels.len().min(x.len());
        let ones = _mm256_set1_epi16(1);
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 32 <= n {
            let lv = _mm256_loadu_si256(levels.as_ptr().add(i).cast());
            let xv = _mm256_loadu_si256(x.as_ptr().add(i).cast());
            let labs = _mm256_abs_epi8(lv);
            let xsgn = _mm256_sign_epi8(xv, lv);
            let pairs = _mm256_maddubs_epi16(labs, xsgn);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(pairs, ones));
            i += 32;
        }
        let q = _mm_add_epi32(_mm256_castsi256_si128(acc), _mm256_extracti128_si256::<1>(acc));
        let q = _mm_add_epi32(q, _mm_shuffle_epi32::<0x4E>(q));
        let q = _mm_add_epi32(q, _mm_shuffle_epi32::<0xB1>(q));
        let mut total = _mm_cvtsi128_si32(q);
        while i < n {
            total += levels[i] as i32 * x[i] as i32;
            i += 1;
        }
        total
    }
}

// ---------------------------------------------------------------------------
// NEON bodies (aarch64 only). Deliberately simpler than the AVX2 tier:
// unpack stays scalar and only the gather/accumulate loops vectorize.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// Codebook gather via `tbl`: 8-entry codebooks use a 2-register
    /// table, 16-entry a 4-register table, 4 f32 lookups per round
    /// (byte indexes `4c..4c+4` select the code's f32 entry). Other
    /// sizes fall back to the scalar loop.
    ///
    /// # Safety
    ///
    /// Caller must verify NEON; every code must be `< cb.len()`.
    // lint: hot-path
    #[target_feature(enable = "neon")]
    pub unsafe fn gather_f32(cb: &[f32], codes: &[u8], levels: &mut [f32]) {
        let n = codes.len().min(levels.len());
        let bytes = cb.as_ptr().cast::<u8>();
        let mut i = 0usize;
        if cb.len() == 8 {
            let t = uint8x16x2_t(vld1q_u8(bytes), vld1q_u8(bytes.add(16)));
            while i + 4 <= n {
                let idx = byte_index4(codes, i);
                let g = vqtbl2q_u8(t, vld1q_u8(idx.as_ptr()));
                vst1q_f32(levels.as_mut_ptr().add(i), vreinterpretq_f32_u8(g));
                i += 4;
            }
        } else if cb.len() == 16 {
            let t = uint8x16x4_t(
                vld1q_u8(bytes),
                vld1q_u8(bytes.add(16)),
                vld1q_u8(bytes.add(32)),
                vld1q_u8(bytes.add(48)),
            );
            while i + 4 <= n {
                let idx = byte_index4(codes, i);
                let g = vqtbl4q_u8(t, vld1q_u8(idx.as_ptr()));
                vst1q_f32(levels.as_mut_ptr().add(i), vreinterpretq_f32_u8(g));
                i += 4;
            }
        }
        while i < n {
            levels[i] = cb[codes[i] as usize];
            i += 1;
        }
    }

    /// Expand 4 codes at `codes[i..i+4]` into the 16 byte indexes of
    /// their f32 table entries. Codes must be < 16 so `4c+3 ≤ 63`.
    // lint: hot-path
    #[inline]
    fn byte_index4(codes: &[u8], i: usize) -> [u8; 16] {
        let mut idx = [0u8; 16];
        for j in 0..4 {
            let b = codes[i + j] * 4;
            idx[4 * j] = b;
            idx[4 * j + 1] = b + 1;
            idx[4 * j + 2] = b + 2;
            idx[4 * j + 3] = b + 3;
        }
        idx
    }

    /// Dot-product continuation over two 4-lane FMA accumulators with a
    /// fixed reduction tree (`vaddvq` of `s0+s1`), mirroring the AVX2
    /// tier's determinism contract.
    ///
    /// # Safety
    ///
    /// Caller must verify NEON. Reads only within the shorter slice.
    // lint: hot-path
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_acc(acc: f32, levels: &[f32], x: &[f32]) -> f32 {
        let n = levels.len().min(x.len());
        let mut s0 = vdupq_n_f32(0.0);
        let mut s1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            let l0 = vld1q_f32(levels.as_ptr().add(i));
            let x0 = vld1q_f32(x.as_ptr().add(i));
            s0 = vfmaq_f32(s0, l0, x0);
            let l1 = vld1q_f32(levels.as_ptr().add(i + 4));
            let x1 = vld1q_f32(x.as_ptr().add(i + 4));
            s1 = vfmaq_f32(s1, l1, x1);
            i += 8;
        }
        while i + 4 <= n {
            let l0 = vld1q_f32(levels.as_ptr().add(i));
            let x0 = vld1q_f32(x.as_ptr().add(i));
            s0 = vfmaq_f32(s0, l0, x0);
            i += 4;
        }
        let mut total = acc + vaddvq_f32(vaddq_f32(s0, s1));
        while i < n {
            total += levels[i] * x[i];
            i += 1;
        }
        total
    }

    /// `out[i] += w · v[i]` over 4 FMA lanes; `mul_add` tail.
    ///
    /// # Safety
    ///
    /// Caller must verify NEON. Touches only the shorter slice.
    // lint: hot-path
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(out: &mut [f32], w: f32, v: &[f32]) {
        let n = out.len().min(v.len());
        let wv = vdupq_n_f32(w);
        let mut i = 0usize;
        while i + 4 <= n {
            let o = vld1q_f32(out.as_ptr().add(i));
            let vv = vld1q_f32(v.as_ptr().add(i));
            vst1q_f32(out.as_mut_ptr().add(i), vfmaq_f32(o, wv, vv));
            i += 4;
        }
        while i < n {
            out[i] = w.mul_add(v[i], out[i]);
            i += 1;
        }
    }

    /// `out[i] = lo + step · codes[i]` via u8→f32 widening and FMA.
    ///
    /// # Safety
    ///
    /// Caller must verify NEON. Touches only the shorter slice.
    // lint: hot-path
    #[target_feature(enable = "neon")]
    pub unsafe fn affine_u8(codes: &[u8], lo: f32, step: f32, out: &mut [f32]) {
        let n = codes.len().min(out.len());
        let lov = vdupq_n_f32(lo);
        let stepv = vdupq_n_f32(step);
        let mut i = 0usize;
        while i + 8 <= n {
            let c16 = vmovl_u8(vld1_u8(codes.as_ptr().add(i)));
            let f0 = vcvtq_f32_u32(vmovl_u16(vget_low_u16(c16)));
            let f1 = vcvtq_f32_u32(vmovl_u16(vget_high_u16(c16)));
            vst1q_f32(out.as_mut_ptr().add(i), vfmaq_f32(lov, stepv, f0));
            vst1q_f32(out.as_mut_ptr().add(i + 4), vfmaq_f32(lov, stepv, f1));
            i += 8;
        }
        while i < n {
            out[i] = step.mul_add(codes[i] as f32, lo);
            i += 1;
        }
    }

    /// i8 codebook lookup via `tbl`, 16 codes per round.
    ///
    /// # Safety
    ///
    /// Caller must verify NEON and that every code is < 16.
    // lint: hot-path
    #[target_feature(enable = "neon")]
    pub unsafe fn gather_i8(codes: &[u8], table: &[i8; 256], out: &mut [i8]) {
        let n = codes.len().min(out.len());
        let t = vld1q_s8(table.as_ptr());
        let mut i = 0usize;
        while i + 16 <= n {
            let c = vld1q_u8(codes.as_ptr().add(i));
            vst1q_s8(out.as_mut_ptr().add(i), vqtbl1q_s8(t, c));
            i += 16;
        }
        while i < n {
            out[i] = table[codes[i] as usize];
            i += 1;
        }
    }

    /// Integer inner product: 16 i8 pairs per round via `smull` +
    /// pairwise-accumulate into i32 lanes — exact, no saturation.
    ///
    /// # Safety
    ///
    /// Caller must verify NEON. Reads only within the shorter slice.
    // lint: hot-path
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_i8(levels: &[i8], x: &[i8]) -> i32 {
        let n = levels.len().min(x.len());
        let mut s = vdupq_n_s32(0);
        let mut i = 0usize;
        while i + 16 <= n {
            let lv = vld1q_s8(levels.as_ptr().add(i));
            let xv = vld1q_s8(x.as_ptr().add(i));
            s = vpadalq_s16(s, vmull_s8(vget_low_s8(lv), vget_low_s8(xv)));
            s = vpadalq_s16(s, vmull_s8(vget_high_s8(lv), vget_high_s8(xv)));
            i += 16;
        }
        let mut total = vaddvq_s32(s);
        while i < n {
            total += levels[i] as i32 * x[i] as i32;
            i += 1;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_pref_parse_round_trips() {
        assert_eq!(TierPref::parse("auto"), Some(TierPref::Auto));
        assert_eq!(TierPref::parse("scalar"), Some(TierPref::Scalar));
        assert_eq!(TierPref::parse("avx2"), Some(TierPref::Avx2));
        assert_eq!(TierPref::parse("neon"), Some(TierPref::Neon));
        assert_eq!(TierPref::parse("bogus"), None);
        assert_eq!(TierPref::parse(""), None);
        assert_eq!(TierPref::parse("AVX2"), None);
    }

    #[test]
    fn unsupported_pref_degrades_to_scalar() {
        // At most one vector arch can be live on any host, so at least
        // one of the explicit vector preferences must degrade.
        let a = detect(TierPref::Avx2);
        let n = detect(TierPref::Neon);
        assert!(a == Tier::Scalar || n == Tier::Scalar, "a={:?} n={:?}", a, n);
        assert_eq!(detect(TierPref::Scalar), Tier::Scalar);
        // Auto resolves to whatever an explicit supported pref gives.
        let auto = detect(TierPref::Auto);
        assert!(auto == a || auto == n || auto == Tier::Scalar);
    }

    #[test]
    fn tier_names_and_ids_are_stable() {
        assert_eq!(Tier::Scalar.name(), "scalar");
        assert_eq!(Tier::Avx2.name(), "avx2");
        assert_eq!(Tier::Neon.name(), "neon");
        assert_eq!(Tier::Scalar.id(), 0);
        assert_eq!(Tier::Avx2.id(), 1);
        assert_eq!(Tier::Neon.id(), 2);
        assert_eq!(ActQuant::F32.name(), "f32");
        assert_eq!(ActQuant::Int8.name(), "int8");
    }

    #[test]
    fn scalar_dot_acc_matches_open_coded_loop() {
        let a: Vec<f32> = (0..37).map(|i| (i as f32 * 0.31).sin()).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32 * 0.17).cos()).collect();
        let mut want = 1.5f32;
        for (x, y) in a.iter().zip(&b) {
            want += *x * *y;
        }
        let got = dot_acc(Tier::Scalar, 1.5, &a, &b);
        assert_eq!(got.to_bits(), want.to_bits());
        // `dot` is dot_acc from zero.
        let mut w0 = 0.0f32;
        for (x, y) in a.iter().zip(&b) {
            w0 += *x * *y;
        }
        assert_eq!(dot(Tier::Scalar, &a, &b).to_bits(), w0.to_bits());
    }

    #[test]
    fn scalar_affine_and_axpy_match_reference() {
        let codes: Vec<u8> = (0..23).map(|i| (i * 7 % 16) as u8).collect();
        let mut out = vec![0.0f32; 23];
        affine_u8(Tier::Scalar, &codes, -1.25, 0.375, &mut out);
        for (o, &c) in out.iter().zip(&codes) {
            let want = -1.25 + 0.375 * c as f32;
            assert_eq!(o.to_bits(), want.to_bits());
        }
        let v: Vec<f32> = (0..23).map(|i| (i as f32 * 0.21).sin()).collect();
        let mut acc = vec![0.5f32; 23];
        let mut want = acc.clone();
        axpy(Tier::Scalar, &mut acc, 0.8, &v);
        for (o, vv) in want.iter_mut().zip(&v) {
            *o += 0.8 * *vv;
        }
        for (a, b) in acc.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn quantize_activations_degenerate_inputs() {
        let mut q = Vec::new();
        assert_eq!(quantize_activations(&[0.0, 0.0, 0.0], &mut q), 0.0);
        assert_eq!(q, vec![0i8; 3]);
        assert_eq!(quantize_activations(&[1.0, f32::NAN], &mut q), 0.0);
        assert_eq!(q, vec![0i8; 2]);
        assert_eq!(quantize_activations(&[f32::INFINITY], &mut q), 0.0);
        // Normal case: absmax maps to ±127 exactly.
        let s = quantize_activations(&[-2.0, 1.0, 2.0], &mut q);
        assert!(s > 0.0);
        assert_eq!(q, vec![-127i8, 64, 127]);
    }

    #[test]
    fn quantize_codebook_fills_staging_table() {
        let cb = [-1.0f32, -0.5, 0.5, 1.0];
        let mut t = [0i8; 256];
        let s = quantize_codebook(&cb, &mut t);
        assert!(s > 0.0);
        assert_eq!(&t[..4], &[-127i8, -64, 64, 127]);
        assert!(t[4..].iter().all(|&v| v == 0));
        let empty: [f32; 0] = [];
        assert_eq!(quantize_codebook(&empty, &mut t), 0.0);
    }

    #[test]
    fn int8_ops_exact_across_tiers() {
        // Integer gather + dot must agree exactly between scalar and
        // whatever vector tier this host offers.
        let tier = detect(TierPref::Auto);
        let mut table = [0i8; 256];
        for (i, t) in table.iter_mut().take(16).enumerate() {
            *t = (i as i8) * 5 - 40;
        }
        let codes: Vec<u8> = (0..67).map(|i| (i * 11 % 16) as u8).collect();
        let xs: Vec<i8> = (0..67).map(|i| ((i * 13 % 255) as i32 - 127) as i8).collect();
        let mut ls = vec![0i8; 67];
        let mut lv = vec![0i8; 67];
        gather_i8(Tier::Scalar, &codes, &table, 16, &mut ls);
        gather_i8(tier, &codes, &table, 16, &mut lv);
        assert_eq!(ls, lv);
        assert_eq!(dot_i8(Tier::Scalar, &ls, &xs), dot_i8(tier, &lv, &xs));
    }

    #[test]
    fn load_window_zero_pads_past_end() {
        let src = [0xABu8, 0xCD, 0xEF];
        assert_eq!(load_window(&src, 0), 0x00EF_CDAB);
        assert_eq!(load_window(&src, 2), 0xEF);
        assert_eq!(load_window(&src, 3), 0);
    }
}

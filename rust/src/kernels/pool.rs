//! Persistent worker pool for the fused kernels (DESIGN.md §8).
//!
//! The serving decode loop runs 7 projections × layers × one GEMM each,
//! **every token**. Spawning OS threads per call (`std::thread::scope`)
//! puts thread creation/teardown — tens of microseconds each — on the
//! per-token path, multiplied by every projection of every layer. A
//! [`WorkerPool`] spawns its workers once and parks them on a condvar;
//! dispatching a parallel region is a queue push + wakeup, and the
//! workers' stacks/TLS stay warm across calls. No external deps: plain
//! `std` threads, `Mutex`/`Condvar` parking, atomic chunk claiming.
//!
//! Execution model: [`WorkerPool::parallel_for`] publishes a job of `n`
//! index-addressed chunks; the **caller participates** (so a pool built
//! with `threads` executors spawns `threads − 1` workers and `threads =
//! 1` runs entirely inline), workers race to claim chunk indices via one
//! atomic counter, and the call returns only when every chunk finished.
//! Multiple threads may submit concurrently — jobs queue FIFO and
//! workers drain them in order.
//!
//! Determinism: chunk→output mapping is fixed by the caller (each output
//! element is written by exactly one closure invocation with a fixed
//! index), so results are bit-identical regardless of worker count or
//! which worker claims which chunk — the kernels' bit-identity contract
//! survives pooling unchanged (property-tested in `tests/kernels_prop.rs`).
//!
//! Panics: a panicking chunk is caught, the job still drains (the other
//! chunks complete), and the submitter receives the payload — via
//! [`PoolPanic`] from the `try_*` forms, which call sites use to attach
//! the failing work range (e.g. the GEMM band's weight rows) before
//! re-panicking, instead of poisoning the whole forward with a bare
//! `join()` expect.
//!
//! SIMD tier (DESIGN.md §14): the pool is deliberately **tier-agnostic**
//! — it schedules closures and knows nothing about vector ISAs. The
//! kernels carry their resolved `simd::Tier` by value into each chunk
//! closure, so a pool can serve scalar and vectorized callers
//! interchangeably and the chunk→output determinism argument above is
//! untouched by tier selection (within one tier; tiers differ only
//! inside the per-dot bounded-error contract).

use crate::trace::{self, Cat};
use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Threads worth using: the machine's available parallelism, or 1 when
/// it cannot be queried.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A chunk's panic, captured by the pool: which chunk index failed plus
/// the original payload.
pub struct PoolPanic {
    /// Index of the panicking chunk (the `i` passed to the job).
    pub task: usize,
    payload: Box<dyn Any + Send>,
}

impl PoolPanic {
    /// Best-effort text of the payload (`&str`/`String` panics; the
    /// overwhelmingly common case).
    pub fn message(&self) -> &str {
        if let Some(s) = self.payload.downcast_ref::<&'static str>() {
            s
        } else if let Some(s) = self.payload.downcast_ref::<String>() {
            s.as_str()
        } else {
            "<non-string panic payload>"
        }
    }

    /// Re-raise the original payload.
    pub fn resume(self) -> ! {
        std::panic::resume_unwind(self.payload)
    }
}

/// Type-erased pointer to the caller's job closure plus a monomorphized
/// trampoline that calls it. The submitter blocks until every chunk
/// completes, so the pointee outlives every dereference; after the last
/// chunk is claimed no executor touches it again (claims past `n`
/// return without dereferencing).
#[derive(Clone, Copy)]
struct JobPtr {
    data: *const (),
    call: unsafe fn(*const (), usize),
}
// SAFETY: the raw pointer is only ever dereferenced through the paired
// trampoline while the submitting thread blocks in `try_parallel_for`,
// so moving a `JobPtr` to a worker never outlives the pointee.
unsafe impl Send for JobPtr {}
// SAFETY: `Job::new` only erases closures bounded by `F: Fn + Sync`, so
// concurrent trampoline calls from many workers are shared `&F` calls.
unsafe impl Sync for JobPtr {}

/// Trampoline: recover the concrete closure type and call it.
///
/// # Safety
/// `data` must point to a live `F` — upheld by the `JobPtr` invariant
/// that the submitter blocks until every chunk completes, keeping the
/// closure borrowed on its stack for the whole region.
unsafe fn call_job<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    (*(data as *const F))(i)
}

/// No-op trampoline for placeholder jobs (never claimed).
///
/// # Safety
/// No preconditions: the pointer is never dereferenced. Used with a null
/// `data` in the rebuilt shell of `try_parallel_for`.
unsafe fn call_nothing(_: *const (), _: usize) {}

/// One published parallel region.
struct Job {
    job: JobPtr,
    n: usize,
    /// Next unclaimed chunk index (may grow past `n`).
    next: AtomicUsize,
    /// Chunks not yet finished.
    pending: AtomicUsize,
    /// First captured panic (chunk index, payload).
    panic: Mutex<Option<(usize, Box<dyn Any + Send>)>>,
    done_mx: Mutex<()>,
    done_cv: Condvar,
}

impl Job {
    fn new<F: Fn(usize) + Sync>(job: &F, n: usize) -> Job {
        // Lifetime erasure through a thin pointer; `JobPtr`'s invariant
        // (submitter outlives all dereferences) restores soundness.
        Job {
            job: JobPtr { data: job as *const F as *const (), call: call_job::<F> },
            n,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n),
            panic: Mutex::new(None),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
        }
    }

    /// Claim and run chunks until none are left.
    fn drain(&self) {
        let job = self.job;
        loop {
            // ORDERING: relaxed — the counter only needs each index
            // claimed exactly once (fetch_add atomicity); the caller's
            // data is published to workers by the queue mutex, and chunk
            // completion is published back by the AcqRel `pending`
            // decrement below, so no claim carries payload ordering.
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                return;
            }
            // SAFETY: see `JobPtr` — valid for the region's duration.
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| unsafe {
                (job.call)(job.data, i)
            })) {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some((i, payload));
                }
            }
            if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                // Last chunk: wake the submitter. Taking the lock before
                // notifying closes the check-then-wait race.
                let _g = self.done_mx.lock().unwrap();
                self.done_cv.notify_all();
            }
        }
    }
}

struct Queue {
    jobs: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    work_cv: Condvar,
}

/// Persistent `std::thread` worker pool with chunked `parallel_for`.
/// Workers park between jobs; dropping the pool joins them.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// A pool with `threads` executors **including the caller**:
    /// `threads − 1` workers are spawned and parked; `threads ≤ 1`
    /// spawns nothing and runs every region inline. `0` means all
    /// available cores.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = if threads == 0 { available_threads() } else { threads.max(1) };
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
        });
        let handles = (0..threads - 1)
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || Self::worker(&shared))
            })
            .collect();
        WorkerPool { shared, handles, threads }
    }

    /// Executor count (parked workers + the participating caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn worker(shared: &Shared) {
        loop {
            let job = {
                // Park interval: from re-entering the wait loop to
                // claiming the next job (or shutdown).
                let _park = trace::span(Cat::Pool, "park", 0);
                let mut q = shared.queue.lock().unwrap();
                loop {
                    if q.shutdown {
                        return;
                    }
                    // Retire fully-claimed jobs from the front.
                    while let Some(j) = q.jobs.front() {
                        // ORDERING: relaxed — a retirement heuristic
                        // under the queue mutex; a stale (low) value only
                        // delays popping, and a claim racing past `n` is
                        // handled by `drain` returning early.
                        if j.next.load(Ordering::Relaxed) >= j.n {
                            q.jobs.pop_front();
                        } else {
                            break;
                        }
                    }
                    if let Some(j) = q.jobs.front() {
                        break j.clone();
                    }
                    q = shared.work_cv.wait(q).unwrap();
                }
            };
            let _busy = trace::span_args(Cat::Pool, "busy", 0, job.n as i64, 0);
            job.drain();
        }
    }

    /// Run `job(i)` for every `i in 0..tasks` across the pool; blocks
    /// until all complete. Panics propagate (first payload wins).
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, tasks: usize, job: &F) {
        if let Err(p) = self.try_parallel_for(tasks, job) {
            p.resume();
        }
    }

    /// Like [`Self::parallel_for`], but a panicking chunk is returned as
    /// [`PoolPanic`] (with its chunk index) instead of re-raised — call
    /// sites use it to attach the failing work range to the message.
    pub fn try_parallel_for<F: Fn(usize) + Sync>(
        &self,
        tasks: usize,
        job: &F,
    ) -> Result<(), PoolPanic> {
        if tasks == 0 {
            return Ok(());
        }
        let region = Job::new(job, tasks);
        if self.handles.is_empty() || tasks == 1 {
            trace::instant(Cat::Pool, "dispatch", 0, tasks as i64, 0);
            region.drain(); // inline: nothing to wake, nothing to wait on
            return Self::finish(region);
        }
        let region = Arc::new(region);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.jobs.push_back(region.clone());
            trace::instant(Cat::Pool, "dispatch", 0, tasks as i64, q.jobs.len() as i64);
        }
        // Wake only as many workers as there are chunks beyond the one
        // the caller will take — small regions on a wide pool must not
        // thundering-herd every parked worker per token. Correctness
        // never depends on wakeups: the caller drains its own region.
        let wake = (tasks - 1).min(self.handles.len());
        for _ in 0..wake {
            self.shared.work_cv.notify_one();
        }
        region.drain(); // the caller is an executor too
        let mut g = region.done_mx.lock().unwrap();
        while region.pending.load(Ordering::Acquire) != 0 {
            g = region.done_cv.wait(g).unwrap();
        }
        drop(g);
        let region = Arc::try_unwrap(region).unwrap_or_else(|arc| {
            // A worker may still hold a clone for an instant after the
            // final decrement; the job is complete either way — rebuild
            // an owned shell around the shared panic slot.
            let payload = arc.panic.lock().unwrap().take();
            Job {
                job: JobPtr { data: std::ptr::null(), call: call_nothing },
                n: 0,
                next: AtomicUsize::new(0),
                pending: AtomicUsize::new(0),
                panic: Mutex::new(payload),
                done_mx: Mutex::new(()),
                done_cv: Condvar::new(),
            }
        });
        Self::finish(region)
    }

    fn finish(region: Job) -> Result<(), PoolPanic> {
        // PANIC: `into_inner` only errs on poisoning, and the slot is
        // written strictly under `catch_unwind` — a poisoned slot means a
        // bug in the pool itself, which must not be papered over.
        match region.panic.into_inner().unwrap() {
            Some((task, payload)) => {
                trace::instant(Cat::Pool, "panic", 0, task as i64, 0);
                if trace::enabled() {
                    trace::flight_dump(&format!("PoolPanic in chunk {}", task));
                }
                Err(PoolPanic { task, payload })
            }
            None => Ok(()),
        }
    }

    /// Chunked parallel-for over disjoint consecutive `chunk`-sized
    /// pieces of `data` (the last may be short): `f(i, piece_i)`.
    pub fn for_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
        &self,
        data: &mut [T],
        chunk: usize,
        f: F,
    ) {
        if let Err(p) = self.try_for_chunks_mut(data, chunk, f) {
            p.resume();
        }
    }

    /// [`Self::for_chunks_mut`] with [`PoolPanic`] reporting.
    pub fn try_for_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
        &self,
        data: &mut [T],
        chunk: usize,
        f: F,
    ) -> Result<(), PoolPanic> {
        assert!(chunk > 0, "chunk must be positive");
        let len = data.len();
        if len == 0 {
            return Ok(());
        }
        struct Base<T>(*mut T);
        // SAFETY: the base pointer derives from an exclusive `&mut [T]`
        // borrow held across the whole region, and `T: Send` lets the
        // elements themselves cross threads.
        unsafe impl<T: Send> Send for Base<T> {}
        // SAFETY: workers sharing `&Base` never touch overlapping memory —
        // each claimed chunk index maps to a disjoint subslice and the
        // pool claims every index exactly once.
        unsafe impl<T: Send> Sync for Base<T> {}
        let base = Base(data.as_mut_ptr());
        self.try_parallel_for(len.div_ceil(chunk), &|i| {
            let start = i * chunk;
            let end = (start + chunk).min(len);
            // SAFETY: disjoint range per claimed index (see above).
            let piece = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
            f(i, piece);
        })
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The process-wide pool the convenience kernels (`gemv_mt`/`gemm_mt`)
/// dispatch through, sized to all available cores and spawned lazily on
/// first use. Components with their own sizing
/// ([`NativeModel`](crate::kernels::NativeModel)) hold their own pool
/// instead.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(available_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_chunk_exactly_once() {
        for threads in [1usize, 2, 4, 9] {
            let pool = WorkerPool::new(threads);
            assert_eq!(pool.threads(), threads);
            let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
            pool.parallel_for(97, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {} (threads {})", i, threads);
            }
        }
    }

    #[test]
    fn for_chunks_mut_partitions_exactly() {
        let pool = WorkerPool::new(4);
        for (len, chunk) in [(100usize, 7usize), (8, 8), (9, 8), (1, 3), (64, 1)] {
            let mut data = vec![0u32; len];
            pool.for_chunks_mut(&mut data, chunk, |i, piece| {
                for (j, v) in piece.iter_mut().enumerate() {
                    *v = (i * chunk + j) as u32;
                }
            });
            for (j, v) in data.iter().enumerate() {
                assert_eq!(*v, j as u32, "len={} chunk={}", len, chunk);
            }
        }
    }

    #[test]
    fn pool_reuse_across_many_regions() {
        // The point of the pool: many cheap regions on warm workers.
        let pool = WorkerPool::new(3);
        let total = AtomicU64::new(0);
        for _ in 0..200 {
            pool.parallel_for(5, &|i| {
                total.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 200 * 15);
    }

    #[test]
    fn panic_reports_chunk_and_message_and_job_drains() {
        let pool = WorkerPool::new(2);
        let done = AtomicU64::new(0);
        let err = pool
            .try_parallel_for(8, &|i| {
                if i == 5 {
                    panic!("chunk five exploded");
                }
                done.fetch_add(1, Ordering::Relaxed);
            })
            .expect_err("must surface the panic");
        assert_eq!(err.task, 5);
        assert!(err.message().contains("chunk five exploded"));
        // Every other chunk still ran: the region drains, it is not torn
        // down mid-flight.
        assert_eq!(done.load(Ordering::Relaxed), 7);
        // The pool survives a panicked region.
        pool.parallel_for(4, &|_| {});
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn parallel_for_resumes_panic() {
        let pool = WorkerPool::new(2);
        pool.parallel_for(3, &|i| {
            if i == 1 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn concurrent_submitters_share_one_pool() {
        let pool = Arc::new(WorkerPool::new(4));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let pool = pool.clone();
            handles.push(std::thread::spawn(move || {
                let sum = AtomicU64::new(0);
                for _ in 0..50 {
                    pool.parallel_for(11, &|i| {
                        sum.fetch_add(i as u64, Ordering::Relaxed);
                    });
                }
                (t, sum.into_inner())
            }));
        }
        for h in handles {
            let (_, got) = h.join().unwrap();
            assert_eq!(got, 50 * 55);
        }
    }

    #[test]
    fn zero_and_one_tasks() {
        let pool = WorkerPool::new(4);
        pool.parallel_for(0, &|_| panic!("must not run"));
        let ran = AtomicU64::new(0);
        pool.parallel_for(1, &|i| {
            assert_eq!(i, 0);
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.into_inner(), 1);
    }
}

//! Fused quantized-plane CPU kernels (DESIGN.md §8).
//!
//! The paper's deployment argument is that low-bit inference is
//! memory-bound: latency is set by the weight bytes a matmul must
//! stream, so a server that dequantizes every layer to f32 before the
//! GEMV throws the 2.3-bit footprint away exactly where it pays. This
//! subsystem keeps weights **bit-packed** in the fused (n+1)-bit
//! [`RuntimePlane`](crate::icquant::runtime::RuntimePlane) form all the
//! way through the matmul — the hot loop streams `(n+1)/8` bytes per
//! weight, not the full byte the v1 layout moved:
//!
//! * [`gemv`] / [`gemv_mt`] / [`gemv_on`] — `y = Wx` via per-block
//!   unpack + per-row codebook gather + accumulate.
//! * [`gemm`] / [`gemm_mt`] / [`gemm_on`] — the batched form `y = xWᵀ`,
//!   unpacking and decoding each weight block once per batch.
//! * [`pool`] — the persistent [`WorkerPool`] the multi-threaded paths
//!   dispatch through: workers spawn once and park between calls, so
//!   the 7-projections-×-layers-×-every-token decode loop pays a queue
//!   push per region instead of a `thread::scope` spawn.
//! * [`model`] — a full native CPU Llama-mini forward (RMSNorm, RoPE
//!   attention, SwiGLU) whose every projection runs through the fused
//!   kernels on the model's own pool: the zero-PJRT serving path behind
//!   [`NativeBackend`](crate::coordinator::backend::NativeBackend). Its
//!   [`KvCache`] is **paged** (DESIGN.md §10): fixed-size token blocks
//!   behind per-slot block tables, refcounted so identical prompt
//!   prefixes share one physical copy (copy-on-write on divergence) —
//!   the weight planes made the weights small; paging makes the KV
//!   cache, the next bottleneck, dense too.
//!
//! All kernels are **bit-identical** to dequantize-then-matmul (see the
//! accumulation contract in [`gemv`]'s module docs and the property
//! tests in `tests/kernels_prop.rs`), at any pool width; `benches/
//! kernels.rs` records the packed-vs-byte and pool-vs-spawn wins as
//! `BENCH_kernels.json`.
//!
//! * [`simd`] — the runtime-dispatched SIMD tier (DESIGN.md §14): the
//!   plain entry points above stay on the scalar bit-identity
//!   reference, while the `*_tier` forms ([`gemv_tier`], [`gemm_tier`],
//!   [`gemv_on_tier`], [`gemm_on_tier`]) and the int8-activation GEMV
//!   ([`gemv_i8`], [`gemv_i8_on`]) dispatch their inner loops on a
//!   resolved [`Tier`] under a bounded-error divergence contract
//!   (`tests/simd_divergence.rs`).

mod gemv;
pub mod model;
pub mod pool;
pub mod simd;

pub use gemv::{gemm, gemm_mt, gemm_on, gemv, gemv_mt, gemv_on};
pub use gemv::{gemm_on_tier, gemm_tier, gemv_i8, gemv_i8_on, gemv_on_tier, gemv_tier};
#[doc(hidden)]
pub use gemv::gemv_rows;
pub use model::{KvCache, KvCacheStats, KvLayout, NativeModel, DEFAULT_BLOCK_TOKENS};
pub use pool::{available_threads, PoolPanic, WorkerPool};
pub use simd::{ActQuant, Tier, TierPref};

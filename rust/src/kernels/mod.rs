//! Fused quantized-plane CPU kernels (DESIGN.md §8).
//!
//! The paper's deployment argument is that low-bit inference is
//! memory-bound: latency is set by the weight bytes a matmul must
//! stream, so a server that dequantizes every layer to f32 before the
//! GEMV throws the 2.3-bit footprint away exactly where it pays. This
//! subsystem keeps weights in the fused (n+1)-bit
//! [`RuntimePlane`](crate::icquant::runtime::RuntimePlane) form all the
//! way through the matmul:
//!
//! * [`gemv`] / [`gemv_mt`] — `y = Wx` via per-row codebook gather +
//!   accumulate, row-partitioned across scoped `std::thread`s.
//! * [`gemm`] / [`gemm_mt`] — the batched form `y = xWᵀ`, decoding each
//!   weight block once and reusing it across the batch.
//! * [`model`] — a full native CPU Llama-mini forward (RMSNorm, RoPE
//!   attention, SwiGLU) whose every projection runs through the fused
//!   kernels: the zero-PJRT serving path behind
//!   [`NativeBackend`](crate::coordinator::backend::NativeBackend).
//!
//! All kernels are **bit-identical** to dequantize-then-matmul (see the
//! accumulation contract in [`gemv`]'s module docs and the property
//! tests in `tests/kernels_prop.rs`); `benches/kernels.rs` records the
//! latency/footprint wins as `BENCH_kernels.json`.

mod gemv;
pub mod model;

pub use gemv::{available_threads, gemm, gemm_mt, gemv, gemv_mt};
pub use model::{KvCache, NativeModel};

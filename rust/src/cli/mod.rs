//! Command-line interface (hand-rolled — clap is not in the offline
//! registry).
//!
//! ```text
//! icquant exp <id|all> [--fast]      regenerate a paper table/figure
//! icquant quantize [opts]            quantize a tensor → .icqm artifact
//! icquant pack [opts]                quantize a zoo model → .icqz container
//! icquant inspect <file|name[@hash]> show an ICQZ container's TOC
//! icquant verify <file|name[@hash]>  full integrity check (CRCs, layout)
//! icquant store list|gc              artifact-registry maintenance
//! icquant stats --family <name>      outlier statistics for a zoo family
//! icquant bound [--gamma g]          Lemma 1 bound table + optimal b
//! icquant serve [opts]               run the serving demo (PJRT or
//!                                    native fused-kernel backend)
//! icquant trace-check <file>         validate a --trace-out trace file
//! icquant eval [--bits n ...]        perplexity of FP vs ICQuant model
//! icquant zoo                        list synthetic model families
//! icquant help
//! ```

pub mod serve_demo;

use crate::experiments;
use crate::icquant::{packed, IcqConfig, IcqMatrix};
use crate::kernels::simd::{self, ActQuant, TierPref};
use crate::quant::QuantizerKind;
use crate::store::{self, container, Registry};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed flag set: positionals + `--key value` + `--flag` booleans.
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    // `--key=value` form (e.g. `serve --backend=native`).
                    flags.insert(k.to_string(), v.to_string());
                    i += 1;
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn f64_flag(&self, key: &str, default: f64) -> Result<f64> {
        match self.flag(key) {
            Some(v) => v.parse::<f64>().with_context(|| format!("--{} {}", key, v)),
            None => Ok(default),
        }
    }

    pub fn usize_flag(&self, key: &str, default: usize) -> Result<usize> {
        match self.flag(key) {
            Some(v) => v.parse::<usize>().with_context(|| format!("--{} {}", key, v)),
            None => Ok(default),
        }
    }
}

pub fn run() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {:#}", e);
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd {
        "exp" => cmd_exp(&args),
        "quantize" => cmd_quantize(&args),
        "pack" => cmd_pack(&args),
        "inspect" => cmd_inspect(&args),
        "verify" => cmd_verify(&args),
        "store" => cmd_store(&args),
        "stats" => cmd_stats(&args),
        "bound" => cmd_bound(&args),
        "serve" => cmd_serve(&args),
        "trace-check" => cmd_trace_check(&args),
        "lint" => cmd_lint(&args),
        "eval" => cmd_eval(&args),
        "zoo" => cmd_zoo(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{}' (try `icquant help`)", other),
    }
}

fn print_help() {
    println!("ICQuant — Index Coding enables Low-bit LLM Quantization");
    println!();
    println!("USAGE: icquant <command> [options]");
    println!();
    println!("  exp <id|all> [--fast]         regenerate a paper table/figure:");
    for e in experiments::registry() {
        println!("      {:<8} {}", e.id, e.paper_artifact);
    }
    println!("  quantize [--bits n] [--ratio g] [--quantizer rtn|sk]");
    println!("           [--rows r --cols c --seed s] [--out file.icqm]");
    println!("                                quantize a (synthetic) matrix");
    println!("  pack [--family f] [--bits n] [--ratio g] [--gap b]");
    println!("       [--quantizer rtn|sk] [--blocks k] [--out file.icqz]");
    println!("       [--name reg-name] [--store dir]");
    println!("                                quantize a zoo model into one");
    println!("                                ICQZ container (+ registry put)");
    println!("  inspect <file|name[@hash]>    show a container's TOC/accounting");
    println!("  verify <file|name[@hash]>     full integrity check (CRC32s,");
    println!("                                padding, layout, accounting)");
    println!("  store list|gc [--store dir]   artifact-registry maintenance");
    println!("  stats --family <name>         outlier stats for a zoo family");
    println!("  bound [--gamma g]             Lemma 1 bound + optimal b");
    println!("  serve [--requests n] [--batch n] [--tokens n] [--quantized]");
    println!("        [--backend pjrt|native] [--family f] [--bits n]");
    println!("        [--threads t] [--block-size b] [--kv-bits 4|8|off]");
    println!("        [--simd auto|scalar|avx2|neon] [--act-quant f32|int8]");
    println!("        [--trace-out f.json]");
    println!("                                batched serving demo;");
    println!("                                pjrt = AOT HLO (needs artifacts),");
    println!("                                native = fused quantized-plane CPU");
    println!("                                kernels, no artifacts needed;");
    println!("                                --kv-bits quantizes filled KV blocks");
    println!("                                in place with ICQ index coding");
    println!("                                (off = full f32, the default);");
    println!("                                --simd pins the kernel tier (default:");
    println!("                                ICQ_SIMD, else auto-detect);");
    println!("                                --act-quant int8 quantizes decode");
    println!("                                activations for the integer GEMV;");
    println!("                                --trace-out writes a Chrome/Perfetto");
    println!("                                trace of the run");
    println!("  trace-check <trace.json>      validate an emitted trace (schema,");
    println!("                                balanced spans, categories,");
    println!("                                registered event names)");
    println!("  lint [--root dir] [--json]    in-tree static analysis: SAFETY/");
    println!("                                ORDERING/PANIC justifications,");
    println!("                                hot-path allocation bans, DESIGN");
    println!("                                refs, BENCH keys, trace-name");
    println!("                                registry (DESIGN.md, section 13)");
    println!("  eval [--bits n] [--ratio g]   ppl: FP vs ICQuant^SK");
    println!("  zoo                           list synthetic model families");
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    experiments::run(id, args.bool_flag("fast"))
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let bits = args.usize_flag("bits", 2)? as u32;
    let ratio = args.f64_flag("ratio", 0.05)?;
    let rows = args.usize_flag("rows", 256)?;
    let cols = args.usize_flag("cols", 1024)?;
    let seed = args.usize_flag("seed", 7)? as u64;
    let quantizer: QuantizerKind = args.flag("quantizer").unwrap_or("rtn").parse()?;
    let w = crate::synthzoo::demo_matrix(rows, cols, seed);
    let cfg = IcqConfig { bits, outlier_ratio: ratio, gap_bits: 0, quantizer };
    let t0 = std::time::Instant::now();
    let q = IcqMatrix::quantize(&w, None, &cfg)?;
    let dt = t0.elapsed();
    let rec = q.dequantize();
    println!(
        "quantized {}x{} with {:?} ({} bits, γ={:.2}%)",
        rows, cols, quantizer, bits, ratio * 100.0
    );
    println!("  gap width b          : {} (Lemma-1 optimal)", q.gap_bits);
    println!("  index overhead B     : {:.4} bits/weight", q.index_bits_per_weight());
    println!(
        "  total bits/weight    : {:.3} (+codebooks: {:.3})",
        q.avg_bits_per_weight(),
        q.avg_bits_per_weight_full()
    );
    println!("  reconstruction MSE   : {:.4e}", w.mse(&rec));
    println!("  quantization time    : {}", crate::util::human_duration(dt));
    if let Some(path) = args.flag("out") {
        packed::save(&q, std::path::Path::new(path))?;
        let size = std::fs::metadata(path)?.len();
        println!(
            "  artifact             : {} ({})",
            path,
            crate::util::human_bytes(size)
        );
    }
    Ok(())
}

fn registry_from(args: &Args) -> Result<Registry> {
    let root = args
        .flag("store")
        .map(PathBuf::from)
        .unwrap_or_else(Registry::default_root);
    Registry::open(root)
}

/// `inspect`/`verify` accept either a filesystem path or a registry
/// `name[@hash]` spec.
fn resolve_container(args: &Args, spec: &str) -> Result<PathBuf> {
    let p = Path::new(spec);
    if p.exists() {
        return Ok(p.to_path_buf());
    }
    let (record, path) = registry_from(args)?.resolve(spec)?;
    println!("resolved {} → {}", record.spec(), path.display());
    Ok(path)
}

fn cmd_pack(args: &Args) -> Result<()> {
    let family_name = args.flag("family").unwrap_or("llama3.2-1b");
    let family = crate::synthzoo::family(family_name)
        .ok_or_else(|| anyhow::anyhow!("unknown family '{}' (see `icquant zoo`)", family_name))?;
    let bits = args.usize_flag("bits", 2)? as u32;
    let ratio = args.f64_flag("ratio", 0.05)?;
    let gap_bits = args.usize_flag("gap", 0)? as u32;
    let quantizer: QuantizerKind = args.flag("quantizer").unwrap_or("rtn").parse()?;
    let blocks = match args.usize_flag("blocks", 0)? {
        0 => None,
        n => Some(n),
    };
    let cfg = IcqConfig { bits, outlier_ratio: ratio, gap_bits, quantizer };
    let out = args
        .flag("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{}.icqz", family_name)));

    let t0 = std::time::Instant::now();
    let model = store::synth_model(&family, &cfg, blocks)?;
    container::save(&model, &out)?;
    let dt = t0.elapsed();
    let info = container::inspect(&out)?;
    println!(
        "packed {} ({} sections, {} quantized / {} dense params) in {}",
        out.display(),
        info.sections.len(),
        info.quantized_params,
        info.dense_params,
        crate::util::human_duration(dt)
    );
    println!(
        "  storage bits/weight  : {:.4} (measured over serialized sections)",
        info.storage_bits_per_weight
    );
    println!(
        "  code bits/weight     : {:.4} (n + B)   full: {:.4} (+codebooks)",
        info.code_bits_per_weight, info.full_bits_per_weight
    );
    println!(
        "  container size       : {}",
        crate::util::human_bytes(info.file_len)
    );
    if let Some(name) = args.flag("name") {
        let record = registry_from(args)?.put_file(name, &out)?;
        println!("  registered           : {}", record.spec());
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let spec = args
        .positional
        .first()
        .context("usage: icquant inspect <file|name[@hash]>")?;
    let path = resolve_container(args, spec)?;
    let info = container::inspect(&path)?;
    println!("{}", path.display());
    if let Some(c) = &info.config {
        println!(
            "  config: vocab={} d_model={} layers={} heads={} d_ff={} max_seq={}",
            c.vocab, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.max_seq
        );
    } else {
        println!("  config: (none)");
    }
    println!(
        "  params: {} quantized + {} dense | bits/weight: {:.4} storage, {:.4} code, {:.4} full",
        info.quantized_params,
        info.dense_params,
        info.storage_bits_per_weight,
        info.code_bits_per_weight,
        info.full_bits_per_weight
    );
    println!(
        "  {} sections, data at {} (64-byte aligned), {} total",
        info.sections.len(),
        info.data_start,
        crate::util::human_bytes(info.file_len)
    );
    println!(
        "\n  {:<16} {:>4} {:>14} {:>10} {:>10}  {}",
        "name", "kind", "shape", "offset", "bytes", "crc32"
    );
    for s in &info.sections {
        let shape = s
            .shape
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("x");
        println!(
            "  {:<16} {:>4} {:>14} {:>10} {:>10}  {:08x}",
            s.name,
            s.kind.as_str(),
            shape,
            s.offset,
            s.len,
            s.crc32
        );
    }

    // Per-layer quantization observability (the paper's own §2/§3
    // statistics, measured from the stored payloads): outlier
    // fraction, gap width b, index-coding overhead B, effective
    // bits/weight, and codebook dynamic range.
    let model = container::load(&path)?;
    let mut header = false;
    for (name, payload) in &model.entries {
        let m = match payload {
            store::TensorPayload::Quantized(m) => m,
            _ => continue,
        };
        if !header {
            println!(
                "\n  {:<16} {:>12} {:>9} {:>3} {:>8} {:>8} {:>8}  {}",
                "quantized", "shape", "outlier%", "b", "B idx", "bits n+B", "+cbooks",
                "codebook range"
            );
            header = true;
        }
        let n_out: u64 = m.index_codes.iter().map(|c| c.n_outliers as u64).sum();
        let frac = n_out as f64 / (m.rows * m.cols) as f64;
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for cb in m.inlier_cbs.iter().chain(m.outlier_cbs.iter()) {
            for &l in &cb.levels {
                lo = lo.min(l);
                hi = hi.max(l);
            }
        }
        println!(
            "  {:<16} {:>12} {:>8.2}% {:>3} {:>8.4} {:>8.3} {:>8.3}  [{:+.3}, {:+.3}]",
            name,
            format!("{}x{}", m.rows, m.cols),
            frac * 100.0,
            m.gap_bits,
            m.index_bits_per_weight(),
            m.avg_bits_per_weight(),
            m.avg_bits_per_weight_full(),
            lo,
            hi
        );
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let spec = args
        .positional
        .first()
        .context("usage: icquant verify <file|name[@hash]>")?;
    let p = Path::new(spec);
    let report = if p.exists() {
        container::verify(p)?
    } else {
        // Registry specs additionally re-check the content hash.
        registry_from(args)?.verify(spec)?
    };
    println!(
        "verified {} sections, {} bytes",
        report.sections_checked,
        report.bytes_checked
    );
    if report.ok() {
        println!("OK: container is intact");
        Ok(())
    } else {
        for issue in &report.issues {
            eprintln!("  FAIL: {}", issue);
        }
        bail!("{} integrity issue(s) found in {}", report.issues.len(), spec);
    }
}

fn cmd_store(args: &Args) -> Result<()> {
    let reg = registry_from(args)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("list") => {
            let records = reg.list()?;
            if records.is_empty() {
                println!("registry {} is empty", reg.root().display());
                return Ok(());
            }
            println!(
                "{:<44} {:>10} {:>10} {:>12}",
                "artifact", "size", "bits/w", "created"
            );
            for r in records {
                println!(
                    "{:<44} {:>10} {:>10.3} {:>12}",
                    r.spec(),
                    crate::util::human_bytes(r.bytes),
                    r.storage_bits_per_weight,
                    r.created_unix
                );
            }
            Ok(())
        }
        Some("gc") => {
            let removed = reg.gc()?;
            println!("removed {} unreferenced object(s)", removed.len());
            for p in removed {
                println!("  {}", p.display());
            }
            Ok(())
        }
        other => bail!("usage: icquant store <list|gc> (got {:?})", other),
    }
}

fn cmd_stats(args: &Args) -> Result<()> {
    let name = args.flag("family").unwrap_or("llama2-7b");
    let f = crate::synthzoo::family(name)
        .ok_or_else(|| anyhow::anyhow!("unknown family '{}' (see `icquant zoo`)", name))?;
    println!(
        "[{}] d_model={} d_ff={} blocks={} (~{} params simulated)",
        f.name,
        f.d_model,
        f.d_ff,
        f.n_blocks,
        f.param_count()
    );
    println!(
        "\n{:<12} {:>12} {:>14} {:>16}",
        "layer", "range@5%", "chi2 reject", "icq B (b=6)"
    );
    for lt in crate::synthzoo::LayerType::ALL {
        let w = f.gen_stat_layer(lt, 0);
        let range = crate::stats::avg_range_taken(&w, 0.05);
        let rej = crate::stats::rejection_rate(&w, 0.0625, 256, 0.05);
        let k = (0.05 * w.cols as f64) as usize;
        let rows: Vec<Vec<usize>> = (0..w.rows)
            .map(|r| crate::quant::mixed_precision::top_k_by_magnitude(w.row(r), k))
            .collect();
        let b = crate::icq::bound::empirical_overhead(&rows, w.cols, 6);
        println!(
            "{:<12} {:>12.3} {:>13.2}% {:>16.4}",
            lt.name(),
            range,
            rej * 100.0,
            b
        );
    }
    Ok(())
}

fn cmd_bound(args: &Args) -> Result<()> {
    let gamma = args.f64_flag("gamma", 0.05)?;
    println!("Lemma 1 bound at γ={:.2}%:", gamma * 100.0);
    for b in 3..=10u32 {
        let bound = crate::icq::lemma1_bound(gamma, b);
        let marker = if b == crate::icq::optimal_b(gamma) {
            "  ← optimal"
        } else {
            ""
        };
        println!("  b={:<2}  B ≤ {:.4} bits/weight{}", b, bound, marker);
    }
    let c = crate::icq::bound::storage_comparison(gamma, 50_000);
    println!("\nvs alternatives (d_in=50k, as §3.2):");
    println!("  binary mask      : {:.3} bits/weight", c.binary_mask);
    println!("  absolute indices : {:.3} bits/weight", c.absolute_indices);
    println!("  ICQuant (b={})    : {:.3} bits/weight", c.icquant_b, c.icquant);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n_requests = args.usize_flag("requests", 16)?;
    let max_batch = args.usize_flag("batch", 8)?;
    let tokens = args.usize_flag("tokens", 16)?;
    let trace_out = args.flag("trace-out");
    // KV-block quantization width (native backend; DESIGN.md §12).
    // "off" (the default) keeps every block f32 and is bit-identical
    // to the pre-quantization serving path.
    let kv_bits = match args.flag("kv-bits").unwrap_or("off") {
        "off" => None,
        "4" => Some(4),
        "8" => Some(8),
        other => bail!("unknown --kv-bits '{}' (expected 4|8|off)", other),
    };
    // SIMD kernel tier (native backend; DESIGN.md §14). The flag
    // outranks `ICQ_SIMD`; with neither, auto-detect.
    let simd_pref = match args.flag("simd") {
        None => simd::env_pref(),
        Some(s) => match TierPref::parse(s) {
            Some(p) => p,
            None => bail!("unknown --simd '{}' (expected auto|scalar|avx2|neon)", s),
        },
    };
    let act_quant = match args.flag("act-quant").unwrap_or("f32") {
        "f32" | "off" => ActQuant::F32,
        "int8" => ActQuant::Int8,
        other => bail!("unknown --act-quant '{}' (expected f32|int8)", other),
    };
    match args.flag("backend").unwrap_or("pjrt") {
        "pjrt" => serve_demo::run(
            n_requests,
            max_batch,
            tokens,
            args.bool_flag("quantized"),
            trace_out,
        ),
        "native" => serve_demo::run_native(
            n_requests,
            max_batch,
            tokens,
            args.flag("family").unwrap_or("llama3.2-1b"),
            args.usize_flag("bits", 2)? as u32,
            args.usize_flag("threads", 0)?, // 0 ⇒ all cores
            args.usize_flag("block-size", 0)?, // 0 ⇒ default KV block size
            kv_bits,
            simd_pref,
            act_quant,
            trace_out,
        ),
        other => bail!("unknown backend '{}' (expected pjrt|native)", other),
    }
}

/// Validate a Chrome trace-event JSON file emitted by `serve
/// --trace-out` (or [`crate::trace::Tracer::export`]): non-empty
/// `traceEvents`, balanced B/E pairs per thread, per-thread monotone
/// timestamps, and all four event categories present. This is the CI
/// trace gate (`ci.sh`).
fn cmd_trace_check(args: &Args) -> Result<()> {
    use crate::util::json::Json;
    let path = args
        .positional
        .first()
        .context("usage: icquant trace-check <trace.json>")?;
    let text = std::fs::read_to_string(path).with_context(|| path.to_string())?;
    let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {}", path, e))?;
    let events = doc
        .req("traceEvents")?
        .as_arr()
        .context("traceEvents is not an array")?;
    anyhow::ensure!(!events.is_empty(), "trace has no events");

    let mut cats: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    // Per-tid open-span depth and last timestamp.
    let mut depth: HashMap<i64, i64> = HashMap::new();
    let mut last_ts: HashMap<i64, f64> = HashMap::new();
    for (i, e) in events.iter().enumerate() {
        let ph = e.req("ph")?.as_str().context("ph not a string")?;
        let tid = e.req("tid")?.as_i64().context("tid not an int")?;
        let ts = e.req("ts")?.as_f64().context("ts not a number")?;
        let cat = e.req("cat")?.as_str().context("cat not a string")?;
        let name = e.req("name")?.as_str().context("name not a string")?;
        anyhow::ensure!(
            crate::trace::names::is_registered(name),
            "event {}: name '{}' is not in the trace::names registry",
            i, name
        );
        cats.insert(cat.to_string());
        if let Some(&prev) = last_ts.get(&tid) {
            anyhow::ensure!(
                ts >= prev,
                "event {}: ts {} < previous ts {} on tid {}",
                i, ts, prev, tid
            );
        }
        last_ts.insert(tid, ts);
        let d = depth.entry(tid).or_insert(0);
        match ph {
            "B" => *d += 1,
            "E" => {
                *d -= 1;
                anyhow::ensure!(*d >= 0, "event {}: unmatched E on tid {}", i, tid);
            }
            "i" => {}
            other => bail!("event {}: unknown phase '{}'", i, other),
        }
    }
    for (tid, d) in &depth {
        anyhow::ensure!(*d == 0, "tid {}: {} unclosed B span(s)", tid, d);
    }
    for want in ["request", "scheduler", "pool", "kv"] {
        anyhow::ensure!(
            cats.contains(want),
            "missing event category '{}' (have: {:?})",
            want, cats
        );
    }
    println!(
        "OK: {} events, {} threads, categories {:?}",
        events.len(),
        depth.len(),
        cats
    );
    Ok(())
}

/// Run the in-tree static analyzer (DESIGN.md §13) and exit non-zero on
/// any diagnostic — the ci.sh hard gate.
fn cmd_lint(args: &Args) -> Result<()> {
    let root = match args.flag("root") {
        Some(r) => PathBuf::from(r),
        None => crate::analysis::find_root(&std::env::current_dir()?)?,
    };
    let report = crate::analysis::lint(&root)?;
    if args.bool_flag("json") {
        println!("{}", report.to_json().to_string());
    } else {
        for d in &report.diagnostics {
            println!("{}", d);
        }
        println!(
            "lint: {} file(s) analyzed, {} diagnostic(s)",
            report.files,
            report.diagnostics.len()
        );
    }
    anyhow::ensure!(
        report.diagnostics.is_empty(),
        "lint found {} diagnostic(s)",
        report.diagnostics.len()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let bits = args.usize_flag("bits", 2)? as u32;
    let ratio = args.f64_flag("ratio", 0.05)?;
    let mut ctx = crate::experiments::EvalCtx::load(args.bool_flag("fast"))?;
    let fp = ctx.ppl_fp()?;
    let m = crate::experiments::methods::Method::IcqSk { bits, ratio };
    let (rep, avg_bits) = m.quantize_model(&ctx.model);
    let q = ctx.ppl_with(&rep)?;
    println!("FP32 ppl                : {:.3}", fp);
    println!("{} ({:.2} bits/w): {:.3}", m.name(), avg_bits, q);
    println!("degradation             : {:+.2}%", (q / fp - 1.0) * 100.0);
    Ok(())
}

fn cmd_zoo() -> Result<()> {
    println!(
        "{:<14} {:>8} {:>7} {:>8} {:>12}",
        "family", "d_model", "d_ff", "blocks", "params(sim)"
    );
    for f in crate::synthzoo::model_families() {
        println!(
            "{:<14} {:>8} {:>7} {:>8} {:>12}",
            f.name,
            f.d_model,
            f.d_ff,
            f.n_blocks,
            f.param_count()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parse_flags_and_positionals() {
        let a = args(&["fig4", "--fast", "--gamma", "0.05"]);
        assert_eq!(a.positional, vec!["fig4"]);
        assert!(a.bool_flag("fast"));
        assert_eq!(a.f64_flag("gamma", 0.1).unwrap(), 0.05);
        assert_eq!(a.usize_flag("missing", 3).unwrap(), 3);
    }

    #[test]
    fn parse_equals_form_flags() {
        let a = args(&["serve", "--backend=native", "--threads=4", "--quantized"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.flag("backend"), Some("native"));
        assert_eq!(a.usize_flag("threads", 0).unwrap(), 4);
        assert!(a.bool_flag("quantized"));
    }

    #[test]
    fn bad_flag_value_errors() {
        let a = args(&["--bits", "notanumber"]);
        assert!(a.usize_flag("bits", 2).is_err());
    }

    #[test]
    fn bound_command_runs() {
        cmd_bound(&args(&["--gamma", "0.05"])).unwrap();
    }

    #[test]
    fn zoo_command_runs() {
        cmd_zoo().unwrap();
    }

    #[test]
    fn quantize_command_runs() {
        cmd_quantize(&args(&["--rows", "32", "--cols", "256", "--bits", "2"])).unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&["frobnicate".to_string()]).is_err());
    }

    #[test]
    fn pack_inspect_verify_flow() {
        let dir = std::env::temp_dir().join("icq_cli_pack_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("m.icqz");
        let reg = dir.join("registry");
        cmd_pack(&args(&[
            "--family", "llama3.2-1b", "--bits", "2", "--blocks", "1",
            "--out", out.to_str().unwrap(),
            "--name", "cli-demo", "--store", reg.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(out.exists());
        let out_s = out.to_str().unwrap();
        cmd_inspect(&args(&[out_s])).unwrap();
        cmd_verify(&args(&[out_s])).unwrap();
        // Registry spec resolution path.
        cmd_verify(&args(&["cli-demo", "--store", reg.to_str().unwrap()])).unwrap();
        cmd_store(&args(&["list", "--store", reg.to_str().unwrap()])).unwrap();
        cmd_store(&args(&["gc", "--store", reg.to_str().unwrap()])).unwrap();
        // A flipped byte must fail verification.
        let mut bytes = std::fs::read(&out).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x08;
        std::fs::write(&out, &bytes).unwrap();
        assert!(cmd_verify(&args(&[out_s])).is_err());
    }

    #[test]
    fn pack_rejects_unknown_family_and_quantizer() {
        assert!(cmd_pack(&args(&["--family", "gpt-17t"])).is_err());
        assert!(cmd_pack(&args(&["--quantizer", "fp4"])).is_err());
    }
}

//! Command-line interface (hand-rolled — clap is not in the offline
//! registry).
//!
//! ```text
//! icquant exp <id|all> [--fast]      regenerate a paper table/figure
//! icquant quantize [opts]            quantize a tensor → .icqm artifact
//! icquant stats --family <name>      outlier statistics for a zoo family
//! icquant bound [--gamma g]          Lemma 1 bound table + optimal b
//! icquant serve [opts]               run the serving demo
//! icquant eval [--bits n ...]        perplexity of FP vs ICQuant model
//! icquant zoo                        list synthetic model families
//! icquant help
//! ```

pub mod serve_demo;

use crate::experiments;
use crate::icquant::{packed, IcqConfig, IcqMatrix};
use crate::quant::QuantizerKind;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Parsed flag set: positionals + `--key value` + `--flag` booleans.
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn f64_flag(&self, key: &str, default: f64) -> Result<f64> {
        match self.flag(key) {
            Some(v) => v.parse::<f64>().with_context(|| format!("--{} {}", key, v)),
            None => Ok(default),
        }
    }

    pub fn usize_flag(&self, key: &str, default: usize) -> Result<usize> {
        match self.flag(key) {
            Some(v) => v.parse::<usize>().with_context(|| format!("--{} {}", key, v)),
            None => Ok(default),
        }
    }
}

pub fn run() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {:#}", e);
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd {
        "exp" => cmd_exp(&args),
        "quantize" => cmd_quantize(&args),
        "stats" => cmd_stats(&args),
        "bound" => cmd_bound(&args),
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "zoo" => cmd_zoo(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{}' (try `icquant help`)", other),
    }
}

fn print_help() {
    println!("ICQuant — Index Coding enables Low-bit LLM Quantization");
    println!();
    println!("USAGE: icquant <command> [options]");
    println!();
    println!("  exp <id|all> [--fast]         regenerate a paper table/figure:");
    for e in experiments::registry() {
        println!("      {:<8} {}", e.id, e.paper_artifact);
    }
    println!("  quantize [--bits n] [--ratio g] [--quantizer rtn|sk]");
    println!("           [--rows r --cols c --seed s] [--out file.icqm]");
    println!("                                quantize a (synthetic) matrix");
    println!("  stats --family <name>         outlier stats for a zoo family");
    println!("  bound [--gamma g]             Lemma 1 bound + optimal b");
    println!("  serve [--requests n] [--batch n] [--tokens n] [--quantized]");
    println!("                                batched serving demo (PJRT)");
    println!("  eval [--bits n] [--ratio g]   ppl: FP vs ICQuant^SK");
    println!("  zoo                           list synthetic model families");
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    experiments::run(id, args.bool_flag("fast"))
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let bits = args.usize_flag("bits", 2)? as u32;
    let ratio = args.f64_flag("ratio", 0.05)?;
    let rows = args.usize_flag("rows", 256)?;
    let cols = args.usize_flag("cols", 1024)?;
    let seed = args.usize_flag("seed", 7)? as u64;
    let quantizer = match args.flag("quantizer").unwrap_or("rtn") {
        "rtn" => QuantizerKind::Rtn,
        "sk" => QuantizerKind::SensitiveKmeans,
        q => bail!("unknown quantizer '{}'", q),
    };
    let w = crate::synthzoo::demo_matrix(rows, cols, seed);
    let cfg = IcqConfig { bits, outlier_ratio: ratio, gap_bits: 0, quantizer };
    let t0 = std::time::Instant::now();
    let q = IcqMatrix::quantize(&w, None, &cfg)?;
    let dt = t0.elapsed();
    let rec = q.dequantize();
    println!(
        "quantized {}x{} with {:?} ({} bits, γ={:.2}%)",
        rows, cols, quantizer, bits, ratio * 100.0
    );
    println!("  gap width b          : {} (Lemma-1 optimal)", q.gap_bits);
    println!("  index overhead B     : {:.4} bits/weight", q.index_bits_per_weight());
    println!(
        "  total bits/weight    : {:.3} (+codebooks: {:.3})",
        q.avg_bits_per_weight(),
        q.avg_bits_per_weight_full()
    );
    println!("  reconstruction MSE   : {:.4e}", w.mse(&rec));
    println!("  quantization time    : {}", crate::util::human_duration(dt));
    if let Some(path) = args.flag("out") {
        packed::save(&q, std::path::Path::new(path))?;
        let size = std::fs::metadata(path)?.len();
        println!(
            "  artifact             : {} ({})",
            path,
            crate::util::human_bytes(size)
        );
    }
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let name = args.flag("family").unwrap_or("llama2-7b");
    let f = crate::synthzoo::family(name)
        .ok_or_else(|| anyhow::anyhow!("unknown family '{}' (see `icquant zoo`)", name))?;
    println!(
        "[{}] d_model={} d_ff={} blocks={} (~{} params simulated)",
        f.name,
        f.d_model,
        f.d_ff,
        f.n_blocks,
        f.param_count()
    );
    println!(
        "\n{:<12} {:>12} {:>14} {:>16}",
        "layer", "range@5%", "chi2 reject", "icq B (b=6)"
    );
    for lt in crate::synthzoo::LayerType::ALL {
        let w = f.gen_stat_layer(lt, 0);
        let range = crate::stats::avg_range_taken(&w, 0.05);
        let rej = crate::stats::rejection_rate(&w, 0.0625, 256, 0.05);
        let k = (0.05 * w.cols as f64) as usize;
        let rows: Vec<Vec<usize>> = (0..w.rows)
            .map(|r| crate::quant::mixed_precision::top_k_by_magnitude(w.row(r), k))
            .collect();
        let b = crate::icq::bound::empirical_overhead(&rows, w.cols, 6);
        println!(
            "{:<12} {:>12.3} {:>13.2}% {:>16.4}",
            lt.name(),
            range,
            rej * 100.0,
            b
        );
    }
    Ok(())
}

fn cmd_bound(args: &Args) -> Result<()> {
    let gamma = args.f64_flag("gamma", 0.05)?;
    println!("Lemma 1 bound at γ={:.2}%:", gamma * 100.0);
    for b in 3..=10u32 {
        let bound = crate::icq::lemma1_bound(gamma, b);
        let marker = if b == crate::icq::optimal_b(gamma) {
            "  ← optimal"
        } else {
            ""
        };
        println!("  b={:<2}  B ≤ {:.4} bits/weight{}", b, bound, marker);
    }
    let c = crate::icq::bound::storage_comparison(gamma, 50_000);
    println!("\nvs alternatives (d_in=50k, as §3.2):");
    println!("  binary mask      : {:.3} bits/weight", c.binary_mask);
    println!("  absolute indices : {:.3} bits/weight", c.absolute_indices);
    println!("  ICQuant (b={})    : {:.3} bits/weight", c.icquant_b, c.icquant);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n_requests = args.usize_flag("requests", 16)?;
    let max_batch = args.usize_flag("batch", 8)?;
    let tokens = args.usize_flag("tokens", 16)?;
    serve_demo::run(n_requests, max_batch, tokens, args.bool_flag("quantized"))
}

fn cmd_eval(args: &Args) -> Result<()> {
    let bits = args.usize_flag("bits", 2)? as u32;
    let ratio = args.f64_flag("ratio", 0.05)?;
    let mut ctx = crate::experiments::EvalCtx::load(args.bool_flag("fast"))?;
    let fp = ctx.ppl_fp()?;
    let m = crate::experiments::methods::Method::IcqSk { bits, ratio };
    let (rep, avg_bits) = m.quantize_model(&ctx.model);
    let q = ctx.ppl_with(&rep)?;
    println!("FP32 ppl                : {:.3}", fp);
    println!("{} ({:.2} bits/w): {:.3}", m.name(), avg_bits, q);
    println!("degradation             : {:+.2}%", (q / fp - 1.0) * 100.0);
    Ok(())
}

fn cmd_zoo() -> Result<()> {
    println!(
        "{:<14} {:>8} {:>7} {:>8} {:>12}",
        "family", "d_model", "d_ff", "blocks", "params(sim)"
    );
    for f in crate::synthzoo::model_families() {
        println!(
            "{:<14} {:>8} {:>7} {:>8} {:>12}",
            f.name,
            f.d_model,
            f.d_ff,
            f.n_blocks,
            f.param_count()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parse_flags_and_positionals() {
        let a = args(&["fig4", "--fast", "--gamma", "0.05"]);
        assert_eq!(a.positional, vec!["fig4"]);
        assert!(a.bool_flag("fast"));
        assert_eq!(a.f64_flag("gamma", 0.1).unwrap(), 0.05);
        assert_eq!(a.usize_flag("missing", 3).unwrap(), 3);
    }

    #[test]
    fn bad_flag_value_errors() {
        let a = args(&["--bits", "notanumber"]);
        assert!(a.usize_flag("bits", 2).is_err());
    }

    #[test]
    fn bound_command_runs() {
        cmd_bound(&args(&["--gamma", "0.05"])).unwrap();
    }

    #[test]
    fn zoo_command_runs() {
        cmd_zoo().unwrap();
    }

    #[test]
    fn quantize_command_runs() {
        cmd_quantize(&args(&["--rows", "32", "--cols", "256", "--bits", "2"])).unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&["frobnicate".to_string()]).is_err());
    }
}

//! Serving demo: load the trained model (optionally ICQuant-quantized),
//! start the coordinator, fire a workload of prompts drawn from the test
//! corpus, and report latency/throughput — the intro's deployment story.

use crate::coordinator::backend::PjrtBackend;
use crate::coordinator::{ServeConfig, Server};
use crate::eval::load_corpus_tokens;
use crate::experiments::methods::Method;
use crate::model::{artifacts_dir, TrainedModel};
use anyhow::Result;
use std::time::{Duration, Instant};

pub fn run(n_requests: usize, max_batch: usize, max_tokens: usize, quantized: bool) -> Result<()> {
    let dir = artifacts_dir();
    let mut model = TrainedModel::load(&dir)?;
    let mut storage_note = String::from("FP32 weights");
    if quantized {
        let m = Method::IcqSk { bits: 2, ratio: 0.05 };
        let t0 = Instant::now();
        let (rep, bits) = m.quantize_model(&model);
        model = model.with_replaced(&rep);
        storage_note = format!(
            "{} ({:.2} bits/weight storage, quantized in {:.1}s)",
            m.name(),
            bits,
            t0.elapsed().as_secs_f64()
        );
    }

    let cfg = ServeConfig {
        max_batch,
        max_wait: Duration::from_millis(15),
        max_new_tokens: max_tokens,
        buckets: vec![1, 2, 4, 8],
        prefill_len: 64,
    };
    println!("starting server: {} | max_batch={} max_wait=15ms", storage_note, max_batch);

    let dir2 = dir.clone();
    let model2 = model.clone();
    let server = Server::start(cfg, move || {
        let mut b = PjrtBackend::new(&dir2, &model2).expect("backend init");
        b.warmup().expect("warmup");
        b
    });

    // Workload: prompts sampled from the test corpus.
    let corpus = load_corpus_tokens(&dir, "test")?;
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        let start = (i * 4099) % (corpus.len() - 80);
        let prompt = corpus[start..start + 48].to_vec();
        let (_, rx) = server.submit(prompt, max_tokens);
        rxs.push(rx);
    }
    let mut total_tokens = 0usize;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(600)).expect("response");
        anyhow::ensure!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        total_tokens += resp.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();

    let snap = server.metrics.snapshot();
    println!("\n=== serving report ===");
    println!("requests               : {}", snap.requests);
    println!("generated tokens       : {}", total_tokens);
    println!("wall time              : {:.2} s", wall);
    println!("throughput             : {:.1} tokens/s", total_tokens as f64 / wall);
    println!("batches                : {} (avg size {:.2}, avg bucket {:.2})",
        snap.batches, snap.avg_batch_size, snap.avg_bucket);
    println!("avg queue latency      : {:.1} ms", snap.avg_queue_ms);
    println!("avg prefill latency    : {:.1} ms", snap.avg_prefill_ms);
    println!("avg decode per token   : {:.1} ms", snap.avg_decode_ms_per_token);
    println!("p50 / p99 latency      : {:.0} / {:.0} ms", snap.p50_latency_ms, snap.p99_latency_ms);
    server.shutdown();
    Ok(())
}

//! Serving demo: start the coordinator over either executor, fire a
//! workload of prompts, and report latency/throughput — the intro's
//! deployment story.
//!
//! Two backends (`serve --backend=pjrt|native`):
//!
//! * **pjrt** — the trained Llama-mini through AOT-compiled HLO
//!   (requires `make artifacts`); optionally quantized first.
//! * **native** — a SynthZoo family quantized into runtime planes and
//!   served entirely by the fused CPU kernels ([`crate::kernels`]): no
//!   artifacts, no PJRT, no Python — weights stay at (n+1) bits for the
//!   whole request (DESIGN.md §8).

use crate::coordinator::backend::{NativeBackend, PjrtBackend};
use crate::coordinator::{SchedulerKind, ServeConfig, Server, SubmitOpts, TokenEvent};
use crate::eval::load_corpus_tokens;
use crate::experiments::methods::Method;
use crate::icquant::IcqConfig;
use crate::kernels::simd;
use crate::kernels::{ActQuant, KvLayout, NativeModel, TierPref, DEFAULT_BLOCK_TOKENS};
use crate::model::{artifacts_dir, TrainedModel};
use crate::quant::QuantizerKind;
use crate::store::{synth_model, DecodeCache, StoredModel};
use crate::trace::{Tracer, DEFAULT_BYTE_BUDGET};
use crate::util::human_bytes;
use crate::util::prng::Rng;
use anyhow::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Arm the flight-recorder tracer when a `--trace-out` path was given.
fn trace_setup(trace_out: Option<&str>) {
    if trace_out.is_some() {
        Tracer::enable(DEFAULT_BYTE_BUDGET);
    }
}

/// Export the recorded trace to `trace_out` (Chrome trace-event JSON,
/// loadable in Perfetto / `chrome://tracing`) and disable the tracer.
fn trace_finish(trace_out: Option<&str>) -> Result<()> {
    if let Some(path) = trace_out {
        let events = Tracer::event_count();
        Tracer::export_to(std::path::Path::new(path))?;
        Tracer::disable();
        println!("trace                  : {} events -> {}", events, path);
    }
    Ok(())
}

/// Serve a SynthZoo family through the native fused-kernel backend:
/// quantize → runtime-plane cache → [`NativeBackend`]. Needs no
/// artifacts directory and never materializes an f32 weight plane.
pub fn run_native(
    n_requests: usize,
    max_batch: usize,
    max_tokens: usize,
    family_name: &str,
    bits: u32,
    threads: usize,
    block_tokens: usize,
    kv_bits: Option<u32>,
    simd_pref: TierPref,
    act_quant: ActQuant,
    trace_out: Option<&str>,
) -> Result<()> {
    let family = crate::synthzoo::family(family_name).ok_or_else(|| {
        anyhow::anyhow!("unknown family '{}' (see `icquant zoo`)", family_name)
    })?;
    let qcfg = IcqConfig {
        bits,
        outlier_ratio: 0.05,
        gap_bits: 0, // Lemma-1-optimal b for γ
        quantizer: QuantizerKind::Rtn,
    };
    let t0 = Instant::now();
    let model = synth_model(&family, &qcfg, None)?;
    let cache = Arc::new(DecodeCache::new(256 << 20));
    let stored = StoredModel::from_model(model, cache.clone(), "serve-native");
    // Built on the main thread for the footprint report; the planes it
    // decodes are shared with the worker through the cache.
    let tier = simd::detect(simd_pref);
    let native = NativeModel::from_stored(&stored, threads)?
        .with_simd(tier)
        .with_act_quant(act_quant);
    let threads = native.threads();
    println!(
        "native model [{}]: {} blocks, d={} | quantized in {:.2}s",
        family.name,
        native.config.n_layers,
        native.config.d_model,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "  resident projections : {} quantized ({} dequantized f32) — {:.2}x smaller",
        human_bytes(native.quantized_bytes() as u64),
        human_bytes(native.dequantized_bytes() as u64),
        native.dequantized_bytes() as f64 / native.quantized_bytes() as f64
    );
    println!(
        "  kernel pool          : {} executors (persistent, parked between tokens) | backend: native fused GEMM (no PJRT)",
        threads
    );
    println!(
        "  kernel tier          : {} SIMD dispatch, {} activations (DESIGN.md §14)",
        tier.name(),
        act_quant.name()
    );
    let kv_layout = KvLayout {
        block_tokens: if block_tokens == 0 { DEFAULT_BLOCK_TOKENS } else { block_tokens },
        kv_bits,
        ..KvLayout::default()
    };
    println!(
        "  paged KV cache       : {}-token blocks, shared-prefix reuse on (DESIGN.md §10)",
        kv_layout.block_tokens
    );
    match kv_bits {
        Some(b) => println!(
            "  KV quantization      : ICQ {}-bit blocks, hot tail f32 (DESIGN.md §12)",
            b
        ),
        None => println!("  KV quantization      : off (full f32 blocks)"),
    }

    // Unlike PJRT there are no pre-compiled bucket entries, so grow the
    // bucket ladder to cover whatever batch size was requested.
    let mut buckets = vec![1usize, 2, 4, 8];
    while *buckets.last().unwrap() < max_batch {
        let next = buckets.last().unwrap() * 2;
        buckets.push(next);
    }
    let cfg = ServeConfig {
        max_batch,
        max_wait: Duration::from_millis(15),
        max_new_tokens: max_tokens,
        buckets,
        prefill_len: 32,
        // Clamped to the model vocab by the worker; the byte-vocab
        // space token is the natural pad here.
        pad_id: b' ' as i32,
        scheduler: SchedulerKind::Continuous,
        ..ServeConfig::default()
    };
    trace_setup(trace_out);
    let server =
        Server::start(cfg, move || Ok(NativeBackend::new(native).with_kv_layout(kv_layout)));
    server.metrics.set_kernel_dispatch(tier.name(), act_quant.name());

    // Workload: synthetic printable-byte prompts (byte-level vocab)
    // behind one shared "system prompt" prefix — the scenario the paged
    // cache's prefix reuse targets (DESIGN.md §10). Even requests use
    // the whole-response API; odd ones ride the per-token streaming
    // channel (DESIGN.md §15) so the demo exercises both front ends.
    let mut rng = Rng::new(0x5E2E);
    let system: Vec<i32> = (0..16).map(|_| 32 + (rng.below(95)) as i32).collect();
    let t0 = Instant::now();
    let mut whole_rxs = Vec::new();
    let mut stream_rxs = Vec::new();
    for i in 0..n_requests {
        let mut prompt = system.clone();
        prompt.extend((0..8).map(|_| 32 + (rng.below(95)) as i32));
        if i % 2 == 0 {
            let (_, rx) = server.submit(prompt, max_tokens)?;
            whole_rxs.push(rx);
        } else {
            let opts = SubmitOpts { max_new_tokens: max_tokens, ..SubmitOpts::default() };
            let (_, rx) = server.submit_streaming(prompt, opts)?;
            stream_rxs.push(rx);
        }
    }
    let mut total_tokens = 0usize;
    let mut streamed_tokens = 0usize;
    let streamed_requests = stream_rxs.len();
    for rx in whole_rxs {
        let resp = rx.recv_timeout(Duration::from_secs(600)).expect("response");
        anyhow::ensure!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        total_tokens += resp.tokens.len();
    }
    for rx in stream_rxs {
        loop {
            match rx.recv_timeout(Duration::from_secs(600)).expect("stream event") {
                TokenEvent::Token(_) => {
                    total_tokens += 1;
                    streamed_tokens += 1;
                }
                TokenEvent::Done(_) => break,
                TokenEvent::Failed(e) => anyhow::bail!("stream failed: {}", e),
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let snap = server.metrics.snapshot();
    let cstats = cache.stats();
    println!("\n=== native serving report ===");
    println!("requests               : {}", snap.requests);
    println!("errors                 : {}", snap.errors);
    println!("shed / cancelled       : {} / {} (QoS admission, DESIGN.md §15)",
        snap.shed, snap.cancelled);
    println!("streamed               : {} requests, {} tokens over per-token channels",
        streamed_requests, streamed_tokens);
    println!("generated tokens       : {}", total_tokens);
    println!("wall time              : {:.2} s", wall);
    println!("throughput             : {:.1} tokens/s", total_tokens as f64 / wall);
    println!("admissions             : {} (avg size {:.2}, avg occupancy after {:.2})",
        snap.batches, snap.avg_batch_size, snap.avg_bucket);
    println!("decode steps           : {} (avg {:.2} active slots)",
        snap.decode_steps, snap.avg_active_slots);
    println!("kernel dispatch        : {} tier, {} activations",
        snap.kernel_tier, snap.act_quant);
    println!("avg prefill latency    : {:.1} ms", snap.avg_prefill_ms);
    println!("avg time-to-1st-token  : {:.1} ms", snap.avg_ttft_ms);
    println!("avg decode per token   : {:.1} ms", snap.avg_decode_ms_per_token);
    println!("p50 / p99 latency      : {:.0} / {:.0} ms", snap.p50_latency_ms, snap.p99_latency_ms);
    println!(
        "prefix cache           : {} block hits ({} prompt tokens not recomputed), {} CoW forks",
        snap.prefix_hits, snap.prefix_hit_tokens, snap.cow_forks
    );
    println!(
        "KV blocks              : {} in use / {} peak / {} total ({:.0}% peak utilization), {} evicted",
        snap.blocks_in_use,
        snap.blocks_in_use_peak,
        snap.kv_total_blocks,
        snap.block_utilization * 100.0,
        snap.blocks_evicted
    );
    if let Some(b) = snap.kv_bits {
        println!(
            "quantized KV ({} bit)   : {} blocks quantized ({} resident now), {} scratch hits, {} resident KV",
            b,
            snap.blocks_quantized,
            snap.quantized_blocks,
            snap.dequant_scratch_hits,
            human_bytes(snap.kv_resident_bytes as u64)
        );
    }
    println!(
        "plane cache            : {} hits / {} misses ({} decoded, {} resident)",
        cstats.hits,
        cstats.misses,
        human_bytes(cstats.decoded_bytes),
        human_bytes(cache.bytes_used() as u64)
    );
    server.shutdown();
    trace_finish(trace_out)?;
    Ok(())
}

pub fn run(
    n_requests: usize,
    max_batch: usize,
    max_tokens: usize,
    quantized: bool,
    trace_out: Option<&str>,
) -> Result<()> {
    let dir = artifacts_dir();
    let mut model = TrainedModel::load(&dir)?;
    let mut storage_note = String::from("FP32 weights");
    if quantized {
        let m = Method::IcqSk { bits: 2, ratio: 0.05 };
        let t0 = Instant::now();
        let (rep, bits) = m.quantize_model(&model);
        model = model.with_replaced(&rep);
        storage_note = format!(
            "{} ({:.2} bits/weight storage, quantized in {:.1}s)",
            m.name(),
            bits,
            t0.elapsed().as_secs_f64()
        );
    }

    let cfg = ServeConfig {
        max_batch,
        max_wait: Duration::from_millis(15),
        max_new_tokens: max_tokens,
        buckets: vec![1, 2, 4, 8],
        prefill_len: 64,
        pad_id: b' ' as i32,
        // The compiled buckets force wave scheduling either way; being
        // explicit keeps the report's batch lines honest.
        scheduler: SchedulerKind::RunToCompletion,
        ..ServeConfig::default()
    };
    println!("starting server: {} | max_batch={} max_wait=15ms", storage_note, max_batch);

    trace_setup(trace_out);
    let dir2 = dir.clone();
    let model2 = model.clone();
    let server = Server::start(cfg, move || {
        let mut b = PjrtBackend::new(&dir2, &model2)?;
        b.warmup()?;
        Ok(b)
    });

    // Workload: prompts sampled from the test corpus.
    let corpus = load_corpus_tokens(&dir, "test")?;
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        let start = (i * 4099) % (corpus.len() - 80);
        let prompt = corpus[start..start + 48].to_vec();
        let (_, rx) = server.submit(prompt, max_tokens)?;
        rxs.push(rx);
    }
    let mut total_tokens = 0usize;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(600)).expect("response");
        anyhow::ensure!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        total_tokens += resp.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();

    let snap = server.metrics.snapshot();
    println!("\n=== serving report ===");
    println!("requests               : {}", snap.requests);
    println!("errors                 : {}", snap.errors);
    println!("shed / cancelled       : {} / {}", snap.shed, snap.cancelled);
    println!("generated tokens       : {}", total_tokens);
    println!("wall time              : {:.2} s", wall);
    println!("throughput             : {:.1} tokens/s", total_tokens as f64 / wall);
    println!("batches                : {} (avg size {:.2}, avg bucket {:.2})",
        snap.batches, snap.avg_batch_size, snap.avg_bucket);
    println!("avg queue latency      : {:.1} ms", snap.avg_queue_ms);
    println!("avg prefill latency    : {:.1} ms", snap.avg_prefill_ms);
    println!("avg time-to-1st-token  : {:.1} ms", snap.avg_ttft_ms);
    println!("avg decode per token   : {:.1} ms", snap.avg_decode_ms_per_token);
    println!("p50 / p99 latency      : {:.0} / {:.0} ms", snap.p50_latency_ms, snap.p99_latency_ms);
    server.shutdown();
    trace_finish(trace_out)?;
    Ok(())
}

//! Flight-recorder tracing + per-stage profiling for the serving stack
//! (DESIGN.md §11).
//!
//! The coordinator's [`Metrics`](crate::coordinator::metrics::Metrics)
//! snapshot says *how much* — this module says *where*: a lock-light,
//! bounded ring-buffer event recorder that captures typed spans and
//! instants across the request lifecycle (enqueue → admit → prefill →
//! decode steps → retire/error), the scheduler (admission rounds with
//! block-need accounting, clamps, wave splits), the kernel pool (job
//! dispatch, per-worker busy/park intervals, queue depth), and the
//! paged KV cache (prefix hits, CoW forks, evictions, reservations).
//!
//! Design constraints, in priority order:
//!
//! 1. **A disabled tracer is near-free on the decode hot path.** Every
//!    public recording entry point starts with one relaxed atomic load
//!    and returns — no allocation, no lock, no timestamp read. The
//!    serving bench asserts the bound (`trace_overhead_pct` in
//!    `BENCH_serving.json`).
//! 2. **Constant memory under sustained traffic**, like the latency
//!    reservoir: each recording thread owns a fixed-capacity ring of
//!    fixed-size [`Event`] records sized from a byte budget; wraparound
//!    overwrites the oldest events and counts them as dropped.
//! 3. **Lock-light when enabled.** The per-thread ring sits behind a
//!    mutex only its owner thread touches (export briefly contends);
//!    the registry lock is taken once per thread per generation.
//!
//! Spans are recorded as separate begin/end events in thread order, so
//! each thread's stream is chronological and properly nested by
//! construction (RAII [`Span`] guards). [`Tracer::export`] renders the
//! rings as Chrome trace-event JSON (`chrome://tracing` /
//! [Perfetto](https://ui.perfetto.dev) loadable): wraparound can orphan
//! an `E` (its `B` was overwritten) or leave a `B` dangling (span still
//! open), so the exporter drops unmatched ends and closes unfinished
//! begins at the thread's last timestamp — the emitted stream always
//! has balanced `B`/`E` pairs and per-thread monotone timestamps.
//!
//! Separately from events, fixed-size log-bucketed [`Stage`] histograms
//! accumulate per-stage durations (queue, prefill, inter-token, decode
//! step, end-to-end); they survive ring wraparound and are embedded in
//! the export under `otherData.histograms`.
//!
//! The **flight recorder** is the failure-path consumer: on a request
//! error or a [`PoolPanic`](crate::kernels::PoolPanic) the serving
//! stack calls [`flight_dump`], which renders the most recent events
//! across all threads to stderr — failures arrive with their own
//! context even when nobody asked for a full trace file.

pub mod names;

use crate::util::json::Json;
use std::cell::RefCell;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Default per-thread ring budget: 256 KiB ≈ 4.6k events per thread.
pub const DEFAULT_BYTE_BUDGET: usize = 256 * 1024;

/// Events rendered by a flight-recorder dump.
const FLIGHT_TAIL: usize = 48;

/// Event category — the four subsystems the trace taxonomy covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cat {
    /// Request lifecycle: enqueue, admit, retire, error.
    Request,
    /// Scheduler: admission rounds, block gating, clamps, waves, steps.
    Sched,
    /// Kernel pool: dispatch, per-worker busy/park, queue depth, panics.
    Pool,
    /// Paged KV cache: prefix hits, CoW forks, evictions, reservations.
    Kv,
}

impl Cat {
    pub fn as_str(self) -> &'static str {
        match self {
            Cat::Request => "request",
            Cat::Sched => "scheduler",
            Cat::Pool => "pool",
            Cat::Kv => "kv",
        }
    }
}

/// Trace-event phase (the Chrome `ph` field).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Begin,
    End,
    Instant,
}

/// One fixed-size ring record. `name` is `&'static str` by contract so
/// recording never allocates; `a`/`b` carry two event-specific counters
/// (block need vs. headroom, clamp before vs. after, …).
#[derive(Clone, Copy, Debug)]
struct Event {
    ts_us: u64,
    cat: Cat,
    ph: Phase,
    name: &'static str,
    id: u64,
    a: i64,
    b: i64,
}

/// Bytes one ring slot costs against the byte budget.
const EVENT_BYTES: usize = std::mem::size_of::<Event>();

/// Pipeline stages with a dedicated duration histogram.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Arrival → admission.
    Queue = 0,
    /// Prompt pass (per admission round / wave).
    Prefill = 1,
    /// Gap between consecutive tokens of an active sequence (= the
    /// decode step wall time while it participates).
    InterToken = 2,
    /// One batched decode step.
    DecodeStep = 3,
    /// End-to-end request latency.
    Total = 4,
}

impl Stage {
    pub const ALL: [Stage; 5] =
        [Stage::Queue, Stage::Prefill, Stage::InterToken, Stage::DecodeStep, Stage::Total];

    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::Prefill => "prefill",
            Stage::InterToken => "inter_token",
            Stage::DecodeStep => "decode_step",
            Stage::Total => "total",
        }
    }
}

/// Log₂-bucketed duration histogram: bucket `i` counts durations in
/// `[2^(i−1), 2^i)` µs (bucket 0 is `0 µs`). Fixed size, atomic — many
/// recorders, no lock, constant memory.
const HIST_BUCKETS: usize = 40;

struct LogHist {
    counts: [AtomicU64; HIST_BUCKETS],
    sum_us: AtomicU64,
    n: AtomicU64,
}

impl LogHist {
    fn new() -> LogHist {
        LogHist {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            n: AtomicU64::new(0),
        }
    }

    fn bucket(us: u64) -> usize {
        ((64 - us.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    /// Add one duration to the histogram.
    /// ORDERING: relaxed — the three counters are statistically, not
    /// transactionally, related; readers tolerate a count/sum torn across
    /// a concurrent record, and no other data is published through them.
    // lint: hot-path
    fn record(&self, us: u64) {
        self.counts[Self::bucket(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    /// Upper edge (µs) of the first bucket whose cumulative count
    /// reaches fraction `p` — a log₂-resolution percentile estimate.
    /// ORDERING: relaxed — reads race with recorders by design; the
    /// estimate is already log₂-coarse, so a slightly stale count is
    /// within the reporting tolerance.
    fn percentile_us(&self, p: f64) -> u64 {
        let total = self.n.load(Ordering::Relaxed);
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * p).ceil() as u64;
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= target {
                return if i == 0 { 0 } else { 1u64 << i };
            }
        }
        1u64 << (HIST_BUCKETS - 1)
    }

    /// Snapshot the histogram as JSON.
    /// ORDERING: relaxed — same racy-snapshot tolerance as
    /// [`Self::percentile_us`]; export runs while recorders are live.
    fn to_json(&self) -> Json {
        let n = self.n.load(Ordering::Relaxed);
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .map(|c| Json::num(c.load(Ordering::Relaxed) as f64))
            .collect();
        Json::obj(vec![
            ("count", Json::num(n as f64)),
            ("mean_us", Json::num(self.sum_us.load(Ordering::Relaxed) as f64 / n.max(1) as f64)),
            ("p50_us", Json::num(self.percentile_us(0.5) as f64)),
            ("p99_us", Json::num(self.percentile_us(0.99) as f64)),
            ("log2_buckets", Json::arr(buckets)),
        ])
    }

    /// Zero every counter.
    /// ORDERING: relaxed — a reset racing recorders may interleave with
    /// their increments; [`Tracer::reset`] documents that in-flight
    /// events may survive or be lost, so no stronger fence would help.
    fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.sum_us.store(0, Ordering::Relaxed);
        self.n.store(0, Ordering::Relaxed);
    }
}

/// Fixed-capacity overwrite-oldest event ring.
struct RingBuf {
    cap: usize,
    events: Vec<Event>,
    /// Oldest slot once full (0 while filling).
    start: usize,
    dropped: u64,
}

impl RingBuf {
    fn new(cap: usize) -> RingBuf {
        RingBuf { cap, events: Vec::with_capacity(cap), start: 0, dropped: 0 }
    }

    fn push(&mut self, e: Event) {
        if self.events.len() < self.cap {
            self.events.push(e);
        } else {
            self.events[self.start] = e;
            self.start = (self.start + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events oldest → newest.
    fn in_order(&self) -> Vec<Event> {
        let mut out = Vec::with_capacity(self.events.len());
        out.extend_from_slice(&self.events[self.start..]);
        out.extend_from_slice(&self.events[..self.start]);
        out
    }
}

/// One thread's ring. The mutex is effectively uncontended: only the
/// owner thread records; export/flight dumps briefly share it.
struct ThreadRing {
    tid: u64,
    buf: Mutex<RingBuf>,
}

struct Shared {
    epoch: Instant,
    /// Bumped by [`Tracer::reset`]: threads re-register fresh rings.
    generation: AtomicU64,
    byte_budget: AtomicUsize,
    next_tid: AtomicU64,
    rings: Mutex<Vec<Arc<ThreadRing>>>,
    hists: [LogHist; Stage::ALL.len()],
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static FLIGHT: AtomicBool = AtomicBool::new(true);
static SHARED: OnceLock<Shared> = OnceLock::new();

fn shared() -> &'static Shared {
    SHARED.get_or_init(|| Shared {
        epoch: Instant::now(),
        generation: AtomicU64::new(0),
        byte_budget: AtomicUsize::new(DEFAULT_BYTE_BUDGET),
        next_tid: AtomicU64::new(1),
        rings: Mutex::new(Vec::new()),
        hists: std::array::from_fn(|_| LogHist::new()),
    })
}

thread_local! {
    /// (generation, ring) cached per recording thread.
    static LOCAL: RefCell<Option<(u64, Arc<ThreadRing>)>> = const { RefCell::new(None) };
}

/// Whether tracing is on — the hot-path gate: one relaxed atomic load.
/// ORDERING: relaxed — the flag carries no payload of its own; a thread
/// observing the flip late records (or skips) a few boundary events,
/// which the trace format tolerates. Ring/budget state is published by
/// the `generation` Acquire/Release pair, not by this flag.
// lint: hot-path
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Append to the calling thread's ring, registering it on first use
/// (or after a [`Tracer::reset`]). Never called while disabled.
/// ORDERING: relaxed on `byte_budget` (a sizing hint — a ring built one
/// enable earlier keeps its old size by documented contract) and on
/// `next_tid` (only uniqueness matters); the `generation` Acquire load
/// pairs with [`Tracer::reset`]'s Release bump and is what actually
/// orders ring registration against ring clearing.
#[inline(never)]
fn record(cat: Cat, ph: Phase, name: &'static str, id: u64, a: i64, b: i64) {
    let sh = shared();
    let ts_us = sh.epoch.elapsed().as_micros() as u64;
    let e = Event { ts_us, cat, ph, name, id, a, b };
    // `try_with`: a record during TLS teardown is silently dropped
    // rather than aborting the thread.
    let _ = LOCAL.try_with(|cell| {
        let mut slot = cell.borrow_mut();
        let generation = sh.generation.load(Ordering::Acquire);
        let stale = match slot.as_ref() {
            Some((g, _)) => *g != generation,
            None => true,
        };
        if stale {
            let cap = (sh.byte_budget.load(Ordering::Relaxed) / EVENT_BYTES).max(16);
            let ring = Arc::new(ThreadRing {
                tid: sh.next_tid.fetch_add(1, Ordering::Relaxed),
                buf: Mutex::new(RingBuf::new(cap)),
            });
            sh.rings.lock().unwrap().push(ring.clone());
            *slot = Some((generation, ring));
        }
        // PANIC: the `stale` branch above just filled the slot on every
        // path that reaches here; `None` is unreachable.
        let (_, ring) = slot.as_ref().expect("registered above");
        ring.buf.lock().unwrap().push(e);
    });
}

/// Record an instant event (`ph: "i"`). Free when tracing is disabled.
// lint: hot-path
#[inline]
pub fn instant(cat: Cat, name: &'static str, id: u64, a: i64, b: i64) {
    if !enabled() {
        return;
    }
    record(cat, Phase::Instant, name, id, a, b);
}

/// RAII span: records `B` on creation (when enabled) and the matching
/// `E` on drop. Must stay on the creating thread (per-thread nesting is
/// what makes the exported `B`/`E` stream valid).
#[must_use = "a span records its end when dropped"]
pub struct Span {
    live: bool,
    cat: Cat,
    name: &'static str,
    id: u64,
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if self.live {
            record(self.cat, Phase::End, self.name, self.id, 0, 0);
        }
    }
}

/// Open a span. Free when tracing is disabled (no timestamp, no lock).
// lint: hot-path
#[inline]
pub fn span(cat: Cat, name: &'static str, id: u64) -> Span {
    span_args(cat, name, id, 0, 0)
}

/// [`span`] with the two counter arguments on the begin event.
// lint: hot-path
#[inline]
pub fn span_args(cat: Cat, name: &'static str, id: u64, a: i64, b: i64) -> Span {
    let live = enabled();
    if live {
        record(cat, Phase::Begin, name, id, a, b);
    }
    Span { live, cat, name, id }
}

/// Record a duration into a stage histogram. Free when disabled.
// lint: hot-path
#[inline]
pub fn stage_us(stage: Stage, us: u64) {
    if !enabled() {
        return;
    }
    shared().hists[stage as usize].record(us);
}

/// [`stage_us`] for a millisecond duration (negative clamps to 0).
// lint: hot-path
#[inline]
pub fn stage_ms(stage: Stage, ms: f64) {
    if !enabled() {
        return;
    }
    shared().hists[stage as usize].record((ms.max(0.0) * 1e3) as u64);
}

/// Dump the most recent events across all threads to stderr — called on
/// request errors and pool panics so failures arrive with context.
/// Returns the rendered dump, or `None` when tracing (or the flight
/// recorder) is off.
/// ORDERING: relaxed on the `FLIGHT` arm flag — it gates a diagnostic
/// dump; the event data itself is read under the ring locks.
pub fn flight_dump(trigger: &str) -> Option<String> {
    if !enabled() || !FLIGHT.load(Ordering::Relaxed) {
        return None;
    }
    let sh = SHARED.get()?;
    let mut recent: Vec<(u64, Event)> = Vec::new();
    for ring in sh.rings.lock().unwrap().iter() {
        let buf = ring.buf.lock().unwrap();
        recent.extend(buf.in_order().into_iter().map(|e| (ring.tid, e)));
    }
    recent.sort_by_key(|(_, e)| e.ts_us);
    let tail = recent.len().saturating_sub(FLIGHT_TAIL);
    let mut out = format!(
        "=== flight recorder: {} (last {} of {} events) ===\n",
        trigger,
        recent.len() - tail,
        recent.len()
    );
    for (tid, e) in &recent[tail..] {
        let ph = match e.ph {
            Phase::Begin => "B",
            Phase::End => "E",
            Phase::Instant => "i",
        };
        out.push_str(&format!(
            "  [{:>12} us] t{:<2} {} {}/{} id={} a={} b={}\n",
            e.ts_us,
            tid,
            ph,
            e.cat.as_str(),
            e.name,
            e.id,
            e.a,
            e.b
        ));
    }
    eprint!("{}", out);
    Some(out)
}

/// Handle to the process-wide tracer.
pub struct Tracer;

impl Tracer {
    /// Turn recording on with a per-thread ring byte budget (applies to
    /// rings created from now on; existing rings keep their size — call
    /// [`Tracer::reset`] first for a clean slate). Also arms the flight
    /// recorder.
    /// ORDERING: relaxed on all three flags — enabling publishes no
    /// event data; a recorder seeing `ENABLED` before the new budget
    /// builds its ring at the old size, which the sizing contract above
    /// explicitly allows.
    pub fn enable(byte_budget_per_thread: usize) {
        shared()
            .byte_budget
            .store(byte_budget_per_thread.max(EVENT_BYTES * 16), Ordering::Relaxed);
        FLIGHT.store(true, Ordering::Relaxed);
        ENABLED.store(true, Ordering::Relaxed);
    }

    /// Stop recording (rings keep their contents for export).
    /// ORDERING: relaxed — a thread seeing the flip late records a few
    /// trailing events into its ring, which export tolerates.
    pub fn disable() {
        ENABLED.store(false, Ordering::Relaxed);
    }

    pub fn is_enabled() -> bool {
        enabled()
    }

    /// Arm/disarm the flight recorder independently of full tracing.
    /// ORDERING: relaxed — a pure on/off gate for a diagnostic dump.
    pub fn set_flight_recorder(on: bool) {
        FLIGHT.store(on, Ordering::Relaxed);
    }

    /// Drop all recorded events and histogram counts. Threads re-register
    /// fresh rings (at the current byte budget) on their next record; a
    /// thread mid-record during the reset may lose that one event.
    pub fn reset() {
        if let Some(sh) = SHARED.get() {
            sh.generation.fetch_add(1, Ordering::Release);
            sh.rings.lock().unwrap().clear();
            for h in &sh.hists {
                h.reset();
            }
        }
    }

    /// Events currently held across all rings (newest-window view).
    pub fn event_count() -> usize {
        match SHARED.get() {
            Some(sh) => {
                sh.rings.lock().unwrap().iter().map(|r| r.buf.lock().unwrap().events.len()).sum()
            }
            None => 0,
        }
    }

    /// Render everything recorded so far as a Chrome trace-event JSON
    /// document (object form: `traceEvents` + `otherData`), loadable in
    /// `chrome://tracing` and Perfetto. Per thread, unmatched `E`
    /// events (begin lost to wraparound) are dropped and dangling `B`
    /// events are closed at the thread's last timestamp, so the output
    /// always carries balanced `B`/`E` pairs in monotone per-thread
    /// timestamp order.
    pub fn export() -> Json {
        let mut events: Vec<Json> = Vec::new();
        let mut dropped = 0u64;
        let mut n_threads = 0usize;
        if let Some(sh) = SHARED.get() {
            let mut rings: Vec<Arc<ThreadRing>> = sh.rings.lock().unwrap().clone();
            rings.sort_by_key(|r| r.tid);
            n_threads = rings.len();
            for ring in rings {
                let buf = ring.buf.lock().unwrap();
                dropped += buf.dropped;
                let evs = buf.in_order();
                drop(buf);
                let mut open: Vec<Event> = Vec::new();
                for e in &evs {
                    match e.ph {
                        Phase::Begin => {
                            open.push(*e);
                            events.push(event_json(ring.tid, e, "B"));
                        }
                        Phase::End => {
                            // An end whose begin was overwritten by
                            // wraparound would unbalance the stream.
                            if open.pop().is_some() {
                                events.push(event_json(ring.tid, e, "E"));
                            }
                        }
                        Phase::Instant => events.push(event_json(ring.tid, e, "i")),
                    }
                }
                // Close spans still open (or cut off by disable) at the
                // thread's newest timestamp.
                let last_ts = evs.last().map(|e| e.ts_us).unwrap_or(0);
                while let Some(b) = open.pop() {
                    let closed = Event { ts_us: last_ts, ph: Phase::End, ..b };
                    events.push(event_json(ring.tid, &closed, "E"));
                }
            }
        }
        let hists = match SHARED.get() {
            Some(sh) => Stage::ALL
                .iter()
                .map(|s| (s.as_str(), sh.hists[*s as usize].to_json()))
                .collect(),
            None => Vec::new(),
        };
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            (
                "otherData",
                Json::obj(vec![
                    ("recorder", Json::str("icquant-flight-recorder")),
                    ("threads", Json::num(n_threads as f64)),
                    ("dropped_events", Json::num(dropped as f64)),
                    ("histograms", Json::obj(hists)),
                ]),
            ),
        ])
    }

    /// [`Tracer::export`] straight to a file.
    pub fn export_to(path: &Path) -> std::io::Result<()> {
        std::fs::write(path, Self::export().to_string())
    }
}

fn event_json(tid: u64, e: &Event, ph: &str) -> Json {
    let mut fields = vec![
        ("ph", Json::str(ph)),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(tid as f64)),
        ("ts", Json::num(e.ts_us as f64)),
        ("cat", Json::str(e.cat.as_str())),
        ("name", Json::str(e.name)),
        (
            "args",
            Json::obj(vec![
                ("id", Json::num(e.id as f64)),
                ("a", Json::num(e.a as f64)),
                ("b", Json::num(e.b as f64)),
            ]),
        ),
    ];
    if ph == "i" {
        fields.push(("s", Json::str("t"))); // thread-scoped instant
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_percentiles() {
        let h = LogHist::new();
        assert_eq!(LogHist::bucket(0), 0);
        assert_eq!(LogHist::bucket(1), 1);
        assert_eq!(LogHist::bucket(2), 2);
        assert_eq!(LogHist::bucket(3), 2);
        assert_eq!(LogHist::bucket(4), 3);
        assert_eq!(LogHist::bucket(u64::MAX), HIST_BUCKETS - 1);
        for us in [1u64, 1, 1, 1000] {
            h.record(us);
        }
        // p50 falls in the 1 µs bucket (upper edge 2), p99 in the
        // 512..1024 bucket (upper edge 1024).
        assert_eq!(h.percentile_us(0.5), 2);
        assert_eq!(h.percentile_us(0.99), 1024);
        h.reset();
        assert_eq!(h.percentile_us(0.5), 0);
    }

    #[test]
    fn ring_overwrites_oldest_in_order() {
        let mk = |i: u64| Event {
            ts_us: i,
            cat: Cat::Sched,
            ph: Phase::Instant,
            name: "e",
            id: i,
            a: 0,
            b: 0,
        };
        let mut r = RingBuf::new(4);
        for i in 0..6 {
            r.push(mk(i));
        }
        let got: Vec<u64> = r.in_order().iter().map(|e| e.ts_us).collect();
        assert_eq!(got, vec![2, 3, 4, 5]);
        assert_eq!(r.dropped, 2);
        assert_eq!(r.events.len(), 4);
    }

    #[test]
    fn disabled_recording_is_a_no_op() {
        // The lib-test binary runs tests concurrently; this test only
        // asserts the *disabled* path, which is the process default —
        // integration tests own the enabled/global-state scenarios.
        if enabled() {
            return; // another harness enabled tracing; skip
        }
        instant(Cat::Request, "noop", 1, 2, 3);
        stage_us(Stage::Queue, 5);
        let s = span(Cat::Pool, "noop", 0);
        drop(s);
        assert!(flight_dump("test").is_none());
    }
}

//! Registry of every trace event name recorded by non-test code
//! (DESIGN.md §13, checker 6). `icquant lint` cross-checks this file
//! against the tree: every name a `trace::instant`/`span`/`span_args`
//! call site passes must be registered here, values must be unique, and
//! every registered name must still be recorded somewhere. Adding an
//! event means adding a constant here first; renaming one means updating
//! both ends in the same commit, which keeps trace-consuming tooling
//! (`icquant trace-check`, the flight recorder dump) in sync with the
//! emitters.

// --- coordinator: admission, batching, delivery -------------------------
pub const ENQUEUE: &str = "enqueue";
pub const ERROR: &str = "error";
pub const ADMIT: &str = "admit";
pub const ADMIT_ROUND: &str = "admit_round";
pub const RETIRE: &str = "retire";
pub const BLOCK_GATE: &str = "block_gate";
pub const FORCE_ADMIT: &str = "force_admit";
pub const PREFILL_ROUND: &str = "prefill_round";
pub const DECODE_STEP: &str = "decode_step";
pub const CLAMP_POSITIONS: &str = "clamp_positions";
pub const CLAMP_RESERVATION: &str = "clamp_reservation";
pub const WAVE: &str = "wave";
pub const PREFILL_WAVE: &str = "prefill_wave";
pub const WAVE_SPLIT: &str = "wave_split";
/// A streaming client dropped its receiver: the sequence was cancelled
/// and its KV blocks returned (DESIGN.md §15). `a` = tokens decoded at
/// cancellation, `b` = the target it would have run to.
pub const CANCEL: &str = "cancel";
/// A request was load-shed before admission (expired deadline or full
/// per-class queue); `a` = its priority class.
pub const SHED: &str = "shed";
/// Shutdown drain: `a` = queued-but-unserved requests failed explicitly.
pub const DRAIN: &str = "drain";

// --- coordinator: backend execution -------------------------------------
pub const BACKEND_PREFILL: &str = "backend_prefill";
pub const BACKEND_DECODE: &str = "backend_decode";
/// Which SIMD tier / act-quant mode served a decode step (DESIGN.md
/// §14): `a` = tier id (0 scalar, 1 avx2, 2 neon), `b` = 1 when int8
/// activation quantization is active.
pub const KERNEL_DISPATCH: &str = "kernel_dispatch";

// --- kernels: paged KV cache ---------------------------------------------
pub const RESERVE: &str = "reserve";
pub const EVICT: &str = "evict";
pub const PREFIX_HIT: &str = "prefix_hit";
pub const DEQUANT_WRITE: &str = "dequant_write";
pub const COW_FORK: &str = "cow_fork";
pub const QUANTIZE_BLOCK: &str = "quantize_block";

// --- kernels: worker pool -------------------------------------------------
pub const PARK: &str = "park";
pub const BUSY: &str = "busy";
pub const DISPATCH: &str = "dispatch";
pub const PANIC: &str = "panic";

/// Every registered event name. `icquant trace-check` uses this to reject
/// traces that carry names the tree never emits.
pub const ALL: &[&str] = &[
    ENQUEUE,
    ERROR,
    ADMIT,
    ADMIT_ROUND,
    RETIRE,
    BLOCK_GATE,
    FORCE_ADMIT,
    PREFILL_ROUND,
    DECODE_STEP,
    CLAMP_POSITIONS,
    CLAMP_RESERVATION,
    WAVE,
    PREFILL_WAVE,
    WAVE_SPLIT,
    CANCEL,
    SHED,
    DRAIN,
    BACKEND_PREFILL,
    BACKEND_DECODE,
    KERNEL_DISPATCH,
    RESERVE,
    EVICT,
    PREFIX_HIT,
    DEQUANT_WRITE,
    COW_FORK,
    QUANTIZE_BLOCK,
    PARK,
    BUSY,
    DISPATCH,
    PANIC,
];

/// True when `name` is a registered trace event name.
pub fn is_registered(name: &str) -> bool {
    ALL.contains(&name)
}

//! Load-time decode: storage artifact → runtime plane.
//!
//! The gap streams decode **once** at model load into a selector bit that
//! is fused into the code as its MSB, producing one byte-aligned
//! (n+1)-bit code per weight plus a fused per-row codebook of `2^(n+1)`
//! entries (inliers at codes `0..2^n`, outliers at `2^n..2^(n+1)`).
//! This is the plane the L1 Pallas kernel and the fused CPU kernels
//! ([`crate::kernels`]) consume: a pure gather, no bit twiddling on the
//! request path (DESIGN.md §4, §8 — on TPU the VPU has no per-lane
//! variable shift, so byte-aligned codes are the right runtime layout).

use super::IcqMatrix;
use crate::util::tensor::Matrix;

/// Runtime representation: byte codes + fused codebooks.
pub struct RuntimePlane {
    pub rows: usize,
    pub cols: usize,
    /// Fused code per weight: `code | (is_outlier << bits)`.
    pub codes: Vec<u8>,
    /// Per-row fused codebook, `2^(bits+1)` f32 levels each.
    pub codebooks: Vec<Vec<f32>>,
    pub bits: u32,
}

impl IcqMatrix {
    /// Decode the storage artifact into the runtime plane.
    pub fn to_runtime(&self) -> RuntimePlane {
        let n = self.rows * self.cols;
        let mut codes = vec![0u8; n];
        // Unpack the whole n-bit plane first (fast bulk path)…
        self.code_plane.unpack_into_u8(&mut codes);
        // …then OR in the outlier selector bit from the gap streams.
        let sel = 1u8 << self.bits;
        for r in 0..self.rows {
            let base = r * self.cols;
            for &c in &self.index_codes[r].decode() {
                codes[base + c] |= sel;
            }
        }
        let codebooks: Vec<Vec<f32>> = (0..self.rows)
            .map(|r| {
                let mut fused =
                    Vec::with_capacity(self.inlier_cbs[r].levels.len() * 2);
                fused.extend_from_slice(&self.inlier_cbs[r].levels);
                fused.extend_from_slice(&self.outlier_cbs[r].levels);
                fused
            })
            .collect();
        RuntimePlane { rows: self.rows, cols: self.cols, codes, codebooks, bits: self.bits }
    }
}

impl RuntimePlane {
    /// Dequantize the full plane to f32 (the serving load path; also what
    /// gets shipped to the PJRT executable as a weight argument).
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let cb = &self.codebooks[r];
            let src = &self.codes[r * self.cols..(r + 1) * self.cols];
            let dst = out.row_mut(r);
            for (d, &c) in dst.iter_mut().zip(src) {
                *d = cb[c as usize];
            }
        }
        out
    }

    /// Dequantize one row into a caller buffer (streaming path).
    pub fn dequantize_row_into(&self, row: usize, out: &mut [f32]) {
        let cb = &self.codebooks[row];
        let src = &self.codes[row * self.cols..(row + 1) * self.cols];
        for (d, &c) in out.iter_mut().zip(src) {
            *d = cb[c as usize];
        }
    }

    /// `y = W x` straight off the quantized plane (gather + FMA per
    /// element) — the memory-bound deployment kernel shape. The
    /// production form (blocked, multi-threaded, batched) lives in
    /// [`crate::kernels`]; this single-pass version stays as the
    /// smallest readable statement of the kernel and for the benches.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let cb = &self.codebooks[r];
            let src = &self.codes[r * self.cols..(r + 1) * self.cols];
            let mut acc = 0.0f32;
            for (c, &code) in src.iter().enumerate() {
                acc += cb[code as usize] * x[c];
            }
            y[r] = acc;
        }
    }

    /// Runtime memory footprint in bytes (codes + codebooks) — the number
    /// that drives memory-fetch latency at inference.
    pub fn memory_bytes(&self) -> usize {
        self.codes.len() + self.codebooks.iter().map(|c| c.len() * 4).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::IcqConfig;
    use crate::synthzoo;

    #[test]
    fn runtime_decode_equals_reference_dequant() {
        // The fused (n+1)-bit plane must reproduce exactly what the
        // two-codebook reference dequantization produces.
        let w = synthzoo::demo_matrix(16, 512, 31);
        for bits in [2u32, 3, 4] {
            let cfg = IcqConfig { bits, outlier_ratio: 0.05, gap_bits: 6, ..Default::default() };
            let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
            let reference = q.dequantize();
            let rt = q.to_runtime();
            let fused = rt.dequantize();
            assert!(reference.mse(&fused) < 1e-12, "bits={}", bits);
        }
    }

    #[test]
    fn matvec_matches_dense() {
        let w = synthzoo::demo_matrix(8, 128, 33);
        let q = IcqMatrix::quantize(&w, None, &IcqConfig::default()).unwrap();
        let rt = q.to_runtime();
        let dense = rt.dequantize();
        let x: Vec<f32> = (0..128).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut y = vec![0.0f32; 8];
        rt.matvec(&x, &mut y);
        for r in 0..8 {
            let want: f32 = dense.row(r).iter().zip(&x).map(|(a, b)| a * b).sum();
            assert!((y[r] - want).abs() < 1e-3, "row {}: {} vs {}", r, y[r], want);
        }
    }

    #[test]
    fn selector_bit_set_exactly_on_outliers() {
        let w = synthzoo::demo_matrix(4, 256, 35);
        let cfg = IcqConfig { bits: 2, outlier_ratio: 0.05, gap_bits: 5, ..Default::default() };
        let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
        let rt = q.to_runtime();
        for r in 0..4 {
            let positions = q.index_codes[r].decode();
            for c in 0..256 {
                let has_sel = rt.codes[r * 256 + c] & 0b100 != 0;
                assert_eq!(has_sel, positions.contains(&c), "r={} c={}", r, c);
            }
        }
    }

    #[test]
    fn memory_footprint_shrinks_vs_fp16() {
        let w = synthzoo::demo_matrix(64, 1024, 37);
        let q = IcqMatrix::quantize(&w, None, &IcqConfig::default()).unwrap();
        let rt = q.to_runtime();
        let fp16_bytes = 64 * 1024 * 2;
        // Runtime plane is byte-aligned (8 bits/weight) — less than fp16
        // but more than the 2.31-bit storage plane; both are reported.
        assert!(rt.memory_bytes() < fp16_bytes);
        assert!(q.storage_bytes() < rt.memory_bytes());
    }
}

//! Load-time decode: storage artifact → runtime plane.
//!
//! The gap streams decode **once** at model load into a selector bit that
//! is fused into the code as its MSB, producing one **bit-packed**
//! (n+1)-bit code per weight plus a flat fused-codebook buffer of
//! `2^(n+1)` entries per row (inliers at codes `0..2^n`, outliers at
//! `2^n..2^(n+1)`). This is the plane the fused CPU kernels
//! ([`crate::kernels`]) consume: fixed-width codes, no per-weight
//! branching, and — unlike the byte-aligned v1 layout — the hot loop
//! streams `(n+1)/8` bytes per weight instead of a full byte, which on
//! the memory-bound shapes the paper targets is the whole latency story
//! (DESIGN.md §4, §8).
//!
//! Layout invariants the kernels rely on:
//!
//! * codes are row-aligned ([`PackedPlane::pack_row_aligned`]): each row
//!   starts on a byte boundary, so a block of `BLOCK` codes at any
//!   `BLOCK`-multiple column offset also starts byte-aligned
//!   (`BLOCK·width ≡ 0 mod 8`), and the in-loop unpacker never needs a
//!   bit offset;
//! * codebooks are one contiguous `f32` buffer with stride `2^(bits+1)`
//!   — `codebook(r)` is a subslice, not a pointer chase through
//!   per-row `Vec`s.

use super::IcqMatrix;
use crate::bitstream::{pack_aligned_u8, PackedPlane};
use crate::util::tensor::Matrix;

/// Codes staged per unpack chunk on the non-kernel paths (dequantize,
/// matvec). The fused kernels use their own block size.
const CHUNK: usize = 512;

/// Runtime representation: bit-packed fused codes + flat codebooks.
pub struct RuntimePlane {
    pub rows: usize,
    pub cols: usize,
    /// Base bit-width n; the packed fused codes are `n+1` bits wide.
    pub bits: u32,
    /// Row-aligned bit-packed `code | (is_outlier << bits)` plane.
    packed: PackedPlane,
    /// Per-row fused codebooks, flattened: `2^(bits+1)` f32 levels per
    /// row, contiguous.
    codebooks: Vec<f32>,
}

impl IcqMatrix {
    /// Decode the storage artifact into the runtime plane.
    ///
    /// The gap-stream selector is OR-ed **directly into the packed
    /// write**: each row's n-bit codes are unpacked into one reused
    /// buffer, outlier positions stream from the index code
    /// ([`crate::icq::RowIndexCode::positions`] — zero per-row heap
    /// allocation), and the fused (n+1)-bit row is packed straight into
    /// the destination buffer.
    pub fn to_runtime(&self) -> RuntimePlane {
        assert!(
            self.bits <= 7,
            "runtime planes stage codes through u8: bits must be ≤7, got {}",
            self.bits
        );
        let width = self.bits + 1;
        let stride = PackedPlane::aligned_row_stride(self.cols, width);
        let mut bytes = vec![0u8; self.rows * stride];
        let sel = 1u8 << self.bits;
        let mut row_codes = vec![0u8; self.cols];
        for r in 0..self.rows {
            self.code_plane.unpack_row_u8(r, &mut row_codes);
            for c in self.index_codes[r].positions() {
                row_codes[c] |= sel;
            }
            pack_aligned_u8(&row_codes, width, &mut bytes[r * stride..(r + 1) * stride]);
        }
        let cb_stride = 1usize << width;
        let mut codebooks = Vec::with_capacity(self.rows * cb_stride);
        for r in 0..self.rows {
            debug_assert_eq!(self.inlier_cbs[r].levels.len() * 2, cb_stride);
            debug_assert_eq!(self.outlier_cbs[r].levels.len() * 2, cb_stride);
            codebooks.extend_from_slice(&self.inlier_cbs[r].levels);
            codebooks.extend_from_slice(&self.outlier_cbs[r].levels);
        }
        RuntimePlane {
            rows: self.rows,
            cols: self.cols,
            bits: self.bits,
            packed: PackedPlane::from_row_aligned_bytes(self.rows, self.cols, width, bytes),
            codebooks,
        }
    }
}

impl RuntimePlane {
    /// Packed code width in bits (`bits + 1`).
    #[inline]
    pub fn width(&self) -> u32 {
        self.bits + 1
    }

    /// Entries per row in the fused codebook (`2^(bits+1)`).
    #[inline]
    pub fn cb_stride(&self) -> usize {
        1usize << (self.bits + 1)
    }

    /// Row `r`'s fused codebook (`2^(bits+1)` levels).
    #[inline]
    pub fn codebook(&self, r: usize) -> &[f32] {
        let s = self.cb_stride();
        &self.codebooks[r * s..(r + 1) * s]
    }

    /// The whole flattened codebook buffer (`rows · 2^(bits+1)` f32) —
    /// the shape the PJRT quantized-forward entry takes as an argument.
    pub fn codebooks_flat(&self) -> &[f32] {
        &self.codebooks
    }

    /// Row `r`'s packed code bytes (`row_stride` of them).
    #[inline]
    pub fn row_bytes(&self, r: usize) -> &[u8] {
        self.packed.row_bytes(r)
    }

    /// Bytes one packed row occupies.
    #[inline]
    pub fn row_stride(&self) -> usize {
        self.packed.row_stride()
    }

    /// The packed code plane itself.
    pub fn packed(&self) -> &PackedPlane {
        &self.packed
    }

    /// One fused code (tests / instrumentation — not a hot path).
    pub fn code_at(&self, r: usize, c: usize) -> u8 {
        self.packed.get(r, c) as u8
    }

    /// Materialize the fused codes as one byte per weight — the v1
    /// layout, kept for consumers that need byte lanes (the PJRT
    /// quantized-forward argument builder, A/B benches, tests).
    pub fn byte_codes(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.rows * self.cols];
        self.packed.unpack_into_u8(&mut out);
        out
    }

    /// Build a plane from byte codes + a flat codebook buffer (tests and
    /// synthetic-plane construction; the serving path uses
    /// [`IcqMatrix::to_runtime`]).
    pub fn from_byte_codes(
        rows: usize,
        cols: usize,
        bits: u32,
        codes: &[u8],
        codebooks: Vec<f32>,
    ) -> RuntimePlane {
        assert!(bits <= 7, "bits must be ≤7");
        assert_eq!(codes.len(), rows * cols);
        assert_eq!(codebooks.len(), rows << (bits + 1), "codebook buffer shape mismatch");
        // Range-check in release too: an oversized code would bleed into
        // the neighboring packed slot and corrupt it silently.
        assert!(
            codes.iter().all(|&c| (c as usize) < (1usize << (bits + 1))),
            "code overflows the fused (bits+1)-bit width"
        );
        let width = bits + 1;
        let stride = PackedPlane::aligned_row_stride(cols, width);
        let mut bytes = vec![0u8; rows * stride];
        for r in 0..rows {
            pack_aligned_u8(
                &codes[r * cols..(r + 1) * cols],
                width,
                &mut bytes[r * stride..(r + 1) * stride],
            );
        }
        RuntimePlane {
            rows,
            cols,
            bits,
            packed: PackedPlane::from_row_aligned_bytes(rows, cols, width, bytes),
            codebooks,
        }
    }

    /// Dequantize the full plane to f32 (the PJRT weight-upload path;
    /// also the reference the fused kernels are bit-identical to).
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            self.dequantize_row_into(r, out.row_mut(r));
        }
        out
    }

    /// Dequantize one row into a caller buffer (streaming path).
    pub fn dequantize_row_into(&self, row: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.cols);
        let cb = self.codebook(row);
        let bytes = self.row_bytes(row);
        let width = self.width();
        let mut codes = [0u8; CHUNK];
        let mut c0 = 0usize;
        while c0 < self.cols {
            let len = CHUNK.min(self.cols - c0);
            let byte0 = c0 * width as usize / 8; // exact: c0 is a CHUNK multiple
            crate::bitstream::unpack_aligned_u8(&bytes[byte0..], width, &mut codes[..len]);
            for (d, &c) in out[c0..c0 + len].iter_mut().zip(&codes[..len]) {
                *d = cb[c as usize];
            }
            c0 += len;
        }
    }

    /// `y = W x` straight off the quantized plane (gather + FMA per
    /// element) — the memory-bound deployment kernel shape. The
    /// production form (blocked, multi-threaded, batched, pooled) lives
    /// in [`crate::kernels`]; this single-pass version stays as the
    /// smallest readable statement of the kernel and for the benches.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let width = self.width();
        let mut codes = [0u8; CHUNK];
        for (r, out) in y.iter_mut().enumerate() {
            let cb = self.codebook(r);
            let bytes = self.row_bytes(r);
            let mut acc = 0.0f32;
            let mut c0 = 0usize;
            while c0 < self.cols {
                let len = CHUNK.min(self.cols - c0);
                let byte0 = c0 * width as usize / 8;
                crate::bitstream::unpack_aligned_u8(&bytes[byte0..], width, &mut codes[..len]);
                for (&c, xv) in codes[..len].iter().zip(&x[c0..c0 + len]) {
                    acc += cb[c as usize] * *xv;
                }
                c0 += len;
            }
            *out = acc;
        }
    }

    /// Runtime memory footprint in bytes (packed codes incl. row padding
    /// + flat codebooks) — the number that drives memory-fetch latency
    /// at inference, and what [`crate::store::DecodeCache`] charges.
    pub fn memory_bytes(&self) -> usize {
        self.packed.storage_bytes() + self.codebooks.len() * 4
    }

    /// Resident bits per weight (codes + codebooks + row padding).
    pub fn bits_per_weight(&self) -> f64 {
        self.memory_bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::IcqConfig;
    use crate::synthzoo;

    #[test]
    fn runtime_decode_equals_reference_dequant() {
        // The fused (n+1)-bit plane must reproduce exactly what the
        // two-codebook reference dequantization produces.
        let w = synthzoo::demo_matrix(16, 512, 31);
        for bits in [2u32, 3, 4, 5] {
            let cfg = IcqConfig { bits, outlier_ratio: 0.05, gap_bits: 6, ..Default::default() };
            let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
            let reference = q.dequantize();
            let rt = q.to_runtime();
            let fused = rt.dequantize();
            assert!(reference.mse(&fused) < 1e-12, "bits={}", bits);
        }
    }

    #[test]
    fn matvec_matches_dense() {
        let w = synthzoo::demo_matrix(8, 128, 33);
        let q = IcqMatrix::quantize(&w, None, &IcqConfig::default()).unwrap();
        let rt = q.to_runtime();
        let dense = rt.dequantize();
        let x: Vec<f32> = (0..128).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut y = vec![0.0f32; 8];
        rt.matvec(&x, &mut y);
        for r in 0..8 {
            let want: f32 = dense.row(r).iter().zip(&x).map(|(a, b)| a * b).sum();
            assert!((y[r] - want).abs() < 1e-3, "row {}: {} vs {}", r, y[r], want);
        }
    }

    #[test]
    fn selector_bit_set_exactly_on_outliers() {
        let w = synthzoo::demo_matrix(4, 256, 35);
        let cfg = IcqConfig { bits: 2, outlier_ratio: 0.05, gap_bits: 5, ..Default::default() };
        let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
        let rt = q.to_runtime();
        for r in 0..4 {
            let positions = q.index_codes[r].decode();
            for c in 0..256 {
                let has_sel = rt.code_at(r, c) & 0b100 != 0;
                assert_eq!(has_sel, positions.contains(&c), "r={} c={}", r, c);
            }
        }
    }

    #[test]
    fn byte_codes_round_trip_through_packed_layout() {
        let w = synthzoo::demo_matrix(6, 333, 39); // odd cols: row padding
        for bits in [2u32, 3, 4] {
            let cfg = IcqConfig { bits, outlier_ratio: 0.05, gap_bits: 6, ..Default::default() };
            let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
            let rt = q.to_runtime();
            let bytes = rt.byte_codes();
            let rebuilt = RuntimePlane::from_byte_codes(
                rt.rows,
                rt.cols,
                rt.bits,
                &bytes,
                rt.codebooks_flat().to_vec(),
            );
            assert_eq!(rebuilt.packed(), rt.packed(), "bits={}", bits);
            assert_eq!(rebuilt.dequantize().data, rt.dequantize().data);
        }
    }

    #[test]
    fn memory_footprint_is_truly_low_bit() {
        let w = synthzoo::demo_matrix(64, 1024, 37);
        let q = IcqMatrix::quantize(&w, None, &IcqConfig::default()).unwrap();
        let rt = q.to_runtime();
        // 2-bit plane: 3 packed bits/weight + codebooks — under half the
        // v1 byte-code plane and far under fp16.
        let byte_plane = 64 * 1024 + rt.codebooks_flat().len() * 4;
        let fp16_bytes = 64 * 1024 * 2;
        assert!(rt.memory_bytes() * 2 < byte_plane);
        assert!(rt.memory_bytes() < fp16_bytes);
        // Still above the ≈2.3-bit storage artifact (selector bit, row
        // padding, f32 codebooks).
        assert!(q.storage_bytes() < rt.memory_bytes());
        // Exact accounting: rows·⌈cols·3/8⌉ + rows·8·4 codebook bytes.
        assert_eq!(rt.memory_bytes(), 64 * (1024 * 3usize).div_ceil(8) + 64 * 8 * 4);
        assert!(rt.bits_per_weight() < 4.1, "{}", rt.bits_per_weight());
    }
}

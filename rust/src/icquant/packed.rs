//! On-disk serialization of [`IcqMatrix`] — the deployment artifact whose
//! size *is* the paper's bits/weight claim, so the format is bit-frugal:
//! dense n-bit code plane, concatenated b-bit gap streams, f16 codebooks.
//!
//! Layout (little-endian):
//! ```text
//! magic   "ICQM"            4 B
//! version u32               4 B
//! hlen    u32               4 B
//! header  JSON              hlen B   (dims, bits, gap_bits, γ, quantizer)
//! n_symbols  rows × u32              (gap symbols per row)
//! n_outliers rows × u32
//! plane_len  u64 + code-plane bytes
//! gaps_len   u64 + concatenated gap-stream bytes (byte-aligned per row)
//! codebooks  rows × 2 × 2^bits × u16 (f16 levels: inlier then outlier)
//! ```

use super::IcqMatrix;
use crate::bitstream::PackedPlane;
use crate::icq::RowIndexCode;
use crate::quant::{Codebook, QuantizerKind};
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"ICQM";
const VERSION: u32 = 1;

fn header_json(m: &IcqMatrix) -> String {
    Json::obj(vec![
        ("rows", Json::num(m.rows as f64)),
        ("cols", Json::num(m.cols as f64)),
        ("bits", Json::num(m.bits as f64)),
        ("gap_bits", Json::num(m.gap_bits as f64)),
        ("outlier_ratio", Json::num(m.outlier_ratio)),
        (
            "quantizer",
            Json::str(match m.quantizer {
                QuantizerKind::Rtn => "rtn",
                QuantizerKind::SensitiveKmeans => "sk",
            }),
        ),
    ])
    .to_string()
}

/// Exact serialized size in bytes.
pub fn serialized_size(m: &IcqMatrix) -> usize {
    let header = header_json(m);
    let gaps: usize = m.index_codes.iter().map(|c| c.bytes().len()).sum();
    4 + 4 + 4 + header.len()
        + m.rows * 8 // n_symbols + n_outliers
        + 8 + m.code_plane.storage_bytes()
        + 8 + gaps
        + m.rows * 2 * (1usize << m.bits) * 2
}

pub fn save(m: &IcqMatrix, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    let header = header_json(m);
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for code in &m.index_codes {
        f.write_all(&code.n_symbols.to_le_bytes())?;
    }
    for code in &m.index_codes {
        f.write_all(&code.n_outliers.to_le_bytes())?;
    }
    let plane = m.code_plane.bytes();
    f.write_all(&(plane.len() as u64).to_le_bytes())?;
    f.write_all(plane)?;
    let gaps_len: usize = m.index_codes.iter().map(|c| c.bytes().len()).sum();
    f.write_all(&(gaps_len as u64).to_le_bytes())?;
    for code in &m.index_codes {
        f.write_all(code.bytes())?;
    }
    for r in 0..m.rows {
        for cb in [&m.inlier_cbs[r], &m.outlier_cbs[r]] {
            for &lv in &cb.levels {
                f.write_all(&f32_to_f16_bits(lv).to_le_bytes())?;
            }
        }
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<IcqMatrix> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an ICQM artifact: bad magic");
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("unsupported ICQM version {}", version);
    }
    let hlen = read_u32(&mut f)? as usize;
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow::anyhow!("header: {}", e))?;
    let rows = header.req("rows")?.as_usize().context("rows")?;
    let cols = header.req("cols")?.as_usize().context("cols")?;
    let bits = header.req("bits")?.as_usize().context("bits")? as u32;
    let gap_bits = header.req("gap_bits")?.as_usize().context("gap_bits")? as u32;
    let outlier_ratio = header.req("outlier_ratio")?.as_f64().context("outlier_ratio")?;
    let quantizer = match header.req("quantizer")?.as_str() {
        Some("rtn") => QuantizerKind::Rtn,
        Some("sk") => QuantizerKind::SensitiveKmeans,
        other => bail!("unknown quantizer {:?}", other),
    };

    let mut n_symbols = Vec::with_capacity(rows);
    for _ in 0..rows {
        n_symbols.push(read_u32(&mut f)?);
    }
    let mut n_outliers = Vec::with_capacity(rows);
    for _ in 0..rows {
        n_outliers.push(read_u32(&mut f)?);
    }
    let plane_len = read_u64(&mut f)? as usize;
    let mut plane_bytes = vec![0u8; plane_len];
    f.read_exact(&mut plane_bytes)?;
    let code_plane = PackedPlane::from_bytes(rows, cols, bits, plane_bytes);

    let gaps_len = read_u64(&mut f)? as usize;
    let mut gap_bytes = vec![0u8; gaps_len];
    f.read_exact(&mut gap_bytes)?;
    let mut index_codes = Vec::with_capacity(rows);
    let mut off = 0usize;
    for r in 0..rows {
        let nbytes = ((n_symbols[r] as usize) * gap_bits as usize).div_ceil(8);
        index_codes.push(RowIndexCode::from_parts(
            gap_bits,
            n_symbols[r],
            n_outliers[r],
            gap_bytes[off..off + nbytes].to_vec(),
        ));
        off += nbytes;
    }
    if off != gaps_len {
        bail!("gap stream length mismatch: consumed {} of {}", off, gaps_len);
    }

    let k = 1usize << bits;
    let mut inlier_cbs = Vec::with_capacity(rows);
    let mut outlier_cbs = Vec::with_capacity(rows);
    let mut lv_bytes = vec![0u8; k * 2];
    for _ in 0..rows {
        for which in 0..2 {
            f.read_exact(&mut lv_bytes)?;
            let levels: Vec<f32> = lv_bytes
                .chunks_exact(2)
                .map(|b| f16_bits_to_f32(u16::from_le_bytes([b[0], b[1]])))
                .collect();
            if which == 0 {
                inlier_cbs.push(Codebook { levels });
            } else {
                outlier_cbs.push(Codebook { levels });
            }
        }
    }

    Ok(IcqMatrix {
        bits,
        gap_bits,
        outlier_ratio,
        quantizer,
        rows,
        cols,
        code_plane,
        index_codes,
        inlier_cbs,
        outlier_cbs,
    })
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::IcqConfig;
    use crate::synthzoo;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("icq_packed_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn save_load_roundtrip_bitexact() {
        let w = synthzoo::demo_matrix(12, 300, 21);
        let cfg = IcqConfig { bits: 3, outlier_ratio: 0.05, gap_bits: 6, ..Default::default() };
        let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
        let p = tmp("roundtrip.icqm");
        save(&q, &p).unwrap();
        let q2 = load(&p).unwrap();
        // Codebooks are stored at f16; serialize once so q is at f16 too.
        let d1 = q.dequantize();
        let d2 = q2.dequantize();
        // Gap streams and code plane are bit-exact:
        assert_eq!(q.code_plane.bytes(), q2.code_plane.bytes());
        for r in 0..q.rows {
            assert_eq!(q.index_codes[r].decode(), q2.index_codes[r].decode());
        }
        // Dequantized values agree to f16 codebook precision.
        assert!(d1.mse(&d2) < 1e-6, "mse {}", d1.mse(&d2));
    }

    #[test]
    fn serialized_size_matches_file() {
        let w = synthzoo::demo_matrix(8, 512, 23);
        let q = IcqMatrix::quantize(&w, None, &IcqConfig::default()).unwrap();
        let p = tmp("size.icqm");
        save(&q, &p).unwrap();
        let actual = std::fs::metadata(&p).unwrap().len() as usize;
        assert_eq!(actual, serialized_size(&q));
        // File-level bits/weight ≈ n + B + codebooks + small header.
        let bits_per_weight = actual as f64 * 8.0 / q.code_plane.storage_bits() as f64
            * q.bits as f64;
        assert!(bits_per_weight < 4.0, "file bits/weight {}", bits_per_weight);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.icqm");
        std::fs::write(&p, b"not an artifact").unwrap();
        assert!(load(&p).is_err());
    }
}

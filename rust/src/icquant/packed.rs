//! On-disk serialization of [`IcqMatrix`] — the deployment artifact whose
//! size *is* the paper's bits/weight claim, so the format is bit-frugal:
//! dense n-bit code plane, concatenated b-bit gap streams, f16 codebooks.
//!
//! Layout (little-endian):
//! ```text
//! magic   "ICQM"            4 B
//! version u32               4 B
//! hlen    u32               4 B
//! header  JSON              hlen B   (dims, bits, gap_bits, γ, quantizer)
//! n_symbols  rows × u32              (gap symbols per row)
//! n_outliers rows × u32
//! plane_len  u64 + code-plane bytes
//! gaps_len   u64 + concatenated gap-stream bytes (byte-aligned per row)
//! codebooks  rows × 2 × 2^bits × u16 (f16 levels: inlier then outlier)
//! ```
//!
//! The same byte layout is embedded verbatim as the `icq` sections of the
//! multi-tensor `ICQZ` container ([`crate::store::container`]); every read
//! here is hardened against truncated or corrupt input — dims are bounded,
//! payload lengths are validated against the header before allocation, and
//! all failures are `anyhow` errors, never panics.

use super::IcqMatrix;
use crate::bitstream::PackedPlane;
use crate::icq::RowIndexCode;
use crate::quant::{Codebook, QuantizerKind};
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"ICQM";
const VERSION: u32 = 1;

/// Upper bound on the JSON header we will ever emit; reads reject larger
/// values before allocating (corrupt `hlen` must not drive an OOM).
const MAX_HEADER_LEN: usize = 1 << 16;

fn header_json(m: &IcqMatrix) -> String {
    Json::obj(vec![
        ("rows", Json::num(m.rows as f64)),
        ("cols", Json::num(m.cols as f64)),
        ("bits", Json::num(m.bits as f64)),
        ("gap_bits", Json::num(m.gap_bits as f64)),
        ("outlier_ratio", Json::num(m.outlier_ratio)),
        ("quantizer", Json::str(m.quantizer.to_str())),
    ])
    .to_string()
}

/// Exact serialized size in bytes.
pub fn serialized_size(m: &IcqMatrix) -> usize {
    let header = header_json(m);
    let gaps: usize = m.index_codes.iter().map(|c| c.bytes().len()).sum();
    4 + 4 + 4 + header.len()
        + m.rows * 8 // n_symbols + n_outliers
        + 8 + m.code_plane.storage_bytes()
        + 8 + gaps
        + m.rows * 2 * (1usize << m.bits) * 2
}

/// Serialize into any writer (file, in-memory container section, …).
pub fn write_to<W: Write>(m: &IcqMatrix, f: &mut W) -> Result<()> {
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    let header = header_json(m);
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for code in &m.index_codes {
        f.write_all(&code.n_symbols.to_le_bytes())?;
    }
    for code in &m.index_codes {
        f.write_all(&code.n_outliers.to_le_bytes())?;
    }
    let plane = m.code_plane.bytes();
    f.write_all(&(plane.len() as u64).to_le_bytes())?;
    f.write_all(plane)?;
    let gaps_len: usize = m.index_codes.iter().map(|c| c.bytes().len()).sum();
    f.write_all(&(gaps_len as u64).to_le_bytes())?;
    for code in &m.index_codes {
        f.write_all(code.bytes())?;
    }
    for r in 0..m.rows {
        for cb in [&m.inlier_cbs[r], &m.outlier_cbs[r]] {
            for &lv in &cb.levels {
                f.write_all(&f32_to_f16_bits(lv).to_le_bytes())?;
            }
        }
    }
    Ok(())
}

/// Serialize to an in-memory buffer (the `ICQZ` section payload path).
pub fn to_bytes(m: &IcqMatrix) -> Vec<u8> {
    let mut buf = Vec::with_capacity(serialized_size(m));
    write_to(m, &mut buf).expect("Vec<u8> writes are infallible");
    buf
}

pub fn save(m: &IcqMatrix, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    write_to(m, &mut f)
}

/// Deserialize from any reader. Every length field is validated against
/// the header dims before allocation; corrupt or truncated input yields a
/// descriptive error, never a panic or an unbounded allocation.
pub fn read_from<R: Read>(f: &mut R) -> Result<IcqMatrix> {
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic).context("read magic")?;
    if &magic != MAGIC {
        bail!("not an ICQM artifact: bad magic");
    }
    let version = read_u32(f).context("read version")?;
    if version != VERSION {
        bail!("unsupported ICQM version {}", version);
    }
    let hlen = read_u32(f).context("read header length")? as usize;
    ensure!(hlen <= MAX_HEADER_LEN, "header length {} exceeds cap {}", hlen, MAX_HEADER_LEN);
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes).context("read header")?;
    let header = Json::parse(std::str::from_utf8(&hbytes).context("header not utf-8")?)
        .map_err(|e| anyhow::anyhow!("header: {}", e))?;
    let rows = header.req("rows")?.as_usize().context("rows")?;
    let cols = header.req("cols")?.as_usize().context("cols")?;
    let bits = header.req("bits")?.as_usize().context("bits")? as u32;
    let gap_bits = header.req("gap_bits")?.as_usize().context("gap_bits")? as u32;
    let outlier_ratio = header.req("outlier_ratio")?.as_f64().context("outlier_ratio")?;
    let quantizer: QuantizerKind = header
        .req("quantizer")?
        .as_str()
        .context("quantizer not a string")?
        .parse()?;
    ensure!(rows >= 1 && cols >= 1, "degenerate dims {}x{}", rows, cols);
    ensure!(
        rows.checked_mul(cols).is_some_and(|n| n <= 1usize << 31),
        "implausible dims {}x{}",
        rows,
        cols
    );
    ensure!((1..=8).contains(&bits), "bits {} out of range 1..=8", bits);
    ensure!((1..=15).contains(&gap_bits), "gap_bits {} out of range 1..=15", gap_bits);
    ensure!(
        outlier_ratio.is_finite() && (0.0..0.5).contains(&outlier_ratio),
        "outlier_ratio {} out of range [0, 0.5)",
        outlier_ratio
    );

    // Every gap symbol advances the decode cursor by ≥ 1 position, so a
    // row of `cols` weights can never take more than `cols` symbols (and
    // never holds more outliers than columns) — bound both before
    // trusting them for the stream-slicing arithmetic below.
    let mut n_symbols = Vec::with_capacity(rows);
    for r in 0..rows {
        let n = read_u32(f).with_context(|| format!("read n_symbols[{}]", r))?;
        ensure!(n as usize <= cols, "row {}: n_symbols {} exceeds cols {}", r, n, cols);
        n_symbols.push(n);
    }
    let mut n_outliers = Vec::with_capacity(rows);
    for r in 0..rows {
        let n = read_u32(f).with_context(|| format!("read n_outliers[{}]", r))?;
        ensure!(n as usize <= cols, "row {}: n_outliers {} exceeds cols {}", r, n, cols);
        ensure!(
            n <= n_symbols[r],
            "row {}: n_outliers {} exceeds n_symbols {}",
            r,
            n,
            n_symbols[r]
        );
        n_outliers.push(n);
    }

    let plane_len = read_u64(f).context("read plane length")? as usize;
    let want_plane = (rows * cols * bits as usize).div_ceil(8);
    ensure!(
        plane_len == want_plane,
        "code plane is {} bytes, header dims imply {}",
        plane_len,
        want_plane
    );
    let mut plane_bytes = vec![0u8; plane_len];
    f.read_exact(&mut plane_bytes).context("read code plane")?;
    let code_plane = PackedPlane::from_bytes(rows, cols, bits, plane_bytes);

    let gaps_len = read_u64(f).context("read gap stream length")? as usize;
    let want_gaps: usize = n_symbols
        .iter()
        .map(|&n| (n as usize * gap_bits as usize).div_ceil(8))
        .sum();
    ensure!(
        gaps_len == want_gaps,
        "gap streams are {} bytes, per-row symbol counts imply {}",
        gaps_len,
        want_gaps
    );
    let mut gap_bytes = vec![0u8; gaps_len];
    f.read_exact(&mut gap_bytes).context("read gap streams")?;
    let mut index_codes = Vec::with_capacity(rows);
    let mut off = 0usize;
    for r in 0..rows {
        let nbytes = ((n_symbols[r] as usize) * gap_bits as usize).div_ceil(8);
        // `off + nbytes ≤ gaps_len` holds by the sum check above.
        let code = RowIndexCode::from_parts(
            gap_bits,
            n_symbols[r],
            n_outliers[r],
            gap_bytes[off..off + nbytes].to_vec(),
        );
        // The stream must decode to exactly the advertised outlier count
        // with every position inside the row — otherwise downstream mask
        // decodes would index out of bounds.
        let positions = code.decode();
        ensure!(
            positions.len() == n_outliers[r] as usize,
            "row {}: gap stream decodes {} outliers, header says {}",
            r,
            positions.len(),
            n_outliers[r]
        );
        if let Some(&last) = positions.last() {
            ensure!(
                last < cols,
                "row {}: outlier position {} out of range (cols {})",
                r,
                last,
                cols
            );
        }
        index_codes.push(code);
        off += nbytes;
    }

    let k = 1usize << bits;
    let mut inlier_cbs = Vec::with_capacity(rows);
    let mut outlier_cbs = Vec::with_capacity(rows);
    let mut lv_bytes = vec![0u8; k * 2];
    for r in 0..rows {
        for which in 0..2 {
            f.read_exact(&mut lv_bytes)
                .with_context(|| format!("read codebook (row {})", r))?;
            let levels: Vec<f32> = lv_bytes
                .chunks_exact(2)
                .map(|b| f16_bits_to_f32(u16::from_le_bytes([b[0], b[1]])))
                .collect();
            if which == 0 {
                inlier_cbs.push(Codebook { levels });
            } else {
                outlier_cbs.push(Codebook { levels });
            }
        }
    }

    Ok(IcqMatrix {
        bits,
        gap_bits,
        outlier_ratio,
        quantizer,
        rows,
        cols,
        code_plane,
        index_codes,
        inlier_cbs,
        outlier_cbs,
    })
}

/// Deserialize from an exact in-memory buffer; trailing bytes are an
/// error (container sections carry exact lengths).
pub fn from_bytes(bytes: &[u8]) -> Result<IcqMatrix> {
    let mut cursor = bytes;
    let m = read_from(&mut cursor)?;
    ensure!(
        cursor.is_empty(),
        "{} trailing bytes after ICQM payload",
        cursor.len()
    );
    Ok(m)
}

pub fn load(path: &Path) -> Result<IcqMatrix> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let m = read_from(&mut f)?;
    let mut probe = [0u8; 1];
    ensure!(
        f.read(&mut probe).context("probe for trailing data")? == 0,
        "trailing data after ICQM payload in {}",
        path.display()
    );
    Ok(m)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::icquant::IcqConfig;
    use crate::synthzoo;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("icq_packed_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn demo_artifact() -> IcqMatrix {
        let w = synthzoo::demo_matrix(12, 300, 21);
        let cfg = IcqConfig { bits: 3, outlier_ratio: 0.05, gap_bits: 6, ..Default::default() };
        IcqMatrix::quantize(&w, None, &cfg).unwrap()
    }

    #[test]
    fn save_load_roundtrip_bitexact() {
        let q = demo_artifact();
        let p = tmp("roundtrip.icqm");
        save(&q, &p).unwrap();
        let q2 = load(&p).unwrap();
        // Codebooks are stored at f16; serialize once so q is at f16 too.
        let d1 = q.dequantize();
        let d2 = q2.dequantize();
        // Gap streams and code plane are bit-exact:
        assert_eq!(q.code_plane.bytes(), q2.code_plane.bytes());
        for r in 0..q.rows {
            assert_eq!(q.index_codes[r].decode(), q2.index_codes[r].decode());
        }
        // Dequantized values agree to f16 codebook precision.
        assert!(d1.mse(&d2) < 1e-6, "mse {}", d1.mse(&d2));
    }

    #[test]
    fn serialized_size_matches_file() {
        let w = synthzoo::demo_matrix(8, 512, 23);
        let q = IcqMatrix::quantize(&w, None, &IcqConfig::default()).unwrap();
        let p = tmp("size.icqm");
        save(&q, &p).unwrap();
        let actual = std::fs::metadata(&p).unwrap().len() as usize;
        assert_eq!(actual, serialized_size(&q));
        assert_eq!(to_bytes(&q).len(), serialized_size(&q));
        // File-level bits/weight ≈ n + B + codebooks + small header.
        let bits_per_weight = actual as f64 * 8.0 / q.code_plane.storage_bits() as f64
            * q.bits as f64;
        assert!(bits_per_weight < 4.0, "file bits/weight {}", bits_per_weight);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.icqm");
        std::fs::write(&p, b"not an artifact").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn bytes_roundtrip_rejects_trailing() {
        let q = demo_artifact();
        let bytes = to_bytes(&q);
        let q2 = from_bytes(&bytes).unwrap();
        assert_eq!(q.code_plane.bytes(), q2.code_plane.bytes());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(from_bytes(&extra).is_err());
    }

    #[test]
    fn truncation_at_every_section_boundary_errors() {
        let q = demo_artifact();
        let bytes = to_bytes(&q);
        let header_len =
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let hdr_end = 12 + header_len;
        let counts_end = hdr_end + q.rows * 8;
        let plane_end = counts_end + 8 + q.code_plane.storage_bytes();
        let gaps: usize = q.index_codes.iter().map(|c| c.bytes().len()).sum();
        let gaps_end = plane_end + 8 + gaps;
        // Truncate at (and just inside) each section boundary: all must
        // error, none may panic.
        for cut in [3, 8, 11, hdr_end - 1, hdr_end, counts_end - 2, counts_end,
                    counts_end + 7, plane_end - 1, plane_end, gaps_end - 1,
                    gaps_end, bytes.len() - 1]
        {
            let err = from_bytes(&bytes[..cut]);
            assert!(err.is_err(), "truncation at {} of {} accepted", cut, bytes.len());
        }
    }

    #[test]
    fn byte_flip_in_metadata_is_detected() {
        let q = demo_artifact();
        let bytes = to_bytes(&q);
        // Flip every byte of the fixed-size prefix + length fields; the
        // loader must reject or at minimum never panic. (Flips inside the
        // code plane silently change codes — that's what the ICQZ CRCs
        // catch at the container level.)
        let header_len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        for i in 0..12 + header_len {
            let mut corrupt = bytes.clone();
            corrupt[i] ^= 0xA5;
            let _ = from_bytes(&corrupt); // must not panic
        }
        // Inflating a per-row symbol count past `cols` must be rejected.
        let mut corrupt = bytes.clone();
        let counts_off = 12 + header_len;
        corrupt[counts_off..counts_off + 4]
            .copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(from_bytes(&corrupt).is_err());
    }

    #[test]
    fn dim_payload_mismatch_is_rejected() {
        let q = demo_artifact();
        let bytes = to_bytes(&q);
        // Grow `cols` in the JSON header: the plane length no longer
        // matches the dims and the loader must say so.
        let header_len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let hdr = String::from_utf8(bytes[12..12 + header_len].to_vec()).unwrap();
        let hacked = hdr.replace("\"cols\":300", "\"cols\":301");
        assert_ne!(hdr, hacked);
        let mut out = Vec::new();
        out.extend_from_slice(&bytes[..8]);
        out.extend_from_slice(&(hacked.len() as u32).to_le_bytes());
        out.extend_from_slice(hacked.as_bytes());
        out.extend_from_slice(&bytes[12 + header_len..]);
        let err = from_bytes(&out).unwrap_err();
        assert!(format!("{:#}", err).contains("imply"), "{:#}", err);
    }
}

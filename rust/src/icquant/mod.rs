//! The ICQuant framework (§3): outlier partitioning + index coding + dual
//! codebooks, applicable on top of any scalar quantizer.
//!
//! Pipeline per output channel (row):
//! 1. **Partition** — the top-γ weights by |w| are outliers
//!    ([`crate::quant::mixed_precision::top_k_by_magnitude`]).
//! 2. **Index-code** — outlier positions become a b-bit gap stream
//!    ([`crate::icq::RowIndexCode`]), ≈0.31 bits/weight at γ=5 %.
//! 3. **Dual quantization** — inliers and outliers are quantized
//!    *separately* with the same bit-width n; each group spans ≈half the
//!    range, so n-bit ICQuant matches (n+1)-bit vanilla resolution.
//!
//! Both groups' codes share one dense n-bit plane (a weight is either an
//! inlier or an outlier, and the index stream disambiguates), so storage
//! is `n + B + codebooks` bits/weight.
//!
//! [`runtime`] holds the load-time decode into the fused (n+1)-bit plane
//! the serving kernels consume; [`packed`] the on-disk serialization.

pub mod packed;
pub mod runtime;

use crate::bitstream::PackedPlane;
use crate::icq::{optimal_b, RowIndexCode};
use crate::quant::mixed_precision::top_k_by_magnitude;
use crate::quant::{rtn, Codebook, QuantizerKind};
use crate::util::tensor::Matrix;
use anyhow::{ensure, Result};

/// Configuration for ICQuant quantization of one matrix.
#[derive(Clone, Copy, Debug)]
pub struct IcqConfig {
    /// Base bit-width n for both inlier and outlier codes.
    pub bits: u32,
    /// Outlier ratio γ (fraction of each row, e.g. 0.05).
    pub outlier_ratio: f64,
    /// Gap width b; 0 = pick the Lemma-1-optimal b for γ.
    pub gap_bits: u32,
    /// Base quantizer applied to each partition.
    pub quantizer: QuantizerKind,
}

impl Default for IcqConfig {
    fn default() -> Self {
        IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 0,
            quantizer: QuantizerKind::Rtn,
        }
    }
}

impl IcqConfig {
    pub fn resolved_gap_bits(&self) -> u32 {
        if self.gap_bits != 0 {
            self.gap_bits
        } else if self.outlier_ratio > 0.0 {
            optimal_b(self.outlier_ratio)
        } else {
            // γ = 0 emits no index stream; any width is vacuous.
            6
        }
    }
}

/// An ICQuant-quantized matrix: the complete storage artifact.
#[derive(Clone, Debug)]
pub struct IcqMatrix {
    pub bits: u32,
    pub gap_bits: u32,
    pub outlier_ratio: f64,
    pub quantizer: QuantizerKind,
    pub rows: usize,
    pub cols: usize,
    /// Dense n-bit code plane (inlier or outlier code per weight).
    pub code_plane: PackedPlane,
    /// Per-row gap-coded outlier positions.
    pub index_codes: Vec<RowIndexCode>,
    /// Per-row inlier codebooks (2^n levels).
    pub inlier_cbs: Vec<Codebook>,
    /// Per-row outlier codebooks (2^n levels).
    pub outlier_cbs: Vec<Codebook>,
}

impl IcqMatrix {
    /// Quantize `w` (optionally sensitivity-weighted) under `cfg`.
    ///
    /// # Examples
    ///
    /// The README's core claim, end to end: quantize at 2 bits + 5 %
    /// outliers for ≈2.3 bits/weight of storage, then decode once into
    /// the fused runtime plane the serving kernels consume.
    ///
    /// ```
    /// use icquant::icquant::{IcqConfig, IcqMatrix};
    ///
    /// let w = icquant::synthzoo::demo_matrix(8, 512, 7);
    /// let cfg = IcqConfig { bits: 2, outlier_ratio: 0.05, ..Default::default() };
    /// let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
    /// assert!(q.avg_bits_per_weight() < 2.5); // n + B ≈ 2.3
    ///
    /// let rt = q.to_runtime(); // bit-packed (n+1)-bit codes + flat fused codebooks
    /// assert_eq!(rt.dequantize().data, q.dequantize().data);
    /// assert!(rt.memory_bytes() < 8 * 512); // smaller than even one byte per code
    /// ```
    pub fn quantize(w: &Matrix, sens: Option<&Matrix>, cfg: &IcqConfig) -> Result<IcqMatrix> {
        // The serving representation is the fused (n+1)-bit runtime plane
        // staged through u8 lanes, so n is capped at 7 here — at quantize
        // time, where it can be an error instead of a load-time panic.
        ensure!(cfg.bits >= 1 && cfg.bits <= 7, "bits must be 1..=7");
        ensure!(
            cfg.outlier_ratio >= 0.0 && cfg.outlier_ratio < 0.5,
            "outlier ratio must be in [0, 0.5)"
        );
        // 0 = auto (Lemma-1 optimal); explicit widths must stay within
        // what the gap codec and the serialized artifact accept.
        ensure!(
            cfg.gap_bits == 0 || (1..=15).contains(&cfg.gap_bits),
            "gap_bits must be 0 (auto) or in 1..=15"
        );
        if let Some(s) = sens {
            ensure!((s.rows, s.cols) == (w.rows, w.cols), "sensitivity shape mismatch");
        }
        let b = cfg.resolved_gap_bits();
        let k = ((cfg.outlier_ratio * w.cols as f64).floor() as usize).min(w.cols);

        let mut codes = vec![0u16; w.numel()];
        let mut index_codes = Vec::with_capacity(w.rows);
        let mut inlier_cbs = Vec::with_capacity(w.rows);
        let mut outlier_cbs = Vec::with_capacity(w.rows);
        let mut is_outlier = vec![false; w.cols];
        let mut inlier_vals: Vec<f32> = Vec::with_capacity(w.cols);
        let mut inlier_sens: Vec<f32> = Vec::with_capacity(w.cols);
        let mut outlier_vals: Vec<f32> = Vec::with_capacity(k.max(1));
        let mut outlier_sens: Vec<f32> = Vec::with_capacity(k.max(1));

        for r in 0..w.rows {
            let row = w.row(r);
            let srow = sens.map(|s| s.row(r));

            let positions = top_k_by_magnitude(row, k);
            is_outlier.iter_mut().for_each(|x| *x = false);
            for &c in &positions {
                is_outlier[c] = true;
            }

            inlier_vals.clear();
            inlier_sens.clear();
            outlier_vals.clear();
            outlier_sens.clear();
            for c in 0..w.cols {
                if is_outlier[c] {
                    outlier_vals.push(row[c]);
                    if let Some(s) = srow {
                        outlier_sens.push(s[c]);
                    }
                } else {
                    inlier_vals.push(row[c]);
                    if let Some(s) = srow {
                        inlier_sens.push(s[c]);
                    }
                }
            }

            let in_cb = cfg.quantizer.fit(
                &inlier_vals,
                srow.map(|_| inlier_sens.as_slice()),
                cfg.bits,
            );
            // Outlier codebook: RTN uses the paper's two-sided layout
            // (Appendix E.1: 1 sign bit + (n−1)-bit per tail); K-means
            // handles the bimodal tails natively.
            let out_cb = if outlier_vals.is_empty() {
                Codebook { levels: vec![0.0; 1 << cfg.bits] }
            } else {
                match cfg.quantizer {
                    QuantizerKind::Rtn if cfg.bits >= 2 => {
                        rtn::fit_rtn_two_sided(&outlier_vals, cfg.bits)
                    }
                    _ => cfg.quantizer.fit(
                        &outlier_vals,
                        srow.map(|_| outlier_sens.as_slice()),
                        cfg.bits,
                    ),
                }
            };

            for c in 0..w.cols {
                let cb = if is_outlier[c] { &out_cb } else { &in_cb };
                codes[r * w.cols + c] = cb.encode(row[c]);
            }
            index_codes.push(RowIndexCode::encode(&positions, b));
            inlier_cbs.push(in_cb);
            outlier_cbs.push(out_cb);
        }

        Ok(IcqMatrix {
            bits: cfg.bits,
            gap_bits: b,
            outlier_ratio: cfg.outlier_ratio,
            quantizer: cfg.quantizer,
            rows: w.rows,
            cols: w.cols,
            code_plane: PackedPlane::pack(w.rows, w.cols, cfg.bits, &codes),
            index_codes,
            inlier_cbs,
            outlier_cbs,
        })
    }

    /// Full dequantization back to f32.
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let mut mask = vec![false; self.cols];
        let mut row_codes = vec![0u8; self.cols];
        for r in 0..self.rows {
            mask.iter_mut().for_each(|x| *x = false);
            self.index_codes[r].decode_into_mask(&mut mask);
            self.code_plane.unpack_row_u8(r, &mut row_codes);
            let in_cb = &self.inlier_cbs[r];
            let out_cb = &self.outlier_cbs[r];
            let orow = out.row_mut(r);
            for c in 0..self.cols {
                let cb = if mask[c] { out_cb } else { in_cb };
                orow[c] = cb.decode(row_codes[c] as u16);
            }
        }
        out
    }

    /// Index-coding overhead B in bits/weight (measured, not the bound).
    pub fn index_bits_per_weight(&self) -> f64 {
        let total: usize = self.index_codes.iter().map(|c| c.storage_bits()).sum();
        total as f64 / (self.rows * self.cols) as f64
    }

    /// Codebook storage in bits/weight (both partitions, f16 entries for
    /// K-means, scale/zero-equivalent for RTN — matching how the baselines
    /// are accounted).
    pub fn codebook_bits_per_weight(&self) -> f64 {
        // Two codebooks per row (inlier + outlier).
        2.0 * self.quantizer.param_bits(self.bits) as f64 / self.cols as f64
    }

    /// Total average bits/weight: n + B + codebooks. The paper's headline
    /// "2.31 bits" counts n + B (codebooks amortize to ~0 for scalar
    /// quantizers at LLM widths); [`Self::avg_bits_per_weight_full`] adds
    /// codebooks.
    pub fn avg_bits_per_weight(&self) -> f64 {
        self.bits as f64 + self.index_bits_per_weight()
    }

    pub fn avg_bits_per_weight_full(&self) -> f64 {
        self.avg_bits_per_weight() + self.codebook_bits_per_weight()
    }

    /// Exact serialized size in bytes (storage plane + index streams +
    /// codebooks + header).
    pub fn storage_bytes(&self) -> usize {
        packed::serialized_size(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synthzoo;
    use crate::util::prng::Rng;

    fn heavy_tailed(rows: usize, cols: usize, seed: u64) -> Matrix {
        synthzoo::demo_matrix(rows, cols, seed)
    }

    #[test]
    fn roundtrip_preserves_shape_and_is_finite() {
        let w = heavy_tailed(16, 256, 1);
        let q = IcqMatrix::quantize(&w, None, &IcqConfig::default()).unwrap();
        let d = q.dequantize();
        assert_eq!((d.rows, d.cols), (16, 256));
        assert!(d.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn overhead_near_lemma1_bound() {
        // γ=5 %, b=6 on uniform-ish outliers ⇒ B ≈ 0.31.
        let w = heavy_tailed(64, 2048, 3);
        let cfg = IcqConfig { bits: 2, outlier_ratio: 0.05, gap_bits: 6, ..Default::default() };
        let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
        let b = q.index_bits_per_weight();
        assert!(b < 0.33, "B={}", b);
        assert!(b > 0.25, "B={}", b);
        assert!((q.avg_bits_per_weight() - (2.0 + b)).abs() < 1e-12);
    }

    #[test]
    fn auto_gap_bits_matches_optimal() {
        let cfg = IcqConfig { outlier_ratio: 0.05, gap_bits: 0, ..Default::default() };
        assert_eq!(cfg.resolved_gap_bits(), 6);
    }

    #[test]
    fn rejects_bit_widths_the_runtime_plane_cannot_serve() {
        // n = 8 would need 9-bit fused codes; refuse it at quantize time
        // (the old byte-code plane silently corrupted the selector for
        // n = 8 in release builds).
        let w = heavy_tailed(2, 64, 17);
        let cfg = IcqConfig { bits: 8, ..Default::default() };
        assert!(IcqMatrix::quantize(&w, None, &cfg).is_err());
        let cfg = IcqConfig { bits: 7, ..Default::default() };
        assert!(IcqMatrix::quantize(&w, None, &cfg).is_ok());
    }

    #[test]
    fn rejects_unencodable_gap_width() {
        // A width the codec (and the ICQM/ICQZ readers) cannot accept
        // must be refused at quantize time, not at load time.
        let w = heavy_tailed(2, 64, 15);
        let cfg = IcqConfig { gap_bits: 16, ..Default::default() };
        assert!(IcqMatrix::quantize(&w, None, &cfg).is_err());
    }

    #[test]
    fn icquant_beats_vanilla_same_quantizer() {
        // Fig 3/Fig 5: n-bit ICQuant ≪ n-bit vanilla on heavy-tailed rows.
        let w = heavy_tailed(32, 1024, 5);
        for kind in [QuantizerKind::Rtn, QuantizerKind::SensitiveKmeans] {
            let cfg = IcqConfig { bits: 3, outlier_ratio: 0.05, gap_bits: 6, quantizer: kind };
            let icq = IcqMatrix::quantize(&w, None, &cfg).unwrap();
            let plain = crate::quant::quantize_per_row(&w, None, kind, 3);
            let icq_mse = w.mse(&icq.dequantize());
            let plain_mse = w.mse(&plain.dequantize());
            assert!(
                icq_mse < plain_mse * 0.6,
                "{:?}: icq {} vs plain {}",
                kind,
                icq_mse,
                plain_mse
            );
        }
    }

    #[test]
    fn matches_next_bit_vanilla_rtn() {
        // The paper's headline resolution claim (Fig 3): 2-bit ICQuant^RTN
        // ≈ 3-bit vanilla RTN when 5 % of outliers take ~50 % of range.
        let w = heavy_tailed(32, 2048, 7);
        let cfg = IcqConfig { bits: 2, outlier_ratio: 0.05, gap_bits: 6, ..Default::default() };
        let icq = IcqMatrix::quantize(&w, None, &cfg).unwrap();
        let rtn3 = crate::quant::quantize_per_row(&w, None, QuantizerKind::Rtn, 3);
        let ratio = w.mse(&icq.dequantize()) / w.mse(&rtn3.dequantize());
        assert!(ratio < 1.4, "2-bit ICQ / 3-bit RTN mse ratio = {}", ratio);
    }

    #[test]
    fn sensitivity_weighted_improves_weighted_error() {
        let w = heavy_tailed(8, 512, 9);
        let mut rng = Rng::new(11);
        let sens = Matrix::from_vec(
            8,
            512,
            (0..8 * 512).map(|_| rng.exponential(1.0) as f32).collect(),
        );
        let cfg = IcqConfig {
            bits: 2,
            quantizer: QuantizerKind::SensitiveKmeans,
            ..Default::default()
        };
        let with = IcqMatrix::quantize(&w, Some(&sens), &cfg).unwrap();
        let without = IcqMatrix::quantize(&w, None, &cfg).unwrap();
        let h: Vec<f32> = vec![1.0; 512]; // use sens directly below instead
        let _ = h;
        let werr = |m: &Matrix| {
            let mut acc = 0.0f64;
            for r in 0..8 {
                for c in 0..512 {
                    let d = (w.get(r, c) - m.get(r, c)) as f64;
                    acc += sens.get(r, c) as f64 * d * d;
                }
            }
            acc
        };
        assert!(werr(&with.dequantize()) <= werr(&without.dequantize()) * 1.02);
    }

    #[test]
    fn zero_outlier_ratio_reduces_to_plain() {
        let w = heavy_tailed(4, 256, 13);
        let cfg = IcqConfig { bits: 3, outlier_ratio: 0.0, gap_bits: 6, ..Default::default() };
        let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
        assert_eq!(q.index_bits_per_weight(), 0.0);
        let plain = crate::quant::quantize_per_row(&w, None, QuantizerKind::Rtn, 3);
        assert!(q.dequantize().mse(&plain.dequantize()) < 1e-12);
    }

    #[test]
    fn prop_outlier_positions_roundtrip_through_artifact() {
        use crate::util::miniprop::{check, Config};
        check(
            "icq-matrix-outlier-positions",
            Config::with_cases(24),
            |rng, size| {
                let rows = 1 + (size * 8.0) as usize;
                let cols = 64 + (size * 900.0) as usize;
                let seed = rng.next_u64();
                (rows, cols, seed)
            },
            |&(rows, cols, seed)| {
                let w = heavy_tailed(rows, cols, seed);
                let cfg = IcqConfig { bits: 2, outlier_ratio: 0.05, gap_bits: 5, ..Default::default() };
                let q = IcqMatrix::quantize(&w, None, &cfg).unwrap();
                let k = (0.05 * cols as f64).floor() as usize;
                for r in 0..rows {
                    let decoded = q.index_codes[r].decode();
                    let expected = top_k_by_magnitude(w.row(r), k);
                    crate::prop_assert!(
                        decoded == expected,
                        "row {} positions mismatch", r
                    );
                }
                Ok(())
            },
        );
    }
}

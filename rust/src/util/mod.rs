//! Substrate utilities: deterministic PRNG, minimal JSON, IEEE-754 half
//! precision, special functions, a tiny property-testing helper, and flat
//! tensor IO.
//!
//! The offline vendored registry only carries the `xla` crate closure, so
//! `rand`, `serde`, `half`, and `proptest` are reimplemented here as small,
//! well-tested modules.

pub mod prng;
pub mod json;
pub mod f16;
pub mod math;
pub mod miniprop;
pub mod tensor;

/// Format a byte count human-readably (`1.50 MiB`).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) lookup table, built at
/// compile time. Used for the per-section checksums of the `ICQZ`
/// container ([`crate::store::container`]).
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE) of `bytes` — the standard zlib/PNG checksum.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Format a duration in adaptive units (`1.23 ms`).
pub fn human_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{:.0} ns", ns)
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_duration_units() {
        assert_eq!(human_duration(std::time::Duration::from_nanos(500)), "500 ns");
        assert_eq!(human_duration(std::time::Duration::from_micros(1500)), "1.50 ms");
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard test vectors for CRC-32/IEEE.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        // Single-bit sensitivity: any flip changes the checksum.
        let base = crc32(b"icqz section payload");
        let mut corrupt = b"icqz section payload".to_vec();
        corrupt[3] ^= 0x01;
        assert_ne!(crc32(&corrupt), base);
    }
}

//! Minimal JSON parser + writer.
//!
//! `serde`/`serde_json` are not in the offline registry. We only need JSON
//! for artifact manifests we author ourselves (python `json.dump` on one
//! side, this module on the other), so a compact recursive-descent parser
//! over the full JSON grammar is sufficient and dependency-free.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in a `BTreeMap` so output is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ----- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| if n.fract() == 0.0 { Some(n as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `obj.req("field")?` with a descriptive error.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required json field '{}'", key))
    }

    // ----- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        self.ws();
        let mut v = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        self.ws();
        let mut m = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: handle BMP only (manifests are ASCII).
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.25", "1e3"] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse(r#"{"a":}"#).is_err());
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn python_style_manifest() {
        // Shape emitted by python json.dump in aot.py / train.py.
        let s = r#"{"layers": 4, "d_model": 256, "tensors": [{"name": "tok_emb", "shape": [512, 256], "offset": 0}]}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.get("layers").unwrap().as_usize(), Some(4));
        let t = &v.get("tensors").unwrap().as_arr().unwrap()[0];
        assert_eq!(t.get("shape").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}

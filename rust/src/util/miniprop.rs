//! A tiny in-repo property-testing helper (`proptest` is not in the offline
//! registry).
//!
//! [`check`] runs a property over many seeded random cases; on failure it
//! re-runs with progressively "smaller" cases drawn from the same generator
//! (generator-driven shrinking: generators receive a `size` hint in `0..=1`
//! and should produce structurally smaller inputs for smaller sizes), then
//! panics with the seed so the case is reproducible.

use crate::util::prng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xDEC0DE }
    }
}

impl Config {
    pub fn with_cases(cases: usize) -> Self {
        Config { cases, seed: 0xDEC0DE }
    }

    /// [`Config::with_cases`] whose seed honours the `ICQ_TEST_SEED`
    /// environment variable when set (decimal or `0x`-hex) — how
    /// `ci.sh` re-runs the randomized suites under a seed matrix
    /// without recompiling. Falls back to the default seed.
    pub fn from_env(cases: usize) -> Self {
        let seed = std::env::var("ICQ_TEST_SEED")
            .ok()
            .and_then(|s| parse_seed(&s))
            .unwrap_or(0xDEC0DE);
        Config { cases, seed }
    }
}

/// Parse a seed string: decimal or `0x`-prefixed hex.
fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse::<u64>().ok(),
    }
}

/// Kernel-pool widths the randomized suites run at: `ICQ_POOL_WORKERS`
/// (comma-separated positive integers) when set — one cell of the
/// ci.sh seed × worker matrix — else the default `1,2,4` sweep.
pub fn pool_worker_matrix() -> Vec<usize> {
    let parsed: Vec<usize> = std::env::var("ICQ_POOL_WORKERS")
        .map(|s| {
            s.split(',')
                .filter_map(|t| t.trim().parse().ok())
                .filter(|&w| w > 0)
                .collect()
        })
        .unwrap_or_default();
    if parsed.is_empty() {
        vec![1, 2, 4]
    } else {
        parsed
    }
}

/// Run `prop` over `cfg.cases` random inputs produced by `gen`.
///
/// `gen(rng, size)` — `size` ramps 0→1 over the run so early cases are
/// small. `prop` returns `Err(msg)` (or panics) to signal failure.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cfg: Config, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng, f64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let size = (case as f64 + 1.0) / cfg.cases as f64;
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let input = gen(&mut case_rng, size);
        if let Err(msg) = prop(&input) {
            // Shrink: try 32 smaller inputs from fresh sub-seeds; report the
            // smallest failing one we find.
            let mut best: (f64, T, String, u64) = (size, input, msg, case_seed);
            let mut shrink_rng = Rng::new(case_seed ^ 0x5EED);
            let mut s = size;
            for _ in 0..32 {
                s *= 0.7;
                let sseed = shrink_rng.next_u64();
                let mut r = Rng::new(sseed);
                let candidate = gen(&mut r, s);
                if let Err(m) = prop(&candidate) {
                    best = (s, candidate, m, sseed);
                }
            }
            panic!(
                "property '{}' failed (case {}, seed {:#x}, size {:.3}):\n  {}\n  input: {:?}",
                name, case, best.3, best.0, best.2, best.1
            );
        }
    }
}

/// Assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(
            "sum-commutes",
            Config::with_cases(64),
            |r, size| {
                let len = 1 + (size * 100.0) as usize;
                (0..len).map(|_| r.f64()).collect::<Vec<_>>()
            },
            |v| {
                n += 1;
                let a: f64 = v.iter().sum();
                let b: f64 = v.iter().rev().sum();
                if (a - b).abs() < 1e-9 * v.len() as f64 {
                    Ok(())
                } else {
                    Err("sum not commutative".into())
                }
            },
        );
        assert_eq!(n, 64);
    }

    #[test]
    #[should_panic(expected = "property 'always-small' failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-small",
            Config::with_cases(64),
            |r, size| (r.f64() * size * 100.0) as u32,
            |&x| {
                if x < 5 {
                    Ok(())
                } else {
                    Err(format!("x={} not < 5", x))
                }
            },
        );
    }

    #[test]
    fn env_seed_parses_decimal_and_hex() {
        // from_env is exercised on its fallback path only (tests run in
        // parallel; mutating the process environment would race).
        assert_eq!(Config::from_env(8).cases, 8);
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed(" 0x2A "), Some(42));
        assert_eq!(parse_seed("0Xff"), Some(255));
        assert_eq!(parse_seed("bogus"), None);
    }

    #[test]
    fn deterministic_across_runs() {
        // Same config must generate the same sequence of inputs.
        let collect = || {
            let mut v = Vec::new();
            check(
                "collect",
                Config::with_cases(16),
                |r, _| r.next_u64(),
                |&x| {
                    v.push(x);
                    Ok(())
                },
            );
            v
        };
        assert_eq!(collect(), collect());
    }
}

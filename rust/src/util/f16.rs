//! IEEE-754 binary16 conversion (the `half` crate is not vendored).
//!
//! Used by the mixed-precision baseline (SqueezeLLM keeps outliers in FP16)
//! and for storage accounting. Round-to-nearest-even on encode, exact on
//! decode.

/// Convert `f32` → `f16` bits with round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | m | ((mant >> 13) as u16 & 0x03FF.min(0x3FF));
    }

    // Re-bias: f32 bias 127, f16 bias 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        // Overflow → ±Inf
        return sign | 0x7C00;
    }
    if unbiased >= -14 {
        // Normal range.
        let half_exp = ((unbiased + 15) as u16) << 10;
        let half_mant = (mant >> 13) as u16;
        let round_bits = mant & 0x1FFF;
        let mut out = sign | half_exp | half_mant;
        // Round to nearest even.
        if round_bits > 0x1000 || (round_bits == 0x1000 && (half_mant & 1) == 1) {
            out = out.wrapping_add(1); // carries into exponent correctly
        }
        return out;
    }
    if unbiased >= -25 {
        // Subnormal half.
        let shift = (-14 - unbiased) as u32; // 1..=11
        let full_mant = mant | 0x0080_0000; // implicit leading 1
        let half_mant = (full_mant >> (13 + shift)) as u16;
        let round_pos = 13 + shift;
        let round_bits = full_mant & ((1u32 << round_pos) - 1);
        let half_ulp = 1u32 << (round_pos - 1);
        let mut out = sign | half_mant;
        if round_bits > half_ulp || (round_bits == half_ulp && (half_mant & 1) == 1) {
            out = out.wrapping_add(1);
        }
        return out;
    }
    // Underflow → ±0
    sign
}

/// Convert `f16` bits → `f32` (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign // ±0
        } else {
            // Subnormal: normalize.
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            // After s shifts, e = msb(mant) − 11; unbiased exp = msb − 24,
            // so the f32 exponent field is e + 114.
            sign | (((e + 114) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // Inf/NaN
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip an f32 through f16 precision (what "store in FP16" costs).
#[inline]
pub fn to_f16_precision(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers() {
        for i in -2048i32..=2048 {
            let x = i as f32;
            assert_eq!(to_f16_precision(x), x, "i={}", i);
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(0.5), 0x3800);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // f16 max
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7BFF), 65504.0);
    }

    #[test]
    fn overflow_to_inf() {
        assert_eq!(f32_to_f16_bits(1e6), 0x7C00);
        assert!(f16_bits_to_f32(0x7C00).is_infinite());
        assert_eq!(f32_to_f16_bits(-1e6), 0xFC00);
    }

    #[test]
    fn subnormals() {
        let tiny = 5.96e-8_f32; // ~smallest subnormal f16
        let rt = to_f16_precision(tiny);
        assert!(rt > 0.0 && (rt - tiny).abs() / tiny < 0.5);
        // Below underflow threshold → 0.
        assert_eq!(to_f16_precision(1e-10), 0.0);
    }

    #[test]
    fn nan_preserved() {
        assert!(to_f16_precision(f32::NAN).is_nan());
    }

    #[test]
    fn relative_error_bound_normals() {
        // f16 has 11 bits of significand → rel err ≤ 2^-11.
        let mut state = 0x12345u64;
        for _ in 0..10_000 {
            let r = crate::util::prng::splitmix64(&mut state);
            let x = ((r >> 40) as f32 / (1u64 << 24) as f32) * 100.0 - 50.0;
            if x.abs() < 1e-3 {
                continue;
            }
            let e = (to_f16_precision(x) - x).abs() / x.abs();
            assert!(e <= 1.0 / 2048.0 + 1e-7, "x={} err={}", x, e);
        }
    }

    #[test]
    fn roundtrip_all_f16_bit_patterns() {
        // Every finite f16 must decode→encode to itself.
        for h in 0u16..=0xFFFF {
            let exp = (h >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/nan patterns not bit-stable for NaN payloads
            }
            let x = f16_bits_to_f32(h);
            let h2 = f32_to_f16_bits(x);
            // -0 and +0 normalize to themselves.
            assert_eq!(h, h2, "h={:04x} x={}", h, x);
        }
    }
}

//! Deterministic pseudo-random number generation.
//!
//! `rand` is not in the offline registry, so we implement the standard
//! splitmix64 seeder + xoshiro256** generator (Blackman & Vigna, 2018).
//! Everything in the repo that needs randomness (synthetic model zoo,
//! property tests, K-means init, Hadamard sign flips, workload generators)
//! goes through [`Rng`], so every experiment is reproducible from a seed.

/// splitmix64 step — used to expand a 64-bit seed into xoshiro state and as
/// a standalone cheap mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Not cryptographic; excellent statistical quality and
/// extremely fast — the right tool for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the last Box-Muller draw.
    cached_normal: Option<f64>,
}

impl Rng {
    /// Seed deterministically. Any `u64` is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (for parallel/per-row generation).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased multiply-shift.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Student-t with `nu` degrees of freedom (ratio-of-normals via
    /// chi-square from sum of squared normals; fine for nu up to ~50).
    pub fn student_t(&mut self, nu: f64) -> f64 {
        // chi2(nu) ~ gamma(nu/2, 2) via Marsaglia-Tsang.
        let chi2 = 2.0 * self.gamma(nu / 2.0);
        self.normal() / (chi2 / nu).sqrt()
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang squeeze (shape >= 0.01).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: gamma(a) = gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Exponential with rate `lambda`.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// `k` distinct indices sampled uniformly from `[0, n)`, sorted.
    /// Uses Floyd's algorithm — O(k) memory regardless of n.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut set = std::collections::HashSet::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j as u64 + 1) as usize;
            if !set.insert(t) {
                set.insert(j);
            }
        }
        let mut v: Vec<usize> = set.into_iter().collect();
        v.sort_unstable();
        v
    }

    /// Zipf-distributed integer in `[0, n)` with exponent `s`, via
    /// precomputed CDF walk (linear; use for modest n in workload gen).
    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.f64();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

/// Precompute a Zipf CDF for [`Rng::zipf`].
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for x in w.iter_mut() {
        acc += *x / total;
        *x = acc;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_range() {
        let mut r = Rng::new(3);
        let mut seen = [0usize; 10];
        for _ in 0..100_000 {
            seen[r.below(10) as usize] += 1;
        }
        for &c in &seen {
            // Each bucket ~10k; allow 10% slack.
            assert!((9_000..11_000).contains(&c), "bucket count {}", c);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.03, "var {}", var);
    }

    #[test]
    fn student_t_heavier_tail_than_normal() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let thresh = 4.0;
        let mut t_tail = 0;
        let mut n_tail = 0;
        for _ in 0..n {
            if r.student_t(3.0).abs() > thresh {
                t_tail += 1;
            }
            if r.normal().abs() > thresh {
                n_tail += 1;
            }
        }
        assert!(t_tail > 10 * (n_tail + 1), "t {} vs n {}", t_tail, n_tail);
    }

    #[test]
    fn gamma_mean() {
        let mut r = Rng::new(17);
        let shape = 4.5;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
        assert!((mean - shape).abs() < 0.06, "mean {}", mean);
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(23);
        for _ in 0..100 {
            let v = r.sample_indices(1000, 50);
            assert_eq!(v.len(), 50);
            for w in v.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(*v.last().unwrap() < 1000);
        }
    }

    #[test]
    fn sample_indices_uniform_positions() {
        // Positions of sampled indices should be uniform — this is the
        // mechanism the paper's random-permutation fallback relies on.
        let mut r = Rng::new(29);
        let mut hist = [0usize; 4];
        for _ in 0..4000 {
            for &i in &r.sample_indices(256, 16) {
                hist[i / 64] += 1;
            }
        }
        let total: usize = hist.iter().sum();
        for &h in &hist {
            let frac = h as f64 / total as f64;
            assert!((frac - 0.25).abs() < 0.02, "frac {}", frac);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(31);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_monotone_frequencies() {
        let cdf = zipf_cdf(50, 1.1);
        let mut r = Rng::new(37);
        let mut hist = vec![0usize; 50];
        for _ in 0..50_000 {
            hist[r.zipf(&cdf)] += 1;
        }
        assert!(hist[0] > hist[10] && hist[10] > hist[40]);
    }
}

//! Special functions needed by the statistics module: log-gamma,
//! regularized incomplete gamma (→ chi-square CDF), and erf.
//!
//! Implementations follow Numerical Recipes (Lanczos approximation for
//! lgamma; series + continued fraction for P(a,x)); accuracy ~1e-10, far
//! beyond what the chi-square tests need.

/// Natural log of the gamma function (Lanczos, g=7, n=9).
pub fn lgamma(x: f64) -> f64 {
    const COF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COF[0];
    let t = x + 7.5;
    for (i, &c) in COF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized lower incomplete gamma P(a, x) = γ(a,x)/Γ(a).
pub fn gamma_p(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0, "gamma_p domain: a={} x={}", a, x);
    if x == 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // Series representation converges fast here.
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-15 {
                break;
            }
        }
        sum * (-x + a * x.ln() - lgamma(a)).exp()
    } else {
        // Continued fraction for Q(a,x), then P = 1 - Q (Lentz's method).
        let mut b = x + 1.0 - a;
        let mut c = 1e308;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..500 {
            let an = -(i as f64) * (i as f64 - a);
            b += 2.0;
            d = an * d + b;
            if d.abs() < 1e-300 {
                d = 1e-300;
            }
            c = b + an / c;
            if c.abs() < 1e-300 {
                c = 1e-300;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 1e-15 {
                break;
            }
        }
        1.0 - h * (-x + a * x.ln() - lgamma(a)).exp()
    }
}

/// Chi-square CDF with `k` degrees of freedom.
#[inline]
pub fn chi2_cdf(x: f64, k: f64) -> f64 {
    if x <= 0.0 {
        0.0
    } else {
        gamma_p(k / 2.0, x / 2.0)
    }
}

/// Chi-square survival function (p-value of an observed statistic).
#[inline]
pub fn chi2_sf(x: f64, k: f64) -> f64 {
    1.0 - chi2_cdf(x, k)
}

/// Error function, Abramowitz & Stegun 7.1.26-style rational approx refined
/// via the incomplete gamma identity erf(x) = P(1/2, x²).
pub fn erf(x: f64) -> f64 {
    if x < 0.0 {
        -erf(-x)
    } else {
        gamma_p(0.5, x * x)
    }
}

/// Standard normal CDF.
#[inline]
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Inverse standard normal CDF (Acklam's algorithm, |rel err| < 1.15e-9).
pub fn norm_ppf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "norm_ppf domain");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -norm_ppf(1.0 - p)
    }
}

/// Chi-square upper critical value: smallest x with SF(x) <= alpha.
/// Bisection on the CDF — called once per (k, alpha), speed irrelevant.
pub fn chi2_critical(k: f64, alpha: f64) -> f64 {
    let (mut lo, mut hi) = (0.0, k + 100.0 * (k.sqrt() + 1.0));
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if chi2_sf(mid, k) > alpha {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{} vs {}", a, b);
    }

    #[test]
    fn lgamma_known() {
        close(lgamma(1.0), 0.0, 1e-12);
        close(lgamma(2.0), 0.0, 1e-12);
        close(lgamma(5.0), (24.0f64).ln(), 1e-10); // Γ(5)=24
        close(lgamma(0.5), (std::f64::consts::PI.sqrt()).ln(), 1e-10);
        close(lgamma(10.5), 13.940_625_219_403_76, 1e-8);
    }

    #[test]
    fn gamma_p_known() {
        // P(1, x) = 1 - e^-x
        for x in [0.1, 1.0, 3.0, 10.0] {
            close(gamma_p(1.0, x), 1.0 - (-x).exp(), 1e-12);
        }
        close(gamma_p(0.5, 0.5), 0.682_689_492_137, 1e-9); // erf(1/√2·√2·…)
    }

    #[test]
    fn chi2_cdf_known() {
        // scipy.stats.chi2.cdf references
        close(chi2_cdf(3.841458820694124, 1.0), 0.95, 1e-9);
        close(chi2_cdf(16.918977604620448, 9.0), 0.95, 1e-9);
        close(chi2_cdf(30.143527205646159, 15.0), 0.989, 2e-2);
        close(chi2_cdf(10.0, 10.0), 0.559_506_714_934, 1e-9);
    }

    #[test]
    fn chi2_critical_inverts_sf() {
        for k in [1.0, 5.0, 15.0, 63.0, 255.0] {
            let c = chi2_critical(k, 0.05);
            close(chi2_sf(c, k), 0.05, 1e-6);
        }
    }

    #[test]
    fn erf_known() {
        close(erf(0.0), 0.0, 1e-15);
        close(erf(1.0), 0.842_700_792_949_715, 1e-9);
        close(erf(-1.0), -0.842_700_792_949_715, 1e-9);
        close(erf(2.0), 0.995_322_265_018_953, 1e-9);
    }

    #[test]
    fn norm_cdf_ppf_roundtrip() {
        for p in [0.001, 0.01, 0.05, 0.3, 0.5, 0.8, 0.975, 0.999] {
            close(norm_cdf(norm_ppf(p)), p, 1e-7);
        }
        close(norm_ppf(0.975), 1.959_963_984_540_054, 1e-7);
    }
}

//! Flat row-major 2-D matrix type and raw binary tensor IO.
//!
//! Weight artifacts are stored as little-endian `f32` blobs plus a JSON
//! manifest (written by `python/compile/train.py`, read by
//! [`crate::model`]); this module provides the in-memory container and the
//! blob codec.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Row-major 2-D f32 matrix. Rows are the paper's "output channels".
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Mean squared error against another matrix of the same shape.
    pub fn mse(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut acc = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            let d = (*a - *b) as f64;
            acc += d * d;
        }
        acc / self.numel() as f64
    }

    /// Squared Frobenius norm of the difference (the paper's ‖Q(w)−w‖²).
    pub fn sq_err(&self, other: &Matrix) -> f64 {
        self.mse(other) * self.numel() as f64
    }

    /// Proxy-Hessian weighted error  Σ_ij H_j (a_ij − b_ij)²  with per-input
    /// -channel diagonal Hessian `h` (len == cols). This is the SqueezeLLM /
    /// GPTQ proxy objective restricted to a diagonal.
    pub fn weighted_sq_err(&self, other: &Matrix, h: &[f32]) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        assert_eq!(h.len(), self.cols);
        let mut acc = 0.0f64;
        for r in 0..self.rows {
            let a = self.row(r);
            let b = other.row(r);
            for c in 0..self.cols {
                let d = (a[c] - b[c]) as f64;
                acc += h[c] as f64 * d * d;
            }
        }
        acc
    }

    /// `self @ other` (naive; used in tests and small evals only — the hot
    /// path runs through PJRT).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (j, &b) in orow.iter().enumerate() {
                    out_row[j] += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.set(c, r, self.get(r, c));
            }
        }
        out
    }
}

/// Write a slice of f32 as little-endian bytes.
pub fn write_f32_slice<W: Write>(w: &mut W, data: &[f32]) -> Result<()> {
    // Chunked to avoid a full copy for large tensors.
    let mut buf = Vec::with_capacity(4 * 65536);
    for chunk in data.chunks(65536) {
        buf.clear();
        for x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Read `n` little-endian f32 values.
pub fn read_f32_vec<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes).context("short read of f32 blob")?;
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// Load a slice of a flat f32 blob file: `n` elements starting at element
/// offset `off`.
pub fn read_f32_at(path: &Path, off: usize, n: usize) -> Result<Vec<f32>> {
    use std::io::Seek;
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    f.seek(std::io::SeekFrom::Start(off as u64 * 4))?;
    read_f32_vec(&mut f, n)
}

/// Save a matrix as `<path>` raw blob (no header; shape travels in JSON).
pub fn save_matrix(path: &Path, m: &Matrix) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_f32_slice(&mut f, &m.data)?;
    Ok(())
}

/// Load a raw blob as a matrix with an externally-known shape.
pub fn load_matrix(path: &Path, rows: usize, cols: usize) -> Result<Matrix> {
    let meta = std::fs::metadata(path)?;
    if meta.len() != (rows * cols * 4) as u64 {
        bail!(
            "blob {} has {} bytes, expected {} for {}x{} f32",
            path.display(),
            meta.len(),
            rows * cols * 4,
            rows,
            cols
        );
    }
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    Ok(Matrix::from_vec(rows, cols, read_f32_vec(&mut f, rows * cols)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_access() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.row(0), &[1., 2., 3.]);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.get(1, 2), 6.0);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(2, 2, vec![1., 1., 1., 1.]);
        assert_eq!(a.matmul(&b).data, vec![3., 3., 7., 7.]);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn mse_and_weighted() {
        let a = Matrix::from_vec(1, 2, vec![1., 2.]);
        let b = Matrix::from_vec(1, 2, vec![2., 0.]);
        assert!((a.mse(&b) - 2.5).abs() < 1e-12);
        let werr = a.weighted_sq_err(&b, &[2.0, 1.0]);
        assert!((werr - (2.0 * 1.0 + 1.0 * 4.0)).abs() < 1e-12);
    }

    #[test]
    fn blob_roundtrip() {
        let dir = std::env::temp_dir().join("icq_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.bin");
        let m = Matrix::from_vec(3, 4, (0..12).map(|i| i as f32 * 0.5 - 2.0).collect());
        save_matrix(&p, &m).unwrap();
        let m2 = load_matrix(&p, 3, 4).unwrap();
        assert_eq!(m, m2);
        // Offset read gets the second row.
        let row1 = read_f32_at(&p, 4, 4).unwrap();
        assert_eq!(row1, m.row(1));
        // Wrong shape errors.
        assert!(load_matrix(&p, 4, 4).is_err());
    }
}

//! Serving coordinator: request router, dynamic batcher, decode scheduler.
//!
//! The paper's motivation is deployment (memory-bound LLM inference);
//! this module is the vLLM-router-shaped consumer of the quantized
//! artifacts. Architecture (std threads — tokio is not in the offline
//! registry, and a single-worker PJRT CPU pipeline doesn't need it):
//!
//! ```text
//! clients ── submit() ──► mpsc queue ──► worker thread
//!                                         │ 1. drain queue into a batch
//!                                         │    (max_batch / max_wait)
//!                                         │ 2. pick bucket (≥ batch len)
//!                                         │ 3. prefill (prompt → KV)
//!                                         │ 4. greedy decode loop
//!                                         └─► per-request response chans
//! ```
//!
//! The PJRT engine lives *inside* the worker thread (xla handles are not
//! `Send`); weight literals are built once at startup. [`backend`]
//! abstracts the model executor so the batching logic is property-tested
//! against a deterministic mock — and so the same loop can serve through
//! either the PJRT executor or the fused quantized-plane CPU kernels
//! ([`backend::NativeBackend`], `serve --backend=native`), whose weights
//! stay in (n+1)-bit runtime form for the whole request (DESIGN.md §7/§8).

pub mod backend;
pub mod batcher;
pub mod metrics;

use backend::Backend;
use batcher::{BatchPolicy, PendingRequest};
use metrics::{Metrics, RequestTiming};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub max_new_tokens: usize,
    /// Available batch buckets (compiled HLO variants), ascending.
    pub buckets: Vec<usize>,
    pub prefill_len: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            max_new_tokens: 32,
            buckets: vec![1, 2, 4, 8],
            prefill_len: 64,
        }
    }
}

/// A generation request.
pub struct GenerateRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// The response delivered on the per-request channel.
#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub timing: RequestTiming,
}

enum WorkItem {
    Request(GenerateRequest, Sender<GenerateResponse>, Instant),
    Shutdown,
}

/// Handle to a running server.
pub struct Server {
    tx: Sender<WorkItem>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start a server whose worker thread builds its own backend (PJRT
    /// handles are thread-local); `make_backend` runs on the worker.
    pub fn start<B, F>(mut cfg: ServeConfig, make_backend: F) -> Server
    where
        B: Backend,
        F: FnOnce() -> B + Send + 'static,
    {
        // A batch larger than the largest bucket cannot be served (the
        // bucket pick would truncate outputs below the batch size), so
        // clamp the policy rather than panic mid-flight.
        assert!(!cfg.buckets.is_empty(), "ServeConfig.buckets must be non-empty");
        cfg.max_batch = cfg.max_batch.min(*cfg.buckets.last().unwrap());
        let (tx, rx) = channel::<WorkItem>();
        let metrics = Arc::new(Metrics::default());
        let m = metrics.clone();
        let worker = std::thread::spawn(move || {
            let backend = make_backend();
            worker_loop(cfg, backend, rx, m);
        });
        Server { tx, next_id: AtomicU64::new(1), metrics, worker: Some(worker) }
    }

    /// Submit a prompt; returns the response receiver and the request id.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
    ) -> (u64, Receiver<GenerateResponse>) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        let req = GenerateRequest { id, prompt, max_new_tokens };
        self.tx
            .send(WorkItem::Request(req, rtx, Instant::now()))
            .expect("server worker gone");
        (id, rrx)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(WorkItem::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(WorkItem::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop<B: Backend>(
    cfg: ServeConfig,
    mut backend: B,
    rx: Receiver<WorkItem>,
    metrics: Arc<Metrics>,
) {
    let policy = BatchPolicy { max_batch: cfg.max_batch, max_wait: cfg.max_wait };
    let mut shutdown = false;
    while !shutdown {
        // Block for the first request.
        let first = match rx.recv() {
            Ok(WorkItem::Request(r, tx, t)) => PendingRequest { req: r, tx, arrived: t },
            Ok(WorkItem::Shutdown) | Err(_) => break,
        };
        let mut batch = vec![first];
        // Accumulate until the policy says flush. The wait deadline is
        // relative to *batch formation start*, not request arrival — a
        // backlog built up while the worker was busy must coalesce
        // immediately instead of tripping the deadline one-by-one.
        let batch_start = Instant::now();
        loop {
            if policy.should_flush(batch.len(), batch_start.elapsed()) {
                break;
            }
            // Drain whatever is already queued without waiting.
            match rx.try_recv() {
                Ok(WorkItem::Request(r, tx, t)) => {
                    batch.push(PendingRequest { req: r, tx, arrived: t });
                    continue;
                }
                Ok(WorkItem::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    shutdown = true;
                    break;
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => {}
            }
            // Queue empty: block for the remaining wait budget.
            let budget = policy.max_wait.saturating_sub(batch_start.elapsed());
            match rx.recv_timeout(budget) {
                Ok(WorkItem::Request(r, tx, t)) => {
                    batch.push(PendingRequest { req: r, tx, arrived: t })
                }
                Ok(WorkItem::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(_) => break, // timeout — flush what we have
            }
        }
        serve_batch(&cfg, &mut backend, batch, &metrics);
    }
}

/// Run one batch through prefill + decode and deliver responses.
fn serve_batch<B: Backend>(
    cfg: &ServeConfig,
    backend: &mut B,
    batch: Vec<PendingRequest>,
    metrics: &Metrics,
) {
    let n = batch.len();
    let bucket = batcher::pick_bucket(&cfg.buckets, n)
        .unwrap_or_else(|| *cfg.buckets.last().unwrap());
    metrics.record_batch(n, bucket);

    // Normalize prompts to the prefill window (left-truncate / left-pad
    // with spaces so the generation-relevant suffix survives).
    let mut prompts = Vec::with_capacity(bucket);
    for p in batch.iter() {
        prompts.push(batcher::fit_prompt(&p.req.prompt, cfg.prefill_len));
    }
    // Pad the bucket with copies of the first prompt (outputs discarded).
    while prompts.len() < bucket {
        prompts.push(prompts[0].clone());
    }

    let t_prefill = Instant::now();
    let mut state = match backend.prefill(&prompts) {
        Ok(s) => s,
        Err(e) => {
            for p in batch {
                let _ = p.tx.send(GenerateResponse {
                    id: p.req.id,
                    tokens: vec![],
                    timing: RequestTiming::failed(format!("prefill: {}", e)),
                });
            }
            return;
        }
    };
    let prefill_ms = t_prefill.elapsed().as_secs_f64() * 1e3;

    let max_steps = batch
        .iter()
        .map(|p| p.req.max_new_tokens)
        .max()
        .unwrap_or(0)
        .min(cfg.max_new_tokens);
    let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); bucket];
    let t_decode = Instant::now();
    let mut steps_done = 0usize;
    for _ in 0..max_steps {
        match backend.decode(&mut state) {
            Ok(next) => {
                for (o, &t) in outputs.iter_mut().zip(&next) {
                    o.push(t);
                }
                steps_done += 1;
            }
            Err(e) => {
                for p in batch {
                    let _ = p.tx.send(GenerateResponse {
                        id: p.req.id,
                        tokens: vec![],
                        timing: RequestTiming::failed(format!("decode: {}", e)),
                    });
                }
                return;
            }
        }
    }
    let decode_ms = t_decode.elapsed().as_secs_f64() * 1e3;

    for (i, p) in batch.into_iter().enumerate() {
        let n_tok = p.req.max_new_tokens.min(steps_done);
        let timing = RequestTiming {
            queue_ms: (t_prefill - p.arrived).as_secs_f64() * 1e3,
            prefill_ms,
            decode_ms,
            tokens: n_tok,
            error: None,
        };
        metrics.record_request(&timing);
        let _ = p.tx.send(GenerateResponse {
            id: p.req.id,
            tokens: outputs[i][..n_tok].to_vec(),
            timing,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::backend::MockBackend;
    use super::*;
    use std::collections::HashSet;

    fn mock_server(max_batch: usize, max_wait_ms: u64) -> Server {
        let cfg = ServeConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            max_new_tokens: 8,
            buckets: vec![1, 2, 4, 8],
            prefill_len: 16,
        };
        Server::start(cfg, MockBackend::new)
    }

    #[test]
    fn single_request_roundtrip() {
        let server = mock_server(4, 5);
        let (id, rx) = server.submit(vec![1, 2, 3], 4);
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.id, id);
        assert_eq!(resp.tokens.len(), 4);
        assert!(resp.timing.error.is_none());
        server.shutdown();
    }

    #[test]
    fn no_request_lost_or_duplicated_under_load() {
        let server = mock_server(8, 2);
        let mut rxs = Vec::new();
        for i in 0..50 {
            let (id, rx) = server.submit(vec![i as i32; 10], 3);
            rxs.push((id, rx));
        }
        let mut seen = HashSet::new();
        for (id, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.tokens.len(), 3);
            assert!(seen.insert(id), "duplicate response for {}", id);
        }
        assert_eq!(seen.len(), 50);
        // Metrics saw all 50.
        assert_eq!(server.metrics.snapshot().requests, 50);
        server.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        // With a generous wait, concurrent submissions coalesce.
        let server = mock_server(8, 50);
        let mut rxs = Vec::new();
        for i in 0..8 {
            let (_, rx) = server.submit(vec![i], 2);
            rxs.push(rx);
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let snap = server.metrics.snapshot();
        assert!(
            snap.batches < 8,
            "expected coalescing, got {} batches for 8 requests",
            snap.batches
        );
        server.shutdown();
    }

    #[test]
    fn mock_decode_is_deterministic_per_prompt() {
        // The mock derives tokens from the prompt — responses must match
        // between two identical submissions even when batched with others.
        let server = mock_server(8, 10);
        let (_, rx1) = server.submit(vec![42, 43], 5);
        let (_, rx2) = server.submit(vec![99], 5);
        let (_, rx3) = server.submit(vec![42, 43], 5);
        let r1 = rx1.recv_timeout(Duration::from_secs(5)).unwrap();
        let _ = rx2.recv_timeout(Duration::from_secs(5)).unwrap();
        let r3 = rx3.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r1.tokens, r3.tokens);
        server.shutdown();
    }

    #[test]
    fn max_batch_clamped_to_largest_bucket() {
        // Regression: max_batch beyond the largest bucket used to form
        // batches the bucket pick truncated, panicking on outputs[i].
        let server = mock_server(16, 30); // buckets top out at 8
        let mut rxs = Vec::new();
        for i in 0..16 {
            let (id, rx) = server.submit(vec![i as i32; 4], 2);
            rxs.push((id, rx));
        }
        for (id, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.tokens.len(), 2);
            assert!(resp.timing.error.is_none());
        }
        server.shutdown();
    }

    #[test]
    fn respects_max_new_tokens_per_request() {
        let server = mock_server(8, 20);
        let (_, rx_short) = server.submit(vec![1], 2);
        let (_, rx_long) = server.submit(vec![2], 7);
        assert_eq!(rx_short.recv_timeout(Duration::from_secs(5)).unwrap().tokens.len(), 2);
        assert_eq!(rx_long.recv_timeout(Duration::from_secs(5)).unwrap().tokens.len(), 7);
        server.shutdown();
    }
}

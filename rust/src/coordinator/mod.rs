//! Serving coordinator: request router, slot scheduler, decode loop.
//!
//! The paper's motivation is deployment (memory-bound LLM inference);
//! this module is the vLLM-router-shaped consumer of the quantized
//! artifacts. Architecture (std threads — tokio is not in the offline
//! registry, and a single-worker CPU pipeline doesn't need it):
//!
//! ```text
//! clients ── submit() ──► mpsc queue ──► worker thread
//!                                         │ owns `max_batch` KV slots
//!                                         │ between decode steps:
//!                                         │  1. retire finished slots
//!                                         │     (respond immediately)
//!                                         │  2. admit queued requests
//!                                         │     into freed slots
//!                                         │     (per-slot prefill)
//!                                         │  3. decode active slots
//!                                         └─► per-request response chans
//! ```
//!
//! This is **continuous batching** (DESIGN.md §9): a 2-token request
//! never waits for a 32-token batchmate, arrivals join mid-flight, and
//! finished slots stop burning kernel time. Backends whose compiled
//! graphs fix the batch shape ([`backend::PjrtBackend`]) are driven in
//! *waves* instead — run-to-completion admission, but responses still
//! leave the moment each lane finishes.
//!
//! Backends with a **paged KV cache** (DESIGN.md §10 —
//! [`backend::NativeBackend`]) are admitted on **free blocks** rather
//! than free slots: an admission round is gated on the pool's
//! allocatable headroom, each request's token target is clamped by a
//! block *reservation* (`Backend::reserve_tokens`) so an overcommitted
//! pool shortens responses instead of erroring mid-decode, and
//! retirement returns blocks (refcount-decremented — shared prefix
//! blocks survive in the registry).
//!
//! The model executor lives *inside* the worker thread (xla handles are
//! not `Send`); weight literals are built once at startup. [`backend`]
//! abstracts the executor so the scheduling logic is property-tested
//! against deterministic mocks — and so the same loop can serve through
//! either the PJRT executor or the fused quantized-plane CPU kernels
//! ([`backend::NativeBackend`], `serve --backend=native`), whose weights
//! stay in (n+1)-bit runtime form for the whole request (DESIGN.md §7–§9).

pub mod backend;
pub mod batcher;
pub mod metrics;

use crate::trace::{self, Cat, Stage};
use anyhow::{anyhow, Result};
use backend::{Backend, DecodeState};
use batcher::{AdmissionPolicy, BatchPolicy, Delivery, PendingRequest, QosQueue};
pub use batcher::{Class, QosConfig};
use metrics::{Metrics, RequestTiming};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Which decode scheduler the worker runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Slot-based continuous batching (default): per-request retirement
    /// and mid-flight admission. Requires a backend that
    /// [`Backend::admits_mid_decode`]; others fall back to waves.
    Continuous,
    /// Legacy run-to-completion waves: a batch is admitted whole and
    /// decodes until its longest member finishes. Kept for
    /// bucket-compiled backends and as the benchmark baseline.
    RunToCompletion,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// KV slots the worker owns (continuous) / largest wave (waves).
    /// Clamped to the largest bucket at startup.
    pub max_batch: usize,
    /// Wave-mode batch formation deadline (unused by the continuous
    /// scheduler, whose admission is immediate).
    pub max_wait: Duration,
    /// Hard per-request cap on generated tokens.
    pub max_new_tokens: usize,
    /// Available batch buckets (compiled HLO variants), ascending.
    pub buckets: Vec<usize>,
    pub prefill_len: usize,
    /// Token id used to left-pad short prompts to `prefill_len`. The
    /// worker clamps it into the backend's vocab before use — an
    /// out-of-range pad would pollute attention and index past the
    /// native embedding table.
    pub pad_id: i32,
    pub scheduler: SchedulerKind,
    /// Load-shedding and per-tenant fairness bounds (DESIGN.md §15);
    /// defaults are unbounded, so QoS is opt-in.
    pub qos: QosConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            max_new_tokens: 32,
            buckets: vec![1, 2, 4, 8],
            prefill_len: 64,
            pad_id: b' ' as i32,
            scheduler: SchedulerKind::Continuous,
            qos: QosConfig::default(),
        }
    }
}

/// A generation request.
pub struct GenerateRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// QoS class: admission priority and shed deadline (DESIGN.md §15).
    pub class: Class,
    /// Fairness bucket for the per-tenant in-flight cap.
    pub tenant: u64,
}

/// The response delivered on the per-request channel.
#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub timing: RequestTiming,
}

/// One event on a streaming response channel (DESIGN.md §15). Tokens
/// arrive the moment their decode step retires; the stream always ends
/// with exactly one `Done` or `Failed` — unless the sequence was
/// cancelled because the client dropped the receiver first, in which
/// case nothing further is delivered (nobody is listening).
#[derive(Clone, Debug)]
pub enum TokenEvent {
    Token(i32),
    Done(RequestTiming),
    Failed(String),
}

/// Per-request submission options beyond the prompt itself.
#[derive(Clone, Copy, Debug)]
pub struct SubmitOpts {
    pub max_new_tokens: usize,
    pub class: Class,
    pub tenant: u64,
}

impl Default for SubmitOpts {
    fn default() -> SubmitOpts {
        SubmitOpts { max_new_tokens: usize::MAX, class: Class::default(), tenant: 0 }
    }
}

enum WorkItem {
    Request(GenerateRequest, Delivery, Instant),
    Shutdown,
}

/// Handle to a running server.
pub struct Server {
    tx: Sender<WorkItem>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    /// Why the worker died, when it did (e.g. backend construction).
    worker_err: Arc<Mutex<Option<String>>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start a server whose worker thread builds its own backend (PJRT
    /// handles are thread-local); `make_backend` runs on the worker.
    /// `start` blocks until the backend is constructed, so a failed
    /// build is observable from the very first [`Server::submit`].
    pub fn start<B, F>(mut cfg: ServeConfig, make_backend: F) -> Server
    where
        B: Backend,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        // A batch larger than the largest bucket cannot be served (the
        // bucket pick would truncate outputs below the batch size), so
        // clamp the policy rather than panic mid-flight.
        assert!(!cfg.buckets.is_empty(), "ServeConfig.buckets must be non-empty");
        // PANIC: non-emptiness is asserted one line up.
        cfg.max_batch = cfg.max_batch.clamp(1, *cfg.buckets.last().unwrap());
        let (tx, rx) = channel::<WorkItem>();
        let metrics = Arc::new(Metrics::default());
        let worker_err = Arc::new(Mutex::new(None));
        let (ready_tx, ready_rx) = channel::<()>();
        let m = metrics.clone();
        let we = worker_err.clone();
        let worker = std::thread::spawn(move || {
            let backend = match make_backend() {
                Ok(b) => {
                    let _ = ready_tx.send(());
                    b
                }
                Err(e) => {
                    *we.lock().unwrap() =
                        Some(format!("backend construction failed: {:#}", e));
                    // Close the queue *before* unblocking `start`, so a
                    // submit racing this return fails deterministically.
                    drop(rx);
                    let _ = ready_tx.send(());
                    return;
                }
            };
            worker_loop(cfg, backend, rx, m);
        });
        let _ = ready_rx.recv();
        Server { tx, next_id: AtomicU64::new(1), metrics, worker_err, worker: Some(worker) }
    }

    /// Submit a prompt; returns the request id and the response
    /// receiver, or the reason the worker is gone (e.g. its backend
    /// failed to build) — the old implementation panicked here,
    /// poisoning every client of a dead server.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<(u64, Receiver<GenerateResponse>)> {
        self.submit_with(prompt, SubmitOpts { max_new_tokens, ..SubmitOpts::default() })
    }

    /// Submit with explicit QoS options; the response still arrives as
    /// one buffered [`GenerateResponse`].
    pub fn submit_with(
        &self,
        prompt: Vec<i32>,
        opts: SubmitOpts,
    ) -> Result<(u64, Receiver<GenerateResponse>)> {
        let (rtx, rrx) = channel();
        let id = self.enqueue(prompt, opts, Delivery::Whole(rtx))?;
        Ok((id, rrx))
    }

    /// Submit for streaming delivery: one [`TokenEvent::Token`] per
    /// decoded token as its step retires, terminated by `Done` (with
    /// the request timing) or `Failed`. Dropping the receiver
    /// mid-stream cancels the sequence — the scheduler retires its
    /// slot and returns its KV blocks on the next step (DESIGN.md §15).
    pub fn submit_streaming(
        &self,
        prompt: Vec<i32>,
        opts: SubmitOpts,
    ) -> Result<(u64, Receiver<TokenEvent>)> {
        let (rtx, rrx) = channel();
        let id = self.enqueue(prompt, opts, Delivery::Stream(rtx))?;
        Ok((id, rrx))
    }

    fn enqueue(&self, prompt: Vec<i32>, opts: SubmitOpts, delivery: Delivery) -> Result<u64> {
        // ORDERING: relaxed — only uniqueness of the id matters; the
        // request payload travels through the channel, which provides
        // its own happens-before edge to the serving thread.
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // An unclamped token budget (SubmitOpts::default) would wrap
        // negative in the trace payload.
        let want = opts.max_new_tokens.min(i64::MAX as usize) as i64;
        trace::instant(Cat::Request, "enqueue", id, prompt.len() as i64, want);
        let req = GenerateRequest {
            id,
            prompt,
            max_new_tokens: opts.max_new_tokens,
            class: opts.class,
            tenant: opts.tenant,
        };
        self.tx
            .send(WorkItem::Request(req, delivery, Instant::now()))
            .map_err(|_| match self.worker_err.lock().unwrap().as_ref() {
                Some(e) => anyhow!("server worker is gone: {}", e),
                None => anyhow!("server worker is gone (channel closed)"),
            })?;
        Ok(id)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(WorkItem::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(WorkItem::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop<B: Backend>(
    cfg: ServeConfig,
    mut backend: B,
    rx: Receiver<WorkItem>,
    metrics: Arc<Metrics>,
) {
    let pad_id = batcher::clamp_pad_id(cfg.pad_id, backend.vocab());
    if backend.admits_mid_decode() && cfg.scheduler == SchedulerKind::Continuous {
        slot_loop(&cfg, pad_id, &mut backend, &rx, &metrics);
    } else {
        wave_loop(&cfg, pad_id, &mut backend, &rx, &metrics);
    }
}

fn fail(p: &PendingRequest, msg: String, metrics: &Metrics) {
    metrics.record_error();
    trace::instant(Cat::Request, "error", p.req.id, 0, 0);
    if trace::enabled() {
        // Failures arrive with their own context: dump the most recent
        // events so the trace shows what the stack was doing.
        trace::flight_dump(&format!("request {} failed: {}", p.req.id, msg));
    }
    p.tx.fail(p.req.id, msg);
}

/// Load-shed a queued request: explicit failure, counted separately
/// from serving errors (DESIGN.md §15).
fn shed(p: &PendingRequest, msg: String, metrics: &Metrics) {
    metrics.record_shed();
    trace::instant(Cat::Request, "shed", p.req.id, p.req.class.priority as i64, 0);
    p.tx.fail(p.req.id, msg);
}

/// Enqueue with the per-class depth bound; overflow is shed on the spot.
fn queue_push(queue: &mut QosQueue, p: PendingRequest, max_per_class: usize, metrics: &Metrics) {
    if let Err(p) = queue.push(p, max_per_class) {
        let msg = format!(
            "shed: queue depth bound exceeded for priority class {}",
            p.req.class.priority
        );
        shed(&p, msg, metrics);
    }
}

/// Shutdown drain: fail the queue and the channel backlog explicitly so
/// no client ever hangs on a receiver whose request was silently
/// dropped (DESIGN.md §15).
fn drain_backlog(rx: &Receiver<WorkItem>, queue: &mut QosQueue, metrics: &Metrics) {
    const MSG: &str = "server shutting down before this request was served";
    let mut n = 0i64;
    for p in queue.drain_all() {
        fail(&p, MSG.to_string(), metrics);
        n += 1;
    }
    // A plain `while let Ok(WorkItem::Request(..))` would stop at a
    // Shutdown item sitting mid-channel and strand everything behind it.
    loop {
        match rx.try_recv() {
            Ok(WorkItem::Request(r, tx, t)) => {
                fail(&PendingRequest::new(r, tx, t), MSG.to_string(), metrics);
                n += 1;
            }
            Ok(WorkItem::Shutdown) => continue,
            Err(_) => break,
        }
    }
    if n > 0 {
        trace::instant(Cat::Sched, "drain", 0, n, 0);
    }
}

// ---------------------------------------------------------------------------
// Continuous-batching slot scheduler
// ---------------------------------------------------------------------------

/// One sequence occupying a KV slot.
struct SlotSeq {
    p: PendingRequest,
    target: usize,
    admitted: Instant,
    prefill_ms: f64,
    first_token_at: Option<Instant>,
    decode_ms: f64,
    tokens: Vec<i32>,
}

/// The continuous scheduler: the worker owns `max_batch` KV slots and,
/// between single decode steps, retires finished sequences (responding
/// immediately), admits queued requests into freed slots via per-slot
/// prefill, and decodes only the active slots.
fn slot_loop<B: Backend>(
    cfg: &ServeConfig,
    pad_id: i32,
    backend: &mut B,
    rx: &Receiver<WorkItem>,
    metrics: &Metrics,
) {
    let cap = cfg.max_batch;
    let policy = AdmissionPolicy { slots: cap };
    let mut state = match backend.new_state(cap) {
        Ok(s) => s,
        Err(e) => {
            // No scheduler state — fail every request until shutdown.
            let msg = format!("scheduler state: {:#}", e);
            while let Ok(WorkItem::Request(r, tx, t)) = rx.recv() {
                fail(&PendingRequest::new(r, tx, t), msg.clone(), metrics);
            }
            return;
        }
    };
    let mut slots: Vec<Option<SlotSeq>> = (0..cap).map(|_| None).collect();
    let mut queue = QosQueue::new();
    let max_per_class = cfg.qos.max_queue_per_class;
    // A zero per-tenant cap would deadlock admission outright; one slot
    // is the tightest fairness that still makes progress.
    let per_tenant = cfg.qos.max_slots_per_tenant.max(1);
    let mut draining = false;
    // Set when `state` (and its paged cache) is replaced after a decode
    // error, so the next metrics report starts a new counter epoch.
    let mut kv_cache_recreated = false;

    loop {
        let occupied = slots.iter().filter(|s| s.is_some()).count();

        // --- intake ------------------------------------------------------
        if !draining {
            if occupied == 0 && queue.is_empty() {
                // Idle: block for work.
                match rx.recv() {
                    Ok(WorkItem::Request(r, tx, t)) => {
                        let p = PendingRequest::new(r, tx, t);
                        queue_push(&mut queue, p, max_per_class, metrics);
                    }
                    Ok(WorkItem::Shutdown) | Err(_) => draining = true,
                }
            }
            // Non-blocking drain between decode steps.
            loop {
                match rx.try_recv() {
                    Ok(WorkItem::Request(r, tx, t)) => {
                        let p = PendingRequest::new(r, tx, t);
                        queue_push(&mut queue, p, max_per_class, metrics);
                    }
                    Ok(WorkItem::Shutdown) | Err(TryRecvError::Disconnected) => {
                        draining = true;
                        break;
                    }
                    Err(TryRecvError::Empty) => break,
                }
            }
            // Deadline shedding happens while requests still wait for a
            // slot — an admitted sequence is never shed mid-decode.
            for p in queue.drain_expired(Instant::now()) {
                shed(&p, "shed: deadline passed before admission".to_string(), metrics);
            }
        }
        if draining {
            // Queued-but-unserved work is failed explicitly (never
            // silently dropped); in-flight sequences finish first.
            drain_backlog(rx, &mut queue, metrics);
            if occupied == 0 {
                break;
            }
        }

        // --- admission: freed slots refill immediately, and the whole
        // round shares one batched prefill pass over the weights ------------
        let to_admit = policy.admit_now(occupied, queue.len());
        let mut picked: Vec<PendingRequest> = Vec::new();
        if to_admit > 0 {
            // QoS selection (DESIGN.md §15): [`QosQueue::select`] yields
            // candidates priority-first, skipping tenants already at
            // their in-flight cap and rotating round-robin across
            // tenants within a class.
            let mut tenant_load: HashMap<u64, usize> = HashMap::new();
            for seq in slots.iter().flatten() {
                *tenant_load.entry(seq.p.req.tenant).or_insert(0) += 1;
            }
            // Paged backends admit on **free blocks**, not free slots
            // (DESIGN.md §10). Each candidate is charged what its
            // prefill would actually allocate (the backend consults
            // its prefix registry — a shared-system-prompt request
            // costs a block or two, not the whole prompt); the
            // worst-case fallback is ⌈prefill_len / block⌉ prompt
            // blocks plus one reservable decode block when the prompt
            // fills its last block exactly (otherwise tail slack
            // guarantees the first decode tokens). A round that does
            // not fit waits for retirements to return blocks; an idle
            // worker still force-admits one request so an impossible
            // prompt fails with a clear error instead of stalling the
            // queue forever.
            let headroom = backend.kv_block_headroom(&state);
            let mut budget = headroom.map(|(free, _)| free);
            let fallback = headroom.map(|(_, block_tokens)| {
                cfg.prefill_len.div_ceil(block_tokens)
                    + usize::from(cfg.prefill_len % block_tokens == 0)
            });
            while picked.len() < to_admit {
                let Some(i) = queue.select(&tenant_load, per_tenant) else { break };
                if let (Some(budget), Some(fallback)) = (budget.as_mut(), fallback) {
                    // The normalized prompt is cached on the request
                    // (this gate re-examines waiting candidates every
                    // iteration). A candidate that does not fit stays
                    // queued — only probed, never removed.
                    let need = {
                        let prompt = queue.get_mut(i).normalized(cfg.prefill_len, pad_id);
                        backend.admission_block_need(&state, prompt).unwrap_or(fallback).max(1)
                    };
                    if need > *budget {
                        break;
                    }
                    *budget -= need;
                }
                let p = queue.remove(i);
                *tenant_load.entry(p.req.tenant).or_insert(0) += 1;
                picked.push(p);
            }
            if let Some((free_blocks, _)) = headroom {
                // Block-need accounting for the trace: how many of the
                // wanted admissions fit the allocatable headroom.
                trace::instant(Cat::Sched, "block_gate", 0, picked.len() as i64, free_blocks as i64);
                if picked.is_empty() && occupied == 0 {
                    // Idle force-admit ignores the tenant cap too — with
                    // nothing in flight the cap cannot be meaningful.
                    if let Some(i) = queue.select(&tenant_load, usize::MAX) {
                        trace::instant(Cat::Sched, "force_admit", 0, 0, free_blocks as i64);
                        picked.push(queue.remove(i));
                    }
                }
            }
        }
        if !picked.is_empty() {
            let mut round: Vec<(usize, PendingRequest)> = Vec::with_capacity(picked.len());
            let mut free_slots = (0..cap).filter(|&s| slots[s].is_none());
            for p in picked {
                // PANIC: `admit_now` never exceeds the free-slot count
                // (and the force-admit override only fires on an idle
                // worker, where every slot is free).
                round.push((free_slots.next().expect("picked within free slots"), p));
            }
            let admissions: Vec<(usize, Vec<i32>)> = round
                .iter_mut()
                .map(|(slot, p)| {
                    (*slot, p.normalized(cfg.prefill_len, pad_id).to_vec())
                })
                .collect();
            let t0 = Instant::now();
            let prefill_span =
                trace::span_args(Cat::Sched, "prefill_round", 0, admissions.len() as i64, 0);
            let prefill_res = backend.prefill_into_many(&mut state, &admissions);
            drop(prefill_span);
            match prefill_res {
                Ok(()) => {
                    // The pass is shared, so each request is charged the
                    // round's wall time (same accounting as a wave).
                    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
                    let n = round.len();
                    let requested: Vec<usize> = round
                        .iter()
                        .map(|(slot, p)| {
                            let want = p.req.max_new_tokens.min(cfg.max_new_tokens);
                            let mut target = want;
                            if let Some(max_pos) = backend.max_positions() {
                                // Clamp to the slot's KV headroom: an
                                // over-long request ends early instead
                                // of exhausting the cache mid-decode
                                // and erroring its whole batch.
                                target = target
                                    .min(max_pos.saturating_sub(state.pos[*slot]));
                            }
                            if target < want {
                                trace::instant(
                                    Cat::Sched,
                                    "clamp_positions",
                                    p.req.id,
                                    want as i64,
                                    target as i64,
                                );
                            }
                            target
                        })
                        .collect();
                    // Paged backends additionally clamp each target to
                    // the allocatable block headroom, *reserving* the
                    // blocks — a clamped sequence can then never hit
                    // pool exhaustion mid-decode. Two phases so a
                    // greedy round member cannot starve a batchmate to
                    // zero: everyone secures their first decode token
                    // first (reservations have total semantics — the
                    // second call extends the first).
                    for (&(slot, _), &want) in round.iter().zip(&requested) {
                        let _ = backend.reserve_tokens(&mut state, slot, want.min(1));
                    }
                    for ((slot, p), want) in round.into_iter().zip(requested) {
                        let target = backend.reserve_tokens(&mut state, slot, want);
                        if target < want {
                            trace::instant(
                                Cat::Sched,
                                "clamp_reservation",
                                p.req.id,
                                want as i64,
                                target as i64,
                            );
                        }
                        if target == 0 && want > 0 {
                            // Only possible on a force-admitted round
                            // into a pool too small to back one decode
                            // token: fail clearly instead of delivering
                            // an empty response as success.
                            let _ = backend.retire(&mut state, slot);
                            fail(
                                &p,
                                "KV block pool too small to decode any tokens for this request"
                                    .to_string(),
                                metrics,
                            );
                            continue;
                        }
                        trace::instant(
                            Cat::Request,
                            "admit",
                            p.req.id,
                            slot as i64,
                            target as i64,
                        );
                        trace::stage_ms(
                            Stage::Queue,
                            (t0 - p.arrived).as_secs_f64() * 1e3,
                        );
                        slots[slot] = Some(SlotSeq {
                            p,
                            target,
                            admitted: t0,
                            prefill_ms,
                            first_token_at: None,
                            decode_ms: 0.0,
                            tokens: Vec::new(),
                        });
                    }
                    metrics.record_batch(n, occupied + n);
                    trace::instant(
                        Cat::Sched,
                        "admit_round",
                        0,
                        n as i64,
                        (occupied + n) as i64,
                    );
                    trace::stage_ms(Stage::Prefill, prefill_ms);
                }
                Err(e) => {
                    let msg = format!("prefill: {:#}", e);
                    for (slot, p) in round {
                        // A partially-failed round may have activated
                        // earlier slots in the backend state; retire is
                        // idempotent, so free them unconditionally to
                        // keep scheduler and backend occupancy in sync.
                        let _ = backend.retire(&mut state, slot);
                        fail(&p, msg.clone(), metrics);
                    }
                }
            }
        }

        // Retire immediately-satisfiable admissions (max_new_tokens = 0).
        retire_finished(backend, &mut state, &mut slots, metrics);
        if slots.iter().all(|s| s.is_none()) {
            continue;
        }

        // --- one decode step over the active slots ------------------------
        let t0 = Instant::now();
        let active_now = slots.iter().filter(|s| s.is_some()).count();
        let step_span = trace::span_args(Cat::Sched, "decode_step", 0, active_now as i64, 0);
        let step_res = backend.decode(&mut state);
        drop(step_span);
        match step_res {
            Ok(next) => {
                let now = Instant::now();
                let step_ms = (now - t0).as_secs_f64() * 1e3;
                trace::stage_ms(Stage::DecodeStep, step_ms);
                let mut n_active = 0usize;
                let mut disconnected: Vec<usize> = Vec::new();
                for (slot, entry) in slots.iter_mut().enumerate() {
                    if let Some(seq) = entry.as_mut() {
                        n_active += 1;
                        seq.tokens.push(next[slot]);
                        seq.decode_ms += step_ms;
                        if seq.first_token_at.is_none() {
                            seq.first_token_at = Some(now);
                        }
                        // Every active sequence gained one token this
                        // step, so its inter-token gap is the step wall
                        // time.
                        trace::stage_ms(Stage::InterToken, step_ms);
                        // Stream the token out the moment its step
                        // retires; a delivery error is a dropped
                        // receiver — the client is gone.
                        if seq.p.tx.send_token(next[slot]).is_err() {
                            disconnected.push(slot);
                        }
                    }
                }
                metrics.record_step(n_active);
                // Cancel disconnected sequences immediately: retire the
                // slot so its KV blocks return to the pool now, not
                // after decoding to `target` for nobody (DESIGN.md §15).
                for slot in disconnected {
                    // PANIC: only occupied slots are pushed above.
                    let seq = slots[slot].take().expect("disconnected slot is occupied");
                    let _ = backend.retire(&mut state, slot);
                    metrics.record_cancelled();
                    trace::instant(
                        Cat::Request,
                        "cancel",
                        seq.p.req.id,
                        seq.tokens.len() as i64,
                        seq.target as i64,
                    );
                }
            }
            Err(e) => {
                // Fail everything in flight and start from fresh state.
                let msg = format!("decode: {:#}", e);
                for (slot, entry) in slots.iter_mut().enumerate() {
                    if let Some(seq) = entry.take() {
                        fail(&seq.p, msg.clone(), metrics);
                        let _ = backend.retire(&mut state, slot);
                    }
                }
                if let Ok(fresh) = backend.new_state(cap) {
                    state = fresh;
                    kv_cache_recreated = true;
                }
                continue;
            }
        }

        // --- retirement: deliver the moment a sequence finishes -----------
        retire_finished(backend, &mut state, &mut slots, metrics);

        // Paged-cache pressure counters (prefix hits, block occupancy,
        // evictions) — one gauge update per step keeps the lock cheap.
        if let Some(ks) = backend.kv_cache_stats(&state) {
            metrics.record_kv(&ks, std::mem::take(&mut kv_cache_recreated));
        }
    }
}

/// Deliver and free every slot whose sequence reached its target.
fn retire_finished<B: Backend>(
    backend: &mut B,
    state: &mut DecodeState,
    slots: &mut [Option<SlotSeq>],
    metrics: &Metrics,
) {
    for slot in 0..slots.len() {
        let done = matches!(&slots[slot], Some(seq) if seq.tokens.len() >= seq.target);
        if !done {
            continue;
        }
        // PANIC: the `done` match two lines up proved the slot is Some.
        let seq = slots[slot].take().expect("checked above");
        let _ = backend.retire(state, slot);
        let timing = RequestTiming {
            queue_ms: (seq.admitted - seq.p.arrived).as_secs_f64() * 1e3,
            prefill_ms: seq.prefill_ms,
            ttft_ms: seq
                .first_token_at
                .map(|t| (t - seq.p.arrived).as_secs_f64() * 1e3)
                .unwrap_or(0.0),
            decode_ms: seq.decode_ms,
            tokens: seq.tokens.len(),
            error: None,
        };
        metrics.record_request(&timing);
        trace::instant(Cat::Request, "retire", seq.p.req.id, timing.tokens as i64, slot as i64);
        trace::stage_ms(Stage::Total, timing.total_ms());
        let id = seq.p.req.id;
        let tokens = timing.tokens as i64;
        if seq.p.tx.finish(id, seq.tokens, timing).is_err() {
            // The client vanished between its last token and delivery;
            // the sequence itself completed, so only count the loss.
            metrics.record_cancelled();
            trace::instant(Cat::Request, "cancel", id, tokens, tokens);
        }
    }
}

// ---------------------------------------------------------------------------
// Wave scheduler (bucket-compiled backends / benchmark baseline)
// ---------------------------------------------------------------------------

/// The wave scheduler: size-or-deadline batch formation, whole-bucket
/// prefill, run-to-completion decode. Responses are still delivered the
/// moment each lane reaches its target — only admission is coarse, so
/// streaming clients see their tokens at wave-step granularity.
fn wave_loop<B: Backend>(
    cfg: &ServeConfig,
    pad_id: i32,
    backend: &mut B,
    rx: &Receiver<WorkItem>,
    metrics: &Metrics,
) {
    let policy = BatchPolicy { max_batch: cfg.max_batch, max_wait: cfg.max_wait };
    let max_per_class = cfg.qos.max_queue_per_class;
    // Fairness at wave granularity: lanes one tenant may hold per wave.
    let per_tenant = cfg.qos.max_slots_per_tenant.max(1);
    let mut queue = QosQueue::new();
    let mut shutdown = false;
    while !shutdown {
        if queue.is_empty() {
            // Idle: block for the first request.
            match rx.recv() {
                Ok(WorkItem::Request(r, tx, t)) => {
                    queue_push(&mut queue, PendingRequest::new(r, tx, t), max_per_class, metrics)
                }
                Ok(WorkItem::Shutdown) | Err(_) => break,
            }
        }
        // Accumulate until the policy says flush. The wait deadline is
        // relative to *batch formation start*, not request arrival — a
        // backlog built up while the worker was busy must coalesce
        // immediately instead of tripping the deadline one-by-one.
        let batch_start = Instant::now();
        loop {
            // Drain whatever is already queued without waiting, so the
            // flush decision (and the QoS pick below) sees the whole
            // backlog rather than its first arrival.
            loop {
                match rx.try_recv() {
                    Ok(WorkItem::Request(r, tx, t)) => queue_push(
                        &mut queue,
                        PendingRequest::new(r, tx, t),
                        max_per_class,
                        metrics,
                    ),
                    Ok(WorkItem::Shutdown) | Err(TryRecvError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                    Err(TryRecvError::Empty) => break,
                }
            }
            if shutdown || policy.should_flush(queue.len(), batch_start.elapsed()) {
                break;
            }
            // Queue empty: block for the remaining wait budget.
            let budget = policy.max_wait.saturating_sub(batch_start.elapsed());
            match rx.recv_timeout(budget) {
                Ok(WorkItem::Request(r, tx, t)) => {
                    queue_push(&mut queue, PendingRequest::new(r, tx, t), max_per_class, metrics)
                }
                Ok(WorkItem::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(_) => break, // timeout — flush what we have
            }
        }
        if shutdown {
            break;
        }
        for p in queue.drain_expired(Instant::now()) {
            shed(&p, "shed: deadline passed before admission".to_string(), metrics);
        }
        // Form the wave with the QoS pick: priority order, round-robin
        // across tenants, at most `per_tenant` lanes per tenant per wave.
        let mut lanes: HashMap<u64, usize> = HashMap::new();
        let mut batch: Vec<PendingRequest> = Vec::new();
        while batch.len() < cfg.max_batch {
            let Some(i) = queue.select(&lanes, per_tenant) else { break };
            let p = queue.remove(i);
            *lanes.entry(p.req.tenant).or_insert(0) += 1;
            batch.push(p);
        }
        if batch.is_empty() {
            continue;
        }
        serve_wave(cfg, pad_id, backend, batch, metrics);
    }
    // Shutdown (or a dropped server handle): the in-formation queue and
    // the channel backlog get explicit failures, never silence.
    drain_backlog(rx, &mut queue, metrics);
}

/// Run one wave through prefill + decode, delivering each response as
/// its lane finishes.
fn serve_wave<B: Backend>(
    cfg: &ServeConfig,
    pad_id: i32,
    backend: &mut B,
    mut batch: Vec<PendingRequest>,
    metrics: &Metrics,
) {
    let n = batch.len();
    let bucket = batcher::pick_bucket(&cfg.buckets, n)
        // PANIC: buckets non-emptiness is asserted at server construction.
        .unwrap_or_else(|| *cfg.buckets.last().unwrap());

    // Normalize prompts to the prefill window (left-truncate / left-pad
    // so the generation-relevant suffix survives); cached on the
    // request, so a split-and-retried wave does not recompute them.
    let mut prompts = Vec::with_capacity(bucket);
    for p in batch.iter_mut() {
        prompts.push(p.normalized(cfg.prefill_len, pad_id).to_vec());
    }
    // Pad the bucket with copies of the first prompt (outputs discarded).
    while prompts.len() < bucket {
        prompts.push(prompts[0].clone());
    }

    let t_prefill = Instant::now();
    let wave_span = trace::span_args(Cat::Sched, "wave", 0, n as i64, bucket as i64);
    let prefill_span = trace::span_args(Cat::Sched, "prefill_wave", 0, n as i64, 0);
    let prefill_res = backend.prefill(&prompts);
    drop(prefill_span);
    let mut state = match prefill_res {
        Ok(s) => s,
        Err(e) => {
            drop(wave_span);
            // A multi-request wave whose prefill failed (e.g. an
            // overcommitted paged pool exhausted mid-batch) degrades
            // to two smaller waves instead of failing every request —
            // pool pressure then serializes waves the way the block
            // gate serializes continuous admission. Only a wave of one
            // reports the error.
            if batch.len() > 1 {
                let mut first = batch;
                let second = first.split_off(first.len() / 2);
                trace::instant(
                    Cat::Sched,
                    "wave_split",
                    0,
                    first.len() as i64,
                    second.len() as i64,
                );
                serve_wave(cfg, pad_id, backend, first, metrics);
                serve_wave(cfg, pad_id, backend, second, metrics);
                return;
            }
            let msg = format!("prefill: {:#}", e);
            for p in &batch {
                fail(p, msg.clone(), metrics);
            }
            return;
        }
    };
    // Counted only for a wave that actually serves (a split-and-retried
    // parent would otherwise double-count its requests).
    metrics.record_batch(n, bucket);
    trace::instant(Cat::Sched, "admit_round", 0, n as i64, bucket as i64);
    let prefill_ms = t_prefill.elapsed().as_secs_f64() * 1e3;
    trace::stage_ms(Stage::Prefill, prefill_ms);
    // Bucket-padding lanes carry no request: retire them immediately so
    // slot backends stop decoding them and paged caches get their
    // blocks back (PJRT's retire is a mask — its compiled graph keeps
    // computing the lane either way).
    for lane in n..bucket {
        let _ = backend.retire(&mut state, lane);
    }

    struct WaveSeq {
        p: Option<PendingRequest>,
        target: usize,
        tokens: Vec<i32>,
    }
    let mut seqs: Vec<WaveSeq> = batch
        .into_iter()
        .map(|p| WaveSeq {
            target: p.req.max_new_tokens.min(cfg.max_new_tokens),
            p: Some(p),
            tokens: Vec::new(),
        })
        .collect();
    if let Some(max_pos) = backend.max_positions() {
        // Clamp to the wave-uniform KV headroom after prefill: an
        // over-long request ends early instead of exhausting the cache
        // mid-decode and erroring the whole wave.
        let headroom = max_pos.saturating_sub(state.pos[0]);
        for seq in seqs.iter_mut() {
            seq.target = seq.target.min(headroom);
        }
    }
    // Paged backends: clamp each lane's target to the allocatable block
    // headroom, reserving the blocks (same contract as the continuous
    // path — an overcommitted pool shortens responses, never errors a
    // wave mid-decode). Two phases (reservations have total semantics):
    // every lane secures its first decode token before any lane
    // reserves deep, so a greedy wave member cannot starve a batchmate
    // to zero. A lane that still clamps to zero cannot decode at all:
    // fail it clearly and free its lane.
    for (lane, seq) in seqs.iter().enumerate() {
        let _ = backend.reserve_tokens(&mut state, lane, seq.target.min(1));
    }
    for (lane, seq) in seqs.iter_mut().enumerate() {
        let before_reserve = seq.target;
        seq.target = backend.reserve_tokens(&mut state, lane, seq.target);
        if seq.target < before_reserve {
            let id = seq.p.as_ref().map(|p| p.req.id).unwrap_or(0);
            trace::instant(
                Cat::Sched,
                "clamp_reservation",
                id,
                before_reserve as i64,
                seq.target as i64,
            );
        }
        if seq.target == 0 && before_reserve > 0 {
            let _ = backend.retire(&mut state, lane);
            if let Some(p) = seq.p.take() {
                fail(
                    &p,
                    "KV block pool too small to decode any tokens for this request".to_string(),
                    metrics,
                );
            }
        }
    }

    let mut decode_elapsed_ms = 0.0f64;
    let mut deliver = |seq: &mut WaveSeq,
                       first_token_at: Option<Instant>,
                       decode_elapsed_ms: f64| {
        // PANIC: each wave sequence is delivered exactly once (retire
        // or error), and delivery consumes the pending request.
        let p = seq.p.take().expect("delivered once");
        let timing = RequestTiming {
            queue_ms: (t_prefill - p.arrived).as_secs_f64() * 1e3,
            prefill_ms,
            ttft_ms: first_token_at
                .map(|t| (t - p.arrived).as_secs_f64() * 1e3)
                .unwrap_or(0.0),
            decode_ms: decode_elapsed_ms,
            tokens: seq.tokens.len(),
            error: None,
        };
        metrics.record_request(&timing);
        trace::instant(Cat::Request, "retire", p.req.id, timing.tokens as i64, 0);
        trace::stage_ms(Stage::Queue, timing.queue_ms);
        trace::stage_ms(Stage::Total, timing.total_ms());
        let tokens = timing.tokens as i64;
        if p.tx.finish(p.req.id, std::mem::take(&mut seq.tokens), timing).is_err() {
            // The client vanished between its last token and delivery;
            // the lane completed, so only count the loss.
            metrics.record_cancelled();
            trace::instant(Cat::Request, "cancel", p.req.id, tokens, tokens);
        }
    };

    // Requests asking for zero tokens are satisfied by prefill alone.
    for (lane, seq) in seqs.iter_mut().enumerate() {
        if seq.p.is_some() && seq.target == 0 {
            deliver(seq, None, 0.0);
            let _ = backend.retire(&mut state, lane);
        }
    }

    let max_steps = seqs.iter().filter(|s| s.p.is_some()).map(|s| s.target).max();
    let mut first_token_at = None;
    // Every wave owns a fresh state (and cache), so its first report
    // opens a new counter epoch — totals accumulate across waves.
    let mut kv_epoch_new = true;
    if let Some(ks) = backend.kv_cache_stats(&state) {
        // Sample right after prefill, while the lanes actually occupy
        // blocks — a single end-of-wave sample would only ever see the
        // registry remnants of retired lanes.
        metrics.record_kv(&ks, std::mem::take(&mut kv_epoch_new));
    }
    for _ in 0..max_steps.unwrap_or(0) {
        if seqs.iter().all(|s| s.p.is_none()) {
            break;
        }
        let t0 = Instant::now();
        let in_flight = seqs.iter().filter(|s| s.p.is_some()).count();
        let step_span = trace::span_args(Cat::Sched, "decode_step", 0, in_flight as i64, 0);
        let step_res = backend.decode(&mut state);
        drop(step_span);
        match step_res {
            Ok(next) => {
                let now = Instant::now();
                let step_ms = (now - t0).as_secs_f64() * 1e3;
                decode_elapsed_ms += step_ms;
                trace::stage_ms(Stage::DecodeStep, step_ms);
                for _ in 0..in_flight {
                    trace::stage_ms(Stage::InterToken, step_ms);
                }
                if first_token_at.is_none() {
                    first_token_at = Some(now);
                }
                // The compiled graph computes the whole bucket, finished
                // or not — record true occupancy, i.e. the bucket.
                metrics.record_step(bucket);
                let mut finished = Vec::new();
                for (i, seq) in seqs.iter_mut().enumerate() {
                    if seq.p.is_none() {
                        continue;
                    }
                    seq.tokens.push(next[i]);
                    // Stream the token at wave-step granularity; a
                    // delivery error is a dropped receiver, and the
                    // lane is cancelled so its blocks free now instead
                    // of after the wave's longest member (§15).
                    if seq.p.as_ref().is_some_and(|p| p.tx.send_token(next[i]).is_err()) {
                        // PANIC: the `is_some_and` one line up proved Some.
                        let p = seq.p.take().expect("lane still pending");
                        metrics.record_cancelled();
                        trace::instant(
                            Cat::Request,
                            "cancel",
                            p.req.id,
                            seq.tokens.len() as i64,
                            seq.target as i64,
                        );
                        finished.push(i);
                        continue;
                    }
                    if seq.tokens.len() >= seq.target {
                        // Early retirement: respond now, even though the
                        // wave keeps decoding for its longest member.
                        deliver(seq, first_token_at, decode_elapsed_ms);
                        finished.push(i);
                    }
                }
                // Free the finished lanes: slot backends stop decoding
                // them and paged caches reclaim their blocks, so a
                // delivered lane can never drag the pool into
                // exhaustion while its long batchmates keep going.
                for i in finished {
                    let _ = backend.retire(&mut state, i);
                }
                if let Some(ks) = backend.kv_cache_stats(&state) {
                    metrics.record_kv(&ks, std::mem::take(&mut kv_epoch_new));
                }
            }
            Err(e) => {
                let msg = format!("decode: {:#}", e);
                for seq in seqs.iter_mut() {
                    if let Some(p) = seq.p.take() {
                        fail(&p, msg.clone(), metrics);
                    }
                }
                return;
            }
        }
    }
    // Final sample catches counter updates from the last retirements.
    if let Some(ks) = backend.kv_cache_stats(&state) {
        metrics.record_kv(&ks, std::mem::take(&mut kv_epoch_new));
    }
}

#[cfg(test)]
mod tests {
    use super::backend::{MockBackend, SimBackend};
    use super::*;
    use std::collections::HashSet;

    fn cfg_with(scheduler: SchedulerKind, max_batch: usize, max_wait_ms: u64) -> ServeConfig {
        ServeConfig {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            max_new_tokens: 8,
            buckets: vec![1, 2, 4, 8],
            prefill_len: 16,
            ..ServeConfig::default()
        }
        .with_scheduler(scheduler)
    }

    impl ServeConfig {
        fn with_scheduler(mut self, s: SchedulerKind) -> ServeConfig {
            self.scheduler = s;
            self
        }
    }

    fn mock_server(max_batch: usize, max_wait_ms: u64) -> Server {
        Server::start(cfg_with(SchedulerKind::Continuous, max_batch, max_wait_ms), || {
            Ok(MockBackend::new())
        })
    }

    #[test]
    fn single_request_roundtrip() {
        let server = mock_server(4, 5);
        let (id, rx) = server.submit(vec![1, 2, 3], 4).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.id, id);
        assert_eq!(resp.tokens.len(), 4);
        assert!(resp.timing.error.is_none());
        assert!(resp.timing.ttft_ms <= resp.timing.total_ms() + 1e-9);
        server.shutdown();
    }

    #[test]
    fn no_request_lost_or_duplicated_under_load() {
        let server = mock_server(8, 2);
        let mut rxs = Vec::new();
        for i in 0..50 {
            let (id, rx) = server.submit(vec![i as i32; 10], 3).unwrap();
            rxs.push((id, rx));
        }
        let mut seen = HashSet::new();
        for (id, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.tokens.len(), 3);
            assert!(seen.insert(id), "duplicate response for {}", id);
        }
        assert_eq!(seen.len(), 50);
        // Metrics saw all 50.
        assert_eq!(server.metrics.snapshot().requests, 50);
        server.shutdown();
    }

    #[test]
    fn staggered_arrivals_are_neither_lost_nor_duplicated() {
        // Arrivals land mid-decode: each burst joins while earlier
        // requests are still generating.
        let server = Server::start(
            cfg_with(SchedulerKind::Continuous, 4, 1),
            || Ok(SimBackend::new(Duration::from_micros(50), Duration::from_micros(200))),
        );
        let mut rxs = Vec::new();
        for burst in 0..5 {
            for i in 0..4 {
                let want = if i % 2 == 0 { 2 } else { 8 };
                let (id, rx) =
                    server.submit(vec![burst * 4 + i; 6], want as usize).unwrap();
                rxs.push((id, rx, want as usize));
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut seen = HashSet::new();
        for (id, rx, want) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.tokens.len(), want);
            assert!(resp.timing.error.is_none());
            assert!(seen.insert(id), "duplicate response for {}", id);
        }
        assert_eq!(seen.len(), 20);
        assert_eq!(server.metrics.snapshot().requests, 20);
        server.shutdown();
    }

    #[test]
    fn wave_mode_coalesces_concurrent_submissions() {
        // With a generous wait, concurrent submissions coalesce into few
        // waves — the size-or-deadline policy the PJRT path relies on.
        let server = Server::start(
            cfg_with(SchedulerKind::RunToCompletion, 8, 50),
            || Ok(MockBackend::new()),
        );
        let mut rxs = Vec::new();
        for i in 0..8 {
            let (_, rx) = server.submit(vec![i], 2).unwrap();
            rxs.push(rx);
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let snap = server.metrics.snapshot();
        assert!(
            snap.batches < 8,
            "expected coalescing, got {} batches for 8 requests",
            snap.batches
        );
        server.shutdown();
    }

    #[test]
    fn mock_decode_is_deterministic_per_prompt() {
        // The mock derives tokens from the prompt — responses must match
        // between two identical submissions even when batched with others.
        let server = mock_server(8, 10);
        let (_, rx1) = server.submit(vec![42, 43], 5).unwrap();
        let (_, rx2) = server.submit(vec![99], 5).unwrap();
        let (_, rx3) = server.submit(vec![42, 43], 5).unwrap();
        let r1 = rx1.recv_timeout(Duration::from_secs(5)).unwrap();
        let _ = rx2.recv_timeout(Duration::from_secs(5)).unwrap();
        let r3 = rx3.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r1.tokens, r3.tokens);
        server.shutdown();
    }

    /// The tentpole equivalence check: both schedulers must produce the
    /// same tokens for the same requests — continuous batching changes
    /// scheduling, never results.
    #[test]
    fn schedulers_produce_identical_outputs() {
        let run = |scheduler: SchedulerKind| -> Vec<Vec<i32>> {
            let server = Server::start(cfg_with(scheduler, 4, 3), || Ok(MockBackend::new()));
            let mut rxs = Vec::new();
            for i in 0..12 {
                let want = [2usize, 5, 8][i % 3];
                let (_, rx) = server.submit(vec![i as i32 * 7 + 1; 5], want).unwrap();
                rxs.push((rx, want));
            }
            let outs: Vec<Vec<i32>> = rxs
                .into_iter()
                .map(|(rx, want)| {
                    let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
                    assert_eq!(resp.tokens.len(), want);
                    resp.tokens
                })
                .collect();
            server.shutdown();
            outs
        };
        assert_eq!(run(SchedulerKind::Continuous), run(SchedulerKind::RunToCompletion));
    }

    #[test]
    fn short_request_finishes_before_long_batchmate() {
        // cap = 2: the long and short run side by side; the short must
        // retire and respond while the long is still decoding.
        let mut cfg = cfg_with(SchedulerKind::Continuous, 2, 1);
        cfg.max_new_tokens = 32;
        cfg.buckets = vec![1, 2];
        let server = Server::start(cfg, || {
            Ok(SimBackend::new(Duration::from_micros(200), Duration::from_millis(2)))
        });
        let (_, rx_long) = server.submit(vec![1, 2, 3], 32).unwrap();
        let (_, rx_short) = server.submit(vec![4, 5, 6], 2).unwrap();
        let short = rx_short.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(short.tokens.len(), 2);
        // The long batchmate needs ≥ 30 more 2ms steps: it cannot have
        // finished yet.
        assert!(
            rx_long.try_recv().is_err(),
            "long request finished with the short one — run-to-completion behaviour"
        );
        let long = rx_long.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(long.tokens.len(), 32);
        server.shutdown();
    }

    #[test]
    fn early_retire_frees_slot_for_queued_request() {
        // cap = 2, three requests: long + short fill the slots, the
        // second short waits in the queue and must enter the slot the
        // first short freed — completing long before the long request.
        let mut cfg = cfg_with(SchedulerKind::Continuous, 2, 1);
        cfg.max_new_tokens = 32;
        cfg.buckets = vec![1, 2];
        let server = Server::start(cfg, || {
            Ok(SimBackend::new(Duration::from_micros(200), Duration::from_millis(2)))
        });
        let (_, rx_long) = server.submit(vec![1], 32).unwrap();
        let (_, rx_short1) = server.submit(vec![2], 2).unwrap();
        let (_, rx_short2) = server.submit(vec![3], 2).unwrap();
        let s1 = rx_short1.recv_timeout(Duration::from_secs(10)).unwrap();
        let s2 = rx_short2.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(s1.tokens.len(), 2);
        assert_eq!(s2.tokens.len(), 2);
        assert!(
            rx_long.try_recv().is_err(),
            "long request finished before the re-admitted short — no slot reuse happened"
        );
        assert_eq!(rx_long.recv_timeout(Duration::from_secs(10)).unwrap().tokens.len(), 32);
        server.shutdown();
    }

    #[test]
    fn single_slot_server_reuses_its_slot_serially() {
        let mut cfg = cfg_with(SchedulerKind::Continuous, 1, 1);
        cfg.buckets = vec![1];
        let server = Server::start(cfg, || Ok(MockBackend::new()));
        let mut rxs = Vec::new();
        for i in 0..3 {
            rxs.push(server.submit(vec![i], 2).unwrap().1);
        }
        for rx in rxs {
            assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().tokens.len(), 2);
        }
        assert_eq!(server.metrics.snapshot().requests, 3);
        server.shutdown();
    }

    #[test]
    fn max_batch_clamped_to_largest_bucket() {
        // Regression: max_batch beyond the largest bucket used to form
        // batches the bucket pick truncated, panicking on outputs[i].
        let server = mock_server(16, 30); // buckets top out at 8
        let mut rxs = Vec::new();
        for i in 0..16 {
            let (id, rx) = server.submit(vec![i as i32; 4], 2).unwrap();
            rxs.push((id, rx));
        }
        for (id, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.tokens.len(), 2);
            assert!(resp.timing.error.is_none());
        }
        server.shutdown();
    }

    #[test]
    fn respects_max_new_tokens_per_request() {
        let server = mock_server(8, 20);
        let (_, rx_short) = server.submit(vec![1], 2).unwrap();
        let (_, rx_long) = server.submit(vec![2], 7).unwrap();
        assert_eq!(rx_short.recv_timeout(Duration::from_secs(5)).unwrap().tokens.len(), 2);
        assert_eq!(rx_long.recv_timeout(Duration::from_secs(5)).unwrap().tokens.len(), 7);
        server.shutdown();
    }

    #[test]
    fn zero_token_request_completes_without_decoding() {
        for scheduler in [SchedulerKind::Continuous, SchedulerKind::RunToCompletion] {
            let server = Server::start(cfg_with(scheduler, 4, 2), || Ok(MockBackend::new()));
            let (_, rx) = server.submit(vec![1, 2], 0).unwrap();
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(resp.tokens.is_empty());
            assert!(resp.timing.error.is_none());
            server.shutdown();
        }
    }

    #[test]
    fn submit_surfaces_backend_construction_error() {
        // Regression: a dead worker used to panic every subsequent
        // submit ("server worker gone").
        let server = Server::start::<MockBackend, _>(cfg_with(SchedulerKind::Continuous, 4, 5), || {
            anyhow::bail!("PJRT artifacts missing")
        });
        let err = server.submit(vec![1, 2, 3], 4).unwrap_err();
        let msg = format!("{}", err);
        assert!(msg.contains("PJRT artifacts missing"), "got: {}", msg);
        server.shutdown();
    }

    #[test]
    fn pad_id_is_threaded_and_clamped() {
        // Two servers whose pad differs only before clamping must serve
        // identical streams; a genuinely different pad must not.
        let run = |pad_id: i32| -> Vec<i32> {
            let mut cfg = cfg_with(SchedulerKind::Continuous, 2, 2);
            cfg.pad_id = pad_id;
            let server = Server::start(cfg, || Ok(MockBackend::new()));
            let (_, rx) = server.submit(vec![1, 2, 3], 4).unwrap(); // shorter than prefill_len → padded
            let toks = rx.recv_timeout(Duration::from_secs(5)).unwrap().tokens;
            server.shutdown();
            toks
        };
        // MockBackend reports vocab 256: 9999 clamps to 255, -5 to 0.
        assert_eq!(run(9999), run(255));
        assert_eq!(run(-5), run(0));
        assert_ne!(run(255), run(0));
    }

    /// A mock whose KV "cache" holds only 5 positions: the scheduler
    /// must clamp over-long requests to the headroom instead of letting
    /// a mid-decode exhaustion error take down the batch.
    struct BoundedMock(MockBackend);

    impl Backend for BoundedMock {
        fn new_state(&mut self, cap: usize) -> Result<backend::DecodeState> {
            self.0.new_state(cap)
        }
        fn prefill_into(
            &mut self,
            state: &mut backend::DecodeState,
            slot: usize,
            prompt: &[i32],
        ) -> Result<()> {
            self.0.prefill_into(state, slot, prompt)
        }
        fn decode(&mut self, state: &mut backend::DecodeState) -> Result<Vec<i32>> {
            self.0.decode(state)
        }
        fn vocab(&self) -> Option<usize> {
            self.0.vocab()
        }
        fn max_positions(&self) -> Option<usize> {
            Some(5)
        }
    }

    /// A mock with a simulated paged block pool: headroom shrinks as
    /// slots admit (⌈prefill_len/bt⌉ blocks each) and reservations are
    /// first-come-first-served, exactly like the native paged cache.
    /// Block accounting is shared (`Arc`) so a test can watch the pool
    /// from outside the worker thread; `step` slows decode to make
    /// mid-stream lifecycle events observable.
    struct PagedMock {
        inner: MockBackend,
        block_tokens: usize,
        total_blocks: usize,
        step: Duration,
        used: Arc<Mutex<Vec<usize>>>,
        reserved: Arc<Mutex<Vec<usize>>>,
    }

    impl PagedMock {
        fn new(block_tokens: usize, total_blocks: usize) -> PagedMock {
            PagedMock::new_slow(block_tokens, total_blocks, Duration::ZERO)
        }
        fn new_slow(block_tokens: usize, total_blocks: usize, step: Duration) -> PagedMock {
            PagedMock {
                inner: MockBackend::new(),
                block_tokens,
                total_blocks,
                step,
                used: Arc::new(Mutex::new(Vec::new())),
                reserved: Arc::new(Mutex::new(Vec::new())),
            }
        }
        fn free_blocks(&self) -> usize {
            self.total_blocks
                - self.used.lock().unwrap().iter().sum::<usize>()
                - self.reserved.lock().unwrap().iter().sum::<usize>()
        }
    }

    impl Backend for PagedMock {
        fn new_state(&mut self, cap: usize) -> Result<backend::DecodeState> {
            *self.used.lock().unwrap() = vec![0; cap];
            *self.reserved.lock().unwrap() = vec![0; cap];
            self.inner.new_state(cap)
        }
        fn prefill_into(
            &mut self,
            state: &mut backend::DecodeState,
            slot: usize,
            prompt: &[i32],
        ) -> Result<()> {
            let need = prompt.len().div_ceil(self.block_tokens).max(1);
            anyhow::ensure!(need <= self.free_blocks(), "block pool exhausted");
            self.inner.prefill_into(state, slot, prompt)?;
            self.used.lock().unwrap()[slot] = need;
            Ok(())
        }
        fn decode(&mut self, state: &mut backend::DecodeState) -> Result<Vec<i32>> {
            if self.step > Duration::ZERO {
                std::thread::sleep(self.step);
            }
            self.inner.decode(state)
        }
        fn retire(&mut self, state: &mut backend::DecodeState, slot: usize) -> Result<()> {
            self.used.lock().unwrap()[slot] = 0;
            self.reserved.lock().unwrap()[slot] = 0;
            state.active[slot] = false;
            state.pos[slot] = 0;
            Ok(())
        }
        fn vocab(&self) -> Option<usize> {
            self.inner.vocab()
        }
        fn kv_block_headroom(&self, _state: &backend::DecodeState) -> Option<(usize, usize)> {
            Some((self.free_blocks(), self.block_tokens))
        }
        fn reserve_tokens(
            &mut self,
            _state: &mut backend::DecodeState,
            slot: usize,
            want: usize,
        ) -> usize {
            // Total semantics, like KvCache::reserve — a repeat call
            // extends the slot's reservation instead of stacking. The
            // free count is read before the lock: `free_blocks` takes
            // both pool locks itself, and std mutexes don't re-enter.
            let needed = want.div_ceil(self.block_tokens);
            let free = self.free_blocks();
            let mut reserved = self.reserved.lock().unwrap();
            let extra = needed.saturating_sub(reserved[slot]).min(free);
            reserved[slot] += extra;
            (reserved[slot] * self.block_tokens).min(want)
        }
    }

    /// A pool with room for exactly one request at a time must serialize
    /// admission even though KV slots are free — admission is by blocks.
    #[test]
    fn paged_backend_admits_by_blocks_not_slots() {
        let mut cfg = cfg_with(SchedulerKind::Continuous, 4, 2);
        cfg.prefill_len = 16;
        cfg.max_new_tokens = 4;
        // 16-token prefill = 4 blocks; pool of 5 fits one request
        // (4 prefill + 1 reserved decode block), never two.
        let server = Server::start(cfg, || Ok(PagedMock::new(4, 5)));
        let mut rxs = Vec::new();
        for i in 0..4 {
            rxs.push(server.submit(vec![i; 4], 4).unwrap().1);
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
            assert_eq!(resp.tokens.len(), 4);
        }
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 4);
        // Block-gated admission keeps at most one sequence in flight.
        assert!(
            snap.avg_active_slots <= 1.0 + 1e-9,
            "admission was not serialized by block headroom: {:.2} active slots",
            snap.avg_active_slots
        );
        server.shutdown();
    }

    /// The reservation clamp bounds an over-long request to allocatable
    /// blocks (short response, no error), like max_positions does for
    /// slot-provisioned caches.
    #[test]
    fn over_long_request_is_clamped_by_block_reservation() {
        let mut cfg = cfg_with(SchedulerKind::Continuous, 1, 2);
        cfg.prefill_len = 4;
        cfg.max_new_tokens = 100;
        cfg.buckets = vec![1];
        // 4-token prefill = 1 block; 3 blocks left ⇒ 12 decode tokens.
        let server = Server::start(cfg, || Ok(PagedMock::new(4, 4)));
        let (_, rx) = server.submit(vec![1, 2, 3], 50).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        assert_eq!(resp.tokens.len(), 12, "target must clamp to reserved blocks");
        server.shutdown();
    }

    #[test]
    fn over_long_request_is_clamped_to_kv_headroom_not_fatal() {
        for scheduler in [SchedulerKind::Continuous, SchedulerKind::RunToCompletion] {
            let mut cfg = cfg_with(scheduler, 2, 2);
            cfg.max_new_tokens = 100;
            let server = Server::start(cfg, || Ok(BoundedMock(MockBackend::new())));
            let (_, rx_long) = server.submit(vec![1, 2], 50).unwrap();
            let (_, rx_short) = server.submit(vec![3, 4], 3).unwrap();
            let long = rx_long.recv_timeout(Duration::from_secs(5)).unwrap();
            let short = rx_short.recv_timeout(Duration::from_secs(5)).unwrap();
            // Mock slots start at position 0, so headroom is 5 tokens.
            assert_eq!(long.tokens.len(), 5, "{:?}", scheduler);
            assert!(long.timing.error.is_none());
            // The batchmate is untouched by the clamp.
            assert_eq!(short.tokens.len(), 3);
            assert!(short.timing.error.is_none());
            server.shutdown();
        }
    }

    #[test]
    fn continuous_metrics_track_occupancy_and_ttft() {
        let server = Server::start(
            cfg_with(SchedulerKind::Continuous, 4, 1),
            || Ok(SimBackend::new(Duration::from_micros(50), Duration::from_micros(100))),
        );
        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(server.submit(vec![i; 4], 6).unwrap().1);
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 8);
        assert_eq!(snap.tokens, 48);
        assert!(snap.decode_steps >= 12, "8 seqs × 6 tokens over ≤4 slots");
        assert!(snap.avg_active_slots >= 1.0);
        assert!(snap.avg_active_slots <= 4.0 + 1e-9);
        assert!(snap.avg_ttft_ms > 0.0);
        server.shutdown();
    }

    fn sim_server(cfg: ServeConfig) -> Server {
        Server::start(cfg, || {
            Ok(SimBackend::new(Duration::from_micros(200), Duration::from_millis(2)))
        })
    }

    /// Regression (both loops): queued-but-unserved requests used to be
    /// dropped silently on shutdown, leaving clients blocked forever on
    /// a receiver nobody would ever write to. The in-flight sequence
    /// must still finish; the backlog must fail explicitly.
    #[test]
    fn slot_shutdown_fails_queued_backlog_instead_of_hanging() {
        let mut cfg = cfg_with(SchedulerKind::Continuous, 1, 1);
        cfg.max_new_tokens = 32;
        cfg.buckets = vec![1];
        let server = sim_server(cfg);
        let (_, rx_filler) = server.submit(vec![1], 32).unwrap();
        std::thread::sleep(Duration::from_millis(10)); // filler occupies the slot
        let rxs: Vec<_> = (0..3).map(|i| server.submit(vec![i + 10], 4).unwrap().1).collect();
        let metrics = server.metrics.clone();
        server.shutdown();
        let filler = rx_filler.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(filler.tokens.len(), 32);
        assert!(filler.timing.error.is_none());
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            let err = resp.timing.error.expect("queued request must fail on shutdown");
            assert!(err.contains("shutting down"), "got: {}", err);
        }
        assert_eq!(metrics.snapshot().errors, 3);
        assert_eq!(metrics.snapshot().requests, 1);
    }

    #[test]
    fn wave_shutdown_fails_in_formation_batch_and_backlog() {
        // max_batch 8 with a 1 s formation window: the three submissions
        // are still in formation when Shutdown lands, so all must fail.
        let server = Server::start(
            cfg_with(SchedulerKind::RunToCompletion, 8, 1_000),
            || Ok(MockBackend::new()),
        );
        let rxs: Vec<_> = (0..3).map(|i| server.submit(vec![i], 4).unwrap().1).collect();
        let metrics = server.metrics.clone();
        server.shutdown();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            let err = resp.timing.error.expect("queued request must fail on shutdown");
            assert!(err.contains("shutting down"), "got: {}", err);
        }
        assert_eq!(metrics.snapshot().errors, 3);
    }

    /// Regression: a client that dropped its receiver used to keep its
    /// slot decoding all the way to `target`. The delivery error must
    /// cancel the sequence and return its KV blocks immediately.
    #[test]
    fn dropped_stream_receiver_cancels_and_frees_blocks() {
        let mut cfg = cfg_with(SchedulerKind::Continuous, 1, 1);
        cfg.prefill_len = 4;
        cfg.max_new_tokens = 64;
        cfg.buckets = vec![1];
        let mock = PagedMock::new_slow(4, 32, Duration::from_millis(10));
        let used = mock.used.clone();
        let reserved = mock.reserved.clone();
        let server = Server::start(cfg, move || Ok(mock));
        let opts = SubmitOpts { max_new_tokens: 64, ..SubmitOpts::default() };
        let (_, rx) = server.submit_streaming(vec![1, 2, 3], opts).unwrap();
        for _ in 0..2 {
            match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
                TokenEvent::Token(_) => {}
                other => panic!("expected a token, got {:?}", other),
            }
        }
        drop(rx); // vanish mid-stream
        // At 10 ms per step the full 64-token target would take ~640 ms;
        // the cancel must free the pool long before that.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let freed = used.lock().unwrap().iter().sum::<usize>() == 0
                && reserved.lock().unwrap().iter().sum::<usize>() == 0;
            let snap = server.metrics.snapshot();
            if freed && snap.cancelled == 1 {
                assert_eq!(snap.requests, 0, "cancelled sequence must not count as served");
                break;
            }
            assert!(Instant::now() < deadline, "disconnect did not cancel the sequence");
            std::thread::sleep(Duration::from_millis(5));
        }
        server.shutdown();
    }

    #[test]
    fn high_priority_request_admitted_before_earlier_low_priority() {
        let mut cfg = cfg_with(SchedulerKind::Continuous, 1, 1);
        cfg.max_new_tokens = 32;
        cfg.buckets = vec![1];
        let server = sim_server(cfg);
        // Fill the only slot so both contenders must queue.
        let (_, rx_filler) = server.submit(vec![1], 32).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        let low = SubmitOpts { max_new_tokens: 2, ..SubmitOpts::default() };
        let high = SubmitOpts {
            max_new_tokens: 2,
            class: Class { priority: 3, deadline: None },
            ..SubmitOpts::default()
        };
        let (_, rx_low) = server.submit_with(vec![2], low).unwrap();
        let (_, rx_high) = server.submit_with(vec![3], high).unwrap();
        let h = rx_high.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(h.timing.error.is_none());
        // The low-priority contender arrived first but must still be
        // waiting: the freed slot went to the higher class.
        assert!(rx_low.try_recv().is_err(), "low priority served before high");
        assert!(rx_low.recv_timeout(Duration::from_secs(10)).unwrap().timing.error.is_none());
        let _ = rx_filler.recv_timeout(Duration::from_secs(10)).unwrap();
        server.shutdown();
    }

    #[test]
    fn wave_mode_orders_queue_by_priority() {
        let mut cfg = cfg_with(SchedulerKind::RunToCompletion, 1, 5);
        cfg.max_new_tokens = 32;
        cfg.buckets = vec![1];
        let server = sim_server(cfg);
        let (_, rx_filler) = server.submit(vec![1], 32).unwrap(); // first wave
        std::thread::sleep(Duration::from_millis(10));
        let low = SubmitOpts { max_new_tokens: 2, ..SubmitOpts::default() };
        let high = SubmitOpts {
            max_new_tokens: 2,
            class: Class { priority: 5, deadline: None },
            ..SubmitOpts::default()
        };
        let (_, rx_low) = server.submit_with(vec![2], low).unwrap();
        let (_, rx_high) = server.submit_with(vec![3], high).unwrap();
        let h = rx_high.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(h.timing.error.is_none());
        assert!(rx_low.try_recv().is_err(), "low-priority wave ran before high");
        assert!(rx_low.recv_timeout(Duration::from_secs(10)).unwrap().timing.error.is_none());
        let _ = rx_filler.recv_timeout(Duration::from_secs(10)).unwrap();
        server.shutdown();
    }

    #[test]
    fn expired_deadline_is_shed_not_served() {
        let server = mock_server(2, 1);
        let opts = SubmitOpts {
            max_new_tokens: 4,
            class: Class {
                priority: 0,
                deadline: Some(Instant::now() - Duration::from_millis(1)),
            },
            ..SubmitOpts::default()
        };
        let (_, rx) = server.submit_with(vec![1, 2], opts).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let err = resp.timing.error.expect("expired request must be shed");
        assert!(err.contains("deadline"), "got: {}", err);
        // A streaming client observes the shed as a Failed event.
        let (_, srx) = server.submit_streaming(vec![3], opts).unwrap();
        match srx.recv_timeout(Duration::from_secs(5)).unwrap() {
            TokenEvent::Failed(msg) => assert!(msg.contains("deadline"), "got: {}", msg),
            other => panic!("expected Failed, got {:?}", other),
        }
        let snap = server.metrics.snapshot();
        assert_eq!(snap.shed, 2);
        assert_eq!(snap.errors, 0, "shedding is not an execution error");
        server.shutdown();
    }

    #[test]
    fn class_queue_depth_bound_sheds_overflow() {
        let mut cfg = cfg_with(SchedulerKind::Continuous, 1, 1);
        cfg.max_new_tokens = 32;
        cfg.buckets = vec![1];
        cfg.qos.max_queue_per_class = 2;
        let server = sim_server(cfg);
        let (_, rx_filler) = server.submit(vec![1], 32).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        let rxs: Vec<_> = (0..4).map(|i| server.submit(vec![i + 10], 2).unwrap().1).collect();
        let (mut served, mut shed) = (0, 0);
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            match resp.timing.error {
                None => served += 1,
                Some(e) => {
                    assert!(e.contains("queue depth"), "got: {}", e);
                    shed += 1;
                }
            }
        }
        assert_eq!((served, shed), (2, 2));
        assert_eq!(server.metrics.snapshot().shed, 2);
        let _ = rx_filler.recv_timeout(Duration::from_secs(10)).unwrap();
        server.shutdown();
    }

    #[test]
    fn tenant_cap_prevents_slot_monopoly() {
        let mut cfg = cfg_with(SchedulerKind::Continuous, 2, 1);
        cfg.max_new_tokens = 16;
        cfg.buckets = vec![1, 2];
        cfg.qos.max_slots_per_tenant = 1;
        let server = sim_server(cfg);
        let t = |tenant: u64| SubmitOpts { max_new_tokens: 16, tenant, ..SubmitOpts::default() };
        // Tenant 1 floods first; tenant 2's single request arrives last
        // but must run beside (not behind) the flood.
        let (_, rx_a1) = server.submit_with(vec![1], t(1)).unwrap();
        let (_, rx_a2) = server.submit_with(vec![2], t(1)).unwrap();
        let (_, rx_a3) = server.submit_with(vec![3], t(1)).unwrap();
        let (_, rx_b1) = server.submit_with(vec![4], t(2)).unwrap();
        let b1 = rx_b1.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(b1.timing.error.is_none());
        // Serving tenant 1's third request requires its first two to have
        // retired serially through its single allowed slot — impossible
        // this early unless the cap was ignored.
        assert!(rx_a3.try_recv().is_err(), "tenant 1 monopolized the slots");
        for rx in [rx_a1, rx_a2, rx_a3] {
            assert!(rx.recv_timeout(Duration::from_secs(10)).unwrap().timing.error.is_none());
        }
        server.shutdown();
    }

    /// Streamed tokens concatenate to exactly the whole-mode response
    /// for the same prompt, under both schedulers (mock path; the native
    /// kv_bits variants live in tests/streaming.rs).
    #[test]
    fn streaming_tokens_match_whole_response() {
        for scheduler in [SchedulerKind::Continuous, SchedulerKind::RunToCompletion] {
            let server = Server::start(cfg_with(scheduler, 4, 3), || Ok(MockBackend::new()));
            let (_, rx_whole) = server.submit(vec![9, 8, 7], 6).unwrap();
            let whole = rx_whole.recv_timeout(Duration::from_secs(5)).unwrap();
            let opts = SubmitOpts { max_new_tokens: 6, ..SubmitOpts::default() };
            let (_, rx) = server.submit_streaming(vec![9, 8, 7], opts).unwrap();
            let mut streamed = Vec::new();
            let timing = loop {
                match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                    TokenEvent::Token(t) => streamed.push(t),
                    TokenEvent::Done(t) => break t,
                    TokenEvent::Failed(e) => panic!("stream failed: {}", e),
                }
            };
            assert_eq!(streamed, whole.tokens, "{:?}", scheduler);
            assert_eq!(timing.tokens, 6);
            server.shutdown();
        }
    }
}

//! Serving metrics: per-request timing + aggregate counters, lock-shared
//! between the worker and observers.
//!
//! Latency percentiles come from a **bounded reservoir** (Vitter's
//! algorithm R over [`crate::util::prng::Rng`]), so memory stays
//! constant under sustained traffic — the previous implementation kept
//! every latency in a `Vec<f64>` forever, which is an OOM under the
//! ROADMAP's heavy-traffic north star. Percentiles use nearest-rank
//! rounding with NaN-safe `total_cmp` ordering (the old `as usize`
//! truncation floored the rank, biasing p99 low on small samples).

use crate::kernels::KvCacheStats;
use crate::util::prng::Rng;
use std::sync::Mutex;

/// Latency samples kept for percentile estimation (~32 KiB of f64s).
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

/// Timing of one request's lifecycle.
#[derive(Clone, Debug, Default)]
pub struct RequestTiming {
    /// Arrival → admission into a KV slot (or wave prefill start).
    pub queue_ms: f64,
    /// Prompt pass for this request (per-slot on the continuous path,
    /// shared across the wave on the batch path).
    pub prefill_ms: f64,
    /// Arrival → first generated token available (time-to-first-token).
    pub ttft_ms: f64,
    /// Decode wall time attributed to this request: the sum of the
    /// decode steps it participated in, ending at its retirement — not
    /// the whole batch's run, as the run-to-completion scheduler used
    /// to report.
    pub decode_ms: f64,
    pub tokens: usize,
    pub error: Option<String>,
}

impl RequestTiming {
    pub fn failed(msg: String) -> RequestTiming {
        RequestTiming { error: Some(msg), ..Default::default() }
    }

    /// End-to-end latency.
    pub fn total_ms(&self) -> f64 {
        self.queue_ms + self.prefill_ms + self.decode_ms
    }
}

/// Fixed-size uniform sample of a stream (algorithm R).
struct Reservoir {
    samples: Vec<f64>,
    seen: u64,
    rng: Rng,
}

impl Reservoir {
    fn new() -> Reservoir {
        Reservoir {
            samples: Vec::new(),
            seen: 0,
            // Fixed seed: metrics are an estimate either way, and a
            // deterministic stream keeps test runs reproducible.
            rng: Rng::new(0x1A7E_9C1E),
        }
    }

    fn record(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR_CAP {
            self.samples.push(v);
        } else {
            let j = self.rng.below(self.seen) as usize;
            if j < LATENCY_RESERVOIR_CAP {
                self.samples[j] = v;
            }
        }
    }
}

/// Nearest-rank percentile of a sorted slice: the smallest value with at
/// least `p` of the sample at or below it. No interpolation, no
/// truncation bias — `percentile(&[1..=10], 0.99)` is 10, not 9.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (sorted.len() as f64 * p).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

struct Inner {
    requests: u64,
    errors: u64,
    cancelled: u64,
    shed: u64,
    batches: u64,
    batch_size_sum: u64,
    bucket_sum: u64,
    tokens: u64,
    queue_ms_sum: f64,
    prefill_ms_sum: f64,
    ttft_ms_sum: f64,
    decode_ms_sum: f64,
    /// Decode steps executed and the KV-slot occupancy at each — the
    /// continuous scheduler's utilization signal.
    decode_steps: u64,
    active_slot_sum: u64,
    latencies: Reservoir,
    /// Paged-KV pressure (DESIGN.md §10): fixed-size counters copied
    /// from the backend's cache each step — reservoir-safe like the
    /// latency fix, nothing here grows with traffic. A cache's counters
    /// are monotonic only for its lifetime and caches are recreated
    /// (per wave; after a decode error), so the totals are kept as
    /// `base` (sum of all finished cache epochs) + `last` (the live
    /// cache's current values); `record_kv` rolls `last` into `base`
    /// when a new epoch starts. `blocks_in_use` is a gauge with a
    /// tracked peak.
    kv_base: KvCacheStats,
    kv_last: KvCacheStats,
    blocks_in_use: usize,
    blocks_in_use_peak: usize,
    /// Peak of per-sample `in_use / total` ratios — pool sizes differ
    /// across epochs (wave buckets), so a cross-epoch absolute peak
    /// divided by the latest total would be meaningless (even > 1).
    block_utilization_peak: f64,
    kv_total_blocks: usize,
    /// Active kernel tier / activation-quant mode (DESIGN.md §14),
    /// reported once by the serving entry point; `""` until set.
    kernel_tier: &'static str,
    act_quant: &'static str,
}

impl Default for Inner {
    fn default() -> Inner {
        Inner {
            requests: 0,
            errors: 0,
            cancelled: 0,
            shed: 0,
            batches: 0,
            batch_size_sum: 0,
            bucket_sum: 0,
            tokens: 0,
            queue_ms_sum: 0.0,
            prefill_ms_sum: 0.0,
            ttft_ms_sum: 0.0,
            decode_ms_sum: 0.0,
            decode_steps: 0,
            active_slot_sum: 0,
            latencies: Reservoir::new(),
            kv_base: KvCacheStats::default(),
            kv_last: KvCacheStats::default(),
            blocks_in_use: 0,
            blocks_in_use_peak: 0,
            block_utilization_peak: 0.0,
            kv_total_blocks: 0,
            kernel_tier: "",
            act_quant: "",
        }
    }
}

/// Aggregate serving metrics.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    /// Successfully completed requests. Failed requests are counted in
    /// [`Snapshot::errors`] instead — their all-zero timings would
    /// deflate every latency aggregate below.
    pub requests: u64,
    /// Requests that failed (prefill/decode error, exhausted KV pool).
    pub errors: u64,
    /// Sequences cancelled because the client dropped its receiver
    /// mid-stream — their slots retired early and their KV blocks
    /// returned to the pool (DESIGN.md §15).
    pub cancelled: u64,
    /// Requests load-shed before admission: expired deadline or a full
    /// per-class queue (DESIGN.md §15). Counted separately from
    /// `errors` — shedding is the admission policy working, not the
    /// serving stack failing.
    pub shed: u64,
    /// Admission rounds (continuous) or waves (batch path).
    pub batches: u64,
    pub avg_batch_size: f64,
    pub avg_bucket: f64,
    pub tokens: u64,
    pub avg_queue_ms: f64,
    pub avg_prefill_ms: f64,
    pub avg_ttft_ms: f64,
    pub avg_decode_ms_per_token: f64,
    pub decode_steps: u64,
    /// Mean KV slots occupied per decode step.
    pub avg_active_slots: f64,
    /// Prompt blocks served from the shared-prefix registry instead of
    /// being recomputed (cumulative; 0 for non-paged backends).
    pub prefix_hits: u64,
    /// Prompt tokens whose prefill compute the registry skipped.
    pub prefix_hit_tokens: u64,
    /// KV blocks currently allocated / the high-water mark.
    pub blocks_in_use: usize,
    pub blocks_in_use_peak: usize,
    /// Registered blocks recycled under pool pressure (cumulative).
    pub blocks_evicted: u64,
    /// Copy-on-write forks of shared blocks (cumulative).
    pub cow_forks: u64,
    /// Physical blocks in the paged pool (0 for non-paged backends;
    /// the latest epoch's pool — wave buckets size pools differently).
    pub kv_total_blocks: usize,
    /// Peak per-sample fraction of the block pool in use (0 when
    /// non-paged); each sample is measured against its own epoch's
    /// pool size, so this never exceeds 1.
    pub block_utilization: f64,
    /// KV quantization width of the latest cache epoch (`None` when
    /// quantization is off or the backend is non-paged).
    pub kv_bits: Option<u32>,
    /// Blocks currently held in the quantized `Icq` state (gauge,
    /// latest epoch — DESIGN.md §12).
    pub quantized_blocks: usize,
    /// Filled blocks quantized in place (cumulative across epochs).
    pub blocks_quantized: u64,
    /// Quantized-block attention reads served from an already-staged
    /// dequant scratch entry (cumulative across epochs).
    pub dequant_scratch_hits: u64,
    /// Logical resident KV bytes of the latest epoch: quantized payload
    /// plus full f32 cost of unquantized blocks (gauge).
    pub kv_resident_bytes: usize,
    /// Resolved SIMD kernel tier (`"scalar"`/`"avx2"`/`"neon"`;
    /// DESIGN.md §14) and activation-quant mode (`"f32"`/`"int8"`)
    /// serving the fused kernels; `""` until the entry point reports.
    pub kernel_tier: &'static str,
    pub act_quant: &'static str,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Latencies observed / currently held in the reservoir.
    pub latencies_seen: u64,
    pub latency_samples: usize,
}

impl Metrics {
    /// One admission event: `size` requests entered, `bucket` = compiled
    /// bucket (waves) or total occupancy after admission (continuous).
    pub fn record_batch(&self, size: usize, bucket: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batch_size_sum += size as u64;
        m.bucket_sum += bucket as u64;
    }

    /// One decode step over `active` occupied slots.
    pub fn record_step(&self, active: usize) {
        let mut m = self.inner.lock().unwrap();
        m.decode_steps += 1;
        m.active_slot_sum += active as u64;
    }

    /// Latest paged-cache counters from the backend (DESIGN.md §10).
    /// `new_epoch` marks the first report from a **recreated** cache
    /// (a fresh wave state, or the replacement state after a decode
    /// error): the previous cache's final counters roll into the
    /// cumulative base so totals never reset or move backwards.
    /// `blocks_in_use` updates a gauge + peak. Constant-size state —
    /// safe under sustained traffic, like the latency reservoir.
    pub fn record_kv(&self, s: &KvCacheStats, new_epoch: bool) {
        let mut m = self.inner.lock().unwrap();
        if new_epoch {
            m.kv_base.prefix_hit_blocks += m.kv_last.prefix_hit_blocks;
            m.kv_base.prefix_hit_tokens += m.kv_last.prefix_hit_tokens;
            m.kv_base.blocks_evicted += m.kv_last.blocks_evicted;
            m.kv_base.cow_forks += m.kv_last.cow_forks;
            m.kv_base.blocks_quantized += m.kv_last.blocks_quantized;
            m.kv_base.dequant_scratch_hits += m.kv_last.dequant_scratch_hits;
        }
        m.kv_last = *s;
        m.blocks_in_use = s.blocks_in_use;
        m.blocks_in_use_peak = m.blocks_in_use_peak.max(s.blocks_in_use);
        m.block_utilization_peak = m
            .block_utilization_peak
            .max(s.blocks_in_use as f64 / s.total_blocks.max(1) as f64);
        m.kv_total_blocks = s.total_blocks;
    }

    /// Record a completed request. A timing carrying an error is routed
    /// to the error counter instead: `RequestTiming::failed` is all
    /// zeros, and feeding it to the reservoir/averages would deflate
    /// p50/p99 and every latency mean exactly when things go wrong.
    pub fn record_request(&self, t: &RequestTiming) {
        let mut m = self.inner.lock().unwrap();
        if t.error.is_some() {
            m.errors += 1;
            return;
        }
        m.requests += 1;
        m.tokens += t.tokens as u64;
        m.queue_ms_sum += t.queue_ms;
        m.prefill_ms_sum += t.prefill_ms;
        m.ttft_ms_sum += t.ttft_ms;
        m.decode_ms_sum += t.decode_ms;
        m.latencies.record(t.total_ms());
    }

    /// Count one failed request (no timing to aggregate).
    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Count one client-disconnect cancellation (DESIGN.md §15). The
    /// sequence's partial timings are discarded — nobody received the
    /// response, so feeding them to the latency aggregates would skew
    /// p50/p99 with lifecycles no client observed end-to-end.
    pub fn record_cancelled(&self) {
        self.inner.lock().unwrap().cancelled += 1;
    }

    /// Count one load-shed request (deadline or queue-depth bound).
    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// Report the kernel tier and activation-quant mode the serving
    /// backend resolved (DESIGN.md §14). Called once at startup; the
    /// names come from [`Tier::name`](crate::kernels::Tier::name) and
    /// [`ActQuant::name`](crate::kernels::ActQuant::name).
    pub fn set_kernel_dispatch(&self, tier: &'static str, act_quant: &'static str) {
        let mut m = self.inner.lock().unwrap();
        m.kernel_tier = tier;
        m.act_quant = act_quant;
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        let mut lat = m.latencies.samples.clone();
        lat.sort_by(|a, b| a.total_cmp(b));
        Snapshot {
            requests: m.requests,
            errors: m.errors,
            cancelled: m.cancelled,
            shed: m.shed,
            batches: m.batches,
            avg_batch_size: m.batch_size_sum as f64 / m.batches.max(1) as f64,
            avg_bucket: m.bucket_sum as f64 / m.batches.max(1) as f64,
            tokens: m.tokens,
            avg_queue_ms: m.queue_ms_sum / m.requests.max(1) as f64,
            avg_prefill_ms: m.prefill_ms_sum / m.requests.max(1) as f64,
            avg_ttft_ms: m.ttft_ms_sum / m.requests.max(1) as f64,
            avg_decode_ms_per_token: m.decode_ms_sum / m.tokens.max(1) as f64,
            decode_steps: m.decode_steps,
            avg_active_slots: m.active_slot_sum as f64 / m.decode_steps.max(1) as f64,
            prefix_hits: m.kv_base.prefix_hit_blocks + m.kv_last.prefix_hit_blocks,
            prefix_hit_tokens: m.kv_base.prefix_hit_tokens + m.kv_last.prefix_hit_tokens,
            blocks_in_use: m.blocks_in_use,
            blocks_in_use_peak: m.blocks_in_use_peak,
            blocks_evicted: m.kv_base.blocks_evicted + m.kv_last.blocks_evicted,
            cow_forks: m.kv_base.cow_forks + m.kv_last.cow_forks,
            kv_total_blocks: m.kv_total_blocks,
            block_utilization: m.block_utilization_peak,
            kv_bits: m.kv_last.kv_bits,
            quantized_blocks: m.kv_last.quantized_blocks,
            blocks_quantized: m.kv_base.blocks_quantized + m.kv_last.blocks_quantized,
            dequant_scratch_hits: m.kv_base.dequant_scratch_hits
                + m.kv_last.dequant_scratch_hits,
            kv_resident_bytes: m.kv_last.kv_resident_bytes,
            kernel_tier: m.kernel_tier,
            act_quant: m.act_quant,
            p50_latency_ms: percentile(&lat, 0.5),
            p99_latency_ms: percentile(&lat, 0.99),
            latencies_seen: m.latencies.seen,
            latency_samples: lat.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_correctly() {
        let m = Metrics::default();
        m.record_batch(3, 4);
        m.record_batch(1, 1);
        m.record_step(4);
        m.record_step(2);
        for _ in 0..4 {
            m.record_request(&RequestTiming {
                queue_ms: 1.0,
                prefill_ms: 2.0,
                ttft_ms: 4.0,
                decode_ms: 10.0,
                tokens: 5,
                error: None,
            });
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert!((s.avg_batch_size - 2.0).abs() < 1e-9);
        assert_eq!(s.tokens, 20);
        // 4 × 10 ms decode over 20 tokens = 2 ms/token.
        assert!((s.avg_decode_ms_per_token - 2.0).abs() < 1e-9);
        assert!((s.avg_ttft_ms - 4.0).abs() < 1e-9);
        assert_eq!(s.decode_steps, 2);
        assert!((s.avg_active_slots - 3.0).abs() < 1e-9);
        assert!((s.p50_latency_ms - 13.0).abs() < 1e-9);
    }

    #[test]
    fn kv_counters_track_latest_and_peak() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().kv_total_blocks, 0);
        assert_eq!(m.snapshot().block_utilization, 0.0);
        m.record_kv(
            &KvCacheStats {
                block_tokens: 4,
                total_blocks: 32,
                blocks_in_use: 10,
                registered_blocks: 2,
                prefix_hit_blocks: 3,
                prefix_hit_tokens: 12,
                blocks_evicted: 1,
                cow_forks: 1,
                kv_bits: Some(4),
                quantized_blocks: 3,
                blocks_quantized: 4,
                dequant_scratch_hits: 7,
                kv_resident_bytes: 900,
                ..Default::default()
            },
            false,
        );
        m.record_kv(
            &KvCacheStats {
                block_tokens: 4,
                total_blocks: 32,
                blocks_in_use: 6, // gauge drops, peak stays
                registered_blocks: 2,
                prefix_hit_blocks: 5,
                prefix_hit_tokens: 20,
                blocks_evicted: 2,
                cow_forks: 1,
                kv_bits: Some(4),
                quantized_blocks: 2, // gauge drops too
                blocks_quantized: 6,
                dequant_scratch_hits: 11,
                kv_resident_bytes: 700,
                ..Default::default()
            },
            false,
        );
        let s = m.snapshot();
        assert_eq!(s.prefix_hits, 5);
        assert_eq!(s.prefix_hit_tokens, 20);
        assert_eq!(s.blocks_in_use, 6);
        assert_eq!(s.blocks_in_use_peak, 10);
        assert_eq!(s.blocks_evicted, 2);
        assert_eq!(s.cow_forks, 1);
        assert_eq!(s.kv_total_blocks, 32);
        assert!((s.block_utilization - 10.0 / 32.0).abs() < 1e-12);
        // Quantized-KV accounting (DESIGN.md §12): gauges track the
        // latest sample, cumulative counters the latest epoch values.
        assert_eq!(s.kv_bits, Some(4));
        assert_eq!(s.quantized_blocks, 2);
        assert_eq!(s.blocks_quantized, 6);
        assert_eq!(s.dequant_scratch_hits, 11);
        assert_eq!(s.kv_resident_bytes, 700);
    }

    #[test]
    fn kv_counters_accumulate_across_cache_epochs() {
        // Regression: caches are recreated per wave / after decode
        // errors, and their counters restart at zero — the snapshot
        // totals must keep accumulating instead of resetting.
        let m = Metrics::default();
        let epoch = |hits: u64, evicted: u64, in_use: usize| KvCacheStats {
            block_tokens: 4,
            total_blocks: 16,
            blocks_in_use: in_use,
            registered_blocks: 0,
            prefix_hit_blocks: hits,
            prefix_hit_tokens: hits * 4,
            blocks_evicted: evicted,
            cow_forks: 0,
            blocks_quantized: hits, // quantized counters roll too
            dequant_scratch_hits: evicted * 3,
            ..Default::default()
        };
        m.record_kv(&epoch(2, 1, 8), true); // wave 1 final counters
        m.record_kv(&epoch(3, 0, 5), true); // wave 2 (fresh cache)
        m.record_kv(&epoch(4, 2, 6), true); // wave 3 (fresh cache)
        let s = m.snapshot();
        assert_eq!(s.prefix_hits, 2 + 3 + 4);
        assert_eq!(s.prefix_hit_tokens, (2 + 3 + 4) * 4);
        assert_eq!(s.blocks_evicted, 1 + 0 + 2);
        assert_eq!(s.blocks_quantized, 2 + 3 + 4);
        assert_eq!(s.dequant_scratch_hits, (1 + 0 + 2) * 3);
        assert_eq!(s.blocks_in_use, 6);
        assert_eq!(s.blocks_in_use_peak, 8);
        // Utilization is a per-sample ratio peak, bounded by 1 even
        // when pool sizes differ across epochs.
        assert!((s.block_utilization - 0.5).abs() < 1e-12);
    }

    #[test]
    fn failed_requests_do_not_pollute_latency_aggregates() {
        // Regression: `RequestTiming::failed` (all-zero timings) used to
        // flow into the reservoir and averages, deflating p50/p99 and
        // ttft exactly when the system was failing.
        let m = Metrics::default();
        for _ in 0..3 {
            m.record_request(&RequestTiming {
                queue_ms: 1.0,
                prefill_ms: 2.0,
                ttft_ms: 5.0,
                decode_ms: 7.0,
                tokens: 4,
                error: None,
            });
        }
        for _ in 0..5 {
            m.record_request(&RequestTiming::failed("decode: boom".into()));
        }
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.errors, 6);
        assert_eq!(s.latency_samples, 3);
        // Aggregates reflect only the successful requests.
        assert!((s.avg_ttft_ms - 5.0).abs() < 1e-9);
        assert!((s.p50_latency_ms - 10.0).abs() < 1e-9);
        assert!((s.p99_latency_ms - 10.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99_latency_ms, 0.0);
        assert_eq!(s.latency_samples, 0);
        assert_eq!(s.cancelled, 0);
        assert_eq!(s.shed, 0);
    }

    #[test]
    fn cancelled_and_shed_count_separately_from_errors() {
        let m = Metrics::default();
        m.record_cancelled();
        m.record_cancelled();
        m.record_shed();
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.cancelled, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.errors, 1);
        // Neither lifecycle feeds the success aggregates.
        assert_eq!(s.requests, 0);
        assert_eq!(s.latency_samples, 0);
    }

    #[test]
    fn reservoir_is_bounded_under_sustained_traffic() {
        // Regression: latencies used to accumulate without bound.
        let m = Metrics::default();
        for i in 0..(LATENCY_RESERVOIR_CAP as u64 * 4) {
            m.record_request(&RequestTiming {
                decode_ms: i as f64,
                tokens: 1,
                ..Default::default()
            });
        }
        let s = m.snapshot();
        assert_eq!(s.latencies_seen, LATENCY_RESERVOIR_CAP as u64 * 4);
        assert_eq!(s.latency_samples, LATENCY_RESERVOIR_CAP);
        // The sample still spans the stream, so percentiles are sane.
        assert!(s.p50_latency_ms > 0.0);
        assert!(s.p99_latency_ms > s.p50_latency_ms);
    }

    #[test]
    fn nearest_rank_does_not_floor_small_samples() {
        // Regression: `(n-1) * p as usize` truncated — on 10 samples the
        // old p99 was the 9th value, not the max.
        let m = Metrics::default();
        for i in 1..=10 {
            m.record_request(&RequestTiming {
                decode_ms: i as f64,
                tokens: 1,
                ..Default::default()
            });
        }
        let s = m.snapshot();
        assert_eq!(s.p99_latency_ms, 10.0);
        assert_eq!(s.p50_latency_ms, 5.0);
    }

    #[test]
    fn nan_latency_does_not_poison_percentiles() {
        // Regression: `partial_cmp(..).unwrap()` panicked on NaN.
        let m = Metrics::default();
        m.record_request(&RequestTiming {
            decode_ms: f64::NAN,
            tokens: 1,
            ..Default::default()
        });
        for i in 0..9 {
            m.record_request(&RequestTiming {
                decode_ms: i as f64,
                tokens: 1,
                ..Default::default()
            });
        }
        let s = m.snapshot(); // must not panic
        assert_eq!(s.latency_samples, 10);
        assert!(s.p50_latency_ms.is_finite());
    }

    #[test]
    fn percentile_edges() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.01), 7.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.5), 50.0);
    }
}

//! Serving metrics: per-request timing + aggregate counters, lock-shared
//! between the worker and observers.

use std::sync::Mutex;

/// Timing of one request's lifecycle.
#[derive(Clone, Debug, Default)]
pub struct RequestTiming {
    pub queue_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub tokens: usize,
    pub error: Option<String>,
}

impl RequestTiming {
    pub fn failed(msg: String) -> RequestTiming {
        RequestTiming { error: Some(msg), ..Default::default() }
    }

    /// End-to-end latency.
    pub fn total_ms(&self) -> f64 {
        self.queue_ms + self.prefill_ms + self.decode_ms
    }
}

#[derive(Default)]
struct Inner {
    requests: u64,
    batches: u64,
    batch_size_sum: u64,
    bucket_sum: u64,
    tokens: u64,
    queue_ms_sum: f64,
    prefill_ms_sum: f64,
    decode_ms_sum: f64,
    latencies_ms: Vec<f64>,
}

/// Aggregate serving metrics.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub avg_batch_size: f64,
    pub avg_bucket: f64,
    pub tokens: u64,
    pub avg_queue_ms: f64,
    pub avg_prefill_ms: f64,
    pub avg_decode_ms_per_token: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
}

impl Metrics {
    pub fn record_batch(&self, size: usize, bucket: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batch_size_sum += size as u64;
        m.bucket_sum += bucket as u64;
    }

    pub fn record_request(&self, t: &RequestTiming) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.tokens += t.tokens as u64;
        m.queue_ms_sum += t.queue_ms;
        m.prefill_ms_sum += t.prefill_ms;
        m.decode_ms_sum += t.decode_ms;
        m.latencies_ms.push(t.total_ms());
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        let mut lat = m.latencies_ms.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                0.0
            } else {
                lat[((lat.len() as f64 - 1.0) * p) as usize]
            }
        };
        Snapshot {
            requests: m.requests,
            batches: m.batches,
            avg_batch_size: m.batch_size_sum as f64 / m.batches.max(1) as f64,
            avg_bucket: m.bucket_sum as f64 / m.batches.max(1) as f64,
            tokens: m.tokens,
            avg_queue_ms: m.queue_ms_sum / m.requests.max(1) as f64,
            avg_prefill_ms: m.prefill_ms_sum / m.requests.max(1) as f64,
            avg_decode_ms_per_token: m.decode_ms_sum / m.tokens.max(1) as f64,
            p50_latency_ms: pct(0.5),
            p99_latency_ms: pct(0.99),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_correctly() {
        let m = Metrics::default();
        m.record_batch(3, 4);
        m.record_batch(1, 1);
        for i in 0..4 {
            m.record_request(&RequestTiming {
                queue_ms: 1.0,
                prefill_ms: 2.0,
                decode_ms: 10.0,
                tokens: 5,
                error: None,
            });
            let _ = i;
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert!((s.avg_batch_size - 2.0).abs() < 1e-9);
        assert_eq!(s.tokens, 20);
        // 4 × 10 ms decode over 20 tokens = 2 ms/token.
        assert!((s.avg_decode_ms_per_token - 2.0).abs() < 1e-9);
        assert!((s.p50_latency_ms - 13.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99_latency_ms, 0.0);
    }
}

//! Serving metrics: per-request timing + aggregate counters, lock-shared
//! between the worker and observers.
//!
//! Latency percentiles come from a **bounded reservoir** (Vitter's
//! algorithm R over [`crate::util::prng::Rng`]), so memory stays
//! constant under sustained traffic — the previous implementation kept
//! every latency in a `Vec<f64>` forever, which is an OOM under the
//! ROADMAP's heavy-traffic north star. Percentiles use nearest-rank
//! rounding with NaN-safe `total_cmp` ordering (the old `as usize`
//! truncation floored the rank, biasing p99 low on small samples).

use crate::util::prng::Rng;
use std::sync::Mutex;

/// Latency samples kept for percentile estimation (~32 KiB of f64s).
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

/// Timing of one request's lifecycle.
#[derive(Clone, Debug, Default)]
pub struct RequestTiming {
    /// Arrival → admission into a KV slot (or wave prefill start).
    pub queue_ms: f64,
    /// Prompt pass for this request (per-slot on the continuous path,
    /// shared across the wave on the batch path).
    pub prefill_ms: f64,
    /// Arrival → first generated token available (time-to-first-token).
    pub ttft_ms: f64,
    /// Decode wall time attributed to this request: the sum of the
    /// decode steps it participated in, ending at its retirement — not
    /// the whole batch's run, as the run-to-completion scheduler used
    /// to report.
    pub decode_ms: f64,
    pub tokens: usize,
    pub error: Option<String>,
}

impl RequestTiming {
    pub fn failed(msg: String) -> RequestTiming {
        RequestTiming { error: Some(msg), ..Default::default() }
    }

    /// End-to-end latency.
    pub fn total_ms(&self) -> f64 {
        self.queue_ms + self.prefill_ms + self.decode_ms
    }
}

/// Fixed-size uniform sample of a stream (algorithm R).
struct Reservoir {
    samples: Vec<f64>,
    seen: u64,
    rng: Rng,
}

impl Reservoir {
    fn new() -> Reservoir {
        Reservoir {
            samples: Vec::new(),
            seen: 0,
            // Fixed seed: metrics are an estimate either way, and a
            // deterministic stream keeps test runs reproducible.
            rng: Rng::new(0x1A7E_9C1E),
        }
    }

    fn record(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR_CAP {
            self.samples.push(v);
        } else {
            let j = self.rng.below(self.seen) as usize;
            if j < LATENCY_RESERVOIR_CAP {
                self.samples[j] = v;
            }
        }
    }
}

/// Nearest-rank percentile of a sorted slice: the smallest value with at
/// least `p` of the sample at or below it. No interpolation, no
/// truncation bias — `percentile(&[1..=10], 0.99)` is 10, not 9.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (sorted.len() as f64 * p).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

struct Inner {
    requests: u64,
    batches: u64,
    batch_size_sum: u64,
    bucket_sum: u64,
    tokens: u64,
    queue_ms_sum: f64,
    prefill_ms_sum: f64,
    ttft_ms_sum: f64,
    decode_ms_sum: f64,
    /// Decode steps executed and the KV-slot occupancy at each — the
    /// continuous scheduler's utilization signal.
    decode_steps: u64,
    active_slot_sum: u64,
    latencies: Reservoir,
}

impl Default for Inner {
    fn default() -> Inner {
        Inner {
            requests: 0,
            batches: 0,
            batch_size_sum: 0,
            bucket_sum: 0,
            tokens: 0,
            queue_ms_sum: 0.0,
            prefill_ms_sum: 0.0,
            ttft_ms_sum: 0.0,
            decode_ms_sum: 0.0,
            decode_steps: 0,
            active_slot_sum: 0,
            latencies: Reservoir::new(),
        }
    }
}

/// Aggregate serving metrics.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub requests: u64,
    /// Admission rounds (continuous) or waves (batch path).
    pub batches: u64,
    pub avg_batch_size: f64,
    pub avg_bucket: f64,
    pub tokens: u64,
    pub avg_queue_ms: f64,
    pub avg_prefill_ms: f64,
    pub avg_ttft_ms: f64,
    pub avg_decode_ms_per_token: f64,
    pub decode_steps: u64,
    /// Mean KV slots occupied per decode step.
    pub avg_active_slots: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Latencies observed / currently held in the reservoir.
    pub latencies_seen: u64,
    pub latency_samples: usize,
}

impl Metrics {
    /// One admission event: `size` requests entered, `bucket` = compiled
    /// bucket (waves) or total occupancy after admission (continuous).
    pub fn record_batch(&self, size: usize, bucket: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batch_size_sum += size as u64;
        m.bucket_sum += bucket as u64;
    }

    /// One decode step over `active` occupied slots.
    pub fn record_step(&self, active: usize) {
        let mut m = self.inner.lock().unwrap();
        m.decode_steps += 1;
        m.active_slot_sum += active as u64;
    }

    pub fn record_request(&self, t: &RequestTiming) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.tokens += t.tokens as u64;
        m.queue_ms_sum += t.queue_ms;
        m.prefill_ms_sum += t.prefill_ms;
        m.ttft_ms_sum += t.ttft_ms;
        m.decode_ms_sum += t.decode_ms;
        m.latencies.record(t.total_ms());
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        let mut lat = m.latencies.samples.clone();
        lat.sort_by(|a, b| a.total_cmp(b));
        Snapshot {
            requests: m.requests,
            batches: m.batches,
            avg_batch_size: m.batch_size_sum as f64 / m.batches.max(1) as f64,
            avg_bucket: m.bucket_sum as f64 / m.batches.max(1) as f64,
            tokens: m.tokens,
            avg_queue_ms: m.queue_ms_sum / m.requests.max(1) as f64,
            avg_prefill_ms: m.prefill_ms_sum / m.requests.max(1) as f64,
            avg_ttft_ms: m.ttft_ms_sum / m.requests.max(1) as f64,
            avg_decode_ms_per_token: m.decode_ms_sum / m.tokens.max(1) as f64,
            decode_steps: m.decode_steps,
            avg_active_slots: m.active_slot_sum as f64 / m.decode_steps.max(1) as f64,
            p50_latency_ms: percentile(&lat, 0.5),
            p99_latency_ms: percentile(&lat, 0.99),
            latencies_seen: m.latencies.seen,
            latency_samples: lat.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_correctly() {
        let m = Metrics::default();
        m.record_batch(3, 4);
        m.record_batch(1, 1);
        m.record_step(4);
        m.record_step(2);
        for _ in 0..4 {
            m.record_request(&RequestTiming {
                queue_ms: 1.0,
                prefill_ms: 2.0,
                ttft_ms: 4.0,
                decode_ms: 10.0,
                tokens: 5,
                error: None,
            });
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert!((s.avg_batch_size - 2.0).abs() < 1e-9);
        assert_eq!(s.tokens, 20);
        // 4 × 10 ms decode over 20 tokens = 2 ms/token.
        assert!((s.avg_decode_ms_per_token - 2.0).abs() < 1e-9);
        assert!((s.avg_ttft_ms - 4.0).abs() < 1e-9);
        assert_eq!(s.decode_steps, 2);
        assert!((s.avg_active_slots - 3.0).abs() < 1e-9);
        assert!((s.p50_latency_ms - 13.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99_latency_ms, 0.0);
        assert_eq!(s.latency_samples, 0);
    }

    #[test]
    fn reservoir_is_bounded_under_sustained_traffic() {
        // Regression: latencies used to accumulate without bound.
        let m = Metrics::default();
        for i in 0..(LATENCY_RESERVOIR_CAP as u64 * 4) {
            m.record_request(&RequestTiming {
                decode_ms: i as f64,
                tokens: 1,
                ..Default::default()
            });
        }
        let s = m.snapshot();
        assert_eq!(s.latencies_seen, LATENCY_RESERVOIR_CAP as u64 * 4);
        assert_eq!(s.latency_samples, LATENCY_RESERVOIR_CAP);
        // The sample still spans the stream, so percentiles are sane.
        assert!(s.p50_latency_ms > 0.0);
        assert!(s.p99_latency_ms > s.p50_latency_ms);
    }

    #[test]
    fn nearest_rank_does_not_floor_small_samples() {
        // Regression: `(n-1) * p as usize` truncated — on 10 samples the
        // old p99 was the 9th value, not the max.
        let m = Metrics::default();
        for i in 1..=10 {
            m.record_request(&RequestTiming {
                decode_ms: i as f64,
                tokens: 1,
                ..Default::default()
            });
        }
        let s = m.snapshot();
        assert_eq!(s.p99_latency_ms, 10.0);
        assert_eq!(s.p50_latency_ms, 5.0);
    }

    #[test]
    fn nan_latency_does_not_poison_percentiles() {
        // Regression: `partial_cmp(..).unwrap()` panicked on NaN.
        let m = Metrics::default();
        m.record_request(&RequestTiming {
            decode_ms: f64::NAN,
            tokens: 1,
            ..Default::default()
        });
        for i in 0..9 {
            m.record_request(&RequestTiming {
                decode_ms: i as f64,
                tokens: 1,
                ..Default::default()
            });
        }
        let s = m.snapshot(); // must not panic
        assert_eq!(s.latency_samples, 10);
        assert!(s.p50_latency_ms.is_finite());
    }

    #[test]
    fn percentile_edges() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.01), 7.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.5), 50.0);
    }
}

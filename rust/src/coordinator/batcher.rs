//! Pure scheduling policy + prompt normalization — the logic the
//! property tests pin down independently of any backend.
//!
//! Two policies live here, one per scheduler mode (DESIGN.md §9):
//!
//! * [`BatchPolicy`] — size-or-deadline flush for the *wave* path
//!   (bucket-compiled backends admit whole batches at a time).
//! * [`AdmissionPolicy`] — work-conserving slot admission for the
//!   *continuous* path: a freed KV slot is refilled from the queue
//!   immediately, with no artificial wait.

use super::{GenerateRequest, GenerateResponse};
use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

/// A queued request with its response channel and arrival time.
pub struct PendingRequest {
    pub req: GenerateRequest,
    pub tx: Sender<GenerateResponse>,
    pub arrived: Instant,
    /// Prompt normalized to the prefill window, computed lazily and
    /// exactly once — the block-admission gate re-examines queued
    /// requests every scheduler iteration, and re-running
    /// [`fit_prompt`] per step would put a per-candidate allocation on
    /// the decode loop.
    normalized: Option<Vec<i32>>,
}

impl PendingRequest {
    pub fn new(
        req: GenerateRequest,
        tx: Sender<GenerateResponse>,
        arrived: Instant,
    ) -> PendingRequest {
        PendingRequest { req, tx, arrived, normalized: None }
    }

    /// The prompt fitted to the prefill window ([`fit_prompt`]), cached
    /// after the first call. `window`/`pad_id` are fixed per server, so
    /// the cache can never go stale.
    pub fn normalized(&mut self, window: usize, pad_id: i32) -> &[i32] {
        if self.normalized.is_none() {
            self.normalized = Some(fit_prompt(&self.req.prompt, window, pad_id));
        }
        // PANIC: filled two lines up when it was None.
        self.normalized.as_deref().unwrap()
    }
}

/// Flush policy: emit the batch when it is full or the oldest member has
/// waited long enough. Classic size-or-deadline dynamic batching — used
/// by the wave scheduler (PJRT's compiled fixed-bucket entries).
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn should_flush(&self, batch_len: usize, oldest_wait: Duration) -> bool {
        batch_len >= self.max_batch || oldest_wait >= self.max_wait
    }
}

/// Admission policy for the continuous-batching scheduler: between two
/// decode steps, how many queued requests enter freed KV slots.
///
/// The policy is deliberately work-conserving — every free slot fills
/// as soon as a request is queued. The whole admission round is served
/// by **one** batched prefill (`Backend::prefill_into_many` decodes
/// each weight block once for all admitted lanes), so coalescing
/// happens for whatever is queued *now*; holding requests back to
/// coalesce with hypothetical future arrivals would only add queue
/// latency.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// Total KV slots the worker owns.
    pub slots: usize,
}

impl AdmissionPolicy {
    /// How many requests to admit given current occupancy and queue depth.
    pub fn admit_now(&self, occupied: usize, queued: usize) -> usize {
        self.slots.saturating_sub(occupied).min(queued)
    }
}

/// Smallest compiled bucket that fits `n` requests.
pub fn pick_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n)
}

/// Fit a prompt into the fixed prefill window: left-truncate if too long
/// (keep the generation-relevant suffix), left-pad with `pad_id` if
/// short. `pad_id` comes from `ServeConfig` and is clamped to the
/// backend's vocab by the worker before any prompt is normalized — an
/// out-of-vocab pad would pollute attention and, on the native backend,
/// index past the embedding table.
pub fn fit_prompt(prompt: &[i32], window: usize, pad_id: i32) -> Vec<i32> {
    if prompt.len() >= window {
        prompt[prompt.len() - window..].to_vec()
    } else {
        let mut out = vec![pad_id; window - prompt.len()];
        out.extend_from_slice(prompt);
        out
    }
}

/// Clamp a configured pad token into `[0, vocab)`.
pub fn clamp_pad_id(pad_id: i32, vocab: Option<usize>) -> i32 {
    match vocab {
        Some(v) if v > 0 => pad_id.clamp(0, (v - 1) as i32),
        _ => pad_id.max(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop::{check, Config};

    #[test]
    fn policy_flushes_on_size() {
        let p = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(1) };
        assert!(!p.should_flush(3, Duration::ZERO));
        assert!(p.should_flush(4, Duration::ZERO));
        assert!(p.should_flush(5, Duration::ZERO));
    }

    #[test]
    fn policy_flushes_on_deadline() {
        let p = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(10) };
        assert!(!p.should_flush(1, Duration::from_millis(9)));
        assert!(p.should_flush(1, Duration::from_millis(10)));
    }

    #[test]
    fn admission_is_work_conserving() {
        let p = AdmissionPolicy { slots: 4 };
        assert_eq!(p.admit_now(0, 10), 4); // empty worker fills up
        assert_eq!(p.admit_now(3, 10), 1); // one freed slot refills
        assert_eq!(p.admit_now(4, 10), 0); // full worker admits nothing
        assert_eq!(p.admit_now(2, 1), 1); // short queue drains fully
        assert_eq!(p.admit_now(5, 1), 0); // over-occupied (clamped) is safe
    }

    #[test]
    fn bucket_selection() {
        let buckets = [1usize, 2, 4, 8];
        assert_eq!(pick_bucket(&buckets, 1), Some(1));
        assert_eq!(pick_bucket(&buckets, 3), Some(4));
        assert_eq!(pick_bucket(&buckets, 8), Some(8));
        assert_eq!(pick_bucket(&buckets, 9), None);
    }

    #[test]
    fn fit_prompt_window() {
        assert_eq!(fit_prompt(&[1, 2, 3], 2, 32), vec![2, 3]);
        let padded = fit_prompt(&[7], 4, 32);
        assert_eq!(padded.len(), 4);
        assert_eq!(padded[3], 7);
        assert_eq!(padded[0], 32);
        assert_eq!(fit_prompt(&[1, 2], 2, 32), vec![1, 2]);
        // The pad id is honoured, not hard-coded.
        assert_eq!(fit_prompt(&[5], 3, 0), vec![0, 0, 5]);
    }

    #[test]
    fn pad_id_clamps_to_vocab() {
        // Regression: the old scheduler padded with `b' ' as i32` (= 32)
        // unconditionally, which is out of range for vocab_size <= 32.
        assert_eq!(clamp_pad_id(32, Some(256)), 32);
        assert_eq!(clamp_pad_id(32, Some(16)), 15);
        assert_eq!(clamp_pad_id(-7, Some(16)), 0);
        assert_eq!(clamp_pad_id(-7, None), 0);
        assert_eq!(clamp_pad_id(1000, Some(256)), 255);
        assert_eq!(clamp_pad_id(9, Some(0)), 9); // degenerate vocab: leave as-is
    }

    #[test]
    fn prop_fit_prompt_invariants() {
        check(
            "fit-prompt",
            Config::with_cases(128),
            |rng, size| {
                let plen = (size * 300.0) as usize + 1;
                let window = 1 + rng.below(128) as usize;
                let pad = rng.below(256) as i32;
                let prompt: Vec<i32> =
                    (0..plen).map(|_| rng.below(256) as i32).collect();
                (prompt, window, pad)
            },
            |(prompt, window, pad)| {
                let out = fit_prompt(prompt, *window, *pad);
                crate::prop_assert!(out.len() == *window, "length");
                // The suffix of the prompt is always preserved.
                let keep = prompt.len().min(*window);
                crate::prop_assert!(
                    out[*window - keep..] == prompt[prompt.len() - keep..],
                    "suffix preserved"
                );
                // Everything before it is the pad token.
                crate::prop_assert!(
                    out[..*window - keep].iter().all(|&t| t == *pad),
                    "prefix is pad"
                );
                Ok(())
            },
        );
    }

    #[test]
    fn prop_bucket_is_minimal_and_sufficient() {
        check(
            "pick-bucket",
            Config::with_cases(128),
            |rng, _| {
                let mut buckets: Vec<usize> =
                    (0..4).map(|_| 1 + rng.below(16) as usize).collect();
                buckets.sort_unstable();
                buckets.dedup();
                let n = 1 + rng.below(20) as usize;
                (buckets, n)
            },
            |(buckets, n)| {
                match pick_bucket(buckets, *n) {
                    Some(b) => {
                        crate::prop_assert!(b >= *n, "bucket too small");
                        crate::prop_assert!(
                            buckets.iter().all(|&x| x >= *n || x < b),
                            "not minimal"
                        );
                    }
                    None => {
                        crate::prop_assert!(
                            buckets.iter().all(|&x| x < *n),
                            "bucket existed but not found"
                        );
                    }
                }
                Ok(())
            },
        );
    }
}

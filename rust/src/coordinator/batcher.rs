//! Pure scheduling policy + prompt normalization — the logic the
//! property tests pin down independently of any backend.
//!
//! Three policies live here (DESIGN.md §9, §15):
//!
//! * [`BatchPolicy`] — size-or-deadline flush for the *wave* path
//!   (bucket-compiled backends admit whole batches at a time).
//! * [`AdmissionPolicy`] — work-conserving slot admission for the
//!   *continuous* path: a freed KV slot is refilled from the queue
//!   immediately, with no artificial wait.
//! * [`QosQueue`] — the priority/deadline/fairness admission queue both
//!   scheduler loops pull from: priority-ordered, deadline-shedding,
//!   round-robin across tenants at equal priority.
//!
//! [`Delivery`] is the response side: one buffered `GenerateResponse`
//! (the pre-streaming contract) or a per-token [`TokenEvent`] stream.

use super::metrics::RequestTiming;
use super::{GenerateRequest, GenerateResponse, TokenEvent};
use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

/// QoS class a request carries into admission (DESIGN.md §15).
#[derive(Clone, Copy, Debug, Default)]
pub struct Class {
    /// Admission priority: higher values are admitted first. Requests
    /// of equal priority are served in arrival order, round-robin
    /// across tenants.
    pub priority: u8,
    /// Absolute shed deadline: a request still queued (not admitted)
    /// when it passes is failed instead of served late.
    pub deadline: Option<Instant>,
}

/// Load-shedding and fairness bounds (DESIGN.md §15). The defaults are
/// effectively unbounded — QoS is opt-in per server.
#[derive(Clone, Copy, Debug)]
pub struct QosConfig {
    /// Queued-but-unadmitted requests allowed per priority class; a
    /// submission beyond the bound is shed with an explicit failure
    /// rather than queued indefinitely.
    pub max_queue_per_class: usize,
    /// In-flight sequences (KV slots / wave lanes) one tenant may hold.
    pub max_slots_per_tenant: usize,
}

impl Default for QosConfig {
    fn default() -> QosConfig {
        QosConfig { max_queue_per_class: usize::MAX, max_slots_per_tenant: usize::MAX }
    }
}

/// The client is gone: its receiver was dropped before delivery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Disconnected;

/// How a request's results travel back to the client (DESIGN.md §15).
pub enum Delivery {
    /// Buffered: one [`GenerateResponse`] when the request retires.
    Whole(Sender<GenerateResponse>),
    /// Streaming: a [`TokenEvent::Token`] per decoded token the moment
    /// the step retires, then `Done` (or `Failed`).
    Stream(Sender<TokenEvent>),
}

impl Delivery {
    /// Push one decoded token. Whole-mode responses are buffered by the
    /// scheduler, so only stream mode can observe a disconnect here;
    /// an `Err` means the client dropped its receiver and the sequence
    /// should be cancelled (§15 cancel semantics).
    pub fn send_token(&self, tok: i32) -> Result<(), Disconnected> {
        match self {
            Delivery::Whole(_) => Ok(()),
            Delivery::Stream(tx) => tx.send(TokenEvent::Token(tok)).map_err(|_| Disconnected),
        }
    }

    /// Terminal success: the whole response, or the stream's `Done`
    /// marker. `Err` means the client disconnected before delivery.
    pub fn finish(
        &self,
        id: u64,
        tokens: Vec<i32>,
        timing: RequestTiming,
    ) -> Result<(), Disconnected> {
        match self {
            Delivery::Whole(tx) => {
                tx.send(GenerateResponse { id, tokens, timing }).map_err(|_| Disconnected)
            }
            Delivery::Stream(tx) => tx.send(TokenEvent::Done(timing)).map_err(|_| Disconnected),
        }
    }

    /// Terminal failure (error or shed). A disconnected client is
    /// ignored — it no longer cares.
    pub fn fail(&self, id: u64, msg: String) {
        match self {
            Delivery::Whole(tx) => {
                let _ = tx.send(GenerateResponse {
                    id,
                    tokens: vec![],
                    timing: RequestTiming::failed(msg),
                });
            }
            Delivery::Stream(tx) => {
                let _ = tx.send(TokenEvent::Failed(msg));
            }
        }
    }
}

/// A queued request with its delivery channel and arrival time.
pub struct PendingRequest {
    pub req: GenerateRequest,
    pub tx: Delivery,
    pub arrived: Instant,
    /// Prompt normalized to the prefill window, computed lazily and
    /// exactly once — the block-admission gate re-examines queued
    /// requests every scheduler iteration, and re-running
    /// [`fit_prompt`] per step would put a per-candidate allocation on
    /// the decode loop.
    normalized: Option<Vec<i32>>,
}

impl PendingRequest {
    pub fn new(req: GenerateRequest, tx: Delivery, arrived: Instant) -> PendingRequest {
        PendingRequest { req, tx, arrived, normalized: None }
    }

    /// The prompt fitted to the prefill window ([`fit_prompt`]), cached
    /// after the first call. `window`/`pad_id` are fixed per server, so
    /// the cache can never go stale.
    pub fn normalized(&mut self, window: usize, pad_id: i32) -> &[i32] {
        if self.normalized.is_none() {
            self.normalized = Some(fit_prompt(&self.req.prompt, window, pad_id));
        }
        // PANIC: filled two lines up when it was None.
        self.normalized.as_deref().unwrap()
    }
}

/// Flush policy: emit the batch when it is full or the oldest member has
/// waited long enough. Classic size-or-deadline dynamic batching — used
/// by the wave scheduler (PJRT's compiled fixed-bucket entries).
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn should_flush(&self, batch_len: usize, oldest_wait: Duration) -> bool {
        batch_len >= self.max_batch || oldest_wait >= self.max_wait
    }
}

/// Admission policy for the continuous-batching scheduler: between two
/// decode steps, how many queued requests enter freed KV slots.
///
/// The policy is deliberately work-conserving — every free slot fills
/// as soon as a request is queued. The whole admission round is served
/// by **one** batched prefill (`Backend::prefill_into_many` decodes
/// each weight block once for all admitted lanes), so coalescing
/// happens for whatever is queued *now*; holding requests back to
/// coalesce with hypothetical future arrivals would only add queue
/// latency.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// Total KV slots the worker owns.
    pub slots: usize,
}

impl AdmissionPolicy {
    /// How many requests to admit given current occupancy and queue depth.
    pub fn admit_now(&self, occupied: usize, queued: usize) -> usize {
        self.slots.saturating_sub(occupied).min(queued)
    }
}

/// The priority/deadline/fairness admission queue (DESIGN.md §15).
///
/// Items are kept priority-descending, FIFO within a priority class, so
/// with all-default classes the queue degenerates to plain FIFO and both
/// scheduler loops behave exactly as before QoS existed. Selection
/// ([`QosQueue::select`]) skips tenants at their in-flight cap and
/// rotates round-robin across tenants at the chosen priority.
#[derive(Default)]
pub struct QosQueue {
    items: Vec<PendingRequest>,
    /// Tenant served by the most recent `select`, for round-robin
    /// rotation at equal priority.
    rr_last: Option<u64>,
}

impl QosQueue {
    pub fn new() -> QosQueue {
        QosQueue { items: Vec::new(), rr_last: None }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Enqueue in priority order. Returns the request back (`Err`) when
    /// its priority class already holds `max_per_class` queued entries —
    /// the caller sheds it with an explicit failure.
    pub fn push(&mut self, p: PendingRequest, max_per_class: usize) -> Result<(), PendingRequest> {
        let prio = p.req.class.priority;
        let depth = self.items.iter().filter(|q| q.req.class.priority == prio).count();
        if depth >= max_per_class {
            return Err(p);
        }
        // Insert before the first strictly-lower priority: descending
        // order, arrival order within a class.
        let at = self
            .items
            .iter()
            .position(|q| q.req.class.priority < prio)
            .unwrap_or(self.items.len());
        self.items.insert(at, p);
        Ok(())
    }

    /// Remove every queued request whose shed deadline has passed. The
    /// caller fails them; admitted sequences are never shed.
    pub fn drain_expired(&mut self, now: Instant) -> Vec<PendingRequest> {
        let mut expired = Vec::new();
        let mut i = 0;
        while i < self.items.len() {
            if self.items[i].req.class.deadline.is_some_and(|d| d <= now) {
                expired.push(self.items.remove(i));
            } else {
                i += 1;
            }
        }
        expired
    }

    /// Pick the next request to admit: the highest-priority class with
    /// an admissible item (tenants already holding `max_per_tenant`
    /// in-flight sequences are skipped so a greedy tenant cannot starve
    /// the rest), rotating round-robin across that class's admissible
    /// tenants starting after the last tenant served. Returns an index
    /// into the queue — the caller may inspect it (block-need probe)
    /// before committing with [`QosQueue::remove`].
    pub fn select(
        &mut self,
        in_flight: &HashMap<u64, usize>,
        max_per_tenant: usize,
    ) -> Option<usize> {
        let admissible = |p: &PendingRequest| {
            in_flight.get(&p.req.tenant).copied().unwrap_or(0) < max_per_tenant
        };
        let first = self.items.iter().position(admissible)?;
        let prio = self.items[first].req.class.priority;
        // First queued item per admissible tenant within the chosen
        // class, in arrival order.
        let mut heads: Vec<(u64, usize)> = Vec::new();
        for (i, p) in self.items.iter().enumerate().skip(first) {
            if p.req.class.priority != prio {
                break;
            }
            if admissible(p) && heads.iter().all(|&(t, _)| t != p.req.tenant) {
                heads.push((p.req.tenant, i));
            }
        }
        // Rotate: continue strictly after the tenant served last time.
        let pick = match self.rr_last.and_then(|t| heads.iter().position(|&(h, _)| h == t)) {
            Some(at) => heads[(at + 1) % heads.len()],
            None => heads[0],
        };
        self.rr_last = Some(pick.0);
        Some(pick.1)
    }

    pub fn get_mut(&mut self, i: usize) -> &mut PendingRequest {
        &mut self.items[i]
    }

    pub fn remove(&mut self, i: usize) -> PendingRequest {
        self.items.remove(i)
    }

    /// Empty the queue (shutdown drain); the caller fails every entry.
    pub fn drain_all(&mut self) -> Vec<PendingRequest> {
        std::mem::take(&mut self.items)
    }
}

/// Smallest compiled bucket that fits `n` requests.
pub fn pick_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n)
}

/// Fit a prompt into the fixed prefill window: left-truncate if too long
/// (keep the generation-relevant suffix), left-pad with `pad_id` if
/// short. `pad_id` comes from `ServeConfig` and is clamped to the
/// backend's vocab by the worker before any prompt is normalized — an
/// out-of-vocab pad would pollute attention and, on the native backend,
/// index past the embedding table.
pub fn fit_prompt(prompt: &[i32], window: usize, pad_id: i32) -> Vec<i32> {
    if prompt.len() >= window {
        prompt[prompt.len() - window..].to_vec()
    } else {
        let mut out = vec![pad_id; window - prompt.len()];
        out.extend_from_slice(prompt);
        out
    }
}

/// Clamp a configured pad token into `[0, vocab)`.
pub fn clamp_pad_id(pad_id: i32, vocab: Option<usize>) -> i32 {
    match vocab {
        Some(v) if v > 0 => pad_id.clamp(0, (v - 1) as i32),
        _ => pad_id.max(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop::{check, Config};

    #[test]
    fn policy_flushes_on_size() {
        let p = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(1) };
        assert!(!p.should_flush(3, Duration::ZERO));
        assert!(p.should_flush(4, Duration::ZERO));
        assert!(p.should_flush(5, Duration::ZERO));
    }

    #[test]
    fn policy_flushes_on_deadline() {
        let p = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(10) };
        assert!(!p.should_flush(1, Duration::from_millis(9)));
        assert!(p.should_flush(1, Duration::from_millis(10)));
    }

    #[test]
    fn admission_is_work_conserving() {
        let p = AdmissionPolicy { slots: 4 };
        assert_eq!(p.admit_now(0, 10), 4); // empty worker fills up
        assert_eq!(p.admit_now(3, 10), 1); // one freed slot refills
        assert_eq!(p.admit_now(4, 10), 0); // full worker admits nothing
        assert_eq!(p.admit_now(2, 1), 1); // short queue drains fully
        assert_eq!(p.admit_now(5, 1), 0); // over-occupied (clamped) is safe
    }

    #[test]
    fn bucket_selection() {
        let buckets = [1usize, 2, 4, 8];
        assert_eq!(pick_bucket(&buckets, 1), Some(1));
        assert_eq!(pick_bucket(&buckets, 3), Some(4));
        assert_eq!(pick_bucket(&buckets, 8), Some(8));
        assert_eq!(pick_bucket(&buckets, 9), None);
    }

    #[test]
    fn fit_prompt_window() {
        assert_eq!(fit_prompt(&[1, 2, 3], 2, 32), vec![2, 3]);
        let padded = fit_prompt(&[7], 4, 32);
        assert_eq!(padded.len(), 4);
        assert_eq!(padded[3], 7);
        assert_eq!(padded[0], 32);
        assert_eq!(fit_prompt(&[1, 2], 2, 32), vec![1, 2]);
        // The pad id is honoured, not hard-coded.
        assert_eq!(fit_prompt(&[5], 3, 0), vec![0, 0, 5]);
    }

    #[test]
    fn pad_id_clamps_to_vocab() {
        // Regression: the old scheduler padded with `b' ' as i32` (= 32)
        // unconditionally, which is out of range for vocab_size <= 32.
        assert_eq!(clamp_pad_id(32, Some(256)), 32);
        assert_eq!(clamp_pad_id(32, Some(16)), 15);
        assert_eq!(clamp_pad_id(-7, Some(16)), 0);
        assert_eq!(clamp_pad_id(-7, None), 0);
        assert_eq!(clamp_pad_id(1000, Some(256)), 255);
        assert_eq!(clamp_pad_id(9, Some(0)), 9); // degenerate vocab: leave as-is
    }

    #[test]
    fn prop_fit_prompt_invariants() {
        check(
            "fit-prompt",
            Config::with_cases(128),
            |rng, size| {
                let plen = (size * 300.0) as usize + 1;
                let window = 1 + rng.below(128) as usize;
                let pad = rng.below(256) as i32;
                let prompt: Vec<i32> =
                    (0..plen).map(|_| rng.below(256) as i32).collect();
                (prompt, window, pad)
            },
            |(prompt, window, pad)| {
                let out = fit_prompt(prompt, *window, *pad);
                crate::prop_assert!(out.len() == *window, "length");
                // The suffix of the prompt is always preserved.
                let keep = prompt.len().min(*window);
                crate::prop_assert!(
                    out[*window - keep..] == prompt[prompt.len() - keep..],
                    "suffix preserved"
                );
                // Everything before it is the pad token.
                crate::prop_assert!(
                    out[..*window - keep].iter().all(|&t| t == *pad),
                    "prefix is pad"
                );
                Ok(())
            },
        );
    }

    fn pend(id: u64, priority: u8, tenant: u64, deadline: Option<Instant>) -> PendingRequest {
        let (tx, rx) = std::sync::mpsc::channel();
        // Queue-policy tests never deliver; the dropped receiver is fine.
        drop(rx);
        PendingRequest::new(
            GenerateRequest {
                id,
                prompt: vec![1],
                max_new_tokens: 4,
                class: Class { priority, deadline },
                tenant,
            },
            Delivery::Whole(tx),
            Instant::now(),
        )
    }

    fn drain_ids(q: &mut QosQueue, in_flight: &HashMap<u64, usize>, cap: usize) -> Vec<u64> {
        let mut ids = Vec::new();
        while let Some(i) = q.select(in_flight, cap) {
            ids.push(q.remove(i).req.id);
        }
        ids
    }

    #[test]
    fn qos_queue_orders_by_priority_then_arrival() {
        let mut q = QosQueue::new();
        for (id, prio) in [(1, 0), (2, 2), (3, 1), (4, 2), (5, 0)] {
            q.push(pend(id, prio, 0, None), usize::MAX).unwrap();
        }
        let ids = drain_ids(&mut q, &HashMap::new(), usize::MAX);
        assert_eq!(ids, vec![2, 4, 3, 1, 5]);
    }

    #[test]
    fn qos_queue_sheds_on_class_depth() {
        let mut q = QosQueue::new();
        assert!(q.push(pend(1, 1, 0, None), 2).is_ok());
        assert!(q.push(pend(2, 1, 0, None), 2).is_ok());
        // Third entry in the same class bounces back to the caller...
        let rejected = q.push(pend(3, 1, 0, None), 2).unwrap_err();
        assert_eq!(rejected.req.id, 3);
        // ...but another class still has headroom.
        assert!(q.push(pend(4, 0, 0, None), 2).is_ok());
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn qos_queue_drains_expired_deadlines() {
        let now = Instant::now();
        let mut q = QosQueue::new();
        q.push(pend(1, 0, 0, Some(now - Duration::from_millis(1))), usize::MAX).unwrap();
        q.push(pend(2, 0, 0, Some(now + Duration::from_secs(60))), usize::MAX).unwrap();
        q.push(pend(3, 0, 0, None), usize::MAX).unwrap();
        let expired: Vec<u64> = q.drain_expired(now).into_iter().map(|p| p.req.id).collect();
        assert_eq!(expired, vec![1]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn qos_queue_skips_tenants_at_cap() {
        let mut q = QosQueue::new();
        q.push(pend(1, 1, 7, None), usize::MAX).unwrap(); // high prio, capped tenant
        q.push(pend(2, 0, 8, None), usize::MAX).unwrap(); // low prio, free tenant
        let in_flight = HashMap::from([(7u64, 2usize)]);
        // Tenant 7 is at its cap, so the lower-priority tenant runs
        // instead of head-of-line blocking behind it.
        let i = q.select(&in_flight, 2).unwrap();
        assert_eq!(q.remove(i).req.id, 2);
        // With nothing admissible, select yields none.
        assert!(q.select(&in_flight, 2).is_none());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn qos_queue_round_robins_tenants_at_equal_priority() {
        let mut q = QosQueue::new();
        // Tenant A submits a burst before tenant B's requests arrive.
        for (id, tenant) in [(1, 10), (2, 10), (3, 10), (4, 20), (5, 20)] {
            q.push(pend(id, 0, tenant, None), usize::MAX).unwrap();
        }
        let ids = drain_ids(&mut q, &HashMap::new(), usize::MAX);
        assert_eq!(ids, vec![1, 4, 2, 5, 3]);
    }

    #[test]
    fn prop_bucket_is_minimal_and_sufficient() {
        check(
            "pick-bucket",
            Config::with_cases(128),
            |rng, _| {
                let mut buckets: Vec<usize> =
                    (0..4).map(|_| 1 + rng.below(16) as usize).collect();
                buckets.sort_unstable();
                buckets.dedup();
                let n = 1 + rng.below(20) as usize;
                (buckets, n)
            },
            |(buckets, n)| {
                match pick_bucket(buckets, *n) {
                    Some(b) => {
                        crate::prop_assert!(b >= *n, "bucket too small");
                        crate::prop_assert!(
                            buckets.iter().all(|&x| x >= *n || x < b),
                            "not minimal"
                        );
                    }
                    None => {
                        crate::prop_assert!(
                            buckets.iter().all(|&x| x < *n),
                            "bucket existed but not found"
                        );
                    }
                }
                Ok(())
            },
        );
    }
}

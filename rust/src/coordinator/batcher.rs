//! Pure batching policy + prompt normalization — the logic the property
//! tests pin down independently of any backend.

use super::{GenerateRequest, GenerateResponse};
use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

/// A queued request with its response channel and arrival time.
pub struct PendingRequest {
    pub req: GenerateRequest,
    pub tx: Sender<GenerateResponse>,
    pub arrived: Instant,
}

/// Flush policy: emit the batch when it is full or the oldest member has
/// waited long enough. Classic size-or-deadline dynamic batching.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn should_flush(&self, batch_len: usize, oldest_wait: Duration) -> bool {
        batch_len >= self.max_batch || oldest_wait >= self.max_wait
    }
}

/// Smallest compiled bucket that fits `n` requests.
pub fn pick_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n)
}

/// Fit a prompt into the fixed prefill window: left-truncate if too long
/// (keep the generation-relevant suffix), left-pad with spaces if short.
pub fn fit_prompt(prompt: &[i32], window: usize) -> Vec<i32> {
    if prompt.len() >= window {
        prompt[prompt.len() - window..].to_vec()
    } else {
        let mut out = vec![b' ' as i32; window - prompt.len()];
        out.extend_from_slice(prompt);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop::{check, Config};

    #[test]
    fn policy_flushes_on_size() {
        let p = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(1) };
        assert!(!p.should_flush(3, Duration::ZERO));
        assert!(p.should_flush(4, Duration::ZERO));
        assert!(p.should_flush(5, Duration::ZERO));
    }

    #[test]
    fn policy_flushes_on_deadline() {
        let p = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(10) };
        assert!(!p.should_flush(1, Duration::from_millis(9)));
        assert!(p.should_flush(1, Duration::from_millis(10)));
    }

    #[test]
    fn bucket_selection() {
        let buckets = [1usize, 2, 4, 8];
        assert_eq!(pick_bucket(&buckets, 1), Some(1));
        assert_eq!(pick_bucket(&buckets, 3), Some(4));
        assert_eq!(pick_bucket(&buckets, 8), Some(8));
        assert_eq!(pick_bucket(&buckets, 9), None);
    }

    #[test]
    fn fit_prompt_window() {
        assert_eq!(fit_prompt(&[1, 2, 3], 2), vec![2, 3]);
        let padded = fit_prompt(&[7], 4);
        assert_eq!(padded.len(), 4);
        assert_eq!(padded[3], 7);
        assert_eq!(padded[0], b' ' as i32);
        assert_eq!(fit_prompt(&[1, 2], 2), vec![1, 2]);
    }

    #[test]
    fn prop_fit_prompt_invariants() {
        check(
            "fit-prompt",
            Config::with_cases(128),
            |rng, size| {
                let plen = (size * 300.0) as usize + 1;
                let window = 1 + rng.below(128) as usize;
                let prompt: Vec<i32> =
                    (0..plen).map(|_| rng.below(256) as i32).collect();
                (prompt, window)
            },
            |(prompt, window)| {
                let out = fit_prompt(prompt, *window);
                crate::prop_assert!(out.len() == *window, "length");
                // The suffix of the prompt is always preserved.
                let keep = prompt.len().min(*window);
                crate::prop_assert!(
                    out[*window - keep..] == prompt[prompt.len() - keep..],
                    "suffix preserved"
                );
                Ok(())
            },
        );
    }

    #[test]
    fn prop_bucket_is_minimal_and_sufficient() {
        check(
            "pick-bucket",
            Config::with_cases(128),
            |rng, _| {
                let mut buckets: Vec<usize> =
                    (0..4).map(|_| 1 + rng.below(16) as usize).collect();
                buckets.sort_unstable();
                buckets.dedup();
                let n = 1 + rng.below(20) as usize;
                (buckets, n)
            },
            |(buckets, n)| {
                match pick_bucket(buckets, *n) {
                    Some(b) => {
                        crate::prop_assert!(b >= *n, "bucket too small");
                        crate::prop_assert!(
                            buckets.iter().all(|&x| x >= *n || x < b),
                            "not minimal"
                        );
                    }
                    None => {
                        crate::prop_assert!(
                            buckets.iter().all(|&x| x < *n),
                            "bucket existed but not found"
                        );
                    }
                }
                Ok(())
            },
        );
    }
}

//! Model-executor abstraction for the serving loop.
//!
//! [`PjrtBackend`] executes prefill/decode HLO entries on the PJRT CPU
//! client with resident weight literals. [`NativeBackend`] serves the
//! same contract with zero PJRT involvement: the forward runs on the
//! fused quantized-plane kernels ([`crate::kernels`]), weights stay in
//! their (n+1)-bit runtime form. [`MockBackend`] is a deterministic
//! stand-in for batcher tests and benches.

use crate::kernels::{KvCache, NativeModel};
use crate::model::TrainedModel;
use crate::runtime::{Engine, HostTensor};
use crate::store::{DecodeCache, StoredModel};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Backend-specific KV-cache payload carried inside [`DecodeState`].
pub enum KvState {
    /// No cache (mock backends, or a state consumed mid-step).
    None,
    /// PJRT k/v literals.
    Pjrt(xla::Literal, xla::Literal),
    /// Native host-memory cache for the fused-kernel forward.
    Native(KvCache),
}

/// In-flight generation state for one batch.
pub struct DecodeState {
    pub bucket: usize,
    pub pos: usize,
    /// Last emitted token per sequence (input to the next decode step).
    pub last_tokens: Vec<i32>,
    /// Backend-specific cache payload.
    pub kv: KvState,
}

/// Greedy per-row argmax over a flat `(rows × c)` logits buffer.
pub fn argmax_rows(logits: &[f32], rows: usize) -> Vec<i32> {
    let cols = logits.len() / rows;
    (0..rows)
        .map(|r| {
            let row = &logits[r * cols..(r + 1) * cols];
            let mut best = (f32::NEG_INFINITY, 0usize);
            for (i, &v) in row.iter().enumerate() {
                if v > best.0 {
                    best = (v, i);
                }
            }
            best.1 as i32
        })
        .collect()
}

/// The serving contract: batch prefill, then repeated single-token decode.
///
/// Deliberately *not* `Send`: PJRT handles are thread-local, so the
/// backend is constructed inside the worker thread (the factory closure
/// is what crosses the thread boundary — see [`super::Server::start`]).
pub trait Backend {
    /// Run the prompt pass for a bucket-sized batch of equal-length
    /// prompts; returns the decode state primed with the first sampled
    /// token per sequence.
    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Result<DecodeState>;

    /// One greedy decode step: returns the next token per sequence and
    /// advances the state.
    fn decode(&mut self, state: &mut DecodeState) -> Result<Vec<i32>>;
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// Real backend: compiled prefill_b{B}/decode_b{B} entries + weights.
///
/// Weights are uploaded to the device **once** at construction
/// (`upload_all`) and borrowed by every prefill/decode call — the
/// coordinator never re-copies the model (§Perf: 4.5× faster decode
/// steps vs the literal path).
pub struct PjrtBackend {
    engine: Engine,
    weights: Vec<crate::runtime::ResidentBuffer>,
    max_seq: usize,
    prefill_len: usize,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: &std::path::Path, model: &TrainedModel) -> Result<PjrtBackend> {
        let engine = Engine::new(artifacts_dir)?;
        let weight_lits = crate::eval::weight_literals(model)?;
        let weights = engine.upload_all(weight_lits)?;
        let prefill_len = engine.manifest().prefill_len;
        Ok(PjrtBackend { engine, weights, max_seq: model.config.max_seq, prefill_len })
    }

    /// Serve straight from an `ICQZ` container: quantized layers are
    /// decoded through the shared LRU cache (one decode per layer even
    /// across backend restarts within the cache's budget), assembled
    /// into the positional weight ABI, and uploaded once.
    pub fn from_container(
        artifacts_dir: &std::path::Path,
        container: &std::path::Path,
        cache: Arc<DecodeCache>,
    ) -> Result<PjrtBackend> {
        let stored = StoredModel::open(container, cache)
            .with_context(|| format!("open container {}", container.display()))?;
        let model = stored.to_trained_model()?;
        Self::new(artifacts_dir, &model)
    }

    /// Pre-compile all serving buckets (avoids first-request latency).
    pub fn warmup(&mut self) -> Result<()> {
        for b in self.engine.manifest().buckets.clone() {
            self.engine.prepare(&format!("prefill_b{}", b))?;
            self.engine.prepare(&format!("decode_b{}", b))?;
        }
        Ok(())
    }
}

impl Backend for PjrtBackend {
    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Result<DecodeState> {
        let bucket = prompts.len();
        let entry = format!("prefill_b{}", bucket);
        self.engine.prepare(&entry)?; // compile before async uploads
        let s = self.prefill_len;
        let mut toks = Vec::with_capacity(bucket * s);
        for p in prompts {
            anyhow::ensure!(p.len() == s, "prompt not normalized to {}", s);
            toks.extend_from_slice(p);
        }
        let data = [self
            .engine
            .upload(HostTensor::I32(toks, vec![bucket, s]).to_literal()?)?];
        let args: Vec<&crate::runtime::ResidentBuffer> = data.iter().chain(self.weights.iter()).collect();
        let mut out = self.engine.execute_buffers(&entry, &args)?;
        anyhow::ensure!(out.len() == 3, "prefill returns (logits, k, v)");
        let v = out.pop().context("v")?;
        let k = out.pop().context("k")?;
        let logits = Engine::literal_f32(&out[0])?;
        let last_tokens = argmax_rows(&logits, bucket);
        Ok(DecodeState { bucket, pos: s, last_tokens, kv: KvState::Pjrt(k, v) })
    }

    fn decode(&mut self, state: &mut DecodeState) -> Result<Vec<i32>> {
        anyhow::ensure!(state.pos < self.max_seq, "KV cache exhausted");
        let entry = format!("decode_b{}", state.bucket);
        self.engine.prepare(&entry)?; // compile before async uploads
        let (k, v) = match std::mem::replace(&mut state.kv, KvState::None) {
            KvState::Pjrt(k, v) => (k, v),
            _ => bail!("kv state missing or not a PJRT payload"),
        };
        let data = [
            self.engine.upload(
                HostTensor::I32(state.last_tokens.clone(), vec![state.bucket])
                    .to_literal()?,
            )?,
            self.engine
                .upload(HostTensor::scalar_i32(state.pos as i32).to_literal()?)?,
            self.engine.upload(k)?,
            self.engine.upload(v)?,
        ];
        let args: Vec<&crate::runtime::ResidentBuffer> =
            data.iter().chain(self.weights.iter()).collect();
        let mut out = self.engine.execute_buffers(&entry, &args)?;
        anyhow::ensure!(out.len() == 3, "decode returns (logits, k, v)");
        let nv = out.pop().context("v")?;
        let nk = out.pop().context("k")?;
        let logits = Engine::literal_f32(&out[0])?;
        let next = argmax_rows(&logits, state.bucket);
        state.last_tokens = next.clone();
        state.kv = KvState::Pjrt(nk, nv);
        state.pos += 1;
        // The emitted token is the one the *previous* position predicted;
        // greedy generation returns it directly.
        Ok(next)
    }
}

// ---------------------------------------------------------------------------
// Native fused-kernel backend
// ---------------------------------------------------------------------------

/// CPU backend serving straight off the quantized runtime planes: every
/// projection is a fused gather+accumulate GEMM
/// ([`crate::kernels::gemm_mt`]) — no f32 weight plane, no PJRT, no
/// Python at request time. Selected with `serve --backend=native`.
pub struct NativeBackend {
    model: NativeModel,
}

impl NativeBackend {
    pub fn new(model: NativeModel) -> NativeBackend {
        NativeBackend { model }
    }

    /// Build from an opened container, pulling every projection through
    /// the store's shared runtime-plane cache. `threads` sizes the
    /// scoped-thread fan-out of the fused kernels (0 ⇒ all cores).
    pub fn from_stored(stored: &StoredModel, threads: usize) -> Result<NativeBackend> {
        Ok(NativeBackend { model: NativeModel::from_stored(stored, threads)? })
    }

    /// Open an `ICQZ` container and build the native backend from it.
    pub fn from_container(
        container: &std::path::Path,
        cache: Arc<DecodeCache>,
        threads: usize,
    ) -> Result<NativeBackend> {
        let stored = StoredModel::open(container, cache)
            .with_context(|| format!("open container {}", container.display()))?;
        Self::from_stored(&stored, threads)
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }
}

impl Backend for NativeBackend {
    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Result<DecodeState> {
        let (last_tokens, kv) = self.model.prefill(prompts)?;
        Ok(DecodeState {
            bucket: prompts.len(),
            pos: kv.len,
            last_tokens,
            kv: KvState::Native(kv),
        })
    }

    fn decode(&mut self, state: &mut DecodeState) -> Result<Vec<i32>> {
        anyhow::ensure!(state.pos < self.model.config.max_seq, "KV cache exhausted");
        let mut kv = match std::mem::replace(&mut state.kv, KvState::None) {
            KvState::Native(kv) => kv,
            _ => bail!("kv state missing or not a native payload"),
        };
        let next = self.model.decode_step(&mut kv, &state.last_tokens)?;
        state.pos = kv.len;
        state.last_tokens = next.clone();
        state.kv = KvState::Native(kv);
        Ok(next)
    }
}

// ---------------------------------------------------------------------------
// Mock backend (tests/benches)
// ---------------------------------------------------------------------------

/// Deterministic mock: token stream derived from a per-sequence hash of
/// the prompt. Decode latency is zero — batcher behaviour only.
pub struct MockBackend {
    hashes: Vec<u64>,
}

impl MockBackend {
    pub fn new() -> MockBackend {
        MockBackend { hashes: Vec::new() }
    }
}

impl Default for MockBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for MockBackend {
    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Result<DecodeState> {
        self.hashes = prompts
            .iter()
            .map(|p| {
                let mut h = 0xcbf29ce484222325u64;
                for &t in p {
                    h = (h ^ t as u64).wrapping_mul(0x100000001b3);
                }
                h
            })
            .collect();
        let last_tokens = self.hashes.iter().map(|&h| (h % 256) as i32).collect();
        Ok(DecodeState { bucket: prompts.len(), pos: 0, last_tokens, kv: KvState::None })
    }

    fn decode(&mut self, state: &mut DecodeState) -> Result<Vec<i32>> {
        let step = state.pos as u64;
        let next: Vec<i32> = self
            .hashes
            .iter()
            .map(|&h| ((h.rotate_left((step % 63) as u32 + 1) ^ step) % 256) as i32)
            .collect();
        state.pos += 1;
        state.last_tokens = next.clone();
        Ok(next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let mut b1 = MockBackend::new();
        let mut b2 = MockBackend::new();
        let prompts = vec![vec![1, 2, 3], vec![4, 5, 6]];
        let mut s1 = b1.prefill(&prompts).unwrap();
        let mut s2 = b2.prefill(&prompts).unwrap();
        for _ in 0..5 {
            assert_eq!(b1.decode(&mut s1).unwrap(), b2.decode(&mut s2).unwrap());
        }
    }

    #[test]
    fn mock_differs_across_prompts() {
        let mut b = MockBackend::new();
        let mut s = b.prefill(&vec![vec![1], vec![2]]).unwrap();
        let toks = b.decode(&mut s).unwrap();
        assert_ne!(toks[0], toks[1]);
    }

    #[test]
    fn argmax_rows_picks_max() {
        let logits = vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 2), vec![1, 0]);
    }

    #[test]
    fn native_backend_round_trips_through_the_contract() {
        use crate::icquant::IcqConfig;
        use crate::quant::QuantizerKind;
        use crate::store::synth_model;
        use crate::synthzoo::FamilySpec;

        let family = FamilySpec {
            name: "tiny-backend-test",
            d_model: 32,
            d_ff: 64,
            n_blocks: 1,
            tail_frac: 0.02,
            tail_scale: 2.5,
            oproj_hot: 0.5,
            seed: 0xBAC1,
        };
        let cfg = IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::Rtn,
        };
        let model = synth_model(&family, &cfg, None).unwrap();
        let cache = Arc::new(DecodeCache::new(64 << 20));
        let stored = StoredModel::from_model(model, cache, "native-backend");
        let mut b = NativeBackend::from_stored(&stored, 2).unwrap();
        let prompts = vec![vec![72, 105, 32, 116], vec![104, 101, 114, 101]];
        let mut state = b.prefill(&prompts).unwrap();
        assert_eq!(state.bucket, 2);
        assert_eq!(state.pos, 4);
        for step in 0..3 {
            let toks = b.decode(&mut state).unwrap();
            assert_eq!(toks.len(), 2);
            assert_eq!(state.pos, 5 + step);
            assert_eq!(toks, state.last_tokens);
        }
        assert!(matches!(state.kv, KvState::Native(_)));
    }
}

//! Model-executor abstraction for the serving loop.
//!
//! The contract is **slot-level** (DESIGN.md §9): a [`DecodeState`]
//! owns `cap` KV slots; the scheduler prefills single requests into
//! free slots ([`Backend::prefill_into`]), decodes whatever subset is
//! active, and retires slots the moment their sequence finishes
//! ([`Backend::retire`]). Backends that execute compiled fixed-bucket
//! graphs — [`PjrtBackend`] — cannot splice one sequence's KV into a
//! live batch literal, so they report
//! [`admits_mid_decode`](Backend::admits_mid_decode)` == false` and are
//! driven in *waves* through the batch-shaped [`Backend::prefill`]
//! shim: admission happens a whole bucket at a time, retirement only
//! masks the lane (the compiled graph keeps computing it), and
//! responses still leave the moment each lane finishes.
//!
//! [`NativeBackend`] serves the same contract with zero PJRT
//! involvement: the forward runs on the fused quantized-plane kernels
//! ([`crate::kernels`]), weights stay in their (n+1)-bit runtime form,
//! and slot admission/retirement map 1:1 onto the slot-addressed host
//! [`KvCache`]. [`MockBackend`] is a deterministic stand-in for batcher
//! tests; [`SimBackend`] adds a simulated per-slot step cost so benches
//! can compare scheduler policies on one machine.

use crate::kernels::{ActQuant, KvCache, KvCacheStats, KvLayout, NativeModel, Tier, WorkerPool};
use crate::model::TrainedModel;
use crate::trace::{self, Cat};
use crate::runtime::{Engine, HostTensor};
use crate::store::{DecodeCache, StoredModel};
use anyhow::{bail, ensure, Context, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Backend-specific KV-cache payload carried inside [`DecodeState`].
pub enum KvState {
    /// No cache (mock backends, or a state consumed mid-step).
    None,
    /// PJRT k/v literals (whole-bucket granularity).
    Pjrt(xla::Literal, xla::Literal),
    /// Native slot-addressed host cache for the fused-kernel forward.
    Native(KvCache),
}

/// In-flight generation state: `cap` KV slots, each holding at most one
/// sequence. For wave-mode backends `cap` doubles as the compiled
/// bucket size (their `prefill` creates one state per wave).
pub struct DecodeState {
    /// Total KV slots this state owns.
    pub cap: usize,
    /// Slot occupancy, maintained by `prefill_into`/`retire`.
    pub active: Vec<bool>,
    /// Per-slot sequence position (backend-interpreted: KV length for
    /// model backends, decode-step counter for mocks).
    pub pos: Vec<usize>,
    /// Last emitted token per slot (input to the next decode step).
    pub last_tokens: Vec<i32>,
    /// Backend-specific cache payload.
    pub kv: KvState,
}

impl DecodeState {
    /// An empty state with every slot free and no cache payload.
    pub fn empty(cap: usize) -> DecodeState {
        DecodeState {
            cap,
            active: vec![false; cap],
            pos: vec![0; cap],
            last_tokens: vec![0; cap],
            kv: KvState::None,
        }
    }

    /// Occupied slot count.
    pub fn n_active(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Ascending indices of occupied slots.
    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.cap).filter(|&i| self.active[i]).collect()
    }

    /// Lowest free slot, if any.
    pub fn first_free(&self) -> Option<usize> {
        (0..self.cap).find(|&i| !self.active[i])
    }
}

/// Greedy per-row argmax over a flat `(rows × c)` logits buffer.
pub fn argmax_rows(logits: &[f32], rows: usize) -> Vec<i32> {
    let cols = logits.len() / rows;
    (0..rows)
        .map(|r| {
            let row = &logits[r * cols..(r + 1) * cols];
            let mut best = (f32::NEG_INFINITY, 0usize);
            for (i, &v) in row.iter().enumerate() {
                if v > best.0 {
                    best = (v, i);
                }
            }
            best.1 as i32
        })
        .collect()
}

/// The serving contract: slot-level prefill/decode/retire, with a
/// batch-shaped [`prefill`](Backend::prefill) entry point for wave-mode
/// executors and benches.
///
/// Deliberately *not* `Send`: PJRT handles are thread-local, so the
/// backend is constructed inside the worker thread (the factory closure
/// is what crosses the thread boundary — see [`super::Server::start`]).
pub trait Backend {
    /// Create an empty decode state owning `cap` KV slots.
    fn new_state(&mut self, cap: usize) -> Result<DecodeState>;

    /// Run the prompt pass for one sequence into free slot `slot`:
    /// primes `last_tokens[slot]` with the first greedily sampled token
    /// and marks the slot active. Callable while other slots are
    /// mid-decode iff [`admits_mid_decode`](Backend::admits_mid_decode).
    fn prefill_into(
        &mut self,
        state: &mut DecodeState,
        slot: usize,
        prompt: &[i32],
    ) -> Result<()>;

    /// Admit several sequences in one backend call; each `(slot,
    /// prompt)` pair lands in a free slot. The default loops
    /// [`Backend::prefill_into`]; model backends override it to share
    /// one pass over the weights across the whole admission round
    /// (admission is memory-bound, like everything else here).
    fn prefill_into_many(
        &mut self,
        state: &mut DecodeState,
        admissions: &[(usize, Vec<i32>)],
    ) -> Result<()> {
        for (slot, prompt) in admissions {
            self.prefill_into(state, *slot, prompt)?;
        }
        Ok(())
    }

    /// One greedy decode step over the active slots: returns a
    /// `cap`-length token vec (inactive entries are unspecified) and
    /// advances the state. Slot backends only spend kernel time on
    /// active slots.
    fn decode(&mut self, state: &mut DecodeState) -> Result<Vec<i32>>;

    /// Release `slot` for reuse. The default masks the lane and resets
    /// its position, which suits stateless mocks; model backends also
    /// free their cache lane.
    ///
    /// Cancellation rides on this same path (DESIGN.md §15): when a
    /// streaming client disconnects mid-decode, the scheduler retires
    /// the slot immediately, so implementations must tolerate being
    /// called on a sequence that has not reached its target length and
    /// must release every resource (KV blocks, reservations) it holds.
    fn retire(&mut self, state: &mut DecodeState, slot: usize) -> Result<()> {
        ensure!(slot < state.cap, "retire: slot {} out of range", slot);
        state.active[slot] = false;
        state.pos[slot] = 0;
        Ok(())
    }

    /// Whether `prefill_into` may target a free slot while other slots
    /// are mid-decode. Compiled fixed-bucket executors return `false`
    /// and are scheduled in waves.
    fn admits_mid_decode(&self) -> bool {
        true
    }

    /// Vocabulary size, when the backend knows it — used by the worker
    /// to clamp the configured pad token into range.
    fn vocab(&self) -> Option<usize> {
        None
    }

    /// Highest KV position a slot can reach, when the backend's cache
    /// is bounded. The scheduler clamps each request's token target to
    /// its slot's remaining headroom at admission, so one over-long
    /// request runs out of room quietly (short response) instead of
    /// erroring the whole batch mid-decode.
    fn max_positions(&self) -> Option<usize> {
        None
    }

    /// Paged-cache admission headroom: `(allocatable blocks, tokens per
    /// block)`. Backends with a paged KV cache (DESIGN.md §10) report
    /// how many blocks an admission round can draw on — free-list
    /// blocks plus evictable prefix-registry blocks — so the scheduler
    /// admits on **free blocks**, not free slots. `None` keeps the
    /// slot-only admission of mocks and wave-mode executors.
    fn kv_block_headroom(&self, state: &DecodeState) -> Option<(usize, usize)> {
        let _ = state;
        None
    }

    /// Blocks admitting this (already prefill-normalized) prompt would
    /// newly allocate, consulting any prefix-sharing state — so the
    /// admission gate charges shared-prefix requests what they really
    /// cost instead of worst-case prompt blocks. `None` falls back to
    /// the gate's worst-case estimate.
    fn admission_block_need(&self, state: &DecodeState, prompt: &[i32]) -> Option<usize> {
        let _ = (state, prompt);
        None
    }

    /// Reserve up to `want` future decode tokens of KV capacity for
    /// `slot`, returning how many are **guaranteed**. The scheduler
    /// clamps each request's token target to this at admission, so an
    /// overcommitted paged pool ends an over-long request early (short
    /// response) instead of exhausting mid-decode and erroring its
    /// whole batch. The default guarantees everything (unbounded or
    /// per-slot-provisioned caches).
    fn reserve_tokens(&mut self, state: &mut DecodeState, slot: usize, want: usize) -> usize {
        let _ = (state, slot);
        want
    }

    /// Point-in-time paged-cache counters (prefix hits, block
    /// occupancy, evictions, CoW forks), when the backend has a paged
    /// cache — surfaced into serving [`Metrics`](super::metrics::Metrics).
    fn kv_cache_stats(&self, state: &DecodeState) -> Option<KvCacheStats> {
        let _ = state;
        None
    }

    /// Batch prefill: a state with one slot per prompt, all prefilled.
    /// Wave-mode backends override this with their compiled batch entry.
    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Result<DecodeState> {
        ensure!(!prompts.is_empty(), "empty batch");
        let mut state = self.new_state(prompts.len())?;
        for (slot, p) in prompts.iter().enumerate() {
            self.prefill_into(&mut state, slot, p)?;
        }
        Ok(state)
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// Real backend: compiled prefill_b{B}/decode_b{B} entries + weights.
///
/// Weights are uploaded to the device **once** at construction
/// (`upload_all`) and borrowed by every prefill/decode call — the
/// coordinator never re-copies the model (§Perf: 4.5× faster decode
/// steps vs the literal path).
///
/// The compiled HLO fixes both the bucket size and the KV layout, so
/// this backend cannot splice one new sequence into a live batch: it
/// admits whole waves via [`Backend::prefill`] and its
/// [`Backend::retire`] only masks the lane (the graph keeps computing
/// it — exactly what the pre-slot scheduler did, minus the delayed
/// responses).
pub struct PjrtBackend {
    engine: Engine,
    weights: Vec<crate::runtime::ResidentBuffer>,
    max_seq: usize,
    vocab: usize,
    prefill_len: usize,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: &std::path::Path, model: &TrainedModel) -> Result<PjrtBackend> {
        let engine = Engine::new(artifacts_dir)?;
        let weight_lits = crate::eval::weight_literals(model)?;
        let weights = engine.upload_all(weight_lits)?;
        let prefill_len = engine.manifest().prefill_len;
        Ok(PjrtBackend {
            engine,
            weights,
            max_seq: model.config.max_seq,
            vocab: model.config.vocab,
            prefill_len,
        })
    }

    /// Serve straight from an `ICQZ` container: quantized layers are
    /// decoded through the shared LRU cache (one decode per layer even
    /// across backend restarts within the cache's budget), assembled
    /// into the positional weight ABI, and uploaded once.
    pub fn from_container(
        artifacts_dir: &std::path::Path,
        container: &std::path::Path,
        cache: Arc<DecodeCache>,
    ) -> Result<PjrtBackend> {
        let stored = StoredModel::open(container, cache)
            .with_context(|| format!("open container {}", container.display()))?;
        let model = stored.to_trained_model()?;
        Self::new(artifacts_dir, &model)
    }

    /// Pre-compile all serving buckets (avoids first-request latency).
    pub fn warmup(&mut self) -> Result<()> {
        for b in self.engine.manifest().buckets.clone() {
            self.engine.prepare(&format!("prefill_b{}", b))?;
            self.engine.prepare(&format!("decode_b{}", b))?;
        }
        Ok(())
    }
}

impl Backend for PjrtBackend {
    fn new_state(&mut self, _cap: usize) -> Result<DecodeState> {
        bail!("PjrtBackend admits at wave granularity; use prefill()")
    }

    fn prefill_into(
        &mut self,
        _state: &mut DecodeState,
        _slot: usize,
        _prompt: &[i32],
    ) -> Result<()> {
        bail!("PjrtBackend cannot splice a sequence into compiled batch KV")
    }

    fn admits_mid_decode(&self) -> bool {
        false
    }

    fn vocab(&self) -> Option<usize> {
        Some(self.vocab)
    }

    fn max_positions(&self) -> Option<usize> {
        Some(self.max_seq)
    }

    fn retire(&mut self, state: &mut DecodeState, slot: usize) -> Result<()> {
        ensure!(slot < state.cap, "retire: slot {} out of range", slot);
        // Mask only: the compiled graph still computes the lane, and the
        // wave-uniform position must not be disturbed.
        state.active[slot] = false;
        Ok(())
    }

    fn prefill(&mut self, prompts: &[Vec<i32>]) -> Result<DecodeState> {
        let bucket = prompts.len();
        let _sp = trace::span_args(Cat::Sched, "backend_prefill", 0, bucket as i64, 0);
        let entry = format!("prefill_b{}", bucket);
        self.engine.prepare(&entry)?; // compile before async uploads
        let s = self.prefill_len;
        let mut toks = Vec::with_capacity(bucket * s);
        for p in prompts {
            anyhow::ensure!(p.len() == s, "prompt not normalized to {}", s);
            toks.extend_from_slice(p);
        }
        let data = [self
            .engine
            .upload(HostTensor::I32(toks, vec![bucket, s]).to_literal()?)?];
        let args: Vec<&crate::runtime::ResidentBuffer> = data.iter().chain(self.weights.iter()).collect();
        let mut out = self.engine.execute_buffers(&entry, &args)?;
        anyhow::ensure!(out.len() == 3, "prefill returns (logits, k, v)");
        let v = out.pop().context("v")?;
        let k = out.pop().context("k")?;
        let logits = Engine::literal_f32(&out[0])?;
        Ok(DecodeState {
            cap: bucket,
            active: vec![true; bucket],
            pos: vec![s; bucket],
            last_tokens: argmax_rows(&logits, bucket),
            kv: KvState::Pjrt(k, v),
        })
    }

    fn decode(&mut self, state: &mut DecodeState) -> Result<Vec<i32>> {
        let _sp = trace::span_args(Cat::Sched, "backend_decode", 0, state.cap as i64, 0);
        // Wave-uniform position: every lane advanced together since the
        // shared prefill.
        anyhow::ensure!(state.pos[0] < self.max_seq, "KV cache exhausted");
        // `cap` is the wave's compiled bucket size (set by prefill).
        let entry = format!("decode_b{}", state.cap);
        self.engine.prepare(&entry)?; // compile before async uploads
        let (k, v) = match std::mem::replace(&mut state.kv, KvState::None) {
            KvState::Pjrt(k, v) => (k, v),
            _ => bail!("kv state missing or not a PJRT payload"),
        };
        let data = [
            self.engine.upload(
                HostTensor::I32(state.last_tokens.clone(), vec![state.cap])
                    .to_literal()?,
            )?,
            self.engine
                .upload(HostTensor::scalar_i32(state.pos[0] as i32).to_literal()?)?,
            self.engine.upload(k)?,
            self.engine.upload(v)?,
        ];
        let args: Vec<&crate::runtime::ResidentBuffer> =
            data.iter().chain(self.weights.iter()).collect();
        let mut out = self.engine.execute_buffers(&entry, &args)?;
        anyhow::ensure!(out.len() == 3, "decode returns (logits, k, v)");
        let nv = out.pop().context("v")?;
        let nk = out.pop().context("k")?;
        let logits = Engine::literal_f32(&out[0])?;
        let next = argmax_rows(&logits, state.cap);
        state.last_tokens = next.clone();
        state.kv = KvState::Pjrt(nk, nv);
        for p in state.pos.iter_mut() {
            *p += 1;
        }
        // The emitted token is the one the *previous* position predicted;
        // greedy generation returns it directly.
        Ok(next)
    }
}

// ---------------------------------------------------------------------------
// Native fused-kernel backend
// ---------------------------------------------------------------------------

/// CPU backend serving straight off the bit-packed quantized runtime
/// planes: every projection is a fused unpack+gather+accumulate GEMM
/// ([`crate::kernels::gemm_on`]) dispatched onto the model's persistent
/// [`WorkerPool`] — no f32 weight plane, no per-token thread spawn, no
/// PJRT, no Python at request time. Selected with
/// `serve --backend=native`.
///
/// Slot operations map directly onto the **paged** host [`KvCache`]
/// (DESIGN.md §10): admission prefills into a freed lane (reusing any
/// registered shared-prefix blocks), decode runs the fused kernels over
/// the active lanes only, and retirement decrements block refcounts and
/// returns exclusive blocks to the free list.
pub struct NativeBackend {
    model: NativeModel,
    layout: KvLayout,
}

impl NativeBackend {
    pub fn new(model: NativeModel) -> NativeBackend {
        NativeBackend { model, layout: KvLayout::default() }
    }

    /// Override the paged-cache layout (block size, pool size, prefix
    /// sharing) used for every state this backend creates.
    pub fn with_kv_layout(mut self, layout: KvLayout) -> NativeBackend {
        self.layout = layout;
        self
    }

    /// Enable (or disable, with `None`) in-place ICQ quantization of
    /// filled KV blocks at `bits` bits per value (DESIGN.md §12).
    /// Shorthand for rewriting `kv_bits` on the current layout.
    pub fn with_kv_quant(mut self, bits: Option<u32>) -> NativeBackend {
        self.layout.kv_bits = bits;
        self
    }

    /// Pin the SIMD kernel tier (DESIGN.md §14) for the model's fused
    /// kernels and for every paged cache this backend creates.
    pub fn with_simd(mut self, tier: Tier) -> NativeBackend {
        self.model.set_simd(tier);
        self
    }

    /// Select the activation-quantization mode for decode projections
    /// (`ActQuant::Int8` routes single-token GEMVs through the integer
    /// inner product; DESIGN.md §14).
    pub fn with_act_quant(mut self, act: ActQuant) -> NativeBackend {
        self.model.set_act_quant(act);
        self
    }

    /// The paged-cache layout new decode states are built with.
    pub fn kv_layout(&self) -> KvLayout {
        self.layout
    }

    /// Build from an opened container, pulling every projection through
    /// the store's shared runtime-plane cache. `threads` sizes the
    /// model's persistent kernel pool (0 ⇒ all cores); the pool is
    /// spawned here, once — the decode loop only enqueues onto it.
    pub fn from_stored(stored: &StoredModel, threads: usize) -> Result<NativeBackend> {
        Ok(NativeBackend::new(NativeModel::from_stored(stored, threads)?))
    }

    /// [`Self::from_stored`] dispatching onto an existing kernel pool —
    /// lets several backends (or backend restarts) share one set of
    /// parked workers instead of spawning per construction.
    pub fn from_stored_with_pool(
        stored: &StoredModel,
        pool: Arc<WorkerPool>,
    ) -> Result<NativeBackend> {
        Ok(NativeBackend::new(NativeModel::from_stored_with_pool(stored, pool)?))
    }

    /// Open an `ICQZ` container and build the native backend from it.
    pub fn from_container(
        container: &std::path::Path,
        cache: Arc<DecodeCache>,
        threads: usize,
    ) -> Result<NativeBackend> {
        let stored = StoredModel::open(container, cache)
            .with_context(|| format!("open container {}", container.display()))?;
        Self::from_stored(&stored, threads)
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }
}

impl Backend for NativeBackend {
    fn new_state(&mut self, cap: usize) -> Result<DecodeState> {
        ensure!(cap > 0, "state needs at least one slot");
        let mut state = DecodeState::empty(cap);
        let mut kv = KvCache::with_layout(&self.model.config, cap, self.layout);
        // The cache's dequant fill must run on the same tier the model
        // resolved (a `--simd` override outranks `ICQ_SIMD`).
        kv.set_simd(self.model.simd_tier());
        state.kv = KvState::Native(kv);
        Ok(state)
    }

    fn prefill_into(
        &mut self,
        state: &mut DecodeState,
        slot: usize,
        prompt: &[i32],
    ) -> Result<()> {
        ensure!(slot < state.cap, "prefill_into: slot {} out of range", slot);
        ensure!(!state.active[slot], "prefill_into: slot {} is occupied", slot);
        let kv = match &mut state.kv {
            KvState::Native(kv) => kv,
            _ => bail!("kv state is not a native payload"),
        };
        let first = self.model.prefill_slot(kv, slot, prompt)?;
        state.last_tokens[slot] = first;
        state.pos[slot] = kv.pos(slot);
        state.active[slot] = true;
        Ok(())
    }

    fn prefill_into_many(
        &mut self,
        state: &mut DecodeState,
        admissions: &[(usize, Vec<i32>)],
    ) -> Result<()> {
        let (first, rest) = match admissions.split_first() {
            Some(parts) => parts,
            None => return Ok(()),
        };
        let _sp =
            trace::span_args(Cat::Sched, "backend_prefill", 0, admissions.len() as i64, 0);
        let seq = first.1.len();
        // Mixed prompt lengths (possible only for direct trait users —
        // the scheduler normalizes to prefill_len) fall back to
        // per-slot passes.
        if rest.iter().any(|(_, p)| p.len() != seq) {
            for (slot, prompt) in admissions {
                self.prefill_into(state, *slot, prompt)?;
            }
            return Ok(());
        }
        for &(slot, _) in admissions {
            ensure!(slot < state.cap, "prefill_into_many: slot {} out of range", slot);
            ensure!(!state.active[slot], "prefill_into_many: slot {} is occupied", slot);
        }
        let mut kv = match std::mem::replace(&mut state.kv, KvState::None) {
            KvState::Native(kv) => kv,
            _ => bail!("kv state missing or not a native payload"),
        };
        let slots: Vec<usize> = admissions.iter().map(|&(s, _)| s).collect();
        let mut tokens = Vec::with_capacity(slots.len() * seq);
        for (_, p) in admissions {
            tokens.extend_from_slice(p);
        }
        // One forward pass decodes each weight block once for every
        // admitted lane.
        let firsts = self.model.prefill_slots(&mut kv, &slots, &tokens, seq);
        state.kv = KvState::Native(kv);
        let firsts = firsts?;
        if let KvState::Native(kv) = &state.kv {
            for (i, &slot) in slots.iter().enumerate() {
                state.last_tokens[slot] = firsts[i];
                state.pos[slot] = kv.pos(slot);
                state.active[slot] = true;
            }
        }
        Ok(())
    }

    fn vocab(&self) -> Option<usize> {
        Some(self.model.config.vocab)
    }

    fn max_positions(&self) -> Option<usize> {
        Some(self.model.config.max_seq)
    }

    fn kv_block_headroom(&self, state: &DecodeState) -> Option<(usize, usize)> {
        match &state.kv {
            KvState::Native(kv) => Some((kv.admission_free_blocks(), kv.block_tokens())),
            _ => None,
        }
    }

    fn admission_block_need(&self, state: &DecodeState, prompt: &[i32]) -> Option<usize> {
        match &state.kv {
            KvState::Native(kv) => Some(kv.admission_block_need(prompt)),
            _ => None,
        }
    }

    fn reserve_tokens(&mut self, state: &mut DecodeState, slot: usize, want: usize) -> usize {
        match &mut state.kv {
            KvState::Native(kv) => kv.reserve(slot, want),
            _ => want,
        }
    }

    fn kv_cache_stats(&self, state: &DecodeState) -> Option<KvCacheStats> {
        match &state.kv {
            KvState::Native(kv) => Some(kv.stats()),
            _ => None,
        }
    }

    fn retire(&mut self, state: &mut DecodeState, slot: usize) -> Result<()> {
        ensure!(slot < state.cap, "retire: slot {} out of range", slot);
        state.active[slot] = false;
        state.pos[slot] = 0;
        if let KvState::Native(kv) = &mut state.kv {
            kv.free_slot(slot);
        }
        Ok(())
    }

    fn decode(&mut self, state: &mut DecodeState) -> Result<Vec<i32>> {
        let slots = state.active_slots();
        ensure!(!slots.is_empty(), "decode with no active slots");
        let _sp = trace::span_args(Cat::Sched, "backend_decode", 0, slots.len() as i64, 0);
        trace::instant(
            Cat::Sched,
            "kernel_dispatch",
            0,
            self.model.simd_tier().id() as i64,
            (self.model.act_quant() == ActQuant::Int8) as i64,
        );
        let mut kv = match std::mem::replace(&mut state.kv, KvState::None) {
            KvState::Native(kv) => kv,
            _ => bail!("kv state missing or not a native payload"),
        };
        let lasts: Vec<i32> = slots.iter().map(|&s| state.last_tokens[s]).collect();
        let step = self.model.decode_slots(&mut kv, &lasts, &slots);
        // Restore the cache even on error so the state stays usable.
        let next = match step {
            Ok(n) => n,
            Err(e) => {
                state.kv = KvState::Native(kv);
                return Err(e);
            }
        };
        let mut out = vec![0i32; state.cap];
        for (i, &slot) in slots.iter().enumerate() {
            out[slot] = next[i];
            state.last_tokens[slot] = next[i];
            state.pos[slot] = kv.pos(slot);
        }
        state.kv = KvState::Native(kv);
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Mock backends (tests/benches)
// ---------------------------------------------------------------------------

/// FNV-style hash of a (normalized) prompt — the seed of a mock token
/// stream.
fn mock_hash(prompt: &[i32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &t in prompt {
        h = (h ^ t as u64).wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic token for decode step `step` of stream `h`.
fn mock_token(h: u64, step: u64) -> i32 {
    ((h.rotate_left((step % 63) as u32 + 1) ^ step) % 256) as i32
}

/// Deterministic mock: token stream derived from a per-slot hash of the
/// prompt, advanced by a per-slot step counter — so a sequence's stream
/// does not depend on when it was admitted or who its batchmates are,
/// exactly like the real backends. Decode latency is zero — scheduler
/// behaviour only. One in-flight [`DecodeState`] at a time.
pub struct MockBackend {
    hashes: Vec<u64>,
}

impl MockBackend {
    pub fn new() -> MockBackend {
        MockBackend { hashes: Vec::new() }
    }
}

impl Default for MockBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for MockBackend {
    fn new_state(&mut self, cap: usize) -> Result<DecodeState> {
        ensure!(cap > 0, "state needs at least one slot");
        self.hashes = vec![0; cap];
        Ok(DecodeState::empty(cap))
    }

    fn prefill_into(
        &mut self,
        state: &mut DecodeState,
        slot: usize,
        prompt: &[i32],
    ) -> Result<()> {
        ensure!(slot < state.cap, "prefill_into: slot {} out of range", slot);
        ensure!(!state.active[slot], "prefill_into: slot {} is occupied", slot);
        let h = mock_hash(prompt);
        self.hashes[slot] = h;
        state.last_tokens[slot] = (h % 256) as i32;
        state.pos[slot] = 0; // decode-step counter for mock streams
        state.active[slot] = true;
        Ok(())
    }

    fn vocab(&self) -> Option<usize> {
        Some(256)
    }

    fn decode(&mut self, state: &mut DecodeState) -> Result<Vec<i32>> {
        let mut out = vec![0i32; state.cap];
        for slot in 0..state.cap {
            if !state.active[slot] {
                continue;
            }
            let t = mock_token(self.hashes[slot], state.pos[slot] as u64);
            out[slot] = t;
            state.last_tokens[slot] = t;
            state.pos[slot] += 1;
        }
        Ok(out)
    }
}

/// [`MockBackend`] streams plus a simulated compute cost: each decode
/// step busy-waits `step_cost` per **active** slot, each slot prefill
/// busy-waits `prefill_cost`. This makes scheduler-policy differences
/// measurable on one machine — a run-to-completion wave keeps paying
/// for finished and padding lanes, the continuous scheduler does not —
/// while keeping token streams bit-identical to [`MockBackend`].
pub struct SimBackend {
    inner: MockBackend,
    prefill_cost: Duration,
    step_cost: Duration,
}

impl SimBackend {
    pub fn new(prefill_cost: Duration, step_cost_per_slot: Duration) -> SimBackend {
        SimBackend { inner: MockBackend::new(), prefill_cost, step_cost: step_cost_per_slot }
    }
}

/// Spin (not sleep) so simulated kernel time has microsecond resolution.
fn busy_wait(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

impl Backend for SimBackend {
    fn new_state(&mut self, cap: usize) -> Result<DecodeState> {
        self.inner.new_state(cap)
    }

    fn prefill_into(
        &mut self,
        state: &mut DecodeState,
        slot: usize,
        prompt: &[i32],
    ) -> Result<()> {
        busy_wait(self.prefill_cost);
        self.inner.prefill_into(state, slot, prompt)
    }

    fn vocab(&self) -> Option<usize> {
        self.inner.vocab()
    }

    fn decode(&mut self, state: &mut DecodeState) -> Result<Vec<i32>> {
        busy_wait(self.step_cost * state.n_active() as u32);
        self.inner.decode(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let mut b1 = MockBackend::new();
        let mut b2 = MockBackend::new();
        let prompts = vec![vec![1, 2, 3], vec![4, 5, 6]];
        let mut s1 = b1.prefill(&prompts).unwrap();
        let mut s2 = b2.prefill(&prompts).unwrap();
        for _ in 0..5 {
            assert_eq!(b1.decode(&mut s1).unwrap(), b2.decode(&mut s2).unwrap());
        }
    }

    #[test]
    fn mock_differs_across_prompts() {
        let mut b = MockBackend::new();
        let mut s = b.prefill(&vec![vec![1], vec![2]]).unwrap();
        let toks = b.decode(&mut s).unwrap();
        assert_ne!(toks[0], toks[1]);
    }

    #[test]
    fn mock_stream_is_admission_time_invariant() {
        // A prompt admitted into a freed slot mid-flight yields the same
        // stream as the same prompt in a fresh uniform batch.
        let mut b1 = MockBackend::new();
        let mut s1 = b1.prefill(&[vec![9, 9, 9]]).unwrap();
        let reference: Vec<i32> =
            (0..4).map(|_| b1.decode(&mut s1).unwrap()[0]).collect();

        let mut b2 = MockBackend::new();
        let mut s2 = b2.new_state(2).unwrap();
        b2.prefill_into(&mut s2, 0, &[1, 2, 3]).unwrap();
        for _ in 0..3 {
            b2.decode(&mut s2).unwrap();
        }
        b2.prefill_into(&mut s2, 1, &[9, 9, 9]).unwrap();
        let got: Vec<i32> = (0..4).map(|_| b2.decode(&mut s2).unwrap()[1]).collect();
        assert_eq!(got, reference);
    }

    #[test]
    fn retire_frees_and_prefill_into_reuses_slot() {
        let mut b = MockBackend::new();
        let mut s = b.prefill(&[vec![5], vec![6]]).unwrap();
        b.decode(&mut s).unwrap();
        b.retire(&mut s, 0).unwrap();
        assert!(!s.active[0]);
        assert_eq!(s.n_active(), 1);
        assert_eq!(s.first_free(), Some(0));
        b.prefill_into(&mut s, 0, &[7]).unwrap();
        assert_eq!(s.n_active(), 2);
        // Occupied slot rejects admission.
        assert!(b.prefill_into(&mut s, 1, &[8]).is_err());
    }

    #[test]
    fn sim_backend_matches_mock_streams() {
        let mut mock = MockBackend::new();
        let mut sim =
            SimBackend::new(Duration::from_micros(10), Duration::from_micros(10));
        let prompts = vec![vec![1, 2], vec![3, 4]];
        let mut sm = mock.prefill(&prompts).unwrap();
        let mut ss = sim.prefill(&prompts).unwrap();
        for _ in 0..4 {
            assert_eq!(mock.decode(&mut sm).unwrap(), sim.decode(&mut ss).unwrap());
        }
    }

    #[test]
    fn argmax_rows_picks_max() {
        let logits = vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 2), vec![1, 0]);
    }

    #[test]
    fn native_backend_round_trips_through_the_contract() {
        use crate::icquant::IcqConfig;
        use crate::quant::QuantizerKind;
        use crate::store::synth_model;
        use crate::synthzoo::FamilySpec;

        let family = FamilySpec {
            name: "tiny-backend-test",
            d_model: 32,
            d_ff: 64,
            n_blocks: 1,
            tail_frac: 0.02,
            tail_scale: 2.5,
            oproj_hot: 0.5,
            seed: 0xBAC1,
        };
        let cfg = IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::Rtn,
        };
        let model = synth_model(&family, &cfg, None).unwrap();
        let cache = Arc::new(DecodeCache::new(64 << 20));
        let stored = StoredModel::from_model(model, cache, "native-backend");
        let mut b = NativeBackend::from_stored(&stored, 2).unwrap();
        let prompts = vec![vec![72, 105, 32, 116], vec![104, 101, 114, 101]];
        let mut state = b.prefill(&prompts).unwrap();
        assert_eq!(state.cap, 2);
        assert_eq!(state.pos, vec![4, 4]);
        assert_eq!(state.n_active(), 2);
        for step in 0..3 {
            let toks = b.decode(&mut state).unwrap();
            assert_eq!(toks.len(), 2);
            assert_eq!(state.pos, vec![5 + step, 5 + step]);
            assert_eq!(toks, state.last_tokens);
        }
        assert!(matches!(state.kv, KvState::Native(_)));

        // Slot lifecycle on the same state: retire one lane, decode the
        // survivor alone, admit a new sequence into the freed lane.
        b.retire(&mut state, 0).unwrap();
        assert_eq!(state.n_active(), 1);
        let toks = b.decode(&mut state).unwrap();
        assert_eq!(state.active_slots(), vec![1]);
        assert_eq!(toks[1], state.last_tokens[1]);
        b.prefill_into(&mut state, 0, &[65, 66, 67]).unwrap();
        assert_eq!(state.n_active(), 2);
        assert_eq!(state.pos[0], 3);
        let toks = b.decode(&mut state).unwrap();
        assert_eq!(toks.len(), 2);
    }

    /// The continuous slot path must reproduce the uniform batch path
    /// token-for-token on the native backend.
    #[test]
    fn native_slot_scheduling_is_stream_invariant() {
        use crate::icquant::IcqConfig;
        use crate::quant::QuantizerKind;
        use crate::store::synth_model;
        use crate::synthzoo::FamilySpec;

        let family = FamilySpec {
            name: "tiny-backend-inv",
            d_model: 32,
            d_ff: 64,
            n_blocks: 1,
            tail_frac: 0.02,
            tail_scale: 2.5,
            oproj_hot: 0.5,
            seed: 0xBAC2,
        };
        let cfg = IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::Rtn,
        };
        let model = synth_model(&family, &cfg, None).unwrap();
        let cache = Arc::new(DecodeCache::new(64 << 20));
        let stored = StoredModel::from_model(model, cache, "native-inv");
        let mut b = NativeBackend::from_stored(&stored, 2).unwrap();
        let prompt = vec![10, 20, 30, 40];

        let mut state = b.prefill(&[prompt.clone()]).unwrap();
        let reference: Vec<i32> =
            (0..4).map(|_| b.decode(&mut state).unwrap()[0]).collect();

        // Same prompt admitted into slot 1 while slot 0 is mid-flight.
        let mut state = b.new_state(2).unwrap();
        b.prefill_into(&mut state, 0, &[99, 98, 97, 96, 95]).unwrap();
        b.decode(&mut state).unwrap();
        b.decode(&mut state).unwrap();
        b.prefill_into(&mut state, 1, &prompt).unwrap();
        let got: Vec<i32> = (0..4).map(|_| b.decode(&mut state).unwrap()[1]).collect();
        assert_eq!(got, reference);

        // Batched admission (one weight pass for the round) must match
        // the per-slot path token-for-token.
        let other = vec![7, 6, 5, 4];
        let mut state = b.new_state(2).unwrap();
        b.prefill_into_many(
            &mut state,
            &[(0, prompt.clone()), (1, other.clone())],
        )
        .unwrap();
        assert_eq!(state.n_active(), 2);
        assert_eq!(state.pos, vec![4, 4]);
        let got: Vec<i32> = (0..4).map(|_| b.decode(&mut state).unwrap()[0]).collect();
        assert_eq!(got, reference);
        // Occupied slots reject a batched admission.
        assert!(b.prefill_into_many(&mut state, &[(0, other)]).is_err());
        // KV headroom is reported for the scheduler's target clamp.
        assert_eq!(b.max_positions(), Some(b.model().config.max_seq));
    }

    /// The paged-cache plumbing the scheduler drives: block headroom is
    /// reported, reservations clamp to allocatable headroom, stats
    /// count prefix hits, and retirement returns blocks.
    #[test]
    fn native_backend_reports_paged_headroom_and_stats() {
        use crate::icquant::IcqConfig;
        use crate::kernels::KvLayout;
        use crate::quant::QuantizerKind;
        use crate::store::synth_model;
        use crate::synthzoo::FamilySpec;

        let family = FamilySpec {
            name: "tiny-backend-paged",
            d_model: 32,
            d_ff: 64,
            n_blocks: 1,
            tail_frac: 0.02,
            tail_scale: 2.5,
            oproj_hot: 0.5,
            seed: 0xBAC4,
        };
        let cfg = IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::Rtn,
        };
        let model = synth_model(&family, &cfg, None).unwrap();
        let cache = Arc::new(DecodeCache::new(64 << 20));
        let stored = StoredModel::from_model(model, cache, "native-paged");
        let layout = KvLayout {
            block_tokens: 4,
            total_blocks: Some(6),
            prefix_sharing: true,
            kv_bits: None,
        };
        let mut b = NativeBackend::from_stored(&stored, 1)
            .unwrap()
            .with_kv_layout(layout);
        assert_eq!(b.kv_layout().block_tokens, 4);
        let mut state = b.new_state(2).unwrap();
        assert_eq!(b.kv_block_headroom(&state), Some((6, 4)));
        assert!(b.kv_cache_stats(&state).unwrap().blocks_in_use == 0);

        // Admit an 8-token prompt: 2 blocks used, 4 left.
        let prompt = vec![10, 20, 30, 40, 50, 60, 70, 80];
        b.prefill_into(&mut state, 0, &prompt).unwrap();
        assert_eq!(b.kv_block_headroom(&state), Some((4, 4)));
        // Reservation clamps to the allocatable headroom: 4 blocks ⇒
        // 16 tokens on top of zero slack.
        assert_eq!(b.reserve_tokens(&mut state, 0, 1000), 16);
        assert_eq!(b.kv_block_headroom(&state), Some((0, 4)));

        // An identical prompt cannot be admitted now (no blocks)…
        assert!(b.prefill_into_many(&mut state, &[(1, prompt.clone())]).is_err());
        // …but after retirement the blocks come back (some held only by
        // the prefix registry, which still counts as allocatable).
        b.retire(&mut state, 0).unwrap();
        assert_eq!(b.kv_block_headroom(&state), Some((6, 4)));
        b.prefill_into(&mut state, 1, &prompt).unwrap();
        let stats = b.kv_cache_stats(&state).unwrap();
        assert!(stats.prefix_hit_blocks >= 2, "re-admitted prompt reuses its blocks");
        assert!(stats.blocks_in_use >= 2);
    }

    /// Two backends sharing one kernel pool must produce the same
    /// streams as a backend with its own pool — pooling is invisible to
    /// the outputs, whatever the pool topology.
    #[test]
    fn shared_kernel_pool_is_output_invariant() {
        use crate::icquant::IcqConfig;
        use crate::quant::QuantizerKind;
        use crate::store::synth_model;
        use crate::synthzoo::FamilySpec;

        let family = FamilySpec {
            name: "tiny-backend-pool",
            d_model: 32,
            d_ff: 64,
            n_blocks: 1,
            tail_frac: 0.02,
            tail_scale: 2.5,
            oproj_hot: 0.5,
            seed: 0xBAC3,
        };
        let cfg = IcqConfig {
            bits: 2,
            outlier_ratio: 0.05,
            gap_bits: 6,
            quantizer: QuantizerKind::Rtn,
        };
        let model = synth_model(&family, &cfg, None).unwrap();
        let cache = Arc::new(DecodeCache::new(64 << 20));
        let stored = StoredModel::from_model(model, cache, "native-pool");
        let prompt = vec![11, 22, 33, 44];

        let mut own = NativeBackend::from_stored(&stored, 1).unwrap();
        let mut state = own.prefill(&[prompt.clone()]).unwrap();
        let reference: Vec<i32> =
            (0..4).map(|_| own.decode(&mut state).unwrap()[0]).collect();

        let pool = Arc::new(WorkerPool::new(3));
        for _ in 0..2 {
            let mut b = NativeBackend::from_stored_with_pool(&stored, pool.clone()).unwrap();
            assert_eq!(b.model().threads(), 3);
            let mut state = b.prefill(&[prompt.clone()]).unwrap();
            let got: Vec<i32> =
                (0..4).map(|_| b.decode(&mut state).unwrap()[0]).collect();
            assert_eq!(got, reference);
        }
    }
}

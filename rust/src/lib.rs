//! # ICQuant — Index Coding enables Low-bit LLM Quantization
//!
//! A production-grade reproduction of *ICQuant* (Li, Hanna, Fragouli,
//! Diggavi, 2025): outlier-aware weight-only post-training quantization
//! where outlier **positions** are stored as b-bit gaps with an escape
//! flag, costing ≈0.3 bits/weight instead of the ≈1 bit of a binary mask.
//!
//! The crate is organized as a three-layer stack (see DESIGN.md §1):
//!
//! * **Substrate** — [`util`], [`bitstream`]: PRNG, JSON, f16, special
//!   functions, bit-level packing; [`trace`]: the flight-recorder
//!   tracing + per-stage profiling subsystem the serving stack reports
//!   through. Everything is `std`-only; the offline vendored registry
//!   carries just the `xla` closure.
//! * **Core library** — [`icq`] (the paper's index-coding contribution),
//!   [`quant`] (RTN / weighted K-means / grouping / mixed-precision /
//!   incoherence / VQ / GPTQ-lite baselines), [`icquant`] (the framework
//!   gluing partitioning + coding + dual codebooks into a packed artifact),
//!   [`stats`] (§2 statistics), [`synthzoo`] (synthetic model families).
//! * **System** — [`model`] (weight/sensitivity artifacts), [`store`]
//!   (the `ICQZ` checkpoint container, the content-addressed artifact
//!   registry, and the LRU decode cache holding fused runtime planes),
//!   [`kernels`] (fused quantized-plane CPU GEMV/GEMM + the native
//!   serving forward), [`runtime`] (PJRT executor for AOT-lowered
//!   JAX/Pallas HLO), [`eval`] (perplexity + zero-shot tasks),
//!   [`coordinator`] (dynamic-batching serving stack), [`experiments`]
//!   (one harness per paper table/figure), [`bench`] (timing harness).

pub mod analysis;
pub mod util;
pub mod trace;
pub mod bitstream;
pub mod icq;
pub mod quant;
pub mod icquant;
pub mod stats;
pub mod synthzoo;
pub mod model;
pub mod store;
pub mod kernels;
pub mod runtime;
pub mod eval;
pub mod coordinator;
pub mod experiments;
pub mod bench;
pub mod cli;

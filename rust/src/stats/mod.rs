//! Statistics of outliers (paper §2, Appendix B/C): range analysis,
//! positional uniformity via the chi-square goodness-of-fit test, and
//! group-frequency histograms.

use crate::quant::mixed_precision::top_k_by_magnitude;
use crate::util::math::{chi2_critical, chi2_sf};
use crate::util::tensor::Matrix;

/// Fraction of a row's value range consumed by its top-`frac` outliers:
/// `1 − range(inliers)/range(all)` (Fig 1a's y-axis).
pub fn range_taken_by_outliers(row: &[f32], frac: f64) -> f64 {
    let k = ((frac * row.len() as f64).floor() as usize).min(row.len());
    if k == 0 {
        return 0.0;
    }
    let out = top_k_by_magnitude(row, k);
    let mut mask = vec![false; row.len()];
    for &c in &out {
        mask[c] = true;
    }
    let (mut flo, mut fhi) = (f32::INFINITY, f32::NEG_INFINITY);
    let (mut ilo, mut ihi) = (f32::INFINITY, f32::NEG_INFINITY);
    for (c, &v) in row.iter().enumerate() {
        flo = flo.min(v);
        fhi = fhi.max(v);
        if !mask[c] {
            ilo = ilo.min(v);
            ihi = ihi.max(v);
        }
    }
    let full = (fhi - flo) as f64;
    let inner = (ihi - ilo) as f64;
    if full <= 0.0 {
        0.0
    } else {
        (1.0 - inner / full).clamp(0.0, 1.0)
    }
}

/// Average of [`range_taken_by_outliers`] over the rows of a matrix.
pub fn avg_range_taken(w: &Matrix, frac: f64) -> f64 {
    (0..w.rows)
        .map(|r| range_taken_by_outliers(w.row(r), frac))
        .sum::<f64>()
        / w.rows as f64
}

/// Outlier counts per group of `group_size` consecutive columns (Fig 2).
pub fn group_frequency(positions: &[usize], cols: usize, group_size: usize) -> Vec<usize> {
    let n_groups = cols.div_ceil(group_size);
    let mut counts = vec![0usize; n_groups];
    for &p in positions {
        counts[p / group_size] += 1;
    }
    counts
}

/// Result of a chi-square uniformity test on one row's outlier positions.
#[derive(Clone, Copy, Debug)]
pub struct Chi2Result {
    pub statistic: f64,
    pub dof: f64,
    pub p_value: f64,
    pub reject: bool,
}

/// Pearson chi-square goodness-of-fit of outlier positions against the
/// uniform distribution, over groups of `group_size` columns (paper
/// Appendix C.1: group_size 256, α = 0.05).
pub fn chi2_uniformity(
    positions: &[usize],
    cols: usize,
    group_size: usize,
    alpha: f64,
) -> Chi2Result {
    let counts = group_frequency(positions, cols, group_size);
    // Only full groups participate (the paper divides rows into
    // non-overlapping groups of 256; widths are multiples in practice).
    let n_full = cols / group_size;
    let total: usize = counts.iter().take(n_full).sum();
    let expected = total as f64 / n_full as f64;
    let mut stat = 0.0;
    for &c in counts.iter().take(n_full) {
        let d = c as f64 - expected;
        stat += d * d / expected.max(1e-12);
    }
    let dof = (n_full - 1) as f64;
    let p = chi2_sf(stat, dof);
    Chi2Result { statistic: stat, dof, p_value: p, reject: p < alpha }
}

/// Rejection rate over all rows of a weight matrix at outlier ratio γ
/// (the Table 1/Table 5 cell).
pub fn rejection_rate(w: &Matrix, gamma: f64, group_size: usize, alpha: f64) -> f64 {
    let k = ((gamma * w.cols as f64).floor() as usize).min(w.cols);
    let mut rejected = 0usize;
    for r in 0..w.rows {
        let positions = top_k_by_magnitude(w.row(r), k);
        if chi2_uniformity(&positions, w.cols, group_size, alpha).reject {
            rejected += 1;
        }
    }
    rejected as f64 / w.rows as f64
}

/// Histogram of a slice (Fig 1b): `bins` equal-width buckets over
/// [min, max]; returns (edges, counts).
pub fn histogram(values: &[f32], bins: usize) -> (Vec<f64>, Vec<usize>) {
    let (lo, hi) = crate::quant::min_max(values);
    let lo = lo as f64;
    let hi = hi as f64;
    let width = ((hi - lo) / bins as f64).max(1e-12);
    let mut counts = vec![0usize; bins];
    for &v in values {
        let b = (((v as f64 - lo) / width) as usize).min(bins - 1);
        counts[b] += 1;
    }
    let edges = (0..=bins).map(|i| lo + width * i as f64).collect();
    (edges, counts)
}

/// Critical value helper re-export for harness display.
pub fn chi2_crit(dof: f64, alpha: f64) -> f64 {
    chi2_critical(dof, alpha)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synthzoo::{family, LayerType};
    use crate::util::prng::Rng;

    #[test]
    fn uniform_positions_rarely_rejected() {
        // False-positive rate at α=0.05 must be ≈5 %.
        let mut rng = Rng::new(3);
        let (cols, group, k) = (2048, 256, 128);
        let mut rejected = 0;
        let trials = 400;
        for _ in 0..trials {
            let positions = rng.sample_indices(cols, k);
            if chi2_uniformity(&positions, cols, group, 0.05).reject {
                rejected += 1;
            }
        }
        let rate = rejected as f64 / trials as f64;
        assert!(rate < 0.10, "uniform rejection rate {}", rate);
    }

    #[test]
    fn clustered_positions_always_rejected() {
        // All outliers in one group — must reject with overwhelming
        // confidence.
        let positions: Vec<usize> = (0..128).collect();
        let res = chi2_uniformity(&positions, 2048, 256, 0.05);
        assert!(res.reject);
        assert!(res.p_value < 1e-10);
    }

    #[test]
    fn group_frequency_counts() {
        let positions = [0usize, 1, 255, 256, 600];
        let f = group_frequency(&positions, 1024, 256);
        assert_eq!(f, vec![3, 1, 1, 0]);
    }

    #[test]
    fn range_taken_gaussian_row_matches_theory() {
        // Gaussian row of width 4096: top-5 % spans ≈ 1 − 1.96/max ≈ 50 %.
        let mut rng = Rng::new(7);
        let row: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let taken = range_taken_by_outliers(&row, 0.05);
        assert!((0.33..0.65).contains(&taken), "taken={}", taken);
        // More outliers take more range; monotone.
        let taken10 = range_taken_by_outliers(&row, 0.10);
        assert!(taken10 > taken);
    }

    #[test]
    fn table1_shape_reproduced() {
        // q_proj near the 5 % false-positive floor; o_proj far above it —
        // the Table 1 anomaly. Uses the paper's setup: groups of 256,
        // γ = 6.25 %, α = 0.05, on the wide statistics layers.
        let f = family("llama2-7b").unwrap();
        let q = f.gen_stat_layer(LayerType::QProj, 1);
        let o = f.gen_stat_layer(LayerType::OProj, 1);
        let rq = rejection_rate(&q, 0.0625, 256, 0.05);
        let ro = rejection_rate(&o, 0.0625, 256, 0.05);
        assert!(rq < 0.15, "q_proj rejection {}", rq);
        assert!(ro > 0.4, "o_proj rejection {}", ro);
        assert!(ro > rq * 3.0);
    }

    #[test]
    fn histogram_sums_to_n() {
        let vals: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.013).sin()).collect();
        let (edges, counts) = histogram(&vals, 32);
        assert_eq!(edges.len(), 33);
        assert_eq!(counts.iter().sum::<usize>(), 1000);
    }

    #[test]
    fn chi2_critical_sane() {
        // group 256 over 2048 cols → dof 7; crit at 0.05 ≈ 14.07.
        let c = chi2_crit(7.0, 0.05);
        assert!((c - 14.067).abs() < 0.01, "crit {}", c);
    }
}

//! PJRT runtime: load AOT-lowered HLO text, compile once, execute from
//! the serving/eval hot path.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1 CPU): HLO **text** is the
//! interchange format — jax ≥0.5 emits 64-bit instruction ids in
//! serialized protos which this XLA rejects; the text parser reassigns
//! ids (see /opt/xla-example/README.md). Executables are compiled lazily
//! and cached per entry name; model weights can be uploaded once as
//! device buffers and reused across calls ([`Engine::upload`]).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Input/output spec of one AOT entry (from aot_manifest.json).
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed aot_manifest.json.
#[derive(Clone, Debug)]
pub struct AotManifest {
    pub eval_batch: usize,
    pub prefill_len: usize,
    pub buckets: Vec<usize>,
    pub q_bits: Vec<usize>,
    pub entries: HashMap<String, EntrySpec>,
}

fn parse_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .context("specs not array")?
        .iter()
        .map(|s| {
            Ok(TensorSpec {
                shape: s
                    .req("shape")?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
                dtype: s.req("dtype")?.as_str().context("dtype")?.to_string(),
            })
        })
        .collect()
}

impl AotManifest {
    pub fn load(dir: &Path) -> Result<AotManifest> {
        let path = dir.join("aot_manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("aot manifest: {}", e))?;
        let mut entries = HashMap::new();
        for e in j.req("entries")?.as_arr().context("entries")? {
            let name = e.req("name")?.as_str().context("name")?.to_string();
            entries.insert(
                name.clone(),
                EntrySpec {
                    name,
                    file: e.req("file")?.as_str().context("file")?.to_string(),
                    inputs: parse_specs(e.req("inputs")?)?,
                    outputs: parse_specs(e.req("outputs")?)?,
                },
            );
        }
        let usize_arr = |key: &str| -> Result<Vec<usize>> {
            j.req(key)?
                .as_arr()
                .context("arr")?
                .iter()
                .map(|v| v.as_usize().context("elem"))
                .collect()
        };
        Ok(AotManifest {
            eval_batch: j.req("eval_batch")?.as_usize().context("eval_batch")?,
            prefill_len: j.req("prefill_len")?.as_usize().context("prefill_len")?,
            buckets: usize_arr("buckets")?,
            q_bits: usize_arr("q_bits")?,
            entries,
        })
    }
}

/// A host-side tensor heading into PJRT.
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostTensor::F32(data, shape) => {
                let lit = xla::Literal::vec1(data);
                if shape.is_empty() {
                    Ok(lit.reshape(&[])?)
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    Ok(lit.reshape(&dims)?)
                }
            }
            HostTensor::I32(data, shape) => {
                let lit = xla::Literal::vec1(data);
                if shape.is_empty() {
                    Ok(lit.reshape(&[])?)
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    Ok(lit.reshape(&dims)?)
                }
            }
        }
    }
}

/// A device buffer paired with the host literal it was copied from.
/// The literal must outlive the buffer because the host→device copy is
/// asynchronous (see [`Engine::upload`]).
pub struct ResidentBuffer {
    buffer: xla::PjRtBuffer,
    _literal: xla::Literal,
}

impl ResidentBuffer {
    pub fn buffer(&self) -> &xla::PjRtBuffer {
        &self.buffer
    }
}

/// The PJRT engine: one CPU client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: AotManifest,
    dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Compile-time per entry (for metrics/EXPERIMENTS.md).
    pub compile_ms: HashMap<String, f64>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = AotManifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            executables: HashMap::new(),
            compile_ms: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &AotManifest {
        &self.manifest
    }

    /// Compile (or fetch cached) an entry.
    pub fn prepare(&mut self, entry: &str) -> Result<()> {
        if self.executables.contains_key(entry) {
            return Ok(());
        }
        let spec = self
            .manifest
            .entries
            .get(entry)
            .ok_or_else(|| anyhow!("unknown AOT entry '{}'", entry))?;
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {}", path.display(), e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compile_ms
            .insert(entry.to_string(), t0.elapsed().as_secs_f64() * 1e3);
        self.executables.insert(entry.to_string(), exe);
        Ok(())
    }

    /// Execute an entry with host tensors; returns untupled output
    /// literals.
    pub fn execute(&mut self, entry: &str, args: &[HostTensor]) -> Result<Vec<xla::Literal>> {
        self.prepare(entry)?;
        let spec = &self.manifest.entries[entry];
        if args.len() != spec.inputs.len() {
            bail!(
                "entry '{}' expects {} inputs, got {}",
                entry,
                spec.inputs.len(),
                args.len()
            );
        }
        let literals: Vec<xla::Literal> =
            args.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.execute_literals(entry, &refs)
    }

    /// Execute with pre-built literal references (the weight literals are
    /// built once by the coordinator and borrowed on every call — no
    /// per-call host copies).
    pub fn execute_literals(
        &mut self,
        entry: &str,
        literals: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.prepare(entry)?;
        let exe = &self.executables[entry];
        let result = exe.execute::<&xla::Literal>(literals)?;
        Self::untuple(result)
    }

    /// Upload a host literal to the device once; the returned
    /// [`ResidentBuffer`] can be reused across any number of
    /// [`Engine::execute_buffers`] calls. This is the §Perf optimization
    /// that removes the per-step weight copy from the decode loop
    /// (EXPERIMENTS.md §Perf).
    ///
    /// `BufferFromHostLiteral` copies **asynchronously**, so the source
    /// literal is moved into the returned handle and kept alive for the
    /// buffer's lifetime — dropping it early is a use-after-free inside
    /// XLA (observed as SIGSEGV with xla_extension 0.5.1).
    pub fn upload(&self, lit: xla::Literal) -> Result<ResidentBuffer> {
        let buffer = self.client.buffer_from_host_literal(None, &lit)?;
        Ok(ResidentBuffer { buffer, _literal: lit })
    }

    /// Upload a batch of literals (e.g. the model weights), then **block
    /// until every copy has landed**. The TFRT CPU client's async
    /// `CopyFromLiteral` tasks race with concurrent XLA compilation
    /// (observed SIGSEGV inside `ShapeUtil::ByteSizeOf` when a compile
    /// overlapped in-flight copies); the bulk upload path always runs
    /// near a compile, so it synchronizes. The crate exposes no
    /// buffer-ready wait, so we force completion with a readback of each
    /// buffer — load-time only, ~µs/MB.
    pub fn upload_all(&self, lits: Vec<xla::Literal>) -> Result<Vec<ResidentBuffer>> {
        let bufs: Vec<ResidentBuffer> =
            lits.into_iter().map(|l| self.upload(l)).collect::<Result<_>>()?;
        for b in &bufs {
            let _ = b.buffer.to_literal_sync()?; // barrier
        }
        Ok(bufs)
    }

    /// Execute with device-resident buffers (weights uploaded once via
    /// [`Engine::upload_all`], per-call data uploaded via
    /// [`Engine::upload`]).
    pub fn execute_buffers(
        &mut self,
        entry: &str,
        buffers: &[&ResidentBuffer],
    ) -> Result<Vec<xla::Literal>> {
        self.prepare(entry)?;
        let exe = &self.executables[entry];
        let refs: Vec<&xla::PjRtBuffer> = buffers.iter().map(|b| &b.buffer).collect();
        let result = exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        Self::untuple(result)
    }

    fn untuple(result: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
        let out = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("empty execution result"))?
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        Ok(out.to_tuple()?)
    }

    /// Read back a literal as f32s.
    pub fn literal_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        Ok(lit.to_vec::<f32>()?)
    }

    /// Read back the first element of a scalar f32 literal.
    pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
        Ok(lit.get_first_element::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_literal_shapes() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.element_count(), 4);
        let t = HostTensor::scalar_i32(7);
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.element_count(), 1);
        assert_eq!(lit.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join("icq_rt_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("aot_manifest.json"),
            r#"{"eval_batch": 4, "prefill_len": 64, "buckets": [1, 2],
                "q_bits": [2], "config": {},
                "entries": [{"name": "e1", "file": "e1.hlo.txt",
                  "inputs": [{"shape": [4, 128], "dtype": "i32"}],
                  "outputs": [{"shape": [], "dtype": "f32"}]}]}"#,
        )
        .unwrap();
        let m = AotManifest::load(&dir).unwrap();
        assert_eq!(m.eval_batch, 4);
        assert_eq!(m.buckets, vec![1, 2]);
        let e = &m.entries["e1"];
        assert_eq!(e.inputs[0].shape, vec![4, 128]);
        assert_eq!(e.outputs[0].dtype, "f32");
    }

    // Engine execution against real HLO is covered by rust/tests/
    // integration tests (requires `make artifacts`).
}

//! In-tree static analysis (`icquant lint`) — DESIGN.md §13.
//!
//! A dependency-free source-model checker: `lexer` strips comments and
//! strings, `model` builds a per-file view (fn spans, unsafe sites, test
//! spans, tag lookup), `checks` runs the checkers over it. The pass
//! self-hosts: ci.sh runs `icquant lint` as a hard gate, so the real tree
//! must stay at zero diagnostics.

pub mod checks;
pub mod lexer;
pub mod model;

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use model::FileModel;

/// One checker finding, pointing at a repo-relative file:line.
#[derive(Clone)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub check: &'static str,
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.check, self.message)
    }
}

pub struct LintReport {
    /// Number of `.rs` files analyzed.
    pub files: usize,
    pub diagnostics: Vec<Diagnostic>,
}

impl LintReport {
    pub fn to_json(&self) -> Json {
        let diags = self
            .diagnostics
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("file", Json::str(d.file.clone())),
                    ("line", Json::num(d.line as f64)),
                    ("check", Json::str(d.check)),
                    ("message", Json::str(d.message.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("files", Json::num(self.files as f64)),
            ("count", Json::num(self.diagnostics.len() as f64)),
            ("diagnostics", Json::arr(diags)),
        ])
    }
}

/// Directories (relative to the repo root) the walker scans for `.rs`
/// sources. `lint_fixtures` (deliberately-bad test inputs) and build
/// output are excluded.
const SCAN_DIRS: &[&str] = &["rust/src", "rust/benches", "rust/tests", "examples"];
const SKIP_DIRS: &[&str] = &["lint_fixtures", "target"];

/// Locate the repo root by walking up from `start` until a directory
/// containing `rust/Cargo.toml` is found.
pub fn find_root(start: &Path) -> Result<PathBuf> {
    let mut p = start.to_path_buf();
    loop {
        if p.join("rust/Cargo.toml").is_file() {
            return Ok(p);
        }
        if !p.pop() {
            bail!(
                "could not locate the repo root (no rust/Cargo.toml above {}); pass --root",
                start.display()
            );
        }
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in fs::read_dir(dir).with_context(|| format!("read_dir {}", dir.display()))? {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if path.is_dir() {
            if !SKIP_DIRS.contains(&name) {
                collect_rs(&path, out)?;
            }
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run every per-file checker on one source text, as if it lived at
/// `rel`. This is the entry point fixture tests drive; `lint` uses it for
/// every walked file.
pub fn analyze_source(rel: &str, src: &str) -> Vec<Diagnostic> {
    let m = FileModel::build(rel, src);
    let mut out = Vec::new();
    checks::safety(&m, &mut out);
    checks::ordering(&m, &mut out);
    checks::hot_path(&m, &mut out);
    checks::panic_policy(&m, &mut out);
    out
}

/// Run the full pass (per-file checkers plus the tree-level DESIGN-ref,
/// BENCH-key, and trace-name checkers) over the repo at `root`.
pub fn lint(root: &Path) -> Result<LintReport> {
    let mut paths = Vec::new();
    for dir in SCAN_DIRS {
        collect_rs(&root.join(dir), &mut paths)?;
    }
    paths.sort();
    if paths.is_empty() {
        bail!("no .rs sources under {} — wrong --root?", root.display());
    }

    let mut models = Vec::new();
    for p in &paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(p).with_context(|| format!("read {}", p.display()))?;
        models.push(FileModel::build(&rel, &src));
    }

    let mut diags = Vec::new();
    for m in &models {
        checks::safety(m, &mut diags);
        checks::ordering(m, &mut diags);
        checks::hot_path(m, &mut diags);
        checks::panic_policy(m, &mut diags);
    }

    let design = fs::read_to_string(root.join("DESIGN.md")).unwrap_or_default();
    let sections = checks::design_sections(&design);
    for m in &models {
        checks::design_refs(m, &sections, &mut diags);
    }

    if let Ok(ci) = fs::read_to_string(root.join("ci.sh")) {
        let benches: Vec<&FileModel> =
            models.iter().filter(|m| m.rel.starts_with("rust/benches/")).collect();
        checks::bench_keys("ci.sh", &ci, &benches, &mut diags);
    }

    match models.iter().find(|m| m.rel == "rust/src/trace/names.rs") {
        Some(names) => {
            let registry = checks::trace_registry(names, &mut diags);
            let mut used = BTreeSet::new();
            for m in &models {
                checks::trace_names(m, &registry, &mut used, &mut diags);
            }
            checks::trace_unused(names, &registry, &used, &mut diags);
        }
        None => diags.push(Diagnostic {
            file: "rust/src/trace/names.rs".to_string(),
            line: 1,
            check: "trace-names",
            message: "trace event name registry is missing".to_string(),
        }),
    }

    diags.sort_by(|a, b| (&a.file, a.line, a.check).cmp(&(&b.file, b.line, b.check)));
    Ok(LintReport { files: models.len(), diagnostics: diags })
}

//! The lint checkers (DESIGN.md §13). Each checker pushes
//! [`Diagnostic`]s; an empty vector after all checkers means the tree is
//! lint-clean. Per-file checkers take one [`FileModel`]; tree-level
//! checkers (bench keys, trace-name registry) take extra context from the
//! driver in `mod.rs`.

use std::collections::{BTreeMap, BTreeSet};

use super::model::{FileModel, UnsafeKind};
use super::Diagnostic;

fn diag(f: &FileModel, line: usize, check: &'static str, message: String) -> Diagnostic {
    Diagnostic { file: f.rel.clone(), line, check, message }
}

// ---------------------------------------------------------------------------
// Checker 1: SAFETY — every unsafe block/impl/trait carries `// SAFETY:`,
// every `unsafe fn` declaration a `# Safety` doc section.
// ---------------------------------------------------------------------------

pub fn safety(f: &FileModel, out: &mut Vec<Diagnostic>) {
    for site in &f.unsafe_sites {
        let (ok, what, want) = match site.kind {
            UnsafeKind::Block | UnsafeKind::Impl | UnsafeKind::Trait => {
                let ok = f.comment(site.line).contains("SAFETY:")
                    || f.comment_run_above(site.line, &|_| false).contains("SAFETY:");
                let what = match site.kind {
                    UnsafeKind::Block => "unsafe block",
                    UnsafeKind::Impl => "unsafe impl",
                    _ => "unsafe trait",
                };
                (ok, what, "a `// SAFETY:` comment")
            }
            UnsafeKind::Fn => {
                let doc = f.comment_run_above(site.line, &|_| false);
                let ok = doc.contains("# Safety") || doc.contains("SAFETY:");
                (ok, "unsafe fn", "a `# Safety` doc section")
            }
        };
        if !ok {
            out.push(diag(f, site.line, "safety", format!("{what} without {want}")));
        }
    }
}

// ---------------------------------------------------------------------------
// Checker 2: ORDERING — every Relaxed/SeqCst use in non-test code carries
// an `// ORDERING:` justification at the site, on the cluster's shared
// comment, or in the enclosing fn's doc. Acquire/Release/AcqRel encode
// their intent in the name and are exempt.
// ---------------------------------------------------------------------------

pub fn ordering(f: &FileModel, out: &mut Vec<Diagnostic>) {
    if f.is_test_file {
        return;
    }
    let is_site = |c: &str| c.contains("Ordering::Relaxed") || c.contains("Ordering::SeqCst");
    for l in 1..=f.lines() {
        if f.in_test(l) {
            continue;
        }
        let code = f.code(l);
        if !is_site(code) {
            continue;
        }
        if code.trim_start().starts_with("use ") {
            out.push(diag(
                f,
                l,
                "ordering",
                "import the `Ordering` enum, not its variants — each call site \
                 must name and justify its ordering"
                    .to_string(),
            ));
            continue;
        }
        let justified = f.comment(l).contains("ORDERING:")
            || f.comment_run_above(l, &is_site).contains("ORDERING:")
            || f
                .enclosing_fn(l)
                .map(|fi| f.fn_doc(fi).contains("ORDERING:"))
                .unwrap_or(false);
        if !justified {
            out.push(diag(
                f,
                l,
                "ordering",
                "`Ordering::Relaxed`/`SeqCst` without an `// ORDERING:` justification \
                 (site comment, cluster comment, or enclosing fn doc)"
                    .to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// Checker 3: hot-path — fns tagged `// lint: hot-path` must not allocate
// or take locks. The ban list is substring-based over comment-stripped,
// string-blanked code, so `"format!"` inside a string cannot trip it.
// ---------------------------------------------------------------------------

/// A tag is a plain comment line that *starts with* this text — prose
/// mentions inside doc comments (like this one) never count.
pub const HOT_PATH_TAG: &str = "// lint: hot-path";

fn is_tag_line(comment: &str) -> bool {
    comment.trim_start().starts_with(HOT_PATH_TAG)
}

const HOT_PATH_BANNED: &[&str] = &[
    "Vec::new",
    "Vec::with_capacity",
    "vec!",
    "Box::new",
    "String::new",
    "String::from",
    "format!",
    ".to_string(",
    ".to_vec(",
    ".to_owned(",
    ".clone(",
    ".collect(",
    ".push(",
    ".push_str(",
    ".extend(",
    ".insert(",
    ".resize(",
    ".reserve(",
    "Mutex::new",
    "RwLock::new",
    ".lock(",
    ".wait(",
    ".join(",
];

pub fn hot_path(f: &FileModel, out: &mut Vec<Diagnostic>) {
    if f.is_test_file {
        return;
    }
    // Every comment line carrying the tag must end up attached to a fn.
    let mut dangling: BTreeSet<usize> = (1..=f.lines())
        .filter(|&l| is_tag_line(f.comment(l)))
        .collect();
    for fi in &f.fns {
        if !f.fn_doc(fi).lines().any(is_tag_line) {
            continue;
        }
        // Consume the tag line(s) in this fn's doc run.
        let mut l = fi.line.wrapping_sub(1);
        while l >= 1 {
            let code = f.code(l).trim();
            if code.is_empty() && !f.comment(l).is_empty() {
                dangling.remove(&l);
            } else if !code.starts_with("#[") {
                break;
            }
            l -= 1;
        }
        let Some((open, close)) = fi.body else {
            out.push(diag(
                f,
                fi.line,
                "hot-path",
                format!("fn `{}` is tagged hot-path but has no body to check", fi.name),
            ));
            continue;
        };
        for l in open..=close {
            let code = f.code(l);
            for pat in HOT_PATH_BANNED {
                if code.contains(pat) {
                    out.push(diag(
                        f,
                        l,
                        "hot-path",
                        format!(
                            "`{pat}` inside hot-path fn `{}` — tagged paths must not \
                             allocate or take locks",
                            fi.name
                        ),
                    ));
                }
            }
        }
    }
    for l in dangling {
        out.push(diag(
            f,
            l,
            "hot-path",
            "`// lint: hot-path` tag is not attached to a fn declaration".to_string(),
        ));
    }
}

// ---------------------------------------------------------------------------
// Checker 4: panic policy — `.unwrap()` / `.expect(` forbidden in
// coordinator/, kernels/, trace/ non-test code unless justified with
// `// PANIC:`. Lock-poisoning propagation (`.lock().unwrap()` and
// `cv.wait(g).unwrap()`) is idiomatic and exempt.
// ---------------------------------------------------------------------------

pub fn panic_policy(f: &FileModel, out: &mut Vec<Diagnostic>) {
    let scoped = ["rust/src/coordinator/", "rust/src/kernels/", "rust/src/trace/"]
        .iter()
        .any(|p| f.rel.starts_with(p));
    if !scoped || f.is_test_file {
        return;
    }
    let is_site = |c: &str| c.contains(".unwrap()") || c.contains(".expect(");
    for l in 1..=f.lines() {
        if f.in_test(l) {
            continue;
        }
        let code = f.code(l);
        let mut sites = Vec::new();
        for pat in [".unwrap()", ".expect("] {
            let mut start = 0;
            while let Some(p) = code[start..].find(pat) {
                let abs = start + p;
                let exempt = pat == ".unwrap()" && is_poison_propagation(&code[..abs]);
                if !exempt {
                    sites.push(pat);
                }
                start = abs + pat.len();
            }
        }
        if sites.is_empty() {
            continue;
        }
        let justified = f.comment(l).contains("PANIC:")
            || f.comment_run_above(l, &is_site).contains("PANIC:");
        if !justified {
            out.push(diag(
                f,
                l,
                "panic",
                format!(
                    "`{}` in {} without a `// PANIC:` justification",
                    sites[0],
                    f.rel.rsplit('/').nth(1).unwrap_or("scoped code")
                ),
            ));
        }
    }
}

/// True when the expression ending at this point is `.lock()` or
/// `cv.wait(guard)` — unwrapping those propagates lock poisoning, which
/// is the crate-wide idiom and needs no per-site note.
fn is_poison_propagation(prefix: &str) -> bool {
    if prefix.ends_with(".lock()") {
        return true;
    }
    if let Some(p) = prefix.rfind(".wait(") {
        let inner = &prefix[p + ".wait(".len()..];
        if let Some(arg) = inner.strip_suffix(')') {
            return !arg.is_empty()
                && arg.chars().all(|c| c.is_alphanumeric() || c == '_');
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Checker 5a: design-doc section references must resolve to a real
// `## §N` header (the needle itself is spelled only in strings here, so
// the comment-only scan cannot trip over this file).
// ---------------------------------------------------------------------------

/// Section numbers declared by `## §N` headers in DESIGN.md.
pub fn design_sections(design: &str) -> BTreeSet<u32> {
    let mut out = BTreeSet::new();
    for line in design.lines() {
        if let Some(rest) = line.trim_start().strip_prefix("## §") {
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            if let Ok(n) = digits.parse() {
                out.insert(n);
            }
        }
    }
    out
}

pub fn design_refs(f: &FileModel, sections: &BTreeSet<u32>, out: &mut Vec<Diagnostic>) {
    for l in 1..=f.lines() {
        // Comments only: references live in rustdoc prose, and scanning
        // string literals would flag this checker's own search pattern.
        for text in [f.comment(l)] {
            let mut rest = text;
            while let Some(p) = rest.find("DESIGN.md §") {
                rest = &rest[p + "DESIGN.md §".len()..];
                let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
                match digits.parse::<u32>() {
                    Ok(n) if sections.contains(&n) => {}
                    Ok(n) => out.push(diag(
                        f,
                        l,
                        "design-ref",
                        format!("`DESIGN.md §{n}` does not resolve to a `## §{n}` section"),
                    )),
                    Err(_) => out.push(diag(
                        f,
                        l,
                        "design-ref",
                        "`DESIGN.md §` reference without a section number".to_string(),
                    )),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Checker 5b: every BENCH key ci.sh greps must be emitted by a bench
// source, so the gate can never silently grep for a key nobody writes.
// ---------------------------------------------------------------------------

pub fn bench_keys(
    ci_rel: &str,
    ci_text: &str,
    benches: &[&FileModel],
    out: &mut Vec<Diagnostic>,
) {
    // Join backslash-continued lines first (the key lists wrap), keeping
    // the logical line anchored at its first physical line.
    let mut logical: Vec<(usize, String)> = Vec::new();
    for (li, raw) in ci_text.lines().enumerate() {
        match logical.last_mut() {
            Some((_, prev)) if prev.ends_with('\\') => {
                prev.pop();
                prev.push(' ');
                prev.push_str(raw.trim_start());
            }
            _ => logical.push((li + 1, raw.to_string())),
        }
    }
    for (li, line) in &logical {
        let li = *li;
        let Some(rest) = line.trim_start().strip_prefix("for key in ") else {
            continue;
        };
        let list = rest.split(';').next().unwrap_or("");
        for key in list.split_whitespace() {
            let needle = format!("\"{key}\"");
            let emitted = benches
                .iter()
                .any(|b| b.stripped.code_str.iter().any(|l| l.contains(&needle)));
            if !emitted {
                out.push(Diagnostic {
                    file: ci_rel.to_string(),
                    line: li,
                    check: "bench-keys",
                    message: format!(
                        "ci.sh greps for BENCH key \"{key}\" but no bench source emits it"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Checker 6: trace event names — unique string literals drawn from the
// `trace::names` registry; every registered name is recorded somewhere.
// ---------------------------------------------------------------------------

/// Parse `pub const NAME: &str = "value";` lines out of
/// `rust/src/trace/names.rs`. Returns name -> declaration line and
/// reports duplicate values.
pub fn trace_registry(
    names: &FileModel,
    out: &mut Vec<Diagnostic>,
) -> BTreeMap<String, usize> {
    let mut reg = BTreeMap::new();
    for l in 1..=names.lines() {
        let t = names.stripped.code_str[l - 1].trim_start();
        if !(t.starts_with("pub const ") && t.contains(": &str = \"")) {
            continue;
        }
        let Some(v) = t.split('"').nth(1).filter(|v| !v.is_empty()) else {
            continue;
        };
        if let Some(prev) = reg.insert(v.to_string(), l) {
            out.push(diag(
                names,
                l,
                "trace-names",
                format!("duplicate trace event name \"{v}\" (also registered on line {prev})"),
            ));
        }
    }
    if reg.is_empty() {
        out.push(diag(
            names,
            1,
            "trace-names",
            "trace name registry declares no `pub const NAME: &str = \"…\";` entries"
                .to_string(),
        ));
    }
    reg
}

pub fn trace_names(
    f: &FileModel,
    registry: &BTreeMap<String, usize>,
    used: &mut BTreeSet<String>,
    out: &mut Vec<Diagnostic>,
) {
    if f.is_test_file || !f.rel.starts_with("rust/src/") || f.rel.ends_with("trace/names.rs") {
        return;
    }
    // Call sites are detected on the string-*blanked* view, so the
    // pattern list below (string literals in this very file) can never
    // match itself; the event name is then read from the char-aligned
    // string-preserved view.
    for l in 1..=f.lines() {
        if f.in_test(l) {
            continue;
        }
        let line = &f.stripped.code[l - 1];
        for pat in ["trace::instant(", "trace::span_args(", "trace::span("] {
            let mut start = 0;
            while let Some(p) = line[start..].find(pat) {
                let abs = start + p;
                match second_arg_literal(f, l, abs + pat.len()) {
                    Some(name) => {
                        if !registry.contains_key(&name) {
                            out.push(diag(
                                f,
                                l,
                                "trace-names",
                                format!(
                                    "trace event name \"{name}\" is not registered in \
                                     trace::names"
                                ),
                            ));
                        }
                        used.insert(name);
                    }
                    None => out.push(diag(
                        f,
                        l,
                        "trace-names",
                        "trace event name must be a string literal from the \
                         trace::names registry"
                            .to_string(),
                    )),
                }
                start = abs + pat.len();
            }
        }
    }
}

pub fn trace_unused(
    names: &FileModel,
    registry: &BTreeMap<String, usize>,
    used: &BTreeSet<String>,
    out: &mut Vec<Diagnostic>,
) {
    for (name, &line) in registry {
        if !used.contains(name) {
            out.push(diag(
                names,
                line,
                "trace-names",
                format!("registered trace event \"{name}\" is never recorded"),
            ));
        }
    }
}

/// Read the second call argument starting after the `(` at byte `col` of
/// line `l`; returns it when it is a plain string literal, spanning up
/// to 8 source lines for rustfmt-wrapped calls. Structure (nesting, the
/// argument comma, the quote delimiters) is walked on the blanked view;
/// the literal's characters come from the char-aligned preserved view.
fn second_arg_literal(f: &FileModel, l: usize, col: usize) -> Option<String> {
    // `col` is a byte offset into the blanked view; convert to a char
    // offset once — the two views are char-aligned, not byte-aligned.
    let skip = f.stripped.code[l - 1][..col].chars().count();
    let chars_from = |lines: &[String]| -> Vec<char> {
        let mut out: Vec<char> = lines[l - 1].chars().skip(skip).collect();
        for extra in l..(l + 8).min(f.lines()) {
            out.push('\n');
            out.extend(lines[extra].chars());
        }
        out
    };
    let code = chars_from(&f.stripped.code);
    let kept = chars_from(&f.stripped.code_str);
    let mut depth = 0i32;
    let mut i = 0usize;
    // Skip the first argument on the blanked view.
    loop {
        let c = *code.get(i)?;
        i += 1;
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => {
                if depth == 0 {
                    return None; // single-argument call
                }
                depth -= 1;
            }
            ',' if depth == 0 => break,
            '"' => return None,
            _ => {}
        }
    }
    while code.get(i).is_some_and(|c| c.is_whitespace()) {
        i += 1;
    }
    if *code.get(i)? != '"' {
        return None;
    }
    i += 1;
    let mut name = String::new();
    while let Some(&c) = code.get(i) {
        if c == '"' {
            return Some(name);
        }
        name.push(*kept.get(i)?);
        i += 1;
    }
    None
}

//! A minimal Rust surface lexer for the in-tree lint pass (DESIGN.md §13).
//!
//! This is deliberately *not* a parser: checkers only need to know, per
//! line, (a) what is code, (b) what is comment text, and (c) where string
//! literals sit so that `"enqueue"` in a trace call can be read while
//! `".unwrap()"` inside a string cannot trip the panic checker. The lexer
//! handles line comments, nested block comments, regular / raw / byte
//! string literals, char literals, and the char-vs-lifetime ambiguity.
//! Everything else (idents, punctuation) passes through untouched.

/// Per-line views of one source file produced by [`strip`].
pub struct Stripped {
    /// Source with comments removed and string/char contents blanked to
    /// spaces (delimiters kept). Substring checks against code tokens
    /// (`.unwrap()`, `Ordering::Relaxed`, …) run on this view.
    pub code: Vec<String>,
    /// Source with comments removed but string literals intact. Literal
    /// extraction (trace event names, BENCH keys) runs on this view.
    pub code_str: Vec<String>,
    /// Comment text only, markers included. Tag lookups (`SAFETY:`,
    /// `ORDERING:`, `PANIC:`, `lint: hot-path`) run on this view.
    pub comments: Vec<String>,
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Normal,
    LineComment,
    /// Nesting depth of `/* … */`.
    BlockComment(u32),
    Str,
    /// Raw string terminated by `"` followed by this many `#`.
    RawStr(u32),
    Char,
}

/// Split `src` into the three per-line views. All three vectors have the
/// same length (one entry per source line).
pub fn strip(src: &str) -> Stripped {
    let bytes: Vec<char> = src.chars().collect();
    let mut code = Vec::new();
    let mut code_str = Vec::new();
    let mut comments = Vec::new();
    let mut lc = String::new();
    let mut ls = String::new();
    let mut lm = String::new();
    let mut mode = Mode::Normal;
    let mut i = 0usize;
    let n = bytes.len();

    macro_rules! flush_line {
        () => {{
            code.push(std::mem::take(&mut lc));
            code_str.push(std::mem::take(&mut ls));
            comments.push(std::mem::take(&mut lm));
        }};
    }

    while i < n {
        let c = bytes[i];
        if c == '\n' {
            if mode == Mode::LineComment {
                mode = Mode::Normal;
            }
            flush_line!();
            i += 1;
            continue;
        }
        match mode {
            Mode::Normal => {
                let next = bytes.get(i + 1).copied();
                let prev_ident = i > 0 && (bytes[i - 1].is_alphanumeric() || bytes[i - 1] == '_');
                if c == '/' && next == Some('/') {
                    mode = Mode::LineComment;
                    lm.push_str("//");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(1);
                    lm.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    mode = Mode::Str;
                    lc.push('"');
                    ls.push('"');
                    i += 1;
                } else if !prev_ident && (c == 'r' || c == 'b') {
                    // Raw / byte string or byte char prefixes: r" r#" br" b" b'
                    let mut j = i;
                    let mut raw = false;
                    if bytes.get(j).copied() == Some('b') {
                        j += 1;
                    }
                    if bytes.get(j).copied() == Some('r') {
                        raw = true;
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while raw && bytes.get(j).copied() == Some('#') {
                        hashes += 1;
                        j += 1;
                    }
                    match bytes.get(j).copied() {
                        Some('"') if raw => {
                            for k in i..=j {
                                lc.push(bytes[k]);
                                ls.push(bytes[k]);
                            }
                            mode = Mode::RawStr(hashes);
                            i = j + 1;
                        }
                        Some('"') if c == 'b' && j == i + 1 => {
                            lc.push('b');
                            ls.push('b');
                            lc.push('"');
                            ls.push('"');
                            mode = Mode::Str;
                            i = j + 1;
                        }
                        Some('\'') if c == 'b' && j == i + 1 => {
                            lc.push('b');
                            ls.push('b');
                            lc.push('\'');
                            ls.push('\'');
                            mode = Mode::Char;
                            i = j + 1;
                        }
                        _ => {
                            lc.push(c);
                            ls.push(c);
                            i += 1;
                        }
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: 'x' / '\n' are chars,
                    // 'static is a lifetime (no closing quote after one
                    // symbol).
                    let is_char = match next {
                        Some('\\') => true,
                        Some(_) => bytes.get(i + 2).copied() == Some('\''),
                        None => false,
                    };
                    lc.push('\'');
                    ls.push('\'');
                    if is_char {
                        mode = Mode::Char;
                    }
                    i += 1;
                } else {
                    lc.push(c);
                    ls.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                lm.push(c);
                i += 1;
            }
            Mode::BlockComment(d) => {
                let next = bytes.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    lm.push_str("*/");
                    mode = if d == 1 { Mode::Normal } else { Mode::BlockComment(d - 1) };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    lm.push_str("/*");
                    mode = Mode::BlockComment(d + 1);
                    i += 2;
                } else {
                    lm.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    lc.push(' ');
                    ls.push(c);
                    if let Some(e) = bytes.get(i + 1).copied() {
                        if e != '\n' {
                            lc.push(' ');
                            ls.push(e);
                            i += 1;
                        }
                    }
                    i += 1;
                } else if c == '"' {
                    lc.push('"');
                    ls.push('"');
                    mode = Mode::Normal;
                    i += 1;
                } else {
                    lc.push(' ');
                    ls.push(c);
                    i += 1;
                }
            }
            Mode::RawStr(h) => {
                if c == '"' {
                    let mut k = 0u32;
                    while (k as usize) < n - i - 1 && bytes[i + 1 + k as usize] == '#' && k < h {
                        k += 1;
                    }
                    if k == h {
                        lc.push('"');
                        ls.push('"');
                        for _ in 0..h {
                            lc.push('#');
                            ls.push('#');
                        }
                        mode = Mode::Normal;
                        i += 1 + h as usize;
                    } else {
                        lc.push(' ');
                        ls.push(c);
                        i += 1;
                    }
                } else {
                    lc.push(' ');
                    ls.push(c);
                    i += 1;
                }
            }
            Mode::Char => {
                if c == '\\' {
                    lc.push(' ');
                    ls.push(c);
                    if let Some(e) = bytes.get(i + 1).copied() {
                        if e != '\n' {
                            lc.push(' ');
                            ls.push(e);
                            i += 1;
                        }
                    }
                    i += 1;
                } else if c == '\'' {
                    lc.push('\'');
                    ls.push('\'');
                    mode = Mode::Normal;
                    i += 1;
                } else {
                    lc.push(' ');
                    ls.push(c);
                    i += 1;
                }
            }
        }
    }
    flush_line!();
    Stripped { code, code_str, comments }
}

/// One lexical token from the comment-stripped code view.
pub struct Tok {
    /// Identifier / keyword / number text, or a single punctuation char.
    pub text: String,
    /// 1-based source line.
    pub line: usize,
}

/// Tokenize the stripped code lines into identifiers and punctuation.
/// Lifetimes (`'a`) come out as a `'` punct followed by an ident, which
/// no checker confuses with anything meaningful.
pub fn tokens(code: &[String]) -> Vec<Tok> {
    let mut out = Vec::new();
    for (li, line) in code.iter().enumerate() {
        let chars: Vec<char> = line.chars().collect();
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            if c.is_whitespace() {
                i += 1;
            } else if c.is_alphanumeric() || c == '_' {
                let start = i;
                while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
                out.push(Tok { text: chars[start..i].iter().collect(), line: li + 1 });
            } else {
                out.push(Tok { text: c.to_string(), line: li + 1 });
                i += 1;
            }
        }
    }
    out
}

//! Per-file source model for the lint pass (DESIGN.md §13): fn spans,
//! `unsafe` sites, `#[cfg(test)]` spans, and the justification-comment
//! lookup that implements the tag grammar.

use super::lexer::{self, Stripped, Tok};

/// Classification of an `unsafe` keyword occurrence.
#[derive(Clone, Copy, PartialEq, Debug)]
pub enum UnsafeKind {
    /// `unsafe { … }`
    Block,
    /// `unsafe impl Trait for T`
    Impl,
    /// `unsafe trait T`
    Trait,
    /// `unsafe fn name(…)` declaration (not a fn-pointer type)
    Fn,
}

pub struct UnsafeSite {
    pub line: usize,
    pub kind: UnsafeKind,
}

pub struct FnInfo {
    pub name: String,
    /// Line of the `fn` keyword.
    pub line: usize,
    /// Body `{ … }` open/close lines, when the fn has a body.
    pub body: Option<(usize, usize)>,
}

pub struct FileModel {
    /// Repo-relative path with `/` separators.
    pub rel: String,
    pub stripped: Stripped,
    pub fns: Vec<FnInfo>,
    pub unsafe_sites: Vec<UnsafeSite>,
    /// `#[cfg(test)]` item spans (attribute line .. closing brace line).
    pub test_spans: Vec<(usize, usize)>,
    /// Whole-file test code (anything under a `tests/` directory).
    pub is_test_file: bool,
}

impl FileModel {
    pub fn build(rel: &str, src: &str) -> FileModel {
        let stripped = lexer::strip(src);
        let toks = lexer::tokens(&stripped.code);
        let fns = find_fns(&toks);
        let unsafe_sites = find_unsafe(&toks);
        let test_spans = find_test_spans(&stripped, &toks);
        let is_test_file =
            rel.contains("/tests/") || rel.starts_with("tests/") || rel.ends_with("/build.rs");
        FileModel { rel: rel.to_string(), stripped, fns, unsafe_sites, test_spans, is_test_file }
    }

    pub fn lines(&self) -> usize {
        self.stripped.code.len()
    }

    /// Stripped code for a 1-based line ("" out of range).
    pub fn code(&self, line: usize) -> &str {
        self.stripped.code.get(line.wrapping_sub(1)).map(|s| s.as_str()).unwrap_or("")
    }

    /// Comment text for a 1-based line ("" out of range).
    pub fn comment(&self, line: usize) -> &str {
        self.stripped.comments.get(line.wrapping_sub(1)).map(|s| s.as_str()).unwrap_or("")
    }

    /// True when `line` is test code: the whole file is a test file or the
    /// line falls inside a `#[cfg(test)]` item.
    pub fn in_test(&self, line: usize) -> bool {
        self.is_test_file || self.test_spans.iter().any(|&(a, b)| a <= line && line <= b)
    }

    /// The comment run directly above `line`: pure-comment lines are
    /// collected, attribute lines (`#[…]`) are skipped, and code lines for
    /// which `skip` returns true are stepped over (so one comment can
    /// cover a cluster of same-kind sites). Any other code line or a blank
    /// line ends the run. Returns the concatenated comment text.
    pub fn comment_run_above(&self, line: usize, skip: &dyn Fn(&str) -> bool) -> String {
        let mut out = String::new();
        let mut l = line.wrapping_sub(1);
        while l >= 1 {
            let code = self.code(l).trim();
            let comment = self.comment(l);
            if code.is_empty() && !comment.is_empty() {
                out.push_str(comment);
                out.push('\n');
            } else if code.starts_with("#[") || (!code.is_empty() && skip(code)) {
                // step over attributes / same-kind sites
            } else {
                break;
            }
            l -= 1;
        }
        out
    }

    /// The fn whose body (or signature line) contains `line`.
    pub fn enclosing_fn(&self, line: usize) -> Option<&FnInfo> {
        // Innermost wins: scan for the tightest body span containing line.
        let mut best: Option<&FnInfo> = None;
        for f in &self.fns {
            if let Some((open, close)) = f.body {
                if f.line <= line && line <= close {
                    let tighter = match best.and_then(|b| b.body) {
                        Some((bo, bc)) => (close - open) < (bc - bo),
                        None => true,
                    };
                    if tighter {
                        best = Some(f);
                    }
                }
            }
        }
        best
    }

    /// Doc/comment run above a fn declaration (attributes skipped).
    pub fn fn_doc(&self, f: &FnInfo) -> String {
        self.comment_run_above(f.line, &|code: &str| {
            // Step over `pub`, `unsafe`, `extern "C"` etc. split onto their
            // own lines (rustfmt never does this, but cheap to tolerate).
            matches!(code, "pub" | "unsafe" | "const" | "async")
        })
    }
}

/// True when the token is one of the keywords that may sit between a doc
/// comment / attribute and the `fn` keyword.
fn is_fn_qualifier(t: &str) -> bool {
    matches!(t, "pub" | "const" | "async" | "unsafe" | "extern") || t.starts_with('"')
}

fn find_fns(toks: &[Tok]) -> Vec<FnInfo> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if toks[i].text == "fn" {
            // `unsafe fn(..)` / `fn(..)` in type position has `(` next.
            let name = match toks.get(i + 1) {
                Some(t) if t.text != "(" => t.text.clone(),
                _ => {
                    i += 1;
                    continue;
                }
            };
            let line = toks[i].line;
            // Find the body open brace: first `{` at paren depth 0, unless
            // a `;` (trait method decl) shows up first.
            let mut j = i + 1;
            let mut paren = 0i32;
            let mut body = None;
            while j < toks.len() {
                match toks[j].text.as_str() {
                    "(" | "[" => paren += 1,
                    ")" | "]" => paren -= 1,
                    "{" if paren == 0 => {
                        let open = toks[j].line;
                        let close = match_brace(toks, j);
                        body = Some((open, close));
                        break;
                    }
                    ";" if paren == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            out.push(FnInfo { name, line, body });
        }
        i += 1;
    }
    out
}

/// Line of the `}` matching the `{` at token index `open` (last token's
/// line when unbalanced — truncated input never panics the linter).
fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    for t in &toks[open..] {
        match t.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return t.line;
                }
            }
            _ => {}
        }
    }
    toks.last().map(|t| t.line).unwrap_or(1)
}

fn find_unsafe(toks: &[Tok]) -> Vec<UnsafeSite> {
    let mut out = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.text != "unsafe" {
            continue;
        }
        let kind = match toks.get(i + 1).map(|t| t.text.as_str()) {
            Some("{") => Some(UnsafeKind::Block),
            Some("impl") => Some(UnsafeKind::Impl),
            Some("trait") => Some(UnsafeKind::Trait),
            Some("fn") => {
                // `unsafe fn(` is a fn-pointer *type*, not a declaration.
                match toks.get(i + 2).map(|t| t.text.as_str()) {
                    Some("(") => None,
                    _ => Some(UnsafeKind::Fn),
                }
            }
            Some("extern") => {
                // `unsafe extern "C" fn name` declaration vs `unsafe
                // extern "C" fn(` type: look past the ABI string remnants.
                let mut j = i + 2;
                while toks.get(j).map(|t| is_fn_qualifier(&t.text)).unwrap_or(false) {
                    j += 1;
                }
                let at = |k: usize| toks.get(k).map(|t| t.text.as_str());
                match (at(j), at(j + 1)) {
                    (Some("fn"), Some("(")) => None,
                    (Some("fn"), _) => Some(UnsafeKind::Fn),
                    _ => None,
                }
            }
            _ => None,
        };
        if let Some(kind) = kind {
            out.push(UnsafeSite { line: t.line, kind });
        }
    }
    out
}

fn find_test_spans(stripped: &Stripped, toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for (li, line) in stripped.code.iter().enumerate() {
        let l = li + 1;
        if !line.contains("#[cfg(test)]") {
            continue;
        }
        // The attributed item's body: first `{` on or after this line.
        let open = toks.iter().position(|t| t.line >= l && t.text == "{");
        if let Some(open) = open {
            out.push((l, match_brace(toks, open)));
        }
    }
    out
}
